# AMQ reproduction build entry points.
#
# `make artifacts` runs the python L2 compile path once (data -> train ->
# hessians -> HLO text -> manifest); everything downstream (the `repro`
# binary, benches, artifact-gated integration tests) is rust-only and
# self-contained afterwards.

PYTHON ?= python3

.PHONY: artifacts artifacts-smoke test clean-artifacts

# Full build (AMQ_TRAIN_STEPS=2000 by default; ~minutes on a laptop CPU).
artifacts:
	cd python && $(PYTHON) -m compile.aot --outdir ../artifacts

# Reduced-step build for CI smoke: same artifact geometry, faster training.
# Quality-sensitive runtime assertions are not valid against this model;
# the artifact-gated host-side tests (asset validation, proxy-bank build)
# are.
artifacts-smoke:
	cd python && AMQ_TRAIN_STEPS=$${AMQ_TRAIN_STEPS:-300} \
		$(PYTHON) -m compile.aot --outdir ../artifacts --tasks-per-family 16

test:
	cargo build --release && cargo test -q

clean-artifacts:
	rm -rf artifacts

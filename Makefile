# AMQ reproduction build entry points.
#
# `make artifacts` runs the python L2 compile path once (data -> train ->
# hessians -> HLO text -> manifest); everything downstream (the `repro`
# binary, benches, artifact-gated integration tests) is rust-only and
# self-contained afterwards.

PYTHON ?= python3

.PHONY: artifacts artifacts-smoke test clean-artifacts

# Full build (AMQ_TRAIN_STEPS=2000 by default; ~minutes on a laptop CPU).
# AMQ_SCORE_LANES sets the candidate-lane count of the stacked scorer
# executable (scores_quant_lanes{L}.hlo.txt; default 8, 1 omits it — the
# rust runtime then falls back to the per-candidate scorer).
# AMQ_SLAB_GATHER gates the per-shape-family gather executables
# (gather_lanes{L}_{N}x{K}.hlo.txt; default 1 = emit them so slab-cache
# misses become device-side gathers; AMQ_SLAB_GATHER=0 builds a
# legacy-style manifest — the runtime then host-packs and uploads slabs).
artifacts:
	cd python && AMQ_SCORE_LANES=$${AMQ_SCORE_LANES:-8} \
		AMQ_SLAB_GATHER=$${AMQ_SLAB_GATHER:-1} \
		$(PYTHON) -m compile.aot --outdir ../artifacts

# Reduced-step build for CI smoke: same artifact geometry (including the
# lane-stacked scorer and the gather executables), faster training.
# Quality-sensitive runtime assertions are not valid against this model;
# the artifact-gated host-side tests (asset validation, proxy-bank build,
# lane-manifest checks) are.
artifacts-smoke:
	cd python && AMQ_TRAIN_STEPS=$${AMQ_TRAIN_STEPS:-300} \
		AMQ_SCORE_LANES=$${AMQ_SCORE_LANES:-8} \
		AMQ_SLAB_GATHER=$${AMQ_SLAB_GATHER:-1} \
		$(PYTHON) -m compile.aot --outdir ../artifacts --tasks-per-family 16

test:
	cargo build --release && cargo test -q

clean-artifacts:
	rm -rf artifacts

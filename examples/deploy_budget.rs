//! Deployment scenario: "I have X MB of accelerator memory — give me the
//! best model that fits."  Mirrors the paper's Figure 1 use case: picks the
//! frontier configuration under the budget, deploy-quantizes it with
//! asym-clip AWQ, and reports quality + simulated serving speed.
//!
//!     cargo run --release --offline --example deploy_budget -- 3000
//!
//! (the argument is the memory budget in MB at 7B-equivalent scale)

use amq::costmodel::{self, DeployKind, L40S};
use amq::coordinator::SearchParams;
use amq::exp::common::{self, Pipeline};
use amq::exp::Ctx;

fn main() -> amq::Result<()> {
    let budget_mb: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3000.0);

    let ctx = Ctx::load(
        &amq::artifacts_dir(),
        std::path::Path::new("results/deploy"),
        SearchParams::default(),
    )?;
    let pipe = Pipeline::build(&ctx)?;
    let archive = common::main_archive(&ctx, &pipe, false)?;
    let m = &ctx.assets.manifest;

    // translate the 7B-equivalent MB budget into average bits
    // (memory ∝ bits; fp16 = 16 bits ≙ full model)
    let fp16_mb = costmodel::model_memory_mb(m, &DeployKind::Fp16);
    let target_bits = (budget_mb / fp16_mb * 16.0).clamp(2.25, 4.25);
    println!(
        "budget {budget_mb} MB @7B-equivalent  (fp16 needs {fp16_mb:.0} MB) -> target {target_bits:.2} bits"
    );

    let cfg = common::pick(&archive, &pipe.space, target_bits)?;
    let actual = pipe.space.avg_bits(&cfg);
    let cfg_bits = pipe.space.config_bits(&cfg);
    let kind = DeployKind::LayerQuant(&cfg_bits);
    println!(
        "selected config: {actual:.3} avg bits, {:.0} MB @7B-equivalent",
        costmodel::model_memory_mb(m, &kind)
    );

    let q = common::amq_quality(&ctx, &cfg)?;
    println!(
        "quality: wiki PPL {:.3}  c4 PPL {:.3}  zero-shot {:.1}%",
        q.wiki_ppl,
        q.c4_ppl,
        q.zero_shot.macro_avg(&amq::data::ZERO_SHOT)
    );
    println!(
        "serving (L40S roofline sim): {:.0} tok/s  (fp16: {:.0} tok/s -> {:.2}x speedup)",
        costmodel::tokens_per_sec(&L40S, m, &kind),
        costmodel::tokens_per_sec(&L40S, m, &DeployKind::Fp16),
        costmodel::tokens_per_sec(&L40S, m, &kind)
            / costmodel::tokens_per_sec(&L40S, m, &DeployKind::Fp16)
    );
    Ok(())
}

//! End-to-end validation driver (DESIGN.md deliverable (b)/§EXPERIMENTS):
//! exercises every layer of the stack on the real artifact workload —
//!
//!   1. artifact + runtime validation (AOT HLO loads, golden numerics);
//!   2. the full AMQ pipeline: HQQ proxy build -> sensitivity scan ->
//!      2x-median pruning -> NSGA-II iterative search (through the fused
//!      L1/L2 Pallas+JAX scorer via PJRT);
//!   3. baselines at the 3.0-bit budget: uniform RTN/GPTQ/AWQ, one-shot,
//!      BitStack, PB-LLM;
//!   4. deploy-time evaluation: PPL + zero-shot suite + serving sim;
//!   5. a consistency audit (fused scorer vs rust-mirror JSD).
//!
//! Prints a PASS/FAIL summary; run via
//!     cargo run --release --offline --example e2e_pipeline

use amq::coordinator::{run_search, ConfigEvaluator, SearchParams};
use amq::data::ZERO_SHOT;
use amq::eval::{self, ModelHandle};
use amq::exp::common::{self, Pipeline};
use amq::exp::Ctx;
use amq::quant::{Quantizer, Rtn};
use std::time::Instant;

fn main() -> amq::Result<()> {
    let t0 = Instant::now();
    let mut failures: Vec<String> = Vec::new();
    let mut check = |name: &str, ok: bool, detail: String| {
        println!("[{}] {name}: {detail}", if ok { "PASS" } else { "FAIL" });
        if !ok {
            failures.push(name.to_string());
        }
    };

    // 1. artifacts + runtime
    // between smoke and repro: enough search budget that AMQ's frontier
    // dominates the heuristic baselines (Table 12 shows the full-preset gap)
    let mut preset = SearchParams::smoke();
    preset.iterations = 14;
    preset.candidates_per_iter = 10;
    let ctx = Ctx::load(
        &amq::artifacts_dir(),
        std::path::Path::new("results/e2e"),
        preset,
    )?;
    let golden = amq::data::Bundle::read(&ctx.assets.manifest.file("golden")?)?;
    let logits = ctx.rt.fp_logits(golden.tensor("tokens")?.as_i32()?)?;
    let want = golden.tensor("fp_logits")?.as_f32()?;
    let max_err = want
        .iter()
        .enumerate()
        .map(|(i, &w)| (logits[i] - w).abs())
        .fold(0.0f32, f32::max);
    check("golden-numerics", max_err < 1e-3,
          format!("rust PJRT vs python jax logits, max abs err {max_err:.2e}"));

    // 2. AMQ pipeline
    let pipe = Pipeline::build(&ctx)?;
    let spread = {
        let s = pipe.sensitivity.scores();
        let hi = s.iter().cloned().fold(0.0f32, f32::max);
        let lo = s.iter().cloned().filter(|v| *v > 0.0).fold(f32::INFINITY, f32::min);
        hi / lo.max(1e-12)
    };
    check("sensitivity-spread", spread > 3.0,
          format!("per-layer sensitivity spread {spread:.1}x (needs heterogeneity)"));

    let mut evaluator = pipe.evaluator(&ctx);
    let res = run_search(&pipe.space, &mut evaluator, &ctx.preset)?;
    check("search-ran", res.true_evals > 50,
          format!("{} true evals, {} predicted, {:.1}s",
                  res.true_evals, res.predictor_queries,
                  res.total_time.as_secs_f64()));

    // 3. baselines @3.0 bits
    let budget = 3.0;
    let amq_cfg = common::pick(&res.archive, &pipe.space, budget)?;
    let amq_jsd = res.archive.best_under(budget, 0.005).unwrap().jsd;

    let uniform3 = common::uniform_config(&pipe.space, 3); // 3.25 bits > budget-0.25
    let mut ev2 = pipe.evaluator(&ctx);
    let mut uni_cfg = uniform3.clone();
    // knock uniform down to <= 3.0 avg bits by randomly demoting (fair-ish)
    let scores = pipe.sensitivity.scores();
    let oneshot_cfg = amq::coordinator::oneshot::one_shot(&pipe.space, &scores, budget);
    let oneshot_jsd = ev2.eval_jsd(&oneshot_cfg)?;
    while pipe.space.avg_bits(&uni_cfg) > budget {
        // demote the first demotable layer one bit step; pruned
        // (pinned-at-max) layers have no lower gene and are skipped
        let Some((i, g)) = uni_cfg
            .iter()
            .enumerate()
            .find_map(|(i, &g)| pipe.space.demote(i, g).map(|d| (i, d)))
        else {
            break;
        };
        uni_cfg[i] = g;
    }
    let uni_jsd = ev2.eval_jsd(&uni_cfg)?;
    check("amq-beats-naive", amq_jsd <= uni_jsd,
          format!("AMQ jsd {amq_jsd:.5} vs naive-demotion {uni_jsd:.5} @{budget} bits"));
    // one-shot gets the full 29-eval sensitivity ranking for free and is a
    // strong heuristic at this 28-layer scale (on calibration JSD it can
    // edge out a short search); AMQ must stay competitive here and wins on
    // deploy-time PPL at the full budget (Table 12 / EXPERIMENTS.md)
    check("amq-competitive-with-oneshot", amq_jsd <= oneshot_jsd * 1.25,
          format!("AMQ jsd {amq_jsd:.5} vs one-shot {oneshot_jsd:.5}"));

    // 4. deploy-time quality
    let fp_q = common::quality(&ctx, &ModelHandle::Fp)?;
    let amq_q = common::amq_quality(&ctx, &amq_cfg)?;
    let retain = amq_q.zero_shot.macro_avg(&ZERO_SHOT)
        / fp_q.zero_shot.macro_avg(&ZERO_SHOT) * 100.0;
    check("quality-retention", retain > 80.0,
          format!("AMQ@{budget}b retains {retain:.1}% of fp16 zero-shot accuracy \
                   (ppl {:.2} vs fp {:.2})", amq_q.wiki_ppl, fp_q.wiki_ppl));

    // 5. consistency audit: fused scorer vs rust mirror
    let layers = pipe.proxy.assemble(&amq_cfg)?;
    let (jsd_fused, _) = ctx.rt.scores(&ctx.search_batches[0], &layers)?;
    let qlogits = ctx.rt.quant_logits(&ctx.search_batches[0].host_tokens, &layers)?;
    let jsd_mirror = eval::jsd_mean(
        &ctx.search_batches[0].host_fp_logits,
        &qlogits,
        ctx.rt.vocab(),
        &ctx.search_batches[0].host_mask,
    );
    check("scorer-consistency", (jsd_fused - jsd_mirror).abs() < 2e-3,
          format!("fused {jsd_fused:.5} vs rust-mirror {jsd_mirror:.5}"));

    // also exercise RTN through the pallas path once
    let w = ctx.assets.weights.linear(&ctx.assets.manifest.layers[0].name)?;
    let q = Rtn.quantize(&w, 4, ctx.assets.manifest.group_size, None);
    check("pack-roundtrip", {
        let packed = amq::quant::pack::pack(&q.codes, 4);
        amq::quant::pack::unpack(&packed, 4, q.codes.len()) == q.codes
    }, "physical 4-bit pack/unpack".into());

    println!(
        "\n=== e2e summary: {} checks failed, total {:.1}s ===",
        failures.len(),
        t0.elapsed().as_secs_f64()
    );
    if failures.is_empty() {
        println!("ALL PASS");
        Ok(())
    } else {
        eyre::bail!("failed checks: {failures:?}")
    }
}

//! Quickstart: load the artifacts, run a small AMQ search, and print the
//! memory/quality Pareto frontier plus the best configuration under a
//! 3.0-bit budget.
//!
//!     make artifacts && cargo run --release --offline --example quickstart

use amq::coordinator::{gene_bits, gene_method, run_search, SearchParams};
use amq::exp::common::{self, Pipeline};
use amq::exp::Ctx;

fn main() -> amq::Result<()> {
    let artifacts = amq::artifacts_dir();
    let ctx = Ctx::load(
        &artifacts,
        std::path::Path::new("results/quickstart"),
        SearchParams::smoke(),
    )?;
    println!(
        "loaded subject model: {} blocks, {} searchable linear layers",
        ctx.assets.manifest.model.n_layers,
        ctx.assets.manifest.layers.len()
    );

    // 1. proxy + sensitivity + pruning (the AMQ pipeline front half)
    let pipe = Pipeline::build(&ctx)?;
    println!(
        "pruning: {} outlier layer(s) pinned to 4-bit; space 10^{:.1} -> 10^{:.1}",
        pipe.prune_report.outliers.len(),
        pipe.full_space.log10_size(),
        pipe.space.log10_size()
    );

    // 2. iterative search-and-update (small smoke budget)
    let mut evaluator = pipe.evaluator(&ctx);
    let res = run_search(&pipe.space, &mut evaluator, &ctx.preset)?;
    println!(
        "search: {} true evaluations, {} predictor queries, {:.1}s",
        res.true_evals,
        res.predictor_queries,
        res.total_time.as_secs_f64()
    );

    // 3. frontier + budget selection
    let front = res.archive.pareto_front();
    println!("\nPareto frontier ({} points):", front.len());
    let mut rows: Vec<_> = front.iter().map(|&i| &res.archive.samples[i]).collect();
    rows.sort_by(|a, b| a.avg_bits.partial_cmp(&b.avg_bits).unwrap());
    for s in rows.iter().step_by((rows.len() / 12).max(1)) {
        println!("  {:.3} bits   jsd {:.5}", s.avg_bits, s.jsd);
    }

    let budget = 3.0;
    let cfg = common::pick(&res.archive, &pipe.space, budget)?;
    println!("\nbest config under {budget} bits (actual {:.3}):", pipe.space.avg_bits(&cfg));
    let multi = pipe.space.n_methods() > 1;
    for (l, &g) in ctx.assets.manifest.layers.iter().zip(&cfg) {
        if multi {
            print!("{}={}@{} ", l.name, gene_bits(g), gene_method(g).name());
        } else {
            print!("{}={} ", l.name, gene_bits(g));
        }
    }
    println!();

    // 4. deploy-time evaluation with asym-clip AWQ
    let q = common::amq_quality(&ctx, &cfg)?;
    println!(
        "\ndeployed (asym-clip AWQ): wiki PPL {:.3}, c4 PPL {:.3}, zero-shot avg {:.1}%",
        q.wiki_ppl,
        q.c4_ppl,
        q.zero_shot.macro_avg(&amq::data::ZERO_SHOT)
    );
    Ok(())
}

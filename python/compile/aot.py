"""AOT build orchestrator: data -> train -> hessians -> HLO text -> manifest.

Runs exactly once (``make artifacts``).  Produces everything the rust
coordinator needs to be self-contained:

  calib.bin / test_wiki.bin / test_c4.bin   token splits (i32 [N, T])
  tasks.json                                task instances
  weights.bin                               trained fp parameters
  hessians.bin                              calibration X^T X + mean|x|
  golden.bin                                fp logits of 2 calib seqs (checks)
  model_fp.hlo.txt                          (tokens, fp params) -> logits
  model_quant.hlo.txt                       (tokens, fp side, qparams) -> logits
  scores_quant.hlo.txt                      fused scorer -> (jsd, ce)
  scores_quant_lanes{L}.hlo.txt             lane-stacked scorer -> (jsd[L], ce[L])
  gather_lanes{L}_{N}x{K}.hlo.txt           device-side slab gather, one per
                                            quant-slot shape family
  train_log.json                            loss curve
  manifest.json                             shapes + argument orders

Interchange is HLO **text**: jax >= 0.5 serializes HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published ``xla``
crate binds) rejects; the text parser reassigns ids (see /opt/xla-example).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import config as C
from . import data as D
from . import hessian as H
from . import io_utils as IO
from . import model as M
from . import train as T


# ---------------------------------------------------------------------------
# HLO text lowering (the aot_recipe / xla-example pattern)
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants is ESSENTIAL: the default printer elides big
    # literals as `constant({...})`, which xla_extension 0.5.1's text parser
    # silently zero-fills (we lost the RoPE tables + causal mask that way).
    return comp.as_hlo_text(print_large_constants=True)


def flat_arg_names(*trees) -> list[str]:
    """Flatten pytrees of *names* exactly as jax flattens the value trees."""
    names: list[str] = []
    for tree in trees:
        leaves, _ = jax.tree_util.tree_flatten(tree)
        names.extend(leaves)
    return names


# ---------------------------------------------------------------------------
# Spec builders
# ---------------------------------------------------------------------------

def fp_param_specs(cfg) -> dict[str, jax.ShapeDtypeStruct]:
    return {k: jax.ShapeDtypeStruct(v, jnp.float32)
            for k, v in M.param_shapes(cfg).items()}


def fp_side_specs(cfg) -> dict[str, jax.ShapeDtypeStruct]:
    shapes = M.param_shapes(cfg)
    return {k: jax.ShapeDtypeStruct(shapes[k], jnp.float32)
            for k in M.fp_side_names(cfg)}


def quant_specs(cfg) -> dict[str, dict[str, jax.ShapeDtypeStruct]]:
    out = {}
    for name, parts in M.quant_param_shapes(cfg).items():
        out[name] = {
            "codes": jax.ShapeDtypeStruct(parts["codes"], jnp.int8),
            "scale": jax.ShapeDtypeStruct(parts["scale"], jnp.float32),
            "zero": jax.ShapeDtypeStruct(parts["zero"], jnp.float32),
        }
    return out


def quant_lane_specs(cfg, lanes: int) -> dict[str, dict[str, jax.ShapeDtypeStruct]]:
    """quant_specs with a leading candidate axis on every leaf."""
    return {name: {p: jax.ShapeDtypeStruct((lanes,) + tuple(s.shape), s.dtype)
                   for p, s in parts.items()}
            for name, parts in quant_specs(cfg).items()}


def name_tree_like_quant(cfg):
    return {name: {p: f"{name}.{p}" for p in ("codes", "scale", "zero")}
            for name in C.layer_names(cfg)}


def name_tree_like_fp(cfg, names):
    return {k: k for k in names}


# ---------------------------------------------------------------------------
# Build steps
# ---------------------------------------------------------------------------

def build(outdir: str, steps: int | None, tasks_per_family: int,
          reuse_weights: bool = False, lanes: int | None = None,
          gather: bool | None = None) -> None:
    os.makedirs(outdir, exist_ok=True)
    cfg = C.MODEL
    if lanes is None:
        lanes = C.score_lanes()
    if gather is None:
        gather = C.slab_gather()
    t0 = time.time()

    print("[aot] generating dataset ...", flush=True)
    ds = D.build_dataset(n_tasks_per_family=tasks_per_family)
    IO.write_tokens(os.path.join(outdir, "calib.bin"), ds.calib)
    IO.write_tokens(os.path.join(outdir, "test_wiki.bin"), ds.test_wiki)
    IO.write_tokens(os.path.join(outdir, "test_c4.bin"), ds.test_c4)
    IO.write_tasks_json(os.path.join(outdir, "tasks.json"), ds.tasks)

    weights_path = os.path.join(outdir, "weights.bin")
    if reuse_weights and os.path.exists(weights_path):
        # perf-iteration path: keep the trained model, regenerate HLO only
        print("[aot] reusing existing trained weights ...", flush=True)
        params = {k: jnp.asarray(v)
                  for k, v in IO.read_bundle(weights_path).items()}
    else:
        print("[aot] training subject model ...", flush=True)
        params, log = T.train(ds, cfg, steps=steps)
        IO.write_bundle(weights_path,
                        {k: np.asarray(v) for k, v in params.items()})
        with open(os.path.join(outdir, "train_log.json"), "w") as f:
            json.dump({"loss": log, "steps": steps or C.train_steps(),
                       "batch": C.train_batch()}, f)

    print("[aot] capturing calibration hessians ...", flush=True)
    hes = H.capture_hessians(params, ds.calib, cfg)
    IO.write_bundle(os.path.join(outdir, "hessians.bin"), hes)

    print("[aot] golden reference outputs ...", flush=True)
    gtoks = jnp.asarray(ds.calib[: C.EVAL_BATCH], jnp.int32)
    glogits = np.asarray(jax.jit(M.forward_fp)(params, gtoks))
    IO.write_bundle(os.path.join(outdir, "golden.bin"), {
        "tokens": np.asarray(gtoks, np.int32),
        "fp_logits": glogits[:2].astype(np.float32),
    })

    print("[aot] lowering HLO executables ...", flush=True)
    B, Tq, V = C.EVAL_BATCH, C.EVAL_SEQ, cfg.vocab_size
    tok_spec = jax.ShapeDtypeStruct((B, Tq), jnp.int32)
    mask_spec = jax.ShapeDtypeStruct((B, Tq), jnp.float32)
    logits_spec = jax.ShapeDtypeStruct((B, Tq, V), jnp.float32)

    # 1. fp logits
    def fp_fn(tokens, params):
        return (M.forward_fp(params, tokens, cfg),)

    low = jax.jit(fp_fn).lower(tok_spec, fp_param_specs(cfg))
    with open(os.path.join(outdir, "model_fp.hlo.txt"), "w") as f:
        f.write(to_hlo_text(low))
    fp_args = flat_arg_names("tokens",
                             name_tree_like_fp(cfg, sorted(M.param_shapes(cfg))))

    # 2. quant logits
    def quant_fn(tokens, fp_side, qparams):
        return (M.forward_quant(fp_side, qparams, tokens, cfg),)

    low = jax.jit(quant_fn).lower(tok_spec, fp_side_specs(cfg), quant_specs(cfg))
    with open(os.path.join(outdir, "model_quant.hlo.txt"), "w") as f:
        f.write(to_hlo_text(low))
    quant_args = flat_arg_names(
        "tokens", name_tree_like_fp(cfg, M.fp_side_names(cfg)),
        name_tree_like_quant(cfg))

    # 3. fused scorer
    def scores_fn(tokens, mask, fp_logits, fp_side, qparams):
        jsd, ce = M.scores_quant(fp_side, qparams, tokens, mask, fp_logits, cfg)
        return (jsd, ce)

    low = jax.jit(scores_fn).lower(tok_spec, mask_spec, logits_spec,
                                   fp_side_specs(cfg), quant_specs(cfg))
    with open(os.path.join(outdir, "scores_quant.hlo.txt"), "w") as f:
        f.write(to_hlo_text(low))
    scores_args = flat_arg_names(
        "tokens", "mask", "fp_logits",
        name_tree_like_fp(cfg, M.fp_side_names(cfg)),
        name_tree_like_quant(cfg))

    # 4. lane-stacked fused scorer: the quant-parameter arguments carry a
    # leading candidate axis of size L, so one dispatch scores L assembled
    # candidates.  Per-lane numerics are bitwise identical to the
    # single-candidate scorer (vmap batches only the candidate axis; every
    # reduction stays per-lane), which is what lets the rust runtime swap
    # dispatch strategies without perturbing search archives.  Skipped when
    # lanes <= 1 (the rust side then falls back to the per-candidate loop).
    lanes_exec = None
    if lanes > 1:
        def scores_lanes_fn(tokens, mask, fp_logits, fp_side, qlanes):
            jsd, ce = M.scores_quant_lanes(fp_side, qlanes, tokens, mask,
                                           fp_logits, cfg)
            return (jsd, ce)

        lanes_file = f"scores_quant_lanes{lanes}.hlo.txt"
        low = jax.jit(scores_lanes_fn).lower(
            tok_spec, mask_spec, logits_spec,
            fp_side_specs(cfg), quant_lane_specs(cfg, lanes))
        with open(os.path.join(outdir, lanes_file), "w") as f:
            f.write(to_hlo_text(low))
        # same flat argument names as the single-candidate scorer: a quant
        # slot name now refers to the lane-stacked buffer of that layer
        lanes_exec = {"file": lanes_file, "args": scores_args,
                      "outputs": ["jsd", "ce"], "lanes": lanes}

    # 5. device-side slab gather: one tiny executable per quant-slot shape
    # family that stacks L resident per-candidate buffers into the [L, ...]
    # slab triple the lane scorer consumes.  With it, a SlabCache miss is a
    # device dispatch over already-resident bank pieces instead of a host
    # pack + O(slab bytes) upload.  Padding is the caller's job (it repeats
    # lane 0's buffers), so the output is bitwise identical to the host
    # pack_lane_slab path.  Only useful alongside the lane scorer; skipped
    # when lanes <= 1 or AMQ_SLAB_GATHER=0 (the rust runtime then falls
    # back to the host pack path — legacy manifests keep working).
    gather_execs = {}
    if lanes_exec and gather:
        families = sorted({parts["codes"]
                           for parts in M.quant_param_shapes(cfg).values()})
        gather_names = [{p: f"lane{i}.{p}" for p in ("codes", "scale", "zero")}
                        for i in range(lanes)]
        gather_args = flat_arg_names(gather_names)
        for n, k in families:
            g = C.n_groups(k)
            part_specs = {
                "codes": jax.ShapeDtypeStruct((n, k), jnp.int8),
                "scale": jax.ShapeDtypeStruct((n, g), jnp.float32),
                "zero": jax.ShapeDtypeStruct((n, g), jnp.float32),
            }
            low = jax.jit(M.gather_lane_slab).lower(
                [dict(part_specs) for _ in range(lanes)])
            gfile = f"gather_lanes{lanes}_{n}x{k}.hlo.txt"
            with open(os.path.join(outdir, gfile), "w") as f:
                f.write(to_hlo_text(low))
            gather_execs[f"gather_lanes_{n}x{k}"] = {
                "file": gfile, "args": gather_args,
                "outputs": ["codes", "scale", "zero"], "lanes": lanes}

    manifest = {
        "model": {
            "vocab_size": cfg.vocab_size, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "seq_len": cfg.seq_len,
            "rope_theta": cfg.rope_theta, "rms_eps": cfg.rms_eps,
        },
        "group_size": C.GROUP_SIZE,
        "bit_choices": list(C.BIT_CHOICES),
        # Quantization methods the search genome may assign per layer
        # (rust quant::registry names).  The coordinator's --methods flag
        # overrides this enable list at search time.
        "methods": ["hqq"],
        "eval_batch": B,
        "layers": [
            {"name": n,
             "out_features": C.linear_shape(cfg, n.split(".")[1])[0],
             "in_features": C.linear_shape(cfg, n.split(".")[1])[1]}
            for n in C.layer_names(cfg)
        ],
        "fp_side_names": M.fp_side_names(cfg),
        "executables": {
            "model_fp": {"file": "model_fp.hlo.txt", "args": fp_args,
                         "outputs": ["logits"]},
            "model_quant": {"file": "model_quant.hlo.txt", "args": quant_args,
                            "outputs": ["logits"]},
            "scores_quant": {"file": "scores_quant.hlo.txt",
                             "args": scores_args, "outputs": ["jsd", "ce"]},
        },
        "score_lanes": lanes if lanes_exec else 1,
        "files": {
            "weights": "weights.bin", "hessians": "hessians.bin",
            "calib": "calib.bin", "test_wiki": "test_wiki.bin",
            "test_c4": "test_c4.bin", "tasks": "tasks.json",
            "golden": "golden.bin",
        },
        "special_tokens": {"pad": C.TOK_PAD, "eos": C.TOK_EOS},
        "build_seconds": round(time.time() - t0, 1),
    }
    if lanes_exec:
        manifest["executables"]["scores_quant_lanes"] = lanes_exec
    manifest["executables"].update(gather_execs)
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] done in {time.time() - t0:.1f}s -> {outdir}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--tasks-per-family", type=int, default=100)
    ap.add_argument("--reuse-weights", action="store_true",
                    help="skip training if weights.bin exists (HLO-only rebuild)")
    ap.add_argument("--lanes", type=int, default=None,
                    help="candidate lanes of the stacked scorer executable "
                         "(default: AMQ_SCORE_LANES or 8; 1 disables it)")
    ap.add_argument("--slab-gather", type=int, default=None, choices=(0, 1),
                    help="emit device-side slab-gather executables, one per "
                         "quant shape family (default: AMQ_SLAB_GATHER or 1; "
                         "0 disables them; requires lanes > 1)")
    args = ap.parse_args()
    build(args.outdir, args.steps, args.tasks_per_family, args.reuse_weights,
          args.lanes,
          None if args.slab_gather is None else bool(args.slab_gather))


if __name__ == "__main__":
    main()

"""Shared build-time configuration for the AMQ reproduction.

Everything here is consumed twice:
  * by the python compile path (training, AOT lowering, data generation), and
  * by the rust coordinator, via ``artifacts/manifest.json`` which is written
    by :mod:`compile.aot` from these values.

The model is a real (trained) tiny-Llama used as the *subject* of the AMQ
search.  See DESIGN.md §3 for why a ~3.4M-parameter transformer preserves the
paper's algorithmic behaviour.
"""

from __future__ import annotations

import dataclasses
import os


# ---------------------------------------------------------------------------
# Vocabulary layout (512 tokens).
#
# The synthetic corpus mixes Markov "text" with structured pattern segments;
# the zero-shot / few-shot task families reuse the same generators so the
# trained model is genuinely above chance on them (DESIGN.md §3).
# ---------------------------------------------------------------------------
VOCAB_SIZE = 512

TEXT_LO, TEXT_HI = 0, 256          # Markov text tokens           [0, 256)
VAL_LO, VAL_HI = 256, 320          # 64 value tokens              [256, 320)
KEY_LO, KEY_HI = 320, 352          # 32 key tokens                [320, 352)
OPEN_LO, OPEN_HI = 352, 368        # 16 opening brackets          [352, 368)
CLOSE_LO, CLOSE_HI = 368, 384      # 16 matching closing brackets [368, 384)

# Special markers.
TOK_COPY = 384     # start of a copy segment
TOK_SEP = 385      # separator between prompt and continuation
TOK_KV = 386       # start of a key-value store segment
TOK_QUERY = 387    # query marker
TOK_PLUS = 388     # modular addition operator
TOK_EQ = 389       # equals sign
TOK_MAJ = 390      # majority-count query marker
TOK_ANS = 391      # answer marker
TOK_HOP = 392      # two-hop chained-recall marker
TOK_A = 393        # counter token A (majority task)
TOK_B = 394        # counter token B (majority task)
TOK_EOS = 395      # segment terminator
TOK_PAD = 396      # padding (masked out everywhere)

MOD_BASE = 64      # modular arithmetic is over Z_64, mapped onto VAL tokens


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = VOCAB_SIZE
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 256
    seq_len: int = 128
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


MODEL = ModelConfig()

# Per-block linear layers, in canonical order.  This order defines LayerId
# numbering everywhere (python, manifest, rust).
LINEAR_KINDS = ("q", "k", "v", "o", "gate", "up", "down")


def linear_shape(cfg: ModelConfig, kind: str) -> tuple[int, int]:
    """(out_features, in_features) of a per-block linear layer."""
    d, f = cfg.d_model, cfg.d_ff
    return {
        "q": (d, d),
        "k": (d, d),
        "v": (d, d),
        "o": (d, d),
        "gate": (f, d),
        "up": (f, d),
        "down": (d, f),
    }[kind]


def layer_names(cfg: ModelConfig) -> list[str]:
    """Canonical flat ordering of the searchable linear layers."""
    return [f"blk{b}.{k}" for b in range(cfg.n_layers) for k in LINEAR_KINDS]


# ---------------------------------------------------------------------------
# Quantization geometry
# ---------------------------------------------------------------------------
GROUP_SIZE = 128   # grouped weight-only quantization, along in_features
BIT_CHOICES = (2, 3, 4)


def n_groups(in_features: int) -> int:
    assert in_features % GROUP_SIZE == 0, in_features
    return in_features // GROUP_SIZE


# ---------------------------------------------------------------------------
# Evaluation batching (fixed shapes for the AOT executables)
# ---------------------------------------------------------------------------
EVAL_BATCH = 16    # sequences per PJRT call (single-core CPU testbed)
EVAL_SEQ = MODEL.seq_len


def score_lanes() -> int:
    """Candidate lanes of the stacked scorer executable.

    The AOT build emits a second fused scorer whose quant-parameter
    arguments carry a leading candidate axis of this size, so one PJRT
    dispatch scores up to ``score_lanes()`` assembled candidates.  Override
    with ``AMQ_SCORE_LANES`` (1 disables the lane-stacked artifact).
    """
    return int(os.environ.get("AMQ_SCORE_LANES", "8"))


def slab_gather() -> bool:
    """Whether the AOT build emits the device-side slab-gather executables.

    One ``gather_lanes{L}_{N}x{K}.hlo.txt`` per quant-slot shape family:
    a lane-slab cache miss then becomes a device dispatch over the bank's
    resident buffers instead of a host pack + upload.  Only meaningful when
    the lane-stacked scorer is built (``score_lanes() > 1``).  Override
    with ``AMQ_SLAB_GATHER`` (0 disables the gather artifacts; the rust
    runtime then falls back to the host pack path).
    """
    return os.environ.get("AMQ_SLAB_GATHER", "1") not in ("0", "")

# Dataset sizes (sequences of EVAL_SEQ tokens).
N_CALIB = 128      # calibration set ("WikiText-2 train" analog)
N_TEST_WIKI = 128  # in-distribution test split ("WikiText-2 test" analog)
N_TEST_C4 = 128    # shifted-distribution test split ("C4 validation" analog)

DATA_SEED = 20250710


def train_steps() -> int:
    """Training steps; override with AMQ_TRAIN_STEPS for fast dev builds."""
    return int(os.environ.get("AMQ_TRAIN_STEPS", "2000"))


def train_batch() -> int:
    return int(os.environ.get("AMQ_TRAIN_BATCH", "16"))

"""Synthetic corpus + task-suite generator (the paper's data substrate).

The paper calibrates on WikiText-2, reports PPL on WikiText-2/C4 and accuracy
on six zero-shot benchmarks plus 5-shot MMLU/GSM8K.  None of those are usable
here (repro gate), so we build the closest synthetic equivalent exercising the
same code paths (DESIGN.md §3):

  * a seeded stochastic-grammar corpus: order-1 sparse Markov "text"
    interleaved with *pattern segments* (copy, key-value recall, induction,
    bracket agreement, majority counting, modular arithmetic, two-hop chains);
  * a "wiki" split drawn from grammar A and a "c4" split drawn from a shifted
    mixture of grammars A and B — giving an in-distribution vs
    shifted-distribution PPL axis like WikiText-2 vs C4;
  * eight task families (six "zero-shot" + two harder "few-shot") whose
    held-out instances are scored by length-normalized choice logprob, the
    LM-Eval-Harness protocol.

Everything is deterministic under ``config.DATA_SEED``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import config as C

# ---------------------------------------------------------------------------
# Markov grammar
# ---------------------------------------------------------------------------
N_SUCC = 8  # sparse branching factor: each text token has 8 likely successors


@dataclasses.dataclass
class Grammar:
    """Sparse order-1 Markov chain over the text-token range."""

    succ: np.ndarray    # [256, N_SUCC] successor token ids (in TEXT range)
    probs: np.ndarray   # [256, N_SUCC] successor probabilities (rows sum to 1)

    @staticmethod
    def build(rng: np.random.Generator) -> "Grammar":
        n = C.TEXT_HI - C.TEXT_LO
        succ = np.empty((n, N_SUCC), dtype=np.int64)
        probs = np.empty((n, N_SUCC), dtype=np.float64)
        for t in range(n):
            succ[t] = rng.choice(n, size=N_SUCC, replace=False) + C.TEXT_LO
            w = rng.dirichlet(np.full(N_SUCC, 0.5))
            probs[t] = w
        return Grammar(succ, probs)

    def walk(self, rng: np.random.Generator, start: int, length: int) -> list[int]:
        out = [start]
        cur = start - C.TEXT_LO
        for _ in range(length - 1):
            j = rng.choice(N_SUCC, p=self.probs[cur])
            nxt = int(self.succ[cur, j])
            out.append(nxt)
            cur = nxt - C.TEXT_LO
        return out

    def sample_start(self, rng: np.random.Generator) -> int:
        return int(rng.integers(C.TEXT_LO, C.TEXT_HI))


class MixGrammar:
    """C4-analog: each step follows grammar A w.p. ``mix`` else grammar B."""

    def __init__(self, a: Grammar, b: Grammar, mix: float = 0.7):
        self.a, self.b, self.mix = a, b, mix

    def walk(self, rng: np.random.Generator, start: int, length: int) -> list[int]:
        out = [start]
        cur = start
        for _ in range(length - 1):
            g = self.a if rng.random() < self.mix else self.b
            row = cur - C.TEXT_LO
            j = rng.choice(N_SUCC, p=g.probs[row])
            cur = int(g.succ[row, j])
            out.append(cur)
        return out

    def sample_start(self, rng: np.random.Generator) -> int:
        return int(rng.integers(C.TEXT_LO, C.TEXT_HI))


# ---------------------------------------------------------------------------
# Pattern segments.  Each returns a flat token list ending in TOK_EOS.
# ---------------------------------------------------------------------------

def seg_copy(rng: np.random.Generator, g: Grammar) -> list[int]:
    k = int(rng.integers(3, 9))
    body = g.walk(rng, g.sample_start(rng), k)
    return [C.TOK_COPY, *body, C.TOK_SEP, *body, C.TOK_EOS]


def seg_kv(rng: np.random.Generator, n_pairs: int | None = None) -> list[int]:
    m = n_pairs or int(rng.integers(2, 5))
    keys = rng.choice(C.KEY_HI - C.KEY_LO, size=m, replace=False) + C.KEY_LO
    vals = rng.integers(C.VAL_LO, C.VAL_HI, size=m)
    seq = [C.TOK_KV]
    for k, v in zip(keys, vals):
        seq += [int(k), int(v)]
    qi = int(rng.integers(m))
    seq += [C.TOK_QUERY, int(keys[qi]), C.TOK_ANS, int(vals[qi]), C.TOK_EOS]
    return seq


def seg_induction(rng: np.random.Generator, g: Grammar) -> list[int]:
    # "... a b <filler> a b" — the repeated bigram is the induction pattern.
    a = g.sample_start(rng)
    bi = g.walk(rng, a, 2)
    filler = g.walk(rng, g.sample_start(rng), int(rng.integers(4, 10)))
    return [*bi, *filler, *bi, C.TOK_EOS]


def seg_bracket(rng: np.random.Generator, g: Grammar) -> list[int]:
    i = int(rng.integers(16))
    filler = g.walk(rng, g.sample_start(rng), int(rng.integers(3, 9)))
    return [C.OPEN_LO + i, *filler, C.CLOSE_LO + i, C.TOK_EOS]


def seg_majority(rng: np.random.Generator) -> list[int]:
    n = int(rng.integers(7, 14))
    na = int(rng.integers(0, n + 1))
    # Force a margin of >= 2 so the answer is unambiguous.
    while abs(2 * na - n) < 2:
        na = int(rng.integers(0, n + 1))
    seq = [C.TOK_A] * na + [C.TOK_B] * (n - na)
    rng.shuffle(seq)
    ans = C.TOK_A if na > n - na else C.TOK_B
    return [C.TOK_MAJ, *seq, C.TOK_ANS, ans, C.TOK_EOS]


def seg_modadd(rng: np.random.Generator) -> list[int]:
    a = int(rng.integers(C.MOD_BASE))
    b = int(rng.integers(C.MOD_BASE))
    c = (a + b) % C.MOD_BASE
    return [C.VAL_LO + a, C.TOK_PLUS, C.VAL_LO + b, C.TOK_EQ, C.VAL_LO + c, C.TOK_EOS]


def seg_twohop(rng: np.random.Generator) -> list[int]:
    # k -> m, m -> v; query k answers v (chained recall).
    k = int(rng.integers(C.KEY_LO, C.KEY_HI))
    m, v = (int(x) for x in rng.integers(C.VAL_LO, C.VAL_HI, size=2))
    return [C.TOK_HOP, k, m, m, v, C.TOK_QUERY, k, C.TOK_ANS, v, C.TOK_EOS]


SEGMENT_FNS = {
    "copy": lambda rng, g: seg_copy(rng, g),
    "kv": lambda rng, g: seg_kv(rng),
    "induction": lambda rng, g: seg_induction(rng, g),
    "bracket": lambda rng, g: seg_bracket(rng, g),
    "majority": lambda rng, g: seg_majority(rng),
    "modadd": lambda rng, g: seg_modadd(rng),
    "twohop": lambda rng, g: seg_twohop(rng),
}


# ---------------------------------------------------------------------------
# Sequence assembly
# ---------------------------------------------------------------------------

# Sampling weights for pattern segments in the training mix: associative
# families (kv recall, bracket agreement, modular addition, two-hop chains)
# need more exposure than the positional ones to be learned at this scale.
SEGMENT_WEIGHTS = {
    "copy": 1.0,
    "kv": 3.0,
    "induction": 1.0,
    "bracket": 2.0,
    "majority": 1.0,
    "modadd": 3.0,
    "twohop": 2.0,
}


def make_sequence(rng: np.random.Generator, grammar, seq_len: int) -> np.ndarray:
    """One training/eval sequence: Markov runs interleaved with segments."""
    toks: list[int] = []
    fams = list(SEGMENT_FNS)
    w = np.asarray([SEGMENT_WEIGHTS[f] for f in fams])
    w = w / w.sum()
    while len(toks) < seq_len:
        if rng.random() < 0.35:
            run = int(rng.integers(8, 25))
            toks += grammar.walk(rng, grammar.sample_start(rng), run)
        else:
            fam = fams[int(rng.choice(len(fams), p=w))]
            base = grammar if isinstance(grammar, Grammar) else grammar.a
            toks += SEGMENT_FNS[fam](rng, base)
    return np.asarray(toks[:seq_len], dtype=np.int32)


def make_split(rng: np.random.Generator, grammar, n_seqs: int, seq_len: int) -> np.ndarray:
    return np.stack([make_sequence(rng, grammar, seq_len) for _ in range(n_seqs)])


# ---------------------------------------------------------------------------
# Task instances (held-out; scored by choice logprob)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TaskInstance:
    family: str
    context: list[int]
    choices: list[list[int]]   # token lists; score = mean logprob per choice
    answer: int                # index of the correct choice


def _distract_vals(rng, correct: int, k: int) -> list[int]:
    opts = [v for v in range(C.VAL_LO, C.VAL_HI) if v != correct]
    return [int(x) for x in rng.choice(opts, size=k, replace=False)]


def task_copy(rng: np.random.Generator, g: Grammar) -> TaskInstance:
    k = int(rng.integers(4, 8))
    body = g.walk(rng, g.sample_start(rng), k)
    ctx = [C.TOK_COPY, *body, C.TOK_SEP]
    wrongs = []
    while len(wrongs) < 3:
        perm = list(body)
        rng.shuffle(perm)
        if perm != body and perm not in wrongs:
            wrongs.append(perm)
    choices = [list(body)] + wrongs
    order = rng.permutation(4)
    return TaskInstance("copy", ctx, [choices[i] for i in order],
                        int(np.argwhere(order == 0)[0, 0]))


def task_recall(rng: np.random.Generator, g: Grammar) -> TaskInstance:
    m = 3
    keys = rng.choice(C.KEY_HI - C.KEY_LO, size=m, replace=False) + C.KEY_LO
    vals = rng.choice(C.VAL_HI - C.VAL_LO, size=m, replace=False) + C.VAL_LO
    ctx = [C.TOK_KV]
    for k, v in zip(keys, vals):
        ctx += [int(k), int(v)]
    qi = int(rng.integers(m))
    ctx += [C.TOK_QUERY, int(keys[qi]), C.TOK_ANS]
    correct = int(vals[qi])
    choices = [[correct]] + [[v] for v in _distract_vals(rng, correct, 3)]
    order = rng.permutation(4)
    return TaskInstance("recall", ctx, [choices[i] for i in order],
                        int(np.argwhere(order == 0)[0, 0]))


def task_induction(rng: np.random.Generator, g: Grammar) -> TaskInstance:
    a = g.sample_start(rng)
    b = int(g.succ[a - C.TEXT_LO, int(rng.integers(N_SUCC))])
    filler = g.walk(rng, g.sample_start(rng), int(rng.integers(5, 12)))
    ctx = [a, b, *filler, a]
    wrongs = [int(x) for x in rng.choice(
        [t for t in range(C.TEXT_LO, C.TEXT_HI) if t != b], size=3, replace=False)]
    choices = [[b]] + [[w] for w in wrongs]
    order = rng.permutation(4)
    return TaskInstance("induction", ctx, [choices[i] for i in order],
                        int(np.argwhere(order == 0)[0, 0]))


def task_agreement(rng: np.random.Generator, g: Grammar) -> TaskInstance:
    i = int(rng.integers(16))
    filler = g.walk(rng, g.sample_start(rng), int(rng.integers(4, 9)))
    ctx = [C.OPEN_LO + i, *filler]
    wrong_ids = [int(x) for x in rng.choice(
        [j for j in range(16) if j != i], size=3, replace=False)]
    choices = [[C.CLOSE_LO + i]] + [[C.CLOSE_LO + j] for j in wrong_ids]
    order = rng.permutation(4)
    return TaskInstance("agreement", ctx, [choices[i] for i in order],
                        int(np.argwhere(order == 0)[0, 0]))


def task_majority(rng: np.random.Generator, g: Grammar) -> TaskInstance:
    n = int(rng.integers(7, 14))
    na = int(rng.integers(0, n + 1))
    while abs(2 * na - n) < 3:
        na = int(rng.integers(0, n + 1))
    seq = [C.TOK_A] * na + [C.TOK_B] * (n - na)
    rng.shuffle(seq)
    ans, other = (C.TOK_A, C.TOK_B) if na > n - na else (C.TOK_B, C.TOK_A)
    ctx = [C.TOK_MAJ, *seq, C.TOK_ANS]
    choices = [[ans], [other]]
    order = rng.permutation(2)
    return TaskInstance("majority", ctx, [choices[i] for i in order],
                        int(np.argwhere(order == 0)[0, 0]))


def task_completion(rng: np.random.Generator, g: Grammar) -> TaskInstance:
    ctx = g.walk(rng, g.sample_start(rng), int(rng.integers(10, 20)))
    cont = g.walk(rng, ctx[-1], 5)[1:]  # grammar-consistent continuation
    wrongs = [[int(x) for x in rng.integers(C.TEXT_LO, C.TEXT_HI, size=4)]
              for _ in range(3)]
    choices = [cont] + wrongs
    order = rng.permutation(4)
    return TaskInstance("completion", ctx, [choices[i] for i in order],
                        int(np.argwhere(order == 0)[0, 0]))


# --- harder, few-shot families (MMLU/GSM8K analog) -------------------------

def task_modadd_fewshot(rng: np.random.Generator, g: Grammar) -> TaskInstance:
    ctx: list[int] = []
    for _ in range(3):  # 3 in-context examples
        ctx += seg_modadd(rng)
    a = int(rng.integers(C.MOD_BASE))
    b = int(rng.integers(C.MOD_BASE))
    c = C.VAL_LO + (a + b) % C.MOD_BASE
    ctx += [C.VAL_LO + a, C.TOK_PLUS, C.VAL_LO + b, C.TOK_EQ]
    choices = [[c]] + [[v] for v in _distract_vals(rng, c, 3)]
    order = rng.permutation(4)
    return TaskInstance("modadd", ctx, [choices[i] for i in order],
                        int(np.argwhere(order == 0)[0, 0]))


def task_chain_fewshot(rng: np.random.Generator, g: Grammar) -> TaskInstance:
    ctx: list[int] = []
    for _ in range(2):  # 2 in-context examples
        ctx += seg_twohop(rng)
    k = int(rng.integers(C.KEY_LO, C.KEY_HI))
    m, v = (int(x) for x in rng.choice(C.VAL_HI - C.VAL_LO, size=2, replace=False) + C.VAL_LO)
    ctx += [C.TOK_HOP, k, m, m, v, C.TOK_QUERY, k, C.TOK_ANS]
    choices = [[v]] + [[x] for x in _distract_vals(rng, v, 3)]
    order = rng.permutation(4)
    return TaskInstance("chain", ctx, [choices[i] for i in order],
                        int(np.argwhere(order == 0)[0, 0]))


ZERO_SHOT_FAMILIES = {
    "copy": task_copy,            # ARC-Easy analog
    "recall": task_recall,        # BoolQ analog
    "induction": task_induction,  # WinoGrande analog
    "agreement": task_agreement,  # PIQA analog
    "majority": task_majority,    # HellaSwag analog
    "completion": task_completion,  # ARC-Challenge analog
}
FEW_SHOT_FAMILIES = {
    "modadd": task_modadd_fewshot,  # GSM8K analog
    "chain": task_chain_fewshot,    # MMLU analog
}


def make_tasks(rng: np.random.Generator, g: Grammar,
               n_per_family: int = 100) -> list[TaskInstance]:
    out: list[TaskInstance] = []
    for fam, fn in {**ZERO_SHOT_FAMILIES, **FEW_SHOT_FAMILIES}.items():
        for _ in range(n_per_family):
            inst = fn(rng, g)
            assert len(inst.context) + max(len(c) for c in inst.choices) \
                <= C.MODEL.seq_len, fam
            out.append(inst)
    return out


# ---------------------------------------------------------------------------
# Top-level dataset build
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Dataset:
    grammar_a: Grammar
    grammar_b: Grammar
    calib: np.ndarray       # [N_CALIB, T] int32 — "wiki train" analog
    test_wiki: np.ndarray   # [N_TEST_WIKI, T]
    test_c4: np.ndarray     # [N_TEST_C4, T]
    tasks: list[TaskInstance]

    def train_batches(self, rng: np.random.Generator, batch: int, steps: int):
        """Infinite-ish stream of fresh training batches from grammar A."""
        for _ in range(steps):
            yield make_split(rng, self.grammar_a, batch, C.MODEL.seq_len)


def build_dataset(seed: int = C.DATA_SEED, n_tasks_per_family: int = 100) -> Dataset:
    rng = np.random.default_rng(seed)
    ga = Grammar.build(rng)
    gb = Grammar.build(rng)
    mix = MixGrammar(ga, gb, mix=0.7)
    calib = make_split(rng, ga, C.N_CALIB, C.MODEL.seq_len)
    test_wiki = make_split(rng, ga, C.N_TEST_WIKI, C.MODEL.seq_len)
    test_c4 = make_split(rng, mix, C.N_TEST_C4, C.MODEL.seq_len)
    tasks = make_tasks(rng, ga, n_tasks_per_family)
    return Dataset(ga, gb, calib, test_wiki, test_c4, tasks)

"""Calibration-Hessian capture for the activation-dependent quantizers.

GPTQ needs H = X^T X over calibration inputs of each linear layer; our
AWQ-style clip search scores candidate clip ranges with the Hessian-weighted
output MSE  tr((W - Wq) H (W - Wq)^T)  so it needs the same statistic, plus
the per-channel mean |x| for AWQ-style scaling.  Within a block, Q/K/V share
an input and so do Gate/Up, so only four distinct activations exist per block
(attn_in, o_in, mlp_in, down_in).

This runs once at build time on the *fp* model over the calibration split and
is saved to ``artifacts/hessians.bin``; the rust quantizers consume it
(DESIGN.md §3 — the paper captures the same statistics on GPU at scale).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import config as C
from . import model as M

# Activation slot feeding each linear kind.
ACT_SLOT = {"q": "attn_in", "k": "attn_in", "v": "attn_in", "o": "o_in",
            "gate": "mlp_in", "up": "mlp_in", "down": "down_in"}
ACT_SLOTS = ("attn_in", "o_in", "mlp_in", "down_in")


def capture_hessians(params, calib: np.ndarray,
                     cfg: C.ModelConfig = C.MODEL,
                     batch: int = C.EVAL_BATCH) -> dict[str, np.ndarray]:
    """Returns {"blk{b}.{slot}.hessian": [K,K], "...{slot}.mean_abs": [K]}."""

    @jax.jit
    def acts_fn(toks):
        _, acts = M.forward_fp_with_acts(params, toks, cfg)
        return acts

    sums: dict[str, np.ndarray] = {}
    counts = 0
    n = calib.shape[0]
    assert n % batch == 0, (n, batch)
    for i in range(0, n, batch):
        toks = jnp.asarray(calib[i:i + batch], jnp.int32)
        acts = acts_fn(toks)
        for b in range(cfg.n_layers):
            for slot in ACT_SLOTS:
                key = f"blk{b}.{slot}"
                x = np.asarray(acts[key], np.float64)       # [M, K]
                h = x.T @ x
                a = np.abs(x).sum(axis=0)
                if f"{key}.hessian" not in sums:
                    sums[f"{key}.hessian"] = h
                    sums[f"{key}.mean_abs"] = a
                else:
                    sums[f"{key}.hessian"] += h
                    sums[f"{key}.mean_abs"] += a
        counts += toks.shape[0] * toks.shape[1]

    out: dict[str, np.ndarray] = {}
    for key, val in sums.items():
        out[key] = (val / counts).astype(np.float32)
    return out

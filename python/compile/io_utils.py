"""Binary artifact formats shared with the rust loader (rust/src/data/).

All files are little-endian.  Each "tensor bundle" file is:

    [u32 header_len] [header_len bytes of UTF-8 JSON] [raw tensor data]

The JSON header is ``{"tensors": [{"name", "dtype", "shape", "offset"}, ...]}``
with *byte* offsets relative to the start of the data section.
dtypes: "f32", "i32", "u16", "i8".

Token-split files use the same container with a single 2-D "tokens" tensor.
Task instances are plain JSON (small).
"""

from __future__ import annotations

import json
import struct

import numpy as np

_DTYPES = {"f32": np.float32, "i32": np.int32, "u16": np.uint16, "i8": np.int8}


def write_bundle(path: str, tensors: dict[str, np.ndarray]) -> None:
    entries = []
    blobs = []
    offset = 0
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name])
        dt = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32",
              np.dtype(np.uint16): "u16", np.dtype(np.int8): "i8"}[arr.dtype]
        entries.append({"name": name, "dtype": dt,
                        "shape": list(arr.shape), "offset": offset})
        blobs.append(arr.tobytes())
        offset += arr.nbytes
    header = json.dumps({"tensors": entries}).encode()
    with open(path, "wb") as f:
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)


def read_bundle(path: str) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        data = f.read()
    out = {}
    for e in header["tensors"]:
        dt = np.dtype(_DTYPES[e["dtype"]])
        n = int(np.prod(e["shape"])) if e["shape"] else 1
        arr = np.frombuffer(data, dtype=dt, count=n, offset=e["offset"])
        out[e["name"]] = arr.reshape(e["shape"])
    return out


def write_tokens(path: str, tokens: np.ndarray) -> None:
    assert tokens.dtype == np.int32 and tokens.ndim == 2
    write_bundle(path, {"tokens": tokens})


def write_tasks_json(path: str, tasks) -> None:
    payload = [{"family": t.family, "context": [int(x) for x in t.context],
                "choices": [[int(x) for x in c] for c in t.choices],
                "answer": int(t.answer)} for t in tasks]
    with open(path, "w") as f:
        json.dump(payload, f)

"""L1 Pallas kernels (build-time; lowered with interpret=True into the HLO)."""

from .dequant_matmul import dequant_matmul, vmem_bytes  # noqa: F401
from .jsd import jsd_tokens  # noqa: F401

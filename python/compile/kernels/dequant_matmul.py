"""L1 Pallas kernel: grouped dequantize + matmul (the inference hot spot).

This is the TPU rethink of the paper's per-layer CUDA kernels (TensorRT-LLM /
AutoGPTQ): the paper's insight is that weight-only quantized inference is
*weight-streaming bound*, and keeping one bit-width per linear layer keeps the
stream regular.  On a TPU that maps to a BlockSpec schedule (DESIGN.md §6):

  grid = (M/TM, N/TN); for each (i, j) the kernel sees
    x tile      [TM, K]   (activations, f32, streamed HBM->VMEM)
    codes tile  [TN, K]   (int8 quantization codes for TN output rows)
    scale tile  [TN, G]   zero tile [TN, G]
  dequantizes the TN x K tile group-wise into VMEM and feeds a [TM,K]x[K,TN]
  MXU matmul, accumulating in f32.

K is kept whole per block (K <= 512 here), so VMEM per program instance is
  TM*K*4 + TN*K*(1+4) + TN*G*8 + TM*TN*4  bytes  (see EXPERIMENTS.md §Perf).

``interpret=True`` lowers the kernel to plain HLO so the AOT artifact runs on
the CPU PJRT client; on a real TPU the same BlockSpecs target VMEM/MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, codes_ref, scale_ref, zero_ref, o_ref, *, group_size: int):
    x = x_ref[...]                      # [TM, K] f32
    codes = codes_ref[...]              # [TN, K] int8
    scale = scale_ref[...]              # [TN, G] f32
    zero = zero_ref[...]                # [TN, G] f32
    tn, k = codes.shape
    g = k // group_size
    c = codes.astype(jnp.float32).reshape(tn, g, group_size)
    w = (c - zero[:, :, None]) * scale[:, :, None]   # dequant in VMEM
    w = w.reshape(tn, k)
    # MXU matmul: [TM, K] x [K, TN] with f32 accumulation.
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group_size", "block_m", "block_n"))
def dequant_matmul(x: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                   zero: jnp.ndarray, *, group_size: int = 128,
                   block_m: int = 128, block_n: int = 128) -> jnp.ndarray:
    """y[M,N] = x[M,K] @ dequant(codes,scale,zero)[N,K].T

    Shapes: x [M,K] f32, codes [N,K] int8, scale/zero [N,G] f32 with
    G = K/group_size.  M must divide by block_m and N by block_n (callers pad;
    the model uses M = batch*seq which is MXU-aligned by construction).
    """
    m, k = x.shape
    n, k2 = codes.shape
    assert k == k2, (k, k2)
    assert k % group_size == 0
    g = k // group_size
    bm = min(block_m, m)
    bn = min(block_n, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)

    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, g), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, g), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU-PJRT target; Mosaic lowering is TPU-only
    )(x, codes, scale, zero)


def vmem_bytes(block_m: int, block_n: int, k: int, group_size: int) -> int:
    """Estimated VMEM footprint per program instance (perf model, §Perf)."""
    g = k // group_size
    return (block_m * k * 4          # x tile f32
            + block_n * k            # codes tile int8
            + block_n * k * 4        # dequantized tile f32
            + block_n * g * 8        # scale + zero
            + block_m * block_n * 4  # accumulator
            )

"""L1 Pallas kernel: per-token Jensen-Shannon divergence.

AMQ's quality signal (§3.4 of the paper) is the JSD between the logits of the
assembled quantized model and the FP reference.  On the search hot path this
runs once per candidate over the whole calibration batch, so it is fused into
the AOT "scorer" executable rather than shipping logits back to rust.

BlockSpec schedule: grid over token blocks; each program instance reduces a
[TB, V] pair of logit tiles to [TB] divergences entirely in VMEM
(V = 512 here -> TB*V*4*2 bytes of logits per instance).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _log_softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    s = x - m
    return s - jnp.log(jnp.sum(jnp.exp(s), axis=-1, keepdims=True))


def _kernel(p_ref, q_ref, o_ref):
    logp = _log_softmax(p_ref[...])
    logq = _log_softmax(q_ref[...])
    p = jnp.exp(logp)
    q = jnp.exp(logq)
    logm = jnp.logaddexp(logp, logq) - jnp.log(2.0)
    kl_pm = jnp.sum(p * (logp - logm), axis=-1)
    kl_qm = jnp.sum(q * (logq - logm), axis=-1)
    o_ref[...] = 0.5 * (kl_pm + kl_qm)


@functools.partial(jax.jit, static_argnames=("block_t",))
def jsd_tokens(logits_p: jnp.ndarray, logits_q: jnp.ndarray,
               *, block_t: int = 256) -> jnp.ndarray:
    """Per-token JSD in nats. logits_*: [T, V] f32 -> [T] f32."""
    t, v = logits_p.shape
    bt = min(block_t, t)
    assert t % bt == 0, (t, bt)
    return pl.pallas_call(
        _kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, v), lambda i: (i, 0)),
            pl.BlockSpec((bt, v), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        interpret=True,
    )(logits_p, logits_q)

"""Pure-jnp oracles for the Pallas kernels (correctness references).

Conventions (shared with the rust side, see rust/src/quant/pack.rs):

  * A linear layer weight ``W`` has shape ``[out, in]`` (y = x @ W.T).
  * Grouped quantization runs along ``in`` with group size ``gs``:
    ``W[o, g*gs + j] ≈ (codes[o, g*gs + j] - zero[o, g]) * scale[o, g]``.
  * ``codes`` are small non-negative integers stored as int8 regardless of
    the logical bit-width; the bit-width only constrains the code range and
    the memory accounting (DESIGN.md §3; physical packing lives in rust).
"""

from __future__ import annotations

import jax.numpy as jnp


def _log_softmax(x: jnp.ndarray) -> jnp.ndarray:
    m = jnp.max(x, axis=-1, keepdims=True)
    s = x - m
    return s - jnp.log(jnp.sum(jnp.exp(s), axis=-1, keepdims=True))


def dequant(codes: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
            group_size: int) -> jnp.ndarray:
    """Reconstruct f32 weights from grouped codes. codes:[N,K], s/z:[N,G]."""
    n, k = codes.shape
    g = k // group_size
    c = codes.astype(jnp.float32).reshape(n, g, group_size)
    w = (c - zero[:, :, None]) * scale[:, :, None]
    return w.reshape(n, k)


def dequant_matmul(x: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                   zero: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """y = x @ dequant(W).T  with x:[M,K], codes:[N,K] -> y:[M,N]."""
    w = dequant(codes, scale, zero, group_size)
    return x @ w.T


def jsd_tokens(logits_p: jnp.ndarray, logits_q: jnp.ndarray) -> jnp.ndarray:
    """Per-token Jensen-Shannon divergence between two logit tensors.

    logits_*: [..., V] -> jsd: [...] in nats; always within [0, ln 2].
    """
    logp = _log_softmax(logits_p)
    logq = _log_softmax(logits_q)
    p = jnp.exp(logp)
    q = jnp.exp(logq)
    logm = jnp.logaddexp(logp, logq) - jnp.log(2.0)
    kl_pm = jnp.sum(p * (logp - logm), axis=-1)
    kl_qm = jnp.sum(q * (logq - logm), axis=-1)
    return 0.5 * (kl_pm + kl_qm)


def cross_entropy_tokens(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Per-token CE in nats. logits:[...,V], targets:[...] int."""
    logp = _log_softmax(logits)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]

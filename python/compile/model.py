"""L2: the subject model — a tiny-Llama in JAX (fp32 + quantized paths).

Architecture: token embedding -> n_layers x (RMSNorm -> causal MHA with RoPE
-> residual; RMSNorm -> SwiGLU MLP -> residual) -> RMSNorm -> LM head.

Two forward paths share all non-linear structure:

  * ``forward_fp``    — plain f32 weights; used for training, the FP reference
    logits, and calibration-Hessian capture.
  * ``forward_quant`` — every per-block linear (Q,K,V,O,Gate,Up,Down) runs
    through the L1 Pallas grouped dequant-matmul kernel on int8 codes +
    per-group scale/zero.  This is the graph the rust coordinator executes
    via PJRT for every assembled candidate configuration.

``scores_quant`` fuses the paper's quality signal into the graph: it returns
(mean JSD vs. the FP logits, mean next-token CE) so the search hot path moves
only token ids + packed parameters across the PJRT boundary, never logits.

Parameter pytrees are plain dicts; JAX flattens dicts in sorted-key order,
which is the argument order recorded in artifacts/manifest.json.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import config as C
from .config import ModelConfig
from .kernels import dequant_matmul, jsd_tokens
from .kernels import ref


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    """Flat name -> shape for every fp parameter (sorted-key arg order)."""
    shapes: dict[str, tuple[int, ...]] = {
        "embed": (cfg.vocab_size, cfg.d_model),
        "lm_head": (cfg.vocab_size, cfg.d_model),
        "final_norm": (cfg.d_model,),
    }
    for b in range(cfg.n_layers):
        shapes[f"blk{b}.attn_norm"] = (cfg.d_model,)
        shapes[f"blk{b}.mlp_norm"] = (cfg.d_model,)
        for kind in C.LINEAR_KINDS:
            shapes[f"blk{b}.{kind}"] = C.linear_shape(cfg, kind)
    return shapes


def init_params(rng: np.random.Generator, cfg: ModelConfig) -> dict[str, jnp.ndarray]:
    params = {}
    for name, shape in param_shapes(cfg).items():
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            std = 1.0 / np.sqrt(fan_in)
            params[name] = jnp.asarray(
                rng.normal(0.0, std, size=shape), jnp.float32)
    return params


def quant_param_shapes(cfg: ModelConfig) -> dict[str, dict[str, tuple[int, ...]]]:
    """name -> {codes, scale, zero} shapes for every searchable linear."""
    out = {}
    for name in C.layer_names(cfg):
        kind = name.split(".")[1]
        n, k = C.linear_shape(cfg, kind)
        g = C.n_groups(k)
        out[name] = {"codes": (n, k), "scale": (n, g), "zero": (n, g)}
    return out


def fp_side_names(cfg: ModelConfig) -> list[str]:
    """FP parameters that stay f32 in the quantized graph (not searched)."""
    names = ["embed", "lm_head", "final_norm"]
    for b in range(cfg.n_layers):
        names += [f"blk{b}.attn_norm", f"blk{b}.mlp_norm"]
    return sorted(names)


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_tables(cfg: ModelConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables, computed in *numpy* so they lower to HLO constants.

    Computed in-graph they would go through each XLA version's pow/cos
    approximations; tiny inv-freq differences produce angle errors that grow
    linearly with position and would make the rust-side (xla_extension 0.5.1)
    logits drift from the build-time (jaxlib) golden reference.
    """
    hd = cfg.head_dim
    pos = np.arange(cfg.seq_len, dtype=np.float64)
    inv = 1.0 / (cfg.rope_theta ** (np.arange(0, hd, 2, dtype=np.float64) / hd))
    ang = pos[:, None] * inv[None, :]          # [T, hd/2]
    return (jnp.asarray(np.cos(ang), jnp.float32),
            jnp.asarray(np.sin(ang), jnp.float32))


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [B, T, H, hd]; rotate interleaved (even, odd) pairs."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    r1 = x1 * c - x2 * s
    r2 = x1 * s + x2 * c
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def _attention(q, k, v, cfg: ModelConfig):
    """q,k,v: [B, T, H, hd] -> [B, T, H*hd]; causal."""
    b, t, h, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, v)
    return out.reshape(b, t, h * hd)


# ---------------------------------------------------------------------------
# Linear dispatch: fp vs quantized
# ---------------------------------------------------------------------------

def _forward(fp_params, tokens, cfg: ModelConfig, lin, capture: bool = False):
    """Shared forward; ``lin(name, x2d)`` dispatches each searchable linear."""
    b, t = tokens.shape
    d = cfg.d_model
    cos, sin = rope_tables(cfg)
    x = fp_params["embed"][tokens]                      # [B,T,D]
    acts: dict[str, jnp.ndarray] = {}

    for blk in range(cfg.n_layers):
        p = f"blk{blk}"
        h = rmsnorm(x, fp_params[f"{p}.attn_norm"], cfg.rms_eps)
        h2 = h.reshape(b * t, d)
        if capture:
            acts[f"{p}.attn_in"] = h2
        qh = lin(f"{p}.q", h2).reshape(b, t, cfg.n_heads, cfg.head_dim)
        kh = lin(f"{p}.k", h2).reshape(b, t, cfg.n_heads, cfg.head_dim)
        vh = lin(f"{p}.v", h2).reshape(b, t, cfg.n_heads, cfg.head_dim)
        qh = apply_rope(qh, cos, sin)
        kh = apply_rope(kh, cos, sin)
        attn = _attention(qh, kh, vh, cfg).reshape(b * t, d)
        if capture:
            acts[f"{p}.o_in"] = attn
        x = x + lin(f"{p}.o", attn).reshape(b, t, d)

        h = rmsnorm(x, fp_params[f"{p}.mlp_norm"], cfg.rms_eps)
        h2 = h.reshape(b * t, d)
        if capture:
            acts[f"{p}.mlp_in"] = h2
        gate = lin(f"{p}.gate", h2)
        up = lin(f"{p}.up", h2)
        act = jax.nn.silu(gate) * up
        if capture:
            acts[f"{p}.down_in"] = act
        x = x + lin(f"{p}.down", act).reshape(b, t, d)

    x = rmsnorm(x, fp_params["final_norm"], cfg.rms_eps)
    logits = x.reshape(b * t, d) @ fp_params["lm_head"].T
    logits = logits.reshape(b, t, cfg.vocab_size)
    return (logits, acts) if capture else logits


def forward_fp(params, tokens, cfg: ModelConfig = C.MODEL):
    return _forward(params, tokens, cfg, lambda n, x: x @ params[n].T)


def forward_fp_with_acts(params, tokens, cfg: ModelConfig = C.MODEL):
    return _forward(params, tokens, cfg, lambda n, x: x @ params[n].T,
                    capture=True)


def forward_quant(fp_params, qparams, tokens, cfg: ModelConfig = C.MODEL):
    # Kernel block shape (EXPERIMENTS.md §Perf).  On a real TPU you would
    # keep MXU-aligned 128x128 tiles and let the grid parallelize across
    # cores; on this CPU-interpret target the lowered grid becomes a serial
    # XLA while-loop, so taking the whole M in one block (M = batch*seq =
    # 2048) removes 15/16 of the loop trips and cut the quantized forward
    # from ~3.0x to ~1.25x the fp32 forward's wall-clock.
    import os
    block_m = int(os.environ.get("AMQ_BLOCK_M", "2048"))
    block_n = int(os.environ.get("AMQ_BLOCK_N", "128"))

    def lin(name, x2d):
        q = qparams[name]
        return dequant_matmul(x2d, q["codes"], q["scale"], q["zero"],
                              group_size=C.GROUP_SIZE,
                              block_m=block_m, block_n=block_n)
    return _forward(fp_params, tokens, cfg, lin)


# ---------------------------------------------------------------------------
# Scoring heads
# ---------------------------------------------------------------------------

def scores_quant(fp_params, qparams, tokens, mask, fp_logits,
                 cfg: ModelConfig = C.MODEL):
    """Fused search-path scorer -> (mean JSD, mean next-token CE) scalars.

    mask: f32 [B,T], 1.0 = position counts.  JSD is averaged over valid
    positions, CE over valid *target* positions (shift by one).
    """
    logits = forward_quant(fp_params, qparams, tokens, cfg)
    b, t, v = logits.shape
    jsd = jsd_tokens(fp_logits.reshape(b * t, v), logits.reshape(b * t, v))
    jsd = jsd.reshape(b, t)
    jsd_mean = jnp.sum(jsd * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    ce_tok = ref.cross_entropy_tokens(logits[:, :-1], tokens[:, 1:])
    tgt_mask = mask[:, 1:]
    ce_mean = jnp.sum(ce_tok * tgt_mask) / jnp.maximum(jnp.sum(tgt_mask), 1.0)
    return jsd_mean, ce_mean


def scores_quant_lanes(fp_params, qlanes, tokens, mask, fp_logits,
                       cfg: ModelConfig = C.MODEL):
    """Lane-stacked scorer: L independent candidates in one executable.

    ``qlanes`` mirrors the ``scores_quant`` qparams pytree, but every leaf
    carries a leading candidate axis of size L (codes ``[L,N,K]``,
    scale/zero ``[L,N,G]``).  tokens / mask / fp reference logits / fp-side
    parameters are shared across lanes.  Returns ``(jsd[L], ce[L])``.

    Each lane is the *unchanged* single-candidate graph vmapped over the
    candidate axis: every reduction (JSD/CE masked means, attention
    softmax) runs over non-batched axes only, so per-lane results are
    bitwise identical to ``scores_quant`` on that candidate — the identity
    the rust runtime's lane-stacked dispatch path relies on (pinned by
    ``test_model.test_scores_quant_lanes_bitwise_identical``).
    """
    def one(qparams):
        return scores_quant(fp_params, qparams, tokens, mask, fp_logits, cfg)
    jsd, ce = jax.vmap(one)(qlanes)
    return jsd, ce


def gather_lane_slab(lane_parts):
    """Device-side slab gather: stack L resident candidate pieces into slabs.

    ``lane_parts`` is a list of L ``{codes, scale, zero}`` dicts of one
    quant-slot shape family (identical ``(N, K, G)`` on every lane); the
    runtime passes the device bank's resident buffers, repeating lane 0's
    piece for the padded tail of a partial group.  Returns the lane-stacked
    slab triple ``(codes [L,N,K], scale [L,N,G], zero [L,N,G])`` — element
    for element the layout the rust host path produces with
    ``pack_lane_slab`` + ``upload_lane_slab``, so a cache miss served by
    this executable is bitwise indistinguishable from a host pack.

    ``jnp.stack`` lowers to broadcasts feeding one ``concatenate`` per
    output; because the inputs are already device-resident, the whole miss
    costs one fused kernel instead of O(slab bytes) over the host link.
    """
    codes = jnp.stack([p["codes"] for p in lane_parts])
    scale = jnp.stack([p["scale"] for p in lane_parts])
    zero = jnp.stack([p["zero"] for p in lane_parts])
    return codes, scale, zero


def ce_fp(params, tokens, cfg: ModelConfig = C.MODEL):
    """Mean next-token CE of the fp model (training loss)."""
    logits = forward_fp(params, tokens, cfg)
    ce = ref.cross_entropy_tokens(logits[:, :-1], tokens[:, 1:])
    return jnp.mean(ce)

"""Build-time training of the subject model (AdamW, cosine schedule).

The paper searches over *pretrained* LLMs; our substitute model must be
genuinely trained so its linear layers develop the heterogeneous quantization
sensitivity the search exploits (DESIGN.md §3).  Runs once inside
``make artifacts``; never on the rust request path.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import config as C
from . import data as D
from . import model as M


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.01):
    step = state["step"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                               state["v"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, m_, v_):
        update = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - lr * (update + weight_decay * p)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}


def cosine_lr(step: jnp.ndarray, total: int, peak: float = 3e-3,
              warmup: int = 40) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = peak * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * peak * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)


def train(dataset: D.Dataset, cfg: C.ModelConfig = C.MODEL,
          steps: int | None = None, batch: int | None = None,
          seed: int = 7, log_every: int = 25):
    """Train the fp model; returns (params, loss_log list of (step, loss))."""
    steps = steps or C.train_steps()
    batch = batch or C.train_batch()
    rng = np.random.default_rng(seed)
    params = M.init_params(rng, cfg)
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt, toks, step_idx):
        loss, grads = jax.value_and_grad(M.ce_fp)(params, toks)
        lr = cosine_lr(step_idx, steps)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    log: list[tuple[int, float]] = []
    t0 = time.time()
    data_rng = np.random.default_rng(seed + 1)
    for i, toks in enumerate(dataset.train_batches(data_rng, batch, steps)):
        toks = jnp.asarray(toks, jnp.int32)
        params, opt, loss = step_fn(params, opt, toks, jnp.int32(i))
        if i % log_every == 0 or i == steps - 1:
            l = float(loss)
            log.append((i, l))
            print(f"[train] step {i:5d}  loss {l:.4f}  "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return params, log

"""Test-suite bootstrap: import-path setup + dependency-missing guards.

The L2 compile layer (``python/compile``) depends on JAX/Pallas, and the
kernel sweeps additionally use ``hypothesis``.  CI runners (and the offline
build image) may lack either, so instead of failing at collection time this
conftest skips exactly the test modules whose imports are unavailable:

* no ``numpy``      -> everything skips (nothing is importable);
* no ``jax``        -> model/AOT/kernel tests skip, pure-numpy data tests run;
* no ``hypothesis`` -> the kernel property sweeps skip.

``python -m pytest python/tests -q`` therefore passes (with skips) on any
runner, and exercises the full surface wherever the real deps exist.
"""

import importlib.util
import os
import sys

# ``from compile import ...`` resolves against python/, regardless of cwd.
_PYTHON_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _PYTHON_DIR not in sys.path:
    sys.path.insert(0, _PYTHON_DIR)


def _missing(*modules: str) -> list:
    return [m for m in modules if importlib.util.find_spec(m) is None]


# Per-module hard requirements (beyond numpy/pytest themselves).
_REQUIREMENTS = {
    "test_data.py": ["numpy"],
    "test_model.py": ["numpy", "jax"],
    "test_aot.py": ["numpy", "jax"],
    "test_kernels.py": ["numpy", "jax", "hypothesis"],
}

collect_ignore = []
for _file, _deps in _REQUIREMENTS.items():
    _absent = _missing(*_deps)
    if _absent:
        sys.stderr.write(
            f"[conftest] skipping {_file}: missing {', '.join(_absent)}\n"
        )
        collect_ignore.append(_file)

"""AOT pipeline smoke tests (tiny build into tmp, no full training)."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import config as C
from compile import data as D
from compile import hessian as H
from compile import io_utils as IO
from compile import model as M
from compile import train as T


LANES = 2  # small lane count keeps the fixture build fast; geometry is L-agnostic


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(out, steps=3, tasks_per_family=3, lanes=LANES)
    return out


def test_manifest_consistency(built):
    m = json.load(open(os.path.join(built, "manifest.json")))
    n_lin = m["model"]["n_layers"] * 7
    assert len(m["layers"]) == n_lin
    # fp exec: tokens + all fp params
    assert len(m["executables"]["model_fp"]["args"]) == \
        1 + len(M.param_shapes(C.MODEL))
    # quant exec: tokens + fp-side + 3 per linear
    assert len(m["executables"]["model_quant"]["args"]) == \
        1 + len(m["fp_side_names"]) + 3 * n_lin
    assert len(m["executables"]["scores_quant"]["args"]) == \
        3 + len(m["fp_side_names"]) + 3 * n_lin
    # manifest arg names must be unique and ordered-deterministic
    for exe in m["executables"].values():
        assert len(exe["args"]) == len(set(exe["args"]))


def test_manifest_lane_scorer(built):
    m = json.load(open(os.path.join(built, "manifest.json")))
    assert m["score_lanes"] == LANES
    lanes = m["executables"]["scores_quant_lanes"]
    assert lanes["lanes"] == LANES
    assert lanes["file"] == f"scores_quant_lanes{LANES}.hlo.txt"
    # same flat argument names (and order) as the single-candidate scorer:
    # the rust arg planner reuses its slot classification for both
    assert lanes["args"] == m["executables"]["scores_quant"]["args"]
    assert lanes["outputs"] == ["jsd", "ce"]


def test_lane_scorer_hlo_carries_candidate_axis(built):
    m = json.load(open(os.path.join(built, "manifest.json")))
    exe = m["executables"]["scores_quant_lanes"]
    text = open(os.path.join(built, exe["file"])).read()
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(") == len(exe["args"])
    # a quant slot must be lane-stacked: codes of the first linear layer
    n, k = C.linear_shape(C.MODEL, "q")
    assert f"s8[{LANES},{n},{k}]" in entry
    # outputs are per-lane vectors, not scalars
    assert f"(f32[{LANES}]" in entry or f"f32[{LANES}]{{0}}" in entry


def quant_shape_families():
    """Distinct (out_features, in_features) of the searchable linears."""
    return sorted({C.linear_shape(C.MODEL, k) for k in C.LINEAR_KINDS})


def test_manifest_gather_entries(built):
    m = json.load(open(os.path.join(built, "manifest.json")))
    families = quant_shape_families()
    keys = [k for k in m["executables"] if k.startswith("gather_lanes_")]
    assert sorted(keys) == [f"gather_lanes_{n}x{k}" for n, k in families]
    want_args = [f"lane{i}.{p}" for i in range(LANES)
                 for p in ("codes", "scale", "zero")]
    for n, k in families:
        exe = m["executables"][f"gather_lanes_{n}x{k}"]
        assert exe["lanes"] == LANES
        assert exe["file"] == f"gather_lanes{LANES}_{n}x{k}.hlo.txt"
        assert os.path.exists(os.path.join(built, exe["file"]))
        # lane-major (codes, scale, zero) triples: the arg order the rust
        # runtime feeds resident bank buffers in (lane 0 repeated for the
        # padded tail of a partial group)
        assert exe["args"] == want_args
        assert exe["outputs"] == ["codes", "scale", "zero"]


def test_gather_hlo_carries_lane_axis(built):
    m = json.load(open(os.path.join(built, "manifest.json")))
    n, k = C.linear_shape(C.MODEL, "q")
    g = C.n_groups(k)
    exe = m["executables"][f"gather_lanes_{n}x{k}"]
    text = open(os.path.join(built, exe["file"])).read()
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(") == len(exe["args"])
    # inputs are per-lane pieces; outputs are lane-stacked slabs
    assert f"s8[{n},{k}]" in entry
    assert f"s8[{LANES},{n},{k}]" in entry
    assert f"f32[{LANES},{n},{g}]" in entry


def test_gather_matches_numpy_stack():
    # The gather fn's contract: its output is elementwise the host
    # pack_lane_slab layout — a plain stack of the lane pieces, with the
    # caller repeating lane 0 for the padded tail.
    from compile import model as M2
    rng = np.random.default_rng(5)
    n, k = C.linear_shape(C.MODEL, "q")
    g = C.n_groups(k)
    pieces = [{
        "codes": rng.integers(-8, 8, size=(n, k)).astype(np.int8),
        "scale": rng.standard_normal((n, g)).astype(np.float32),
        "zero": rng.standard_normal((n, g)).astype(np.float32),
    } for _ in range(2)]
    padded = pieces + [pieces[0], pieces[0]]  # 2 real lanes padded to 4
    codes, scale, zero = M2.gather_lane_slab(padded)
    np.testing.assert_array_equal(
        np.asarray(codes), np.stack([p["codes"] for p in padded]))
    np.testing.assert_array_equal(
        np.asarray(scale), np.stack([p["scale"] for p in padded]))
    np.testing.assert_array_equal(
        np.asarray(zero), np.stack([p["zero"] for p in padded]))
    np.testing.assert_array_equal(np.asarray(codes)[2], pieces[0]["codes"])


def test_build_without_lanes_omits_artifact(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts_nolanes"))
    aot.build(out, steps=2, tasks_per_family=2, lanes=1)
    m = json.load(open(os.path.join(out, "manifest.json")))
    assert m["score_lanes"] == 1
    assert "scores_quant_lanes" not in m["executables"]
    assert not [f for f in os.listdir(out) if f.startswith("scores_quant_lanes")]
    # no lane scorer -> no gather executables either, even though the
    # gather default is on: gathering is only meaningful for lane slabs
    assert not [k for k in m["executables"] if k.startswith("gather_lanes_")]
    assert not [f for f in os.listdir(out) if f.startswith("gather_lanes")]


def test_build_without_gather_keeps_lane_scorer(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts_nogather"))
    aot.build(out, steps=2, tasks_per_family=2, lanes=LANES, gather=False)
    m = json.load(open(os.path.join(out, "manifest.json")))
    assert m["score_lanes"] == LANES
    assert "scores_quant_lanes" in m["executables"]
    assert not [k for k in m["executables"] if k.startswith("gather_lanes_")]
    assert not [f for f in os.listdir(out) if f.startswith("gather_lanes")]


def test_hlo_entry_param_counts(built):
    m = json.load(open(os.path.join(built, "manifest.json")))
    for exe in m["executables"].values():
        text = open(os.path.join(built, exe["file"])).read()
        entry = text[text.index("ENTRY"):]
        assert entry.count("parameter(") == len(exe["args"]), exe["file"]


def test_weights_roundtrip(built):
    w = IO.read_bundle(os.path.join(built, "weights.bin"))
    shapes = M.param_shapes(C.MODEL)
    assert set(w) == set(shapes)
    for k, v in w.items():
        assert tuple(v.shape) == tuple(shapes[k])
        assert np.isfinite(v).all()


def test_hessians_posdefish(built):
    h = IO.read_bundle(os.path.join(built, "hessians.bin"))
    for k, v in h.items():
        if k.endswith("hessian"):
            assert v.shape[0] == v.shape[1]
            # symmetric PSD (up to fp noise)
            np.testing.assert_allclose(v, v.T, rtol=1e-3, atol=1e-4)
            eig = np.linalg.eigvalsh(v.astype(np.float64))
            assert eig.min() > -1e-4, k


def test_golden_matches_recomputed(built):
    import jax.numpy as jnp
    g = IO.read_bundle(os.path.join(built, "golden.bin"))
    w = IO.read_bundle(os.path.join(built, "weights.bin"))
    params = {k: jnp.asarray(v) for k, v in w.items()}
    logits = M.forward_fp(params, jnp.asarray(g["tokens"][:2], jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), g["fp_logits"],
                               rtol=2e-3, atol=2e-3)


def test_bundle_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.standard_normal((3, 4)).astype(np.float32),
        "b": rng.integers(0, 100, size=(7,)).astype(np.int32),
        "c": rng.integers(-8, 8, size=(2, 5)).astype(np.int8),
    }
    path = str(tmp_path / "t.bin")
    IO.write_bundle(path, tensors)
    back = IO.read_bundle(path)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


def test_training_reduces_loss():
    ds = D.build_dataset(seed=9, n_tasks_per_family=2)
    _, log = T.train(ds, C.MODEL, steps=60, batch=8, log_every=10)
    first, last = log[0][1], log[-1][1]
    assert np.isfinite(last)
    assert last < first - 0.5, (first, last)

"""Corpus/task generator invariants: determinism, vocab ranges, solvability."""

import numpy as np
import pytest

from compile import config as C
from compile import data as D


@pytest.fixture(scope="module")
def small_ds():
    return D.build_dataset(seed=123, n_tasks_per_family=5)


def test_deterministic():
    a = D.build_dataset(seed=42, n_tasks_per_family=3)
    b = D.build_dataset(seed=42, n_tasks_per_family=3)
    np.testing.assert_array_equal(a.calib, b.calib)
    np.testing.assert_array_equal(a.test_c4, b.test_c4)
    assert [t.context for t in a.tasks] == [t.context for t in b.tasks]


def test_seed_changes_data():
    a = D.build_dataset(seed=1, n_tasks_per_family=2)
    b = D.build_dataset(seed=2, n_tasks_per_family=2)
    assert not np.array_equal(a.calib, b.calib)


def test_token_ranges(small_ds):
    for split in (small_ds.calib, small_ds.test_wiki, small_ds.test_c4):
        assert split.dtype == np.int32
        assert split.min() >= 0 and split.max() < C.VOCAB_SIZE


def test_split_shapes(small_ds):
    assert small_ds.calib.shape == (C.N_CALIB, C.MODEL.seq_len)
    assert small_ds.test_wiki.shape == (C.N_TEST_WIKI, C.MODEL.seq_len)
    assert small_ds.test_c4.shape == (C.N_TEST_C4, C.MODEL.seq_len)


def test_task_instances_valid(small_ds):
    fams = set()
    for t in small_ds.tasks:
        fams.add(t.family)
        assert 0 <= t.answer < len(t.choices)
        assert len(t.choices) >= 2
        total = len(t.context) + max(len(c) for c in t.choices)
        assert total <= C.MODEL.seq_len
        for tok in t.context + [x for c in t.choices for x in c]:
            assert 0 <= tok < C.VOCAB_SIZE
    assert fams == set(D.ZERO_SHOT_FAMILIES) | set(D.FEW_SHOT_FAMILIES)


def test_task_choices_distinct(small_ds):
    for t in small_ds.tasks:
        as_tuples = [tuple(c) for c in t.choices]
        assert len(set(as_tuples)) == len(as_tuples), t.family


def test_segments_end_with_eos():
    rng = np.random.default_rng(0)
    g = D.Grammar.build(rng)
    for fam, fn in D.SEGMENT_FNS.items():
        seg = fn(rng, g)
        assert seg[-1] == C.TOK_EOS, fam
        assert all(0 <= t < C.VOCAB_SIZE for t in seg), fam


def test_grammar_walk_follows_transitions():
    rng = np.random.default_rng(0)
    g = D.Grammar.build(rng)
    walk = g.walk(rng, C.TEXT_LO + 5, 50)
    for prev, nxt in zip(walk, walk[1:]):
        assert nxt in set(g.succ[prev - C.TEXT_LO].tolist())


def test_modadd_correct():
    rng = np.random.default_rng(0)
    for _ in range(50):
        seg = D.seg_modadd(rng)
        a, b, c = seg[0] - C.VAL_LO, seg[2] - C.VAL_LO, seg[4] - C.VAL_LO
        assert (a + b) % C.MOD_BASE == c


def test_majority_answer_correct():
    rng = np.random.default_rng(0)
    for _ in range(50):
        seg = D.seg_majority(rng)
        body = seg[1:-3]
        na = sum(1 for t in body if t == C.TOK_A)
        nb = sum(1 for t in body if t == C.TOK_B)
        ans = seg[-2]
        assert ans == (C.TOK_A if na > nb else C.TOK_B)

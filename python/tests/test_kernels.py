"""L1 kernel correctness: Pallas vs pure-jnp oracle (hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dequant_matmul, jsd_tokens, vmem_bytes
from compile.kernels import ref


def _mk_quant(rng, n, k, gs, bits):
    codes = rng.integers(0, 2 ** bits, size=(n, k)).astype(np.int8)
    g = k // gs
    scale = rng.uniform(0.01, 0.2, size=(n, g)).astype(np.float32)
    zero = rng.uniform(0.0, 2 ** bits - 1, size=(n, g)).astype(np.float32)
    return codes, scale, zero


# ---------------------------------------------------------------------------
# dequant_matmul
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    m_blocks=st.integers(1, 3),
    n_blocks=st.integers(1, 2),
    k_groups=st.integers(1, 4),
    gs=st.sampled_from([32, 64, 128]),
    bits=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_dequant_matmul_matches_ref(m_blocks, n_blocks, k_groups, gs, bits, seed):
    rng = np.random.default_rng(seed)
    bm, bn = 32, 32
    m, n, k = m_blocks * bm, n_blocks * bn, k_groups * gs
    x = rng.standard_normal((m, k)).astype(np.float32)
    codes, scale, zero = _mk_quant(rng, n, k, gs, bits)
    got = dequant_matmul(jnp.asarray(x), jnp.asarray(codes),
                         jnp.asarray(scale), jnp.asarray(zero),
                         group_size=gs, block_m=bm, block_n=bn)
    want = ref.dequant_matmul(jnp.asarray(x), jnp.asarray(codes),
                              jnp.asarray(scale), jnp.asarray(zero), gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_dequant_matmul_model_shapes():
    """The exact shapes the model uses (M=B*T, per-layer N,K)."""
    rng = np.random.default_rng(0)
    for n, k in [(128, 128), (256, 128), (128, 256)]:
        m = 16 * 128
        x = rng.standard_normal((m, k)).astype(np.float32)
        codes, scale, zero = _mk_quant(rng, n, k, 128, 4)
        got = dequant_matmul(jnp.asarray(x), jnp.asarray(codes),
                             jnp.asarray(scale), jnp.asarray(zero),
                             group_size=128)
        want = ref.dequant_matmul(jnp.asarray(x), jnp.asarray(codes),
                                  jnp.asarray(scale), jnp.asarray(zero), 128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_dequant_exact_roundtrip():
    """If W is exactly representable, dequant-matmul is exact (up to fp)."""
    rng = np.random.default_rng(1)
    n, k, gs = 64, 128, 64
    codes, scale, zero = _mk_quant(rng, n, k, gs, 3)
    w = np.asarray(ref.dequant(jnp.asarray(codes), jnp.asarray(scale),
                               jnp.asarray(zero), gs))
    x = rng.standard_normal((32, k)).astype(np.float32)
    got = dequant_matmul(jnp.asarray(x), jnp.asarray(codes),
                         jnp.asarray(scale), jnp.asarray(zero),
                         group_size=gs, block_m=32, block_n=32)
    np.testing.assert_allclose(np.asarray(got), x @ w.T, rtol=1e-4, atol=1e-4)


def test_vmem_estimate_within_budget():
    # Default blocks on the largest layer shape must fit a 16 MiB VMEM.
    assert vmem_bytes(128, 128, 256, 128) < 16 * 2 ** 20


# ---------------------------------------------------------------------------
# jsd
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    t_blocks=st.integers(1, 3),
    v=st.sampled_from([64, 512]),
    scale=st.floats(0.1, 8.0),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_jsd_matches_ref(t_blocks, v, scale, seed):
    rng = np.random.default_rng(seed)
    t = t_blocks * 64
    p = (rng.standard_normal((t, v)) * scale).astype(np.float32)
    q = (rng.standard_normal((t, v)) * scale).astype(np.float32)
    got = jsd_tokens(jnp.asarray(p), jnp.asarray(q), block_t=64)
    want = ref.jsd_tokens(jnp.asarray(p), jnp.asarray(q))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_jsd_properties():
    rng = np.random.default_rng(3)
    p = rng.standard_normal((128, 512)).astype(np.float32)
    q = rng.standard_normal((128, 512)).astype(np.float32)
    j_pq = np.asarray(jsd_tokens(jnp.asarray(p), jnp.asarray(q)))
    j_qp = np.asarray(jsd_tokens(jnp.asarray(q), jnp.asarray(p)))
    # symmetric, bounded by ln 2, zero on identical inputs
    np.testing.assert_allclose(j_pq, j_qp, rtol=1e-5, atol=1e-6)
    assert (j_pq >= -1e-6).all() and (j_pq <= np.log(2.0) + 1e-5).all()
    j_pp = np.asarray(jsd_tokens(jnp.asarray(p), jnp.asarray(p)))
    np.testing.assert_allclose(j_pp, 0.0, atol=1e-6)


def test_jsd_shift_invariance():
    """JSD depends on softmax(logits): constant per-row shifts are no-ops."""
    rng = np.random.default_rng(4)
    p = rng.standard_normal((64, 128)).astype(np.float32)
    q = rng.standard_normal((64, 128)).astype(np.float32)
    shift = rng.standard_normal((64, 1)).astype(np.float32) * 5
    a = np.asarray(jsd_tokens(jnp.asarray(p), jnp.asarray(q), block_t=64))
    b = np.asarray(jsd_tokens(jnp.asarray(p + shift), jnp.asarray(q), block_t=64))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_cross_entropy_ref_matches_manual():
    rng = np.random.default_rng(5)
    logits = rng.standard_normal((4, 7, 16)).astype(np.float32)
    targets = rng.integers(0, 16, size=(4, 7))
    ce = np.asarray(ref.cross_entropy_tokens(jnp.asarray(logits),
                                             jnp.asarray(targets)))
    lse = np.log(np.exp(logits).sum(-1))
    manual = lse - np.take_along_axis(logits, targets[..., None], -1)[..., 0]
    np.testing.assert_allclose(ce, manual, rtol=1e-4, atol=1e-5)

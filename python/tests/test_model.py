"""L2 model invariants: shapes, quant-vs-fp consistency, scoring heads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config as C
from compile import model as M


@pytest.fixture(scope="module")
def params():
    return M.init_params(np.random.default_rng(0), C.MODEL)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.integers(0, C.VOCAB_SIZE, size=(2, C.MODEL.seq_len)),
                       jnp.int32)


def _exact_qparams(params):
    """Quant params whose dequantization reproduces a *representable* W.

    codes are random 4-bit ints; W := dequant(codes) replaces the fp weight,
    so forward_quant(fp', q) must equal forward_fp(fp' with W) exactly.
    """
    rng = np.random.default_rng(2)
    qparams, fp2 = {}, dict(params)
    for name in C.layer_names(C.MODEL):
        kind = name.split(".")[1]
        n, k = C.linear_shape(C.MODEL, kind)
        g = C.n_groups(k)
        codes = rng.integers(0, 16, size=(n, k)).astype(np.int8)
        scale = rng.uniform(0.01, 0.05, size=(n, g)).astype(np.float32)
        zero = rng.uniform(0, 15, size=(n, g)).astype(np.float32)
        w = (codes.reshape(n, g, -1) - zero[:, :, None]) * scale[:, :, None]
        fp2[name] = jnp.asarray(w.reshape(n, k), jnp.float32)
        qparams[name] = {"codes": jnp.asarray(codes),
                         "scale": jnp.asarray(scale),
                         "zero": jnp.asarray(zero)}
    return fp2, qparams


def test_fp_forward_shape_finite(params, tokens):
    logits = M.forward_fp(params, tokens)
    assert logits.shape == (2, C.MODEL.seq_len, C.VOCAB_SIZE)
    assert np.isfinite(np.asarray(logits)).all()


def test_quant_forward_matches_fp_on_representable_weights(params, tokens):
    fp2, qparams = _exact_qparams(params)
    want = M.forward_fp(fp2, tokens)
    got = M.forward_quant(fp2, qparams, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_capture_slots(params, tokens):
    logits, acts = M.forward_fp_with_acts(params, tokens)
    bt = 2 * C.MODEL.seq_len
    for b in range(C.MODEL.n_layers):
        assert acts[f"blk{b}.attn_in"].shape == (bt, C.MODEL.d_model)
        assert acts[f"blk{b}.o_in"].shape == (bt, C.MODEL.d_model)
        assert acts[f"blk{b}.mlp_in"].shape == (bt, C.MODEL.d_model)
        assert acts[f"blk{b}.down_in"].shape == (bt, C.MODEL.d_ff)


def test_scores_quant_zero_jsd_on_identity(params, tokens):
    """Scorer JSD must be ~0 when quant logits coincide with fp logits."""
    fp2, qparams = _exact_qparams(params)
    fp_logits = M.forward_fp(fp2, tokens)
    mask = jnp.ones(tokens.shape, jnp.float32)
    jsd, ce = M.scores_quant(fp2, qparams, tokens, mask, fp_logits)
    assert float(jsd) < 1e-4
    assert 0.0 < float(ce) < 20.0


def test_scores_quant_positive_jsd_on_perturbation(params, tokens):
    fp2, qparams = _exact_qparams(params)
    fp_logits = M.forward_fp(fp2, tokens)
    # corrupt one layer's codes
    bad = dict(qparams)
    name = C.layer_names(C.MODEL)[0]
    bad[name] = dict(bad[name])
    bad[name]["codes"] = jnp.zeros_like(bad[name]["codes"])
    mask = jnp.ones(tokens.shape, jnp.float32)
    jsd, _ = M.scores_quant(fp2, bad, tokens, mask, fp_logits)
    assert float(jsd) > 1e-4


def _random_qparams(rng, lanes=None):
    """Random (not necessarily representable) qparams; optional lane axis."""
    out = {}
    for name in C.layer_names(C.MODEL):
        kind = name.split(".")[1]
        n, k = C.linear_shape(C.MODEL, kind)
        g = C.n_groups(k)
        lead = () if lanes is None else (lanes,)
        out[name] = {
            "codes": jnp.asarray(
                rng.integers(0, 16, size=lead + (n, k)).astype(np.int8)),
            "scale": jnp.asarray(
                rng.uniform(0.01, 0.05, size=lead + (n, g)).astype(np.float32)),
            "zero": jnp.asarray(
                rng.uniform(0, 15, size=lead + (n, g)).astype(np.float32)),
        }
    return out


def test_scores_quant_lanes_bitwise_identical(params, tokens):
    """Per-lane results of the stacked scorer must be *bitwise* equal to the
    single-candidate scorer on the same candidate — the identity contract
    that lets the rust runtime switch dispatch strategies without changing
    search archives."""
    lanes = 3
    fp2, _ = _exact_qparams(params)
    fp_side = {k: fp2[k] for k in M.fp_side_names(C.MODEL)}
    fp_logits = M.forward_fp(fp2, tokens)
    mask = jnp.ones(tokens.shape, jnp.float32)
    qlanes = _random_qparams(np.random.default_rng(5), lanes=lanes)
    jsd_l, ce_l = jax.jit(M.scores_quant_lanes)(
        fp_side, qlanes, tokens, mask, fp_logits)
    assert jsd_l.shape == (lanes,) and ce_l.shape == (lanes,)
    single = jax.jit(M.scores_quant)
    for lane in range(lanes):
        qp = {name: {p: parts[p][lane] for p in parts}
              for name, parts in qlanes.items()}
        jsd_s, ce_s = single(fp_side, qp, tokens, mask, fp_logits)
        assert np.asarray(jsd_l[lane]).tobytes() == \
            np.asarray(jsd_s).tobytes(), lane
        assert np.asarray(ce_l[lane]).tobytes() == \
            np.asarray(ce_s).tobytes(), lane


def test_scores_quant_lanes_are_independent(params, tokens):
    """Corrupting one lane's candidate must not perturb the other lanes."""
    lanes = 2
    fp2, _ = _exact_qparams(params)
    fp_side = {k: fp2[k] for k in M.fp_side_names(C.MODEL)}
    fp_logits = M.forward_fp(fp2, tokens)
    mask = jnp.ones(tokens.shape, jnp.float32)
    qlanes = _random_qparams(np.random.default_rng(6), lanes=lanes)
    jsd_a, _ = jax.jit(M.scores_quant_lanes)(
        fp_side, qlanes, tokens, mask, fp_logits)
    # zero lane 1's codes of the first layer; lane 0 must be untouched
    name = C.layer_names(C.MODEL)[0]
    corrupted = dict(qlanes)
    corrupted[name] = dict(corrupted[name])
    corrupted[name]["codes"] = corrupted[name]["codes"].at[1].set(0)
    jsd_b, _ = jax.jit(M.scores_quant_lanes)(
        fp_side, corrupted, tokens, mask, fp_logits)
    assert np.asarray(jsd_a[0]).tobytes() == np.asarray(jsd_b[0]).tobytes()
    assert float(jsd_a[1]) != float(jsd_b[1])


def test_mask_excludes_positions(params, tokens):
    fp2, qparams = _exact_qparams(params)
    fp_logits = M.forward_fp(fp2, tokens)
    mask = jnp.ones(tokens.shape, jnp.float32).at[:, 64:].set(0.0)
    jsd, ce = M.scores_quant(fp2, qparams, tokens, mask, fp_logits)
    assert np.isfinite(float(jsd)) and np.isfinite(float(ce))


def test_rope_rotation_preserves_norm():
    cfg = C.MODEL
    cos, sin = M.rope_tables(cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(
        (1, cfg.seq_len, cfg.n_heads, cfg.head_dim)), jnp.float32)
    r = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(r), axis=-1),
                               rtol=1e-4)


def test_param_shapes_cover_all_linears():
    shapes = M.param_shapes(C.MODEL)
    for name in C.layer_names(C.MODEL):
        assert name in shapes
    assert len(C.layer_names(C.MODEL)) == C.MODEL.n_layers * 7

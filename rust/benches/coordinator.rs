//! Coordinator micro-benchmarks: NSGA-II generations, predictor fit/predict,
//! archive and space operations.  (Hand-rolled harness; see util::bench.)

use amq::coordinator::nsga2::{self, Nsga2Params};
use amq::coordinator::predictor::{self, PredictorKind, QualityPredictor};
use amq::coordinator::space::{gene, SearchSpace};
use amq::coordinator::{Archive, Config, ProxyBank};
use amq::quant::{MethodId, Quantizer};
use amq::runtime::EvalService;
use amq::tensor::Mat;
use amq::util::bench::{bench, header};
use amq::util::Rng;
use std::time::Duration;

fn toy_space(n: usize) -> SearchSpace {
    SearchSpace {
        choices: vec![vec![2, 3, 4]; n],
        params: vec![128 * 128; n],
        groups: vec![128; n],
        group_size: 128,
    }
}

fn main() {
    let budget = Duration::from_millis(600);
    header("coordinator");
    let space = toy_space(28);

    // dataset for predictors
    let mut rng = Rng::new(0);
    let xs: Vec<Vec<f32>> = (0..200)
        .map(|_| (0..28).map(|_| [0.0f32, 0.5, 1.0][rng.below(3)]).collect())
        .collect();
    let ys: Vec<f32> = xs
        .iter()
        .map(|x| (-(x.iter().sum::<f32>() / 28.0) * 2.0).exp())
        .collect();

    bench("rbf fit (200 samples, 28 dims)", budget, || {
        let mut p = predictor::make(PredictorKind::Rbf, 0);
        p.fit(&xs, &ys);
    })
    .print();

    let mut rbf = predictor::make(PredictorKind::Rbf, 0);
    rbf.fit(&xs, &ys);
    let probe = xs[7].clone();
    bench("rbf predict", budget, || {
        std::hint::black_box(rbf.predict(&probe));
    })
    .print();

    bench("mlp fit (200 samples, 300 epochs)", Duration::from_secs(2), || {
        let mut p = predictor::make(PredictorKind::Mlp, 0);
        p.fit(&xs, &ys);
    })
    .print();

    let nsga_params = Nsga2Params {
        pop_size: 100,
        generations: 15,
        crossover_prob: 0.9,
        mutation_prob: 0.1,
    };
    let mut seed = 0u64;
    bench("nsga-ii pop100 x 15 gens (predictor-free)", Duration::from_secs(2), || {
        seed += 1;
        let mut r = Rng::new(seed);
        let pop = nsga2::run(&space, vec![], &nsga_params, &mut r, |cfg| {
            [cfg.iter().map(|&b| (4 - b) as f64).sum(), space.avg_bits(cfg)]
        });
        std::hint::black_box(pop.len());
    })
    .print();

    bench("nsga-ii pop100 x 15 gens + rbf objective", Duration::from_secs(3), || {
        seed += 1;
        let mut r = Rng::new(seed);
        let active: Vec<usize> = (0..28).collect();
        let pop = nsga2::run(&space, vec![], &nsga_params, &mut r, |cfg| {
            [rbf.predict(&space.features(cfg, &active)) as f64, space.avg_bits(cfg)]
        });
        std::hint::black_box(pop.len());
    })
    .print();

    bench("archive insert+pareto (500 samples)", budget, || {
        let mut a = Archive::new();
        let mut r = Rng::new(1);
        for _ in 0..500 {
            let cfg = space.random(&mut r);
            let bits = space.avg_bits(&cfg);
            a.insert(cfg, r.f32(), bits);
        }
        std::hint::black_box(a.pareto_front().len());
    })
    .print();

    bench("space avg_bits", budget, || {
        let cfg = vec![3u16; 28];
        std::hint::black_box(space.avg_bits(&cfg));
    })
    .print();

    // -- proxy bank: build + assemble cost, 1 vs 4 methods ----------------
    // 28 layers of 64x256 synthetic weights quantized at {2,3,4} bits per
    // enabled method: the per-method build/upload cost of the method-aware
    // genome, and the (cheap, pointer-chasing) per-candidate assembly.
    header("proxy bank (28 layers x {2,3,4} bits, synthetic 64x256 weights)");
    let mats: Vec<Mat> = (0..28)
        .map(|i| {
            let mut rng = Rng::new(100 + i as u64);
            let mut w = Mat::zeros(64, 256);
            for v in &mut w.data {
                *v = rng.normal() * 0.1;
            }
            w
        })
        .collect();
    let build_bank = |methods: &[MethodId]| -> ProxyBank {
        let pieces = methods
            .iter()
            .map(|m| {
                let q = m.build();
                mats.iter()
                    .map(|w| [2u8, 3, 4].iter().map(|&b| q.quantize(w, b, 128, None)).collect())
                    .collect()
            })
            .collect();
        ProxyBank::from_parts(methods.to_vec(), vec![2, 3, 4], pieces).unwrap()
    };
    let one_method = [MethodId::Hqq];
    let four_methods = [MethodId::Hqq, MethodId::Rtn, MethodId::Gptq, MethodId::AwqClip];
    for methods in [&one_method[..], &four_methods[..]] {
        let res = bench(
            &format!("bank build ({} method(s))", methods.len()),
            Duration::from_secs(2),
            || {
                std::hint::black_box(build_bank(methods).memory_bytes());
            },
        );
        res.print();
    }
    let bank1 = build_bank(&one_method);
    let bank4 = build_bank(&four_methods);
    println!(
        "bank memory: 1 method {:.1} MB, 4 methods {:.1} MB",
        bank1.memory_bytes() as f64 / 1e6,
        bank4.memory_bytes() as f64 / 1e6
    );
    let mut rng_asm = Rng::new(3);
    let methods4 = four_methods;
    bench("bank assemble (1 method, 28 layers)", budget, || {
        let cfg: Config = (0..28).map(|_| [2u16, 3, 4][rng_asm.below(3)]).collect();
        std::hint::black_box(bank1.assemble(&cfg).len());
    })
    .print();
    let mut rng_asm4 = Rng::new(4);
    bench("bank assemble (4 methods, 28 layers)", budget, || {
        let cfg: Config = (0..28)
            .map(|_| gene(methods4[rng_asm4.below(4)], [2u8, 3, 4][rng_asm4.below(3)]))
            .collect();
        std::hint::black_box(bank4.assemble(&cfg).len());
    })
    .print();

    // -- evaluation pool: 1 vs N workers on a queue-bound workload --------
    // Each request sleeps 2ms, standing in for a PJRT scorer round trip
    // (the search hot path is device-wait bound, not CPU bound).  The
    // per-candidate result is derived from a payload-seeded RNG, matching
    // the pool's determinism contract.
    header("evaluation pool (32-candidate batch, 2ms simulated device wait)");
    let pool_bench = |workers: usize| {
        let svc: EvalService<u64, f32> = EvalService::spawn_sharded(workers, |_shard| {
            |candidate: u64| {
                std::thread::sleep(Duration::from_millis(2));
                let mut r = Rng::new(candidate ^ 0x9E3779B97F4A7C15);
                r.f32()
            }
        });
        let res = bench(
            &format!("pool with {workers} worker(s)"),
            Duration::from_secs(2),
            || {
                std::hint::black_box(svc.call_batch((0..32).collect()));
            },
        );
        res.print();
        res
    };
    let one = pool_bench(1);
    let four = pool_bench(4);
    let speedup = one.median.as_secs_f64() / four.median.as_secs_f64().max(1e-12);
    println!("pool speedup (4 vs 1 workers): {speedup:.2}x  (target: >= 2x on queue-bound work)");
}

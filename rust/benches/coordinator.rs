//! Coordinator micro-benchmarks: NSGA-II generations, predictor fit/predict,
//! archive and space operations.  (Hand-rolled harness; see util::bench.)

use amq::coordinator::nsga2::{self, Nsga2Params};
use amq::coordinator::predictor::{self, PredictorKind, QualityPredictor};
use amq::coordinator::space::{gene, SearchSpace};
use amq::coordinator::{
    run_search, run_search_seeded, slab_budget_bytes, warmstart, Archive, BankShareStats, Config,
    ConfigEvaluator, EvalPool, PooledEvaluator, ProxyBank, SearchParams, WarmKey, WarmLoad,
};
use amq::quant::{MethodId, Quantizer};
use amq::runtime::{
    lane_routed, lane_slab_sig, EvalService, FaultKind, FaultPlan, FaultSpec, HedgePolicy,
    ShardFlow, SlabCache,
};
use amq::tensor::Mat;
use amq::util::bench::{bench, header};
use amq::util::Rng;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn toy_space(n: usize) -> SearchSpace {
    SearchSpace {
        choices: vec![vec![2, 3, 4]; n],
        params: vec![128 * 128; n],
        groups: vec![128; n],
        group_size: 128,
    }
}

fn main() {
    let budget = Duration::from_millis(600);
    header("coordinator");
    let space = toy_space(28);

    // dataset for predictors
    let mut rng = Rng::new(0);
    let xs: Vec<Vec<f32>> = (0..200)
        .map(|_| (0..28).map(|_| [0.0f32, 0.5, 1.0][rng.below(3)]).collect())
        .collect();
    let ys: Vec<f32> = xs
        .iter()
        .map(|x| (-(x.iter().sum::<f32>() / 28.0) * 2.0).exp())
        .collect();

    bench("rbf fit (200 samples, 28 dims)", budget, || {
        let mut p = predictor::make(PredictorKind::Rbf, 0);
        p.fit(&xs, &ys);
    })
    .print();

    let mut rbf = predictor::make(PredictorKind::Rbf, 0);
    rbf.fit(&xs, &ys);
    let probe = xs[7].clone();
    bench("rbf predict", budget, || {
        std::hint::black_box(rbf.predict(&probe));
    })
    .print();

    bench("mlp fit (200 samples, 300 epochs)", Duration::from_secs(2), || {
        let mut p = predictor::make(PredictorKind::Mlp, 0);
        p.fit(&xs, &ys);
    })
    .print();

    bench("gp fit (200 samples, 28 dims, cholesky)", Duration::from_secs(2), || {
        let mut p = predictor::make(PredictorKind::Gp, 0);
        p.fit(&xs, &ys);
    })
    .print();

    let mut gp = predictor::make(PredictorKind::Gp, 0);
    gp.fit(&xs, &ys);
    bench("gp predict (posterior mean + std)", budget, || {
        std::hint::black_box(gp.predict_with_std(&probe));
    })
    .print();

    let nsga_params = Nsga2Params {
        pop_size: 100,
        generations: 15,
        crossover_prob: 0.9,
        mutation_prob: 0.1,
    };
    let mut seed = 0u64;
    bench("nsga-ii pop100 x 15 gens (predictor-free)", Duration::from_secs(2), || {
        seed += 1;
        let mut r = Rng::new(seed);
        let pop = nsga2::run(&space, vec![], &nsga_params, &mut r, |cfg| {
            [cfg.iter().map(|&b| (4 - b) as f64).sum(), space.avg_bits(cfg)]
        });
        std::hint::black_box(pop.len());
    })
    .print();

    bench("nsga-ii pop100 x 15 gens + rbf objective", Duration::from_secs(3), || {
        seed += 1;
        let mut r = Rng::new(seed);
        let active: Vec<usize> = (0..28).collect();
        let pop = nsga2::run(&space, vec![], &nsga_params, &mut r, |cfg| {
            [rbf.predict(&space.features(cfg, &active)) as f64, space.avg_bits(cfg)]
        });
        std::hint::black_box(pop.len());
    })
    .print();

    bench("archive insert+pareto (500 samples)", budget, || {
        let mut a = Archive::new();
        let mut r = Rng::new(1);
        for _ in 0..500 {
            let cfg = space.random(&mut r);
            let bits = space.avg_bits(&cfg);
            a.insert(cfg, r.f32(), bits);
        }
        std::hint::black_box(a.pareto_front().len());
    })
    .print();

    bench("space avg_bits", budget, || {
        let cfg = vec![3u16; 28];
        std::hint::black_box(space.avg_bits(&cfg));
    })
    .print();

    // -- proxy bank: build + assemble cost, 1 vs 4 methods ----------------
    // 28 layers of 64x256 synthetic weights quantized at {2,3,4} bits per
    // enabled method: the per-method build/upload cost of the method-aware
    // genome, and the (cheap, pointer-chasing) per-candidate assembly.
    header("proxy bank (28 layers x {2,3,4} bits, synthetic 64x256 weights)");
    let mats: Vec<Mat> = (0..28)
        .map(|i| {
            let mut rng = Rng::new(100 + i as u64);
            let mut w = Mat::zeros(64, 256);
            for v in &mut w.data {
                *v = rng.normal() * 0.1;
            }
            w
        })
        .collect();
    let build_bank = |methods: &[MethodId]| -> ProxyBank {
        let pieces = methods
            .iter()
            .map(|m| {
                let q = m.build();
                mats.iter()
                    .map(|w| [2u8, 3, 4].iter().map(|&b| q.quantize(w, b, 128, None)).collect())
                    .collect()
            })
            .collect();
        ProxyBank::from_parts(methods.to_vec(), vec![2, 3, 4], pieces).unwrap()
    };
    let one_method = [MethodId::Hqq];
    let four_methods = [MethodId::Hqq, MethodId::Rtn, MethodId::Gptq, MethodId::AwqClip];
    for methods in [&one_method[..], &four_methods[..]] {
        let res = bench(
            &format!("bank build ({} method(s))", methods.len()),
            Duration::from_secs(2),
            || {
                std::hint::black_box(build_bank(methods).memory_bytes());
            },
        );
        res.print();
    }
    let bank1 = build_bank(&one_method);
    let bank4 = build_bank(&four_methods);
    println!(
        "bank memory: 1 method {:.1} MB, 4 methods {:.1} MB",
        bank1.memory_bytes() as f64 / 1e6,
        bank4.memory_bytes() as f64 / 1e6
    );
    let mut rng_asm = Rng::new(3);
    let methods4 = four_methods;
    bench("bank assemble (1 method, 28 layers)", budget, || {
        let cfg: Config = (0..28).map(|_| [2u16, 3, 4][rng_asm.below(3)]).collect();
        std::hint::black_box(bank1.assemble(&cfg).unwrap().len());
    })
    .print();
    let mut rng_asm4 = Rng::new(4);
    bench("bank assemble (4 methods, 28 layers)", budget, || {
        let cfg: Config = (0..28)
            .map(|_| gene(methods4[rng_asm4.below(4)], [2u8, 3, 4][rng_asm4.below(3)]))
            .collect();
        std::hint::black_box(bank4.assemble(&cfg).unwrap().len());
    })
    .print();

    // -- evaluation pool: 1 vs N workers on a queue-bound workload --------
    // Each request sleeps 2ms, standing in for a PJRT scorer round trip
    // (the search hot path is device-wait bound, not CPU bound).  The
    // per-candidate result is derived from a payload-seeded RNG, matching
    // the pool's determinism contract.
    header("evaluation pool (32-candidate batch, 2ms simulated device wait)");
    let pool_bench = |workers: usize| {
        let svc: EvalService<u64, f32> = EvalService::spawn_sharded(workers, |_shard| {
            |candidate: u64| {
                std::thread::sleep(Duration::from_millis(2));
                let mut r = Rng::new(candidate ^ 0x9E3779B97F4A7C15);
                r.f32()
            }
        });
        let res = bench(
            &format!("pool with {workers} worker(s)"),
            Duration::from_secs(2),
            || {
                std::hint::black_box(svc.call_batch((0..32).collect()).unwrap());
            },
        );
        res.print();
        res
    };
    let one = pool_bench(1);
    let four = pool_bench(4);
    let speedup = one.median.as_secs_f64() / four.median.as_secs_f64().max(1e-12);
    println!("pool speedup (4 vs 1 workers): {speedup:.2}x  (target: >= 2x on queue-bound work)");

    // -- batched candidate scoring: the search hot path end to end --------
    // A full smoke search through the pooled evaluator at every
    // (workers, score-batch, lanes, slab-cache) corner: archives must hash
    // identically, and the dispatch counters quantify the dedup +
    // microbatching + lane-stacking + slab-reuse wins.  The simulated
    // device cost model mirrors the lane-stacked scorer: every device
    // dispatch pays a fixed submission overhead, plus a marginal cost per
    // executed lane (padding included — padded lanes burn FLOPs too),
    // plus a slab pack+upload cost per cache *miss* (hits are free — the
    // slab-reuse term).  Lane-path scores are reconstructed from the
    // cached slab contents, so the archive-identity assertion also proves
    // the cache transparent.  The numbers land in BENCH_search.json (same
    // schema as `repro search`) so CI can track the perf trajectory as an
    // artifact.
    header("batched candidate scoring (smoke search, synthetic lane-aware scorer)");
    const DISPATCH_US: u64 = 200; // per device call
    const LANE_US: u64 = 30; // per executed lane
    const SLAB_US: u64 = 60; // per host slab pack+upload (cache miss, host route)
    const GATHER_US: u64 = 15; // per device-side gather dispatch (cache miss, gather route)
    const SLAB_BYTES: usize = 1 << 14; // nominal bytes per packed slab
    const N_LAYERS: usize = 16;
    let search_space = toy_space(N_LAYERS);
    let synth_score = |cfg: &Config| -> f32 {
        // payload-seeded: the pool determinism contract
        let mut seed = 0x6A09_E667_F3BC_C908u64;
        for &g in cfg {
            seed = seed.wrapping_mul(0x1000_0000_01B3).wrapping_add(g as u64);
        }
        let mut r = Rng::new(seed);
        let base: f32 = cfg
            .iter()
            .enumerate()
            .map(|(i, &g)| {
                let w = if i % 4 == 0 { 1.0 } else { 0.05 };
                w * ((4 - g) as f32).powi(2)
            })
            .sum();
        base + r.f32() * 1e-4
    };
    let archive_hash = |a: &Archive| -> u64 {
        let mut h = 0xCBF2_9CE4_8422_2325u64;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x1000_0000_01B3);
        };
        for s in &a.samples {
            for &g in &s.config {
                mix(g as u64);
            }
            mix(s.jsd.to_bits() as u64);
            mix(s.avg_bits.to_bits());
        }
        h
    };
    let mut params = SearchParams::smoke();
    params.seed = 7;
    let mut rows = String::new();
    let mut hashes: Vec<u64> = Vec::new();
    // `gather` swaps the per-miss cost from a host pack+upload (SLAB_US)
    // to a device-side gather dispatch over resident bank pieces
    // (GATHER_US) — the miss count is identical, only who pays changes,
    // so the archive-identity assertion below also covers the gather
    // route's transparency.
    for (workers, score_batch, lanes, slab_mb, gather) in [
        (1usize, 1usize, 1usize, 0usize, false),
        (1, 8, 1, 0, false),
        (4, 1, 1, 0, false),
        (4, 8, 1, 0, false),
        (1, 8, 8, 0, false),
        (1, 8, 8, 64, false),
        (4, 8, 8, 0, false),
        (4, 8, 8, 64, false),
        (1, 8, 8, 64, true),
        (4, 8, 8, 0, true),
        (4, 8, 8, 64, true),
    ] {
        let device_dispatches = Arc::new(AtomicU64::new(0));
        let lane_candidates = Arc::new(AtomicU64::new(0));
        let lanes_padded = Arc::new(AtomicU64::new(0));
        let slab_lookups = Arc::new(AtomicU64::new(0));
        let slab_uploads = Arc::new(AtomicU64::new(0));
        let slab_gathers = Arc::new(AtomicU64::new(0));
        // one slab cache per corner, shared by every shard (as in prod)
        let slab_cache: Arc<SlabCache<Vec<u16>>> =
            Arc::new(SlabCache::new(slab_budget_bytes(slab_mb)));
        let (dd, lc, lp, sl, su, sg, sc) = (
            device_dispatches.clone(),
            lane_candidates.clone(),
            lanes_padded.clone(),
            slab_lookups.clone(),
            slab_uploads.clone(),
            slab_gathers.clone(),
            slab_cache.clone(),
        );
        let svc: Arc<EvalPool> = Arc::new(EvalService::spawn_sharded(workers, move |_shard| {
            let (dd, lc, lp, sl, su, sg, sc) = (
                dd.clone(),
                lc.clone(),
                lp.clone(),
                sl.clone(),
                su.clone(),
                sg.clone(),
                sc.clone(),
            );
            move |chunk: Vec<Config>| -> amq::Result<Vec<f32>> {
                // production routing (the shared `lane_routed` predicate):
                // single-candidate chunks take the per-candidate path even
                // when the lane executable exists
                if lane_routed(chunk.len(), lanes) {
                    // plan: resolve each group's per-layer slab through the
                    // shared cache; misses pay the host pack+upload cost, or
                    // the (cheaper) device gather dispatch on the gather route
                    let mut uploads_now = 0u64;
                    let mut gathers_now = 0u64;
                    let mut plan: Vec<(usize, Vec<Arc<Vec<u16>>>)> = Vec::new();
                    for group in chunk.chunks(lanes) {
                        let mut slabs = Vec::with_capacity(N_LAYERS);
                        for li in 0..N_LAYERS {
                            let sig = lane_slab_sig(group, li, lanes);
                            let mut missed = false;
                            let slab = sc.get_or_build((li, sig.clone()), || {
                                missed = true;
                                Ok((sig.clone(), SLAB_BYTES))
                            })?;
                            if missed {
                                if gather {
                                    gathers_now += 1;
                                } else {
                                    uploads_now += 1;
                                }
                            }
                            slabs.push(slab);
                        }
                        plan.push((group.len(), slabs));
                    }
                    let d = plan.len() as u64;
                    let executed = d * lanes as u64;
                    let padded = executed - chunk.len() as u64;
                    sl.fetch_add(d * N_LAYERS as u64, Ordering::Relaxed);
                    su.fetch_add(uploads_now, Ordering::Relaxed);
                    sg.fetch_add(gathers_now, Ordering::Relaxed);
                    dd.fetch_add(d, Ordering::Relaxed);
                    lc.fetch_add(chunk.len() as u64, Ordering::Relaxed);
                    lp.fetch_add(padded, Ordering::Relaxed);
                    std::thread::sleep(Duration::from_micros(
                        d * DISPATCH_US
                            + executed * LANE_US
                            + uploads_now * SLAB_US
                            + gathers_now * GATHER_US,
                    ));
                    // the device reads the slabs, not the candidates:
                    // cache transparency is load-bearing for the archive
                    let mut out = Vec::with_capacity(chunk.len());
                    for (real, slabs) in &plan {
                        for j in 0..*real {
                            let cfg: Config =
                                (0..N_LAYERS).map(|li| slabs[li][j]).collect();
                            out.push(synth_score(&cfg));
                        }
                    }
                    Ok(out)
                } else {
                    let d = chunk.len() as u64;
                    dd.fetch_add(d, Ordering::Relaxed);
                    std::thread::sleep(Duration::from_micros(
                        d * DISPATCH_US + d * LANE_US,
                    ));
                    Ok(chunk.iter().map(synth_score).collect())
                }
            }
        }));
        let mut ev = PooledEvaluator::from_service(svc).with_score_batch(score_batch);
        let t0 = Instant::now();
        let res = run_search(&search_space, &mut ev, &params).unwrap();
        let wall = t0.elapsed();
        let stats = ev.batch_stats().unwrap();
        let pool = ev.pool_stats();
        hashes.push(archive_hash(&res.archive));
        let cps = res.true_evals as f64 / wall.as_secs_f64().max(1e-9);
        let devd = device_dispatches.load(Ordering::Relaxed);
        let cand = lane_candidates.load(Ordering::Relaxed);
        let padded = lanes_padded.load(Ordering::Relaxed);
        let fill = if cand + padded == 0 { 0.0 } else { cand as f64 / (cand + padded) as f64 };
        let lookups = slab_lookups.load(Ordering::Relaxed);
        let uploads = slab_uploads.load(Ordering::Relaxed);
        let gathers = slab_gathers.load(Ordering::Relaxed);
        let bytes_avoided = gathers * SLAB_BYTES as u64;
        let misses = uploads + gathers;
        let slab_hit = if lookups == 0 {
            0.0
        } else {
            (lookups - misses) as f64 / lookups as f64
        };
        println!(
            "workers {workers} k {score_batch} lanes {lanes} slab {slab_mb}MB gather {}: \
             {:>8} wall, {:.0} cand/s, {} chunk dispatches / {} device dispatches for {} \
             requested ({} dedup hits, {:.0}% lane fill, {} slab uploads + {} gathers / {} \
             lookups = {:.0}% hit)",
            if gather { "on" } else { "off" },
            format!("{:.0?}", wall),
            cps,
            stats.dispatches,
            devd,
            stats.requested,
            stats.cache_hits + stats.dup_hits,
            fill * 100.0,
            uploads,
            gathers,
            lookups,
            slab_hit * 100.0,
        );
        if !rows.is_empty() {
            rows.push_str(",\n");
        }
        let _ = write!(
            rows,
            "    {{\"workers\": {workers}, \"score_batch\": {score_batch}, \
             \"lanes\": {lanes}, \"slab_cache_mb\": {slab_mb}, \"scorer_variant\": \"{}\", \
             \"topology\": \"in-process\", \"remote_shards\": 0, \"requeued_chunks\": {}, \
             \"hedged_dispatched\": {}, \"hedged_won\": {}, \"hedged_wasted\": {}, \
             \"latency_p50_ms\": {:.3}, \
             \"wall_seconds\": {:.4}, \"true_evals\": {}, \"candidates_per_sec\": {:.2}, \
             \"scorer_dispatches\": {}, \"device_dispatches\": {}, \
             \"lane_fill_fraction\": {:.4}, \"slab_lookups\": {lookups}, \
             \"slab_uploads\": {uploads}, \"slab_gather\": {gather}, \
             \"gather_dispatches\": {gathers}, \
             \"slab_upload_bytes_avoided\": {bytes_avoided}, \
             \"slab_hit_fraction\": {slab_hit:.4}, \
             \"slab_resident_bytes\": {}, \"requested_configs\": {}, \"dedup_hits\": {}, \
             \"dedup_fraction\": {:.4}, \"dispatch_reduction\": {:.3}}}",
            if lanes > 1 { "lane-stacked" } else { "per-candidate" },
            pool.requeued,
            pool.hedged_dispatched,
            pool.hedged_won,
            pool.hedged_wasted,
            pool.latency_p50.as_secs_f64() * 1e3,
            wall.as_secs_f64(),
            res.true_evals,
            cps,
            stats.dispatches,
            devd,
            fill,
            slab_cache.stats().resident_bytes,
            stats.requested,
            stats.cache_hits + stats.dup_hits,
            stats.dedup_fraction(),
            stats.dispatch_reduction(),
        );
    }
    let identical = hashes.iter().all(|&h| h == hashes[0]);
    assert!(
        identical,
        "archives diverged across (workers, score-batch, lanes, slab-cache, gather) combos"
    );
    println!(
        "archives identical across all (workers, score-batch, lanes, slab-cache, gather) \
         combos: {identical}"
    );

    // -- hedged straggler re-dispatch: a deterministically wedged shard ----
    // Shard 0 wedges on its first chunk (seeded fault plan, rate 1.0, capped
    // at one injection) and holds it until the gate opens; the hedging
    // policy re-dispatches the stalled chunk to an idle shard, so the search
    // completes at healthy speed without waiting out any timeout, and the
    // archive still hashes identically to the fault-free corners above
    // (evals are pure, the first reply wins, the wedged copy is discarded
    // by chunk id on delivery).
    header("hedged straggler re-dispatch (wedged shard, fault-injected)");
    {
        let spec = FaultSpec { seed: 7, kind: FaultKind::Wedge, rate: 1.0 };
        let plan = Arc::new(FaultPlan::new(spec).with_max_faults(1));
        let labels: Vec<String> = (0..4).map(|i| format!("local#{i}")).collect();
        let plan_for_builder = plan.clone();
        let builder = move |shard: usize| {
            let inner: Box<dyn FnMut(Vec<Config>) -> ShardFlow<amq::Result<Vec<f32>>>> =
                Box::new(move |chunk: Vec<Config>| {
                    ShardFlow::Reply(Ok(chunk.iter().map(synth_score).collect()))
                });
            if shard == 0 {
                plan_for_builder.wrap_flow(inner)
            } else {
                inner
            }
        };
        let policy = HedgePolicy::from_factor(4.0);
        let svc: Arc<EvalPool> = Arc::new(EvalService::spawn_flow_with(labels, builder, policy));
        let mut ev = PooledEvaluator::from_service(svc).with_score_batch(8);
        let t0 = Instant::now();
        let res = run_search(&search_space, &mut ev, &params).unwrap();
        let wall = t0.elapsed();
        let pool = ev.pool_stats();
        assert_eq!(
            archive_hash(&res.archive),
            hashes[0],
            "hedged archive diverged from the fault-free baseline"
        );
        assert!(
            pool.hedged_won >= 1,
            "the wedged chunk should have been won by a hedged duplicate"
        );
        println!(
            "wedged shard + hedging (factor 4): {:>8} wall, hedged {} (won {}, wasted {}), \
             p50 {:.2}ms, requeued {}, archive identical to baseline",
            format!("{:.0?}", wall),
            pool.hedged_dispatched,
            pool.hedged_won,
            pool.hedged_wasted,
            pool.latency_p50.as_secs_f64() * 1e3,
            pool.requeued,
        );
        rows.push_str(",\n");
        let _ = write!(
            rows,
            "    {{\"workers\": 4, \"score_batch\": 8, \"lanes\": 1, \"slab_cache_mb\": 0, \
             \"scorer_variant\": \"per-candidate\", \"topology\": \"in-process\", \
             \"remote_shards\": 0, \"fault_spec\": \"{}\", \"hedge_factor\": 4, \
             \"requeued_chunks\": {}, \"hedged_dispatched\": {}, \"hedged_won\": {}, \
             \"hedged_wasted\": {}, \"latency_p50_ms\": {:.3}, \"wall_seconds\": {:.4}, \
             \"true_evals\": {}}}",
            spec.to_spec_string(),
            pool.requeued,
            pool.hedged_dispatched,
            pool.hedged_won,
            pool.hedged_wasted,
            pool.latency_p50.as_secs_f64() * 1e3,
            wall.as_secs_f64(),
            res.true_evals,
        );
        // The wedged worker is still parked inside its flow holding the
        // (already-hedged) chunk; open the gate so the service can drain
        // and join cleanly.
        plan.release_wedges();
    }

    // -- GP surrogate + warm-start: cold search vs persisted restart ------
    // A smoke search under the exact-GP predictor with the UCB screen on
    // (κ = 0.5), then both warm tiers against the persisted archive: an
    // exact-key hit adopts the cold archive verbatim (bit-exact, zero
    // evaluations), and a seed-tier restart reuses every persisted sample
    // so only the new trajectory pays for true evaluations.
    header("gp predictor + warm-start (cold vs exact adopt vs seeded restart)");
    {
        let mut gp_params = SearchParams::smoke();
        gp_params.seed = 7;
        gp_params.predictor = PredictorKind::Gp;
        gp_params.ucb_kappa = 0.5;
        let make_pool = || -> Arc<EvalPool> {
            Arc::new(EvalService::spawn_sharded(1, move |_shard| {
                move |chunk: Vec<Config>| -> amq::Result<Vec<f32>> {
                    Ok(chunk.iter().map(synth_score).collect())
                }
            }))
        };
        let mut ev = PooledEvaluator::from_service(make_pool()).with_score_batch(8);
        let t0 = Instant::now();
        let cold = run_search(&search_space, &mut ev, &gp_params).unwrap();
        let cold_wall = t0.elapsed();

        let warm_dir = std::env::temp_dir().join("amq_bench_warm");
        let _ = std::fs::remove_dir_all(&warm_dir);
        let key = WarmKey::from_params("bench-synth", "hqq", &gp_params);
        warmstart::save(&warm_dir, &key, &cold.archive, &search_space).unwrap();

        // Exact tier: the persisted archive must reload bit-exactly.
        let WarmLoad::Exact(entry) = warmstart::load(&warm_dir, &key, &search_space) else {
            panic!("expected an exact warm-start hit for the matching key");
        };
        assert_eq!(
            archive_hash(&entry.archive),
            archive_hash(&cold.archive),
            "warm-start reload must reproduce the cold archive bit-exactly"
        );

        // Seed tier: restart seeded with every persisted sample; none of
        // them is re-evaluated, so the restart strictly saves evaluations.
        let mut ev = PooledEvaluator::from_service(make_pool()).with_score_batch(8);
        let t1 = Instant::now();
        let warm =
            run_search_seeded(&search_space, &mut ev, &gp_params, &entry.archive.samples).unwrap();
        let warm_wall = t1.elapsed();
        assert!(
            warm.true_evals < cold.true_evals,
            "seeded restart must skip evaluations the cold run already paid for"
        );
        let _ = std::fs::remove_dir_all(&warm_dir);
        println!(
            "gp cold: {:>8} wall, {} true evals; exact adopt: 0 evals (bit-exact); \
             seeded restart: {:>8} wall, {} true evals ({} seeds reused)",
            format!("{:.0?}", cold_wall),
            cold.true_evals,
            format!("{:.0?}", warm_wall),
            warm.true_evals,
            entry.archive.len(),
        );
        rows.push_str(",\n");
        let _ = write!(
            rows,
            "    {{\"predictor\": \"gp\", \"ucb_kappa\": 0.5, \"warm_start\": \"cold\", \
             \"wall_seconds\": {:.4}, \"true_evals\": {}, \"archive_len\": {}}}",
            cold_wall.as_secs_f64(),
            cold.true_evals,
            cold.archive.len(),
        );
        rows.push_str(",\n");
        let _ = write!(
            rows,
            "    {{\"predictor\": \"gp\", \"ucb_kappa\": 0.5, \"warm_start\": \"seed\", \
             \"wall_seconds\": {:.4}, \"true_evals\": {}, \"archive_len\": {}, \
             \"seed_samples\": {}, \"exact_adopt_bit_exact\": true}}",
            warm_wall.as_secs_f64(),
            warm.true_evals,
            warm.archive.len(),
            entry.archive.len(),
        );
    }

    // shared-bank residency: 4 shards referencing one Arc'd bank count 1x
    let shard_refs: Vec<Arc<ProxyBank>> = {
        let shared = Arc::new(build_bank(&four_methods));
        (0..4).map(|_| shared.clone()).collect()
    };
    let share = BankShareStats::from_shard_banks(&shard_refs);
    println!(
        "bank residency with 4 shards: {:.1} MB resident vs {:.1} MB unshared",
        share.resident_bytes as f64 / 1e6,
        share.referenced_bytes as f64 / 1e6
    );

    let out = std::env::var("AMQ_BENCH_OUT").unwrap_or_else(|_| "BENCH_search.json".into());
    let json = format!(
        "{{\n  \"bench\": \"coordinator_synthetic_search\",\n  \"identical_archives\": \
         {identical},\n  \"runs\": [\n{rows}\n  ],\n  \"bank\": {{\"resident_bytes\": {}, \
         \"unshared_bytes\": {}, \"shards\": {}}}\n}}\n",
        share.resident_bytes, share.referenced_bytes, share.shards,
    );
    std::fs::write(&out, json).unwrap();
    println!("wrote {out}");
}

//! End-to-end benchmarks over the real artifacts (skipped when absent):
//! PJRT scorer latency (the search hot-path unit), fp/quant executable
//! latency, proxy assembly, candidate evaluation, and upload costs —
//! one line per paper-relevant cost.

use amq::coordinator::{ConfigEvaluator, ProxyBank, ProxyEvaluator, SearchSpace};
use amq::model::ModelAssets;
use amq::quant::{Hqq, MethodRegistry};
use amq::runtime::Runtime;
use amq::util::bench::{bench, header};
use amq::util::Rng;
use std::time::Duration;

fn main() -> amq::Result<()> {
    if !amq::artifacts_available() {
        eprintln!("[skip] artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let dir = amq::artifacts_dir();
    let assets = ModelAssets::load(&dir)?;
    let rt = Runtime::load(&dir, &assets.weights)?;
    let calib = amq::data::load_tokens(&assets.manifest.file("calib")?)?;
    let b = rt.batch_size();
    let t = rt.seq_len();
    let toks = calib.batch(0, b).to_vec();
    let mask = vec![1.0f32; b * t];
    let batch = rt.prepare_batch(&toks, &mask)?;

    header("end-to-end (PJRT CPU, batch 16x128)");
    let bank =
        ProxyBank::build(&assets.manifest, &assets.weights, None, &MethodRegistry::default())?;
    let proxy = amq::coordinator::DeviceProxy::new(&rt, bank)?;
    let space = SearchSpace::full(&assets.manifest);
    let mut rng = Rng::new(0);

    bench("proxy assemble (28 layers)", Duration::from_millis(300), || {
        let cfg = space.random(&mut rng);
        std::hint::black_box(proxy.assemble(&cfg).unwrap().len());
    })
    .print();

    let cfg3 = space.uniform(3);
    let layers = proxy.assemble(&cfg3).unwrap();
    bench("fused scorer call (jsd+ce)", Duration::from_secs(6), || {
        std::hint::black_box(rt.scores(&batch, &layers).unwrap());
    })
    .print();

    bench("fp logits call", Duration::from_secs(4), || {
        std::hint::black_box(rt.fp_logits(&toks).unwrap().len());
    })
    .print();

    bench("quant logits call (pallas dequant-matmul)", Duration::from_secs(6), || {
        std::hint::black_box(rt.quant_logits(&toks, &layers).unwrap().len());
    })
    .print();

    let batches = vec![batch];
    let mut evaluator = ProxyEvaluator::new(&proxy, &batches);
    let mut rng2 = Rng::new(7);
    bench("candidate true-eval (assemble+score, uncached)", Duration::from_secs(6), || {
        let cfg = space.random(&mut rng2);
        std::hint::black_box(evaluator.eval_jsd(&cfg).unwrap());
    })
    .print();

    let q = Hqq::default();
    let w = assets.weights.linear(&assets.manifest.layers[6].name)?;
    bench("hqq quantize largest layer (256x128)", Duration::from_secs(2), || {
        std::hint::black_box(amq::quant::Quantizer::quantize(&q, &w, 3, 128, None));
    })
    .print();

    let ql = amq::quant::Quantizer::quantize(&q, &w, 3, 128, None);
    bench("upload quant layer buffers", Duration::from_secs(1), || {
        std::hint::black_box(rt.upload_quant_layer(&ql).unwrap());
    })
    .print();
    Ok(())
}

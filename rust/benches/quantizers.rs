//! Quantizer benchmarks: per-layer cost of each method at the subject
//! model's layer shapes (these are the "compression time" primitives of
//! Table 4) plus pack/unpack throughput.

use amq::model::CalibStats;
use amq::quant::{pack, AwqClip, BitStackLayer, Gptq, Hqq, PbLlm, Quantizer, Rtn};
use amq::tensor::Mat;
use amq::util::bench::{bench, header};
use amq::util::Rng;
use std::time::Duration;

fn rand_w(n: usize, k: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let mut w = Mat::zeros(n, k);
    for v in &mut w.data {
        *v = rng.normal() * 0.1;
    }
    w
}

fn stats(k: usize, seed: u64) -> CalibStats {
    let x = rand_w(2 * k, k, seed);
    let mut h = Mat::zeros(k, k);
    let mut ma = vec![0.0f32; k];
    for r in 0..x.rows {
        let row = x.row(r);
        for i in 0..k {
            ma[i] += row[i].abs();
            for j in 0..k {
                h[(i, j)] += row[i] * row[j];
            }
        }
    }
    CalibStats { hessian: h, mean_abs: ma }
}

fn main() {
    let budget = Duration::from_millis(800);
    header("quantizers (layer 256x128 = the model's largest shape)");
    let w = rand_w(256, 128, 1);
    let st = stats(128, 2);

    bench("rtn w3 g128", budget, || {
        std::hint::black_box(Rtn.quantize(&w, 3, 128, None));
    })
    .print();
    bench("hqq w3 g128 (20 iters)", budget, || {
        std::hint::black_box(Hqq::default().quantize(&w, 3, 128, None));
    })
    .print();
    bench("gptq w3 g128 (with hessian)", budget, || {
        std::hint::black_box(Gptq::default().quantize(&w, 3, 128, Some(&st)));
    })
    .print();
    bench("awq-clip w3 g128 (grid search)", Duration::from_secs(2), || {
        std::hint::black_box(AwqClip::default().quantize(&w, 3, 128, Some(&st)));
    })
    .print();
    bench("pbllm rho=0.29 g128", budget, || {
        std::hint::black_box(PbLlm::new(0.29, 128).quantize(&w, Some(&st)));
    })
    .print();
    bench("bitstack decompose 10 blocks", Duration::from_secs(2), || {
        std::hint::black_box(BitStackLayer::decompose("l", &w, 10));
    })
    .print();

    header("bit packing (1M codes)");
    let mut rng = Rng::new(3);
    let codes: Vec<u8> = (0..1 << 20).map(|_| rng.below(8) as u8).collect();
    for bits in [2u8, 3, 4] {
        let codes_b: Vec<u8> = codes.iter().map(|&c| c % (1 << bits)).collect();
        let packed = pack::pack(&codes_b, bits);
        bench(&format!("pack {bits}-bit"), budget, || {
            std::hint::black_box(pack::pack(&codes_b, bits));
        })
        .print();
        bench(&format!("unpack {bits}-bit"), budget, || {
            std::hint::black_box(pack::unpack(&packed, bits, codes_b.len()));
        })
        .print();
    }
}

//! Serve-path micro-benchmarks: continuous-batcher throughput at 1 vs N
//! lanes under a simulated device dispatch cost, and the fixed-bucket
//! latency histogram's record/percentile cost.  (Hand-rolled harness; see
//! util::bench.)

use amq::coordinator::synth::synth_jsd;
use amq::coordinator::Config;
use amq::runtime::serve::LatencyHistogram;
use amq::runtime::{ContinuousBatcher, SchedulerOptions};
use amq::util::bench::{bench, header};
use std::time::Duration;

fn main() {
    // The evaluator stands in for a lane-stacked PJRT scorer round trip:
    // a fixed per-dispatch submission cost plus a marginal cost per lane
    // (padding included), mirroring the coordinator bench's device model.
    const DISPATCH_US: u64 = 200;
    const LANE_US: u64 = 30;
    header("continuous batcher (8 closed-loop clients, 200us simulated dispatch)");
    for lanes in [1usize, 8] {
        let batcher = ContinuousBatcher::spawn(
            SchedulerOptions {
                lanes,
                max_wait: Duration::from_micros(500),
                queue_cap: 1024,
            },
            move || {
                move |chunk: &[Config]| -> amq::Result<Vec<f32>> {
                    std::thread::sleep(Duration::from_micros(
                        DISPATCH_US + lanes as u64 * LANE_US,
                    ));
                    Ok(chunk.iter().map(|c| synth_jsd(c)).collect())
                }
            },
        );
        let res = bench(
            &format!("32-request wave, lanes {lanes}"),
            Duration::from_secs(2),
            || {
                std::thread::scope(|scope| {
                    for t in 0..8usize {
                        let batcher = &batcher;
                        scope.spawn(move || {
                            for i in 0..4usize {
                                let genes = vec![2 + ((t + i) % 3) as u16; 12];
                                std::hint::black_box(
                                    batcher.score(genes).expect("score failed"),
                                );
                            }
                        });
                    }
                });
            },
        );
        res.print();
        let stats = batcher.stats();
        println!(
            "  lanes {lanes}: {} requests / {} dispatches, {:.0}% lane fill, \
             mean queue wait {:.0}us",
            stats.requests,
            stats.dispatches,
            stats.lane_fill_fraction() * 100.0,
            stats.mean_wait_us()
        );
    }

    header("latency histogram (64 log2 buckets)");
    let mut hist = LatencyHistogram::new();
    let mut x = 0x2545F4914F6CDD1Du64;
    bench("record", Duration::from_millis(400), || {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        hist.record(x >> 44);
    })
    .print();
    bench("percentile (p99)", Duration::from_millis(400), || {
        std::hint::black_box(hist.percentile(0.99));
    })
    .print();
    println!(
        "  {} samples, p50 {}us / p99 {}us / max {}us",
        hist.count(),
        hist.percentile(0.50),
        hist.percentile(0.99),
        hist.max_us()
    );
}

//! The archive: all truly-evaluated (configuration, JSD, avg-bits) samples.
//! Feeds predictor training and the final Pareto extraction (§3.5).

use super::space::Config;
use std::collections::HashSet;

#[derive(Clone, Debug)]
pub struct Sample {
    pub config: Config,
    pub jsd: f32,
    pub avg_bits: f64,
}

#[derive(Default)]
pub struct Archive {
    pub samples: Vec<Sample>,
    seen: HashSet<Config>,
}

impl Archive {
    pub fn new() -> Archive {
        Archive::default()
    }

    /// Insert if unseen; returns false on duplicates.
    pub fn insert(&mut self, config: Config, jsd: f32, avg_bits: f64) -> bool {
        if self.seen.contains(&config) {
            return false;
        }
        self.seen.insert(config.clone());
        self.samples.push(Sample { config, jsd, avg_bits });
        true
    }

    pub fn contains(&self, config: &Config) -> bool {
        self.seen.contains(config)
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Indices of the non-dominated samples (minimize jsd AND avg_bits).
    pub fn pareto_front(&self) -> Vec<usize> {
        pareto_front_of(
            &self
                .samples
                .iter()
                .map(|s| (s.jsd as f64, s.avg_bits))
                .collect::<Vec<_>>(),
        )
    }

    /// Best sample with avg_bits <= budget (+tolerance), by jsd.
    pub fn best_under(&self, budget_bits: f64, tol: f64) -> Option<&Sample> {
        self.samples
            .iter()
            .filter(|s| s.avg_bits <= budget_bits + tol)
            .min_by(|a, b| a.jsd.partial_cmp(&b.jsd).unwrap())
    }
}

/// Non-dominated indices for 2-objective minimization.
pub fn pareto_front_of(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    // sort by first objective asc, then second asc
    idx.sort_by(|&a, &b| {
        points[a]
            .partial_cmp(&points[b])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut front = Vec::new();
    let mut best_second = f64::INFINITY;
    for &i in &idx {
        if points[i].1 < best_second {
            front.push(i);
            best_second = points[i].1;
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup() {
        let mut a = Archive::new();
        assert!(a.insert(vec![2, 3], 0.1, 2.75));
        assert!(!a.insert(vec![2, 3], 0.2, 2.75));
        assert_eq!(a.len(), 1);
        assert!(a.contains(&vec![2, 3]));
    }

    #[test]
    fn pareto_front_simple() {
        let mut a = Archive::new();
        a.insert(vec![2, 2], 0.5, 2.25); // front (cheapest)
        a.insert(vec![4, 4], 0.05, 4.25); // front (best quality)
        a.insert(vec![3, 3], 0.2, 3.25); // front (middle)
        a.insert(vec![2, 4], 0.6, 3.25); // dominated by [3,3]
        let front = a.pareto_front();
        assert_eq!(front.len(), 3);
        assert!(!front.contains(&3));
    }

    #[test]
    fn best_under_budget() {
        let mut a = Archive::new();
        a.insert(vec![2, 2], 0.5, 2.25);
        a.insert(vec![3, 3], 0.2, 3.25);
        a.insert(vec![4, 4], 0.05, 4.25);
        let best = a.best_under(3.25, 0.005).unwrap();
        assert_eq!(best.config, vec![3, 3]);
        assert!(a.best_under(2.0, 0.005).is_none());
    }

    #[test]
    fn pareto_front_of_handles_ties() {
        let pts = vec![(1.0, 1.0), (1.0, 1.0), (0.5, 2.0), (2.0, 0.5)];
        let f = pareto_front_of(&pts);
        // one of the duplicates is on the front, the other dominated-equal
        assert!(f.contains(&2) && f.contains(&3));
        assert_eq!(f.iter().filter(|&&i| i <= 1).count(), 1);
    }
}

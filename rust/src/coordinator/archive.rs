//! The archive: all truly-evaluated (configuration, JSD, avg-bits) samples.
//! Feeds predictor training and the final Pareto extraction (§3.5).

use super::space::Config;
use std::collections::HashSet;

#[derive(Clone, Debug)]
pub struct Sample {
    pub config: Config,
    pub jsd: f32,
    pub avg_bits: f64,
}

#[derive(Default)]
pub struct Archive {
    pub samples: Vec<Sample>,
    seen: HashSet<Config>,
}

impl Archive {
    pub fn new() -> Archive {
        Archive::default()
    }

    /// Insert if unseen; returns false on duplicates.
    pub fn insert(&mut self, config: Config, jsd: f32, avg_bits: f64) -> bool {
        if self.seen.contains(&config) {
            return false;
        }
        self.seen.insert(config.clone());
        self.samples.push(Sample { config, jsd, avg_bits });
        true
    }

    pub fn contains(&self, config: &Config) -> bool {
        self.seen.contains(config)
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Indices of the non-dominated samples (minimize jsd AND avg_bits).
    pub fn pareto_front(&self) -> Vec<usize> {
        pareto_front_of(
            &self
                .samples
                .iter()
                .map(|s| (s.jsd as f64, s.avg_bits))
                .collect::<Vec<_>>(),
        )
    }

    /// Best sample with avg_bits <= budget (+tolerance), by jsd.
    pub fn best_under(&self, budget_bits: f64, tol: f64) -> Option<&Sample> {
        self.samples
            .iter()
            .filter(|s| s.avg_bits <= budget_bits + tol)
            .min_by(|a, b| a.jsd.partial_cmp(&b.jsd).unwrap())
    }

    /// FNV-1a digest of the archive contents in insertion order — genes,
    /// jsd bits and avg-bits bits all fold in, so two archives hash equal
    /// iff they hold bit-identical samples in the same order.  This is the
    /// byte-identity oracle for the topology matrix: {sequential, threaded,
    /// remote shards, mixed} runs of a fixed-seed search must all produce
    /// the same digest.
    pub fn content_hash(&self) -> u64 {
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        let mut mix = |x: u64| {
            // fold each byte, FNV-1a
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01B3);
            }
        };
        mix(self.samples.len() as u64);
        for s in &self.samples {
            mix(s.config.len() as u64);
            for &g in &s.config {
                mix(g as u64);
            }
            mix(s.jsd.to_bits() as u64);
            mix(s.avg_bits.to_bits());
        }
        h
    }
}

/// Non-dominated indices for 2-objective minimization.
pub fn pareto_front_of(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    // sort by first objective asc, then second asc
    idx.sort_by(|&a, &b| {
        points[a]
            .partial_cmp(&points[b])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut front = Vec::new();
    let mut best_second = f64::INFINITY;
    for &i in &idx {
        if points[i].1 < best_second {
            front.push(i);
            best_second = points[i].1;
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup() {
        let mut a = Archive::new();
        assert!(a.insert(vec![2, 3], 0.1, 2.75));
        assert!(!a.insert(vec![2, 3], 0.2, 2.75));
        assert_eq!(a.len(), 1);
        assert!(a.contains(&vec![2, 3]));
    }

    #[test]
    fn pareto_front_simple() {
        let mut a = Archive::new();
        a.insert(vec![2, 2], 0.5, 2.25); // front (cheapest)
        a.insert(vec![4, 4], 0.05, 4.25); // front (best quality)
        a.insert(vec![3, 3], 0.2, 3.25); // front (middle)
        a.insert(vec![2, 4], 0.6, 3.25); // dominated by [3,3]
        let front = a.pareto_front();
        assert_eq!(front.len(), 3);
        assert!(!front.contains(&3));
    }

    #[test]
    fn best_under_budget() {
        let mut a = Archive::new();
        a.insert(vec![2, 2], 0.5, 2.25);
        a.insert(vec![3, 3], 0.2, 3.25);
        a.insert(vec![4, 4], 0.05, 4.25);
        let best = a.best_under(3.25, 0.005).unwrap();
        assert_eq!(best.config, vec![3, 3]);
        assert!(a.best_under(2.0, 0.005).is_none());
    }

    #[test]
    fn content_hash_is_order_and_bit_sensitive() {
        let mut a = Archive::new();
        a.insert(vec![2, 3], 0.5, 2.75);
        a.insert(vec![4, 4], 0.05, 4.25);
        let mut b = Archive::new();
        b.insert(vec![2, 3], 0.5, 2.75);
        b.insert(vec![4, 4], 0.05, 4.25);
        assert_eq!(a.content_hash(), b.content_hash());
        // order matters
        let mut c = Archive::new();
        c.insert(vec![4, 4], 0.05, 4.25);
        c.insert(vec![2, 3], 0.5, 2.75);
        assert_ne!(a.content_hash(), c.content_hash());
        // a single-ulp score change matters
        let mut d = Archive::new();
        d.insert(vec![2, 3], f32::from_bits(0.5f32.to_bits() + 1), 2.75);
        d.insert(vec![4, 4], 0.05, 4.25);
        assert_ne!(a.content_hash(), d.content_hash());
        assert_ne!(Archive::new().content_hash(), a.content_hash());
    }

    #[test]
    fn pareto_front_of_handles_ties() {
        let pts = vec![(1.0, 1.0), (1.0, 1.0), (0.5, 2.0), (2.0, 0.5)];
        let f = pareto_front_of(&pts);
        // one of the duplicates is on the front, the other dominated-equal
        assert!(f.contains(&2) && f.contains(&3));
        assert_eq!(f.iter().filter(|&&i| i <= 1).count(), 1);
    }
}

//! Greedy search (Appendix G baseline): start from all layers at max bits;
//! repeatedly try demoting each remaining layer one step, truly evaluate
//! the JSD impact, and permanently demote the layer that hurts least.
//! Expensive (O(layers) true evals per step) — exactly the cost Table 11
//! contrasts with AMQ.

use super::proxy::ConfigEvaluator;
use super::space::{Config, Gene, SearchSpace};
use crate::Result;

pub struct GreedyResult {
    pub config: Config,
    pub true_evals: usize,
    pub steps: usize,
}

pub fn greedy(
    space: &SearchSpace,
    evaluator: &mut dyn ConfigEvaluator,
    target_bits: f64,
) -> Result<GreedyResult> {
    let start_evals = evaluator.count();
    let mut cfg: Config = space.max_config();
    let mut steps = 0usize;
    while space.avg_bits(&cfg) > target_bits {
        let mut best: Option<(f32, usize, Gene)> = None;
        for li in 0..space.n_layers() {
            let Some(g) = space.demote(li, cfg[li]) else { continue };
            let mut cand = cfg.clone();
            cand[li] = g;
            let jsd = evaluator.eval_jsd(&cand)?;
            if best.map(|(j, _, _)| jsd < j).unwrap_or(true) {
                best = Some((jsd, li, g));
            }
        }
        match best {
            Some((_, li, g)) => {
                cfg[li] = g;
                steps += 1;
            }
            None => break, // nothing left to demote
        }
    }
    Ok(GreedyResult {
        config: cfg,
        true_evals: evaluator.count() - start_evals,
        steps,
    })
}

/// One greedy demotion step: returns the best single-layer demotion of
/// `cfg`, or None when nothing can be demoted.  (Used by harnesses that
/// snapshot the descent at multiple budgets.)
pub fn greedy_step(
    space: &SearchSpace,
    evaluator: &mut dyn ConfigEvaluator,
    cfg: &Config,
) -> Result<Option<Config>> {
    let mut best: Option<(f32, Config)> = None;
    for li in 0..space.n_layers() {
        let Some(g) = space.demote(li, cfg[li]) else { continue };
        let mut cand = cfg.clone();
        cand[li] = g;
        let jsd = evaluator.eval_jsd(&cand)?;
        if best.as_ref().map(|(j, _)| jsd < *j).unwrap_or(true) {
            best = Some((jsd, cand));
        }
    }
    Ok(best.map(|(_, c)| c))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::space::toy_space;

    struct SynthEval {
        weights: Vec<f32>,
        evals: usize,
    }

    impl ConfigEvaluator for SynthEval {
        fn eval_jsd(&mut self, config: &Config) -> Result<f32> {
            self.evals += 1;
            Ok(config
                .iter()
                .enumerate()
                .map(|(i, &b)| self.weights[i] * ((4 - b) as f32).powi(2))
                .sum())
        }

        fn count(&self) -> usize {
            self.evals
        }
    }

    #[test]
    fn demotes_cheapest_layers_first() {
        let space = toy_space(6);
        let mut ev = SynthEval { weights: vec![1.0, 0.01, 1.0, 0.01, 1.0, 0.01], evals: 0 };
        let res = greedy(&space, &mut ev, 3.5 + 0.25).unwrap();
        // cheap layers (odd) should be the demoted ones
        let cheap: u32 = [1, 3, 5].iter().map(|&i| res.config[i] as u32).sum();
        let dear: u32 = [0, 2, 4].iter().map(|&i| res.config[i] as u32).sum();
        assert!(cheap < dear, "{:?}", res.config);
        assert!(space.avg_bits(&res.config) <= 3.75);
    }

    #[test]
    fn eval_count_scales_with_layers_times_steps() {
        let space = toy_space(8);
        let mut ev = SynthEval { weights: vec![0.1; 8], evals: 0 };
        let res = greedy(&space, &mut ev, 2.25).unwrap();
        // full demotion: 16 steps, each trying <= 8 layers
        assert_eq!(space.avg_bits(&res.config), 2.25);
        assert!(res.true_evals > 60, "{}", res.true_evals);
        assert_eq!(res.steps, 16);
    }

    #[test]
    fn stops_at_floor() {
        let space = toy_space(3);
        let mut ev = SynthEval { weights: vec![0.1; 3], evals: 0 };
        let res = greedy(&space, &mut ev, 1.0).unwrap(); // below reachable
        assert_eq!(res.config, vec![2, 2, 2]);
    }
}

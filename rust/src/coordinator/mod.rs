//! The AMQ coordinator — the paper's contribution (§3, Algorithm 1):
//!
//! * [`space`] — layer-wise `(method, bits)` search space + average-bits
//!   objective (genes over the `quant::registry` method axis);
//! * [`sensitivity`] — per-layer low-bit sensitivity scan (Fig. 2) and the
//!   per-`(layer, method, bits)` gene scan;
//! * [`pruning`] — 2x-median outlier exclusion (§3.2, Table 5);
//! * [`proxy`] — the precomputed `(method, layer, bits)` piece bank +
//!   zero-copy candidate assembly (§3.3) and the
//!   [`proxy::ConfigEvaluator`] true-evaluation interface;
//! * [`predictor`] — RBF (default) / MLP / exact-GP quality predictors
//!   (§3.4; the GP also prices uncertainty for the UCB candidate screen);
//! * [`nsga2`] — the multi-objective genetic engine;
//! * [`search`] — the iterative search-and-update loop (§3.5);
//! * [`warmstart`] — archive + predictor-training-set persistence keyed by
//!   `(model, methods, budget)` for `repro search --warm-start DIR`;
//! * [`oneshot`], [`greedy`] — the Appendix G discrete-search baselines;
//! * [`archive`] — evaluated samples, Pareto front, budget selection;
//! * [`synth`] — the deterministic synthetic workload the topology-matrix
//!   CI and the remote-shard tests score cross-process.

pub mod archive;
pub mod greedy;
pub mod nsga2;
pub mod oneshot;
pub mod predictor;
pub mod pruning;
pub mod proxy;
pub mod search;
pub mod sensitivity;
pub mod space;
pub mod synth;
pub mod warmstart;

pub use archive::{Archive, Sample};
pub use proxy::{
    slab_budget_bytes, BankShareStats, ConfigEvaluator, DeviceBank, DeviceProxy,
    EvalBatchStats, EvalPool, MethodBuildStats, PooledEvaluator, ProxyBank, ProxyEvaluator,
    DEFAULT_SLAB_CACHE_MB,
};
pub use search::{run_search, run_search_seeded, SearchParams, SearchResult};
pub use space::{gene, gene_bits, gene_method, try_gene_method, Config, Gene, SearchSpace};
pub use warmstart::{WarmEntry, WarmKey, WarmLoad};

//! NSGA-II (Deb et al., 2002) over `(method, bits)` gene configurations:
//! fast non-dominated sort, crowding distance, binary tournament, uniform
//! crossover and per-gene mutation (the paper's §3.5 search engine).
//! The operators are genome-agnostic — a gene is an opaque choice from
//! `space.choices[i]` — so the RNG stream is identical to the legacy
//! bits-only genome whenever the per-layer choice counts match.

use super::space::{Config, SearchSpace};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct Nsga2Params {
    pub pop_size: usize,
    pub generations: usize,
    pub crossover_prob: f32,
    pub mutation_prob: f32,
}

impl Default for Nsga2Params {
    fn default() -> Self {
        // Table 6 defaults (pop 200, 20 generations, pc 0.9, pm 0.1)
        Nsga2Params {
            pop_size: 200,
            generations: 20,
            crossover_prob: 0.9,
            mutation_prob: 0.1,
        }
    }
}

/// One evaluated individual: objectives are (predicted quality, avg bits),
/// both minimized.
#[derive(Clone, Debug)]
pub struct Individual {
    pub config: Config,
    pub obj: [f64; 2],
    pub rank: usize,
    pub crowding: f64,
}

/// `a` dominates `b` (2-objective minimization).
#[inline]
pub fn dominates(a: &[f64; 2], b: &[f64; 2]) -> bool {
    a[0] <= b[0] && a[1] <= b[1] && (a[0] < b[0] || a[1] < b[1])
}

/// Fast non-dominated sort: assigns ranks, returns the fronts.
pub fn non_dominated_sort(pop: &mut [Individual]) -> Vec<Vec<usize>> {
    let n = pop.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut dom_count = vec![0usize; n];
    for i in 0..n {
        for j in i + 1..n {
            if dominates(&pop[i].obj, &pop[j].obj) {
                dominated_by[i].push(j);
                dom_count[j] += 1;
            } else if dominates(&pop[j].obj, &pop[i].obj) {
                dominated_by[j].push(i);
                dom_count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dom_count[i] == 0).collect();
    let mut rank = 0;
    while !current.is_empty() {
        for &i in &current {
            pop[i].rank = rank;
        }
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
        rank += 1;
    }
    fronts
}

/// Crowding distance within a front (boundary points get infinity).
pub fn crowding_distance(pop: &mut [Individual], front: &[usize]) {
    for &i in front {
        pop[i].crowding = 0.0;
    }
    let m = front.len();
    if m <= 2 {
        for &i in front {
            pop[i].crowding = f64::INFINITY;
        }
        return;
    }
    for obj in 0..2 {
        let mut order: Vec<usize> = front.to_vec();
        order.sort_by(|&a, &b| {
            pop[a].obj[obj]
                .partial_cmp(&pop[b].obj[obj])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let lo = pop[order[0]].obj[obj];
        let hi = pop[order[m - 1]].obj[obj];
        pop[order[0]].crowding = f64::INFINITY;
        pop[order[m - 1]].crowding = f64::INFINITY;
        if hi <= lo {
            continue;
        }
        for w in 1..m - 1 {
            let delta = (pop[order[w + 1]].obj[obj] - pop[order[w - 1]].obj[obj]) / (hi - lo);
            pop[order[w]].crowding += delta;
        }
    }
}

fn tournament<'a>(pop: &'a [Individual], rng: &mut Rng) -> &'a Individual {
    let a = &pop[rng.below(pop.len())];
    let b = &pop[rng.below(pop.len())];
    if a.rank < b.rank || (a.rank == b.rank && a.crowding > b.crowding) {
        a
    } else {
        b
    }
}

/// Uniform crossover + per-gene mutation, repaired into the space.
fn make_child(
    space: &SearchSpace,
    p1: &Config,
    p2: &Config,
    params: &Nsga2Params,
    rng: &mut Rng,
) -> Config {
    let mut child: Config = if rng.bool(params.crossover_prob) {
        p1.iter()
            .zip(p2)
            .map(|(&a, &b)| if rng.bool(0.5) { a } else { b })
            .collect()
    } else {
        p1.clone()
    };
    for (i, gene) in child.iter_mut().enumerate() {
        if rng.bool(params.mutation_prob) && space.choices[i].len() > 1 {
            let mut b = *rng.choice(&space.choices[i]);
            while b == *gene {
                b = *rng.choice(&space.choices[i]);
            }
            *gene = b;
        }
    }
    space.repair(&mut child);
    child
}

/// Run NSGA-II with a per-config objective function (the search plugs in
/// `(predictor(config), avg_bits(config))`).  Returns the final population
/// sorted by (rank, -crowding).  Thin wrapper over [`run_batched`]; the RNG
/// stream and results are identical to evaluating inline because objective
/// evaluation never consumes the RNG.
pub fn run<F>(
    space: &SearchSpace,
    seed_pop: Vec<Config>,
    params: &Nsga2Params,
    rng: &mut Rng,
    mut objectives: F,
) -> Vec<Individual>
where
    F: FnMut(&Config) -> [f64; 2],
{
    run_batched(space, seed_pop, params, rng, |cfgs| {
        cfgs.iter().map(&mut objectives).collect()
    })
}

/// Run NSGA-II with a *batched* objective: each generation's offspring are
/// produced first (all genetic operators run, consuming the RNG), then the
/// whole cohort is scored in one call — the hook the sharded evaluation
/// pool uses to fan per-individual scoring out across workers.
pub fn run_batched<F>(
    space: &SearchSpace,
    seed_pop: Vec<Config>,
    params: &Nsga2Params,
    rng: &mut Rng,
    mut objectives: F,
) -> Vec<Individual>
where
    F: FnMut(&[Config]) -> Vec<[f64; 2]>,
{
    let mut init: Vec<Config> = seed_pop.into_iter().take(params.pop_size).collect();
    while init.len() < params.pop_size {
        init.push(space.random(rng));
    }
    let objs = objectives(&init);
    assert_eq!(objs.len(), init.len(), "batched objective must score every config");
    let mut pop: Vec<Individual> = init
        .into_iter()
        .zip(objs)
        .map(|(config, obj)| Individual { config, obj, rank: 0, crowding: 0.0 })
        .collect();
    rank_population(&mut pop);

    for _gen in 0..params.generations {
        // offspring cohort (genetic operators only — no scoring yet)
        let mut offspring: Vec<Config> = Vec::with_capacity(params.pop_size);
        while offspring.len() < params.pop_size {
            let p1 = tournament(&pop, rng).config.clone();
            let p2 = tournament(&pop, rng).config.clone();
            offspring.push(make_child(space, &p1, &p2, params, rng));
        }
        // score the whole cohort at once (a short result would silently
        // shrink the population through the zip below — hard error instead)
        let objs = objectives(&offspring);
        assert_eq!(objs.len(), offspring.len(), "batched objective must score every config");
        let mut children: Vec<Individual> = offspring
            .into_iter()
            .zip(objs)
            .map(|(config, obj)| Individual { config, obj, rank: 0, crowding: 0.0 })
            .collect();
        pop.append(&mut children);
        rank_population(&mut pop);
        // environmental selection: best pop_size by (rank, crowding)
        pop.sort_by(|a, b| {
            a.rank
                .cmp(&b.rank)
                .then(b.crowding.partial_cmp(&a.crowding).unwrap_or(std::cmp::Ordering::Equal))
        });
        pop.truncate(params.pop_size);
        rank_population(&mut pop);
    }
    pop.sort_by(|a, b| {
        a.rank
            .cmp(&b.rank)
            .then(b.crowding.partial_cmp(&a.crowding).unwrap_or(std::cmp::Ordering::Equal))
    });
    pop
}

fn rank_population(pop: &mut [Individual]) {
    let fronts = non_dominated_sort(pop);
    for front in &fronts {
        crowding_distance(pop, front);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::space::toy_space;

    fn ind(o0: f64, o1: f64) -> Individual {
        Individual { config: vec![], obj: [o0, o1], rank: 0, crowding: 0.0 }
    }

    #[test]
    fn dominates_cases() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 2.0], &[2.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]));
    }

    #[test]
    fn sort_ranks_fronts() {
        let mut pop = vec![ind(1.0, 1.0), ind(2.0, 2.0), ind(0.5, 3.0), ind(3.0, 3.0)];
        let fronts = non_dominated_sort(&mut pop);
        assert_eq!(pop[0].rank, 0);
        assert_eq!(pop[2].rank, 0);
        assert_eq!(pop[1].rank, 1);
        assert_eq!(pop[3].rank, 2);
        assert_eq!(fronts[0].len(), 2);
    }

    #[test]
    fn crowding_boundaries_infinite() {
        let mut pop = vec![ind(0.0, 3.0), ind(1.0, 2.0), ind(2.0, 1.0), ind(3.0, 0.0)];
        let fronts = non_dominated_sort(&mut pop);
        crowding_distance(&mut pop, &fronts[0]);
        assert!(pop[0].crowding.is_infinite());
        assert!(pop[3].crowding.is_infinite());
        assert!(pop[1].crowding.is_finite() && pop[1].crowding > 0.0);
    }

    #[test]
    fn converges_to_known_front() {
        // objective: jsd surrogate = sum over layers of (4-bits)^2 (lower
        // bits hurt), second = avg bits. The Pareto front is the set of
        // "uniform-ish" configs; at minimum, high-bit configs must dominate
        // the quality end.
        let space = toy_space(10);
        let mut rng = Rng::new(42);
        let pop = run(&space, vec![], &Nsga2Params {
            pop_size: 80, generations: 40, crossover_prob: 0.9, mutation_prob: 0.1,
        }, &mut rng, |cfg| {
            let q: f64 = cfg.iter().map(|&b| ((4 - b) as f64).powi(2)).sum();
            [q, space.avg_bits(cfg)]
        });
        // the front must reach (or come within one gene of) both corners:
        // quality optimum ~ all-4, memory optimum ~ all-2
        let best_q = pop
            .iter()
            .min_by(|a, b| a.obj[0].partial_cmp(&b.obj[0]).unwrap())
            .unwrap();
        let fours = best_q.config.iter().filter(|&&b| b == 4).count();
        assert!(fours >= 9, "quality corner not reached: {:?}", best_q.config);
        let best_m = pop
            .iter()
            .min_by(|a, b| a.obj[1].partial_cmp(&b.obj[1]).unwrap())
            .unwrap();
        let twos = best_m.config.iter().filter(|&&b| b == 2).count();
        assert!(twos >= 9, "memory corner not reached: {:?}", best_m.config);
    }

    #[test]
    fn respects_pinned_layers() {
        let mut space = toy_space(6);
        space.pin(0, 4);
        space.pin(3, 4);
        let mut rng = Rng::new(7);
        let pop = run(&space, vec![], &Nsga2Params {
            pop_size: 20, generations: 5, crossover_prob: 0.9, mutation_prob: 0.3,
        }, &mut rng, |cfg| [0.0, space.avg_bits(cfg)]);
        for ind in &pop {
            assert_eq!(ind.config[0], 4);
            assert_eq!(ind.config[3], 4);
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let space = toy_space(5);
        let p =
            Nsga2Params { pop_size: 16, generations: 4, crossover_prob: 0.9, mutation_prob: 0.1 };
        let f = |cfg: &Config| [cfg.iter().map(|&b| b as f64).sum::<f64>(), 0.0];
        let a = run(&space, vec![], &p, &mut Rng::new(9), f);
        let b = run(&space, vec![], &p, &mut Rng::new(9), f);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.config, y.config);
        }
    }

    #[test]
    fn batched_matches_per_config() {
        // run() and run_batched() must walk the identical RNG stream and
        // produce the identical population (the pool-dispatch refactor must
        // not change search results).
        let space = toy_space(7);
        let p =
            Nsga2Params { pop_size: 20, generations: 6, crossover_prob: 0.9, mutation_prob: 0.15 };
        let score = |cfg: &Config| {
            let q: f64 = cfg.iter().map(|&b| ((4 - b) as f64).powi(2)).sum();
            [q, space.avg_bits(cfg)]
        };
        let a = run(&space, vec![], &p, &mut Rng::new(31), score);
        let b = run_batched(&space, vec![], &p, &mut Rng::new(31), |cfgs| {
            cfgs.iter().map(score).collect()
        });
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.config, y.config);
            assert_eq!(x.obj, y.obj);
            assert_eq!(x.rank, y.rank);
        }
    }
}

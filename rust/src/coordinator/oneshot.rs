//! One-shot search (Appendix G baseline): rank layers by sensitivity, then
//! assign the most sensitive layers 4 bits and the least sensitive 2 bits
//! in a single pass so the average bit-width matches the target.

use super::space::{Config, SearchSpace};

/// Build a configuration hitting `target_bits` (±tol best effort) from a
/// sensitivity ranking: walk the layers from least to most sensitive,
/// demoting 4->3->2 (method preserved per gene) until the target is
/// reached.
pub fn one_shot(space: &SearchSpace, sensitivity: &[f32], target_bits: f64) -> Config {
    let n = space.n_layers();
    assert_eq!(sensitivity.len(), n);
    let mut cfg: Config = space.max_config();
    // least sensitive first
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| sensitivity[a].partial_cmp(&sensitivity[b]).unwrap());

    // pass 1: demote max -> mid, pass 2: mid -> min (preserves the one-shot
    // "most sensitive stay high" structure)
    for _pass in 0..2 {
        for &li in &order {
            if space.avg_bits(&cfg) <= target_bits {
                return cfg;
            }
            if space.choices[li].len() <= 1 {
                continue;
            }
            if let Some(g) = space.demote(li, cfg[li]) {
                // each pass takes one bit step down per layer
                cfg[li] = g;
            }
        }
    }
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::space::toy_space;

    #[test]
    fn hits_target_bits() {
        let space = toy_space(16);
        let sens: Vec<f32> = (0..16).map(|i| i as f32).collect();
        for target in [2.5f64, 3.0, 3.5, 4.0] {
            let cfg = one_shot(&space, &sens, target);
            let avg = space.avg_bits(&cfg);
            assert!(avg <= target + 0.01, "target {target} got {avg}");
            assert!(avg >= target - 0.25, "undershoot: target {target} got {avg}");
        }
    }

    #[test]
    fn sensitive_layers_keep_more_bits() {
        let space = toy_space(8);
        let sens = vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let cfg = one_shot(&space, &sens, 3.25);
        // least sensitive layer gets <= bits of most sensitive layer
        assert!(cfg[0] <= cfg[7]);
        assert!(cfg[1] <= cfg[6]);
    }

    #[test]
    fn respects_pinned_layers() {
        let mut space = toy_space(6);
        space.pin(2, 4);
        let sens = vec![0.0; 6];
        let cfg = one_shot(&space, &sens, 2.5);
        assert_eq!(cfg[2], 4);
    }
}

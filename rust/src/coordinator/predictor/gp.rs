//! Exact Gaussian-process regressor with an RBF kernel (§3.4 surrogate,
//! uncertainty-aware variant):
//!
//!   mean(x) = b + k(x)^T (K + λI)^{-1} (y - b)
//!   var(x)  = k(x,x) + λ - ||L^{-1} k(x)||²,   K + λI = L L^T
//!
//! The kernel and bandwidth heuristic are identical to [`RbfPredictor`],
//! so point predictions match the RBF surrogate; what the GP adds is the
//! retained Cholesky factor, which prices every query's *uncertainty* —
//! zero at training points, growing with distance from the archive.  The
//! search's UCB screen (`SearchParams::ucb_kappa`) consumes that via
//! [`QualityPredictor::predict_with_std`].
//!
//! Duplicate training points make `K` singular; the fit escalates the
//! diagonal jitter until the factorization succeeds, so repeated archive
//! entries degrade the conditioning, never the process.
//!
//! [`RbfPredictor`]: super::RbfPredictor

use super::rbf::dist2;
use super::QualityPredictor;
use crate::tensor::cholesky_f64;

pub struct GpPredictor {
    /// Base diagonal jitter λ (matches the RBF ridge so the two
    /// surrogates' point predictions agree).
    pub ridge: f32,
    centers: Vec<Vec<f32>>,
    alpha: Vec<f64>,
    /// Lower Cholesky factor of `K + λI` (row-major n×n); empty until fit.
    chol: Vec<f64>,
    /// The jitter actually factorized (escalated on duplicate points).
    jitter: f64,
    bias: f32,
    gamma2: f32, // 2 γ²
}

impl Default for GpPredictor {
    fn default() -> Self {
        GpPredictor {
            ridge: 1e-4,
            centers: Vec::new(),
            alpha: Vec::new(),
            chol: Vec::new(),
            jitter: 0.0,
            bias: 0.0,
            gamma2: 1.0,
        }
    }
}

impl GpPredictor {
    /// Kernel vector k(x, centers) in f64.
    fn kvec(&self, x: &[f32]) -> Vec<f64> {
        self.centers
            .iter()
            .map(|c| (-(dist2(c, x) as f64) / self.gamma2 as f64).exp())
            .collect()
    }
}

impl QualityPredictor for GpPredictor {
    fn name(&self) -> &'static str {
        "gp"
    }

    fn fit(&mut self, x: &[Vec<f32>], y: &[f32]) {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let n = x.len();
        // bandwidth: median pairwise squared distance (same heuristic as
        // the RBF surrogate, subsampled for big archives)
        let mut d2s = Vec::new();
        let step = (n / 64).max(1);
        for i in (0..n).step_by(step) {
            for j in (i + 1..n).step_by(step) {
                let d = dist2(&x[i], &x[j]);
                if d > 0.0 {
                    d2s.push(d);
                }
            }
        }
        self.gamma2 = crate::tensor::median(&d2s).max(1e-6);

        self.bias = y.iter().sum::<f32>() / n as f32;
        let yc: Vec<f64> = y.iter().map(|&v| (v - self.bias) as f64).collect();
        self.centers = x.to_vec();

        // kernel matrix in f64; factorize with escalating jitter so
        // duplicate rows never NaN the fit
        let mut k = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i..n {
                let v = (-(dist2(&x[i], &x[j]) as f64) / self.gamma2 as f64).exp();
                k[i * n + j] = v;
                k[j * n + i] = v;
            }
        }
        let mut jitter = self.ridge as f64;
        let mut chol = None;
        for _ in 0..8 {
            let mut kj = k.clone();
            for i in 0..n {
                kj[i * n + i] += jitter;
            }
            if let Some(l) = cholesky_f64(&kj, n) {
                chol = Some(l);
                break;
            }
            jitter *= 10.0;
        }
        let Some(l) = chol else {
            // pathological inputs: degrade to the constant mean predictor
            self.alpha = vec![0.0; n];
            self.chol = Vec::new();
            self.jitter = jitter;
            return;
        };
        // alpha = (K + λI)^{-1} yc via the two triangular solves
        let mut v = vec![0.0f64; n];
        for i in 0..n {
            let mut s = yc[i];
            for t in 0..i {
                s -= l[i * n + t] * v[t];
            }
            v[i] = s / l[i * n + i];
        }
        let mut alpha = vec![0.0f64; n];
        for i in (0..n).rev() {
            let mut s = v[i];
            for t in i + 1..n {
                s -= l[t * n + i] * alpha[t];
            }
            alpha[i] = s / l[i * n + i];
        }
        self.alpha = alpha;
        self.chol = l;
        self.jitter = jitter;
    }

    fn predict(&self, x: &[f32]) -> f32 {
        let k = self.kvec(x);
        let s: f64 = k.iter().zip(&self.alpha).map(|(kv, a)| kv * a).sum();
        self.bias + s as f32
    }

    fn predict_with_std(&self, x: &[f32]) -> (f32, f32) {
        let mean = self.predict(x);
        let n = self.centers.len();
        if self.chol.is_empty() {
            return (mean, 0.0);
        }
        // forward solve L v = k(x); var = k(x,x) + λ - v^T v
        let k = self.kvec(x);
        let l = &self.chol;
        let mut v = vec![0.0f64; n];
        for i in 0..n {
            let mut s = k[i];
            for t in 0..i {
                s -= l[i * n + t] * v[t];
            }
            v[i] = s / l[i * n + i];
        }
        let vtv: f64 = v.iter().map(|&x| x * x).sum();
        let var = (1.0 + self.jitter - vtv).max(0.0);
        (mean, var.sqrt() as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_on_single_point() {
        let mut p = GpPredictor::default();
        p.fit(&[vec![0.5, 0.5]], &[3.0]);
        assert!((p.predict(&[0.5, 0.5]) - 3.0).abs() < 1e-3);
    }

    #[test]
    fn std_near_zero_at_training_points_grows_with_distance() {
        let mut p = GpPredictor::default();
        let xs: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32 * 0.2, 0.5]).collect();
        let ys: Vec<f32> = xs.iter().map(|x| x[0] * 2.0 + 1.0).collect();
        p.fit(&xs, &ys);
        let (_, s_train) = p.predict_with_std(&xs[2]);
        assert!(s_train < 0.05, "std at a training point: {s_train}");
        let (_, s_near) = p.predict_with_std(&[0.5, 0.6]);
        let (_, s_far) = p.predict_with_std(&[5.0, -4.0]);
        assert!(
            s_train < s_near && s_near < s_far,
            "std must grow with distance: {s_train} / {s_near} / {s_far}"
        );
        // far from every center the prior variance k(x,x)+λ ≈ 1 dominates
        assert!(s_far > 0.9, "{s_far}");
    }

    #[test]
    fn duplicate_points_do_not_nan() {
        let mut p = GpPredictor::default();
        let xs = vec![vec![0.0, 0.0], vec![0.0, 0.0], vec![0.0, 0.0], vec![1.0, 1.0]];
        let ys = vec![1.0, 1.0, 1.0, 2.0];
        p.fit(&xs, &ys);
        let (m, s) = p.predict_with_std(&[0.0, 0.0]);
        assert!(m.is_finite() && s.is_finite(), "mean {m}, std {s}");
        assert!((m - 1.0).abs() < 0.2, "{m}");
        let (m, s) = p.predict_with_std(&[0.7, 0.3]);
        assert!(m.is_finite() && s.is_finite());
        assert!(s >= 0.0);
    }

    #[test]
    fn smooth_between_points() {
        let mut p = GpPredictor::default();
        p.fit(&[vec![0.0], vec![1.0]], &[0.0, 1.0]);
        let mid = p.predict(&[0.5]);
        assert!(mid > 0.2 && mid < 0.8, "{mid}");
    }
}

//! One-hidden-layer MLP predictor (tanh, Adam) — the Table 9 ablation
//! comparator.  Deliberately small: archives have a few hundred samples.

use super::QualityPredictor;
use crate::util::Rng;

pub struct MlpPredictor {
    pub hidden: usize,
    pub epochs: usize,
    pub lr: f32,
    seed: u64,
    // weights: w1 [h, d], b1 [h], w2 [h], b2
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: f32,
    d: usize,
    y_mean: f32,
    y_std: f32,
}

impl MlpPredictor {
    pub fn new(seed: u64) -> MlpPredictor {
        MlpPredictor {
            hidden: 32,
            epochs: 300,
            lr: 1e-2,
            seed,
            w1: Vec::new(),
            b1: Vec::new(),
            w2: Vec::new(),
            b2: 0.0,
            d: 0,
            y_mean: 0.0,
            y_std: 1.0,
        }
    }

    fn forward(&self, x: &[f32], hid: &mut [f32]) -> f32 {
        let h = self.hidden;
        for i in 0..h {
            let mut s = self.b1[i];
            let row = &self.w1[i * self.d..(i + 1) * self.d];
            for (w, v) in row.iter().zip(x) {
                s += w * v;
            }
            hid[i] = s.tanh();
        }
        let mut out = self.b2;
        for i in 0..h {
            out += self.w2[i] * hid[i];
        }
        out
    }
}

impl QualityPredictor for MlpPredictor {
    fn name(&self) -> &'static str {
        "mlp"
    }

    fn fit(&mut self, x: &[Vec<f32>], y: &[f32]) {
        assert!(!x.is_empty());
        let n = x.len();
        self.d = x[0].len();
        let h = self.hidden;
        let mut rng = Rng::new(self.seed);
        let scale = (2.0 / self.d as f32).sqrt();
        self.w1 = (0..h * self.d).map(|_| rng.normal() * scale).collect();
        self.b1 = vec![0.0; h];
        self.w2 = (0..h).map(|_| rng.normal() * (1.0 / (h as f32).sqrt())).collect();
        self.b2 = 0.0;

        // normalize targets
        self.y_mean = y.iter().sum::<f32>() / n as f32;
        let var = y.iter().map(|v| (v - self.y_mean).powi(2)).sum::<f32>() / n as f32;
        self.y_std = var.sqrt().max(1e-6);
        let yn: Vec<f32> = y.iter().map(|v| (v - self.y_mean) / self.y_std).collect();

        // Adam state
        let np = h * self.d + h + h + 1;
        let mut m = vec![0.0f32; np];
        let mut v = vec![0.0f32; np];
        let (b1a, b2a, eps) = (0.9f32, 0.999f32, 1e-8f32);

        let mut hid = vec![0.0f32; h];
        let mut grad = vec![0.0f32; np];
        for epoch in 0..self.epochs {
            grad.iter_mut().for_each(|g| *g = 0.0);
            // full-batch gradient
            for (xi, &yi) in x.iter().zip(&yn) {
                let pred = self.forward(xi, &mut hid);
                let err = 2.0 * (pred - yi) / n as f32;
                // output layer
                for i in 0..h {
                    grad[h * self.d + h + i] += err * hid[i]; // w2
                    let dh = err * self.w2[i] * (1.0 - hid[i] * hid[i]);
                    for j in 0..self.d {
                        grad[i * self.d + j] += dh * xi[j]; // w1
                    }
                    grad[h * self.d + i] += dh; // b1
                }
                grad[np - 1] += err; // b2
            }
            // Adam step
            let t = (epoch + 1) as f32;
            let lr_t = self.lr * (1.0 - b2a.powf(t)).sqrt() / (1.0 - b1a.powf(t));
            let mut apply = |idx: usize, p: &mut f32| {
                m[idx] = b1a * m[idx] + (1.0 - b1a) * grad[idx];
                v[idx] = b2a * v[idx] + (1.0 - b2a) * grad[idx] * grad[idx];
                *p -= lr_t * m[idx] / (v[idx].sqrt() + eps);
            };
            for i in 0..h * self.d {
                let mut p = self.w1[i];
                apply(i, &mut p);
                self.w1[i] = p;
            }
            for i in 0..h {
                let mut p = self.b1[i];
                apply(h * self.d + i, &mut p);
                self.b1[i] = p;
            }
            for i in 0..h {
                let mut p = self.w2[i];
                apply(h * self.d + h + i, &mut p);
                self.w2[i] = p;
            }
            let mut p = self.b2;
            apply(np - 1, &mut p);
            self.b2 = p;
        }
    }

    fn predict(&self, x: &[f32]) -> f32 {
        let mut hid = vec![0.0f32; self.hidden];
        self.forward(x, &mut hid) * self.y_std + self.y_mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_linear_function() {
        let xs: Vec<Vec<f32>> = (0..40)
            .map(|i| vec![(i % 5) as f32 / 4.0, (i / 5 % 4) as f32 / 3.0])
            .collect();
        let ys: Vec<f32> = xs.iter().map(|x| 1.0 + 2.0 * x[0] - x[1]).collect();
        let mut p = MlpPredictor::new(0);
        p.fit(&xs, &ys);
        let mut max_err = 0.0f32;
        for (x, &y) in xs.iter().zip(&ys) {
            max_err = max_err.max((p.predict(x) - y).abs());
        }
        assert!(max_err < 0.25, "max err {max_err}");
    }

    #[test]
    fn deterministic_per_seed() {
        let xs = vec![vec![0.0f32], vec![0.5], vec![1.0]];
        let ys = vec![0.0f32, 0.3, 1.0];
        let mut a = MlpPredictor::new(7);
        let mut b = MlpPredictor::new(7);
        a.fit(&xs, &ys);
        b.fit(&xs, &ys);
        assert_eq!(a.predict(&[0.25]), b.predict(&[0.25]));
    }
}

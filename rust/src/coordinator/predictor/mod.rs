//! Quality predictors (§3.4): estimate a configuration's JSD from its
//! bit-vector without touching the model.  RBF is the paper's default;
//! a small MLP is kept for the Table 9 ablation; the exact GP shares the
//! RBF kernel but additionally prices each query's *uncertainty*
//! ([`QualityPredictor::predict_with_std`]), which the search's UCB
//! candidate screen consumes.

mod gp;
mod mlp;
mod rbf;

pub use gp::GpPredictor;
pub use mlp::MlpPredictor;
pub use rbf::RbfPredictor;

/// A trainable (features -> quality) regressor.
pub trait QualityPredictor {
    /// Fit on (feature vector, target) pairs.  Targets are JSD values.
    fn fit(&mut self, x: &[Vec<f32>], y: &[f32]);

    /// Predict the quality of one feature vector.
    fn predict(&self, x: &[f32]) -> f32;

    /// Predict with a one-sigma uncertainty estimate.  Point predictors
    /// report zero uncertainty (the UCB screen then reduces to the plain
    /// point-estimate screen); the GP overrides this with its posterior
    /// standard deviation.
    fn predict_with_std(&self, x: &[f32]) -> (f32, f32) {
        (self.predict(x), 0.0)
    }

    fn name(&self) -> &'static str;
}

/// Which predictor the search uses (Table 9 ablation; CLI `--predictor`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictorKind {
    Rbf,
    Mlp,
    Gp,
}

impl PredictorKind {
    /// Every selectable predictor, CLI order — the single source of truth
    /// the `parse` error text and the ablation harnesses derive from, so
    /// adding a variant can never leave the help text stale.
    pub const ALL: [PredictorKind; 3] =
        [PredictorKind::Rbf, PredictorKind::Mlp, PredictorKind::Gp];

    pub fn name(self) -> &'static str {
        match self {
            PredictorKind::Rbf => "rbf",
            PredictorKind::Mlp => "mlp",
            PredictorKind::Gp => "gp",
        }
    }

    /// Comma-joined list of every selectable predictor name.
    pub fn available() -> String {
        PredictorKind::ALL.map(|k| k.name()).join(", ")
    }

    /// Parse a CLI predictor name.
    pub fn parse(s: &str) -> crate::Result<PredictorKind> {
        let t = s.trim();
        PredictorKind::ALL
            .into_iter()
            .find(|k| k.name() == t)
            .ok_or_else(|| {
                eyre::anyhow!("unknown predictor `{t}` (available: {})", Self::available())
            })
    }
}

pub fn make(kind: PredictorKind, seed: u64) -> Box<dyn QualityPredictor> {
    match kind {
        PredictorKind::Rbf => Box::new(RbfPredictor::default()),
        PredictorKind::Mlp => Box::new(MlpPredictor::new(seed)),
        PredictorKind::Gp => Box::new(GpPredictor::default()),
    }
}

#[cfg(test)]
pub(crate) fn test_function(x: &[f32]) -> f32 {
    // smooth, monotone-ish surrogate of "JSD vs bits": higher features
    // (more bits) -> lower value, with curvature + interactions
    let s: f32 = x.iter().sum();
    let inter: f32 = x.windows(2).map(|w| w[0] * w[1]).sum();
    (-(s / x.len() as f32) * 2.0).exp() + 0.05 * inter / x.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn dataset(n: usize, d: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let xs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| [0.0f32, 0.5, 1.0][rng.below(3)]).collect())
            .collect();
        let ys: Vec<f32> = xs.iter().map(|x| test_function(x)).collect();
        (xs, ys)
    }

    fn check_generalizes(mut p: Box<dyn QualityPredictor>) {
        let (xs, ys) = dataset(160, 12, 1);
        p.fit(&xs, &ys);
        let (xt, yt) = dataset(60, 12, 2);
        // rank correlation on held-out points (what the search needs)
        let pred: Vec<f32> = xt.iter().map(|x| p.predict(x)).collect();
        let tau = kendall_tau(&pred, &yt);
        assert!(tau > 0.6, "{} kendall tau too low: {tau}", p.name());
    }

    pub(crate) fn kendall_tau(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let mut conc = 0i32;
        let mut disc = 0i32;
        for i in 0..n {
            for j in i + 1..n {
                let x = (a[i] - a[j]) as f64;
                let y = (b[i] - b[j]) as f64;
                let s = x * y;
                if s > 0.0 {
                    conc += 1;
                } else if s < 0.0 {
                    disc += 1;
                }
            }
        }
        (conc - disc) as f32 / ((n * (n - 1) / 2) as f32)
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in PredictorKind::ALL {
            assert_eq!(PredictorKind::parse(k.name()).unwrap(), k);
            assert_eq!(make(k, 0).name(), k.name());
        }
        assert!(PredictorKind::parse("nope").is_err());
    }

    #[test]
    fn parse_error_lists_every_kind() {
        // the available-list is derived from ALL, so it can never drift
        let msg = format!("{}", PredictorKind::parse("nope").unwrap_err());
        for k in PredictorKind::ALL {
            assert!(msg.contains(k.name()), "error text misses `{}`: {msg}", k.name());
        }
    }

    #[test]
    fn rbf_generalizes() {
        check_generalizes(make(PredictorKind::Rbf, 0));
    }

    #[test]
    fn mlp_generalizes() {
        check_generalizes(make(PredictorKind::Mlp, 0));
    }

    #[test]
    fn gp_generalizes() {
        check_generalizes(make(PredictorKind::Gp, 0));
    }

    #[test]
    fn gp_matches_rbf_tau() {
        // same kernel, same bandwidth heuristic, f64 solve: the GP's
        // held-out rank correlation must not fall below the RBF's
        let (xs, ys) = dataset(160, 12, 1);
        let (xt, yt) = dataset(60, 12, 2);
        let tau = |kind| {
            let mut p = make(kind, 0);
            p.fit(&xs, &ys);
            let pred: Vec<f32> = xt.iter().map(|x| p.predict(x)).collect();
            kendall_tau(&pred, &yt)
        };
        let (t_rbf, t_gp) = (tau(PredictorKind::Rbf), tau(PredictorKind::Gp));
        assert!(t_gp >= t_rbf - 0.01, "gp tau {t_gp} below rbf tau {t_rbf}");
        assert!(t_gp > 0.6, "{t_gp}");
    }

    #[test]
    fn default_predict_with_std_is_zero_uncertainty() {
        let (xs, ys) = dataset(30, 6, 4);
        let mut p = make(PredictorKind::Rbf, 0);
        p.fit(&xs, &ys);
        let (m, s) = p.predict_with_std(&xs[0]);
        assert_eq!(s, 0.0, "point predictors report zero std");
        assert_eq!(m, p.predict(&xs[0]));
    }

    #[test]
    fn rbf_interpolates_training_points() {
        let (xs, ys) = dataset(50, 8, 3);
        let mut p = RbfPredictor::default();
        p.fit(&xs, &ys);
        for (x, &y) in xs.iter().zip(&ys).take(10) {
            let e = (p.predict(x) - y).abs();
            assert!(e < 0.05, "interpolation error {e}");
        }
    }
}

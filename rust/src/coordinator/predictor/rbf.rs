//! Gaussian RBF interpolator with ridge regularization (Baker et al. 2017
//! style performance predictor — the paper's default choice, §3.4).
//!
//!   f(x) = Σ_i a_i exp(-||x - c_i||² / (2 γ²)) + b
//!
//! Centers are the training points; γ is the median pairwise distance
//! (scale-free heuristic); coefficients come from a Cholesky ridge solve.

use super::QualityPredictor;
use crate::tensor::{cholesky_solve, Mat};

pub struct RbfPredictor {
    pub ridge: f32,
    centers: Vec<Vec<f32>>,
    coef: Vec<f32>,
    bias: f32,
    gamma2: f32, // 2 γ²
}

impl Default for RbfPredictor {
    fn default() -> Self {
        RbfPredictor {
            ridge: 1e-4,
            centers: Vec::new(),
            coef: Vec::new(),
            bias: 0.0,
            gamma2: 1.0,
        }
    }
}

pub(crate) fn dist2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

impl QualityPredictor for RbfPredictor {
    fn name(&self) -> &'static str {
        "rbf"
    }

    fn fit(&mut self, x: &[Vec<f32>], y: &[f32]) {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let n = x.len();
        // bandwidth: median pairwise distance (subsampled for big archives)
        let mut d2s = Vec::new();
        let step = (n / 64).max(1);
        for i in (0..n).step_by(step) {
            for j in (i + 1..n).step_by(step) {
                let d = dist2(&x[i], &x[j]);
                if d > 0.0 {
                    d2s.push(d);
                }
            }
        }
        let med = crate::tensor::median(&d2s).max(1e-6);
        self.gamma2 = med;

        // center targets (bias = mean) for a well-conditioned solve
        self.bias = y.iter().sum::<f32>() / n as f32;
        let yc: Vec<f32> = y.iter().map(|v| v - self.bias).collect();

        // kernel matrix + ridge
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = (-dist2(&x[i], &x[j]) / self.gamma2).exp();
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
            k[(i, i)] += self.ridge;
        }
        self.coef = cholesky_solve(&k, &yc).unwrap_or_else(|| vec![0.0; n]);
        self.centers = x.to_vec();
    }

    fn predict(&self, x: &[f32]) -> f32 {
        let mut s = self.bias;
        for (c, a) in self.centers.iter().zip(&self.coef) {
            s += a * (-dist2(c, x) / self.gamma2).exp();
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_on_single_point() {
        let mut p = RbfPredictor::default();
        p.fit(&[vec![0.5, 0.5]], &[3.0]);
        assert!((p.predict(&[0.5, 0.5]) - 3.0).abs() < 1e-3);
    }

    #[test]
    fn smooth_between_points() {
        let mut p = RbfPredictor::default();
        p.fit(
            &[vec![0.0], vec![1.0]],
            &[0.0, 1.0],
        );
        let mid = p.predict(&[0.5]);
        assert!(mid > 0.2 && mid < 0.8, "{mid}");
    }

    #[test]
    fn handles_duplicate_points() {
        let mut p = RbfPredictor::default();
        p.fit(
            &[vec![0.0, 0.0], vec![0.0, 0.0], vec![1.0, 1.0]],
            &[1.0, 1.0, 2.0],
        );
        assert!((p.predict(&[0.0, 0.0]) - 1.0).abs() < 0.2);
    }
}

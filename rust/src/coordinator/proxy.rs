//! Quantization proxy (§3.3): every searchable layer is quantized once per
//! bit-width with the activation-independent proxy quantizer (HQQ); any
//! candidate configuration is then *assembled* by picking the precomputed
//! (layer, bits) pieces.  The pieces are also uploaded to the PJRT device
//! once, so assembly costs zero host->device copies on the search hot path.

use super::space::Config;
use crate::data::Manifest;
use crate::model::{HessianStore, WeightStore};
use crate::quant::{QuantizedLinear, Quantizer};
use crate::runtime::{EvalService, QuantLayerBufs, Runtime, ScoreBatch, ServiceStats};
use crate::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Host-side precomputed quantizations: (layer index, bits) -> layer.
pub struct ProxyStore {
    pub quantizer_name: &'static str,
    pub bit_choices: Vec<u8>,
    /// `layers[li][bi]` for bit_choices[bi].
    pub layers: Vec<Vec<QuantizedLinear>>,
    pub build_time: Duration,
}

impl ProxyStore {
    /// Quantize every layer at every candidate bit-width.
    pub fn build(
        manifest: &Manifest,
        weights: &WeightStore,
        hessians: Option<&HessianStore>,
        quantizer: &dyn Quantizer,
    ) -> Result<ProxyStore> {
        let t0 = Instant::now();
        let mut layers = Vec::with_capacity(manifest.layers.len());
        for l in &manifest.layers {
            let w = weights.linear(&l.name)?;
            let stats = match hessians {
                Some(h) => Some(h.for_layer(&l.name)?),
                None => None,
            };
            let mut per_bits = Vec::with_capacity(manifest.bit_choices.len());
            for &bits in &manifest.bit_choices {
                per_bits.push(quantizer.quantize(&w, bits, manifest.group_size, stats));
            }
            layers.push(per_bits);
        }
        Ok(ProxyStore {
            quantizer_name: quantizer.name(),
            bit_choices: manifest.bit_choices.clone(),
            layers,
            build_time: t0.elapsed(),
        })
    }

    fn bit_index(&self, bits: u8) -> usize {
        self.bit_choices
            .iter()
            .position(|&b| b == bits)
            .unwrap_or_else(|| panic!("bit width {bits} not precomputed"))
    }

    /// Host-side assembly (for tests / CPU paths).
    pub fn assemble(&self, config: &Config) -> Vec<&QuantizedLinear> {
        config
            .iter()
            .enumerate()
            .map(|(li, &b)| &self.layers[li][self.bit_index(b)])
            .collect()
    }
}

/// Device-side proxy: all pieces uploaded once; assembly picks buffer refs.
/// The host-side [`ProxyStore`] is behind an `Arc` so pool shards can reuse
/// one quantization pass — only the device buffers are per-shard.
pub struct DeviceProxy<'rt> {
    pub store: Arc<ProxyStore>,
    bufs: Vec<Vec<QuantLayerBufs>>,
    rt: &'rt Runtime,
    pub upload_time: Duration,
}

impl<'rt> DeviceProxy<'rt> {
    pub fn new(rt: &'rt Runtime, store: ProxyStore) -> Result<DeviceProxy<'rt>> {
        Self::new_shared(rt, Arc::new(store))
    }

    /// Upload from a shared host-side store.
    pub fn new_shared(rt: &'rt Runtime, store: Arc<ProxyStore>) -> Result<DeviceProxy<'rt>> {
        let t0 = Instant::now();
        let mut bufs = Vec::with_capacity(store.layers.len());
        for per_bits in &store.layers {
            let mut row = Vec::with_capacity(per_bits.len());
            for q in per_bits {
                row.push(rt.upload_quant_layer(q)?);
            }
            bufs.push(row);
        }
        Ok(DeviceProxy { store, bufs, rt, upload_time: t0.elapsed() })
    }

    /// Zero-copy assembly of a configuration into buffer references.
    pub fn assemble(&self, config: &Config) -> Vec<&QuantLayerBufs> {
        config
            .iter()
            .enumerate()
            .map(|(li, &b)| &self.bufs[li][self.store.bit_index(b)])
            .collect()
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
}

/// True-evaluation interface the search loop drives.  Implemented by the
/// PJRT-backed proxy evaluator and by synthetic evaluators in tests.
pub trait ConfigEvaluator {
    /// Mean calibration JSD of an assembled configuration (lower = better).
    fn eval_jsd(&mut self, config: &Config) -> Result<f32>;

    /// Evaluate a batch of configurations, returning JSDs in input order.
    ///
    /// The default runs sequentially; pool-backed evaluators override this
    /// to fan the batch out across worker shards.  Implementations must be
    /// deterministic per configuration so results are bit-identical
    /// regardless of batching or worker count.
    fn eval_jsd_batch(&mut self, configs: &[Config]) -> Result<Vec<f32>> {
        configs.iter().map(|c| self.eval_jsd(c)).collect()
    }

    /// Number of true evaluations performed so far.
    fn count(&self) -> usize;
}

/// Mean fused-scorer JSD of an assembled configuration over a batch set —
/// the single definition of the search's true-evaluation quantity, shared
/// by the in-thread [`ProxyEvaluator`] and the pool shards so their results
/// are bit-identical by construction.
pub fn mean_jsd(proxy: &DeviceProxy, batches: &[ScoreBatch], config: &Config) -> Result<f32> {
    let layers = proxy.assemble(config);
    let mut sum = 0.0f64;
    for b in batches {
        let (jsd, _ce) = proxy.runtime().scores(b, &layers)?;
        sum += jsd as f64;
    }
    Ok((sum / batches.len().max(1) as f64) as f32)
}

/// PJRT-backed evaluator: assembles through the device proxy and runs the
/// fused scorer over the prepared calibration batches, caching results.
pub struct ProxyEvaluator<'rt> {
    pub proxy: &'rt DeviceProxy<'rt>,
    pub batches: &'rt [ScoreBatch],
    cache: HashMap<Config, f32>,
    evals: usize,
    pub eval_time: Duration,
}

impl<'rt> ProxyEvaluator<'rt> {
    pub fn new(proxy: &'rt DeviceProxy<'rt>, batches: &'rt [ScoreBatch]) -> Self {
        ProxyEvaluator {
            proxy,
            batches,
            cache: HashMap::new(),
            evals: 0,
            eval_time: Duration::ZERO,
        }
    }
}

impl ConfigEvaluator for ProxyEvaluator<'_> {
    fn eval_jsd(&mut self, config: &Config) -> Result<f32> {
        if let Some(&v) = self.cache.get(config) {
            return Ok(v);
        }
        let t0 = Instant::now();
        let jsd = mean_jsd(self.proxy, self.batches, config)?;
        self.evals += 1;
        self.eval_time += t0.elapsed();
        self.cache.insert(config.clone(), jsd);
        Ok(jsd)
    }

    fn count(&self) -> usize {
        self.evals
    }
}

/// The sharded evaluation pool's wire types: owned configurations in,
/// per-candidate JSD results out.
pub type EvalPool = EvalService<Config, Result<f32>>;

/// Pool-backed [`ConfigEvaluator`]: fans candidate batches out across the
/// shards of an [`EvalPool`] and reassembles replies in submission order, so
/// the archive a search produces is identical for any worker count.
///
/// The JSD cache and the true-eval counter live on the caller side (like
/// [`ProxyEvaluator`]); shards stay stateless with respect to candidates.
pub struct PooledEvaluator {
    svc: Arc<EvalPool>,
    cache: HashMap<Config, f32>,
    evals: usize,
    pub eval_time: Duration,
}

impl PooledEvaluator {
    /// Spawn a fresh pool: `builder(shard)` runs on each worker thread and
    /// constructs that shard's evaluation closure there (this is where a
    /// non-`Send` PJRT runtime stack gets built per shard).
    pub fn spawn<B, F>(workers: usize, builder: B) -> Self
    where
        B: Fn(usize) -> F + Send + Sync + 'static,
        F: FnMut(Config) -> Result<f32> + 'static,
    {
        Self::from_service(Arc::new(EvalService::spawn_sharded(workers, builder)))
    }

    /// Wrap an existing (possibly shared) pool.  Each wrapper gets its own
    /// cache/counters; the underlying shards are reused across searches.
    pub fn from_service(svc: Arc<EvalPool>) -> Self {
        PooledEvaluator {
            svc,
            cache: HashMap::new(),
            evals: 0,
            eval_time: Duration::ZERO,
        }
    }

    pub fn workers(&self) -> usize {
        self.svc.n_workers()
    }

    pub fn pool_stats(&self) -> ServiceStats {
        self.svc.stats()
    }
}

impl ConfigEvaluator for PooledEvaluator {
    fn eval_jsd(&mut self, config: &Config) -> Result<f32> {
        Ok(self.eval_jsd_batch(std::slice::from_ref(config))?[0])
    }

    fn eval_jsd_batch(&mut self, configs: &[Config]) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        // Unseen, batch-deduplicated candidates, in first-occurrence order.
        let mut pending: Vec<Config> = Vec::new();
        for c in configs {
            if !self.cache.contains_key(c) && !pending.contains(c) {
                pending.push(c.clone());
            }
        }
        // Fan out, then reassemble in submission order (deterministic).
        let replies: Vec<_> = pending.iter().map(|c| self.svc.submit(c.clone())).collect();
        for (c, rx) in pending.iter().zip(replies) {
            let jsd = rx
                .recv()
                .map_err(|_| eyre::anyhow!("evaluation pool worker died"))??;
            self.evals += 1;
            self.cache.insert(c.clone(), jsd);
        }
        self.eval_time += t0.elapsed();
        configs
            .iter()
            .map(|c| {
                self.cache
                    .get(c)
                    .copied()
                    .ok_or_else(|| eyre::anyhow!("missing pooled eval result"))
            })
            .collect()
    }

    fn count(&self) -> usize {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Rtn;
    use crate::tensor::Mat;

    fn toy_store() -> ProxyStore {
        // 2 layers x 3 bit choices of small random weights
        let mk = |seed: u64| {
            let mut state = seed | 1;
            let mut w = Mat::zeros(8, 128);
            for v in &mut w.data {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                *v = ((state >> 11) as f32 / (1u64 << 53) as f32 - 0.5) * 0.2;
            }
            w
        };
        let rtn = Rtn;
        let layers = (0..2)
            .map(|i| {
                let w = mk(i + 1);
                vec![
                    rtn.quantize(&w, 2, 128, None),
                    rtn.quantize(&w, 3, 128, None),
                    rtn.quantize(&w, 4, 128, None),
                ]
            })
            .collect();
        ProxyStore {
            quantizer_name: "rtn",
            bit_choices: vec![2, 3, 4],
            layers,
            build_time: Duration::ZERO,
        }
    }

    #[test]
    fn assemble_picks_right_bits() {
        let store = toy_store();
        let asm = store.assemble(&vec![2, 4]);
        assert_eq!(asm[0].bits, 2);
        assert_eq!(asm[1].bits, 4);
        let asm = store.assemble(&vec![3, 3]);
        assert_eq!(asm[0].bits, 3);
        assert_eq!(asm[1].bits, 3);
    }

    #[test]
    #[should_panic]
    fn assemble_rejects_unknown_bits() {
        let store = toy_store();
        store.assemble(&vec![5, 3]);
    }

    #[test]
    fn assembly_equals_direct_quantization() {
        // the proxy invariant: assembling precomputed pieces is *identical*
        // to quantizing the model at that configuration directly
        let store = toy_store();
        let asm = store.assemble(&vec![2, 3]);
        assert_eq!(asm[0].codes, store.layers[0][0].codes);
        assert_eq!(asm[1].codes, store.layers[1][1].codes);
    }

    /// Deterministic synthetic shard eval: quadratic bit penalty, plus a
    /// per-candidate seeded perturbation (the RNG is derived from the
    /// payload, never from shard state — the pool's determinism contract).
    fn synth_pool(workers: usize) -> PooledEvaluator {
        PooledEvaluator::spawn(workers, |_shard| {
            |cfg: Config| -> Result<f32> {
                let mut seed = 0xA076_1D64_78BD_642Fu64;
                for &b in &cfg {
                    seed = seed.wrapping_mul(0x100000001B3).wrapping_add(b as u64);
                }
                let mut rng = crate::util::Rng::new(seed);
                let base: f32 = cfg.iter().map(|&b| ((4 - b) as f32).powi(2)).sum();
                Ok(base + rng.f32() * 1e-3)
            }
        })
    }

    #[test]
    fn pooled_evaluator_caches_and_counts() {
        let mut ev = synth_pool(2);
        let a = ev.eval_jsd(&vec![2, 3, 4]).unwrap();
        let b = ev.eval_jsd(&vec![2, 3, 4]).unwrap();
        assert_eq!(a, b);
        assert_eq!(ev.count(), 1, "cache hit must not re-evaluate");
        let out = ev
            .eval_jsd_batch(&[vec![2, 3, 4], vec![4, 4, 4], vec![2, 3, 4]])
            .unwrap();
        assert_eq!(out[0], a);
        assert_eq!(out[2], a);
        assert_eq!(ev.count(), 2, "batch dedups against cache and itself");
    }

    #[test]
    fn pooled_evaluator_bit_identical_across_worker_counts() {
        let configs: Vec<Config> = (0..24)
            .map(|i| (0..6).map(|j| [2u8, 3, 4][(i + j) % 3]).collect())
            .collect();
        let mut one = synth_pool(1);
        let mut four = synth_pool(4);
        let a = one.eval_jsd_batch(&configs).unwrap();
        let b = four.eval_jsd_batch(&configs).unwrap();
        assert_eq!(a, b, "results must not depend on worker count");
    }

    #[test]
    fn pooled_evaluator_surfaces_shard_errors() {
        let mut ev = PooledEvaluator::spawn(2, |_shard| {
            |cfg: Config| -> Result<f32> {
                eyre::ensure!(cfg.len() == 3, "bad config length {}", cfg.len());
                Ok(1.0)
            }
        });
        assert!(ev.eval_jsd(&vec![2, 3, 4]).is_ok());
        assert!(ev.eval_jsd(&vec![2, 3]).is_err());
        assert_eq!(ev.count(), 1, "failed evals are not counted or cached");
    }
}

//! Quantization proxy (§3.3), generalized over methods: every searchable
//! layer is quantized once per *(method, bit-width)* with each enabled
//! quantizer; any candidate configuration is then *assembled* by picking
//! the precomputed `(method, layer, bits)` pieces.  The pieces are also
//! uploaded to the PJRT device once, so assembly costs zero host->device
//! copies on the search hot path.
//!
//! With the default single-method registry (HQQ) this is exactly the
//! paper's activation-independent proxy; enabling more methods widens the
//! genome without changing the assembly contract.

use super::space::{gene_bits, try_gene_method, Config, Gene};
use crate::data::Manifest;
use crate::model::{HessianStore, WeightStore};
use crate::quant::{MethodId, MethodRegistry, QuantizedLinear, Quantizer};
use crate::runtime::{
    lane_routed, EvalService, LaneChunkPlan, LaneGroup, LaneSlabCache, QuantLayerBufs, Runtime,
    ScoreBatch, ServiceStats,
};
use crate::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-method build accounting: quantization wall-clock and resident bytes
/// of all `(layer, bits)` pieces of one method.
#[derive(Clone, Debug)]
pub struct MethodBuildStats {
    /// The quantization method the pieces belong to.
    pub method: MethodId,
    /// Wall-clock spent quantizing this method's pieces.
    pub build_time: Duration,
    /// Resident bytes of this method's pieces (packed codes + metadata).
    pub memory_bytes: usize,
}

/// Host-side precomputed quantizations for every enabled method:
/// `(method, layer index, bits) -> quantized layer`.
///
/// Weight matrices and Hessian statistics are loaded once per layer and
/// shared across methods — the method axis multiplies quantization work,
/// never I/O.
pub struct ProxyBank {
    /// Enabled methods, bank-slot order.
    pub methods: Vec<MethodId>,
    /// Candidate bit-widths, manifest order.
    pub bit_choices: Vec<u8>,
    /// `pieces[slot][li][bi]` for methods[slot], bit_choices[bi].
    pieces: Vec<Vec<Vec<QuantizedLinear>>>,
    /// Per-method build time + memory.
    pub stats: Vec<MethodBuildStats>,
}

impl ProxyBank {
    /// Quantize every layer at every candidate bit-width with every enabled
    /// method.  `hessians` are consulted only by methods that use
    /// calibration statistics.
    pub fn build(
        manifest: &Manifest,
        weights: &WeightStore,
        hessians: Option<&HessianStore>,
        registry: &MethodRegistry,
    ) -> Result<ProxyBank> {
        let methods: Vec<MethodId> = registry.enabled().to_vec();
        let quantizers: Vec<Box<dyn Quantizer>> = methods.iter().map(|m| m.build()).collect();
        let mut pieces: Vec<Vec<Vec<QuantizedLinear>>> =
            (0..methods.len()).map(|_| Vec::with_capacity(manifest.layers.len())).collect();
        let mut build_time = vec![Duration::ZERO; methods.len()];
        for l in &manifest.layers {
            // one weight / stats load per layer, shared by every method
            let w = weights.linear(&l.name)?;
            let stats = match hessians {
                Some(h) => Some(h.for_layer(&l.name)?),
                None => None,
            };
            for (slot, method) in methods.iter().enumerate() {
                let t0 = Instant::now();
                let layer_stats = if method.needs_stats() { stats } else { None };
                let mut per_bits = Vec::with_capacity(manifest.bit_choices.len());
                for &bits in &manifest.bit_choices {
                    per_bits.push(quantizers[slot].quantize(
                        &w,
                        bits,
                        manifest.group_size,
                        layer_stats,
                    ));
                }
                pieces[slot].push(per_bits);
                build_time[slot] += t0.elapsed();
            }
        }
        let stats = methods
            .iter()
            .zip(&pieces)
            .zip(build_time)
            .map(|((&method, rows), build_time)| MethodBuildStats {
                method,
                build_time,
                memory_bytes: rows
                    .iter()
                    .flat_map(|per_bits| per_bits.iter())
                    .map(|q| q.memory_bytes())
                    .sum(),
            })
            .collect();
        Ok(ProxyBank { methods, bit_choices: manifest.bit_choices.clone(), pieces, stats })
    }

    /// Assemble a bank from already-quantized pieces (`pieces[slot][li][bi]`)
    /// — synthetic banks for tests and benches; build times are zero,
    /// memory accounting is real.
    pub fn from_parts(
        methods: Vec<MethodId>,
        bit_choices: Vec<u8>,
        pieces: Vec<Vec<Vec<QuantizedLinear>>>,
    ) -> Result<ProxyBank> {
        eyre::ensure!(!methods.is_empty(), "proxy bank needs at least one method");
        eyre::ensure!(
            pieces.len() == methods.len(),
            "piece slots ({}) must match methods ({})",
            pieces.len(),
            methods.len()
        );
        let n_layers = pieces[0].len();
        for (slot, rows) in pieces.iter().enumerate() {
            eyre::ensure!(
                rows.len() == n_layers,
                "method slot {slot} has {} layers, expected {n_layers}",
                rows.len()
            );
            for per_bits in rows {
                eyre::ensure!(
                    per_bits.len() == bit_choices.len(),
                    "piece row has {} bit variants, expected {}",
                    per_bits.len(),
                    bit_choices.len()
                );
            }
        }
        let stats = methods
            .iter()
            .zip(&pieces)
            .map(|(&method, rows)| MethodBuildStats {
                method,
                build_time: Duration::ZERO,
                memory_bytes: rows
                    .iter()
                    .flat_map(|per_bits| per_bits.iter())
                    .map(|q| q.memory_bytes())
                    .sum(),
            })
            .collect();
        Ok(ProxyBank { methods, bit_choices, pieces, stats })
    }

    pub fn n_layers(&self) -> usize {
        self.pieces.first().map(|rows| rows.len()).unwrap_or(0)
    }

    /// Total quantization wall-clock across methods.
    pub fn build_time(&self) -> Duration {
        self.stats.iter().map(|s| s.build_time).sum()
    }

    /// Total resident bytes across all pieces.
    pub fn memory_bytes(&self) -> usize {
        self.stats.iter().map(|s| s.memory_bytes).sum()
    }

    /// Decode and look up a gene's `(slot, bit index)` coordinates.  Genes
    /// arrive from wire `Chunk` frames and persisted archives as well as
    /// from the in-process search, so every miss — an invalid method byte,
    /// a method the bank never precomputed, a bit-width outside the
    /// manifest — is a clean `Err` that fails the one request, never a
    /// panic that takes down the process.
    fn locate(&self, g: Gene) -> Result<(usize, usize)> {
        let method = try_gene_method(g)
            .ok_or_else(|| eyre::anyhow!("invalid method byte in gene {g:#06x}"))?;
        let slot = self
            .methods
            .iter()
            .position(|&m| m == method)
            .ok_or_else(|| {
                eyre::anyhow!("method {} not precomputed in bank", method.name())
            })?;
        let bits = gene_bits(g);
        let bi = self
            .bit_choices
            .iter()
            .position(|&b| b == bits)
            .ok_or_else(|| eyre::anyhow!("bit width {bits} not precomputed"))?;
        Ok((slot, bi))
    }

    /// The precomputed piece for one layer's gene.
    pub fn piece(&self, li: usize, g: Gene) -> Result<&QuantizedLinear> {
        let (slot, bi) = self.locate(g)?;
        Ok(&self.pieces[slot][li][bi])
    }

    /// Host-side assembly (for tests / CPU paths).
    pub fn assemble(&self, config: &[Gene]) -> Result<Vec<&QuantizedLinear>> {
        config.iter().enumerate().map(|(li, &g)| self.piece(li, g)).collect()
    }
}

/// Default lane-slab cache budget in MB (`--slab-cache-mb`).  Archives
/// are byte-identical for any budget — the cache only changes how many
/// slab uploads the lane path pays.
pub const DEFAULT_SLAB_CACHE_MB: usize = 64;

/// The MB→bytes conversion every `--slab-cache-mb` value goes through on
/// its way to a [`LaneSlabCache`] budget (decimal MB, matching the MB
/// figures in the reports) — one definition so the CLI and library
/// defaults can never diverge.
pub const fn slab_budget_bytes(mb: usize) -> usize {
    mb * 1_000_000
}

/// [`DEFAULT_SLAB_CACHE_MB`] in bytes — the budget
/// [`DeviceBank::upload`] uses when no explicit budget is given.
pub const DEFAULT_SLAB_CACHE_BYTES: usize = slab_budget_bytes(DEFAULT_SLAB_CACHE_MB);

/// The process-wide device-side bank: every `(method, layer, bits)` piece
/// uploaded **exactly once**, then `Arc`-shared by the main thread and every
/// evaluation-pool shard.  Before this split each shard uploaded (and kept
/// resident) its own private copy — N workers meant N uploads and N× device
/// bytes; now uploads and residency are 1× regardless of pool width.
///
/// The host bank is resident exactly once, too: when misses host-pack,
/// lane-slab packing **borrows** its rows straight from the bank's host
/// pieces ([`Runtime::upload_lane_slab`]) — the uploaded [`QuantLayerBufs`]
/// carry no host mirrors — and with the gather artifacts present, misses
/// never touch the host at all ([`Runtime::gather_lane_slab`] assembles
/// slabs on device from these resident buffers).  Either way the slabs
/// land in this bank's [`LaneSlabCache`], staying device-resident across
/// calibration batches and across search generations under the
/// `--slab-cache-mb` budget (exact byte accounting via [`BankShareStats`]).
///
/// Holds no runtime reference: a [`DeviceProxy`] pairs a shared bank with
/// the runtime that executes against it.
pub struct DeviceBank {
    /// The host-side bank the buffers mirror.
    pub bank: Arc<ProxyBank>,
    /// `bufs[slot][li][bi]`, mirroring the bank's piece layout.
    bufs: Vec<Vec<Vec<QuantLayerBufs>>>,
    /// Device-resident packed lane slabs, keyed by `(layer, lane
    /// signature)`; shared by every shard that scores through this bank.
    pub slab_cache: LaneSlabCache,
    /// Per-method upload wall-clock, bank-slot order.
    pub upload_times: Vec<Duration>,
    /// Total upload wall-clock across methods.
    pub upload_time: Duration,
}

impl DeviceBank {
    /// Upload every piece of a host bank with the default slab-cache
    /// budget.  Called once per process; sharing is the caller's job (wrap
    /// in `Arc`, clone the handle per shard).
    pub fn upload(rt: &Runtime, bank: Arc<ProxyBank>) -> Result<DeviceBank> {
        Self::upload_with_slab_budget(rt, bank, DEFAULT_SLAB_CACHE_BYTES)
    }

    /// Upload with an explicit slab-cache byte budget (`--slab-cache-mb`;
    /// 0 disables slab retention — lane groups re-pack and re-upload per
    /// plan, the pre-cache behaviour).
    pub fn upload_with_slab_budget(
        rt: &Runtime,
        bank: Arc<ProxyBank>,
        slab_budget_bytes: usize,
    ) -> Result<DeviceBank> {
        let t0 = Instant::now();
        let mut bufs = Vec::with_capacity(bank.pieces.len());
        let mut upload_times = Vec::with_capacity(bank.pieces.len());
        for rows in &bank.pieces {
            let t_m = Instant::now();
            let mut slot = Vec::with_capacity(rows.len());
            for per_bits in rows {
                let mut row = Vec::with_capacity(per_bits.len());
                for q in per_bits {
                    row.push(rt.upload_quant_layer(q)?);
                }
                slot.push(row);
            }
            bufs.push(slot);
            upload_times.push(t_m.elapsed());
        }
        Ok(DeviceBank {
            bank,
            bufs,
            slab_cache: LaneSlabCache::new(slab_budget_bytes),
            upload_times,
            upload_time: t0.elapsed(),
        })
    }

    /// Number of uploaded pieces (= methods × layers × bit choices).
    pub fn n_pieces(&self) -> usize {
        self.bufs.iter().flat_map(|rows| rows.iter()).map(|r| r.len()).sum()
    }

    /// Device-resident bytes of the uploaded pieces (mirrors the host
    /// bank's packed-codes + group-metadata accounting).
    pub fn resident_bytes(&self) -> usize {
        self.bank.memory_bytes()
    }

    /// The uploaded buffers of one layer's gene.
    pub fn piece(&self, li: usize, g: Gene) -> Result<&QuantLayerBufs> {
        let (slot, bi) = self.bank.locate(g)?;
        Ok(&self.bufs[slot][li][bi])
    }

    /// Zero-copy assembly of a configuration into buffer references.
    pub fn assemble(&self, config: &[Gene]) -> Result<Vec<&QuantLayerBufs>> {
        config.iter().enumerate().map(|(li, &g)| self.piece(li, g)).collect()
    }
}

/// Device-bank residency accounting across pool shards: every distinct bank
/// is counted **once**, no matter how many shards reference it through an
/// `Arc` — the "shared vs private" memory story in one struct.  Slab-cache
/// bytes fold in through [`BankShareStats::with_slab_cache_bytes`], so the
/// lane path's extra residency is on the books next to the bank's
/// packed-bytes figure (the device copies of the pieces mirror that figure
/// 1×; the old host mirrors that silently doubled host bank bytes are
/// gone).
#[derive(Clone, Debug, Default)]
pub struct BankShareStats {
    /// Bank references registered (one per initialized shard).
    pub shards: usize,
    /// Bytes the shards would hold with private per-shard copies.
    pub referenced_bytes: usize,
    /// Bytes actually resident (each distinct bank counted once).
    pub resident_bytes: usize,
    /// Device bytes of the packed lane slabs currently resident in the
    /// shared [`LaneSlabCache`] (0 when the lane path never ran or the
    /// cache is disabled).
    pub slab_cache_bytes: usize,
}

impl BankShareStats {
    /// Aggregate the banks the pool shards actually hold.  Shards sharing
    /// one bank contribute its bytes to `referenced_bytes` each, but to
    /// `resident_bytes` once (identity = `Arc` pointer).
    pub fn from_shard_banks(banks: &[Arc<ProxyBank>]) -> BankShareStats {
        let mut seen: Vec<*const ProxyBank> = Vec::new();
        let mut stats = BankShareStats { shards: banks.len(), ..Default::default() };
        for b in banks {
            let bytes = b.memory_bytes();
            stats.referenced_bytes += bytes;
            let ptr = Arc::as_ptr(b);
            if !seen.contains(&ptr) {
                seen.push(ptr);
                stats.resident_bytes += bytes;
            }
        }
        stats
    }

    /// Fold in the live slab-cache bytes (exact, recomputed from the live
    /// entries — see [`crate::runtime::SlabCacheStats`]).
    pub fn with_slab_cache_bytes(mut self, bytes: usize) -> BankShareStats {
        self.slab_cache_bytes = bytes;
        self
    }

    /// Distinct bank pieces (packed-bytes accounting, counted once) plus
    /// the resident packed lane slabs — the search path's residency
    /// figure.  Device copies of the bank pieces track `resident_bytes`
    /// 1:1, so this is also the right order for device-memory sizing.
    pub fn total_resident_bytes(&self) -> usize {
        self.resident_bytes + self.slab_cache_bytes
    }
}

/// Thin per-runtime view over a shared [`DeviceBank`]: the scoring state a
/// shard (or the main thread) actually owns is this pair of pointers —
/// uploads happen in [`DeviceBank::upload`], exactly once per process.
pub struct DeviceProxy<'rt> {
    /// The shared host-side bank (same `Arc` as `dev.bank`).
    pub bank: Arc<ProxyBank>,
    /// The shared device buffers.
    pub dev: Arc<DeviceBank>,
    rt: &'rt Runtime,
}

impl<'rt> DeviceProxy<'rt> {
    /// Upload a private bank (single-runtime paths: benches, examples).
    pub fn new(rt: &'rt Runtime, bank: ProxyBank) -> Result<DeviceProxy<'rt>> {
        Self::new_shared(rt, Arc::new(bank))
    }

    /// Upload from a shared host-side bank.
    pub fn new_shared(rt: &'rt Runtime, bank: Arc<ProxyBank>) -> Result<DeviceProxy<'rt>> {
        Ok(Self::from_device_bank(rt, Arc::new(DeviceBank::upload(rt, bank)?)))
    }

    /// Wrap an already-uploaded shared bank — zero device work.
    pub fn from_device_bank(rt: &'rt Runtime, dev: Arc<DeviceBank>) -> DeviceProxy<'rt> {
        DeviceProxy { bank: dev.bank.clone(), dev, rt }
    }

    /// Zero-copy assembly of a configuration into buffer references.
    pub fn assemble(&self, config: &[Gene]) -> Result<Vec<&QuantLayerBufs>> {
        self.dev.assemble(config)
    }

    /// Resolve a chunk's lane-dispatch plan: group the configs `lanes` at a
    /// time and, per group and layer, fetch the packed slab from the shared
    /// [`LaneSlabCache`].  A miss is resolved one of two ways:
    ///
    ///  * *device gather* (gather executables loaded —
    ///    [`Runtime::slab_gather_enabled`]): one dispatch of the family's
    ///    gather executable reads the group's **already-resident** bank
    ///    buffers and writes the padded slab on device — zero host→device
    ///    bytes ([`Runtime::gather_lane_slab`]);
    ///  * *host pack* (legacy artifacts or `--slab-gather off`): the slab
    ///    is packed from rows **borrowed** from the bank's host pieces and
    ///    uploaded once ([`Runtime::upload_lane_slab`]).
    ///
    /// Both produce bitwise-identical slab bytes, so the cache key, the
    /// scorer results, and the archives never depend on the route.  The
    /// returned plan pins its slabs (`Arc`) for its lifetime, so scoring it
    /// against every calibration batch costs zero further uploads even if
    /// the cache evicts under a tiny `--slab-cache-mb` budget.
    ///
    /// Callers route here only when [`lane_routed`] says so (done by
    /// [`mean_jsd_batch`]); the per-candidate path needs no plan.
    pub fn plan_lane_chunk(&self, configs: &[Config]) -> Result<LaneChunkPlan> {
        let lanes = self.rt.scorer_variant().lanes();
        eyre::ensure!(lanes > 1, "lane plan on a per-candidate runtime");
        let n_layers = self.bank.n_layers();
        for c in configs {
            eyre::ensure!(
                c.len() == n_layers,
                "config has {} genes, bank has {n_layers} layers",
                c.len()
            );
        }
        let gather = self.rt.slab_gather_enabled();
        let mut groups = Vec::with_capacity(configs.len().div_ceil(lanes));
        for group in configs.chunks(lanes) {
            let mut slabs = Vec::with_capacity(n_layers);
            for li in 0..n_layers {
                let sig = crate::runtime::lane_slab_sig(group, li, lanes);
                let slab = self.dev.slab_cache.get_or_build((li, sig), || {
                    if gather {
                        let pieces: Vec<&QuantLayerBufs> = group
                            .iter()
                            .map(|c| self.dev.piece(li, c[li]))
                            .collect::<Result<_>>()?;
                        let bufs = self.rt.gather_lane_slab(&pieces)?;
                        let bytes = bufs.bytes;
                        Ok((bufs, bytes))
                    } else {
                        let pieces: Vec<&QuantizedLinear> = group
                            .iter()
                            .map(|c| self.bank.piece(li, c[li]))
                            .collect::<Result<_>>()?;
                        let bufs = self.rt.upload_lane_slab(&pieces)?;
                        let bytes = bufs.bytes;
                        Ok((bufs, bytes))
                    }
                })?;
                slabs.push(slab);
            }
            groups.push(LaneGroup { real: group.len(), slabs });
        }
        LaneChunkPlan::new(groups)
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
}

/// Dispatch/dedup accounting of an evaluator's batched hot path.
#[derive(Clone, Debug, Default)]
pub struct EvalBatchStats {
    /// Configurations passed through `eval_jsd_batch` (+ single evals).
    pub requested: u64,
    /// Served from the cross-generation cache without any dispatch.
    pub cache_hits: u64,
    /// Duplicates collapsed *within* one incoming batch.  `run_search`
    /// pre-filters its batches against the archive, so on that path both
    /// hit counters are a defense-in-depth backstop (typically zero);
    /// direct `eval_jsd_batch` callers get real protection.
    pub dup_hits: u64,
    /// Configurations actually scored.
    pub evaluated: u64,
    /// Scorer dispatches issued (microbatch chunks, not candidates).
    pub dispatches: u64,
    /// The microbatch size the evaluator packs chunks to.
    pub score_batch: usize,
}

impl EvalBatchStats {
    /// Fraction of requested configs that never reached the scorer.
    pub fn dedup_fraction(&self) -> f64 {
        if self.requested == 0 {
            0.0
        } else {
            (self.cache_hits + self.dup_hits) as f64 / self.requested as f64
        }
    }

    /// Requested configs per dispatch — the combined dedup × batching win
    /// (1.0 = the old one-dispatch-per-candidate behaviour).
    pub fn dispatch_reduction(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            self.requested as f64 / self.dispatches as f64
        }
    }
}

/// Split an incoming batch into the unseen, batch-deduplicated configs (in
/// first-occurrence order), updating the dedup counters — the single dedup
/// definition shared by the plain and pooled evaluators so sequential and
/// pooled runs issue identical scoring work.
fn dedup_pending(
    cache: &HashMap<Config, f32>,
    configs: &[Config],
    stats: &mut EvalBatchStats,
) -> Vec<Config> {
    stats.requested += configs.len() as u64;
    let mut pending: Vec<Config> = Vec::new();
    for c in configs {
        if cache.contains_key(c) {
            stats.cache_hits += 1;
        } else if pending.contains(c) {
            stats.dup_hits += 1;
        } else {
            pending.push(c.clone());
        }
    }
    pending
}

/// True-evaluation interface the search loop drives.  Implemented by the
/// PJRT-backed proxy evaluator and by synthetic evaluators in tests.
pub trait ConfigEvaluator {
    /// Mean calibration JSD of an assembled configuration (lower = better).
    fn eval_jsd(&mut self, config: &Config) -> Result<f32>;

    /// Evaluate a batch of configurations, returning JSDs in input order.
    ///
    /// The default runs sequentially; the production evaluators override it
    /// to dedup the batch and dispatch scorer-sized chunks (pool-backed ones
    /// additionally fan chunks out across worker shards).  Implementations
    /// must be deterministic per configuration so results are bit-identical
    /// regardless of batching or worker count.
    fn eval_jsd_batch(&mut self, configs: &[Config]) -> Result<Vec<f32>> {
        configs.iter().map(|c| self.eval_jsd(c)).collect()
    }

    /// Number of true evaluations performed so far.
    fn count(&self) -> usize;

    /// Dispatch/dedup accounting, when the evaluator tracks it.
    fn batch_stats(&self) -> Option<EvalBatchStats> {
        None
    }
}

/// Mean fused-scorer JSD of an assembled configuration over a batch set —
/// the single definition of the search's true-evaluation quantity, shared
/// by the in-thread [`ProxyEvaluator`] and the pool shards so their results
/// are bit-identical by construction.
pub fn mean_jsd(proxy: &DeviceProxy, batches: &[ScoreBatch], config: &Config) -> Result<f32> {
    Ok(mean_jsd_batch(proxy, batches, std::slice::from_ref(config))?[0])
}

/// Mean fused-scorer JSD of a *chunk* of configurations, in input order.
///
/// The chunk's dispatch resources are resolved **once, above the
/// calibration-batch loop**, then reused for every batch:
///
///  * *lane-stacked* (lane artifact present, chunk > 1 candidate — the
///    shared [`lane_routed`] predicate): [`DeviceProxy::plan_lane_chunk`]
///    resolves each group's slabs through the bank's [`LaneSlabCache`]
///    (packed from borrowed bank pieces on a miss), and every batch
///    dispatches the same pinned plan ([`Runtime::scores_lane_chunk`]) —
///    slab uploads scale with *distinct slabs per search*, never with
///    batches, even under a tiny cache budget;
///  * *per-candidate*: candidates are assembled once (pointer-chasing into
///    the resident bank) and each batch is scored through
///    [`Runtime::scores_chunk`] (static scorer args resolved once per
///    batch per chunk) — zero uploads as before.
///
/// The per-candidate accumulation order matches the single-candidate path,
/// so results are bit-identical to calling [`mean_jsd`] per config.
pub fn mean_jsd_batch(
    proxy: &DeviceProxy,
    batches: &[ScoreBatch],
    configs: &[Config],
) -> Result<Vec<f32>> {
    if configs.is_empty() {
        return Ok(Vec::new());
    }
    let rt = proxy.runtime();
    let mut sums = vec![0.0f64; configs.len()];
    if lane_routed(configs.len(), rt.scorer_variant().lanes()) {
        let plan = proxy.plan_lane_chunk(configs)?;
        for b in batches {
            let scored = rt.scores_lane_chunk(b, &plan)?;
            for (sum, (jsd, _ce)) in sums.iter_mut().zip(scored) {
                *sum += jsd as f64;
            }
        }
    } else {
        let assembled: Vec<Vec<&QuantLayerBufs>> =
            configs.iter().map(|c| proxy.assemble(c)).collect::<Result<_>>()?;
        let candidates: Vec<&[&QuantLayerBufs]> =
            assembled.iter().map(|v| v.as_slice()).collect();
        for b in batches {
            let scored = rt.scores_chunk(b, &candidates)?;
            for (sum, (jsd, _ce)) in sums.iter_mut().zip(scored) {
                *sum += jsd as f64;
            }
        }
    }
    let n = batches.len().max(1) as f64;
    Ok(sums.into_iter().map(|s| (s / n) as f32).collect())
}

/// PJRT-backed evaluator: assembles through the device proxy and runs the
/// fused scorer over the prepared calibration batches, caching results.
/// Batches are deduped and dispatched in `score_batch`-sized chunks, so
/// sequential (non-pooled) runs get the same dispatch savings as the pool.
pub struct ProxyEvaluator<'rt> {
    /// The device proxy candidates are assembled through.
    pub proxy: &'rt DeviceProxy<'rt>,
    /// Prepared calibration batches the scorer runs over.
    pub batches: &'rt [ScoreBatch],
    cache: HashMap<Config, f32>,
    evals: usize,
    /// Wall-clock spent inside `eval_jsd_batch` (dispatch + reassembly).
    pub eval_time: Duration,
    score_batch: usize,
    stats: EvalBatchStats,
}

impl<'rt> ProxyEvaluator<'rt> {
    pub fn new(proxy: &'rt DeviceProxy<'rt>, batches: &'rt [ScoreBatch]) -> Self {
        ProxyEvaluator {
            proxy,
            batches,
            cache: HashMap::new(),
            evals: 0,
            eval_time: Duration::ZERO,
            score_batch: 1,
            stats: EvalBatchStats { score_batch: 1, ..Default::default() },
        }
    }

    /// Set the microbatch size (`--score-batch`).  Results are identical
    /// for any value; only dispatch granularity changes.
    pub fn with_score_batch(mut self, k: usize) -> Self {
        self.score_batch = k.max(1);
        self.stats.score_batch = self.score_batch;
        self
    }
}

impl ConfigEvaluator for ProxyEvaluator<'_> {
    fn eval_jsd(&mut self, config: &Config) -> Result<f32> {
        Ok(self.eval_jsd_batch(std::slice::from_ref(config))?[0])
    }

    fn eval_jsd_batch(&mut self, configs: &[Config]) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let pending = dedup_pending(&self.cache, configs, &mut self.stats);
        for chunk in pending.chunks(self.score_batch.max(1)) {
            let jsds = mean_jsd_batch(self.proxy, self.batches, chunk)?;
            self.stats.dispatches += 1;
            for (c, jsd) in chunk.iter().zip(jsds) {
                self.evals += 1;
                self.stats.evaluated += 1;
                self.cache.insert(c.clone(), jsd);
            }
        }
        self.eval_time += t0.elapsed();
        configs
            .iter()
            .map(|c| {
                self.cache
                    .get(c)
                    .copied()
                    .ok_or_else(|| eyre::anyhow!("missing proxy eval result"))
            })
            .collect()
    }

    fn count(&self) -> usize {
        self.evals
    }

    fn batch_stats(&self) -> Option<EvalBatchStats> {
        Some(self.stats.clone())
    }
}

/// The sharded evaluation pool's wire types: a *microbatch* of owned
/// configurations in, per-candidate JSD results (input order) out.  One
/// request = one scorer dispatch on a shard.
pub type EvalPool = EvalService<Vec<Config>, Result<Vec<f32>>>;

/// Pool-backed [`ConfigEvaluator`]: dedups each candidate batch, packs it
/// into `score_batch`-sized chunks, fans the chunks out across the shards
/// of an [`EvalPool`] and reassembles replies in submission order, so the
/// archive a search produces is identical for any `(workers, score_batch)`.
///
/// The JSD cache and the true-eval counter live on the caller side (like
/// [`ProxyEvaluator`]); shards stay stateless with respect to candidates.
pub struct PooledEvaluator {
    svc: Arc<EvalPool>,
    cache: HashMap<Config, f32>,
    evals: usize,
    /// Wall-clock spent inside `eval_jsd_batch` (dispatch + reassembly).
    pub eval_time: Duration,
    score_batch: usize,
    stats: EvalBatchStats,
}

impl PooledEvaluator {
    /// Spawn a fresh pool from a *per-candidate* evaluation closure:
    /// `builder(shard)` runs on each worker thread and constructs that
    /// shard's closure there; the pool wraps it into the microbatch wire
    /// format (chunks map over the closure).
    pub fn spawn<B, F>(workers: usize, builder: B) -> Self
    where
        B: Fn(usize) -> F + Send + Sync + 'static,
        F: FnMut(Config) -> Result<f32> + 'static,
    {
        Self::from_service(Arc::new(EvalService::spawn_sharded(workers, move |shard| {
            let mut eval = builder(shard);
            move |chunk: Vec<Config>| -> Result<Vec<f32>> {
                chunk.into_iter().map(&mut eval).collect()
            }
        })))
    }

    /// Wrap an existing (possibly shared) pool.  Each wrapper gets its own
    /// cache/counters; the underlying shards are reused across searches.
    pub fn from_service(svc: Arc<EvalPool>) -> Self {
        PooledEvaluator {
            svc,
            cache: HashMap::new(),
            evals: 0,
            eval_time: Duration::ZERO,
            score_batch: 1,
            stats: EvalBatchStats { score_batch: 1, ..Default::default() },
        }
    }

    /// Set the microbatch size (`--score-batch`).  Results are identical
    /// for any value; only dispatch granularity changes.
    pub fn with_score_batch(mut self, k: usize) -> Self {
        self.score_batch = k.max(1);
        self.stats.score_batch = self.score_batch;
        self
    }

    /// Number of pool shards behind this evaluator (including retired).
    pub fn workers(&self) -> usize {
        self.svc.n_workers()
    }

    /// Shards still serving (spawned minus retired).
    pub fn live_workers(&self) -> usize {
        self.svc.live_workers()
    }

    /// Queue/latency statistics of the underlying pool.
    pub fn pool_stats(&self) -> ServiceStats {
        self.svc.stats()
    }
}

impl ConfigEvaluator for PooledEvaluator {
    fn eval_jsd(&mut self, config: &Config) -> Result<f32> {
        Ok(self.eval_jsd_batch(std::slice::from_ref(config))?[0])
    }

    fn eval_jsd_batch(&mut self, configs: &[Config]) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let pending = dedup_pending(&self.cache, configs, &mut self.stats);
        // Pack into scorer-sized chunks, fan out, then reassemble in
        // submission order (deterministic for any worker count).  The chunk
        // size is additionally capped at ceil(pending / workers) so a
        // generation smaller than k × workers still spreads across every
        // shard instead of serializing onto one — chunking is invisible in
        // the results either way.
        let workers = self.svc.n_workers().max(1);
        let k = self
            .score_batch
            .max(1)
            .min(pending.len().div_ceil(workers).max(1));
        let chunks: Vec<&[Config]> = pending.chunks(k).collect();
        let replies: Vec<_> = chunks.iter().map(|c| self.svc.submit(c.to_vec())).collect();
        for (chunk, rx) in chunks.iter().zip(replies) {
            // A shard that dies mid-chunk requeues its in-flight request
            // onto the surviving shards, so this recv only fails once the
            // *whole* pool has retired (transport loss to every remote,
            // or every local closure panicked).
            let jsds = rx.recv().map_err(|_| {
                eyre::anyhow!(
                    "evaluation pool request dropped: all {} shard(s) retired",
                    self.svc.n_workers()
                )
            })??;
            self.stats.dispatches += 1;
            eyre::ensure!(
                jsds.len() == chunk.len(),
                "pool shard returned {} results for a {}-candidate chunk",
                jsds.len(),
                chunk.len()
            );
            for (c, jsd) in chunk.iter().zip(jsds) {
                self.evals += 1;
                self.stats.evaluated += 1;
                self.cache.insert(c.clone(), jsd);
            }
        }
        self.eval_time += t0.elapsed();
        configs
            .iter()
            .map(|c| {
                self.cache
                    .get(c)
                    .copied()
                    .ok_or_else(|| eyre::anyhow!("missing pooled eval result"))
            })
            .collect()
    }

    fn count(&self) -> usize {
        self.evals
    }

    fn batch_stats(&self) -> Option<EvalBatchStats> {
        Some(self.stats.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::space::gene;
    use crate::quant::Rtn;
    use crate::tensor::Mat;

    fn toy_weight(seed: u64) -> Mat {
        let mut state = seed | 1;
        let mut w = Mat::zeros(8, 128);
        for v in &mut w.data {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *v = ((state >> 11) as f32 / (1u64 << 53) as f32 - 0.5) * 0.2;
        }
        w
    }

    fn toy_bank(methods: &[MethodId]) -> ProxyBank {
        // 2 layers x |methods| x 3 bit choices of small random weights
        let pieces = methods
            .iter()
            .map(|m| {
                let q = m.build();
                (0..2u64)
                    .map(|i| {
                        let w = toy_weight(i + 1);
                        vec![
                            q.quantize(&w, 2, 128, None),
                            q.quantize(&w, 3, 128, None),
                            q.quantize(&w, 4, 128, None),
                        ]
                    })
                    .collect()
            })
            .collect();
        ProxyBank::from_parts(methods.to_vec(), vec![2, 3, 4], pieces).unwrap()
    }

    #[test]
    fn assemble_picks_right_bits() {
        let bank = toy_bank(&[MethodId::Rtn]);
        let asm = bank.assemble(&[gene(MethodId::Rtn, 2), gene(MethodId::Rtn, 4)]).unwrap();
        assert_eq!(asm[0].bits, 2);
        assert_eq!(asm[1].bits, 4);
        let asm = bank.assemble(&[gene(MethodId::Rtn, 3), gene(MethodId::Rtn, 3)]).unwrap();
        assert_eq!(asm[0].bits, 3);
        assert_eq!(asm[1].bits, 3);
    }

    #[test]
    fn assemble_picks_right_method() {
        let bank = toy_bank(&[MethodId::Hqq, MethodId::Rtn]);
        let cfg = vec![gene(MethodId::Rtn, 3), gene(MethodId::Hqq, 2)];
        let asm = bank.assemble(&cfg).unwrap();
        assert_eq!(asm[0].codes, bank.piece(0, gene(MethodId::Rtn, 3)).unwrap().codes);
        assert_eq!(asm[1].codes, bank.piece(1, gene(MethodId::Hqq, 2)).unwrap().codes);
        // HQQ refines the RTN start, so 2-bit pieces of the two methods
        // genuinely differ on random weights
        let h = bank.piece(0, gene(MethodId::Hqq, 2)).unwrap();
        let r = bank.piece(0, gene(MethodId::Rtn, 2)).unwrap();
        assert_eq!((h.bits, r.bits), (2, 2));
        assert_ne!(h.codes, r.codes, "methods must produce distinct pieces");
    }

    #[test]
    fn assemble_rejects_unknown_bits() {
        let bank = toy_bank(&[MethodId::Rtn]);
        let err = bank
            .assemble(&[gene(MethodId::Rtn, 5), gene(MethodId::Rtn, 3)])
            .unwrap_err();
        assert!(format!("{err}").contains("bit width 5"), "{err}");
    }

    #[test]
    fn assemble_rejects_unknown_method() {
        let bank = toy_bank(&[MethodId::Rtn]);
        let err = bank
            .assemble(&[gene(MethodId::Hqq, 3), gene(MethodId::Rtn, 3)])
            .unwrap_err();
        assert!(format!("{err}").contains("not precomputed"), "{err}");
    }

    #[test]
    fn assemble_rejects_invalid_method_byte() {
        // a garbage method byte (0x0F) — the corrupt-archive / malicious
        // wire-chunk case — must fail the request, not panic the process
        let bank = toy_bank(&[MethodId::Rtn]);
        let err = bank.assemble(&[0x0F03, gene(MethodId::Rtn, 3)]).unwrap_err();
        assert!(format!("{err}").contains("invalid method byte"), "{err}");
    }

    #[test]
    fn assembly_equals_direct_quantization() {
        // the proxy invariant: assembling precomputed pieces is *identical*
        // to quantizing the model at that configuration directly
        let bank = toy_bank(&[MethodId::Rtn]);
        let asm = bank.assemble(&[gene(MethodId::Rtn, 2), gene(MethodId::Rtn, 3)]).unwrap();
        let w0 = toy_weight(1);
        let w1 = toy_weight(2);
        assert_eq!(asm[0].codes, Rtn.quantize(&w0, 2, 128, None).codes);
        assert_eq!(asm[1].codes, Rtn.quantize(&w1, 3, 128, None).codes);
    }

    #[test]
    fn bank_reports_per_method_stats() {
        let bank = toy_bank(&[MethodId::Hqq, MethodId::Rtn]);
        assert_eq!(bank.stats.len(), 2);
        assert_eq!(bank.n_layers(), 2);
        for s in &bank.stats {
            // 2 layers x 3 bit choices of 8x128 weights each
            let expect: usize = (0..2)
                .flat_map(|li| {
                    [2u8, 3, 4]
                        .map(|b| bank.piece(li, gene(s.method, b)).unwrap().memory_bytes())
                })
                .sum();
            assert_eq!(s.memory_bytes, expect);
            assert!(s.memory_bytes > 0);
        }
        assert_eq!(bank.memory_bytes(), bank.stats.iter().map(|s| s.memory_bytes).sum::<usize>());
    }

    /// Deterministic synthetic shard eval: quadratic bit penalty, plus a
    /// per-candidate seeded perturbation (the RNG is derived from the
    /// payload, never from shard state — the pool's determinism contract).
    fn synth_pool(workers: usize) -> PooledEvaluator {
        PooledEvaluator::spawn(workers, |_shard| {
            |cfg: Config| -> Result<f32> {
                let mut seed = 0xA076_1D64_78BD_642Fu64;
                for &g in &cfg {
                    seed = seed.wrapping_mul(0x100000001B3).wrapping_add(g as u64);
                }
                let mut rng = crate::util::Rng::new(seed);
                let base: f32 =
                    cfg.iter().map(|&g| ((4 - gene_bits(g) as i32) as f32).powi(2)).sum();
                Ok(base + rng.f32() * 1e-3)
            }
        })
    }

    #[test]
    fn pooled_evaluator_caches_and_counts() {
        let mut ev = synth_pool(2);
        let a = ev.eval_jsd(&vec![2, 3, 4]).unwrap();
        let b = ev.eval_jsd(&vec![2, 3, 4]).unwrap();
        assert_eq!(a, b);
        assert_eq!(ev.count(), 1, "cache hit must not re-evaluate");
        let out = ev
            .eval_jsd_batch(&[vec![2, 3, 4], vec![4, 4, 4], vec![2, 3, 4]])
            .unwrap();
        assert_eq!(out[0], a);
        assert_eq!(out[2], a);
        assert_eq!(ev.count(), 2, "batch dedups against cache and itself");
    }

    #[test]
    fn pooled_evaluator_bit_identical_across_worker_counts() {
        let configs: Vec<Config> = (0..24)
            .map(|i| (0..6).map(|j| [2u16, 3, 4][(i + j) % 3]).collect())
            .collect();
        let mut one = synth_pool(1);
        let mut four = synth_pool(4);
        let a = one.eval_jsd_batch(&configs).unwrap();
        let b = four.eval_jsd_batch(&configs).unwrap();
        assert_eq!(a, b, "results must not depend on worker count");
    }

    #[test]
    fn pooled_evaluator_surfaces_shard_errors() {
        let mut ev = PooledEvaluator::spawn(2, |_shard| {
            |cfg: Config| -> Result<f32> {
                eyre::ensure!(cfg.len() == 3, "bad config length {}", cfg.len());
                Ok(1.0)
            }
        });
        assert!(ev.eval_jsd(&vec![2, 3, 4]).is_ok());
        assert!(ev.eval_jsd(&vec![2, 3]).is_err());
        assert_eq!(ev.count(), 1, "failed evals are not counted or cached");
    }

    #[test]
    fn score_batch_chunking_is_invisible_in_results() {
        // identical inputs through k=1 and k=8 must give identical outputs
        // and identical eval counts; only the dispatch count changes
        let configs: Vec<Config> = (0..24)
            .map(|i| (0..5).map(|j| [2u16, 3, 4][(i + 2 * j) % 3]).collect())
            .collect();
        let mut k1 = synth_pool(2);
        // workers = 1 so the dispatch count is exactly ceil(evaluated / 8)
        // (with more workers, chunks are further split to keep shards busy)
        let mut k8 = synth_pool(1).with_score_batch(8);
        let a = k1.eval_jsd_batch(&configs).unwrap();
        let b = k8.eval_jsd_batch(&configs).unwrap();
        assert_eq!(a, b, "score-batch size must not change results");
        assert_eq!(k1.count(), k8.count());
        let (s1, s8) = (k1.batch_stats().unwrap(), k8.batch_stats().unwrap());
        assert_eq!(s1.evaluated, s8.evaluated);
        assert!(
            s8.dispatches < s1.dispatches,
            "k=8 must dispatch fewer chunks ({} vs {})",
            s8.dispatches,
            s1.dispatches
        );
        assert_eq!(s8.dispatches, (s8.evaluated as usize).div_ceil(8) as u64);
        assert!(s8.dispatch_reduction() > s1.dispatch_reduction());
    }

    #[test]
    fn dedup_stats_count_cache_and_batch_duplicates() {
        let mut ev = synth_pool(1).with_score_batch(4);
        // 3 unique configs, one repeated twice within the batch
        let batch = vec![
            vec![2u16, 3, 4],
            vec![3, 3, 3],
            vec![2, 3, 4],
            vec![4, 4, 4],
        ];
        ev.eval_jsd_batch(&batch).unwrap();
        let s = ev.batch_stats().unwrap();
        assert_eq!(s.requested, 4);
        assert_eq!(s.dup_hits, 1);
        assert_eq!(s.cache_hits, 0);
        assert_eq!(s.evaluated, 3);
        assert_eq!(s.dispatches, 1, "3 unique configs fit one k=4 chunk");
        // resubmitting the same batch is pure cache traffic
        ev.eval_jsd_batch(&batch).unwrap();
        let s = ev.batch_stats().unwrap();
        assert_eq!(s.requested, 8);
        assert_eq!(s.cache_hits, 4);
        assert_eq!(s.evaluated, 3);
        assert_eq!(s.dispatches, 1, "no new dispatch for an all-cached batch");
        assert!((s.dedup_fraction() - 5.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn bank_share_stats_count_shared_banks_once() {
        // 4 shards referencing one Arc'd bank: referenced = 4x, resident = 1x
        let bank = Arc::new(toy_bank(&[MethodId::Hqq]));
        let bytes = bank.memory_bytes();
        assert!(bytes > 0);
        let shards: Vec<Arc<ProxyBank>> = (0..4).map(|_| bank.clone()).collect();
        let s = BankShareStats::from_shard_banks(&shards);
        assert_eq!(s.shards, 4);
        assert_eq!(s.referenced_bytes, 4 * bytes);
        assert_eq!(s.resident_bytes, bytes, "shared bank bytes must be counted once");
        // two *distinct* banks genuinely add up
        let other = Arc::new(toy_bank(&[MethodId::Rtn]));
        let mixed = vec![bank.clone(), bank.clone(), other.clone()];
        let s = BankShareStats::from_shard_banks(&mixed);
        assert_eq!(s.resident_bytes, bytes + other.memory_bytes());
        assert_eq!(s.referenced_bytes, 2 * bytes + other.memory_bytes());
    }

    #[test]
    fn bank_share_stats_fold_in_slab_cache_bytes() {
        // the residency report must cover every live buffer the scoring
        // path holds: bank pieces once + the resident packed lane slabs
        let bank = Arc::new(toy_bank(&[MethodId::Hqq]));
        let bytes = bank.memory_bytes();
        let shards: Vec<Arc<ProxyBank>> = (0..2).map(|_| bank.clone()).collect();
        let s = BankShareStats::from_shard_banks(&shards);
        assert_eq!(s.slab_cache_bytes, 0, "nothing folded in by default");
        assert_eq!(s.total_resident_bytes(), bytes);
        let s = s.with_slab_cache_bytes(1234);
        assert_eq!(s.slab_cache_bytes, 1234);
        assert_eq!(s.resident_bytes, bytes, "bank residency unchanged");
        assert_eq!(s.total_resident_bytes(), bytes + 1234);
    }
}

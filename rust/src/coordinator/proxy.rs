//! Quantization proxy (§3.3): every searchable layer is quantized once per
//! bit-width with the activation-independent proxy quantizer (HQQ); any
//! candidate configuration is then *assembled* by picking the precomputed
//! (layer, bits) pieces.  The pieces are also uploaded to the PJRT device
//! once, so assembly costs zero host->device copies on the search hot path.

use super::space::Config;
use crate::data::Manifest;
use crate::model::{HessianStore, WeightStore};
use crate::quant::{QuantizedLinear, Quantizer};
use crate::runtime::{QuantLayerBufs, Runtime, ScoreBatch};
use crate::Result;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Host-side precomputed quantizations: (layer index, bits) -> layer.
pub struct ProxyStore {
    pub quantizer_name: &'static str,
    pub bit_choices: Vec<u8>,
    /// `layers[li][bi]` for bit_choices[bi].
    pub layers: Vec<Vec<QuantizedLinear>>,
    pub build_time: Duration,
}

impl ProxyStore {
    /// Quantize every layer at every candidate bit-width.
    pub fn build(
        manifest: &Manifest,
        weights: &WeightStore,
        hessians: Option<&HessianStore>,
        quantizer: &dyn Quantizer,
    ) -> Result<ProxyStore> {
        let t0 = Instant::now();
        let mut layers = Vec::with_capacity(manifest.layers.len());
        for l in &manifest.layers {
            let w = weights.linear(&l.name)?;
            let stats = match hessians {
                Some(h) => Some(h.for_layer(&l.name)?),
                None => None,
            };
            let mut per_bits = Vec::with_capacity(manifest.bit_choices.len());
            for &bits in &manifest.bit_choices {
                per_bits.push(quantizer.quantize(&w, bits, manifest.group_size, stats));
            }
            layers.push(per_bits);
        }
        Ok(ProxyStore {
            quantizer_name: quantizer.name(),
            bit_choices: manifest.bit_choices.clone(),
            layers,
            build_time: t0.elapsed(),
        })
    }

    fn bit_index(&self, bits: u8) -> usize {
        self.bit_choices
            .iter()
            .position(|&b| b == bits)
            .unwrap_or_else(|| panic!("bit width {bits} not precomputed"))
    }

    /// Host-side assembly (for tests / CPU paths).
    pub fn assemble(&self, config: &Config) -> Vec<&QuantizedLinear> {
        config
            .iter()
            .enumerate()
            .map(|(li, &b)| &self.layers[li][self.bit_index(b)])
            .collect()
    }
}

/// Device-side proxy: all pieces uploaded once; assembly picks buffer refs.
pub struct DeviceProxy<'rt> {
    pub store: ProxyStore,
    bufs: Vec<Vec<QuantLayerBufs>>,
    rt: &'rt Runtime,
    pub upload_time: Duration,
}

impl<'rt> DeviceProxy<'rt> {
    pub fn new(rt: &'rt Runtime, store: ProxyStore) -> Result<DeviceProxy<'rt>> {
        let t0 = Instant::now();
        let mut bufs = Vec::with_capacity(store.layers.len());
        for per_bits in &store.layers {
            let mut row = Vec::with_capacity(per_bits.len());
            for q in per_bits {
                row.push(rt.upload_quant_layer(q)?);
            }
            bufs.push(row);
        }
        Ok(DeviceProxy { store, bufs, rt, upload_time: t0.elapsed() })
    }

    /// Zero-copy assembly of a configuration into buffer references.
    pub fn assemble(&self, config: &Config) -> Vec<&QuantLayerBufs> {
        config
            .iter()
            .enumerate()
            .map(|(li, &b)| &self.bufs[li][self.store.bit_index(b)])
            .collect()
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
}

/// True-evaluation interface the search loop drives.  Implemented by the
/// PJRT-backed proxy evaluator and by synthetic evaluators in tests.
pub trait ConfigEvaluator {
    /// Mean calibration JSD of an assembled configuration (lower = better).
    fn eval_jsd(&mut self, config: &Config) -> Result<f32>;

    /// Number of true evaluations performed so far.
    fn count(&self) -> usize;
}

/// PJRT-backed evaluator: assembles through the device proxy and runs the
/// fused scorer over the prepared calibration batches, caching results.
pub struct ProxyEvaluator<'rt> {
    pub proxy: &'rt DeviceProxy<'rt>,
    pub batches: &'rt [ScoreBatch],
    cache: HashMap<Config, f32>,
    evals: usize,
    pub eval_time: Duration,
}

impl<'rt> ProxyEvaluator<'rt> {
    pub fn new(proxy: &'rt DeviceProxy<'rt>, batches: &'rt [ScoreBatch]) -> Self {
        ProxyEvaluator {
            proxy,
            batches,
            cache: HashMap::new(),
            evals: 0,
            eval_time: Duration::ZERO,
        }
    }
}

impl ConfigEvaluator for ProxyEvaluator<'_> {
    fn eval_jsd(&mut self, config: &Config) -> Result<f32> {
        if let Some(&v) = self.cache.get(config) {
            return Ok(v);
        }
        let t0 = Instant::now();
        let layers = self.proxy.assemble(config);
        let mut sum = 0.0f64;
        for b in self.batches {
            let (jsd, _ce) = self.proxy.runtime().scores(b, &layers)?;
            sum += jsd as f64;
        }
        let jsd = (sum / self.batches.len().max(1) as f64) as f32;
        self.evals += 1;
        self.eval_time += t0.elapsed();
        self.cache.insert(config.clone(), jsd);
        Ok(jsd)
    }

    fn count(&self) -> usize {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Rtn;
    use crate::tensor::Mat;

    fn toy_store() -> ProxyStore {
        // 2 layers x 3 bit choices of small random weights
        let mk = |seed: u64| {
            let mut state = seed | 1;
            let mut w = Mat::zeros(8, 128);
            for v in &mut w.data {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                *v = ((state >> 11) as f32 / (1u64 << 53) as f32 - 0.5) * 0.2;
            }
            w
        };
        let rtn = Rtn;
        let layers = (0..2)
            .map(|i| {
                let w = mk(i + 1);
                vec![
                    rtn.quantize(&w, 2, 128, None),
                    rtn.quantize(&w, 3, 128, None),
                    rtn.quantize(&w, 4, 128, None),
                ]
            })
            .collect();
        ProxyStore {
            quantizer_name: "rtn",
            bit_choices: vec![2, 3, 4],
            layers,
            build_time: Duration::ZERO,
        }
    }

    #[test]
    fn assemble_picks_right_bits() {
        let store = toy_store();
        let asm = store.assemble(&vec![2, 4]);
        assert_eq!(asm[0].bits, 2);
        assert_eq!(asm[1].bits, 4);
        let asm = store.assemble(&vec![3, 3]);
        assert_eq!(asm[0].bits, 3);
        assert_eq!(asm[1].bits, 3);
    }

    #[test]
    #[should_panic]
    fn assemble_rejects_unknown_bits() {
        let store = toy_store();
        store.assemble(&vec![5, 3]);
    }

    #[test]
    fn assembly_equals_direct_quantization() {
        // the proxy invariant: assembling precomputed pieces is *identical*
        // to quantizing the model at that configuration directly
        let store = toy_store();
        let asm = store.assemble(&vec![2, 3]);
        assert_eq!(asm[0].codes, store.layers[0][0].codes);
        assert_eq!(asm[1].codes, store.layers[1][1].codes);
    }
}

//! Search-space pruning via prior knowledge (§3.2, Table 5): layers whose
//! single-layer low-bit sensitivity exceeds `threshold x median` are
//! outliers and get pinned to the highest bit-width.

use super::sensitivity::Sensitivity;
use super::space::SearchSpace;
use crate::tensor::median;

#[derive(Clone, Debug)]
pub struct PruneReport {
    /// Indices of outlier layers (pinned to max bits).
    pub outliers: Vec<usize>,
    pub threshold: f32,
    pub median: f32,
    /// Fraction of layers excluded.
    pub excluded_frac: f32,
}

/// Apply the threshold-x-median rule (2x by default; Table 5 ablates).
/// Mutates `space` by pinning outlier layers to their max bit-width.
///
/// The paper stresses the criterion must stay *conservative* ("overly
/// aggressive pruning risks eliminating promising candidates"); on LLMs it
/// excludes 0.45-2.14% of layers.  Our subject model's sensitivity tail is
/// relatively heavier, so we enforce conservatism explicitly: at most
/// `MAX_EXCLUDED_FRAC` of layers (the most sensitive ones) are pinned,
/// which also keeps the low-bits end of the frontier reachable.
pub const MAX_EXCLUDED_FRAC: f32 = 0.08;

pub fn prune(
    space: &mut SearchSpace,
    sensitivity: &Sensitivity,
    threshold_x_median: f32,
) -> PruneReport {
    let scores = sensitivity.scores();
    let med = median(&scores);
    let cut = threshold_x_median * med;
    let mut over: Vec<usize> = (0..scores.len())
        .filter(|&li| med > 0.0 && scores[li] > cut)
        .collect();
    // conservatism cap: keep only the most sensitive offenders
    over.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let cap = ((scores.len() as f32 * MAX_EXCLUDED_FRAC).floor() as usize).max(1);
    over.truncate(cap);
    over.sort();
    for &li in &over {
        let max_gene = space.max_gene(li);
        space.pin(li, max_gene);
    }
    PruneReport {
        excluded_frac: over.len() as f32 / scores.len() as f32,
        outliers: over,
        threshold: threshold_x_median,
        median: med,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::space::toy_space;

    fn sens(scores: Vec<f32>) -> Sensitivity {
        Sensitivity { jsd: scores, baseline: 0.0 }
    }

    #[test]
    fn pins_only_outliers() {
        let mut space = toy_space(6);
        // median of [1,1,1,1,1,10] = 1; threshold 2 -> only idx 5 pruned
        let s = sens(vec![1.0, 1.0, 1.0, 1.0, 1.0, 10.0]);
        let rep = prune(&mut space, &s, 2.0);
        assert_eq!(rep.outliers, vec![5]);
        assert_eq!(space.choices[5], vec![4]);
        assert_eq!(space.active_layers().len(), 5);
        assert!((rep.excluded_frac - 1.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn stricter_threshold_prunes_more() {
        let scores: Vec<f32> = (0..40)
            .map(|i| if i % 10 == 0 { 5.0 + i as f32 } else { 1.0 })
            .collect();
        let mut s1 = toy_space(40);
        let r1 = prune(&mut s1, &sens(scores.clone()), 1.5);
        let mut s2 = toy_space(40);
        let r2 = prune(&mut s2, &sens(scores), 40.0);
        assert!(r1.outliers.len() >= r2.outliers.len());
    }

    #[test]
    fn exclusion_cap_enforced() {
        // 6 of 28 layers exceed the cut, but only the cap-many most
        // sensitive are pinned (paper: exclusion stays ~1-2%)
        let scores: Vec<f32> = (0..28)
            .map(|i| if (14..20).contains(&i) { 100.0 + i as f32 } else { 1.0 })
            .collect();
        let mut space = toy_space(28);
        let rep = prune(&mut space, &sens(scores), 2.0);
        let cap = ((28.0f32 * MAX_EXCLUDED_FRAC).floor() as usize).max(1);
        assert_eq!(rep.outliers.len(), cap);
        // the pinned ones are the MOST sensitive (highest indices 18, 19)
        assert!(rep.outliers.contains(&19));
    }

    #[test]
    fn no_outliers_when_flat() {
        let mut space = toy_space(4);
        let rep = prune(&mut space, &sens(vec![1.0; 4]), 2.0);
        assert!(rep.outliers.is_empty());
        assert_eq!(space.active_layers().len(), 4);
    }

    #[test]
    fn conservative_rule_is_small_fraction() {
        // paper: 0.45%-2.14% of layers excluded; our Fig-2 analog shows a
        // >10x spread, so with 2x median only the tail should be pinned
        let mut space = toy_space(28);
        let mut scores: Vec<f32> = (0..28).map(|i| 1.0 + 0.05 * i as f32).collect();
        scores[3] = 9.0;
        scores[21] = 12.0;
        let rep = prune(&mut space, &sens(scores), 2.0);
        assert_eq!(rep.outliers, vec![3, 21]);
        assert!(rep.excluded_frac <= MAX_EXCLUDED_FRAC + 1e-6);
    }
}

//! Iterative search-and-update (§3.5, Algorithm 1): random init -> train
//! predictor -> NSGA-II on (predicted JSD, avg bits) -> true-evaluate the
//! most promising unseen candidates -> update archive -> repeat.

use super::archive::{Archive, Sample};
use super::nsga2::{self, Nsga2Params};
use super::predictor::{self, PredictorKind};
use super::proxy::ConfigEvaluator;
use super::space::{Config, SearchSpace};
use crate::util::Rng;
use crate::Result;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct SearchParams {
    /// Initial random samples (paper "Pretraining Data", Table 6).
    pub n_init: usize,
    /// Outer search-and-update iterations.
    pub iterations: usize,
    /// Candidates truly evaluated per iteration (paper "NSGA-II Candidate").
    pub candidates_per_iter: usize,
    pub nsga: Nsga2Params,
    pub predictor: PredictorKind,
    pub seed: u64,
    /// UCB exploration weight κ for the candidate screen.  0.0 (the
    /// default) keeps the classic point-estimate screen — and with it every
    /// existing archive hash; κ > 0 admits unseen individuals whose
    /// optimistic bound `mean − κ·std` beats the generation floor, so
    /// high-variance explorers survive when the predictor reports
    /// uncertainty (`--predictor gp`).
    pub ucb_kappa: f64,
}

impl Default for SearchParams {
    fn default() -> Self {
        // "repro" preset: Table 6 scaled to the 28-layer subject model and
        // the single-core testbed (see DESIGN.md §5); the paper-scale preset
        // lives in `SearchParams::paper()`.
        SearchParams {
            n_init: 64,
            iterations: 25,
            candidates_per_iter: 12,
            nsga: Nsga2Params {
                pop_size: 100,
                generations: 15,
                crossover_prob: 0.9,
                mutation_prob: 0.1,
            },
            predictor: PredictorKind::Rbf,
            seed: 0,
            ucb_kappa: 0.0,
        }
    }
}

impl SearchParams {
    /// Paper Table 6 values (7B column).
    pub fn paper() -> SearchParams {
        SearchParams {
            n_init: 250,
            iterations: 200,
            candidates_per_iter: 50,
            nsga: Nsga2Params::default(),
            predictor: PredictorKind::Rbf,
            seed: 0,
            ucb_kappa: 0.0,
        }
    }

    /// Tiny preset for smoke tests / quickstart.
    pub fn smoke() -> SearchParams {
        SearchParams {
            n_init: 24,
            iterations: 6,
            candidates_per_iter: 8,
            nsga: Nsga2Params {
                pop_size: 48,
                generations: 8,
                crossover_prob: 0.9,
                mutation_prob: 0.1,
            },
            predictor: PredictorKind::Rbf,
            seed: 0,
            ucb_kappa: 0.0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct IterStat {
    pub iteration: usize,
    pub archive_size: usize,
    pub new_evals: usize,
    /// Best true JSD near each probe bit-width (for Fig. 11-style curves).
    pub frontier_probe: Vec<(f64, f32)>,
    pub elapsed: Duration,
}

pub struct SearchResult {
    pub archive: Archive,
    pub history: Vec<IterStat>,
    pub true_evals: usize,
    pub predictor_queries: usize,
    pub total_time: Duration,
}

/// Probe bit-widths for history tracking.
const PROBES: [f64; 4] = [2.5, 3.0, 3.5, 4.0];

fn frontier_probe(_space: &SearchSpace, archive: &Archive) -> Vec<(f64, f32)> {
    PROBES
        .iter()
        .map(|&b| {
            let best = archive
                .samples
                .iter()
                .filter(|s| s.avg_bits <= b + 0.005)
                .map(|s| s.jsd)
                .fold(f32::INFINITY, f32::min);
            (b, best)
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|(b, j)| (b, if j.is_finite() { j } else { f32::NAN }))
        .collect()
}

/// Run Algorithm 1.  `evaluator` supplies true JSD scores (proxy-assembled
/// PJRT scorer in production; synthetic functions in tests).
pub fn run_search(
    space: &SearchSpace,
    evaluator: &mut dyn ConfigEvaluator,
    params: &SearchParams,
) -> Result<SearchResult> {
    run_search_seeded(space, evaluator, params, &[])
}

/// [`run_search`] warm-started from already-evaluated samples (a persisted
/// archive from a related run — see `coordinator::warmstart`).  Valid seeds
/// are inserted before the random init, so they count toward `n_init`, seed
/// the predictor's training set, and are never re-evaluated; seeds outside
/// `space` (stale or corrupt entries) are skipped, not fatal.  With an
/// empty seed slice this is exactly `run_search`.
pub fn run_search_seeded(
    space: &SearchSpace,
    evaluator: &mut dyn ConfigEvaluator,
    params: &SearchParams,
    seed_samples: &[Sample],
) -> Result<SearchResult> {
    let t_start = Instant::now();
    let mut rng = Rng::new(params.seed);
    let mut archive = Archive::new();
    let active = space.active_layers();
    let mut predictor_queries = 0usize;

    for s in seed_samples {
        if !space.contains(&s.config) {
            continue;
        }
        archive.insert(s.config.clone(), s.jsd, space.avg_bits(&s.config));
    }

    // -- initial sampling, spread across the bits range ------------------
    // Candidates are drawn in chunks and true-evaluated through
    // `eval_jsd_batch`, which pool-backed evaluators fan out across worker
    // shards.  The RNG stream and the archive contents depend only on the
    // chunk boundaries, never on how a chunk was scheduled, so the result
    // is identical for any worker count.
    let lo = space.avg_bits(&space.min_config());
    let hi = space.avg_bits(&space.max_config());
    let chunk_size = params.candidates_per_iter.max(1);
    let mut tries = 0;
    while archive.len() < params.n_init && tries < params.n_init * 50 {
        let want = (params.n_init - archive.len()).min(chunk_size);
        let mut chunk: Vec<Config> = Vec::with_capacity(want);
        while chunk.len() < want && tries < params.n_init * 50 {
            tries += 1;
            let target = lo + (hi - lo) * rng.f64();
            let cfg = space.random_near(&mut rng, target, 0.05);
            if archive.contains(&cfg) || chunk.contains(&cfg) {
                continue;
            }
            chunk.push(cfg);
        }
        let jsds = evaluator.eval_jsd_batch(&chunk)?;
        eyre::ensure!(
            jsds.len() == chunk.len(),
            "evaluator returned {} results for {} candidates",
            jsds.len(),
            chunk.len()
        );
        for (cfg, jsd) in chunk.into_iter().zip(jsds) {
            let bits = space.avg_bits(&cfg);
            archive.insert(cfg, jsd, bits);
        }
    }

    let mut history = Vec::new();

    // -- iterative search-and-update --------------------------------------
    for it in 0..params.iterations {
        let t_it = Instant::now();
        // (re)train predictor on the full archive
        let xs: Vec<Vec<f32>> = archive
            .samples
            .iter()
            .map(|s| space.features(&s.config, &active))
            .collect();
        let ys: Vec<f32> = archive.samples.iter().map(|s| s.jsd).collect();
        let mut pred = predictor::make(params.predictor, params.seed ^ it as u64);
        pred.fit(&xs, &ys);

        // NSGA-II against the predictor, seeded with the current front.
        // The batched objective scores a whole generation of offspring at
        // once (per-individual fan-out when the predictor is remote/pooled).
        let seed_pop: Vec<Config> = archive
            .pareto_front()
            .into_iter()
            .map(|i| archive.samples[i].config.clone())
            .collect();
        let mut queries = 0usize;
        let pop = nsga2::run_batched(space, seed_pop, &params.nsga, &mut rng, |cfgs| {
            queries += cfgs.len();
            cfgs.iter()
                .map(|cfg| {
                    [
                        pred.predict(&space.features(cfg, &active)) as f64,
                        space.avg_bits(cfg),
                    ]
                })
                .collect()
        });
        predictor_queries += queries;

        // candidate subset: unseen rank-0 individuals, spread over bits.
        // With κ > 0 the screen is uncertainty-aware: a dominated
        // individual survives if its optimistic bound mean − κ·std still
        // beats the worst predicted JSD on rank 0 (the generation floor),
        // so high-variance explorers are not killed by a pessimistic
        // point estimate.  κ = 0 short-circuits before any extra
        // predictor query, leaving the classic screen — and every
        // existing archive hash — untouched.
        let floor = pop
            .iter()
            .filter(|i| i.rank == 0)
            .map(|i| i.obj[0])
            .fold(f64::NEG_INFINITY, f64::max);
        let mut cands: Vec<&nsga2::Individual> = pop
            .iter()
            .filter(|i| !archive.contains(&i.config))
            .filter(|i| {
                if i.rank == 0 {
                    return true;
                }
                if params.ucb_kappa <= 0.0 {
                    return false;
                }
                predictor_queries += 1;
                let (m, s) = pred.predict_with_std(&space.features(&i.config, &active));
                (m as f64) - params.ucb_kappa * (s as f64) <= floor
            })
            .collect();
        cands.sort_by(|a, b| a.obj[1].partial_cmp(&b.obj[1]).unwrap());
        let picked: Vec<Config> = if cands.len() <= params.candidates_per_iter {
            cands.iter().map(|i| i.config.clone()).collect()
        } else {
            // evenly spaced across the predicted front
            (0..params.candidates_per_iter)
                .map(|k| {
                    let idx = k * (cands.len() - 1) / (params.candidates_per_iter - 1).max(1);
                    cands[idx].config.clone()
                })
                .collect()
        };

        // true evaluation + archive update: the whole candidate set goes to
        // the evaluator as one batch (concurrent across pool shards), then
        // archive insertion replays the replies in submission order.
        let mut to_eval: Vec<Config> = Vec::new();
        for cfg in picked {
            if !archive.contains(&cfg) && !to_eval.contains(&cfg) {
                to_eval.push(cfg);
            }
        }
        let jsds = evaluator.eval_jsd_batch(&to_eval)?;
        eyre::ensure!(
            jsds.len() == to_eval.len(),
            "evaluator returned {} results for {} candidates",
            jsds.len(),
            to_eval.len()
        );
        let mut new_evals = 0;
        for (cfg, jsd) in to_eval.into_iter().zip(jsds) {
            let bits = space.avg_bits(&cfg);
            if archive.insert(cfg, jsd, bits) {
                new_evals += 1;
            }
        }
        // keep exploring if the predictor front collapsed (all seen): draw
        // refill chunks until quota, stopping at the first duplicate draw
        while new_evals < params.candidates_per_iter / 2 {
            let want = params.candidates_per_iter / 2 - new_evals;
            let mut chunk: Vec<Config> = Vec::with_capacity(want);
            let mut saw_duplicate = false;
            while chunk.len() < want {
                let target = lo + (hi - lo) * rng.f64();
                let cfg = space.random_near(&mut rng, target, 0.05);
                if archive.contains(&cfg) || chunk.contains(&cfg) {
                    saw_duplicate = true;
                    break;
                }
                chunk.push(cfg);
            }
            let jsds = evaluator.eval_jsd_batch(&chunk)?;
            eyre::ensure!(
                jsds.len() == chunk.len(),
                "evaluator returned {} results for {} candidates",
                jsds.len(),
                chunk.len()
            );
            for (cfg, jsd) in chunk.into_iter().zip(jsds) {
                let bits = space.avg_bits(&cfg);
                if archive.insert(cfg, jsd, bits) {
                    new_evals += 1;
                }
            }
            if saw_duplicate {
                break;
            }
        }

        history.push(IterStat {
            iteration: it,
            archive_size: archive.len(),
            new_evals,
            frontier_probe: frontier_probe(space, &archive),
            elapsed: t_it.elapsed(),
        });
    }

    Ok(SearchResult {
        true_evals: evaluator.count(),
        archive,
        history,
        predictor_queries,
        total_time: t_start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::space::toy_space;

    /// Synthetic quality: weighted quadratic penalty per layer + noise-free.
    struct SynthEval {
        weights: Vec<f32>,
        evals: usize,
    }

    impl ConfigEvaluator for SynthEval {
        fn eval_jsd(&mut self, config: &Config) -> Result<f32> {
            self.evals += 1;
            Ok(config
                .iter()
                .enumerate()
                .map(|(i, &b)| self.weights[i] * ((4 - b) as f32).powi(2))
                .sum())
        }

        fn count(&self) -> usize {
            self.evals
        }
    }

    #[test]
    fn search_beats_random_at_fixed_budget() {
        let space = toy_space(16);
        // heterogeneous sensitivities: the search should learn to keep
        // heavy layers at 4 bits and drop light layers to 2
        let weights: Vec<f32> = (0..16)
            .map(|i| if i % 4 == 0 { 1.0 } else { 0.02 })
            .collect();

        let params = SearchParams {
            n_init: 40,
            iterations: 10,
            candidates_per_iter: 10,
            nsga: Nsga2Params {
                pop_size: 60,
                generations: 10,
                crossover_prob: 0.9,
                mutation_prob: 0.1,
            },
            predictor: PredictorKind::Rbf,
            seed: 3,
            ucb_kappa: 0.0,
        };
        let mut ev = SynthEval { weights: weights.clone(), evals: 0 };
        let res = run_search(&space, &mut ev, &params).unwrap();

        // same number of evals spent purely at random
        let mut rng = Rng::new(99);
        let mut rnd_ev = SynthEval { weights, evals: 0 };
        let mut best_random = f32::INFINITY;
        for _ in 0..res.true_evals {
            let cfg = space.random_near(&mut rng, 3.25, 0.05);
            let j = rnd_ev.eval_jsd(&cfg).unwrap();
            if space.avg_bits(&cfg) <= 3.25 + 0.005 {
                best_random = best_random.min(j);
            }
        }
        let best = res
            .archive
            .best_under(3.25, 0.005)
            .expect("init sampling spans the bits range, so 3.25 is populated");
        let best_search = best.jsd;
        assert!(
            best_search <= best_random,
            "search {best_search} vs random {best_random}"
        );
        // the search must discover the structure: at the 3.25 budget the
        // heavy layers should be kept high
        let heavy_bits: f32 = (0..16)
            .filter(|i| i % 4 == 0)
            .map(|i| best.config[i] as f32)
            .sum::<f32>() / 4.0;
        let light_bits: f32 = (0..16)
            .filter(|i| i % 4 != 0)
            .map(|i| best.config[i] as f32)
            .sum::<f32>() / 12.0;
        assert!(
            heavy_bits > light_bits,
            "heavy {heavy_bits} vs light {light_bits}"
        );
    }

    #[test]
    fn history_tracks_progress() {
        let space = toy_space(8);
        let mut ev = SynthEval { weights: vec![0.3; 8], evals: 0 };
        let res = run_search(&space, &mut ev, &SearchParams::smoke()).unwrap();
        assert_eq!(res.history.len(), SearchParams::smoke().iterations);
        // archive grows monotonically
        for w in res.history.windows(2) {
            assert!(w[1].archive_size >= w[0].archive_size);
        }
        assert!(res.predictor_queries > 1000, "{}", res.predictor_queries);
        assert!(res.true_evals < res.predictor_queries / 10);
    }

    #[test]
    fn deterministic_per_seed() {
        let space = toy_space(6);
        let mk = || SynthEval { weights: vec![0.1, 0.5, 0.2, 0.9, 0.05, 0.3], evals: 0 };
        let mut p = SearchParams::smoke();
        p.seed = 11;
        let a = run_search(&space, &mut mk(), &p).unwrap();
        let b = run_search(&space, &mut mk(), &p).unwrap();
        assert_eq!(a.archive.len(), b.archive.len());
        for (x, y) in a.archive.samples.iter().zip(&b.archive.samples) {
            assert_eq!(x.config, y.config);
        }
    }

    #[test]
    fn empty_under_budget_is_none_not_panic() {
        let space = toy_space(8);
        let mut ev = SynthEval { weights: vec![0.3; 8], evals: 0 };
        let res = run_search(&space, &mut ev, &SearchParams::smoke()).unwrap();
        // the toy space floors at 2 bits/layer, so nothing sits under 1.5
        assert!(res.archive.best_under(1.5, 0.005).is_none());
        assert!(res.archive.best_under(4.0, 0.005).is_some());
    }

    #[test]
    fn seeded_search_reuses_samples_without_reeval() {
        let space = toy_space(6);
        let mk = || SynthEval { weights: vec![0.1, 0.5, 0.2, 0.9, 0.05, 0.3], evals: 0 };
        let mut p = SearchParams::smoke();
        p.seed = 11;
        let cold = run_search(&space, &mut mk(), &p).unwrap();
        let seeds = cold.archive.samples.clone();
        let warm = run_search_seeded(&space, &mut mk(), &p, &seeds).unwrap();
        // every seed is adopted verbatim, in order, and never re-evaluated
        assert!(warm.archive.len() >= seeds.len());
        for (s, w) in seeds.iter().zip(&warm.archive.samples) {
            assert_eq!(s.config, w.config);
            assert_eq!(s.jsd.to_bits(), w.jsd.to_bits());
        }
        assert_eq!(warm.true_evals, warm.archive.len() - seeds.len());
        // warm-started runs are deterministic too
        let warm2 = run_search_seeded(&space, &mut mk(), &p, &seeds).unwrap();
        assert_eq!(warm.archive.content_hash(), warm2.archive.content_hash());
    }

    #[test]
    fn invalid_seed_samples_are_skipped() {
        let space = toy_space(4);
        let mk = || SynthEval { weights: vec![0.2; 4], evals: 0 };
        let mut p = SearchParams::smoke();
        p.seed = 5;
        let bad = vec![
            // corrupt method byte (no MethodId has index 0x0F)
            Sample { config: vec![0x0F03, 2, 3, 4], jsd: 0.1, avg_bits: 3.0 },
            // wrong layer count
            Sample { config: vec![2, 3], jsd: 0.1, avg_bits: 2.5 },
            // bit width outside the space's choices
            Sample { config: vec![9, 9, 9, 9], jsd: 0.1, avg_bits: 9.0 },
        ];
        let warm = run_search_seeded(&space, &mut mk(), &p, &bad).unwrap();
        let cold = run_search(&space, &mut mk(), &p).unwrap();
        // all seeds rejected -> byte-identical to a cold start
        assert_eq!(warm.archive.content_hash(), cold.archive.content_hash());
    }

    #[test]
    fn ucb_screen_with_gp_is_deterministic() {
        let space = toy_space(6);
        let mk = || SynthEval { weights: vec![0.1, 0.5, 0.2, 0.9, 0.05, 0.3], evals: 0 };
        let mut p = SearchParams::smoke();
        p.predictor = PredictorKind::Gp;
        p.ucb_kappa = 1.0;
        p.seed = 11;
        let a = run_search(&space, &mut mk(), &p).unwrap();
        let b = run_search(&space, &mut mk(), &p).unwrap();
        assert_eq!(a.archive.content_hash(), b.archive.content_hash());
        assert!(a.true_evals > 0);
        // the screen consults the predictor, never the RNG, so extra
        // queries may accrue but determinism holds
        assert_eq!(a.predictor_queries, b.predictor_queries);
    }
}

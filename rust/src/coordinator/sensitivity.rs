//! Per-layer quantization sensitivity (Figure 2): quantize one layer to the
//! lowest bit-width while keeping all others at the highest, and measure the
//! calibration JSD of the assembled model.

use super::proxy::ConfigEvaluator;
use super::space::{Config, SearchSpace};
use crate::Result;

#[derive(Clone, Debug)]
pub struct Sensitivity {
    /// JSD per layer when that layer alone is at min bits.
    pub jsd: Vec<f32>,
    /// Baseline JSD with every layer at max bits.
    pub baseline: f32,
}

pub fn measure(
    space: &SearchSpace,
    evaluator: &mut dyn ConfigEvaluator,
) -> Result<Sensitivity> {
    let n = space.n_layers();
    let max_cfg: Vec<u8> = space
        .choices
        .iter()
        .map(|c| *c.iter().max().unwrap())
        .collect();
    let baseline = evaluator.eval_jsd(&max_cfg)?;
    // One single-layer-at-min config per layer, dispatched as a single
    // batch: a pool-backed evaluator scans all layers concurrently.
    let probes: Vec<Config> = (0..n)
        .map(|li| {
            let mut cfg = max_cfg.clone();
            cfg[li] = *space.choices[li].iter().min().unwrap();
            cfg
        })
        .collect();
    let jsd = evaluator.eval_jsd_batch(&probes)?;
    eyre::ensure!(
        jsd.len() == probes.len(),
        "evaluator returned {} results for {} probes",
        jsd.len(),
        probes.len()
    );
    Ok(Sensitivity { jsd, baseline })
}

impl Sensitivity {
    /// Sensitivity scores relative to the all-max baseline.
    pub fn scores(&self) -> Vec<f32> {
        self.jsd.iter().map(|&j| (j - self.baseline).max(0.0)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::space::toy_space;

    /// Synthetic evaluator: layer i contributes weight[i] * (4 - bits)^2.
    pub struct SynthEval {
        pub weights: Vec<f32>,
        pub evals: usize,
    }

    impl ConfigEvaluator for SynthEval {
        fn eval_jsd(&mut self, config: &super::super::space::Config) -> Result<f32> {
            self.evals += 1;
            Ok(config
                .iter()
                .enumerate()
                .map(|(i, &b)| self.weights[i] * ((4 - b) as f32).powi(2))
                .sum())
        }

        fn count(&self) -> usize {
            self.evals
        }
    }

    #[test]
    fn recovers_known_sensitivities() {
        let space = toy_space(5);
        let weights = vec![0.1, 1.0, 0.05, 0.5, 0.2];
        let mut ev = SynthEval { weights: weights.clone(), evals: 0 };
        let sens = measure(&space, &mut ev).unwrap();
        assert_eq!(sens.baseline, 0.0);
        let scores = sens.scores();
        // order must match the ground-truth weights
        let mut order: Vec<usize> = (0..5).collect();
        order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        assert_eq!(order[0], 1);
        assert_eq!(order[1], 3);
        // one eval for baseline + one per layer
        assert_eq!(ev.count(), 6);
    }
}

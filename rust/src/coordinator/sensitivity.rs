//! Per-layer quantization sensitivity (Figure 2): quantize one layer to the
//! lowest bit-width while keeping all others at the highest, and measure the
//! calibration JSD of the assembled model.  With a multi-method genome the
//! gene scan generalizes this to every `(layer, method, bits)` probe.

use super::proxy::ConfigEvaluator;
use super::space::{gene_bits, gene_method, Config, SearchSpace};
use crate::quant::MethodId;
use crate::Result;

#[derive(Clone, Debug)]
pub struct Sensitivity {
    /// JSD per layer when that layer alone is at min bits.
    pub jsd: Vec<f32>,
    /// Baseline JSD with every layer at max bits.
    pub baseline: f32,
}

pub fn measure(
    space: &SearchSpace,
    evaluator: &mut dyn ConfigEvaluator,
) -> Result<Sensitivity> {
    let n = space.n_layers();
    let max_cfg = space.max_config();
    let baseline = evaluator.eval_jsd(&max_cfg)?;
    // One single-layer-at-min config per layer, dispatched as a single
    // batch: a pool-backed evaluator scans all layers concurrently.
    let probes: Vec<Config> = (0..n)
        .map(|li| {
            let mut cfg = max_cfg.clone();
            cfg[li] = space.min_gene(li);
            cfg
        })
        .collect();
    let jsd = evaluator.eval_jsd_batch(&probes)?;
    eyre::ensure!(
        jsd.len() == probes.len(),
        "evaluator returned {} results for {} probes",
        jsd.len(),
        probes.len()
    );
    Ok(Sensitivity { jsd, baseline })
}

impl Sensitivity {
    /// Sensitivity scores relative to the all-max baseline.
    pub fn scores(&self) -> Vec<f32> {
        self.jsd.iter().map(|&j| (j - self.baseline).max(0.0)).collect()
    }
}

/// One gene-scan probe: layer `li` set to `(method, bits)`, all other
/// layers at their max gene.
#[derive(Clone, Debug)]
pub struct GeneProbe {
    pub layer: usize,
    pub method: MethodId,
    pub bits: u8,
    pub jsd: f32,
}

/// The per-`(layer, method, bits)` sensitivity scan of a (multi-method)
/// space: how much each gene choice hurts relative to the all-max baseline.
#[derive(Clone, Debug)]
pub struct GeneScan {
    pub baseline: f32,
    pub probes: Vec<GeneProbe>,
}

impl GeneScan {
    /// Probes of one layer, in choice order.
    pub fn layer(&self, li: usize) -> Vec<&GeneProbe> {
        self.probes.iter().filter(|p| p.layer == li).collect()
    }

    /// The gentlest (lowest-JSD) probe per layer — which `(method, bits)`
    /// a layer tolerates best.
    pub fn best_per_layer(&self, n_layers: usize) -> Vec<Option<&GeneProbe>> {
        (0..n_layers)
            .map(|li| {
                self.probes
                    .iter()
                    .filter(|p| p.layer == li)
                    .min_by(|a, b| a.jsd.partial_cmp(&b.jsd).unwrap_or(std::cmp::Ordering::Equal))
            })
            .collect()
    }
}

/// Scan every non-max gene of every layer (others at max), dispatched as a
/// single batch so pool shards scan concurrently.  Cost:
/// `1 + sum(choices per layer - 1)` true evaluations.
pub fn scan_genes(
    space: &SearchSpace,
    evaluator: &mut dyn ConfigEvaluator,
) -> Result<GeneScan> {
    let max_cfg = space.max_config();
    let baseline = evaluator.eval_jsd(&max_cfg)?;
    let mut meta: Vec<(usize, MethodId, u8)> = Vec::new();
    let mut probes: Vec<Config> = Vec::new();
    for li in 0..space.n_layers() {
        for &g in &space.choices[li] {
            if g == max_cfg[li] {
                continue;
            }
            let mut cfg = max_cfg.clone();
            cfg[li] = g;
            meta.push((li, gene_method(g), gene_bits(g)));
            probes.push(cfg);
        }
    }
    let jsd = evaluator.eval_jsd_batch(&probes)?;
    eyre::ensure!(
        jsd.len() == probes.len(),
        "evaluator returned {} results for {} probes",
        jsd.len(),
        probes.len()
    );
    Ok(GeneScan {
        baseline,
        probes: meta
            .into_iter()
            .zip(jsd)
            .map(|((layer, method, bits), jsd)| GeneProbe { layer, method, bits, jsd })
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::space::{toy_space, toy_space_methods};

    /// Synthetic evaluator: layer i contributes weight[i] * (4 - bits)^2,
    /// doubled for RTN genes (a method-quality gap the scan must see).
    pub struct SynthEval {
        pub weights: Vec<f32>,
        pub evals: usize,
    }

    impl ConfigEvaluator for SynthEval {
        fn eval_jsd(&mut self, config: &Config) -> Result<f32> {
            self.evals += 1;
            Ok(config
                .iter()
                .enumerate()
                .map(|(i, &g)| {
                    let penalty = ((4 - gene_bits(g) as i32) as f32).powi(2);
                    let factor = if gene_method(g) == MethodId::Rtn { 2.0 } else { 1.0 };
                    self.weights[i] * penalty * factor
                })
                .sum())
        }

        fn count(&self) -> usize {
            self.evals
        }
    }

    #[test]
    fn recovers_known_sensitivities() {
        let space = toy_space(5);
        let weights = vec![0.1, 1.0, 0.05, 0.5, 0.2];
        let mut ev = SynthEval { weights: weights.clone(), evals: 0 };
        let sens = measure(&space, &mut ev).unwrap();
        assert_eq!(sens.baseline, 0.0);
        let scores = sens.scores();
        // order must match the ground-truth weights
        let mut order: Vec<usize> = (0..5).collect();
        order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        assert_eq!(order[0], 1);
        assert_eq!(order[1], 3);
        // one eval for baseline + one per layer
        assert_eq!(ev.count(), 6);
    }

    #[test]
    fn gene_scan_covers_every_choice_and_sees_methods() {
        let space = toy_space_methods(3, &[MethodId::Hqq, MethodId::Rtn]);
        let mut ev = SynthEval { weights: vec![1.0, 0.5, 0.2], evals: 0 };
        let scan = scan_genes(&space, &mut ev).unwrap();
        // 6 choices per layer, one of which is the max gene -> 5 probes each
        assert_eq!(scan.probes.len(), 3 * 5);
        assert_eq!(ev.count(), 1 + 15);
        // the synthetic evaluator penalizes rtn 2x: at equal bits, hqq
        // probes must score strictly better on every layer
        for li in 0..3 {
            let probes = scan.layer(li);
            for bits in [2u8, 3] {
                let hqq = probes
                    .iter()
                    .find(|p| p.method == MethodId::Hqq && p.bits == bits)
                    .unwrap();
                let rtn = probes
                    .iter()
                    .find(|p| p.method == MethodId::Rtn && p.bits == bits)
                    .unwrap();
                assert!(hqq.jsd < rtn.jsd, "layer {li} bits {bits}");
            }
            // rtn@4 carries zero bit penalty in the synthetic model, so it
            // ties the baseline and wins the layer
            let best = scan.best_per_layer(3)[li].unwrap();
            assert_eq!((best.method, best.bits), (MethodId::Rtn, 4));
            assert_eq!(best.jsd, scan.baseline);
        }
        // single-method spaces degrade to the classic per-layer scan shape
        let single = toy_space(4);
        let mut ev2 = SynthEval { weights: vec![0.1; 4], evals: 0 };
        let scan2 = scan_genes(&single, &mut ev2).unwrap();
        assert_eq!(scan2.probes.len(), 4 * 2);
    }
}

//! Search space: per-layer candidate bit-widths, configurations, and the
//! average-bits / memory objective (§3.1 of the paper).

use crate::data::Manifest;
use crate::quant::GROUP_OVERHEAD_BITS;
use crate::util::Rng;

/// A configuration: one bit-width per searchable layer (manifest order).
pub type Config = Vec<u8>;

/// The (possibly pruned) search space.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Allowed bit-widths per layer; pruned layers have a single choice.
    pub choices: Vec<Vec<u8>>,
    /// Parameter count per layer (average-bits weights).
    pub params: Vec<usize>,
    /// Groups per layer (metadata overhead accounting).
    pub groups: Vec<usize>,
    pub group_size: usize,
}

impl SearchSpace {
    /// Full space: every layer may take any of the manifest bit choices.
    pub fn full(m: &Manifest) -> SearchSpace {
        SearchSpace {
            choices: vec![m.bit_choices.clone(); m.layers.len()],
            params: m.layers.iter().map(|l| l.params()).collect(),
            groups: m.layers.iter().map(|l| l.n_groups(m.group_size)).collect(),
            group_size: m.group_size,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.choices.len()
    }

    /// log10 of the number of configurations (the paper's 10^106 headline).
    pub fn log10_size(&self) -> f64 {
        self.choices.iter().map(|c| (c.len() as f64).log10()).sum()
    }

    /// Pin a layer to a single bit-width (pruning).
    pub fn pin(&mut self, layer: usize, bits: u8) {
        self.choices[layer] = vec![bits];
    }

    /// Layers that still have more than one choice.
    pub fn active_layers(&self) -> Vec<usize> {
        (0..self.n_layers())
            .filter(|&i| self.choices[i].len() > 1)
            .collect()
    }

    /// Weighted average bits of a config, including per-group fp16
    /// scale+zero overhead (group size 128 -> +0.25, range [2.25, 4.25]).
    pub fn avg_bits(&self, config: &[u8]) -> f64 {
        debug_assert_eq!(config.len(), self.n_layers());
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..self.n_layers() {
            let p = self.params[i] as f64;
            num += p * config[i] as f64 + self.groups[i] as f64 * GROUP_OVERHEAD_BITS;
            den += p;
        }
        num / den
    }

    /// Searchable-weight memory in MB for a config (codes + group metadata).
    pub fn memory_mb(&self, config: &[u8]) -> f64 {
        let bits: f64 = (0..self.n_layers())
            .map(|i| {
                self.params[i] as f64 * config[i] as f64
                    + self.groups[i] as f64 * GROUP_OVERHEAD_BITS
            })
            .sum();
        bits / 8.0 / 1e6
    }

    /// Uniform random configuration.
    pub fn random(&self, rng: &mut Rng) -> Config {
        self.choices.iter().map(|c| *rng.choice(c)).collect()
    }

    /// Random configuration biased toward a target average bit-width:
    /// sample uniformly, then repair toward the target by single-layer moves.
    pub fn random_near(&self, rng: &mut Rng, target_bits: f64, tol: f64) -> Config {
        let mut cfg = self.random(rng);
        for _ in 0..10_000 {
            let avg = self.avg_bits(&cfg);
            if (avg - target_bits).abs() <= tol {
                break;
            }
            let li = rng.below(self.n_layers());
            let cur = cfg[li];
            let want_up = avg < target_bits;
            let cands: Vec<u8> = self.choices[li]
                .iter()
                .copied()
                .filter(|&b| if want_up { b > cur } else { b < cur })
                .collect();
            if let Some(&b) = cands.first() {
                cfg[li] = if want_up {
                    *cands.iter().min().unwrap()
                } else {
                    *cands.iter().max().unwrap()
                };
                let _ = b;
            }
        }
        cfg
    }

    /// Clamp a config to the space (after crossover/mutation of pinned dims).
    pub fn repair(&self, config: &mut Config) {
        for i in 0..self.n_layers() {
            if !self.choices[i].contains(&config[i]) {
                // snap to nearest allowed choice
                let c = *self.choices[i]
                    .iter()
                    .min_by_key(|&&b| (b as i32 - config[i] as i32).abs())
                    .unwrap();
                config[i] = c;
            }
        }
    }

    /// True when every gene is an allowed choice.
    pub fn contains(&self, config: &[u8]) -> bool {
        config.len() == self.n_layers()
            && config
                .iter()
                .zip(&self.choices)
                .all(|(b, c)| c.contains(b))
    }

    /// Normalized feature vector for the quality predictor: active layers
    /// only, bits mapped to [0, 1].
    pub fn features(&self, config: &[u8], active: &[usize]) -> Vec<f32> {
        active
            .iter()
            .map(|&i| {
                let lo = *self.choices[i].iter().min().unwrap() as f32;
                let hi = *self.choices[i].iter().max().unwrap() as f32;
                if hi > lo {
                    (config[i] as f32 - lo) / (hi - lo)
                } else {
                    0.0
                }
            })
            .collect()
    }
}

#[cfg(test)]
pub fn toy_space(n_layers: usize) -> SearchSpace {
    SearchSpace {
        choices: vec![vec![2, 3, 4]; n_layers],
        params: vec![128 * 128; n_layers],
        groups: vec![128; n_layers],
        group_size: 128,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_bits_uniform_configs() {
        let s = toy_space(8);
        assert!((s.avg_bits(&vec![2u8; 8]) - 2.25).abs() < 1e-9);
        assert!((s.avg_bits(&vec![3u8; 8]) - 3.25).abs() < 1e-9);
        assert!((s.avg_bits(&vec![4u8; 8]) - 4.25).abs() < 1e-9);
    }

    #[test]
    fn log10_size() {
        let s = toy_space(28);
        // 3^28 ~= 10^13.36
        assert!((s.log10_size() - 28.0 * 3f64.log10()).abs() < 1e-9);
    }

    #[test]
    fn pin_reduces_space() {
        let mut s = toy_space(4);
        s.pin(1, 4);
        assert_eq!(s.active_layers(), vec![0, 2, 3]);
        assert!(s.log10_size() < toy_space(4).log10_size());
    }

    #[test]
    fn random_near_hits_target() {
        let s = toy_space(28);
        let mut rng = Rng::new(1);
        for target in [2.5f64, 3.0, 3.5, 4.0] {
            let cfg = s.random_near(&mut rng, target, 0.05);
            assert!((s.avg_bits(&cfg) - target).abs() <= 0.06,
                    "target {target} got {}", s.avg_bits(&cfg));
        }
    }

    #[test]
    fn repair_snaps_to_choices() {
        let mut s = toy_space(3);
        s.pin(0, 4);
        let mut cfg = vec![2u8, 3, 3];
        s.repair(&mut cfg);
        assert_eq!(cfg[0], 4);
        assert!(s.contains(&cfg));
    }

    #[test]
    fn features_normalized() {
        let s = toy_space(3);
        let active = vec![0usize, 1, 2];
        let f = s.features(&[2, 3, 4], &active);
        assert_eq!(f, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn memory_tracks_bits() {
        let s = toy_space(4);
        assert!(s.memory_mb(&vec![2u8; 4]) < s.memory_mb(&vec![4u8; 4]));
    }
}

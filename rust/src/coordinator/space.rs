//! Search space: per-layer candidate `(method, bits)` genes, configurations,
//! and the average-bits / memory objective (§3.1 of the paper, generalized
//! to the method axis the official AMQ repo searches over).

use crate::data::Manifest;
use crate::quant::{MethodId, MethodRegistry};
use crate::util::Rng;

/// A per-layer gene: quantization method + bit-width, packed into a `u16`
/// with the stable [`MethodId`] index in the high byte and the bit-width in
/// the low byte.
///
/// Packing is load-bearing: genes of the default single-method genome
/// (method 0 = the HQQ proxy) are numerically identical to the legacy
/// bits-only `Vec<u8>` genome, so archives, JSON caches and RNG streams are
/// unchanged when one method is enabled.
pub type Gene = u16;

/// Pack a `(method, bits)` gene.
#[inline]
pub fn gene(method: MethodId, bits: u8) -> Gene {
    ((method.index() as Gene) << 8) | bits as Gene
}

/// The bit-width of a gene.
#[inline]
pub fn gene_bits(g: Gene) -> u8 {
    (g & 0xFF) as u8
}

/// The method of a gene, if the method byte is valid.  This is the entry
/// point for *untrusted* genes — bytes carried by a wire `Chunk` frame or a
/// persisted archive — where a corrupt method byte must fail the one
/// request, not the process.
#[inline]
pub fn try_gene_method(g: Gene) -> Option<MethodId> {
    MethodId::from_index((g >> 8) as usize)
}

/// The method of a gene.  Panics on an invalid method byte, so this form is
/// reserved for genes that are valid by construction (drawn from a
/// [`SearchSpace`]); untrusted bytes go through [`try_gene_method`].
#[inline]
pub fn gene_method(g: Gene) -> MethodId {
    try_gene_method(g).unwrap_or_else(|| panic!("invalid method byte in gene {g:#06x}"))
}

/// A configuration: one `(method, bits)` gene per searchable layer
/// (manifest order).
pub type Config = Vec<Gene>;

/// The (possibly pruned) search space.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Allowed genes per layer; pruned layers have a single choice.
    pub choices: Vec<Vec<Gene>>,
    /// Parameter count per layer (average-bits weights).
    pub params: Vec<usize>,
    /// Total quantization groups per layer (= params / group_size for the
    /// per-`(row, group)` fp16 scale+zero metadata every grouped method
    /// emits).
    pub groups: Vec<usize>,
    pub group_size: usize,
}

impl SearchSpace {
    /// Full space over the manifest's enabled methods (the `methods` list,
    /// defaulting to single-method HQQ): every layer may take any
    /// `(method, bits)` combination.
    pub fn full(m: &Manifest) -> SearchSpace {
        Self::with_methods(m, &MethodRegistry::from_names(&m.methods))
    }

    /// Full space over an explicit method registry (CLI `--methods`).
    pub fn with_methods(m: &Manifest, registry: &MethodRegistry) -> SearchSpace {
        let layer_choices: Vec<Gene> = registry
            .enabled()
            .iter()
            .flat_map(|&method| m.bit_choices.iter().map(move |&b| gene(method, b)))
            .collect();
        SearchSpace {
            choices: vec![layer_choices; m.layers.len()],
            params: m.layers.iter().map(|l| l.params()).collect(),
            groups: m.layers.iter().map(|l| l.params() / m.group_size).collect(),
            group_size: m.group_size,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.choices.len()
    }

    /// log10 of the number of configurations (the paper's 10^106 headline;
    /// the method axis multiplies the per-layer choice count).
    pub fn log10_size(&self) -> f64 {
        self.choices.iter().map(|c| (c.len() as f64).log10()).sum()
    }

    /// Bitmask of the method indices present anywhere in the space — a
    /// tight allocation-free scan, cheap enough for the predictor hot path
    /// (`features` is called once per NSGA-II candidate).
    #[inline]
    fn method_mask(&self) -> u8 {
        let mut mask = 0u8;
        for c in &self.choices {
            for &g in c {
                mask |= 1u8 << ((g >> 8) & 0x07);
            }
        }
        mask
    }

    /// Distinct methods appearing anywhere in the space, in stable
    /// [`MethodId`] index order.
    pub fn methods(&self) -> Vec<MethodId> {
        let mask = self.method_mask();
        MethodId::ALL
            .iter()
            .copied()
            .filter(|m| mask & (1u8 << m.index()) != 0)
            .collect()
    }

    /// Number of distinct methods in the space (1 = legacy genome).
    pub fn n_methods(&self) -> usize {
        self.method_mask().count_ones() as usize
    }

    /// Pin a layer to a single gene (pruning).
    pub fn pin(&mut self, layer: usize, g: Gene) {
        self.choices[layer] = vec![g];
    }

    /// Layers that still have more than one choice.
    pub fn active_layers(&self) -> Vec<usize> {
        (0..self.n_layers())
            .filter(|&i| self.choices[i].len() > 1)
            .collect()
    }

    /// The lowest-bits gene of a layer (ties broken toward the lowest
    /// method index, deterministically).
    pub fn min_gene(&self, layer: usize) -> Gene {
        *self.choices[layer]
            .iter()
            .min_by_key(|&&g| (gene_bits(g), g))
            .unwrap()
    }

    /// The highest-bits gene of a layer (ties broken toward the lowest
    /// method index, deterministically).
    pub fn max_gene(&self, layer: usize) -> Gene {
        *self.choices[layer]
            .iter()
            .max_by_key(|&&g| (gene_bits(g), std::cmp::Reverse(g)))
            .unwrap()
    }

    /// All-minimum-bits configuration.
    pub fn min_config(&self) -> Config {
        (0..self.n_layers()).map(|li| self.min_gene(li)).collect()
    }

    /// All-maximum-bits configuration.
    pub fn max_config(&self) -> Config {
        (0..self.n_layers()).map(|li| self.max_gene(li)).collect()
    }

    /// Uniform-bits configuration at `bits`; each layer keeps the method of
    /// an existing choice with those bits when available (lowest method
    /// index), falling back to the layer's first listed method.
    pub fn uniform(&self, bits: u8) -> Config {
        (0..self.n_layers())
            .map(|li| {
                self.choices[li]
                    .iter()
                    .copied()
                    .filter(|&g| gene_bits(g) == bits)
                    .min()
                    .unwrap_or_else(|| gene(gene_method(self.choices[li][0]), bits))
            })
            .collect()
    }

    /// One step down in bits for a layer's gene, preferring the same
    /// method; `None` when nothing below the current bits exists.
    pub fn demote(&self, layer: usize, g: Gene) -> Option<Gene> {
        let bits = gene_bits(g);
        let method = gene_method(g);
        let step = |same_method: bool| {
            self.choices[layer]
                .iter()
                .copied()
                .filter(|&c| gene_bits(c) < bits && (!same_method || gene_method(c) == method))
                .max_by_key(|&c| (gene_bits(c), std::cmp::Reverse(c)))
        };
        step(true).or_else(|| step(false))
    }

    /// The bit-widths of a config (deploy-time view; drops the methods).
    pub fn config_bits(&self, config: &[Gene]) -> Vec<u8> {
        config.iter().map(|&g| gene_bits(g)).collect()
    }

    /// Weighted average bits of a config, including the per-group metadata
    /// overhead of each gene's *method* (fp16 scale+zero -> +32 bits/group;
    /// group size 128 -> +0.25 bits/weight, range [2.25, 4.25]).
    pub fn avg_bits(&self, config: &[Gene]) -> f64 {
        debug_assert_eq!(config.len(), self.n_layers());
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..self.n_layers() {
            let p = self.params[i] as f64;
            num += p * gene_bits(config[i]) as f64
                + self.groups[i] as f64 * gene_method(config[i]).group_overhead_bits();
            den += p;
        }
        num / den
    }

    /// Searchable-weight memory in MB for a config (codes + per-method
    /// group metadata) — agrees with `ProxyBank` per-piece
    /// `memory_bytes()` accounting.
    pub fn memory_mb(&self, config: &[Gene]) -> f64 {
        let bits: f64 = (0..self.n_layers())
            .map(|i| {
                self.params[i] as f64 * gene_bits(config[i]) as f64
                    + self.groups[i] as f64 * gene_method(config[i]).group_overhead_bits()
            })
            .sum();
        bits / 8.0 / 1e6
    }

    /// Uniform random configuration.
    pub fn random(&self, rng: &mut Rng) -> Config {
        self.choices.iter().map(|c| *rng.choice(c)).collect()
    }

    /// Random configuration biased toward a target average bit-width:
    /// sample uniformly, then repair toward the target by single-layer
    /// bit moves (the gene's method is preserved when it offers the needed
    /// step, so multi-method init populations stay method-diverse).
    pub fn random_near(&self, rng: &mut Rng, target_bits: f64, tol: f64) -> Config {
        let mut cfg = self.random(rng);
        for _ in 0..10_000 {
            let avg = self.avg_bits(&cfg);
            if (avg - target_bits).abs() <= tol {
                break;
            }
            let li = rng.below(self.n_layers());
            let cur_bits = gene_bits(cfg[li]);
            let cur_method = gene_method(cfg[li]);
            let want_up = avg < target_bits;
            let pick = |same_method: bool| {
                let cands = self.choices[li].iter().copied().filter(|&g| {
                    let dir_ok = if want_up {
                        gene_bits(g) > cur_bits
                    } else {
                        gene_bits(g) < cur_bits
                    };
                    dir_ok && (!same_method || gene_method(g) == cur_method)
                });
                if want_up {
                    cands.min_by_key(|&g| (gene_bits(g), g))
                } else {
                    cands.max_by_key(|&g| (gene_bits(g), std::cmp::Reverse(g)))
                }
            };
            if let Some(g) = pick(true).or_else(|| pick(false)) {
                cfg[li] = g;
            }
        }
        cfg
    }

    /// Clamp a config to the space (after crossover/mutation of pinned
    /// dims): snap to the nearest allowed gene by bits distance, preferring
    /// the same method among equally near choices.
    pub fn repair(&self, config: &mut Config) {
        for i in 0..self.n_layers() {
            if !self.choices[i].contains(&config[i]) {
                let bits = gene_bits(config[i]) as i32;
                let method = gene_method(config[i]);
                let g = *self.choices[i]
                    .iter()
                    .min_by_key(|&&c| {
                        ((gene_bits(c) as i32 - bits).abs(), gene_method(c) != method, c)
                    })
                    .unwrap();
                config[i] = g;
            }
        }
    }

    /// True when every gene is an allowed choice.
    pub fn contains(&self, config: &[Gene]) -> bool {
        config.len() == self.n_layers()
            && config
                .iter()
                .zip(&self.choices)
                .all(|(g, c)| c.contains(g))
    }

    /// Normalized feature vector for the quality predictor: active layers
    /// only, bits mapped to [0, 1].  When the space carries more than one
    /// method, a one-hot method channel per active layer is appended after
    /// the bits block, so single-method feature vectors stay byte-identical
    /// to the legacy encoding.
    pub fn features(&self, config: &[Gene], active: &[usize]) -> Vec<f32> {
        let mut out: Vec<f32> = active
            .iter()
            .map(|&i| {
                let lo = self.choices[i].iter().map(|&g| gene_bits(g)).min().unwrap() as f32;
                let hi = self.choices[i].iter().map(|&g| gene_bits(g)).max().unwrap() as f32;
                if hi > lo {
                    (gene_bits(config[i]) as f32 - lo) / (hi - lo)
                } else {
                    0.0
                }
            })
            .collect();
        if self.method_mask().count_ones() > 1 {
            let methods = self.methods();
            out.reserve(active.len() * methods.len());
            for &i in active {
                let m = gene_method(config[i]);
                for &cand in &methods {
                    out.push(if cand == m { 1.0 } else { 0.0 });
                }
            }
        }
        out
    }
}

#[cfg(test)]
pub fn toy_space(n_layers: usize) -> SearchSpace {
    SearchSpace {
        choices: vec![vec![2, 3, 4]; n_layers],
        params: vec![128 * 128; n_layers],
        groups: vec![128; n_layers],
        group_size: 128,
    }
}

/// A toy space whose layers may take every `(method, bits)` combination of
/// the given methods.
#[cfg(test)]
pub fn toy_space_methods(n_layers: usize, methods: &[MethodId]) -> SearchSpace {
    let choices: Vec<Gene> = methods
        .iter()
        .flat_map(|&m| [2u8, 3, 4].iter().map(move |&b| gene(m, b)))
        .collect();
    SearchSpace {
        choices: vec![choices; n_layers],
        params: vec![128 * 128; n_layers],
        groups: vec![128; n_layers],
        group_size: 128,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gene_packing_roundtrip() {
        for m in MethodId::ALL {
            for b in [2u8, 3, 4, 8] {
                let g = gene(m, b);
                assert_eq!(gene_bits(g), b);
                assert_eq!(gene_method(g), m);
            }
        }
        // single-method (hqq) genes are numerically the bit-width — the
        // legacy-genome compatibility contract
        assert_eq!(gene(MethodId::Hqq, 3), 3);
        assert_eq!(gene(MethodId::Rtn, 3), 0x0103);
    }

    #[test]
    fn try_gene_method_rejects_garbage_bytes() {
        for m in MethodId::ALL {
            assert_eq!(try_gene_method(gene(m, 3)), Some(m));
        }
        // a method byte beyond the registry: the kind of byte a corrupt
        // cached archive or a malicious wire chunk can carry
        assert_eq!(try_gene_method(0x0F03), None);
        assert_eq!(try_gene_method(0xFF02), None);
    }

    #[test]
    fn avg_bits_uniform_configs() {
        let s = toy_space(8);
        assert!((s.avg_bits(&vec![2u16; 8]) - 2.25).abs() < 1e-9);
        assert!((s.avg_bits(&vec![3u16; 8]) - 3.25).abs() < 1e-9);
        assert!((s.avg_bits(&vec![4u16; 8]) - 4.25).abs() < 1e-9);
    }

    #[test]
    fn avg_bits_ignores_method_at_equal_overhead() {
        // all registered methods emit the same fp16 scale/zero metadata, so
        // avg_bits depends only on the bits axis today
        let s = toy_space_methods(6, &[MethodId::Hqq, MethodId::Rtn]);
        let hqq3 = s.uniform(3);
        let rtn3: Config = vec![gene(MethodId::Rtn, 3); 6];
        assert!((s.avg_bits(&hqq3) - s.avg_bits(&rtn3)).abs() < 1e-12);
        assert!((s.avg_bits(&hqq3) - 3.25).abs() < 1e-9);
    }

    #[test]
    fn log10_size() {
        let s = toy_space(28);
        // 3^28 ~= 10^13.36
        assert!((s.log10_size() - 28.0 * 3f64.log10()).abs() < 1e-9);
        // the method axis multiplies the genome
        let m = toy_space_methods(28, &[MethodId::Hqq, MethodId::Rtn]);
        assert!((m.log10_size() - 28.0 * 6f64.log10()).abs() < 1e-9);
        assert_eq!(m.n_methods(), 2);
        assert_eq!(toy_space(5).n_methods(), 1);
    }

    #[test]
    fn pin_reduces_space() {
        let mut s = toy_space(4);
        s.pin(1, 4);
        assert_eq!(s.active_layers(), vec![0, 2, 3]);
        assert!(s.log10_size() < toy_space(4).log10_size());
    }

    #[test]
    fn random_near_hits_target() {
        let s = toy_space(28);
        let mut rng = Rng::new(1);
        for target in [2.5f64, 3.0, 3.5, 4.0] {
            let cfg = s.random_near(&mut rng, target, 0.05);
            assert!(
                (s.avg_bits(&cfg) - target).abs() <= 0.06,
                "target {target} got {}",
                s.avg_bits(&cfg)
            );
        }
    }

    #[test]
    fn random_near_preserves_methods_multi() {
        let s = toy_space_methods(28, &[MethodId::Hqq, MethodId::Rtn]);
        let mut rng = Rng::new(5);
        let cfg = s.random_near(&mut rng, 3.0, 0.05);
        assert!(s.contains(&cfg));
        assert!((s.avg_bits(&cfg) - 3.0).abs() <= 0.06);
        // with 28 layers and uniform method sampling, both methods should
        // survive the bit-repair walk
        let rtn = cfg.iter().filter(|&&g| gene_method(g) == MethodId::Rtn).count();
        assert!(rtn > 0 && rtn < 28, "method diversity lost: {rtn}/28");
    }

    #[test]
    fn repair_snaps_to_choices() {
        let mut s = toy_space(3);
        s.pin(0, 4);
        let mut cfg = vec![2u16, 3, 3];
        s.repair(&mut cfg);
        assert_eq!(cfg[0], 4);
        assert!(s.contains(&cfg));
    }

    #[test]
    fn repair_prefers_same_method() {
        let mut s = toy_space_methods(2, &[MethodId::Hqq, MethodId::Rtn]);
        // layer 0 restricted to rtn@{2,4}; a stray rtn@3 must stay rtn
        s.choices[0] = vec![gene(MethodId::Rtn, 2), gene(MethodId::Rtn, 4), gene(MethodId::Hqq, 2)];
        let mut cfg = vec![gene(MethodId::Rtn, 3), gene(MethodId::Hqq, 3)];
        s.repair(&mut cfg);
        assert_eq!(cfg[0], gene(MethodId::Rtn, 2), "same-method tie must win");
        assert_eq!(cfg[1], gene(MethodId::Hqq, 3));
    }

    #[test]
    fn min_max_uniform_demote_helpers() {
        let s = toy_space_methods(3, &[MethodId::Hqq, MethodId::Rtn]);
        assert_eq!(s.min_gene(0), gene(MethodId::Hqq, 2));
        assert_eq!(s.max_gene(0), gene(MethodId::Hqq, 4));
        assert_eq!(s.uniform(3), vec![gene(MethodId::Hqq, 3); 3]);
        // demote keeps the method
        assert_eq!(s.demote(0, gene(MethodId::Rtn, 4)), Some(gene(MethodId::Rtn, 3)));
        assert_eq!(s.demote(0, gene(MethodId::Rtn, 2)), None);
        assert_eq!(s.config_bits(&s.max_config()), vec![4, 4, 4]);
    }

    #[test]
    fn features_normalized() {
        let s = toy_space(3);
        let active = vec![0usize, 1, 2];
        let f = s.features(&[2, 3, 4], &active);
        assert_eq!(f, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn features_append_method_one_hot_only_when_multi() {
        let s = toy_space_methods(3, &[MethodId::Hqq, MethodId::Rtn]);
        let active = vec![0usize, 1, 2];
        let cfg = vec![gene(MethodId::Hqq, 2), gene(MethodId::Rtn, 3), gene(MethodId::Hqq, 4)];
        let f = s.features(&cfg, &active);
        // 3 bits features + 3 layers x 2-way one-hot
        assert_eq!(f.len(), 9);
        assert_eq!(&f[..3], &[0.0, 0.5, 1.0]);
        assert_eq!(&f[3..], &[1.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
        // single-method spaces keep the legacy layout exactly
        let legacy = toy_space(3).features(&[2, 3, 4], &active);
        assert_eq!(legacy.len(), 3);
    }

    #[test]
    fn with_methods_builds_cross_product() {
        let m = crate::data::manifest::toy_manifest();
        let single = SearchSpace::full(&m);
        assert_eq!(single.choices[0], vec![2u16, 3, 4]);
        let reg = MethodRegistry::parse("hqq,rtn").unwrap();
        let multi = SearchSpace::with_methods(&m, &reg);
        assert_eq!(multi.choices[0].len(), 6);
        assert_eq!(multi.n_methods(), 2);
        assert!(multi.log10_size() > single.log10_size());
        // group accounting covers every (row, group) metadata entry
        assert_eq!(single.groups[0], m.layers[0].params() / m.group_size);
    }

    #[test]
    fn memory_tracks_bits() {
        let s = toy_space(4);
        assert!(s.memory_mb(&vec![2u16; 4]) < s.memory_mb(&vec![4u16; 4]));
    }
}

//! Deterministic synthetic search workload, shared by tests, the remote
//! topology suite and the CI `pool-smoke` command.
//!
//! The point of living in the library (rather than a test helper) is
//! cross-*process* agreement: `repro shard-serve --synthetic` and the
//! coordinator it serves must compute bit-identical scores from the same
//! genes, or the topology matrix ({in-process, multi-process} archives
//! byte-identical for a fixed seed) could never hold.  Everything here is a
//! pure function of its inputs — all randomness is seeded from the
//! candidate genes.

use super::space::{Config, SearchSpace};
use crate::util::Rng;

/// Deterministic synthetic "true evaluation": a heterogeneous quadratic bit
/// penalty plus a small perturbation from a per-candidate seeded RNG (the
/// pool's determinism contract: all randomness derives from the payload).
pub fn synth_jsd(cfg: &[u16]) -> f32 {
    let mut seed = 0xCBF2_9CE4_8422_2325u64;
    for &b in cfg {
        seed = seed.wrapping_mul(0x1000_0000_01B3).wrapping_add(b as u64);
    }
    let mut rng = Rng::new(seed);
    let base: f32 = cfg
        .iter()
        .enumerate()
        .map(|(i, &b)| {
            let w = if i % 4 == 0 { 1.0 } else { 0.05 };
            w * ((4 - b) as f32).powi(2)
        })
        .sum();
    base + rng.f32() * 1e-4
}

/// Chunk-shaped synthetic evaluator — the exact closure signature the eval
/// pool and the shard server both consume.
pub fn synth_chunk(chunk: &[Config]) -> crate::Result<Vec<f32>> {
    Ok(chunk.iter().map(|c| synth_jsd(c)).collect())
}

/// The bits-only toy space the synthetic workload searches over (mirrors
/// the test fixtures: choices {2,3,4} bits, 128×128 params per layer).
pub fn synth_space(n_layers: usize) -> SearchSpace {
    SearchSpace {
        choices: vec![vec![2, 3, 4]; n_layers],
        params: vec![128 * 128; n_layers],
        groups: vec![128; n_layers],
        group_size: 128,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_jsd_is_pure_and_bit_stable() {
        let a = synth_jsd(&[2, 3, 4, 2]);
        let b = synth_jsd(&[2, 3, 4, 2]);
        assert_eq!(a.to_bits(), b.to_bits());
        assert_ne!(a.to_bits(), synth_jsd(&[2, 3, 4, 3]).to_bits());
    }

    #[test]
    fn synth_jsd_prefers_more_bits() {
        assert!(synth_jsd(&[4; 8]) < synth_jsd(&[2; 8]));
    }

    #[test]
    fn synth_chunk_matches_per_candidate() {
        let chunk: Vec<Config> = vec![vec![2, 3], vec![4, 4], vec![3, 2]];
        let scores = synth_chunk(&chunk).unwrap();
        assert_eq!(scores.len(), 3);
        for (c, s) in chunk.iter().zip(&scores) {
            assert_eq!(s.to_bits(), synth_jsd(c).to_bits());
        }
    }

    #[test]
    fn synth_space_shape() {
        let s = synth_space(12);
        assert_eq!(s.n_layers(), 12);
        assert_eq!(s.choices[0], vec![2, 3, 4]);
        assert_eq!(s.group_size, 128);
    }
}

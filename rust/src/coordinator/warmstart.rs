//! Warm-start persistence for the search (`repro search --warm-start DIR`).
//!
//! A finished search's archive (plus the predictor training set derived
//! from it) is serialized to `DIR/warm_<fnv64(model|methods)>.json`, keyed
//! by the full budget tuple `(model manifest hash, methods, n_init,
//! iterations, candidates_per_iter, pop_size, generations, seed,
//! predictor, ucb_kappa)`.  On the next run the file is loaded back in one
//! of three tiers:
//!
//! * [`WarmLoad::Exact`] — every key field matches: the archive is adopted
//!   verbatim and reproduces the cold run's `content_hash` bit-exactly
//!   (floats travel as their raw bit patterns, `avg_bits` is recomputed
//!   from the genes through the same `SearchSpace::avg_bits` that produced
//!   it, and object keys render in `BTreeMap` order, so save -> load is a
//!   byte-exact round trip);
//! * [`WarmLoad::Seed`] — same model + methods but a different budget
//!   tuple: the samples seed [`super::search::run_search_seeded`] (initial
//!   population and predictor training set) and the search continues;
//! * [`WarmLoad::Cold`] — no file, a mismatched model/methods key, or any
//!   corruption (bad JSON, genes outside the space, a content-hash
//!   mismatch): a warning line is printed and the search starts cold.
//!   Stale state degrades the warm start, never the result.

use super::archive::Archive;
use super::search::SearchParams;
use super::space::SearchSpace;
use crate::data::json::Value;
use crate::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// The identity of a persisted search: archive reuse is only valid for the
/// same model + method axis, and only bit-exact for the same budget tuple.
#[derive(Clone, Debug, PartialEq)]
pub struct WarmKey {
    /// Model identity (manifest content hash in production; any stable
    /// label in tests/benches).
    pub model: String,
    /// Canonical method-axis string (e.g. `"hqq,rtn"`).
    pub methods: String,
    pub n_init: usize,
    pub iterations: usize,
    pub candidates_per_iter: usize,
    pub pop_size: usize,
    pub generations: usize,
    pub seed: u64,
    pub predictor: String,
    pub ucb_kappa: f64,
}

impl WarmKey {
    pub fn from_params(model: &str, methods: &str, p: &SearchParams) -> WarmKey {
        WarmKey {
            model: model.to_string(),
            methods: methods.to_string(),
            n_init: p.n_init,
            iterations: p.iterations,
            candidates_per_iter: p.candidates_per_iter,
            pop_size: p.nsga.pop_size,
            generations: p.nsga.generations,
            seed: p.seed,
            predictor: p.predictor.name().to_string(),
            ucb_kappa: p.ucb_kappa,
        }
    }

    /// File name inside the warm-start dir.  Only `(model, methods)` feed
    /// the name: budget variants of the same subject share a slot, so a
    /// re-run with a bigger budget overwrites (upgrades) the entry instead
    /// of accumulating stale siblings.
    pub fn file_name(&self) -> String {
        let bytes = self.model.bytes().chain(std::iter::once(0)).chain(self.methods.bytes());
        format!("warm_{:016x}.json", fnv64(bytes))
    }

    fn to_value(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("model".into(), Value::Str(self.model.clone()));
        m.insert("methods".into(), Value::Str(self.methods.clone()));
        m.insert("n_init".into(), Value::Num(self.n_init as f64));
        m.insert("iterations".into(), Value::Num(self.iterations as f64));
        m.insert("candidates_per_iter".into(), Value::Num(self.candidates_per_iter as f64));
        m.insert("pop_size".into(), Value::Num(self.pop_size as f64));
        m.insert("generations".into(), Value::Num(self.generations as f64));
        let (sh, sl) = split_u64(self.seed);
        m.insert("seed_hi".into(), Value::Num(sh as f64));
        m.insert("seed_lo".into(), Value::Num(sl as f64));
        m.insert("predictor".into(), Value::Str(self.predictor.clone()));
        let (kh, kl) = split_u64(self.ucb_kappa.to_bits());
        m.insert("ucb_kappa_bits_hi".into(), Value::Num(kh as f64));
        m.insert("ucb_kappa_bits_lo".into(), Value::Num(kl as f64));
        Value::Obj(m)
    }
}

/// A loaded warm-start entry: the persisted archive plus the predictor
/// training set ((feature vector, JSD) pairs) derived from it at save time.
pub struct WarmEntry {
    pub archive: Archive,
    pub train_x: Vec<Vec<f32>>,
    pub train_y: Vec<f32>,
}

/// The three warm-start tiers (see the module doc).
pub enum WarmLoad {
    /// Full key match: the archive is the cold run's archive, bit-exact.
    Exact(WarmEntry),
    /// Same model + methods, different budget: seed and continue.
    Seed(WarmEntry),
    /// Nothing usable on disk: start from scratch.
    Cold,
}

/// Stable model-identity label for [`WarmKey::model`]: the FNV-1a digest
/// of the raw manifest bytes, hex-rendered.  Any manifest edit (weights,
/// layer list, calibration files) changes the label and invalidates stale
/// warm-start entries.
pub fn model_label(manifest_bytes: &[u8]) -> String {
    format!("{:016x}", fnv64(manifest_bytes.iter().copied()))
}

/// FNV-1a over a byte stream (same constants as `Archive::content_hash`).
fn fnv64(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01B3);
    }
    h
}

/// JSON numbers carry at most 53 exact bits, so u64s travel as two u32s.
fn split_u64(x: u64) -> (u32, u32) {
    ((x >> 32) as u32, x as u32)
}

fn join_u64(hi: u32, lo: u32) -> u64 {
    ((hi as u64) << 32) | lo as u64
}

fn read_u32(v: &Value, key: &str) -> Result<u32> {
    let n = v.get(key)?.as_u64()?;
    eyre::ensure!(n <= u32::MAX as u64, "`{key}` out of u32 range: {n}");
    Ok(n as u32)
}

fn read_u64_pair(v: &Value, hi_key: &str, lo_key: &str) -> Result<u64> {
    Ok(join_u64(read_u32(v, hi_key)?, read_u32(v, lo_key)?))
}

/// Persist `archive` (and its derived predictor training set) under `key`.
/// Returns the file path written.
pub fn save(dir: &Path, key: &WarmKey, archive: &Archive, space: &SearchSpace) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let active = space.active_layers();

    let samples: Vec<Value> = archive
        .samples
        .iter()
        .map(|s| {
            let mut m = BTreeMap::new();
            m.insert(
                "config".into(),
                Value::Arr(s.config.iter().map(|&g| Value::Num(g as f64)).collect()),
            );
            m.insert("jsd_bits".into(), Value::Num(s.jsd.to_bits() as f64));
            Value::Obj(m)
        })
        .collect();
    let (hh, hl) = split_u64(archive.content_hash());
    let mut arc = BTreeMap::new();
    arc.insert("hash_hi".into(), Value::Num(hh as f64));
    arc.insert("hash_lo".into(), Value::Num(hl as f64));
    arc.insert("samples".into(), Value::Arr(samples));

    let xs: Vec<Value> = archive
        .samples
        .iter()
        .map(|s| {
            Value::Arr(
                space
                    .features(&s.config, &active)
                    .iter()
                    .map(|f| Value::Num(f.to_bits() as f64))
                    .collect(),
            )
        })
        .collect();
    let ys: Vec<Value> = archive
        .samples
        .iter()
        .map(|s| Value::Num(s.jsd.to_bits() as f64))
        .collect();
    let mut train = BTreeMap::new();
    train.insert("x_bits".into(), Value::Arr(xs));
    train.insert("y_bits".into(), Value::Arr(ys));

    let mut root = BTreeMap::new();
    root.insert("format".into(), Value::Num(1.0));
    root.insert("key".into(), key.to_value());
    root.insert("archive".into(), Value::Obj(arc));
    root.insert("train".into(), Value::Obj(train));

    let path = dir.join(key.file_name());
    std::fs::write(&path, Value::Obj(root).render())?;
    Ok(path)
}

/// Load the entry for `key` from `dir`.  Never fails: a missing file is a
/// silent [`WarmLoad::Cold`]; a mismatched or corrupt file warns on stderr
/// and falls back to [`WarmLoad::Cold`].
pub fn load(dir: &Path, key: &WarmKey, space: &SearchSpace) -> WarmLoad {
    let path = dir.join(key.file_name());
    if !path.exists() {
        return WarmLoad::Cold;
    }
    match try_load(&path, key, space) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("warning: ignoring warm-start file {}: {e}", path.display());
            WarmLoad::Cold
        }
    }
}

fn try_load(path: &Path, key: &WarmKey, space: &SearchSpace) -> Result<WarmLoad> {
    let text = std::fs::read_to_string(path)?;
    let v = Value::parse(&text)?;
    let format = v.get("format")?.as_usize()?;
    eyre::ensure!(format == 1, "unknown warm-start format {format}");

    let k = v.get("key")?;
    let model = k.get("model")?.as_str()?;
    let methods = k.get("methods")?.as_str()?;
    eyre::ensure!(
        model == key.model && methods == key.methods,
        "key mismatch: file is for model `{model}` methods `{methods}`, \
         this run is model `{}` methods `{}`",
        key.model,
        key.methods
    );
    let exact = k.get("n_init")?.as_usize()? == key.n_init
        && k.get("iterations")?.as_usize()? == key.iterations
        && k.get("candidates_per_iter")?.as_usize()? == key.candidates_per_iter
        && k.get("pop_size")?.as_usize()? == key.pop_size
        && k.get("generations")?.as_usize()? == key.generations
        && read_u64_pair(k, "seed_hi", "seed_lo")? == key.seed
        && k.get("predictor")?.as_str()? == key.predictor
        && read_u64_pair(k, "ucb_kappa_bits_hi", "ucb_kappa_bits_lo")? == key.ucb_kappa.to_bits();

    let arc = v.get("archive")?;
    let mut archive = Archive::new();
    for s in arc.get("samples")?.as_arr()? {
        let config: Vec<u16> = s
            .get("config")?
            .as_arr()?
            .iter()
            .map(|g| {
                let g = g.as_u64()?;
                eyre::ensure!(g <= u16::MAX as u64, "gene out of range: {g}");
                Ok(g as u16)
            })
            .collect::<Result<_>>()?;
        eyre::ensure!(
            space.contains(&config),
            "sample outside the search space: {config:?}"
        );
        let jsd = f32::from_bits(read_u32(s, "jsd_bits")?);
        let bits = space.avg_bits(&config);
        eyre::ensure!(archive.insert(config, jsd, bits), "duplicate sample");
    }
    let stored = read_u64_pair(arc, "hash_hi", "hash_lo")?;
    let recomputed = archive.content_hash();
    eyre::ensure!(
        recomputed == stored,
        "content hash mismatch: stored {stored:#018x}, recomputed {recomputed:#018x}"
    );

    let train = v.get("train")?;
    let train_x: Vec<Vec<f32>> = train
        .get("x_bits")?
        .as_arr()?
        .iter()
        .map(|row| {
            row.as_arr()?
                .iter()
                .map(|b| {
                    let b = b.as_u64()?;
                    eyre::ensure!(b <= u32::MAX as u64, "feature bits out of range");
                    Ok(f32::from_bits(b as u32))
                })
                .collect::<Result<Vec<f32>>>()
        })
        .collect::<Result<_>>()?;
    let train_y: Vec<f32> = train
        .get("y_bits")?
        .as_arr()?
        .iter()
        .map(|b| {
            let b = b.as_u64()?;
            eyre::ensure!(b <= u32::MAX as u64, "target bits out of range");
            Ok(f32::from_bits(b as u32))
        })
        .collect::<Result<_>>()?;
    eyre::ensure!(
        train_x.len() == archive.len() && train_y.len() == archive.len(),
        "training set size {} / {} disagrees with archive size {}",
        train_x.len(),
        train_y.len(),
        archive.len()
    );

    let entry = WarmEntry { archive, train_x, train_y };
    Ok(if exact {
        WarmLoad::Exact(entry)
    } else {
        WarmLoad::Seed(entry)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::space::toy_space;

    fn toy_archive(space: &SearchSpace, n: usize) -> Archive {
        let mut rng = crate::util::Rng::new(42);
        let mut a = Archive::new();
        while a.len() < n {
            let cfg = space.random_near(&mut rng, 3.0, 0.5);
            let jsd = rng.f64() as f32;
            let bits = space.avg_bits(&cfg);
            a.insert(cfg, jsd, bits);
        }
        a
    }

    fn key(model: &str) -> WarmKey {
        WarmKey::from_params(model, "hqq", &SearchParams::smoke())
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let dir = std::env::temp_dir().join("amq_warm_roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let space = toy_space(6);
        let a = toy_archive(&space, 12);
        let k = key("model-a");
        save(&dir, &k, &a, &space).unwrap();
        let WarmLoad::Exact(entry) = load(&dir, &k, &space) else {
            panic!("expected an exact hit");
        };
        assert_eq!(entry.archive.content_hash(), a.content_hash());
        // the persisted training set matches a fresh derivation, bitwise
        let active = space.active_layers();
        let pairs = entry.train_x.iter().zip(&entry.train_y);
        for (s, (x, &y)) in a.samples.iter().zip(pairs) {
            let fresh = space.features(&s.config, &active);
            assert_eq!(x.len(), fresh.len());
            for (got, want) in x.iter().zip(&fresh) {
                assert_eq!(got.to_bits(), want.to_bits());
            }
            assert_eq!(y.to_bits(), s.jsd.to_bits());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn different_budget_is_seed_tier() {
        let dir = std::env::temp_dir().join("amq_warm_seedtier");
        let _ = std::fs::remove_dir_all(&dir);
        let space = toy_space(6);
        let a = toy_archive(&space, 8);
        save(&dir, &key("model-a"), &a, &space).unwrap();
        let mut bigger = key("model-a");
        bigger.iterations += 10;
        match load(&dir, &bigger, &space) {
            WarmLoad::Seed(e) => assert_eq!(e.archive.len(), 8),
            _ => panic!("expected the seed tier"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_key_is_ignored() {
        let dir = std::env::temp_dir().join("amq_warm_mismatch");
        let _ = std::fs::remove_dir_all(&dir);
        let space = toy_space(6);
        let a = toy_archive(&space, 8);
        let ka = key("model-a");
        let kb = key("model-b");
        let written = save(&dir, &ka, &a, &space).unwrap();
        // missing file: silent cold start
        assert!(matches!(load(&dir, &kb, &space), WarmLoad::Cold));
        // a file parked under the wrong slot (copied/renamed by hand) is
        // detected by the embedded key and ignored with a warning
        std::fs::copy(&written, dir.join(kb.file_name())).unwrap();
        assert!(matches!(load(&dir, &kb, &space), WarmLoad::Cold));
        // the original slot still loads
        assert!(matches!(load(&dir, &ka, &space), WarmLoad::Exact(_)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_gene_falls_back_to_cold() {
        let dir = std::env::temp_dir().join("amq_warm_corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        let space = toy_space(4);
        // an archive holding a gene the space does not contain (corrupt
        // method byte 0x0F) — insert() takes anything, load must reject
        let mut a = Archive::new();
        a.insert(vec![0x0F03, 2, 3, 4], 0.1, 3.0);
        let k = key("model-a");
        save(&dir, &k, &a, &space).unwrap();
        assert!(matches!(load(&dir, &k, &space), WarmLoad::Cold));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_file_falls_back_to_cold() {
        let dir = std::env::temp_dir().join("amq_warm_trunc");
        let _ = std::fs::remove_dir_all(&dir);
        let space = toy_space(4);
        let k = key("model-a");
        let written = save(&dir, &k, &toy_archive(&space, 4), &space).unwrap();
        let text = std::fs::read_to_string(&written).unwrap();
        std::fs::write(&written, &text[..text.len() / 2]).unwrap();
        assert!(matches!(load(&dir, &k, &space), WarmLoad::Cold));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! Inference-speed cost model (Figures 1-bottom, 5, 8 analogs).
//!
//! The paper's speed results are single-batch token generation on GPUs,
//! which is *weight-streaming bound*: every generated token must read every
//! weight byte once.  We reproduce the figures' shape with a roofline
//! simulator — per token,
//!
//!   t = Σ_layers max(bytes_moved / BW, flops / F) + n_kernels * launch
//!
//! plus method-specific overheads: BitStack re-materializes every loaded
//! residual block per forward (extra reads + compute, the paper's Fig. 8
//! slowdown); group-wise *mixed* precision (Slim-LLM-style) pays an
//! irregular-access bandwidth derating (Fig. 5).  Absolute numbers are not
//! the claim — who wins and by what factor is (DESIGN.md §3).
//!
//! A `measured` path also exists: `exp::speed` times the real PJRT
//! executables for the FP16-vs-quant comparison on this CPU testbed.

use crate::data::Manifest;
use crate::quant::pack;

/// Hardware profile for the roofline.
#[derive(Clone, Debug)]
pub struct HwProfile {
    pub name: &'static str,
    pub mem_bw_gbs: f64,      // effective memory bandwidth
    pub flops_gflops: f64,    // dense f16 compute
    pub kernel_launch_us: f64,
    pub vram_mb: f64,
    /// Effective-bandwidth fraction under irregular (group-mixed) access.
    pub irregular_bw_frac: f64,
}

/// NVIDIA L40S-like profile (paper's Fig. 1/5).
pub const L40S: HwProfile = HwProfile {
    name: "L40S",
    mem_bw_gbs: 864.0,
    flops_gflops: 90_000.0,
    kernel_launch_us: 4.0,
    vram_mb: 46_068.0,
    irregular_bw_frac: 0.30,
};

/// NVIDIA RTX 3090-like profile (paper's Fig. 8 right).
pub const RTX3090: HwProfile = HwProfile {
    name: "RTX3090",
    mem_bw_gbs: 936.0,
    flops_gflops: 35_000.0,
    kernel_launch_us: 6.0,
    vram_mb: 24_268.0,
    irregular_bw_frac: 0.30,
};

/// Deployment variant being timed.
pub enum DeployKind<'a> {
    Fp16,
    /// One bit-width per linear layer (AMQ / GPTQ / AWQ kernels).
    LayerQuant(&'a [u8]),
    /// Group-wise mixed precision *within* layers at the same average bits
    /// (Slim-LLM-style irregular access).
    GroupMixed(f64),
    /// BitStack with `blocks[i]` residual blocks loaded per layer.
    BitStack(&'a [usize]),
    /// PB-LLM partial binarization at salient fraction rho.
    PbLlm(f64),
}

/// Scale factor applied to the subject model so the simulated workload has
/// LLM-like arithmetic intensity (our tiny-Llama divided by a 7B model's
/// layer sizes would be pure launch overhead).  The *ratios* between methods
/// are scale-invariant; we report at 7B-equivalent scale.
pub const SCALE_TO_7B: f64 = 6_476_005_376.0; // Llama-2-7B linear params

fn model_linear_params(m: &Manifest) -> f64 {
    m.total_linear_params() as f64
}

/// Per-token generation latency in seconds.
pub fn token_latency(hw: &HwProfile, m: &Manifest, kind: &DeployKind) -> f64 {
    let scale = SCALE_TO_7B / model_linear_params(m);
    let bw = hw.mem_bw_gbs * 1e9;
    let fl = hw.flops_gflops * 1e9;
    let launch = hw.kernel_launch_us * 1e-6;
    // fp-side params (embeddings/norms/head) always stream at fp16
    let fp_side_bytes = m.fp_side_params() as f64 * scale.sqrt() * 2.0;
    // attention/kv/softmax etc: approximate as 10% extra traffic + 4 kernels
    let misc = fp_side_bytes / bw + 4.0 * launch;

    let mut t = misc;
    for (li, l) in m.layers.iter().enumerate() {
        let params = l.params() as f64 * scale;
        let (bytes, flops, k_launch, bw_frac) = match kind {
            DeployKind::Fp16 => (params * 2.0, 2.0 * params, 1.0, 1.0),
            DeployKind::LayerQuant(bits) => {
                let b = bits[li];
                let code_bytes =
                    pack::packed_bytes(1 << 20, b) as f64 / (1u64 << 20) as f64 * params;
                let meta = params / m.group_size as f64 * 4.0; // fp16 s+z
                (code_bytes + meta, 2.0 * params, 1.0, 1.0)
            }
            DeployKind::GroupMixed(avg_bits) => {
                let code_bytes = params * avg_bits / 8.0;
                let meta = params / m.group_size as f64 * 6.0; // s+z+bit idx
                (code_bytes + meta, 2.0 * params, 1.0, hw.irregular_bw_frac)
            }
            DeployKind::BitStack(blocks) => {
                let nb = blocks[li] as f64;
                // per block: 1 bit/weight signs + rank-1 factors; each block
                // is read AND re-materialized into a f16 weight tile
                let sign_bytes = nb * params / 8.0;
                let factor_bytes = nb * (l.out_features + l.in_features) as f64
                    * scale.sqrt() * 2.0;
                let rebuild_flops = nb * params * 2.0;
                let rebuild_bytes = nb * params * 2.0; // write + re-read f16
                (
                    sign_bytes + factor_bytes + rebuild_bytes,
                    2.0 * params + rebuild_flops,
                    1.0 + nb, // one launch per block + matmul
                    1.0,
                )
            }
            DeployKind::PbLlm(rho) => {
                let bytes = params * (rho * 8.0 + (1.0 - rho) * 1.0) / 8.0
                    + params / m.group_size as f64 * 4.0;
                // sparse salient gather: derated bandwidth on that fraction
                (bytes, 2.0 * params, 2.0, 0.6 + 0.4 * (1.0 - rho))
            }
        };
        t += (bytes / (bw * bw_frac)).max(flops / fl) + k_launch * launch;
    }
    t
}

/// Median tokens/second for 128-token generation at batch 1 (paper metric).
pub fn tokens_per_sec(hw: &HwProfile, m: &Manifest, kind: &DeployKind) -> f64 {
    1.0 / token_latency(hw, m, kind)
}

/// Model memory at 7B-equivalent scale in MB (for "fits in VRAM" checks).
pub fn model_memory_mb(m: &Manifest, kind: &DeployKind) -> f64 {
    let scale = SCALE_TO_7B / model_linear_params(m);
    let fp_side = m.fp_side_params() as f64 * scale.sqrt() * 2.0;
    let mut bytes = fp_side;
    for (li, l) in m.layers.iter().enumerate() {
        let params = l.params() as f64 * scale;
        bytes += match kind {
            DeployKind::Fp16 => params * 2.0,
            DeployKind::LayerQuant(bits) => {
                params * bits[li] as f64 / 8.0 + params / m.group_size as f64 * 4.0
            }
            DeployKind::GroupMixed(avg) => {
                params * avg / 8.0 + params / m.group_size as f64 * 6.0
            }
            DeployKind::BitStack(blocks) => {
                blocks[li] as f64
                    * (params / 8.0
                        + (l.out_features + l.in_features) as f64 * scale.sqrt() * 2.0)
            }
            DeployKind::PbLlm(rho) => {
                params * (rho * 8.0 + (1.0 - rho)) / 8.0
                    + params / m.group_size as f64 * 4.0
            }
        };
    }
    bytes / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        Manifest::from_json(
            r#"{
            "model": {"vocab_size": 512, "d_model": 128, "n_layers": 2,
                      "n_heads": 4, "d_ff": 256, "seq_len": 128,
                      "rope_theta": 10000.0, "rms_eps": 1e-5},
            "group_size": 128, "bit_choices": [2,3,4], "eval_batch": 16,
            "layers": [
                {"name": "blk0.q", "out_features": 128, "in_features": 128},
                {"name": "blk0.down", "out_features": 128, "in_features": 256},
                {"name": "blk1.q", "out_features": 128, "in_features": 128},
                {"name": "blk1.down", "out_features": 128, "in_features": 256}
            ],
            "fp_side_names": [], "executables": {}, "files": {}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn quant_faster_than_fp16() {
        let m = toy_manifest();
        let bits = vec![4u8; 4];
        let fp = tokens_per_sec(&L40S, &m, &DeployKind::Fp16);
        let q4 = tokens_per_sec(&L40S, &m, &DeployKind::LayerQuant(&bits));
        assert!(q4 > fp * 1.5, "4-bit {q4} vs fp16 {fp}");
        // speedup bounded by the bandwidth ratio (16/4 = 4x + overheads)
        assert!(q4 < fp * 4.5);
    }

    #[test]
    fn lower_bits_faster() {
        let m = toy_manifest();
        let b2 = vec![2u8; 4];
        let b4 = vec![4u8; 4];
        let t2 = tokens_per_sec(&L40S, &m, &DeployKind::LayerQuant(&b2));
        let t4 = tokens_per_sec(&L40S, &m, &DeployKind::LayerQuant(&b4));
        assert!(t2 > t4);
    }

    #[test]
    fn group_mixed_slower_than_layerwise() {
        // Fig. 5's claim: same avg bits, irregular access loses.
        let m = toy_manifest();
        let bits = vec![3u8; 4];
        let lw = tokens_per_sec(&L40S, &m, &DeployKind::LayerQuant(&bits));
        let gm = tokens_per_sec(&L40S, &m, &DeployKind::GroupMixed(3.0));
        assert!(lw > gm * 1.5, "{lw} vs {gm}");
    }

    #[test]
    fn bitstack_slower_than_quant_at_same_memory() {
        // Fig. 8's claim: reconstruction overhead dominates.
        let m = toy_manifest();
        let bits = vec![3u8; 4];
        let blocks = vec![3usize; 4]; // ~3 bits/weight worth of blocks
        let q = tokens_per_sec(&L40S, &m, &DeployKind::LayerQuant(&bits));
        let bs = tokens_per_sec(&L40S, &m, &DeployKind::BitStack(&blocks));
        assert!(q > bs * 1.3, "{q} vs {bs}");
    }

    #[test]
    fn memory_ordering() {
        let m = toy_manifest();
        let b2 = vec![2u8; 4];
        let b4 = vec![4u8; 4];
        let m2 = model_memory_mb(&m, &DeployKind::LayerQuant(&b2));
        let m4 = model_memory_mb(&m, &DeployKind::LayerQuant(&b4));
        let mf = model_memory_mb(&m, &DeployKind::Fp16);
        assert!(m2 < m4 && m4 < mf);
    }

    #[test]
    fn fp16_7b_speed_plausible() {
        // sanity: 7B fp16 on L40S-like ~ 40-80 tok/s (paper Fig. 5: ~45)
        let m = toy_manifest();
        let fp = tokens_per_sec(&L40S, &m, &DeployKind::Fp16);
        assert!(fp > 25.0 && fp < 120.0, "{fp}");
    }
}

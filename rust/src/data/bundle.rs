//! Tensor-bundle container reader (`io_utils.write_bundle` counterpart).

use super::json::Value;
use crate::Result;
use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

struct Entry {
    name: String,
    dtype: String,
    shape: Vec<usize>,
    offset: usize, // bytes into the data section
}

fn parse_header(bytes: &[u8]) -> Result<Vec<Entry>> {
    let text = std::str::from_utf8(bytes)
        .map_err(|_| eyre::anyhow!("bundle header is not utf-8"))?;
    let v = Value::parse(text)?;
    v.get("tensors")?
        .as_arr()?
        .iter()
        .map(|e| {
            Ok(Entry {
                name: e.get("name")?.as_str()?.to_string(),
                dtype: e.get("dtype")?.as_str()?.to_string(),
                shape: e
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|s| s.as_usize())
                    .collect::<Result<Vec<_>>>()?,
                offset: e.get("offset")?.as_usize()?,
            })
        })
        .collect()
}

/// One tensor from a bundle, decoded to its native element type.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub payload: Payload,
}

#[derive(Clone, Debug)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U16(Vec<u16>),
    I8(Vec<i8>),
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.payload {
            Payload::F32(v) => Ok(v),
            other => eyre::bail!("expected f32 tensor, got {other:?}"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.payload {
            Payload::I32(v) => Ok(v),
            other => eyre::bail!("expected i32 tensor, got {other:?}"),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.payload {
            Payload::I8(v) => Ok(v),
            other => eyre::bail!("expected i8 tensor, got {other:?}"),
        }
    }
}

/// A parsed bundle: name -> tensor.
pub struct Bundle {
    tensors: HashMap<String, Tensor>,
}

impl Bundle {
    pub fn read(path: &Path) -> Result<Bundle> {
        let mut file = std::fs::File::open(path)
            .map_err(|e| eyre::anyhow!("open {}: {e}", path.display()))?;
        let mut len_buf = [0u8; 4];
        file.read_exact(&mut len_buf)?;
        let hlen = u32::from_le_bytes(len_buf) as usize;
        let mut hbuf = vec![0u8; hlen];
        file.read_exact(&mut hbuf)?;
        let header = parse_header(&hbuf)?;
        let mut data = Vec::new();
        file.read_to_end(&mut data)?;

        let mut tensors = HashMap::new();
        for e in header {
            let numel: usize = e.shape.iter().product();
            let payload = match e.dtype.as_str() {
                "f32" => Payload::F32(read_slice::<4, f32>(
                    &data, e.offset, numel, f32::from_le_bytes)?),
                "i32" => Payload::I32(read_slice::<4, i32>(
                    &data, e.offset, numel, i32::from_le_bytes)?),
                "u16" => Payload::U16(read_slice::<2, u16>(
                    &data, e.offset, numel, u16::from_le_bytes)?),
                "i8" => {
                    let end = e.offset + numel;
                    eyre::ensure!(end <= data.len(), "i8 tensor out of range");
                    Payload::I8(data[e.offset..end].iter().map(|&b| b as i8).collect())
                }
                other => eyre::bail!("unknown dtype {other}"),
            };
            tensors.insert(e.name, Tensor { shape: e.shape, payload });
        }
        Ok(Bundle { tensors })
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| eyre::anyhow!("tensor `{name}` not in bundle"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }
}

fn read_slice<const N: usize, T>(
    data: &[u8],
    offset: usize,
    numel: usize,
    from_le: fn([u8; N]) -> T,
) -> Result<Vec<T>> {
    let end = offset + numel * N;
    eyre::ensure!(end <= data.len(),
        "tensor out of range: offset {offset} + {numel}*{N} > {}", data.len());
    Ok(data[offset..end]
        .chunks_exact(N)
        .map(|c| from_le(c.try_into().unwrap()))
        .collect())
}

//! Minimal JSON parser + serializer — substrate for manifest/tasks/bundle
//! headers and the evaluation pool's wire frames ([`crate::runtime::wire`]).
//!
//! The offline build has no serde, so we parse the (entirely under our
//! control) artifact JSON with a small recursive-descent parser.  Supports
//! the full JSON grammar; numbers are f64 (all our integers fit exactly).
//!
//! Serialization ([`Value::render`]) is deterministic by construction:
//! objects are `BTreeMap`s, so keys always render in sorted order and the
//! same `Value` renders to the same bytes on every host — the property the
//! wire format's cross-version layout guard pins.

use crate::Result;
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        eyre::ensure!(p.pos == p.bytes.len(), "trailing garbage at {}", p.pos);
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m
                .get(key)
                .ok_or_else(|| eyre::anyhow!("missing key `{key}`")),
            _ => eyre::bail!("not an object (looking up `{key}`)"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => eyre::bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => eyre::bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        eyre::ensure!(f >= 0.0 && f.fract() == 0.0, "not a usize: {f}");
        Ok(f as usize)
    }

    pub fn as_i32(&self) -> Result<i32> {
        let f = self.as_f64()?;
        eyre::ensure!(f.fract() == 0.0, "not an int: {f}");
        Ok(f as i32)
    }

    /// Exact non-negative integer accessor.  Only integers up to 2^53 are
    /// representable exactly in a JSON number; larger values are rejected
    /// rather than silently rounded (wire ids / bit patterns stay exact).
    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        eyre::ensure!(
            f >= 0.0 && f.fract() == 0.0 && f <= 9_007_199_254_740_992.0,
            "not an exact u64: {f}"
        );
        Ok(f as u64)
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => eyre::bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => eyre::bail!("not an object"),
        }
    }

    /// Serialize to compact JSON (no whitespace).  Deterministic: object
    /// keys render in `BTreeMap` order, integers that fit f64 exactly print
    /// without a fractional part, and non-finite numbers (which JSON cannot
    /// carry) render as `null`.  `parse(render(v))` round-trips every value
    /// the parser can produce.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        use std::fmt::Write as _;
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() <= 9_007_199_254_740_992.0 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    // f64 Display is shortest-roundtrip in Rust, so the
                    // rendered text parses back to the identical f64.
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => render_str(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Value::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_str(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Escape + quote a string, the exact inverse of the parser's unescaping.
fn render_str(s: &str, out: &mut String) {
    use std::fmt::Write as _;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| eyre::anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        eyre::ensure!(got == b, "expected `{}` got `{}` at {}",
                      b as char, got as char, self.pos - 1);
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(_) => self.number(),
            None => eyre::bail!("unexpected end of JSON"),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        let end = self.pos + word.len();
        eyre::ensure!(
            end <= self.bytes.len() && &self.bytes[self.pos..end] == word.as_bytes(),
            "bad literal at {}", self.pos
        );
        self.pos = end;
        Ok(v)
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => eyre::bail!("expected , or }} got `{}`", c as char),
            }
        }
        Ok(Value::Obj(map))
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => eyre::bail!("expected , or ] got `{}`", c as char),
            }
        }
        Ok(Value::Arr(out))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => break,
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| eyre::anyhow!("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    c => eyre::bail!("bad escape \\{}", c as char),
                },
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: collect the sequence
                    let extra = match c {
                        0xC0..=0xDF => 1,
                        0xE0..=0xEF => 2,
                        _ => 3,
                    };
                    let start = self.pos - 1;
                    for _ in 0..extra {
                        self.bump()?;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| eyre::anyhow!("bad utf8 in string"))?,
                    );
                }
            }
        }
        Ok(s)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text
            .parse()
            .map_err(|_| eyre::anyhow!("bad number `{text}` at {start}"))?;
        Ok(Value::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(
            Value::parse(r#""a\nb\"c""#).unwrap(),
            Value::Str("a\nb\"c".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x");
        assert!(v.get("c").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn unicode_escape_and_utf8() {
        assert_eq!(
            Value::parse(r#""é""#).unwrap(),
            Value::Str("é".into())
        );
        assert_eq!(Value::parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integer_accessors() {
        let v = Value::parse(r#"{"n": 128, "x": 1.5}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 128);
        assert!(v.get("x").unwrap().as_usize().is_err());
        assert_eq!(v.get("n").unwrap().as_i32().unwrap(), 128);
    }

    #[test]
    fn u64_accessor_is_exact() {
        assert_eq!(Value::Num(0.0).as_u64().unwrap(), 0);
        assert_eq!(Value::Num(4294967295.0).as_u64().unwrap(), u32::MAX as u64);
        assert!(Value::Num(-1.0).as_u64().is_err());
        assert!(Value::Num(1.5).as_u64().is_err());
        assert!(Value::Num(1e300).as_u64().is_err(), "beyond exact range");
        assert!(Value::Str("7".into()).as_u64().is_err());
    }

    #[test]
    fn render_scalars() {
        assert_eq!(Value::Null.render(), "null");
        assert_eq!(Value::Bool(true).render(), "true");
        assert_eq!(Value::Num(42.0).render(), "42");
        assert_eq!(Value::Num(-150.0).render(), "-150");
        assert_eq!(Value::Num(1.5).render(), "1.5");
        assert_eq!(Value::Num(f64::NAN).render(), "null");
        assert_eq!(Value::Str("a\nb\"c\\d".into()).render(), r#""a\nb\"c\\d""#);
        assert_eq!(Value::Str("\u{0001}".into()).render(), r#""\u0001""#);
    }

    #[test]
    fn render_is_deterministic_and_sorted() {
        // insertion order differs from key order; render must sort
        let mut m = BTreeMap::new();
        m.insert("zebra".to_string(), Value::Num(1.0));
        m.insert("alpha".to_string(), Value::Arr(vec![Value::Num(2.0), Value::Null]));
        let v = Value::Obj(m);
        assert_eq!(v.render(), r#"{"alpha":[2,null],"zebra":1}"#);
        assert_eq!(v.render(), v.render());
    }

    #[test]
    fn render_parse_round_trip() {
        let texts = [
            r#"{"a": [1, 2, {"b": "x"}], "c": {}, "d": -1.5e2, "e": "héllo"}"#,
            r#"[[], [null, true, false], "é", 9007199254740992]"#,
            "0.125",
        ];
        for t in texts {
            let v = Value::parse(t).unwrap();
            let rendered = v.render();
            let back = Value::parse(&rendered).unwrap();
            assert_eq!(v, back, "round trip changed value for {t}");
            // a second render of the reparsed value is byte-identical
            assert_eq!(rendered, back.render());
        }
    }

    #[test]
    fn render_f32_bits_survive_via_u32() {
        // the wire format carries f32 scores as their u32 bit patterns;
        // every u32 is exact in f64, so render->parse is lossless
        for bits in [0u32, 1, 0x7F80_0000, 0xFFC0_0001, u32::MAX, 0x3F80_0000] {
            let v = Value::Num(bits as f64);
            let back = Value::parse(&v.render()).unwrap().as_u64().unwrap();
            assert_eq!(back as u32, bits);
        }
    }
}

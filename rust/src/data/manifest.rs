//! `artifacts/manifest.json` — the contract between the python compile path
//! and the rust coordinator (shapes, argument orders, file names).

use super::json::Value;
use crate::Result;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
}

#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub name: String,
    pub out_features: usize,
    pub in_features: usize,
}

impl LayerSpec {
    pub fn params(&self) -> usize {
        self.out_features * self.in_features
    }

    pub fn n_groups(&self, group_size: usize) -> usize {
        debug_assert_eq!(self.in_features % group_size, 0);
        self.in_features / group_size
    }

    /// Per-block linear kind ("q" | ... | "down").
    pub fn kind(&self) -> &str {
        self.name.split('.').nth(1).unwrap_or("?")
    }

    /// Block index.
    pub fn block(&self) -> usize {
        self.name
            .trim_start_matches("blk")
            .split('.')
            .next()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0)
    }
}

/// One AOT-lowered HLO executable: file name, flat argument-name order
/// (the contract [`crate::runtime`] plans argument slots from) and output
/// names.
#[derive(Clone, Debug)]
pub struct ExecutableSpec {
    /// HLO-text file name, relative to the artifacts directory.
    pub file: String,
    /// Flat argument names in executable parameter order.
    pub args: Vec<String>,
    /// Output names, tuple order.
    pub outputs: Vec<String>,
    /// Candidate-lane count of a lane-stacked executable (the leading axis
    /// its quant-slot arguments carry); `None` for single-candidate
    /// executables.
    pub lanes: Option<usize>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: ModelSpec,
    pub group_size: usize,
    pub bit_choices: Vec<u8>,
    /// Quantization methods the search genome may assign per layer
    /// (names understood by `quant::registry`).  Optional in the JSON;
    /// defaults to the single-method HQQ proxy (the legacy genome).
    pub methods: Vec<String>,
    pub eval_batch: usize,
    pub layers: Vec<LayerSpec>,
    pub fp_side_names: Vec<String>,
    pub executables: HashMap<String, ExecutableSpec>,
    pub files: HashMap<String, String>,
    pub special_tokens: HashMap<String, u32>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            eyre::anyhow!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            )
        })?;
        let mut m = Self::from_json(&text)?;
        m.dir = artifacts_dir.to_path_buf();
        Ok(m)
    }

    pub fn from_json(text: &str) -> Result<Manifest> {
        let v = Value::parse(text)?;
        let mv = v.get("model")?;
        let model = ModelSpec {
            vocab_size: mv.get("vocab_size")?.as_usize()?,
            d_model: mv.get("d_model")?.as_usize()?,
            n_layers: mv.get("n_layers")?.as_usize()?,
            n_heads: mv.get("n_heads")?.as_usize()?,
            d_ff: mv.get("d_ff")?.as_usize()?,
            seq_len: mv.get("seq_len")?.as_usize()?,
            rope_theta: mv.get("rope_theta")?.as_f64()?,
            rms_eps: mv.get("rms_eps")?.as_f64()?,
        };
        let layers = v
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| {
                Ok(LayerSpec {
                    name: l.get("name")?.as_str()?.to_string(),
                    out_features: l.get("out_features")?.as_usize()?,
                    in_features: l.get("in_features")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let fp_side_names = v
            .get("fp_side_names")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let mut executables = HashMap::new();
        for (k, e) in v.get("executables")?.as_obj()? {
            executables.insert(
                k.clone(),
                ExecutableSpec {
                    file: e.get("file")?.as_str()?.to_string(),
                    args: e
                        .get("args")?
                        .as_arr()?
                        .iter()
                        .map(|a| Ok(a.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                    outputs: e
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(|a| Ok(a.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                    lanes: match e.opt("lanes") {
                        Some(l) => Some(l.as_usize()?),
                        None => None,
                    },
                },
            );
        }
        let mut files = HashMap::new();
        for (k, f) in v.get("files")?.as_obj()? {
            files.insert(k.clone(), f.as_str()?.to_string());
        }
        let mut special_tokens = HashMap::new();
        if let Some(st) = v.opt("special_tokens") {
            for (k, t) in st.as_obj()? {
                special_tokens.insert(k.clone(), t.as_usize()? as u32);
            }
        }
        let methods = match v.opt("methods") {
            Some(ms) => ms
                .as_arr()?
                .iter()
                .map(|m| Ok(m.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            None => vec!["hqq".to_string()],
        };
        Ok(Manifest {
            model,
            group_size: v.get("group_size")?.as_usize()?,
            bit_choices: v
                .get("bit_choices")?
                .as_arr()?
                .iter()
                .map(|b| Ok(b.as_usize()? as u8))
                .collect::<Result<Vec<_>>>()?,
            methods,
            eval_batch: v.get("eval_batch")?.as_usize()?,
            layers,
            fp_side_names,
            executables,
            files,
            special_tokens,
            dir: PathBuf::new(),
        })
    }

    pub fn file(&self, key: &str) -> Result<PathBuf> {
        let name = self
            .files
            .get(key)
            .ok_or_else(|| eyre::anyhow!("no file entry `{key}` in manifest"))?;
        Ok(self.dir.join(name))
    }

    pub fn executable(&self, key: &str) -> Result<&ExecutableSpec> {
        self.executables
            .get(key)
            .ok_or_else(|| eyre::anyhow!("no executable `{key}` in manifest"))
    }

    pub fn hlo_path(&self, key: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.executable(key)?.file))
    }

    pub fn layer(&self, name: &str) -> Result<&LayerSpec> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .ok_or_else(|| eyre::anyhow!("unknown layer `{name}`"))
    }

    pub fn layer_index(&self, name: &str) -> Option<usize> {
        self.layers.iter().position(|l| l.name == name)
    }

    /// Lane count of the lane-stacked scorer executable
    /// (`scores_quant_lanes`), when the artifacts carry one.  `None` means
    /// the runtime must score candidates one executable call at a time.
    pub fn scorer_lanes(&self) -> Option<usize> {
        self.executables
            .get("scores_quant_lanes")
            .and_then(|e| e.lanes)
            .filter(|&l| l > 1)
    }

    /// Manifest key of the slab-gather executable for a quant-slot shape
    /// family (`out_features` x `in_features`).
    pub fn gather_key(n: usize, k: usize) -> String {
        format!("gather_lanes_{n}x{k}")
    }

    /// Lane count of the device-side slab-gather executables
    /// (`gather_lanes_{n}x{k}`), when the artifacts carry them.  All
    /// families must agree on the lane count; `None` means lane-slab
    /// cache misses must take the host pack + upload path.
    pub fn gather_lanes(&self) -> Option<usize> {
        let mut lanes = None;
        for (key, e) in &self.executables {
            if !key.starts_with("gather_lanes_") {
                continue;
            }
            let l = e.lanes.filter(|&l| l > 1)?;
            match lanes {
                None => lanes = Some(l),
                Some(prev) if prev != l => return None,
                Some(_) => {}
            }
        }
        lanes
    }

    /// The slab-gather executable for one shape family, if present.
    pub fn gather_executable(&self, n: usize, k: usize) -> Option<&ExecutableSpec> {
        self.executables.get(&Self::gather_key(n, k))
    }

    /// Distinct quant-slot shape families `(out_features, in_features)`
    /// across the searchable layers, sorted.  One slab-gather executable
    /// exists per family (static HLO shapes).
    pub fn shape_families(&self) -> Vec<(usize, usize)> {
        let mut fams: Vec<_> = self
            .layers
            .iter()
            .map(|l| (l.out_features, l.in_features))
            .collect();
        fams.sort_unstable();
        fams.dedup();
        fams
    }

    pub fn pad_token(&self) -> i32 {
        self.special_tokens.get("pad").copied().unwrap_or(0) as i32
    }

    /// Total searchable parameters (the denominator of average-bits).
    pub fn total_linear_params(&self) -> usize {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Parameters that stay fp16 at deploy time (embeddings, norms, head).
    pub fn fp_side_params(&self) -> usize {
        let d = self.model.d_model;
        let v = self.model.vocab_size;
        // embed + lm_head + final_norm + 2 norms per block
        2 * v * d + d + 2 * self.model.n_layers * d
    }
}

/// A small hand-written manifest for unit tests across the crate.
#[cfg(test)]
pub fn toy_manifest() -> Manifest {
    Manifest::from_json(
        r#"{
        "model": {"vocab_size": 512, "d_model": 128, "n_layers": 2,
                  "n_heads": 4, "d_ff": 256, "seq_len": 128,
                  "rope_theta": 10000.0, "rms_eps": 1e-5},
        "group_size": 128,
        "bit_choices": [2, 3, 4],
        "eval_batch": 16,
        "layers": [
            {"name": "blk0.q", "out_features": 128, "in_features": 128},
            {"name": "blk0.down", "out_features": 128, "in_features": 256},
            {"name": "blk1.q", "out_features": 128, "in_features": 128},
            {"name": "blk1.down", "out_features": 128, "in_features": 256}
        ],
        "fp_side_names": ["embed"],
        "executables": {
            "model_fp": {"file": "model_fp.hlo.txt",
                         "args": ["tokens"], "outputs": ["logits"]}
        },
        "files": {"weights": "weights.bin"},
        "special_tokens": {"pad": 396}
    }"#,
    )
    .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_accessors() {
        let m = toy_manifest();
        assert_eq!(m.layer("blk0.down").unwrap().n_groups(128), 2);
        assert_eq!(m.layer("blk0.q").unwrap().kind(), "q");
        assert_eq!(m.layer("blk1.down").unwrap().block(), 1);
        assert_eq!(m.layer_index("blk0.down"), Some(1));
        assert_eq!(m.shape_families(), vec![(128, 128), (128, 256)]);
        assert!(m.layer("nope").is_err());
        assert_eq!(m.total_linear_params(), 2 * (128 * 128 + 128 * 256));
        assert_eq!(m.pad_token(), 396);
    }

    #[test]
    fn methods_default_to_single_hqq() {
        let m = toy_manifest();
        assert_eq!(m.methods, vec!["hqq".to_string()]);
    }

    #[test]
    fn scorer_lanes_absent_without_lane_executable() {
        // legacy manifests (no scores_quant_lanes entry) -> per-candidate
        let m = toy_manifest();
        assert_eq!(m.scorer_lanes(), None);
        assert_eq!(m.executable("model_fp").unwrap().lanes, None);
    }

    #[test]
    fn scorer_lanes_parsed_from_lane_executable() {
        let m = Manifest::from_json(
            r#"{
            "model": {"vocab_size": 512, "d_model": 128, "n_layers": 1,
                      "n_heads": 4, "d_ff": 256, "seq_len": 128,
                      "rope_theta": 10000.0, "rms_eps": 1e-5},
            "group_size": 128, "bit_choices": [2,3,4], "eval_batch": 16,
            "layers": [{"name": "blk0.q", "out_features": 128, "in_features": 128}],
            "fp_side_names": ["embed"],
            "executables": {
                "scores_quant_lanes": {"file": "scores_quant_lanes8.hlo.txt",
                                       "args": ["tokens"], "outputs": ["jsd", "ce"],
                                       "lanes": 8}
            },
            "files": {}
        }"#,
        )
        .unwrap();
        assert_eq!(m.scorer_lanes(), Some(8));
        assert_eq!(m.executable("scores_quant_lanes").unwrap().lanes, Some(8));
    }

    #[test]
    fn gather_lanes_absent_without_gather_executables() {
        let m = toy_manifest();
        assert_eq!(m.gather_lanes(), None);
        assert!(m.gather_executable(128, 128).is_none());
    }

    #[test]
    fn gather_lanes_parsed_and_validated() {
        let base = r#"{
            "model": {"vocab_size": 512, "d_model": 128, "n_layers": 1,
                      "n_heads": 4, "d_ff": 256, "seq_len": 128,
                      "rope_theta": 10000.0, "rms_eps": 1e-5},
            "group_size": 128, "bit_choices": [2,3,4], "eval_batch": 16,
            "layers": [{"name": "blk0.q", "out_features": 128, "in_features": 128}],
            "fp_side_names": ["embed"],
            "executables": {EXECS},
            "files": {}
        }"#;
        let gather = |n: usize, k: usize, lanes: usize| {
            format!(
                r#""gather_lanes_{n}x{k}": {{
                    "file": "gather_lanes{lanes}_{n}x{k}.hlo.txt",
                    "args": ["lane0.codes", "lane0.scale", "lane0.zero"],
                    "outputs": ["codes", "scale", "zero"], "lanes": {lanes}}}"#
            )
        };
        // two families, agreeing lane counts
        let execs = format!("{{{}, {}}}", gather(128, 128, 8), gather(128, 256, 8));
        let m = Manifest::from_json(&base.replace("{EXECS}", &execs)).unwrap();
        assert_eq!(m.gather_lanes(), Some(8));
        assert_eq!(Manifest::gather_key(128, 256), "gather_lanes_128x256");
        assert!(m.gather_executable(128, 128).is_some());
        assert!(m.gather_executable(256, 128).is_none());
        // disagreeing lane counts -> treated as no usable gather artifact
        let execs = format!("{{{}, {}}}", gather(128, 128, 8), gather(128, 256, 4));
        let m = Manifest::from_json(&base.replace("{EXECS}", &execs)).unwrap();
        assert_eq!(m.gather_lanes(), None);
        // lanes <= 1 -> not a lane-stacked gather
        let execs = format!("{{{}}}", gather(128, 128, 1));
        let m = Manifest::from_json(&base.replace("{EXECS}", &execs)).unwrap();
        assert_eq!(m.gather_lanes(), None);
    }

    #[test]
    fn file_paths() {
        let m = toy_manifest();
        assert!(m.file("weights").unwrap().ends_with("weights.bin"));
        assert!(m.file("nope").is_err());
        assert!(m.hlo_path("model_fp").unwrap().ends_with("model_fp.hlo.txt"));
        assert_eq!(m.executable("model_fp").unwrap().args, vec!["tokens"]);
    }
}

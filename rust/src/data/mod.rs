//! Artifact loaders — the rust mirror of `python/compile/io_utils.py`.
//!
//! Bundle container: `[u32 header_len][JSON header][raw data]`, with byte
//! offsets into the data section and dtypes `f32 | i32 | u16 | i8`.

pub mod json;

mod bundle;
pub mod manifest;
mod tasks;

pub use bundle::{Bundle, Payload, Tensor};
pub use manifest::{ExecutableSpec, LayerSpec, Manifest, ModelSpec};
pub use tasks::{load_tasks, TaskInstance, FEW_SHOT, ZERO_SHOT};

use crate::Result;
use std::path::Path;

/// A token split: `[n_seqs, seq_len]` i32 row-major.
#[derive(Clone, Debug)]
pub struct TokenSplit {
    pub n_seqs: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
}

impl TokenSplit {
    pub fn seq(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }

    /// Borrow a contiguous batch of `n` sequences starting at `start`.
    pub fn batch(&self, start: usize, n: usize) -> &[i32] {
        &self.tokens[start * self.seq_len..(start + n) * self.seq_len]
    }
}

/// Load a token split from a bundle file containing a single `tokens` tensor.
pub fn load_tokens(path: &Path) -> Result<TokenSplit> {
    let bundle = Bundle::read(path)?;
    let t = bundle.tensor("tokens")?;
    eyre::ensure!(t.shape.len() == 2, "tokens must be 2-D, got {:?}", t.shape);
    Ok(TokenSplit {
        n_seqs: t.shape[0],
        seq_len: t.shape[1],
        tokens: t.as_i32()?.to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_test_bundle(path: &Path) {
        // header: one i32 tensor "tokens" [2,3] followed by one f32 "w" [2]
        let toks: [i32; 6] = [1, 2, 3, 4, 5, 6];
        let w: [f32; 2] = [0.5, -1.5];
        let header = r#"{"tensors": [
            {"name": "tokens", "dtype": "i32", "shape": [2, 3], "offset": 0},
            {"name": "w", "dtype": "f32", "shape": [2], "offset": 24}
        ]}"#;
        let hbytes = header.as_bytes().to_vec();
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(&(hbytes.len() as u32).to_le_bytes()).unwrap();
        f.write_all(&hbytes).unwrap();
        for t in toks {
            f.write_all(&t.to_le_bytes()).unwrap();
        }
        for x in w {
            f.write_all(&x.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn bundle_roundtrip() {
        let dir = std::env::temp_dir().join("amq_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        write_test_bundle(&path);
        let b = Bundle::read(&path).unwrap();
        assert_eq!(b.tensor("tokens").unwrap().as_i32().unwrap(),
                   &[1, 2, 3, 4, 5, 6]);
        assert_eq!(b.tensor("w").unwrap().as_f32().unwrap(), &[0.5, -1.5]);
        let split = load_tokens(&path).unwrap();
        assert_eq!(split.n_seqs, 2);
        assert_eq!(split.seq(1), &[4, 5, 6]);
        assert_eq!(split.batch(0, 2).len(), 6);
    }
}

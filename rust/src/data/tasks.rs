//! Task-instance loader (`tasks.json`) — the zero-/few-shot benchmark suite.

use super::json::Value;
use crate::Result;
use std::path::Path;

/// One multiple-choice instance, scored by length-normalized choice logprob
/// (the LM-Eval-Harness protocol the paper uses).
#[derive(Clone, Debug)]
pub struct TaskInstance {
    pub family: String,
    pub context: Vec<i32>,
    pub choices: Vec<Vec<i32>>,
    pub answer: usize,
}

pub fn load_tasks(path: &Path) -> Result<Vec<TaskInstance>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| eyre::anyhow!("read {}: {e}", path.display()))?;
    let v = Value::parse(&text)?;
    let toks = |val: &Value| -> Result<Vec<i32>> {
        val.as_arr()?.iter().map(|t| t.as_i32()).collect()
    };
    let tasks = v
        .as_arr()?
        .iter()
        .map(|t| {
            Ok(TaskInstance {
                family: t.get("family")?.as_str()?.to_string(),
                context: toks(t.get("context")?)?,
                choices: t
                    .get("choices")?
                    .as_arr()?
                    .iter()
                    .map(&toks)
                    .collect::<Result<Vec<_>>>()?,
                answer: t.get("answer")?.as_usize()?,
            })
        })
        .collect::<Result<Vec<TaskInstance>>>()?;
    for (i, t) in tasks.iter().enumerate() {
        eyre::ensure!(!t.choices.is_empty(), "task {i}: no choices");
        eyre::ensure!(t.answer < t.choices.len(), "task {i}: bad answer idx");
    }
    Ok(tasks)
}

/// The six zero-shot families (Table 1 analog columns, in order).
pub const ZERO_SHOT: [&str; 6] =
    ["copy", "completion", "agreement", "majority", "induction", "recall"];

/// The two harder few-shot families (Table 2 analog).
pub const FEW_SHOT: [&str; 2] = ["chain", "modadd"];

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn load_and_validate() {
        let dir = std::env::temp_dir().join("amq_test_tasks");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tasks.json");
        let mut f = std::fs::File::create(&path).unwrap();
        write!(
            f,
            r#"[{{"family":"copy","context":[1,2],"choices":[[3],[4]],"answer":1}}]"#
        )
        .unwrap();
        let tasks = load_tasks(&path).unwrap();
        assert_eq!(tasks.len(), 1);
        assert_eq!(tasks[0].answer, 1);
    }

    #[test]
    fn reject_bad_answer() {
        let dir = std::env::temp_dir().join("amq_test_tasks2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tasks.json");
        std::fs::write(
            &path,
            r#"[{"family":"x","context":[1],"choices":[[2]],"answer":3}]"#,
        )
        .unwrap();
        assert!(load_tasks(&path).is_err());
    }
}

//! Rust mirror of the L1 JSD kernel — used on the baseline path (fp exec
//! returns raw logits) and as a cross-check of the fused scorer.

/// log-softmax of one row, in place into `out`.
fn log_softmax(row: &[f32], out: &mut [f32]) {
    let mut m = f32::NEG_INFINITY;
    for &v in row {
        m = m.max(v);
    }
    let mut lse = 0.0f32;
    for &v in row {
        lse += (v - m).exp();
    }
    let lse = lse.ln() + m;
    for (o, &v) in out.iter_mut().zip(row) {
        *o = v - lse;
    }
}

/// Per-token Jensen-Shannon divergence between two logit tensors
/// `[n_tokens, vocab]` (nats, in [0, ln 2]).
pub fn jsd_tokens(logits_p: &[f32], logits_q: &[f32], vocab: usize) -> Vec<f32> {
    assert_eq!(logits_p.len(), logits_q.len());
    assert_eq!(logits_p.len() % vocab, 0);
    let n = logits_p.len() / vocab;
    let mut out = vec![0.0f32; n];
    let mut lp = vec![0.0f32; vocab];
    let mut lq = vec![0.0f32; vocab];
    let ln2 = std::f32::consts::LN_2;
    for t in 0..n {
        let rp = &logits_p[t * vocab..(t + 1) * vocab];
        let rq = &logits_q[t * vocab..(t + 1) * vocab];
        log_softmax(rp, &mut lp);
        log_softmax(rq, &mut lq);
        let mut kl_pm = 0.0f32;
        let mut kl_qm = 0.0f32;
        for j in 0..vocab {
            let a = lp[j];
            let b = lq[j];
            // log m = logaddexp(a, b) - ln 2
            let (hi, lo) = if a > b { (a, b) } else { (b, a) };
            let logm = hi + (1.0 + (lo - hi).exp()).ln() - ln2;
            kl_pm += a.exp() * (a - logm);
            kl_qm += b.exp() * (b - logm);
        }
        out[t] = 0.5 * (kl_pm + kl_qm);
    }
    out
}

/// Masked mean JSD (mask per token, 1.0 = counts).
pub fn jsd_mean(logits_p: &[f32], logits_q: &[f32], vocab: usize, mask: &[f32]) -> f32 {
    let per = jsd_tokens(logits_p, logits_q, vocab);
    assert_eq!(per.len(), mask.len());
    let mut num = 0.0f32;
    let mut den = 0.0f32;
    for (j, m) in per.iter().zip(mask) {
        num += j * m;
        den += m;
    }
    num / den.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_logits_zero_jsd() {
        let p = vec![0.1f32, 2.0, -1.0, 0.5, 3.0, 0.0, 1.0, -2.0];
        let j = jsd_tokens(&p, &p, 4);
        for v in j {
            assert!(v.abs() < 1e-6);
        }
    }

    #[test]
    fn bounded_by_ln2() {
        // extreme opposite distributions approach ln 2
        let p = vec![100.0f32, 0.0, 0.0, 0.0];
        let q = vec![0.0f32, 0.0, 0.0, 100.0];
        let j = jsd_tokens(&p, &q, 4)[0];
        assert!(j <= std::f32::consts::LN_2 + 1e-5);
        assert!(j > 0.69);
    }

    #[test]
    fn symmetric() {
        let p = vec![0.3f32, -1.0, 2.0, 0.1];
        let q = vec![1.0f32, 0.0, -0.5, 0.2];
        let a = jsd_tokens(&p, &q, 4)[0];
        let b = jsd_tokens(&q, &p, 4)[0];
        assert!((a - b).abs() < 1e-6);
    }

    #[test]
    fn masked_mean_ignores_masked() {
        let p = vec![100.0f32, 0.0, 0.0, 100.0]; // 2 tokens, vocab 2
        let q = vec![100.0f32, 0.0, 100.0, 0.0];
        let m_all = jsd_mean(&p, &q, 2, &[1.0, 1.0]);
        let m_first = jsd_mean(&p, &q, 2, &[1.0, 0.0]);
        assert!(m_first.abs() < 1e-6);
        assert!(m_all > 0.3);
    }
}

//! Model-quality evaluation: JSD (the search signal), perplexity (paper
//! tables), and the zero-/few-shot task suite — all driven through the PJRT
//! runtime with a uniform [`ModelHandle`].
//!
//! Batch-loop reuse rules: anything resolved per *evaluation* is hoisted
//! above the per-batch loop.  [`jsd_on_batches`] reuses each prepared
//! batch's resident buffers (zero uploads per batch); the search hot path's
//! equivalent, `coordinator::proxy::mean_jsd_batch`, additionally resolves
//! a candidate chunk's lane-slab plan once — through the device bank's
//! slab cache — and replays it across every calibration batch.

pub mod jsd;
pub mod ppl;
pub mod tasks;

pub use jsd::{jsd_mean, jsd_tokens};
pub use ppl::{cross_entropy, perplexity};
pub use tasks::{score_tasks, TaskResults};

use crate::data::{TaskInstance, TokenSplit};
use crate::runtime::{QuantLayerBufs, Runtime};
use crate::Result;
use std::collections::HashMap;

/// Which model variant to evaluate.
pub enum ModelHandle<'a> {
    /// The fp subject model (resident weights).
    Fp,
    /// fp graph with some weights replaced (BitStack / PB-LLM / fixed-
    /// precision reconstructions uploaded once by the caller).
    Override(&'a HashMap<String, xla::PjRtBuffer>),
    /// Grouped-quantized model through the Pallas dequant-matmul kernel.
    Quant(&'a [&'a QuantLayerBufs]),
}

impl Runtime {
    /// Uniform logits entry point for evaluation (uploads `tokens`).
    pub fn logits(&self, handle: &ModelHandle, tokens: &[i32]) -> Result<Vec<f32>> {
        match handle {
            ModelHandle::Fp => self.fp_logits(tokens),
            ModelHandle::Override(ov) => self.fp_logits_with(tokens, ov),
            ModelHandle::Quant(layers) => self.quant_logits(tokens, layers),
        }
    }

    /// Logits against a prepared batch, reusing its resident token buffer —
    /// zero host→device copies per call (the token upload that
    /// [`Runtime::logits`] pays on every invocation happens once here, in
    /// [`Runtime::prepare_batch`]).
    pub fn logits_for_batch(
        &self,
        handle: &ModelHandle,
        batch: &crate::runtime::ScoreBatch,
    ) -> Result<Vec<f32>> {
        match handle {
            ModelHandle::Fp => self.fp_logits_for_batch(batch, &HashMap::new()),
            ModelHandle::Override(ov) => self.fp_logits_for_batch(batch, ov),
            ModelHandle::Quant(layers) => self.quant_logits_for_batch(batch, layers),
        }
    }
}

/// Perplexity of a model over a token split (full mask).
pub fn perplexity_on(rt: &Runtime, handle: &ModelHandle, split: &TokenSplit) -> Result<f32> {
    let b = rt.batch_size();
    let t = rt.seq_len();
    let v = rt.vocab();
    eyre::ensure!(split.seq_len == t, "split seq len mismatch");
    eyre::ensure!(split.n_seqs % b == 0, "split not divisible by batch");
    let mask = vec![1.0f32; b * t];
    let mut ce_sum = 0.0f64;
    let mut n_batches = 0usize;
    for start in (0..split.n_seqs).step_by(b) {
        let toks = split.batch(start, b);
        let logits = rt.logits(handle, toks)?;
        let ce = cross_entropy(&logits, toks, &mask, b, t, v);
        ce_sum += ce as f64;
        n_batches += 1;
    }
    Ok(perplexity((ce_sum / n_batches as f64) as f32))
}

/// Mean JSD of a model vs. prepared fp batches (baseline path: raw
/// logits).  Every per-batch iteration runs against the batch's resident
/// token buffer — zero host→device copies inside the loop; the handle's
/// own buffers (overrides, quant layers) are whatever the caller uploaded
/// once before the loop.
pub fn jsd_on_batches(
    rt: &Runtime,
    handle: &ModelHandle,
    batches: &[crate::runtime::ScoreBatch],
) -> Result<f32> {
    let v = rt.vocab();
    let mut sum = 0.0f64;
    for b in batches {
        let logits = rt.logits_for_batch(handle, b)?;
        sum += jsd_mean(&b.host_fp_logits, &logits, v, &b.host_mask) as f64;
    }
    Ok((sum / batches.len().max(1) as f64) as f32)
}

/// Task accuracy for a model handle.
pub fn tasks_on(
    rt: &Runtime,
    handle: &ModelHandle,
    tasks: &[TaskInstance],
    pad: i32,
) -> Result<TaskResults> {
    score_tasks(
        tasks,
        rt.batch_size(),
        rt.seq_len(),
        rt.vocab(),
        pad,
        |toks| rt.logits(handle, toks),
    )
}

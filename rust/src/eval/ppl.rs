//! Perplexity from logits: masked next-token cross-entropy, PPL = exp(CE).

/// Mean next-token CE (nats) over `[B, T, V]` logits and `[B, T]` tokens.
/// Position (b, t) contributes logprob of token (b, t+1) when
/// `mask[b, t+1] > 0`.
pub fn cross_entropy(
    logits: &[f32],
    tokens: &[i32],
    mask: &[f32],
    batch: usize,
    seq: usize,
    vocab: usize,
) -> f32 {
    assert_eq!(logits.len(), batch * seq * vocab);
    assert_eq!(tokens.len(), batch * seq);
    assert_eq!(mask.len(), batch * seq);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for b in 0..batch {
        for t in 0..seq - 1 {
            let m = mask[b * seq + t + 1];
            if m <= 0.0 {
                continue;
            }
            let row = &logits[(b * seq + t) * vocab..(b * seq + t + 1) * vocab];
            let target = tokens[b * seq + t + 1] as usize;
            let mut mx = f32::NEG_INFINITY;
            for &v in row {
                mx = mx.max(v);
            }
            let mut lse = 0.0f32;
            for &v in row {
                lse += (v - mx).exp();
            }
            let logprob = row[target] - mx - lse.ln();
            num += (-logprob as f64) * m as f64;
            den += m as f64;
        }
    }
    (num / den.max(1.0)) as f32
}

/// Perplexity = exp(mean CE).
pub fn perplexity(ce: f32) -> f32 {
    ce.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_ce_is_log_vocab() {
        let (b, t, v) = (1, 4, 8);
        let logits = vec![0.0f32; b * t * v];
        let tokens = vec![3i32; b * t];
        let mask = vec![1.0f32; b * t];
        let ce = cross_entropy(&logits, &tokens, &mask, b, t, v);
        assert!((ce - (v as f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn perfect_prediction_near_zero_ce() {
        let (b, t, v) = (1, 3, 4);
        let tokens = vec![1i32, 2, 3];
        let mut logits = vec![0.0f32; b * t * v];
        // position t predicts token[t+1] with huge margin
        logits[0 * v + 2] = 50.0;
        logits[1 * v + 3] = 50.0;
        let mask = vec![1.0f32; b * t];
        let ce = cross_entropy(&logits, &tokens, &mask, b, t, v);
        assert!(ce < 1e-3, "{ce}");
    }

    #[test]
    fn mask_excludes_targets() {
        let (b, t, v) = (1, 3, 4);
        let tokens = vec![0i32, 1, 2];
        let mut logits = vec![0.0f32; b * t * v];
        logits[0 * v + 1] = 50.0; // predicts pos1 perfectly
        // pos2 badly: uniform
        let mask = vec![1.0, 1.0, 0.0]; // exclude target at pos 2
        let ce = cross_entropy(&logits, &tokens, &mask, b, t, v);
        assert!(ce < 1e-3, "{ce}");
    }

    #[test]
    fn ppl_is_exp_ce() {
        assert!((perplexity(0.0) - 1.0).abs() < 1e-6);
        assert!((perplexity(1.0) - std::f32::consts::E).abs() < 1e-5);
    }
}

//! Multiple-choice task scoring — the LM-Eval-Harness protocol: each choice
//! is appended to the context and scored by its length-normalized logprob;
//! the model answers with the argmax choice.

use crate::data::TaskInstance;
use crate::Result;
use std::collections::BTreeMap;

/// One packed row: an (instance, choice) pair ready for a batch.
struct Row {
    instance: usize,
    choice: usize,
    ctx_len: usize,
    choice_len: usize,
    tokens: Vec<i32>,
}

/// Accuracy per family plus the macro averages the paper's tables report.
#[derive(Clone, Debug, Default)]
pub struct TaskResults {
    pub per_family: BTreeMap<String, (usize, usize)>, // (correct, total)
}

impl TaskResults {
    pub fn accuracy(&self, family: &str) -> f32 {
        self.per_family
            .get(family)
            .map(|&(c, n)| 100.0 * c as f32 / n.max(1) as f32)
            .unwrap_or(f32::NAN)
    }

    /// Macro average over the given families (Avg column).
    pub fn macro_avg(&self, families: &[&str]) -> f32 {
        let accs: Vec<f32> = families
            .iter()
            .filter(|f| self.per_family.contains_key(**f))
            .map(|f| self.accuracy(f))
            .collect();
        if accs.is_empty() {
            f32::NAN
        } else {
            accs.iter().sum::<f32>() / accs.len() as f32
        }
    }
}

/// Score all task instances using a batched logits function.
///
/// `logits_fn(tokens)` takes a full `[batch*seq]` token buffer and returns
/// `[batch*seq*vocab]` logits; rows are padded with `pad` (never a real
/// target in scoring since choice positions are explicit).
pub fn score_tasks<F>(
    tasks: &[TaskInstance],
    batch: usize,
    seq: usize,
    vocab: usize,
    pad: i32,
    mut logits_fn: F,
) -> Result<TaskResults>
where
    F: FnMut(&[i32]) -> Result<Vec<f32>>,
{
    // Build rows.
    let mut rows: Vec<Row> = Vec::new();
    for (ii, t) in tasks.iter().enumerate() {
        for (ci, ch) in t.choices.iter().enumerate() {
            let mut toks = Vec::with_capacity(seq);
            toks.extend_from_slice(&t.context);
            toks.extend_from_slice(ch);
            eyre::ensure!(toks.len() <= seq, "task row exceeds seq len");
            let ctx_len = t.context.len();
            let choice_len = ch.len();
            toks.resize(seq, pad);
            rows.push(Row { instance: ii, choice: ci, ctx_len, choice_len, tokens: toks });
        }
    }

    // Batch, execute, score.
    let mut scores: Vec<Vec<f32>> = tasks.iter().map(|t| vec![0.0; t.choices.len()]).collect();
    let mut i = 0;
    while i < rows.len() {
        let n = (rows.len() - i).min(batch);
        let mut buf = Vec::with_capacity(batch * seq);
        for r in &rows[i..i + n] {
            buf.extend_from_slice(&r.tokens);
        }
        // pad the batch with copies of the last row (discarded)
        for _ in n..batch {
            buf.extend_from_slice(&rows[i + n - 1].tokens);
        }
        let logits = logits_fn(&buf)?;
        eyre::ensure!(logits.len() == batch * seq * vocab, "bad logits size");
        for (bi, r) in rows[i..i + n].iter().enumerate() {
            let mut lp_sum = 0.0f32;
            for j in 0..r.choice_len {
                let pos = r.ctx_len + j; // token to predict
                let prev = pos - 1;      // logits position that predicts it
                let row = &logits[(bi * seq + prev) * vocab..(bi * seq + prev + 1) * vocab];
                let target = r.tokens[pos] as usize;
                let mut mx = f32::NEG_INFINITY;
                for &v in row {
                    mx = mx.max(v);
                }
                let mut lse = 0.0f32;
                for &v in row {
                    lse += (v - mx).exp();
                }
                lp_sum += row[target] - mx - lse.ln();
            }
            scores[r.instance][r.choice] = lp_sum / r.choice_len.max(1) as f32;
        }
        i += n;
    }

    // Aggregate.
    let mut results = TaskResults::default();
    for (ii, t) in tasks.iter().enumerate() {
        let pred = scores[ii]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        let e = results.per_family.entry(t.family.clone()).or_insert((0, 0));
        e.1 += 1;
        if pred == t.answer {
            e.0 += 1;
        }
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst(family: &str, ctx: Vec<i32>, choices: Vec<Vec<i32>>, ans: usize) -> TaskInstance {
        TaskInstance { family: family.into(), context: ctx, choices, answer: ans }
    }

    /// Logits that always put all mass on token `fav` at every position.
    fn const_logits_fn(fav: usize, batch: usize, seq: usize, vocab: usize)
        -> impl FnMut(&[i32]) -> Result<Vec<f32>> {
        move |_tokens: &[i32]| {
            let mut l = vec![0.0f32; batch * seq * vocab];
            for t in 0..batch * seq {
                l[t * vocab + fav] = 25.0;
            }
            Ok(l)
        }
    }

    #[test]
    fn picks_choice_matching_model_preference() {
        let tasks = vec![
            inst("fam", vec![1, 2, 3], vec![vec![7], vec![5]], 1),
            inst("fam", vec![1, 2], vec![vec![5], vec![6]], 0),
        ];
        // model always predicts token 5 -> picks the choice == [5]
        let res = score_tasks(&tasks, 4, 16, 10, 0,
                              const_logits_fn(5, 4, 16, 10)).unwrap();
        assert_eq!(res.per_family["fam"], (2, 2));
        assert!((res.accuracy("fam") - 100.0).abs() < 1e-5);
    }

    #[test]
    fn length_normalization() {
        // choice A = [5,5] (2 tokens both favored) vs B = [5] — equal mean
        // logprob; with favored=5 both ~max; tie broken by first max => A.
        let tasks = vec![inst("f", vec![1], vec![vec![5, 5], vec![5]], 0)];
        let res = score_tasks(&tasks, 2, 8, 10, 0,
                              const_logits_fn(5, 2, 8, 10)).unwrap();
        assert_eq!(res.per_family["f"].1, 1);
    }

    #[test]
    fn macro_avg_over_families() {
        let mut r = TaskResults::default();
        r.per_family.insert("a".into(), (1, 2)); // 50%
        r.per_family.insert("b".into(), (2, 2)); // 100%
        assert!((r.macro_avg(&["a", "b"]) - 75.0).abs() < 1e-5);
        assert!((r.macro_avg(&["a"]) - 50.0).abs() < 1e-5);
        assert!(r.macro_avg(&["zzz"]).is_nan());
    }

    #[test]
    fn batches_larger_than_batch_size() {
        let tasks: Vec<TaskInstance> = (0..10)
            .map(|_| inst("f", vec![1, 2], vec![vec![5], vec![6]], 0))
            .collect();
        let res = score_tasks(&tasks, 4, 8, 10, 0,
                              const_logits_fn(5, 4, 8, 10)).unwrap();
        assert_eq!(res.per_family["f"], (10, 10));
    }
}

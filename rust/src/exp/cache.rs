//! JSON cache for search archives: expensive runs (minutes each) are shared
//! between experiments that consume the same frontier (fig1/7/12, table1-3).

use crate::coordinator::{Archive, Config};
use crate::data::json::Value;
use crate::Result;
use std::fmt::Write as _;
use std::path::Path;

pub fn save_archive(path: &Path, archive: &Archive) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut s = String::from("{\"samples\": [");
    for (i, smp) in archive.samples.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let cfg: Vec<String> = smp.config.iter().map(|b| b.to_string()).collect();
        let _ = write!(
            s,
            "{{\"config\": [{}], \"jsd\": {}, \"bits\": {}}}",
            cfg.join(","),
            smp.jsd,
            smp.avg_bits
        );
    }
    s.push_str("]}");
    std::fs::write(path, s)?;
    Ok(())
}

pub fn load_archive(path: &Path) -> Result<Archive> {
    let text = std::fs::read_to_string(path)?;
    let v = Value::parse(&text)?;
    let mut archive = Archive::new();
    for smp in v.get("samples")?.as_arr()? {
        // Genes serialize as bare integers; single-method (hqq) configs are
        // numerically the bit-widths, so legacy bits-only caches round-trip.
        let config: Config = smp
            .get("config")?
            .as_arr()?
            .iter()
            .map(|b| Ok(b.as_usize()? as u16))
            .collect::<Result<Vec<_>>>()?;
        archive.insert(
            config,
            smp.get("jsd")?.as_f64()? as f32,
            smp.get("bits")?.as_f64()?,
        );
    }
    Ok(archive)
}

/// Load an archive if cached, otherwise compute and persist it.
pub fn archive_cached<F>(path: &Path, fresh: bool, compute: F) -> Result<Archive>
where
    F: FnOnce() -> Result<Archive>,
{
    if !fresh && path.exists() {
        if let Ok(a) = load_archive(path) {
            if !a.is_empty() {
                eprintln!("[cache] loaded {} samples from {}", a.len(), path.display());
                return Ok(a);
            }
        }
    }
    let archive = compute()?;
    save_archive(path, &archive)?;
    Ok(archive)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut a = Archive::new();
        a.insert(vec![2, 3, 4], 0.125, 3.25);
        a.insert(vec![4, 4, 4], 0.01, 4.25);
        let dir = std::env::temp_dir().join("amq_cache_test");
        let path = dir.join("arch.json");
        save_archive(&path, &a).unwrap();
        let b = load_archive(&path).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b.samples[0].config, vec![2, 3, 4]);
        assert!((b.samples[0].jsd - 0.125).abs() < 1e-6);
        assert!((b.samples[1].avg_bits - 4.25).abs() < 1e-9);
    }

    #[test]
    fn cached_compute_once() {
        let dir = std::env::temp_dir().join("amq_cache_test2");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("arch.json");
        let mut calls = 0;
        for _ in 0..2 {
            let a = archive_cached(&path, false, || {
                calls += 1;
                let mut a = Archive::new();
                a.insert(vec![2], 0.5, 2.25);
                Ok(a)
            })
            .unwrap();
            assert_eq!(a.len(), 1);
        }
        assert_eq!(calls, 1);
    }
}

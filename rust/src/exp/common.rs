//! Shared experiment pipeline: sensitivity -> pruning -> proxy -> search,
//! plus deploy-time evaluation helpers used by every table.

use super::{cache, Ctx, SearchRunStats};
use crate::coordinator::{
    gene_bits, gene_method, pruning, run_search, run_search_seeded, sensitivity, warmstart,
    Archive, Config, ConfigEvaluator, DeviceBank, DeviceProxy, EvalPool, PooledEvaluator,
    ProxyBank, ProxyEvaluator, SearchParams, SearchSpace, WarmKey, WarmLoad,
};
use crate::eval::{self, ModelHandle, TaskResults};
use crate::model::ModelAssets;
use crate::quant::{AwqClip, BitStack, MethodId, MethodRegistry, PbLlm, Quantizer};
use crate::runtime::{EvalService, HedgePolicy, QuantLayerBufs};
use crate::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Memory budgets (average bits) used across Tables 1/2 and Figures 1/7/8.
pub const BUDGETS: [f64; 4] = [2.5, 3.0, 3.5, 4.0];

/// Budget tolerance when selecting from the frontier (paper: ±0.005).
pub const TOL: f64 = 0.005;

/// The standard pipeline state shared by most experiments.
pub struct Pipeline<'rt> {
    pub space: SearchSpace,
    pub full_space: SearchSpace,
    pub sensitivity: sensitivity::Sensitivity,
    pub prune_report: pruning::PruneReport,
    pub proxy: DeviceProxy<'rt>,
    pub proxy_build_secs: f64,
}

/// The proxy bank every evaluation path shares: each enabled method's
/// `(layer, bits)` pieces, quantized once (§3.3 generalized over the
/// method axis).  Single definition so the main thread and the pool shards
/// quantize identically.  Hessian statistics are loaded only when an
/// enabled method consumes them, so the single-method HQQ default stays
/// activation-independent.
pub(super) fn build_proxy_bank(
    assets: &ModelAssets,
    registry: &MethodRegistry,
) -> Result<ProxyBank> {
    let hessians = registry.any_needs_stats().then_some(&assets.hessians);
    ProxyBank::build(&assets.manifest, &assets.weights, hessians, registry)
}

impl<'rt> Pipeline<'rt> {
    /// Build (or reuse) the process-wide device bank, measure sensitivity,
    /// prune at 2x median.
    pub fn build(ctx: &'rt Ctx) -> Result<Pipeline<'rt>> {
        let t0 = Instant::now();
        // Quantization + upload happen in Ctx::device_bank, exactly once —
        // the pool shards wrap the *same* Arc'd bank, so `--workers N`
        // costs 1x uploads and 1x resident device bytes, not Nx.
        let dev = ctx.device_bank()?;
        let proxy = DeviceProxy::from_device_bank(&ctx.rt, dev);
        let proxy_build_secs = t0.elapsed().as_secs_f64();

        let full_space = SearchSpace::with_methods(&ctx.assets.manifest, &ctx.registry);
        // The sensitivity scan is one batched dispatch of n_layers probes,
        // so it fans out across pool shards when `--workers > 1`.
        let sens = match ctx.eval_pool() {
            Some(svc) => {
                let mut evaluator =
                    PooledEvaluator::from_service(svc).with_score_batch(ctx.score_batch);
                sensitivity::measure(&full_space, &mut evaluator)?
            }
            None => {
                let mut evaluator = ProxyEvaluator::new(&proxy, &ctx.search_batches)
                    .with_score_batch(ctx.score_batch);
                sensitivity::measure(&full_space, &mut evaluator)?
            }
        };
        let mut space = full_space.clone();
        let prune_report = pruning::prune(&mut space, &sens, 2.0);
        Ok(Pipeline {
            space,
            full_space,
            sensitivity: sens,
            prune_report,
            proxy,
            proxy_build_secs,
        })
    }

    pub fn evaluator<'a>(&'a self, ctx: &'a Ctx) -> ProxyEvaluator<'a> {
        ProxyEvaluator::new(&self.proxy, &ctx.search_batches).with_score_batch(ctx.score_batch)
    }
}

// ---------------------------------------------------------------------------
// Sharded evaluation pool (--workers N)
// ---------------------------------------------------------------------------

/// Spawn the evaluation pool: `ctx.local_workers()` in-process shards plus
/// one feeder shard per `--shards` address, all sharing one FIFO (a chunk
/// goes to whichever shard — local closure or remote socket — is idle
/// first).  Local shards share *everything heavy* with the main thread —
/// the `Sync` PJRT runtime, the process-wide uploaded [`DeviceBank`] and
/// the prepared calibration batches — so per-shard scoring state is nothing
/// but a few `Arc` handles, resolved lazily on the shard's first request
/// (an unused pool costs nothing, and the first toucher — main thread or
/// any shard — pays the one-time quantize + upload for everyone).  Remote
/// feeders speak the `runtime::wire` frame protocol; one dying beyond its
/// retry budget retires (its in-flight chunk requeues onto the survivors)
/// rather than failing the search.
///
/// The wire unit is a *microbatch* of candidates: one request = one scorer
/// dispatch of up to `--score-batch` configs on whichever shard is idle.
///
/// Straggler hedging (`--hedge-factor`): the pool tracks each chunk's
/// in-flight age against a rolling p50 of completed chunks; when a chunk
/// overstays `factor × p50` and a shard is idle, that shard evaluates a
/// speculative duplicate and the first reply wins (evals are pure, so the
/// copies are bitwise-identical — archives never depend on who won).
pub(super) fn spawn_search_pool(ctx: &Ctx) -> EvalPool {
    let rt = ctx.rt.clone();
    let batches = ctx.search_batches.clone();
    let assets = ctx.assets.clone();
    let registry = ctx.registry.clone();
    let cell = ctx.device_bank.clone();
    let shard_banks = ctx.shard_banks.clone();
    let slab_budget = crate::coordinator::slab_budget_bytes(ctx.slab_cache_mb);
    let local = ctx.local_workers();
    let remotes = ctx.shards.clone();
    let labels: Vec<String> = (0..local)
        .map(|i| format!("local#{i}"))
        .chain(remotes.iter().cloned())
        .collect();
    let policy = HedgePolicy::from_factor(ctx.hedge_factor);
    let builder = move |shard: usize| {
        if shard >= local {
            // Remote feeder: forward chunks over TCP, retire on transport
            // death (the pool requeues the in-flight chunk).
            return crate::runtime::remote::remote_eval_flow(
                remotes[shard - local].clone(),
                crate::runtime::remote::RetryPolicy::default(),
            );
        }
        let rt = rt.clone();
        let batches = batches.clone();
        let assets = assets.clone();
        let registry = registry.clone();
        let cell = cell.clone();
        let shard_banks = shard_banks.clone();
        let mut dev: Option<Arc<DeviceBank>> = None;
        let mut eval = move |chunk: Vec<Config>| -> Result<Vec<f32>> {
            if dev.is_none() {
                let resolved = cell
                    .get_or_init(|| {
                        let bank = build_proxy_bank(&assets, &registry)
                            .map_err(|e| format!("{e}"))?;
                        DeviceBank::upload_with_slab_budget(&rt, Arc::new(bank), slab_budget)
                            .map(Arc::new)
                            .map_err(|e| format!("{e}"))
                    })
                    .clone()
                    .map_err(|e| eyre::anyhow!("shard init failed: {e}"))?;
                // accounting: this shard references the shared bank
                shard_banks.lock().unwrap().push(resolved.bank.clone());
                dev = Some(resolved);
            }
            let proxy = DeviceProxy::from_device_bank(&rt, dev.as_ref().unwrap().clone());
            // Literally the same scoring function the in-thread
            // [`ProxyEvaluator`] calls, over the same shared batches, so
            // pooled and sequential searches agree bit-for-bit.
            crate::coordinator::proxy::mean_jsd_batch(&proxy, &batches, &chunk)
        };
        Box::new(move |chunk: Vec<Config>| crate::runtime::ShardFlow::Reply(eval(chunk)))
    };
    EvalService::spawn_flow_with(labels, builder, policy)
}

/// The evaluator a search should drive: pool-backed when `--workers > 1`,
/// the in-thread proxy evaluator otherwise.  Both dedup and microbatch
/// identically and produce identical archives for a fixed seed.
pub fn search_evaluator<'a>(ctx: &'a Ctx, pipe: &'a Pipeline) -> Box<dyn ConfigEvaluator + 'a> {
    match ctx.eval_pool() {
        Some(svc) => {
            Box::new(PooledEvaluator::from_service(svc).with_score_batch(ctx.score_batch))
        }
        None => Box::new(pipe.evaluator(ctx)),
    }
}

/// The warm-start key of this context: the model identity is the FNV-1a
/// digest of the manifest bytes (any artifact edit invalidates old
/// entries), the method axis is the canonical comma-joined enable list,
/// and the budget tuple comes from the preset.
pub fn warm_key(ctx: &Ctx) -> Result<WarmKey> {
    let manifest = std::fs::read(ctx.artifacts.join("manifest.json"))?;
    let model = warmstart::model_label(&manifest);
    let methods = ctx.registry.names().join(",");
    Ok(WarmKey::from_params(&model, &methods, &ctx.preset))
}

/// The main AMQ search (ctx.preset), cached under `results/cache/`.
/// Any non-default method list gets its own cache key — including a
/// *single* non-hqq method — so `--methods rtn` can never collide with a
/// default-genome archive; the default hqq tag is unchanged, so legacy
/// caches keep hitting.
///
/// With `--warm-start DIR` (and a cold local cache), the search first
/// consults the warm-start store: an exact key hit adopts the persisted
/// archive verbatim (bit-identical `content_hash`, zero evaluations), a
/// same-model/methods hit with a different budget seeds the search, and
/// anything else (missing, mismatched, corrupt) runs cold.  The finished
/// archive is persisted back for the next run.
pub fn main_archive(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<Archive> {
    let mut tag = format!(
        "search_main_i{}_n{}_s{}",
        ctx.preset.iterations, ctx.preset.n_init, ctx.preset.seed
    );
    if ctx.registry.single() != Some(MethodId::Hqq) {
        tag = format!("{tag}_m{}", ctx.registry.names().join("-"));
    }
    let path = ctx.out_dir.join("cache").join(format!("{tag}.json"));
    let archive = cache::archive_cached(&path, fresh, || {
        let mut seeds = Vec::new();
        if let Some(dir) = &ctx.warm_start {
            let key = warm_key(ctx)?;
            match warmstart::load(dir, &key, &pipe.space) {
                WarmLoad::Exact(entry) => {
                    eprintln!(
                        "[warm-start] exact key hit: adopting {} persisted samples \
                         (content hash {:#018x}), no evaluations",
                        entry.archive.len(),
                        entry.archive.content_hash(),
                    );
                    ctx.note_warm_tier("exact");
                    return Ok(entry.archive);
                }
                WarmLoad::Seed(entry) => {
                    eprintln!(
                        "[warm-start] seeding from {} samples of a prior \
                         same-model run (different budget)",
                        entry.archive.len(),
                    );
                    ctx.note_warm_tier("seed");
                    seeds = entry.archive.samples;
                }
                WarmLoad::Cold => {
                    eprintln!("[warm-start] no usable entry, starting cold");
                    ctx.note_warm_tier("cold");
                }
            }
        }
        let mut evaluator = search_evaluator(ctx, pipe);
        let res = run_search_seeded(&pipe.space, evaluator.as_mut(), &ctx.preset, &seeds)?;
        eprintln!(
            "[search] {} true evals, {} predictor queries, {:.1}s ({} worker{}, score-batch {})",
            res.true_evals,
            res.predictor_queries,
            res.total_time.as_secs_f64(),
            ctx.workers,
            if ctx.workers == 1 { "" } else { "s" },
            ctx.score_batch,
        );
        if let Some(s) = evaluator.batch_stats() {
            eprintln!(
                "[search] {} scorer dispatches for {} requested configs \
                 ({} cache hits, {} in-batch dups; {:.2}x fewer dispatches)",
                s.dispatches,
                s.requested,
                s.cache_hits,
                s.dup_hits,
                s.dispatch_reduction(),
            );
        }
        if ctx.rt.slab_gather_enabled() {
            let rs = ctx.rt.stats();
            eprintln!(
                "[search] slab gather: {} device dispatch(es), \
                 {:.2} MB of host slab uploads avoided",
                rs.gather_dispatches,
                rs.slab_upload_bytes_avoided as f64 / 1e6,
            );
        }
        ctx.note_eval_stats(evaluator.batch_stats());
        ctx.note_search_stats(SearchRunStats {
            true_evals: res.true_evals,
            predictor_queries: res.predictor_queries,
            wall_secs: res.total_time.as_secs_f64(),
        });
        if let Some(dir) = &ctx.warm_start {
            let key = warm_key(ctx)?;
            let saved = warmstart::save(dir, &key, &res.archive, &pipe.space)?;
            eprintln!(
                "[warm-start] persisted {} samples to {}",
                res.archive.len(),
                saved.display()
            );
        }
        Ok(res.archive)
    })?;
    Ok(rebits(archive, &pipe.space))
}

/// Recompute every sample's avg_bits from its genes against the *current*
/// space accounting.  Cached archives are authoritative only on (config,
/// jsd); the stored bits may predate an accounting change (e.g. the
/// group-metadata fix) and would otherwise leak stale budgets into
/// frontier selection.
pub fn rebits(archive: Archive, space: &SearchSpace) -> Archive {
    let mut out = Archive::new();
    for s in archive.samples {
        let bits = space.avg_bits(&s.config);
        out.insert(s.config, s.jsd, bits);
    }
    out
}

/// Load the config a `repro serve` process should serve as its default:
/// an archive JSON written by a search (`results/cache/*.json` — the
/// "searched archive entry"), narrowed to `budget` average bits when given
/// (same ±[`TOL`] rule as the paper tables), otherwise the archive's
/// lowest-JSD sample.  Returns the chosen sample so the server can log its
/// provenance (bits + proxy JSD) next to the listen address.
pub fn load_served_config(
    path: &std::path::Path,
    budget: Option<f64>,
) -> Result<crate::coordinator::Sample> {
    let archive = cache::load_archive(path)?;
    eyre::ensure!(!archive.is_empty(), "archive {} holds no samples", path.display());
    let sample = match budget {
        Some(b) => archive.best_under(b, TOL).ok_or_else(|| {
            eyre::anyhow!(
                "no sample under {b} bits (±{TOL}) in {} ({} samples)",
                path.display(),
                archive.len()
            )
        })?,
        None => archive
            .samples
            .iter()
            .min_by(|a, b| a.jsd.partial_cmp(&b.jsd).unwrap_or(std::cmp::Ordering::Equal))
            .expect("non-empty archive"),
    };
    Ok(sample.clone())
}

/// Pick the frontier config for a budget (panics with context if none).
pub fn pick(archive: &Archive, space: &SearchSpace, budget: f64) -> Result<Config> {
    archive
        .best_under(budget, TOL)
        .map(|s| s.config.clone())
        .ok_or_else(|| eyre::anyhow!("no archive sample under {budget} bits"))
        .map(|c| {
            debug_assert!(space.contains(&c));
            c
        })
}

/// Deploy-quantize a configuration's *bit-widths* with a given quantizer
/// and upload (the fixed-deploy-method comparators).
pub fn deploy_layers(
    ctx: &Ctx,
    config: &Config,
    quantizer: &dyn Quantizer,
    use_stats: bool,
) -> Result<Vec<QuantLayerBufs>> {
    let m = &ctx.assets.manifest;
    let mut out = Vec::with_capacity(m.layers.len());
    for (li, l) in m.layers.iter().enumerate() {
        let w = ctx.assets.weights.linear(&l.name)?;
        let stats = if use_stats {
            Some(ctx.assets.hessians.for_layer(&l.name)?)
        } else {
            None
        };
        let q = quantizer.quantize(&w, gene_bits(config[li]), m.group_size, stats);
        out.push(ctx.rt.upload_quant_layer(&q)?);
    }
    Ok(out)
}

/// Deploy-quantize a configuration honoring each gene's *method*: every
/// layer is quantized with its assigned method at its assigned bit-width
/// (method-aware genomes deploy what they searched).
pub fn deploy_gene_layers(ctx: &Ctx, config: &Config) -> Result<Vec<QuantLayerBufs>> {
    let m = &ctx.assets.manifest;
    let mut quantizers: HashMap<MethodId, Box<dyn Quantizer>> = HashMap::new();
    let mut out = Vec::with_capacity(m.layers.len());
    for (li, l) in m.layers.iter().enumerate() {
        let method = gene_method(config[li]);
        let quantizer = quantizers.entry(method).or_insert_with(|| method.build());
        let stats = if method.needs_stats() {
            Some(ctx.assets.hessians.for_layer(&l.name)?)
        } else {
            None
        };
        let w = ctx.assets.weights.linear(&l.name)?;
        let q = quantizer.quantize(&w, gene_bits(config[li]), m.group_size, stats);
        out.push(ctx.rt.upload_quant_layer(&q)?);
    }
    Ok(out)
}

/// Full quality readout for a quantized model handle.
pub struct QualityOut {
    pub wiki_ppl: f32,
    pub c4_ppl: f32,
    pub zero_shot: TaskResults,
}

pub fn quality(ctx: &Ctx, handle: &ModelHandle) -> Result<QualityOut> {
    let wiki_ppl = eval::perplexity_on(&ctx.rt, handle, &ctx.wiki)?;
    let c4_ppl = eval::perplexity_on(&ctx.rt, handle, &ctx.c4)?;
    // zero-shot families only here; the few-shot suite is table2's job
    let subset: Vec<_> = ctx
        .tasks
        .iter()
        .filter(|t| crate::data::ZERO_SHOT.contains(&t.family.as_str()))
        .cloned()
        .collect();
    let zero_shot = eval::tasks_on(&ctx.rt, handle, &subset, ctx.pad())?;
    Ok(QualityOut { wiki_ppl, c4_ppl, zero_shot })
}

/// Few-shot-only readout (Table 2).
pub fn few_shot(ctx: &Ctx, handle: &ModelHandle) -> Result<TaskResults> {
    let subset: Vec<_> = ctx
        .tasks
        .iter()
        .filter(|t| crate::data::FEW_SHOT.contains(&t.family.as_str()))
        .cloned()
        .collect();
    eval::tasks_on(&ctx.rt, handle, &subset, ctx.pad())
}

/// PPL-only readout (ablation tables).
pub fn ppl_only(ctx: &Ctx, handle: &ModelHandle) -> Result<(f32, f32)> {
    Ok((
        eval::perplexity_on(&ctx.rt, handle, &ctx.wiki)?,
        eval::perplexity_on(&ctx.rt, handle, &ctx.c4)?,
    ))
}

/// AMQ deploy evaluation.  Legacy single-method (HQQ-proxy) configs deploy
/// with asym-clip AWQ (the paper's deploy quantizer); configs that carry
/// explicit non-default method genes deploy each layer with its own method.
pub fn amq_quality(ctx: &Ctx, config: &Config) -> Result<QualityOut> {
    let proxy_only = config.iter().all(|&g| gene_method(g) == MethodId::Hqq);
    let layers = if proxy_only {
        deploy_layers(ctx, config, &AwqClip::default(), true)?
    } else {
        deploy_gene_layers(ctx, config)?
    };
    let refs: Vec<&QuantLayerBufs> = layers.iter().collect();
    quality(ctx, &ModelHandle::Quant(&refs))
}

// ---------------------------------------------------------------------------
// Any-size baselines
// ---------------------------------------------------------------------------

/// BitStack decomposition over all searchable layers (built once, reused
/// across budgets).
pub fn bitstack_build(ctx: &Ctx, max_blocks: usize) -> Result<BitStack> {
    let mut ws = Vec::new();
    for l in &ctx.assets.manifest.layers {
        ws.push((l.name.clone(), ctx.assets.weights.linear(&l.name)?));
    }
    Ok(BitStack::decompose(&ws, max_blocks))
}

/// Byte budget equivalent to an average-bits target over the searchable
/// weights (+ the same group-metadata overhead AMQ pays).
pub fn budget_bytes(space: &SearchSpace, avg_bits: f64) -> usize {
    let params: usize = space.params.iter().sum();
    (params as f64 * avg_bits / 8.0) as usize
}

/// Evaluate BitStack at a byte budget: allocate blocks, reconstruct, eval
/// through the fp graph with weight overrides.
pub fn bitstack_quality(
    ctx: &Ctx,
    bs: &BitStack,
    budget_bytes: usize,
) -> Result<(QualityOut, Vec<usize>)> {
    let loaded = bs.allocate(budget_bytes);
    let recon = bs.reconstruct_all(&loaded);
    let overrides = ctx.rt.upload_weight_overrides(&recon)?;
    Ok((quality(ctx, &ModelHandle::Override(&overrides))?, loaded))
}

/// PB-LLM at a target average-bits (rho chosen so bits match).
pub fn pbllm_quality(ctx: &Ctx, avg_bits: f64) -> Result<QualityOut> {
    let rho = ((avg_bits - 1.0) / 7.0).clamp(0.0, 1.0) as f32;
    let pb = PbLlm::new(rho, ctx.assets.manifest.group_size);
    let mut recon = Vec::new();
    for l in &ctx.assets.manifest.layers {
        let w = ctx.assets.weights.linear(&l.name)?;
        let stats = ctx.assets.hessians.for_layer(&l.name)?;
        recon.push((l.name.clone(), pb.quantize(&w, Some(stats)).dequant().clone()));
    }
    let overrides = ctx.rt.upload_weight_overrides(&recon)?;
    quality(ctx, &ModelHandle::Override(&overrides))
}

/// Uniform fixed-precision configuration at `bits` for every layer (each
/// layer keeps a method present in its choices).
pub fn uniform_config(space: &SearchSpace, bits: u8) -> Config {
    space.uniform(bits)
}

/// JSD of an arbitrary override model vs the fp reference on the search
/// calibration batches (used by greedy/one-shot comparisons on baselines).
pub fn override_jsd(
    ctx: &Ctx,
    overrides: &HashMap<String, xla::PjRtBuffer>,
) -> Result<f32> {
    eval::jsd_on_batches(&ctx.rt, &ModelHandle::Override(overrides), &ctx.search_batches)
}

/// Convenience: evaluator-backed JSD for an assembled proxy config on the
/// full calibration split (final-quality numbers, not the search path).
pub fn proxy_full_jsd(ctx: &Ctx, pipe: &Pipeline, config: &Config) -> Result<f32> {
    let batches = ctx.batches_for(&ctx.calib)?;
    let layers = pipe.proxy.assemble(config)?;
    let mut sum = 0.0f64;
    for b in &batches {
        let (jsd, _) = ctx.rt.scores(b, &layers)?;
        sum += jsd as f64;
    }
    Ok((sum / batches.len() as f64) as f32)
}

/// Run a search with explicit params (ablations), cached by tag.  Like
/// [`main_archive`], non-default method lists extend the cache key and
/// cached bits are recomputed against the current accounting.
pub fn search_cached(
    ctx: &Ctx,
    pipe: &Pipeline,
    params: &SearchParams,
    tag: &str,
    fresh: bool,
) -> Result<Archive> {
    let mut tag = tag.to_string();
    if ctx.registry.single() != Some(MethodId::Hqq) {
        tag = format!("{tag}_m{}", ctx.registry.names().join("-"));
    }
    let path = ctx.out_dir.join("cache").join(format!("{tag}.json"));
    let archive = cache::archive_cached(&path, fresh, || {
        let mut evaluator = search_evaluator(ctx, pipe);
        let res = run_search(&pipe.space, evaluator.as_mut(), params)?;
        ctx.note_eval_stats(evaluator.batch_stats());
        ctx.note_search_stats(SearchRunStats {
            true_evals: res.true_evals,
            predictor_queries: res.predictor_queries,
            wall_secs: res.total_time.as_secs_f64(),
        });
        Ok(res.archive)
    })?;
    Ok(rebits(archive, &pipe.space))
}

/// Memory column (MB) for an AMQ/uniform config row: searchable weights at
/// config bits + fp-side parameters at fp16 (paper accounting).
pub fn row_memory_mb(ctx: &Ctx, space: &SearchSpace, config: &Config) -> f64 {
    space.memory_mb(config) + ctx.assets.manifest.fp_side_params() as f64 * 2.0 / 1e6
}

/// FP16 memory (MB).
pub fn fp16_memory_mb(ctx: &Ctx) -> f64 {
    (ctx.assets.manifest.total_linear_params() + ctx.assets.manifest.fp_side_params()) as f64
        * 2.0
        / 1e6
}

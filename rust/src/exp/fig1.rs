//! Figures 1 & 7 analog: the memory vs zero-shot-accuracy trade-off for
//! AMQ / BitStack / PB-LLM (+ tokens/s from the cost model for Fig 1's
//! bottom panel).

use super::common::{self, Pipeline};
use super::Ctx;
use crate::costmodel::{self, DeployKind, L40S};
use crate::data::ZERO_SHOT;
use crate::eval::ModelHandle;
use crate::report::{fmt, Table};
use crate::Result;

pub fn run(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let archive = common::main_archive(ctx, pipe, fresh)?;
    let mut table = Table::new(
        "Figure 1/7 — accuracy + speed vs average bits",
        &["avg_bits", "method", "mem_MB", "avg_acc", "tok_per_s(L40S sim)"],
    );
    let m = &ctx.assets.manifest;

    // FP16 anchor
    let fp_q = common::quality(ctx, &ModelHandle::Fp)?;
    table.row(vec![
        "16".into(),
        "FP16".into(),
        fmt(common::fp16_memory_mb(ctx) as f32, 1),
        fmt(fp_q.zero_shot.macro_avg(&ZERO_SHOT), 2),
        fmt(costmodel::tokens_per_sec(&L40S, m, &DeployKind::Fp16) as f32, 1),
    ]);

    let bs = common::bitstack_build(ctx, 10)?;
    for &budget in &common::BUDGETS {
        // AMQ
        let cfg = common::pick(&archive, &pipe.space, budget)?;
        let amq_q = common::amq_quality(ctx, &cfg)?;
        let cfg_bits = pipe.space.config_bits(&cfg);
        let speed = costmodel::tokens_per_sec(&L40S, m, &DeployKind::LayerQuant(&cfg_bits));
        table.row(vec![
            format!("{budget}"),
            "AMQ".into(),
            fmt(common::row_memory_mb(ctx, &pipe.space, &cfg) as f32, 1),
            fmt(amq_q.zero_shot.macro_avg(&ZERO_SHOT), 2),
            fmt(speed as f32, 1),
        ]);
        // BitStack
        let bytes = common::budget_bytes(&pipe.space, budget);
        let (bs_q, loaded) = common::bitstack_quality(ctx, &bs, bytes)?;
        let bs_speed =
            costmodel::tokens_per_sec(&L40S, m, &DeployKind::BitStack(&loaded));
        table.row(vec![
            format!("{budget}"),
            "BitStack".into(),
            fmt((bytes as f64 / 1e6) as f32, 1),
            fmt(bs_q.zero_shot.macro_avg(&ZERO_SHOT), 2),
            fmt(bs_speed as f32, 1),
        ]);
        // PB-LLM
        let pb_q = common::pbllm_quality(ctx, budget)?;
        let pb_speed =
            costmodel::tokens_per_sec(&L40S, m, &DeployKind::PbLlm((budget - 1.0) / 7.0));
        table.row(vec![
            format!("{budget}"),
            "PB-LLM".into(),
            fmt((common::budget_bytes(&pipe.space, budget) as f64 / 1e6) as f32, 1),
            fmt(pb_q.zero_shot.macro_avg(&ZERO_SHOT), 2),
            fmt(pb_speed as f32, 1),
        ]);
    }
    table.print();
    table.to_csv(&ctx.out_dir.join("fig1.csv"))?;
    Ok(())
}

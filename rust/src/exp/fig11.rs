//! Figure 11 analog: robustness over random seeds — frontier C4-proxy JSD
//! per bit-width as the search iterates, for 6 seeds.

use super::common::{self, Pipeline};
use super::Ctx;
use crate::coordinator::run_search;
use crate::report::{fmt, Table};
use crate::Result;

pub fn run(ctx: &Ctx, pipe: &Pipeline) -> Result<()> {
    let seeds = [11u64, 22, 33, 44, 55, 66];
    let checkpoints = [2usize, 5, 10, ctx.preset.iterations - 1];
    let mut table = Table::new(
        "Figure 11 — frontier JSD vs iteration across 6 seeds",
        &["iteration", "bits", "jsd_min", "jsd_max", "jsd_spread"],
    );

    // gather histories
    let mut histories = Vec::new();
    for &seed in &seeds {
        let mut params = ctx.preset.clone();
        params.seed = seed;
        // lighter budget per seed: fig11 is about variance, not depth
        params.iterations = ctx.preset.iterations;
        let mut evaluator = common::search_evaluator(ctx, pipe);
        let res = run_search(&pipe.space, evaluator.as_mut(), &params)?;
        histories.push(res.history);
    }

    for &it in &checkpoints {
        for (bi, &bits) in [2.5f64, 3.0, 3.5, 4.0].iter().enumerate() {
            let vals: Vec<f32> = histories
                .iter()
                .filter_map(|h| h.get(it))
                .map(|s| s.frontier_probe[bi].1)
                .filter(|v| v.is_finite())
                .collect();
            if vals.len() < seeds.len() {
                continue; // paper: plot only when all seeds have a sample
            }
            let lo = vals.iter().fold(f32::INFINITY, |m, &v| m.min(v));
            let hi = vals.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            table.row(vec![
                it.to_string(),
                format!("{bits}"),
                fmt(lo, 4),
                fmt(hi, 4),
                fmt(hi - lo, 4),
            ]);
        }
    }
    table.print();
    println!("(spread should shrink with iteration — the paper's convergence claim)");
    table.to_csv(&ctx.out_dir.join("fig11.csv"))?;
    Ok(())
}

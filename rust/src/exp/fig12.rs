//! Figures 12/13/14 analog: bit-allocation visualization — which layers get
//! which bit-width at each average-bits budget (text heatmap, rows = linear
//! kinds Q K V O Gate Up Down, columns = blocks).

use super::common::{self, Pipeline};
use super::Ctx;
use crate::coordinator::{gene_bits, gene_method};
use crate::report::Table;
use crate::Result;

const KINDS: [&str; 7] = ["q", "k", "v", "o", "gate", "up", "down"];

pub fn run(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let archive = common::main_archive(ctx, pipe, fresh)?;
    let m = &ctx.assets.manifest;
    let n_blocks = m.model.n_layers;

    let multi = pipe.space.n_methods() > 1;
    let mut csv = Table::new(
        "Figure 12 — bit allocation per layer",
        &["avg_bits", "layer", "bits", "method"],
    );
    for &budget in &common::BUDGETS {
        let cfg = common::pick(&archive, &pipe.space, budget)?;
        println!("\navg bits {budget} (actual {:.3}):", pipe.space.avg_bits(&cfg));
        println!("        {}", (0..n_blocks).map(|b| format!("blk{b}"))
                 .collect::<Vec<_>>().join("  "));
        for kind in KINDS {
            let mut cells = Vec::new();
            for b in 0..n_blocks {
                let name = format!("blk{b}.{kind}");
                let li = m.layer_index(&name).unwrap();
                let (bits, method) = (gene_bits(cfg[li]), gene_method(cfg[li]));
                if multi {
                    cells.push(format!(" {bits}@{} ", method.name()));
                } else {
                    cells.push(format!("  {bits} "));
                }
                csv.row(vec![
                    format!("{budget}"),
                    name,
                    bits.to_string(),
                    method.name().to_string(),
                ]);
            }
            println!("{kind:>6}  {}", cells.join("  "));
        }
        // per-kind average (the paper's "V stays high, Q/K drop first")
        let mut means = Vec::new();
        for kind in KINDS {
            let vals: Vec<f32> = (0..n_blocks)
                .map(|b| {
                    gene_bits(cfg[m.layer_index(&format!("blk{b}.{kind}")).unwrap()]) as f32
                })
                .collect();
            means.push(format!(
                "{kind}={:.2}",
                vals.iter().sum::<f32>() / vals.len() as f32
            ));
        }
        println!("  kind means: {}", means.join(" "));
    }
    csv.to_csv(&ctx.out_dir.join("fig12.csv"))?;
    Ok(())
}

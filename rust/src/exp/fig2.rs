//! Figure 2 analog: per-linear-layer sensitivity — quantize one layer to
//! 2-bit (HQQ proxy), all others at 4-bit, report calibration JSD and
//! WikiText-analog PPL degradation.

use super::common::Pipeline;
use super::Ctx;
use crate::eval::{self, ModelHandle};
use crate::report::{fmt, Table};
use crate::Result;

pub fn run(ctx: &Ctx, pipe: &Pipeline) -> Result<()> {
    let m = &ctx.assets.manifest;
    let scores = pipe.sensitivity.scores();

    // PPL per single-layer-2bit config on the wiki split (the paper's Fig 2
    // y-axis); JSD is the signal pruning actually uses.
    let mut table = Table::new(
        "Figure 2 — single-layer 2-bit sensitivity (others 4-bit)",
        &["layer", "kind", "block", "jsd", "wiki_ppl"],
    );
    let max_cfg = pipe.full_space.max_config();
    let mut rows = Vec::new();
    for (li, l) in m.layers.iter().enumerate() {
        let mut cfg = max_cfg.clone();
        cfg[li] = pipe.full_space.min_gene(li);
        let layers = pipe.proxy.assemble(&cfg)?;
        let ppl = eval::perplexity_on(&ctx.rt, &ModelHandle::Quant(&layers), &ctx.wiki)?;
        rows.push((l.name.clone(), l.kind().to_string(), l.block(), scores[li], ppl));
    }
    let baseline_ppl = {
        let layers = pipe.proxy.assemble(&max_cfg)?;
        eval::perplexity_on(&ctx.rt, &ModelHandle::Quant(&layers), &ctx.wiki)?
    };
    for (name, kind, block, jsd, ppl) in &rows {
        table.row(vec![
            name.clone(),
            kind.clone(),
            block.to_string(),
            fmt(*jsd, 5),
            fmt(*ppl, 3),
        ]);
    }
    table.print();
    println!(
        "baseline (all-4bit) wiki PPL = {baseline_ppl:.3}; sensitivity spread = {:.1}x",
        scores.iter().fold(0.0f32, |m, &s| m.max(s))
            / scores
                .iter()
                .filter(|s| **s > 0.0)
                .fold(f32::INFINITY, |m, &s| m.min(s))
                .max(1e-9)
    );
    println!(
        "pruning (2x median): {} outliers {:?} ({:.2}% of layers)",
        pipe.prune_report.outliers.len(),
        pipe.prune_report
            .outliers
            .iter()
            .map(|&i| m.layers[i].name.clone())
            .collect::<Vec<_>>(),
        pipe.prune_report.excluded_frac * 100.0
    );
    table.to_csv(&ctx.out_dir.join("fig2.csv"))?;
    Ok(())
}

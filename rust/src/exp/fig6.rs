//! Figure 6 analog: does the HQQ proxy preserve the quality *ordering* of
//! the activation-dependent quantizers (GPTQ, asym-clip AWQ)?  We sample
//! configurations from the AMQ frontier, evaluate wiki PPL under all three
//! quantizers, and report pairwise Kendall-τ rank agreement — the empirical
//! check behind the §3.3 theorem.

use super::common::{self, Pipeline};
use super::Ctx;
use crate::eval::{self, ModelHandle};
use crate::quant::{AwqClip, Gptq, Quantizer};
use crate::report::{fmt, Table};
use crate::Result;

fn kendall_tau(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let mut conc = 0i32;
    let mut disc = 0i32;
    for i in 0..n {
        for j in i + 1..n {
            let s = ((a[i] - a[j]) as f64) * ((b[i] - b[j]) as f64);
            if s > 0.0 {
                conc += 1;
            } else if s < 0.0 {
                disc += 1;
            }
        }
    }
    (conc - disc) as f32 / ((n * (n - 1) / 2).max(1) as f32)
}

pub fn run(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let archive = common::main_archive(ctx, pipe, fresh)?;
    // sample up to 16 frontier configs spread over the bits range
    let front = archive.pareto_front();
    let mut configs: Vec<_> = front
        .iter()
        .map(|&i| archive.samples[i].clone())
        .collect();
    configs.sort_by(|a, b| a.avg_bits.partial_cmp(&b.avg_bits).unwrap());
    let take = 16.min(configs.len());
    let picked: Vec<_> = (0..take)
        .map(|k| configs[k * (configs.len() - 1) / take.max(1)].clone())
        .collect();

    let mut table = Table::new(
        "Figure 6 — proxy (HQQ) vs deploy quantizer PPL on frontier configs",
        &["avg_bits", "hqq_ppl", "awq_ppl", "gptq_ppl"],
    );
    let mut hqq_v = Vec::new();
    let mut awq_v = Vec::new();
    let mut gptq_v = Vec::new();
    for s in &picked {
        // proxy (HQQ pieces already uploaded)
        let layers = pipe.proxy.assemble(&s.config)?;
        let hqq_ppl =
            eval::perplexity_on(&ctx.rt, &ModelHandle::Quant(&layers), &ctx.wiki)?;
        // deploy-time quantizers
        let awq_layers =
            common::deploy_layers(ctx, &s.config, &AwqClip::default() as &dyn Quantizer, true)?;
        let refs: Vec<&_> = awq_layers.iter().collect();
        let awq_ppl = eval::perplexity_on(&ctx.rt, &ModelHandle::Quant(&refs), &ctx.wiki)?;
        let gptq_layers =
            common::deploy_layers(ctx, &s.config, &Gptq::default() as &dyn Quantizer, true)?;
        let refs: Vec<&_> = gptq_layers.iter().collect();
        let gptq_ppl = eval::perplexity_on(&ctx.rt, &ModelHandle::Quant(&refs), &ctx.wiki)?;
        table.row(vec![
            fmt(s.avg_bits as f32, 3),
            fmt(hqq_ppl, 3),
            fmt(awq_ppl, 3),
            fmt(gptq_ppl, 3),
        ]);
        hqq_v.push(hqq_ppl);
        awq_v.push(awq_ppl);
        gptq_v.push(gptq_ppl);
    }
    table.print();
    println!(
        "Kendall-τ(HQQ, AWQ) = {:.3}   Kendall-τ(HQQ, GPTQ) = {:.3}",
        kendall_tau(&hqq_v, &awq_v),
        kendall_tau(&hqq_v, &gptq_v)
    );
    table.to_csv(&ctx.out_dir.join("fig6.csv"))?;
    Ok(())
}

//! Figures 9 & 10 analog: the effect of search-space pruning — bit-region
//! coverage of explored samples (Fig 9) and frontier C4 PPL (Fig 10), with
//! vs without the 2x-median outlier exclusion.

use super::common::{self, Pipeline};
use super::Ctx;
use crate::report::{fmt, Table};
use crate::Result;

pub fn run(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    // with pruning (pruned space), without pruning (full space)
    let with = common::search_cached(ctx, pipe, &ctx.preset, "search_pruned", fresh)?;
    let without = {
        let tag = "search_unpruned";
        let path = ctx.out_dir.join("cache").join(format!("{tag}.json"));
        let archive = super::cache::archive_cached(&path, fresh, || {
            let mut evaluator = common::search_evaluator(ctx, pipe);
            let res = crate::coordinator::run_search(
                &pipe.full_space,
                evaluator.as_mut(),
                &ctx.preset,
            )?;
            Ok(res.archive)
        })?;
        common::rebits(archive, &pipe.full_space)
    };

    // Fig 9: histogram of explored avg-bits
    let mut hist = Table::new(
        "Figure 9 — explored samples per bit region",
        &["bits_bin", "with_pruning", "without_pruning"],
    );
    let bins = [(2.25, 2.75), (2.75, 3.25), (3.25, 3.75), (3.75, 4.26)];
    for (lo, hi) in bins {
        let cw = with
            .samples
            .iter()
            .filter(|s| s.avg_bits >= lo && s.avg_bits < hi)
            .count();
        let co = without
            .samples
            .iter()
            .filter(|s| s.avg_bits >= lo && s.avg_bits < hi)
            .count();
        hist.row(vec![format!("[{lo},{hi})"), cw.to_string(), co.to_string()]);
    }
    hist.print();
    hist.to_csv(&ctx.out_dir.join("fig9.csv"))?;

    // Fig 10: frontier C4 PPL with vs without pruning
    let mut ppl = Table::new(
        "Figure 10 — frontier C4 PPL with vs without pruning",
        &["avg_bits", "with_pruning", "without_pruning"],
    );
    for &budget in &common::BUDGETS {
        let mut row = vec![format!("{budget}")];
        for (archive, space) in [(&with, &pipe.space), (&without, &pipe.full_space)] {
            match archive.best_under(budget, common::TOL) {
                Some(s) => {
                    let layers = common::deploy_layers(
                        ctx, &s.config, &crate::quant::AwqClip::default(), true)?;
                    let refs: Vec<&_> = layers.iter().collect();
                    let (_w, c4) =
                        common::ppl_only(ctx, &crate::eval::ModelHandle::Quant(&refs))?;
                    let _ = space;
                    row.push(fmt(c4, 2));
                }
                None => row.push("-".into()),
            }
        }
        ppl.row(row);
    }
    ppl.print();
    ppl.to_csv(&ctx.out_dir.join("fig10.csv"))?;
    Ok(())
}

//! `repro genescan` — the per-`(layer, method, bits)` gene sensitivity
//! scan (`sensitivity::scan_genes`) as a standalone experiment: how much
//! each gene choice hurts relative to the all-max baseline, which
//! `(method, bits)` each layer tolerates best, and a machine-readable JSON
//! dump.  The scan is one batched dispatch, so it dedups, microbatches and
//! fans out across pool shards exactly like the search hot path.

use super::{common, Ctx};
use crate::coordinator::sensitivity;
use crate::report::{fmt, Table};
use crate::Result;
use std::fmt::Write as _;

pub fn run(ctx: &Ctx, pipe: &common::Pipeline) -> Result<()> {
    let space = &pipe.full_space;
    let mut evaluator = common::search_evaluator(ctx, pipe);
    let scan = sensitivity::scan_genes(space, evaluator.as_mut())?;

    let layer_name = |li: usize| ctx.assets.manifest.layers[li].name.clone();

    let mut table = Table::new(
        "gene sensitivity scan (Δjsd vs all-max baseline)",
        &["layer", "method", "bits", "jsd", "delta"],
    );
    for p in &scan.probes {
        table.row(vec![
            layer_name(p.layer),
            p.method.name().to_string(),
            p.bits.to_string(),
            fmt(p.jsd, 5),
            fmt(p.jsd - scan.baseline, 5),
        ]);
    }
    table.print();

    let mut best = Table::new(
        "gentlest probe per layer",
        &["layer", "method", "bits", "delta"],
    );
    for (li, probe) in scan.best_per_layer(space.n_layers()).iter().enumerate() {
        if let Some(p) = probe {
            best.row(vec![
                layer_name(li),
                p.method.name().to_string(),
                p.bits.to_string(),
                fmt(p.jsd - scan.baseline, 5),
            ]);
        }
    }
    best.print();
    if let Some(s) = evaluator.batch_stats() {
        eprintln!(
            "[genescan] {} probes in {} scorer dispatches (score-batch {})",
            scan.probes.len(),
            s.dispatches,
            s.score_batch,
        );
    }

    let mut json = String::from("{\n");
    let _ = write!(json, "  \"baseline_jsd\": {},\n  \"probes\": [\n", scan.baseline);
    for (i, p) in scan.probes.iter().enumerate() {
        if i > 0 {
            json.push_str(",\n");
        }
        let _ = write!(
            json,
            "    {{\"layer\": \"{}\", \"method\": \"{}\", \"bits\": {}, \"jsd\": {}}}",
            layer_name(p.layer),
            p.method.name(),
            p.bits,
            p.jsd,
        );
    }
    json.push_str("\n  ]\n}\n");
    let path = ctx.out_dir.join("genescan.json");
    std::fs::write(&path, json)?;
    eprintln!("[genescan] wrote {}", path.display());
    Ok(())
}

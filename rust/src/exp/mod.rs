//! Experiment harnesses — one module per paper table/figure (DESIGN.md §5).
//!
//! Shared machinery lives here: the loaded [`Ctx`] (assets + runtime +
//! calibration batches), the pruned-space pipeline every experiment starts
//! from, and a JSON cache so expensive search runs are shared between
//! figures/tables that draw from the same frontier.

pub mod cache;
pub mod common;
pub mod fig1;
pub mod fig11;
pub mod fig12;
pub mod fig2;
pub mod fig6;
pub mod fig9;
pub mod genescan;
pub mod pruning_ablation;
pub mod speed;
pub mod table1;
pub mod table10;
pub mod table11;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table78;
pub mod table9;

use crate::coordinator::{
    BankShareStats, DeviceBank, EvalBatchStats, EvalPool, ProxyBank, SearchParams,
};
use crate::data::{load_tasks, load_tokens, TaskInstance, TokenSplit};
use crate::model::ModelAssets;
use crate::quant::MethodRegistry;
use crate::runtime::{Runtime, ScoreBatch, ServiceStats, SlabGatherMode};
use crate::Result;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

/// Number of calibration sequences used on the search hot path (one
/// scorer dispatch per candidate chunk — or per lane group — per batch).
/// Final tables evaluate on the full splits.
pub const SEARCH_CALIB_SEQS: usize = 16;

/// Prepared batches over the first [`SEARCH_CALIB_SEQS`] calibration
/// sequences — the single definition shared by [`Ctx::load`] and the pool
/// shards, so pooled and in-thread evaluation score identical data.
pub fn prepare_search_batches(rt: &Runtime, calib: &TokenSplit) -> Result<Vec<ScoreBatch>> {
    let b = rt.batch_size();
    let t = rt.seq_len();
    let mask = vec![1.0f32; b * t];
    let n = SEARCH_CALIB_SEQS.min(calib.n_seqs);
    eyre::ensure!(n % b == 0, "search calib must divide batch");
    let mut batches = Vec::new();
    for start in (0..n).step_by(b) {
        batches.push(rt.prepare_batch(calib.batch(start, b), &mask)?);
    }
    Ok(batches)
}

/// Default microbatch size for candidate scoring (`--score-batch`).
/// Results are identical for any value; only dispatch granularity changes.
pub const DEFAULT_SCORE_BATCH: usize = 8;

pub use crate::coordinator::DEFAULT_SLAB_CACHE_MB;

/// Headline numbers of the most recent (non-cached) search run, stashed for
/// the machine-readable bench report.
#[derive(Clone, Debug, Default)]
pub struct SearchRunStats {
    pub true_evals: usize,
    pub predictor_queries: usize,
    pub wall_secs: f64,
}

/// Everything an experiment needs, loaded once.  The heavyweight pieces
/// (assets, runtime, calibration batches, the uploaded device bank) are
/// behind `Arc`s: the main thread and every evaluation-pool shard share one
/// copy of each — shards own nothing but cheap handles.
pub struct Ctx {
    pub assets: Arc<ModelAssets>,
    pub rt: Arc<Runtime>,
    pub calib: TokenSplit,
    pub wiki: TokenSplit,
    pub c4: TokenSplit,
    pub tasks: Vec<TaskInstance>,
    /// Prepared batches over the first [`SEARCH_CALIB_SEQS`] calib seqs,
    /// shared with the pool shards.
    pub search_batches: Arc<Vec<ScoreBatch>>,
    pub out_dir: PathBuf,
    pub preset: SearchParams,
    /// Artifacts directory.
    pub artifacts: PathBuf,
    /// Evaluation-pool width (`--workers N`); 1 = in-thread evaluation.
    pub workers: usize,
    /// Remote shard-server addresses (`--shards a:p,b:p`).  Each address
    /// becomes one feeder shard on the same FIFO as the local workers, so
    /// in-process and remote shards mix freely (see
    /// [`common::spawn_search_pool`]).
    pub shards: Vec<String>,
    /// Scoring microbatch size (`--score-batch K`).
    pub score_batch: usize,
    /// Hedged-dispatch aggressiveness (`--hedge-factor F`): a chunk
    /// in-flight longer than `F × rolling p50` is speculatively duplicated
    /// onto an idle shard (first reply wins).  `0` disables hedging.
    /// Archives are identical either way — evals are pure, so a hedge can
    /// change wall-clock, never results.
    pub hedge_factor: f64,
    /// Lane-slab cache budget in MB (`--slab-cache-mb`; 0 = off).
    pub slab_cache_mb: usize,
    /// Requested slab-gather mode (`--slab-gather`); whether misses
    /// actually gather on device is [`Runtime::slab_gather_enabled`].
    pub slab_gather: SlabGatherMode,
    /// Enabled quantization methods (`--methods`, default: the manifest's
    /// list, which defaults to single-method HQQ — the legacy genome).
    pub registry: MethodRegistry,
    /// Warm-start directory (`--warm-start DIR`): finished searches persist
    /// their archive + predictor training set there, and later searches
    /// with a matching `(model, methods)` key reload them (see
    /// [`crate::coordinator::warmstart`]).  `None` = off.
    pub warm_start: Option<PathBuf>,
    /// Lazily-spawned sharded evaluation pool, shared across searches.
    pool: OnceLock<Arc<EvalPool>>,
    /// The process-wide device bank: quantized once, uploaded once, shared
    /// by the main thread and every pool shard (the error arm memoizes a
    /// failed build so shards report it instead of retrying).
    device_bank: Arc<OnceLock<std::result::Result<Arc<DeviceBank>, String>>>,
    /// Bank references registered by initialized pool shards (accounting).
    shard_banks: Arc<Mutex<Vec<Arc<ProxyBank>>>>,
    /// Dispatch/dedup stats of the most recent search evaluator.
    last_eval_stats: Mutex<Option<EvalBatchStats>>,
    /// Headline numbers of the most recent (non-cached) search run.
    last_search: Mutex<Option<SearchRunStats>>,
    /// Warm-start tier the most recent search resolved to ("off" until a
    /// search runs with `--warm-start`).
    last_warm: Mutex<&'static str>,
}

impl Ctx {
    pub fn load(artifacts_dir: &Path, out_dir: &Path, preset: SearchParams) -> Result<Ctx> {
        Self::load_with_workers(artifacts_dir, out_dir, preset, 1)
    }

    /// Load with an explicit evaluation-pool width and the manifest's
    /// method enable list.
    pub fn load_with_workers(
        artifacts_dir: &Path,
        out_dir: &Path,
        preset: SearchParams,
        workers: usize,
    ) -> Result<Ctx> {
        Self::load_with_opts(
            artifacts_dir,
            out_dir,
            preset,
            workers,
            None,
            DEFAULT_SCORE_BATCH,
            0,
            DEFAULT_SLAB_CACHE_MB,
            SlabGatherMode::Auto,
        )
    }

    /// Load with explicit options.  `workers <= 1` keeps every
    /// true-evaluation on the calling thread (the seed behaviour);
    /// `workers > 1` spawns that many shards on first use — all sharing
    /// this context's runtime, proxy device bank and calibration batches.
    /// `registry` overrides the manifest's method enable list (CLI
    /// `--methods`); `score_batch` is the scoring microbatch size (CLI
    /// `--score-batch`, clamped to >= 1); `lanes` is the scorer lane
    /// request (CLI `--lanes`: 0 = auto, 1 = per-candidate, N = require an
    /// N-lane artifact — see [`Runtime::load_with_lanes`]);
    /// `slab_cache_mb` is the lane-slab cache budget (CLI
    /// `--slab-cache-mb`, 0 = off — archives identical either way);
    /// `slab_gather` routes lane-slab cache misses (CLI `--slab-gather`:
    /// auto = gather on device when the artifacts allow, off = always
    /// host-pack + upload, require = error without the gather artifacts —
    /// archives identical for any mode, see [`SlabGatherMode`]).
    #[allow(clippy::too_many_arguments)]
    pub fn load_with_opts(
        artifacts_dir: &Path,
        out_dir: &Path,
        preset: SearchParams,
        workers: usize,
        registry: Option<MethodRegistry>,
        score_batch: usize,
        lanes: usize,
        slab_cache_mb: usize,
        slab_gather: SlabGatherMode,
    ) -> Result<Ctx> {
        let assets = Arc::new(ModelAssets::load(artifacts_dir)?);
        let rt = Arc::new(Runtime::load_with_opts(
            artifacts_dir,
            &assets.weights,
            lanes,
            slab_gather,
        )?);
        let calib = load_tokens(&assets.manifest.file("calib")?)?;
        let wiki = load_tokens(&assets.manifest.file("test_wiki")?)?;
        let c4 = load_tokens(&assets.manifest.file("test_c4")?)?;
        let tasks = load_tasks(&assets.manifest.file("tasks")?)?;

        let search_batches = Arc::new(prepare_search_batches(&rt, &calib)?);
        std::fs::create_dir_all(out_dir)?;
        std::fs::create_dir_all(out_dir.join("cache"))?;
        let registry =
            registry.unwrap_or_else(|| MethodRegistry::from_names(&assets.manifest.methods));
        Ok(Ctx {
            assets,
            rt,
            calib,
            wiki,
            c4,
            tasks,
            search_batches,
            out_dir: out_dir.to_path_buf(),
            preset,
            artifacts: artifacts_dir.to_path_buf(),
            workers: workers.max(1),
            shards: Vec::new(),
            score_batch: score_batch.max(1),
            hedge_factor: crate::runtime::DEFAULT_HEDGE_FACTOR,
            slab_cache_mb,
            slab_gather,
            registry,
            warm_start: None,
            pool: OnceLock::new(),
            device_bank: Arc::new(OnceLock::new()),
            shard_banks: Arc::new(Mutex::new(Vec::new())),
            last_eval_stats: Mutex::new(None),
            last_search: Mutex::new(None),
            last_warm: Mutex::new("off"),
        })
    }

    /// The process-wide device bank: the proxy quantization pass and the
    /// device upload both happen exactly once, on first demand, and every
    /// caller (pipeline build, pool shards) shares the same `Arc`.
    pub fn device_bank(&self) -> Result<Arc<DeviceBank>> {
        self.device_bank
            .get_or_init(|| {
                let bank = common::build_proxy_bank(&self.assets, &self.registry)
                    .map_err(|e| format!("{e}"))?;
                DeviceBank::upload_with_slab_budget(
                    &self.rt,
                    Arc::new(bank),
                    crate::coordinator::slab_budget_bytes(self.slab_cache_mb),
                )
                .map(Arc::new)
                .map_err(|e| format!("{e}"))
            })
            .clone()
            .map_err(|e| eyre::anyhow!("device bank unavailable: {e}"))
    }

    /// Slab-cache counters of the process-wide device bank, if it was ever
    /// uploaded (does not force an upload).
    pub fn slab_cache_stats(&self) -> Option<crate::runtime::SlabCacheStats> {
        match self.device_bank.get() {
            Some(Ok(dev)) => Some(dev.slab_cache.stats()),
            _ => None,
        }
    }

    /// Point the evaluation pool at remote shard servers (`--shards`).
    /// Must be called before the pool first spawns; the addresses become
    /// feeder shards sharing the local workers' FIFO.
    pub fn set_shards(&mut self, shards: Vec<String>) {
        debug_assert!(self.pool.get().is_none(), "set_shards after pool spawn");
        self.shards = shards;
    }

    /// Set the hedged-dispatch factor (`--hedge-factor`; 0 disables).
    /// Must be called before the pool first spawns.
    pub fn set_hedge_factor(&mut self, factor: f64) {
        debug_assert!(self.pool.get().is_none(), "set_hedge_factor after pool spawn");
        self.hedge_factor = factor.max(0.0);
    }

    /// Point searches at a warm-start directory (`--warm-start DIR`).
    pub fn set_warm_start(&mut self, dir: Option<String>) {
        self.warm_start = dir.map(PathBuf::from);
    }

    /// Record which warm-start tier a search resolved to
    /// ("exact"/"seed"/"cold"; stays "off" when `--warm-start` is unset).
    pub fn note_warm_tier(&self, tier: &'static str) {
        *self.last_warm.lock().unwrap() = tier;
    }

    pub fn warm_tier(&self) -> &'static str {
        *self.last_warm.lock().unwrap()
    }

    /// Local (in-process) shard count for the pool topology: with no remote
    /// shards this is `--workers`; with `--shards` alone evaluation is pure
    /// remote (0 local); `--workers N --shards ...` (N > 1) mixes both.
    pub fn local_workers(&self) -> usize {
        if self.shards.is_empty() || self.workers > 1 {
            self.workers
        } else {
            0
        }
    }

    /// The shared evaluation pool, spawned on first use (None when running
    /// single-worker with no remote shards).  Shards initialize lazily on
    /// their first request, so spawning the pool is cheap.
    pub fn eval_pool(&self) -> Option<Arc<EvalPool>> {
        if self.workers <= 1 && self.shards.is_empty() {
            return None;
        }
        Some(
            self.pool
                .get_or_init(|| Arc::new(common::spawn_search_pool(self)))
                .clone(),
        )
    }

    /// Pool statistics, if a pool was ever spawned (does not spawn one).
    pub fn pool_stats(&self) -> Option<ServiceStats> {
        self.pool.get().map(|p| p.stats())
    }

    /// Shut the evaluation pool down, joining the shard threads and closing
    /// any remote feeder connections.  Sequential shard servers can then
    /// accept follow-up connections — the post-search stats probe relies on
    /// this.  Best-effort (a still-cloned pool handle defers the join to
    /// its own drop); no-op when no pool was ever spawned.
    pub fn shutdown_pool(&mut self) {
        drop(self.pool.take());
    }

    /// Device-bank residency across the shards that actually initialized:
    /// the shared bank is counted once, however many shards reference it,
    /// and the live slab-cache bytes fold in so the report covers every
    /// buffer the scoring path holds.
    pub fn bank_share_stats(&self) -> Option<BankShareStats> {
        let banks = self.shard_banks.lock().unwrap();
        if banks.is_empty() {
            None
        } else {
            let slab_bytes =
                self.slab_cache_stats().map(|s| s.resident_bytes).unwrap_or(0);
            Some(BankShareStats::from_shard_banks(&banks).with_slab_cache_bytes(slab_bytes))
        }
    }

    /// Stash the dispatch/dedup stats of a finished search evaluator
    /// (reported by `repro` and serialized into the bench JSON).
    pub fn note_eval_stats(&self, stats: Option<EvalBatchStats>) {
        if let Some(s) = stats {
            *self.last_eval_stats.lock().unwrap() = Some(s);
        }
    }

    pub fn last_eval_stats(&self) -> Option<EvalBatchStats> {
        self.last_eval_stats.lock().unwrap().clone()
    }

    /// Stash the headline numbers of a finished (non-cached) search run.
    pub fn note_search_stats(&self, stats: SearchRunStats) {
        *self.last_search.lock().unwrap() = Some(stats);
    }

    pub fn last_search_stats(&self) -> Option<SearchRunStats> {
        self.last_search.lock().unwrap().clone()
    }

    /// Prepared batches over a whole token split (for final JSD evals).
    pub fn batches_for(&self, split: &TokenSplit) -> Result<Vec<ScoreBatch>> {
        let b = self.rt.batch_size();
        let t = self.rt.seq_len();
        let mask = vec![1.0f32; b * t];
        let mut out = Vec::new();
        for start in (0..split.n_seqs).step_by(b) {
            out.push(self.rt.prepare_batch(split.batch(start, b), &mask)?);
        }
        Ok(out)
    }

    pub fn pad(&self) -> i32 {
        self.assets.manifest.pad_token()
    }
}

/// Registry of all experiments for `repro all` / `repro list`.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("fig1", "memory vs task accuracy + tokens/s trade-off"),
    ("fig2", "per-layer 2-bit quantization sensitivity"),
    ("fig5", "layer-wise vs group-mixed vs fp16 inference speed"),
    ("fig6", "proxy (HQQ) vs GPTQ/AWQ Pareto order agreement"),
    ("fig7", "accuracy vs avg-bits trade-off curves"),
    ("fig8", "tokens/s at each avg-bits for all methods"),
    ("fig9", "search bit-histogram with vs without pruning"),
    ("genescan", "per-(layer, method, bits) gene sensitivity scan"),
    ("fig10", "frontier PPL with vs without pruning"),
    ("fig11", "frontier PPL vs iteration over 6 seeds"),
    ("fig12", "bit-allocation heatmaps per budget"),
    ("table1", "AMQ vs BitStack vs PB-LLM @ 2.5/3.0/3.5 bits"),
    ("table2", "harder few-shot tasks (MMLU/GSM8K analog)"),
    ("table3", "AMQ vs fixed-precision GPTQ/AWQ"),
    ("table4", "search + compression wallclock costs"),
    ("table5", "pruning threshold x calibration-set ablation"),
    ("table7", "NSGA-II crossover-probability robustness"),
    ("table8", "NSGA-II mutation-probability robustness"),
    ("table9", "RBF vs MLP predictor ablation"),
    ("table10", "search-iteration budget ablation"),
    ("table11", "one-shot vs greedy vs AMQ (cost + quality)"),
];

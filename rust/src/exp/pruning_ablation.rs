//! Table 5 analog: pruning threshold x calibration-set ablation — which
//! layers are excluded and how frontier C4 PPL responds.

use super::common::{self, Pipeline};
use super::Ctx;
use crate::coordinator::{pruning, sensitivity, ProxyEvaluator};
use crate::report::{fmt, Table};
use crate::Result;

pub fn run(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let m = &ctx.assets.manifest;

    // alternative calibration set: first 16 sequences of the shifted (C4)
    // split, mirroring the paper's WikiText-2 vs C4 column
    let b = ctx.rt.batch_size();
    let t = ctx.rt.seq_len();
    let mask = vec![1.0f32; b * t];
    let alt_batches = vec![ctx.rt.prepare_batch(ctx.c4.batch(0, b), &mask)?];

    let mut table = Table::new(
        "Table 5 — pruning threshold x calibration set",
        &["calib", "threshold", "outliers", "frac_%", "ppl@2.5", "ppl@3.0",
          "ppl@3.5", "ppl@4.0"],
    );

    for (calib_name, batches) in [
        ("wiki", ctx.search_batches.as_slice()),
        ("c4", alt_batches.as_slice()),
    ] {
        // sensitivity under this calibration set (same genome as the
        // pipeline, so the proxy bank covers every probed gene)
        let full = pipe.full_space.clone();
        let mut ev = ProxyEvaluator::new(&pipe.proxy, batches);
        let sens = sensitivity::measure(&full, &mut ev)?;
        for &thr in &[1.5f32, 2.0, 3.0, 5.0] {
            let mut space = full.clone();
            let rep = pruning::prune(&mut space, &sens, thr);
            let names: Vec<String> = rep
                .outliers
                .iter()
                .map(|&i| m.layers[i].name.clone())
                .collect();
            // light search on this space, then frontier PPL
            let mut params = ctx.preset.clone();
            params.iterations = (ctx.preset.iterations / 2).max(4);
            let tag = format!("search_prune_{calib_name}_{}", (thr * 10.0) as u32);
            let path = ctx.out_dir.join("cache").join(format!("{tag}.json"));
            let archive = super::cache::archive_cached(&path, fresh, || {
                let mut evaluator = common::search_evaluator(ctx, pipe);
                let res =
                    crate::coordinator::run_search(&space, evaluator.as_mut(), &params)?;
                Ok(res.archive)
            })?;
            let archive = common::rebits(archive, &space);
            let mut row = vec![
                calib_name.to_string(),
                format!("{thr}x"),
                if names.is_empty() { "-".into() } else { names.join(" ") },
                fmt(rep.excluded_frac * 100.0, 2),
            ];
            for &budget in &common::BUDGETS {
                match archive.best_under(budget, common::TOL) {
                    Some(s) => {
                        let layers = common::deploy_layers(
                            ctx, &s.config, &crate::quant::AwqClip::default(), true)?;
                        let refs: Vec<&_> = layers.iter().collect();
                        let (_w, c4) = common::ppl_only(
                            ctx, &crate::eval::ModelHandle::Quant(&refs))?;
                        row.push(fmt(c4, 2));
                    }
                    None => row.push("-".into()),
                }
            }
            table.row(row);
        }
    }
    table.print();
    table.to_csv(&ctx.out_dir.join("table5.csv"))?;
    Ok(())
}

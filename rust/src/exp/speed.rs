//! Figures 5 & 8 analog: inference speed.  Two sources:
//!  * the roofline cost model at 7B-equivalent scale (the paper's GPUs are
//!    simulated; DESIGN.md §3 documents the substitution), and
//!  * *measured* wall-clock of the real PJRT executables on this CPU
//!    (fp32 graph vs Pallas dequant-matmul graph) as the honest local datum.

use super::common::{self, Pipeline};
use super::Ctx;
use crate::costmodel::{self, DeployKind, HwProfile, L40S, RTX3090};
use crate::report::{fmt, Table};
use crate::Result;
use std::time::Instant;

pub fn run_fig5(ctx: &Ctx, _pipe: &Pipeline) -> Result<()> {
    let m = &ctx.assets.manifest;
    let mut table = Table::new(
        "Figure 5 — layer-wise vs group-mixed speed (7B-equivalent, simulated)",
        &["hw", "method", "tok_per_s"],
    );
    for hw in [&L40S, &RTX3090] {
        let fp = costmodel::tokens_per_sec(hw, m, &DeployKind::Fp16);
        let bits3 = vec![3u8; m.layers.len()];
        let lw = costmodel::tokens_per_sec(hw, m, &DeployKind::LayerQuant(&bits3));
        let gm = costmodel::tokens_per_sec(hw, m, &DeployKind::GroupMixed(3.0));
        table.row(vec![hw.name.into(), "FP16".into(), fmt(fp as f32, 1)]);
        table.row(vec![hw.name.into(), "group-mixed w3".into(), fmt(gm as f32, 1)]);
        table.row(vec![hw.name.into(), "layer-wise w3".into(), fmt(lw as f32, 1)]);
    }
    table.print();
    table.to_csv(&ctx.out_dir.join("fig5.csv"))?;
    Ok(())
}

pub fn run_fig8(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let m = &ctx.assets.manifest;
    let archive = common::main_archive(ctx, pipe, fresh)?;
    let bs = common::bitstack_build(ctx, 10)?;
    let mut table = Table::new(
        "Figure 8 — tokens/s at each average bits (simulated)",
        &["hw", "avg_bits", "AMQ", "BitStack", "PB-LLM", "FP16"],
    );
    for hw in [&L40S, &RTX3090] {
        let fp = costmodel::tokens_per_sec(hw, m, &DeployKind::Fp16);
        for &budget in &common::BUDGETS {
            let cfg = common::pick(&archive, &pipe.space, budget)?;
            let cfg_bits = pipe.space.config_bits(&cfg);
            let amq = costmodel::tokens_per_sec(hw, m, &DeployKind::LayerQuant(&cfg_bits));
            let loaded = bs.allocate(common::budget_bytes(&pipe.space, budget));
            let bst = costmodel::tokens_per_sec(hw, m, &DeployKind::BitStack(&loaded));
            let pb = costmodel::tokens_per_sec(
                hw, m, &DeployKind::PbLlm((budget - 1.0) / 7.0));
            table.row(vec![
                hw.name.into(),
                format!("{budget}"),
                fmt(amq as f32, 1),
                fmt(bst as f32, 1),
                fmt(pb as f32, 1),
                fmt(fp as f32, 1),
            ]);
        }
    }
    table.print();
    println!();
    measured(ctx, pipe)?;
    table.to_csv(&ctx.out_dir.join("fig8.csv"))?;
    Ok(())
}

/// Honest local measurement: per-batch latency of the fp32 executable vs the
/// Pallas dequant-matmul executable on this CPU.
pub fn measured(ctx: &Ctx, pipe: &Pipeline) -> Result<()> {
    let b = ctx.rt.batch_size();
    let t = ctx.rt.seq_len();
    let toks = ctx.calib.batch(0, b);
    let cfg3 = pipe.full_space.uniform(3);
    let layers = pipe.proxy.assemble(&cfg3)?;

    // warmup
    let _ = ctx.rt.fp_logits(toks)?;
    let _ = ctx.rt.quant_logits(toks, &layers)?;

    let reps = 5;
    let t0 = Instant::now();
    for _ in 0..reps {
        let _ = ctx.rt.fp_logits(toks)?;
    }
    let fp_s = t0.elapsed().as_secs_f64() / reps as f64;
    let t0 = Instant::now();
    for _ in 0..reps {
        let _ = ctx.rt.quant_logits(toks, &layers)?;
    }
    let q_s = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "measured (CPU PJRT, batch {b}x{t}): fp32 {:.1} ms, quant(w3, Pallas) {:.1} ms \
         ({:.0} vs {:.0} tok/s prefill)",
        fp_s * 1e3,
        q_s * 1e3,
        (b * t) as f64 / fp_s,
        (b * t) as f64 / q_s
    );
    Ok(())
}

#[allow(dead_code)]
fn hw_list() -> Vec<&'static HwProfile> {
    vec![&L40S, &RTX3090]
}

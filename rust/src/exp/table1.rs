//! Table 1 analog: AMQ vs BitStack vs PB-LLM at average bits 2.5/3.0/3.5 —
//! WikiText/C4-analog PPL + the six zero-shot task families.

use super::common::{self, Pipeline};
use super::Ctx;
use crate::data::ZERO_SHOT;
use crate::eval::ModelHandle;
use crate::report::{fmt, Table};
use crate::Result;

pub fn run(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let archive = common::main_archive(ctx, pipe, fresh)?;
    let mut table = Table::new(
        "Table 1 — AMQ vs any-size baselines",
        &[
            "mem_MB", "avg_bits", "method", "wiki_ppl", "c4_ppl", "copy", "compl",
            "agree", "major", "induc", "recall", "avg_acc",
        ],
    );

    let mut push = |mem: f64, bits: String, method: &str, q: &common::QualityOut| {
        let mut row = vec![
            fmt(mem as f32, 1),
            bits,
            method.to_string(),
            fmt(q.wiki_ppl, 2),
            fmt(q.c4_ppl, 2),
        ];
        for f in ZERO_SHOT {
            row.push(fmt(q.zero_shot.accuracy(f), 1));
        }
        row.push(fmt(q.zero_shot.macro_avg(&ZERO_SHOT), 2));
        table.row(row);
    };

    // FP16 reference row
    let fp_q = common::quality(ctx, &ModelHandle::Fp)?;
    push(common::fp16_memory_mb(ctx), "16".into(), "FP16", &fp_q);

    let bs = common::bitstack_build(ctx, 10)?;
    for &budget in &[2.5f64, 3.0, 3.5] {
        // AMQ: frontier config, deployed with asym-clip AWQ
        let cfg = common::pick(&archive, &pipe.space, budget)?;
        let amq_q = common::amq_quality(ctx, &cfg)?;
        let mem = common::row_memory_mb(ctx, &pipe.space, &cfg);

        // BitStack at the same searchable-weight byte budget
        let bytes = common::budget_bytes(&pipe.space, budget);
        let (bs_q, _loaded) = common::bitstack_quality(ctx, &bs, bytes)?;

        // PB-LLM at matching average bits
        let pb_q = common::pbllm_quality(ctx, budget)?;

        push(mem, format!("{budget}"), "PB-LLM", &pb_q);
        push(mem, format!("{budget}"), "BitStack", &bs_q);
        push(mem, format!("{budget}"), "AMQ", &amq_q);
    }

    table.print();
    table.to_csv(&ctx.out_dir.join("table1.csv"))?;
    Ok(())
}

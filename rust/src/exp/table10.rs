//! Table 10 analog: iteration-budget ablation — search cost vs frontier
//! C4-analog PPL at each budget.

use super::common::{self, Pipeline};
use super::Ctx;
use crate::coordinator::run_search;
use crate::report::{fmt, Table};
use crate::Result;
use std::time::Instant;

pub fn run(ctx: &Ctx, pipe: &Pipeline, _fresh: bool) -> Result<()> {
    let mut table = Table::new(
        "Table 10 — iteration budget vs cost and C4 PPL",
        &["iters", "time_s", "true_evals", "ppl@2.5", "ppl@3.0", "ppl@3.5", "ppl@4.0"],
    );
    // run fresh each time (timing is the point), half/default/double budget
    let base = ctx.preset.iterations;
    for iters in [base / 2, base, base * 2] {
        let mut params = ctx.preset.clone();
        params.iterations = iters.max(1);
        let mut evaluator = common::search_evaluator(ctx, pipe);
        let t0 = Instant::now();
        let res = run_search(&pipe.space, evaluator.as_mut(), &params)?;
        let secs = t0.elapsed().as_secs_f64();
        let mut row = vec![
            format!("{}", params.iterations),
            fmt(secs as f32, 1),
            format!("{}", res.true_evals),
        ];
        for &budget in &common::BUDGETS {
            let cfg = common::pick(&res.archive, &pipe.space, budget)?;
            let layers =
                common::deploy_layers(ctx, &cfg, &crate::quant::AwqClip::default(), true)?;
            let refs: Vec<&_> = layers.iter().collect();
            let (_wiki, c4) = common::ppl_only(ctx, &crate::eval::ModelHandle::Quant(&refs))?;
            row.push(fmt(c4, 2));
        }
        table.row(row);
    }
    table.print();
    table.to_csv(&ctx.out_dir.join("table10.csv"))?;
    Ok(())
}

//! Tables 11 & 12 analog: one-shot and greedy discrete-search baselines vs
//! AMQ — search cost and resulting quality at each budget.

use super::common::{self, Pipeline};
use super::Ctx;
use crate::coordinator::{greedy, oneshot, ConfigEvaluator};
use crate::data::ZERO_SHOT;
use crate::report::{fmt, Table};
use crate::Result;
use std::time::Instant;

pub fn run(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let mut cost = Table::new(
        "Table 11 — search cost (seconds, true evals)",
        &["method", "time_s", "true_evals"],
    );
    let mut quality = Table::new(
        "Table 12 — one-shot vs greedy vs AMQ",
        &["avg_bits", "method", "wiki_ppl", "c4_ppl", "avg_acc"],
    );

    let scores = pipe.sensitivity.scores();

    // one-shot: sensitivity ranking reused, one pass per budget
    let t0 = Instant::now();
    let oneshot_cfgs: Vec<_> = common::BUDGETS
        .iter()
        .map(|&b| oneshot::one_shot(&pipe.space, &scores, b))
        .collect();
    // sensitivity scan cost (n_layers + 1 true evals) dominates one-shot
    let oneshot_secs = t0.elapsed().as_secs_f64()
        + pipe.space.n_layers() as f64 * 0.0; // ranking reuse; scan timed below
    cost.row(vec![
        "One-shot".into(),
        fmt(oneshot_secs as f32, 2),
        format!("{} (sensitivity scan)", pipe.space.n_layers() + 1),
    ]);

    // greedy: true-eval driven demotion per budget (expensive — the point);
    // configs cached since the runs are minutes long
    let greedy_cache = ctx.out_dir.join("cache").join("greedy_configs.json");
    let mut greedy_cfgs = Vec::new();
    let t0 = Instant::now();
    #[allow(unused_assignments)]
    let mut greedy_evals = 0usize;
    let cached = (!fresh)
        .then(|| super::cache::load_archive(&greedy_cache).ok())
        .flatten()
        .filter(|a| a.len() == common::BUDGETS.len());
    match cached {
        Some(a) => {
            for s in &a.samples {
                greedy_cfgs.push(s.config.clone());
            }
            cost.row(vec!["Greedy".into(), "(cached)".into(), "-".into()]);
        }
        None => {
            // one pass from max bits down to the lowest budget; snapshot the
            // config whenever it crosses each budget (single greedy descent
            // serves every budget, like the paper's procedure)
            let mut ev = pipe.evaluator(ctx);
            let lowest = common::BUDGETS.iter().cloned().fold(f64::INFINITY, f64::min);
            let mut snapshots: Vec<Option<crate::coordinator::Config>> =
                vec![None; common::BUDGETS.len()];
            {
                // re-implement the descent with snapshots via repeated calls
                let mut targets: Vec<(usize, f64)> = common::BUDGETS
                    .iter().cloned().enumerate().collect();
                targets.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                let mut current_target_idx = 0usize;
                let mut cfg: crate::coordinator::Config = pipe.space.max_config();
                while pipe.space.avg_bits(&cfg) > lowest {
                    let res = greedy::greedy_step(&pipe.space, &mut ev, &cfg)?;
                    match res {
                        Some(next) => cfg = next,
                        None => break,
                    }
                    while current_target_idx < targets.len()
                        && pipe.space.avg_bits(&cfg) <= targets[current_target_idx].1
                    {
                        snapshots[targets[current_target_idx].0] = Some(cfg.clone());
                        current_target_idx += 1;
                    }
                }
            }
            greedy_evals = ev.count();
            for (bi, snap) in snapshots.into_iter().enumerate() {
                greedy_cfgs.push(snap.unwrap_or_else(|| {
                    oneshot::one_shot(&pipe.space, &scores, common::BUDGETS[bi])
                }));
            }
            // persist
            let mut a = crate::coordinator::Archive::new();
            for (bi, c) in greedy_cfgs.iter().enumerate() {
                a.insert(c.clone(), 0.0, common::BUDGETS[bi]);
            }
            super::cache::save_archive(&greedy_cache, &a)?;
            cost.row(vec![
                "Greedy".into(),
                fmt(t0.elapsed().as_secs_f64() as f32, 2),
                format!("{greedy_evals}"),
            ]);
        }
    }

    // AMQ (cached archive; cost reported in table4 — re-derive evals here)
    let t0 = Instant::now();
    let archive = common::main_archive(ctx, pipe, fresh)?;
    let mut ev = pipe.evaluator(ctx);
    let _ = ev.eval_jsd(&common::uniform_config(&pipe.space, 4))?; // warm
    cost.row(vec![
        "AMQ".into(),
        fmt(t0.elapsed().as_secs_f64() as f32, 2),
        format!("{} (archive)", archive.len()),
    ]);

    for (bi, &budget) in common::BUDGETS.iter().enumerate() {
        let entries: Vec<(&str, crate::coordinator::Config)> = vec![
            ("One-shot", oneshot_cfgs[bi].clone()),
            ("Greedy", greedy_cfgs[bi].clone()),
            ("AMQ", common::pick(&archive, &pipe.space, budget)?),
        ];
        for (name, cfg) in entries {
            let q = common::amq_quality(ctx, &cfg)?;
            quality.row(vec![
                format!("{budget}"),
                name.into(),
                fmt(q.wiki_ppl, 2),
                fmt(q.c4_ppl, 2),
                fmt(q.zero_shot.macro_avg(&ZERO_SHOT), 2),
            ]);
        }
    }

    cost.print();
    quality.print();
    cost.to_csv(&ctx.out_dir.join("table11.csv"))?;
    quality.to_csv(&ctx.out_dir.join("table12.csv"))?;
    Ok(())
}

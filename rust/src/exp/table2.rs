//! Table 2 analog: harder few-shot tasks (chained recall ≙ MMLU, modular
//! arithmetic ≙ GSM8K), AMQ vs BitStack across budgets.

use super::common::{self, Pipeline};
use super::Ctx;
use crate::data::FEW_SHOT;
use crate::eval::ModelHandle;
use crate::report::{fmt, Table};
use crate::Result;

pub fn run(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let archive = common::main_archive(ctx, pipe, fresh)?;
    let mut table = Table::new(
        "Table 2 — harder few-shot tasks (MMLU/GSM8K analog)",
        &["avg_bits", "method", "chain(MMLU~)", "modadd(GSM8K~)"],
    );

    let fp_fs = common::few_shot(ctx, &ModelHandle::Fp)?;
    table.row(vec![
        "16".into(),
        "FP16".into(),
        fmt(fp_fs.accuracy(FEW_SHOT[0]), 2),
        fmt(fp_fs.accuracy(FEW_SHOT[1]), 2),
    ]);

    let bs = common::bitstack_build(ctx, 10)?;
    for &budget in &common::BUDGETS {
        let bytes = common::budget_bytes(&pipe.space, budget);
        let loaded = bs.allocate(bytes);
        let recon = bs.reconstruct_all(&loaded);
        let overrides = ctx.rt.upload_weight_overrides(&recon)?;
        let bs_fs = common::few_shot(ctx, &ModelHandle::Override(&overrides))?;

        let cfg = common::pick(&archive, &pipe.space, budget)?;
        let layers = common::deploy_layers(
            ctx, &cfg, &crate::quant::AwqClip::default(), true)?;
        let refs: Vec<&_> = layers.iter().collect();
        let amq_fs = common::few_shot(ctx, &ModelHandle::Quant(&refs))?;

        for (name, fs) in [("BitStack", &bs_fs), ("AMQ", &amq_fs)] {
            table.row(vec![
                format!("{budget}"),
                name.into(),
                fmt(fs.accuracy(FEW_SHOT[0]), 2),
                fmt(fs.accuracy(FEW_SHOT[1]), 2),
            ]);
        }
    }
    table.print();
    table.to_csv(&ctx.out_dir.join("table2.csv"))?;
    Ok(())
}

//! Table 3/13 analog: AMQ vs fixed-precision GPTQ/AWQ quantization at
//! matched average bit-widths (w2g128 ≙ 2.25+, w3, w3g128 ≙ 3.25, w4).

use super::common::{self, Pipeline};
use super::Ctx;
use crate::data::ZERO_SHOT;
use crate::eval::ModelHandle;
use crate::quant::{AwqClip, Gptq, Quantizer};
use crate::report::{fmt, Table};
use crate::runtime::QuantLayerBufs;
use crate::Result;

/// Evaluate a *uniform* quantization with per-row grouping when
/// `grouped=false` (the paper's w3/w4 rows) or gs=128 when true.
fn uniform_quality(
    ctx: &Ctx,
    bits: u8,
    grouped: bool,
    quantizer: &dyn Quantizer,
) -> Result<common::QualityOut> {
    let m = &ctx.assets.manifest;
    let mut layers = Vec::new();
    for l in &m.layers {
        let w = ctx.assets.weights.linear(&l.name)?;
        let gs = if grouped { m.group_size } else { l.in_features };
        let stats = ctx.assets.hessians.for_layer(&l.name)?;
        let q = quantizer.quantize(&w, bits, gs, Some(stats));
        // per-row grouping changes scale/zero geometry; the AOT graph is
        // compiled for gs=128, so re-expand scale/zero to the 128-grid
        let q = if grouped {
            q
        } else {
            expand_groups(q, m.group_size)
        };
        layers.push(ctx.rt.upload_quant_layer(&q)?);
    }
    let refs: Vec<&QuantLayerBufs> = layers.iter().collect();
    common::quality(ctx, &ModelHandle::Quant(&refs))
}

/// Re-express a coarser grouping on the fixed 128-group grid the AOT
/// executable expects (values replicate; numerics identical).
fn expand_groups(q: crate::quant::QuantizedLinear, gs: usize) -> crate::quant::QuantizedLinear {
    if q.group_size == gs {
        return q;
    }
    assert!(q.group_size % gs == 0);
    let reps = q.group_size / gs;
    let old_g = q.in_features / q.group_size;
    let new_g = q.in_features / gs;
    let mut scale = vec![0f32; q.out_features * new_g];
    let mut zero = vec![0f32; q.out_features * new_g];
    for o in 0..q.out_features {
        for g0 in 0..old_g {
            for r in 0..reps {
                scale[o * new_g + g0 * reps + r] = q.scale[o * old_g + g0];
                zero[o * new_g + g0 * reps + r] = q.zero[o * old_g + g0];
            }
        }
    }
    crate::quant::QuantizedLinear { group_size: gs, scale, zero, ..q }
}

pub fn run(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let archive = common::main_archive(ctx, pipe, fresh)?;
    let mut table = Table::new(
        "Table 3 — AMQ vs fixed-precision GPTQ / asym-clip AWQ",
        &["avg_bits", "method", "wiki_ppl", "c4_ppl", "avg_acc"],
    );
    let mut push = |bits: String, method: &str, q: &common::QualityOut| {
        table.row(vec![
            bits,
            method.to_string(),
            fmt(q.wiki_ppl, 2),
            fmt(q.c4_ppl, 2),
            fmt(q.zero_shot.macro_avg(&ZERO_SHOT), 2),
        ]);
    };

    let fp_q = common::quality(ctx, &ModelHandle::Fp)?;
    push("16".into(), "FP16", &fp_q);

    let gptq = Gptq::default();
    let awq = AwqClip::default();

    // 2.25 (w2g128) vs AMQ at 2.35 — the paper gives AMQ +0.1 bits here
    push("2.25".into(), "GPTQ_w2g128", &uniform_quality(ctx, 2, true, &gptq)?);
    push("2.25".into(), "AWQ_w2g128", &uniform_quality(ctx, 2, true, &awq)?);
    let cfg = common::pick(&archive, &pipe.space, 2.35)?;
    push("2.35".into(), "AMQ", &common::amq_quality(ctx, &cfg)?);

    // 3.0 (w3, per-row groups) vs AMQ 3.0
    push("3.0".into(), "GPTQ_w3", &uniform_quality(ctx, 3, false, &gptq)?);
    push("3.0".into(), "AWQ_w3", &uniform_quality(ctx, 3, false, &awq)?);
    let cfg = common::pick(&archive, &pipe.space, 3.0)?;
    push("3.0".into(), "AMQ", &common::amq_quality(ctx, &cfg)?);

    // 3.25 (w3g128) vs AMQ 3.25
    push("3.25".into(), "GPTQ_w3g128", &uniform_quality(ctx, 3, true, &gptq)?);
    push("3.25".into(), "AWQ_w3g128", &uniform_quality(ctx, 3, true, &awq)?);
    let cfg = common::pick(&archive, &pipe.space, 3.25)?;
    push("3.25".into(), "AMQ", &common::amq_quality(ctx, &cfg)?);

    // 4.0 (w4, per-row) vs AMQ 4.0
    push("4.0".into(), "GPTQ_w4", &uniform_quality(ctx, 4, false, &gptq)?);
    push("4.0".into(), "AWQ_w4", &uniform_quality(ctx, 4, false, &awq)?);
    let cfg = common::pick(&archive, &pipe.space, 4.0)?;
    push("4.0".into(), "AMQ", &common::amq_quality(ctx, &cfg)?);

    table.print();
    table.to_csv(&ctx.out_dir.join("table3.csv"))?;
    Ok(())
}

//! Table 4 analog: wall-clock search and compression costs of AWQ,
//! BitStack and AMQ on this testbed (single-core CPU; the paper reports
//! A100 hours — the *structure* of the comparison is what reproduces:
//! AMQ search is cheap thanks to the proxy + predictor, BitStack search is
//! dominated by block evaluation/sorting, AWQ has no search knob).

use super::common::{self, Pipeline};
use super::Ctx;
use crate::coordinator::run_search;
use crate::quant::{AwqClip, Gptq, Quantizer};
use crate::report::{fmt, Table};
use crate::Result;
use std::time::Instant;

pub fn run(ctx: &Ctx, pipe: &Pipeline) -> Result<()> {
    let mut table = Table::new(
        "Table 4 — search + compression wall-clock (this testbed, seconds)",
        &["method", "search_s", "compress_s", "notes"],
    );

    // AWQ: no search; compression = quantize all layers at one width.
    let awq = AwqClip::default();
    let t0 = Instant::now();
    for l in &ctx.assets.manifest.layers {
        let w = ctx.assets.weights.linear(&l.name)?;
        let stats = ctx.assets.hessians.for_layer(&l.name)?;
        let _ = awq.quantize(&w, 3, ctx.assets.manifest.group_size, Some(stats));
    }
    let awq_compress = t0.elapsed().as_secs_f64();
    table.row(vec![
        "AWQ".into(),
        "-".into(),
        fmt(awq_compress as f32, 2),
        "fixed precision only".into(),
    ]);

    // GPTQ likewise.
    let gptq = Gptq::default();
    let t0 = Instant::now();
    for l in &ctx.assets.manifest.layers {
        let w = ctx.assets.weights.linear(&l.name)?;
        let stats = ctx.assets.hessians.for_layer(&l.name)?;
        let _ = gptq.quantize(&w, 3, ctx.assets.manifest.group_size, Some(stats));
    }
    table.row(vec![
        "GPTQ".into(),
        "-".into(),
        fmt(t0.elapsed().as_secs_f64() as f32, 2),
        "fixed precision only".into(),
    ]);

    // BitStack: "search" = residual decomposition + block sorting over
    // budgets; compression = reconstruction at one budget.
    let t0 = Instant::now();
    let bs = common::bitstack_build(ctx, 10)?;
    for &b in &common::BUDGETS {
        let _ = bs.allocate(common::budget_bytes(&pipe.space, b));
    }
    let bs_search = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let loaded = bs.allocate(common::budget_bytes(&pipe.space, 3.0));
    let _ = bs.reconstruct_all(&loaded);
    let bs_compress = t0.elapsed().as_secs_f64();
    table.row(vec![
        "BitStack".into(),
        fmt(bs_search as f32, 2),
        fmt(bs_compress as f32, 2),
        "decompose + block sort".into(),
    ]);

    // AMQ: search = proxy build + sensitivity + NSGA-II loop (fresh, not
    // cached, so the number is honest); compression = deploy-time AWQ of
    // the chosen config.
    let t0 = Instant::now();
    let mut evaluator = common::search_evaluator(ctx, pipe);
    let res = run_search(&pipe.space, evaluator.as_mut(), &ctx.preset)?;
    let amq_search = pipe.proxy_build_secs + t0.elapsed().as_secs_f64();
    let cfg = common::pick(&res.archive, &pipe.space, 3.0)?;
    let t0 = Instant::now();
    let _ = common::deploy_layers(ctx, &cfg, &awq, true)?;
    let amq_compress = t0.elapsed().as_secs_f64();
    table.row(vec![
        "AMQ".into(),
        fmt(amq_search as f32, 2),
        fmt(amq_compress as f32, 2),
        format!(
            "{} true evals, {} predicted",
            res.true_evals, res.predictor_queries
        ),
    ]);

    table.print();
    table.to_csv(&ctx.out_dir.join("table4.csv"))?;
    Ok(())
}

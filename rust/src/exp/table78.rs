//! Tables 7 & 8 analog: robustness of the search to NSGA-II crossover and
//! mutation probabilities (PPL of the frontier configs at each budget).

use super::common::{self, Pipeline};
use super::Ctx;
use crate::report::{fmt, Table};
use crate::Result;

fn sweep(
    ctx: &Ctx,
    pipe: &Pipeline,
    fresh: bool,
    name: &str,
    values: &[f32],
    set: fn(&mut crate::coordinator::SearchParams, f32),
) -> Result<Table> {
    let mut table = Table::new(
        &format!("{name} robustness"),
        &["avg_bits", name, "wiki_ppl", "c4_ppl"],
    );
    for &v in values {
        let mut params = ctx.preset.clone();
        set(&mut params, v);
        let tag = format!("search_{}_{}", name, (v * 100.0) as u32);
        let archive = common::search_cached(ctx, pipe, &params, &tag, fresh)?;
        for &budget in &common::BUDGETS {
            let cfg = common::pick(&archive, &pipe.space, budget)?;
            let layers = common::deploy_layers(
                ctx, &cfg, &crate::quant::AwqClip::default(), true)?;
            let refs: Vec<&_> = layers.iter().collect();
            let (wiki, c4) =
                common::ppl_only(ctx, &crate::eval::ModelHandle::Quant(&refs))?;
            table.row(vec![
                format!("{budget}"),
                format!("{v}"),
                fmt(wiki, 2),
                fmt(c4, 2),
            ]);
        }
    }
    Ok(table)
}

pub fn run_table7(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let t = sweep(ctx, pipe, fresh, "crossover_prob", &[0.5, 0.7, 0.9],
                  |p, v| p.nsga.crossover_prob = v)?;
    t.print();
    t.to_csv(&ctx.out_dir.join("table7.csv"))?;
    Ok(())
}

pub fn run_table8(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let t = sweep(ctx, pipe, fresh, "mutation_prob", &[0.01, 0.1, 0.3],
                  |p, v| p.nsga.mutation_prob = v)?;
    t.print();
    t.to_csv(&ctx.out_dir.join("table8.csv"))?;
    Ok(())
}

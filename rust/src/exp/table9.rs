//! Table 9 analog: RBF vs MLP quality predictor — frontier PPL per budget.

use super::common::{self, Pipeline};
use super::Ctx;
use crate::coordinator::predictor::PredictorKind;
use crate::report::{fmt, Table};
use crate::Result;

pub fn run(ctx: &Ctx, pipe: &Pipeline, fresh: bool) -> Result<()> {
    let mut table = Table::new(
        "Table 9 — predictor ablation",
        &["avg_bits", "predictor", "wiki_ppl", "c4_ppl"],
    );
    for (kind, name) in [(PredictorKind::Mlp, "MLP"), (PredictorKind::Rbf, "RBF")] {
        let mut params = ctx.preset.clone();
        params.predictor = kind;
        let archive =
            common::search_cached(ctx, pipe, &params, &format!("search_pred_{name}"), fresh)?;
        for &budget in &common::BUDGETS {
            let cfg = common::pick(&archive, &pipe.space, budget)?;
            let layers =
                common::deploy_layers(ctx, &cfg, &crate::quant::AwqClip::default(), true)?;
            let refs: Vec<&_> = layers.iter().collect();
            let (wiki, c4) = common::ppl_only(ctx, &crate::eval::ModelHandle::Quant(&refs))?;
            table.row(vec![
                format!("{budget}"),
                name.into(),
                fmt(wiki, 2),
                fmt(c4, 2),
            ]);
        }
    }
    table.print();
    table.to_csv(&ctx.out_dir.join("table9.csv"))?;
    Ok(())
}

//! # AMQ — Automated Mixed-Precision Weight-Only Quantization
//!
//! Reproduction of *"AMQ: Enabling AutoML for Mixed-precision Weight-Only
//! Quantization of Large Language Models"* (EMNLP 2025) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: search-space
//!   pruning, quantization proxy, RBF quality predictor and the NSGA-II
//!   iterative search-and-update loop ([`coordinator`]), plus every substrate
//!   it needs: quantizers ([`quant`]), a PJRT runtime ([`runtime`]),
//!   evaluation ([`eval`]), an inference cost model ([`costmodel`]) and the
//!   experiment harnesses ([`exp`]).
//! * **L2** — the subject model's forward/scoring graphs, authored in JAX and
//!   AOT-lowered to HLO text at build time (`python/compile/`).
//! * **L1** — Pallas kernels (grouped dequant-matmul, JSD) inside those
//!   graphs.
//!
//! Python never runs at search/serve time: `make artifacts` produces
//! `artifacts/` once and the `repro` binary is self-contained afterwards.

pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod eval;
pub mod exp;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate-wide result type (eyre for rich error context).
pub type Result<T> = eyre::Result<T>;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory: `$AMQ_ARTIFACTS`, `./artifacts`, or
/// walking up from the current dir (so examples/tests work from anywhere).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("AMQ_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}

/// True when `make artifacts` has been run (integration tests / benches skip
/// gracefully otherwise).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

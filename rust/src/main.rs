//! `repro` — the AMQ reproduction CLI.
//!
//! Usage:
//! ```text
//!   repro list                       show all experiments
//!   repro <exp> [flags]             run one experiment (fig1, table3, ...)
//!   repro all [flags]               run everything
//!   repro search [flags]            run the main AMQ search and print the
//!                                   Pareto frontier
//!   repro check                     validate artifacts + runtime golden
//!   repro shard-serve --listen ADDR serve candidate-chunk frames over TCP
//!                                   (own runtime + device bank; --synthetic
//!                                   serves the deterministic toy workload
//!                                   with no artifacts, for CI)
//!   repro pool-smoke --shards LIST  seeded synthetic search across the
//!                                   topology matrix (sequential / threaded /
//!                                   remote / mixed), asserting identical
//!                                   archive hashes; writes
//!                                   BENCH_pool_smoke.json
//!   repro serve --listen ADDR       continuous-batching score server: admit
//!                                   concurrent score_req frames, coalesce
//!                                   them into lane dispatches (--max-wait-us
//!                                   deadline), serve a searched config as
//!                                   the default (--config ARCHIVE.json
//!                                   [--budget B]); --synthetic needs no
//!                                   artifacts
//!   repro serve-bench --addr ADDR   closed-/open-loop load generator against
//!                                   a serve process (--clients N --rps R
//!                                   --duration S); writes BENCH_serve.json
//!                                   (p50/p95/p99 latency, throughput, lane
//!                                   fill, queue stats)
//!
//! Flags:
//!   --preset smoke|repro|paper      search budget preset (default: repro)
//!   --fresh                         ignore cached search archives
//!   --seed N                        search seed
//!   --out DIR                       results directory (default: results)
//!   --artifacts DIR                 artifacts directory
//!   --workers N                     evaluation-pool shards (default: 1);
//!                                   shards share one runtime + one device
//!                                   bank, archives are identical for any N
//!   --score-batch K                 scoring microbatch size (default: 8);
//!                                   candidates are deduped per generation
//!                                   and dispatched K per scorer call,
//!                                   archives are identical for any K
//!   --lanes N                       scorer lane request (default: 0 = auto
//!                                   — use the lane-stacked artifact when
//!                                   present; 1 forces the per-candidate
//!                                   scorer; N > 1 requires an N-lane
//!                                   artifact).  Archives are identical
//!                                   for any setting
//!   --slab-cache-mb N               lane-slab cache budget (default: 64;
//!                                   0 disables retention).  Packed lane
//!                                   slabs stay device-resident across
//!                                   calibration batches and generations;
//!                                   archives are identical for any budget
//!   --slab-gather auto|off|require  on-device lane-slab assembly (default:
//!                                   auto — on a slab-cache miss, gather the
//!                                   slab on-device from resident bank
//!                                   pieces when the manifest ships gather
//!                                   executables, else pack on the host;
//!                                   off forces the host path; require
//!                                   errors without the artifact).  Archives
//!                                   are identical for any setting
//!   --methods LIST                  comma-separated quantization methods
//!                                   the genome may assign per layer
//!                                   (hqq,rtn,gptq,awq_clip; default: the
//!                                   manifest's list, normally just hqq)
//!   --predictor rbf|mlp|gp          quality predictor (default: rbf; gp
//!                                   adds posterior uncertainty for the
//!                                   UCB candidate screen)
//!   --ucb-kappa F                   UCB exploration weight κ for the
//!                                   candidate screen (default: 0 = the
//!                                   classic point-estimate screen; κ > 0
//!                                   keeps dominated candidates whose
//!                                   mean − κ·std beats the generation
//!                                   floor — meaningful with --predictor
//!                                   gp, a no-op for point predictors)
//!   --warm-start DIR                persist finished searches to DIR and
//!                                   reload them: an exact (model, methods,
//!                                   budget) key match reproduces the cold
//!                                   archive bit-exactly with zero evals; a
//!                                   same-model match with a different
//!                                   budget seeds the new search; mismatch
//!                                   or corruption warns and runs cold
//!   --shards a:p,b:p                remote shard servers to feed (each
//!                                   address becomes one pool shard on the
//!                                   same FIFO as the local workers;
//!                                   archives identical for any topology)
//!   --hedge-factor F                straggler hedging (default: 4): a chunk
//!                                   in-flight longer than F x the rolling
//!                                   p50 is speculatively duplicated onto an
//!                                   idle shard, first reply wins (0
//!                                   disables; archives identical either
//!                                   way — evals are pure)
//!   --chunk-timeout-ms N            (pool-smoke) per-chunk reply deadline
//!                                   for remote feeders (default: 300000);
//!                                   a shard silent that long retires and
//!                                   its chunk requeues
//!   --fault-spec SEED:KIND:RATE     (shard-serve) deterministic fault
//!                                   injection: each chunk draws a seeded
//!                                   decision, triggered faults
//!                                   delay|wedge|drop|disconnect the
//!                                   chunk's handling (results, when sent,
//!                                   are unchanged) — the chaos-test /
//!                                   straggler-CI knob
//!   --listen ADDR                   (shard-serve, serve) bind address
//!   --synthetic                     (shard-serve, serve) serve the
//!                                   deterministic synthetic workload, no
//!                                   artifacts needed
//!   --config PATH                   (serve) archive JSON whose best entry
//!                                   becomes the served default config
//!   --budget B                      (serve) narrow --config to the best
//!                                   entry under B average bits (±0.005)
//!   --max-wait-us N                 (serve) batch-forming deadline: a
//!                                   partial lane batch dispatches once its
//!                                   oldest request has waited N µs
//!                                   (default: 1000)
//!   --queue-cap N                   (serve) admission-queue bound; requests
//!                                   beyond it are rejected (default: 1024)
//!   --conn-cap N                    (serve) simultaneous-connection cap
//!                                   (default: 64)
//!   --addr ADDR                     (serve-bench) server to load
//!   --clients N                     (serve-bench) concurrent connections
//!                                   (default: 4)
//!   --rps R                         (serve-bench) open-loop arrival rate,
//!                                   requests/sec across all clients
//!                                   (default: 0 = closed loop)
//!   --duration S                    (serve-bench) seconds of load
//!                                   (default: 5)
//! ```

use amq::coordinator::predictor::PredictorKind;
use amq::coordinator::SearchParams;
use amq::exp::{self, Ctx};
use amq::quant::MethodRegistry;
use amq::runtime::SlabGatherMode;
use amq::Result;

struct Args {
    cmd: String,
    preset: String,
    fresh: bool,
    seed: Option<u64>,
    out: String,
    artifacts: Option<String>,
    workers: usize,
    score_batch: usize,
    lanes: usize,
    slab_cache_mb: usize,
    slab_gather: SlabGatherMode,
    methods: Option<String>,
    predictor: Option<String>,
    ucb_kappa: Option<f64>,
    warm_start: Option<String>,
    shards: Vec<String>,
    hedge_factor: f64,
    chunk_timeout_ms: u64,
    fault_spec: Option<String>,
    listen: Option<String>,
    synthetic: bool,
    config: Option<String>,
    budget: Option<f64>,
    max_wait_us: u64,
    queue_cap: usize,
    conn_cap: usize,
    addr: Option<String>,
    clients: usize,
    rps: f64,
    duration: f64,
}

fn parse_args() -> Args {
    let mut args = Args {
        cmd: String::new(),
        preset: "repro".into(),
        fresh: false,
        seed: None,
        out: "results".into(),
        artifacts: None,
        workers: 1,
        score_batch: exp::DEFAULT_SCORE_BATCH,
        lanes: 0,
        slab_cache_mb: exp::DEFAULT_SLAB_CACHE_MB,
        slab_gather: SlabGatherMode::Auto,
        methods: None,
        predictor: None,
        ucb_kappa: None,
        warm_start: None,
        shards: Vec::new(),
        hedge_factor: amq::runtime::DEFAULT_HEDGE_FACTOR,
        chunk_timeout_ms: 300_000,
        fault_spec: None,
        listen: None,
        synthetic: false,
        config: None,
        budget: None,
        max_wait_us: 1000,
        queue_cap: 1024,
        conn_cap: 64,
        addr: None,
        clients: 4,
        rps: 0.0,
        duration: 5.0,
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--preset" => {
                i += 1;
                args.preset = argv[i].clone();
            }
            "--fresh" => args.fresh = true,
            "--seed" => {
                i += 1;
                args.seed = Some(argv[i].parse().expect("--seed N"));
            }
            "--out" => {
                i += 1;
                args.out = argv[i].clone();
            }
            "--artifacts" => {
                i += 1;
                args.artifacts = Some(argv[i].clone());
            }
            "--workers" => {
                i += 1;
                args.workers = argv[i].parse().expect("--workers N");
            }
            "--score-batch" => {
                i += 1;
                args.score_batch = argv[i].parse().expect("--score-batch K");
            }
            "--lanes" => {
                i += 1;
                args.lanes = argv[i].parse().expect("--lanes N");
            }
            "--slab-cache-mb" => {
                i += 1;
                args.slab_cache_mb = argv[i].parse().expect("--slab-cache-mb N");
            }
            "--slab-gather" => {
                i += 1;
                args.slab_gather = match SlabGatherMode::parse(&argv[i]) {
                    Ok(m) => m,
                    Err(e) => {
                        eprintln!("{e}");
                        std::process::exit(2);
                    }
                };
            }
            "--methods" => {
                i += 1;
                args.methods = Some(argv[i].clone());
            }
            "--predictor" => {
                i += 1;
                args.predictor = Some(argv[i].clone());
            }
            "--ucb-kappa" => {
                i += 1;
                args.ucb_kappa = Some(argv[i].parse().expect("--ucb-kappa F"));
            }
            "--warm-start" => {
                i += 1;
                args.warm_start = Some(argv[i].clone());
            }
            "--shards" => {
                i += 1;
                args.shards = argv[i]
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(String::from)
                    .collect();
            }
            "--hedge-factor" => {
                i += 1;
                args.hedge_factor = argv[i].parse().expect("--hedge-factor F");
            }
            "--chunk-timeout-ms" => {
                i += 1;
                args.chunk_timeout_ms = argv[i].parse().expect("--chunk-timeout-ms N");
            }
            "--fault-spec" => {
                i += 1;
                args.fault_spec = Some(argv[i].clone());
            }
            "--listen" => {
                i += 1;
                args.listen = Some(argv[i].clone());
            }
            "--synthetic" => args.synthetic = true,
            "--config" => {
                i += 1;
                args.config = Some(argv[i].clone());
            }
            "--budget" => {
                i += 1;
                args.budget = Some(argv[i].parse().expect("--budget B"));
            }
            "--max-wait-us" => {
                i += 1;
                args.max_wait_us = argv[i].parse().expect("--max-wait-us N");
            }
            "--queue-cap" => {
                i += 1;
                args.queue_cap = argv[i].parse().expect("--queue-cap N");
            }
            "--conn-cap" => {
                i += 1;
                args.conn_cap = argv[i].parse().expect("--conn-cap N");
            }
            "--addr" => {
                i += 1;
                args.addr = Some(argv[i].clone());
            }
            "--clients" => {
                i += 1;
                args.clients = argv[i].parse().expect("--clients N");
            }
            "--rps" => {
                i += 1;
                args.rps = argv[i].parse().expect("--rps R");
            }
            "--duration" => {
                i += 1;
                args.duration = argv[i].parse().expect("--duration S");
            }
            flag if flag.starts_with("--") => {
                eprintln!("unknown flag {flag}");
                std::process::exit(2);
            }
            cmd => {
                if args.cmd.is_empty() {
                    args.cmd = cmd.to_string();
                } else {
                    eprintln!("unexpected argument {cmd}");
                    std::process::exit(2);
                }
            }
        }
        i += 1;
    }
    args
}

fn preset(args: &Args) -> SearchParams {
    let mut p = match args.preset.as_str() {
        "smoke" => SearchParams::smoke(),
        "repro" => SearchParams::default(),
        "paper" => SearchParams::paper(),
        other => {
            eprintln!("unknown preset {other} (smoke|repro|paper)");
            std::process::exit(2);
        }
    };
    if let Some(s) = args.seed {
        p.seed = s;
    }
    if let Some(name) = args.predictor.as_deref() {
        p.predictor = match PredictorKind::parse(name) {
            Ok(k) => k,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        };
    }
    if let Some(k) = args.ucb_kappa {
        if !k.is_finite() || k < 0.0 {
            eprintln!("--ucb-kappa must be a finite value >= 0, got {k}");
            std::process::exit(2);
        }
        p.ucb_kappa = k;
    }
    p
}

/// The pool topology a context runs: all-local, all-remote, or both kinds
/// of shard on one FIFO.
fn topology_of(ctx: &Ctx) -> &'static str {
    if ctx.shards.is_empty() {
        "in-process"
    } else if ctx.local_workers() > 0 {
        "mixed"
    } else {
        "remote"
    }
}

/// `repro shard-serve --listen ADDR [--synthetic]`: serve candidate-chunk
/// frames over TCP.  With `--synthetic` the shard scores the deterministic
/// toy workload (no artifacts, genome length unconstrained — the CI
/// topology job uses this); otherwise it loads artifacts and builds its own
/// runtime + device bank, exactly like a local `--workers` shard would.
fn run_shard_serve(args: &Args) -> Result<()> {
    use amq::runtime::remote::DEFAULT_LIVE_CONNS;
    use amq::runtime::FaultSpec;
    use std::sync::Arc;

    let listen = args
        .listen
        .as_deref()
        .ok_or_else(|| eyre::anyhow!("shard-serve requires --listen ADDR"))?;
    let listener = std::net::TcpListener::bind(listen)?;
    eprintln!("[shard] listening on {}", listener.local_addr()?);
    // Deterministic fault injection (--fault-spec SEED:KIND:RATE): which
    // chunks fault is a pure function of the spec, so a failing CI run
    // replays exactly from its command line.
    let fault_plan = match args.fault_spec.as_deref() {
        Some(spec) => {
            let spec = FaultSpec::parse(spec)?;
            eprintln!(
                "[shard] fault injection armed: {} (kind {}, rate {}, seed {})",
                spec.to_spec_string(),
                spec.kind.name(),
                spec.rate,
                spec.seed
            );
            Some(Arc::new(spec.plan()))
        }
        None => None,
    };
    if args.synthetic {
        eprintln!("[shard] serving the synthetic workload (no artifacts)");
        return amq::runtime::remote::serve_shard_with_faults(
            listener,
            0,
            None,
            DEFAULT_LIVE_CONNS,
            fault_plan,
            amq::coordinator::synth::synth_chunk,
        );
    }
    let artifacts = args
        .artifacts
        .as_ref()
        .map(std::path::PathBuf::from)
        .unwrap_or_else(amq::artifacts_dir);
    eyre::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts not found at {} — run `make artifacts` (or use --synthetic)",
        artifacts.display()
    );
    let params = preset(args);
    let registry = match args.methods.as_deref() {
        Some(list) => Some(MethodRegistry::parse(list)?),
        None => None,
    };
    let ctx = Ctx::load_with_opts(
        &artifacts,
        std::path::Path::new(&args.out),
        params,
        1,
        registry,
        args.score_batch,
        args.lanes,
        args.slab_cache_mb,
        args.slab_gather,
    )?;
    let dev = ctx.device_bank()?;
    let proxy = amq::coordinator::DeviceProxy::from_device_bank(&ctx.rt, dev);
    let batches = ctx.search_batches.clone();
    let n_layers = ctx.assets.manifest.layers.len() as u64;
    eprintln!(
        "[shard] runtime + device bank ready ({n_layers}-layer genome, scorer {})",
        ctx.rt.scorer_variant().name()
    );
    amq::runtime::remote::serve_shard_with_faults(
        listener,
        n_layers,
        None,
        DEFAULT_LIVE_CONNS,
        fault_plan,
        move |genes| amq::coordinator::proxy::mean_jsd_batch(&proxy, &batches, genes),
    )
}

/// The fixed default config a `--synthetic` serve process answers
/// empty-genes requests with when no `--config` archive is given: 12 layers
/// at 3 bits, inside [`amq::coordinator::synth::synth_space`]'s choices.
fn synth_default_config() -> Vec<u16> {
    vec![3u16; 12]
}

/// `repro serve --listen ADDR [--synthetic | --config ARCHIVE.json
/// [--budget B]] [--lanes N] [--max-wait-us N] [--queue-cap N]
/// [--conn-cap N]`: the continuous-batching score server.  Concurrent
/// connections feed one admission queue; a lane batcher coalesces up to
/// `lanes` requests per evaluator dispatch, flushing partial batches when
/// the oldest request has waited `--max-wait-us`.  With artifacts, the
/// evaluator is the lane-stacked scorer over the shared device bank —
/// steady-state serving of the default config hits the slab cache and does
/// zero host uploads.
fn run_serve(args: &Args) -> Result<()> {
    use amq::runtime::serve::{serve_scores, SchedulerOptions, ServeOptions};

    let listen = args
        .listen
        .as_deref()
        .ok_or_else(|| eyre::anyhow!("serve requires --listen ADDR"))?;
    let listener = std::net::TcpListener::bind(listen)?;
    eprintln!("[serve] listening on {}", listener.local_addr()?);

    let served = match args.config.as_deref() {
        Some(path) => {
            let sample =
                exp::common::load_served_config(std::path::Path::new(path), args.budget)?;
            eprintln!(
                "[serve] serving searched config from {path}: {:.3} avg bits, proxy JSD {} ({})",
                sample.avg_bits,
                sample.jsd,
                match args.budget {
                    Some(b) => format!("budget {b}"),
                    None => "lowest JSD".into(),
                }
            );
            Some(sample.config)
        }
        None => None,
    };

    let scheduler = SchedulerOptions {
        // --lanes 0 = auto: resolved below once the scorer variant is known
        // (synthetic serving defaults to 8-wide batching).
        lanes: args.lanes,
        max_wait: std::time::Duration::from_micros(args.max_wait_us),
        queue_cap: args.queue_cap,
    };

    if args.synthetic {
        let opts = ServeOptions {
            scheduler: SchedulerOptions {
                lanes: if args.lanes == 0 { 8 } else { args.lanes },
                ..scheduler
            },
            max_conns: None,
            live_cap: args.conn_cap,
            default_genes: Some(served.unwrap_or_else(synth_default_config)),
        };
        eprintln!(
            "[serve] synthetic workload, lanes {}, max-wait {} us, queue cap {}",
            opts.scheduler.lanes, args.max_wait_us, args.queue_cap
        );
        let stats = serve_scores(listener, 0, opts, || amq::coordinator::synth::synth_chunk)?;
        println!("[serve] {}", stats.summary());
        return Ok(());
    }

    let artifacts = args
        .artifacts
        .as_ref()
        .map(std::path::PathBuf::from)
        .unwrap_or_else(amq::artifacts_dir);
    eyre::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts not found at {} — run `make artifacts` (or use --synthetic)",
        artifacts.display()
    );
    let params = preset(args);
    let registry = match args.methods.as_deref() {
        Some(list) => Some(MethodRegistry::parse(list)?),
        None => None,
    };
    let ctx = Ctx::load_with_opts(
        &artifacts,
        std::path::Path::new(&args.out),
        params,
        1,
        registry,
        args.score_batch,
        args.lanes,
        args.slab_cache_mb,
        args.slab_gather,
    )?;
    let dev = ctx.device_bank()?;
    let rt = ctx.rt.clone();
    let batches = ctx.search_batches.clone();
    let n_layers = ctx.assets.manifest.layers.len() as u64;
    // Lane width follows the scorer the artifacts actually carry, so a full
    // admission batch fills the lane slab exactly.
    let lanes = if args.lanes == 0 {
        ctx.rt.scorer_variant().lanes().max(1)
    } else {
        args.lanes
    };
    let opts = ServeOptions {
        scheduler: SchedulerOptions { lanes, ..scheduler },
        max_conns: None,
        live_cap: args.conn_cap,
        default_genes: served,
    };
    eprintln!(
        "[serve] runtime + device bank ready ({n_layers}-layer genome, scorer {}, lanes {}, max-wait {} us)",
        ctx.rt.scorer_variant().name(),
        lanes,
        args.max_wait_us
    );
    let stats = serve_scores(listener, n_layers, opts, move || {
        // Built on the batcher thread: the proxy wraps the shared
        // already-uploaded bank, so construction is zero device work.
        move |genes: &[Vec<u16>]| {
            let proxy = amq::coordinator::DeviceProxy::from_device_bank(&rt, dev.clone());
            amq::coordinator::proxy::mean_jsd_batch(&proxy, &batches, genes)
        }
    })?;
    println!("[serve] {}", stats.summary());
    Ok(())
}

/// `repro serve-bench --addr ADDR [--clients N] [--rps R] [--duration S]
/// [--out DIR]`: load a serve process and write `BENCH_serve.json`.
///
/// `--rps 0` (default) runs **closed-loop**: every client fires its next
/// request the moment the previous reply lands, and latency is measured
/// send→reply.  `--rps R > 0` runs **open-loop**: request `k` is scheduled
/// at `k/R` seconds (round-robined across clients) and latency is measured
/// from the *scheduled* arrival — a backlogged server accrues queueing
/// delay instead of silently slowing the arrival process (no coordinated
/// omission).  All requests score the server's default config (empty
/// genes), which is the steady-state serving shape: one resident lane slab,
/// zero host uploads after warmup.
fn run_serve_bench(args: &Args) -> Result<()> {
    use amq::runtime::serve::{fetch_serve_stats, LatencyHistogram, ScoreClient};
    use std::fmt::Write as _;
    use std::time::{Duration, Instant};

    let addr = args
        .addr
        .as_deref()
        .ok_or_else(|| eyre::anyhow!("serve-bench requires --addr ADDR"))?;
    let clients = args.clients.max(1);
    eyre::ensure!(args.duration > 0.0, "--duration must be positive");
    let duration = Duration::from_secs_f64(args.duration);
    let timeout = Duration::from_secs(30);
    eprintln!(
        "[bench] {} client(s) against {addr} for {:.1}s ({})",
        clients,
        args.duration,
        if args.rps > 0.0 {
            format!("open loop, {} rps", args.rps)
        } else {
            "closed loop".into()
        }
    );

    struct ClientResult {
        hist: LatencyHistogram,
        requests: u64,
        errors: u64,
    }
    let start = Instant::now() + Duration::from_millis(50); // common epoch
    let results: Vec<ClientResult> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|ci| {
                scope.spawn(move || -> Result<ClientResult> {
                    let mut client = ScoreClient::connect(addr, timeout)?;
                    let mut res = ClientResult {
                        hist: LatencyHistogram::new(),
                        requests: 0,
                        errors: 0,
                    };
                    // Wait for the common epoch so every client (and the
                    // wall-clock denominator) starts together.
                    let now = Instant::now();
                    if start > now {
                        std::thread::sleep(start - now);
                    }
                    let mut k = ci as u64; // global request index (open loop)
                    loop {
                        let now = Instant::now();
                        let sched = if args.rps > 0.0 {
                            let at = start + Duration::from_secs_f64(k as f64 / args.rps);
                            if at >= start + duration {
                                break;
                            }
                            if at > now {
                                std::thread::sleep(at - now);
                            }
                            at
                        } else {
                            if now >= start + duration {
                                break;
                            }
                            now.max(start)
                        };
                        let reply = client.score(&[])?;
                        res.hist
                            .record(sched.elapsed().as_micros().min(u64::MAX as u128) as u64);
                        res.requests += 1;
                        if reply.is_err() {
                            res.errors += 1;
                        }
                        k += clients as u64;
                    }
                    Ok(res)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("bench client panicked"))
            .collect::<Result<Vec<_>>>()
    })?;

    let mut hist = LatencyHistogram::new();
    let mut requests = 0u64;
    let mut errors = 0u64;
    for r in &results {
        hist.merge(&r.hist);
        requests += r.requests;
        errors += r.errors;
    }
    let wall = start.elapsed().as_secs_f64().max(1e-9);
    let throughput = requests as f64 / wall;
    let (p50, p95, p99) =
        (hist.percentile(0.50), hist.percentile(0.95), hist.percentile(0.99));
    println!(
        "[bench] {requests} requests ({errors} errors) in {wall:.2}s: {throughput:.1} req/s | \
         p50 {p50} us, p95 {p95} us, p99 {p99} us, max {} us",
        hist.max_us()
    );

    // Server-side truth over the wire: lane fill vs queue wait, separately.
    let server = match fetch_serve_stats(addr, timeout) {
        Ok(st) => {
            println!("[serve] {}", st.summary());
            Some(st)
        }
        Err(e) => {
            eprintln!("[bench] server-side serve stats unavailable ({e})");
            None
        }
    };

    std::fs::create_dir_all(&args.out)?;
    let mut s = String::from("{\n");
    let _ = write!(s, "  \"bench\": \"serve\",\n");
    let _ = write!(s, "  \"addr\": \"{addr}\",\n");
    let _ = write!(s, "  \"clients\": {clients},\n");
    let _ = write!(s, "  \"rps\": {},\n", args.rps);
    let _ = write!(s, "  \"open_loop\": {},\n", args.rps > 0.0);
    let _ = write!(s, "  \"duration_seconds\": {:.3},\n", args.duration);
    let _ = write!(s, "  \"wall_seconds\": {wall:.3},\n");
    let _ = write!(s, "  \"requests\": {requests},\n");
    let _ = write!(s, "  \"errors\": {errors},\n");
    let _ = write!(s, "  \"throughput_rps\": {throughput:.2},\n");
    let _ = write!(
        s,
        "  \"latency_us\": {{\"p50\": {p50}, \"p95\": {p95}, \"p99\": {p99}, \
         \"mean\": {:.1}, \"max\": {}}}",
        hist.mean_us(),
        hist.max_us()
    );
    if let Some(st) = server {
        let _ = write!(
            s,
            ",\n  \"server\": {{\"requests\": {}, \"rejected\": {}, \"dispatches\": {}, \
             \"full_dispatches\": {}, \"deadline_dispatches\": {}, \
             \"drain_dispatches\": {}, \"lanes\": {}, \"lane_fill_fraction\": {:.4}, \
             \"queue\": {{\"mean_wait_us\": {:.1}, \"mean_depth\": {:.2}, \
             \"max_depth\": {}}}}}",
            st.requests,
            st.rejected,
            st.dispatches,
            st.full_dispatches,
            st.deadline_dispatches,
            st.drain_dispatches(),
            st.lanes,
            st.lane_fill_fraction(),
            st.mean_wait_us(),
            st.mean_depth(),
            st.depth_max,
        );
    }
    s.push_str("\n}\n");
    let path = std::path::Path::new(&args.out).join("BENCH_serve.json");
    std::fs::write(&path, s)?;
    eprintln!("[report] wrote {}", path.display());
    Ok(())
}

/// `repro pool-smoke --shards a:p,b:p [--seed N] [--out DIR]`: the
/// cross-process half of the topology matrix.  Runs the same seeded
/// synthetic search sequentially, across local threads, against the remote
/// shards, and mixed — then bails unless every archive hashes identically.
/// Writes `BENCH_pool_smoke.json` (perf artifact) and a small
/// `search_report.json` (pool-debug artifact) under `--out`.
fn run_pool_smoke(args: &Args) -> Result<()> {
    use amq::coordinator::synth::{synth_chunk, synth_space};
    use amq::coordinator::{run_search, Config, EvalPool, PooledEvaluator};
    use amq::runtime::remote::{
        fetch_shard_stats, remote_eval_flow_with_timeout, RetryPolicy,
    };
    use amq::runtime::{EvalService, HedgePolicy, ShardFlow};
    use std::fmt::Write as _;
    use std::sync::Arc;

    eyre::ensure!(
        !args.shards.is_empty(),
        "pool-smoke requires --shards addr1,addr2,..."
    );
    let space = synth_space(12);
    let mut params = SearchParams::smoke();
    params.seed = args.seed.unwrap_or(17);
    let remotes = args.shards.clone();
    // --hedge-factor: stragglers (e.g. a --fault-spec-wedged shard server)
    // are speculatively duplicated onto idle shards instead of stalling the
    // generation barrier; --chunk-timeout-ms bounds how long a silent
    // server can pin its feeder before it retires.  Both change wall-clock
    // only — the identical-hash assertion below is the proof.
    let policy = HedgePolicy::from_factor(args.hedge_factor);
    let chunk_timeout = std::time::Duration::from_millis(args.chunk_timeout_ms.max(1));

    let local_pool = |workers: usize| -> Arc<EvalPool> {
        Arc::new(EvalService::spawn_sharded_with(
            workers,
            |_shard| |chunk: Vec<Config>| -> Result<Vec<f32>> { synth_chunk(&chunk) },
            policy,
        ))
    };
    let remote_pool = |local: usize| -> Arc<EvalPool> {
        let remotes = remotes.clone();
        let labels: Vec<String> = (0..local)
            .map(|i| format!("local#{i}"))
            .chain(remotes.iter().cloned())
            .collect();
        let builder = move |shard: usize| {
            if shard < local {
                Box::new(move |chunk: Vec<Config>| ShardFlow::Reply(synth_chunk(&chunk)))
            } else {
                remote_eval_flow_with_timeout(
                    remotes[shard - local].clone(),
                    RetryPolicy::default(),
                    Some(chunk_timeout),
                )
            }
        };
        Arc::new(EvalService::spawn_flow_with(labels, builder, policy))
    };

    struct Run {
        topology: &'static str,
        workers: usize,
        remote_shards: usize,
        svc: Arc<EvalPool>,
    }
    let runs = [
        Run { topology: "sequential", workers: 1, remote_shards: 0, svc: local_pool(1) },
        Run { topology: "in-process", workers: 4, remote_shards: 0, svc: local_pool(4) },
        Run {
            topology: "remote",
            workers: remotes.len(),
            remote_shards: remotes.len(),
            svc: remote_pool(0),
        },
        Run {
            topology: "mixed",
            workers: 2 + remotes.len(),
            remote_shards: remotes.len(),
            svc: remote_pool(2),
        },
    ];

    std::fs::create_dir_all(&args.out)?;
    let mut rows = String::new();
    let mut report = String::new();
    let mut hashes: Vec<u64> = Vec::new();
    for run in &runs {
        let mut ev = PooledEvaluator::from_service(run.svc.clone()).with_score_batch(8);
        let t0 = std::time::Instant::now();
        let res = run_search(&space, &mut ev, &params)?;
        let wall = t0.elapsed().as_secs_f64();
        let hash = res.archive.content_hash();
        let pool = ev.pool_stats();
        hashes.push(hash);
        println!(
            "[smoke] {:<10} workers {} (remote {}): archive {:016x}, {} samples, \
             {} requeued, hedged {} (won {}, wasted {}), {:.2}s",
            run.topology,
            run.workers,
            run.remote_shards,
            hash,
            res.archive.len(),
            pool.requeued,
            pool.hedged_dispatched,
            pool.hedged_won,
            pool.hedged_wasted,
            wall
        );
        if !rows.is_empty() {
            rows.push_str(",\n");
            report.push_str(",\n");
        }
        let _ = write!(
            rows,
            "    {{\"topology\": \"{}\", \"workers\": {}, \"remote_shards\": {}, \
             \"requeued_chunks\": {}, \"hedged_dispatched\": {}, \"hedged_won\": {}, \
             \"hedged_wasted\": {}, \"latency_p50_ms\": {:.3}, \
             \"archive_hash\": \"{hash:016x}\", \
             \"archive_len\": {}, \"true_evals\": {}, \"wall_seconds\": {wall:.4}}}",
            run.topology,
            run.workers,
            run.remote_shards,
            pool.requeued,
            pool.hedged_dispatched,
            pool.hedged_won,
            pool.hedged_wasted,
            pool.latency_p50.as_secs_f64() * 1e3,
            res.archive.len(),
            res.true_evals,
        );
        let shard_rows: Vec<String> = pool
            .per_shard
            .iter()
            .map(|s| {
                format!(
                    "{{\"label\": \"{}\", \"completed\": {}, \"retired\": {}}}",
                    s.label, s.completed, s.retired
                )
            })
            .collect();
        let _ = write!(
            report,
            "    {{\"topology\": \"{}\", \"archive_hash\": \"{hash:016x}\", \
             \"shards\": [{}]}}",
            run.topology,
            shard_rows.join(", ")
        );
    }
    // Server-side truth from the shard processes: drop the run services
    // first — that joins the feeder threads and closes their connections,
    // so the sequential shard servers can accept the dedicated stats-probe
    // connections.  The client-side per-shard counters above only see the
    // wire; these counters come from inside the server's eval loop.
    drop(runs);
    let mut server_rows: Vec<String> = Vec::new();
    for addr in &remotes {
        match fetch_shard_stats(addr, std::time::Duration::from_secs(10)) {
            Ok(st) => {
                println!(
                    "[pool] shard {addr}: server-side {} chunk(s) completed, \
                     {:.2}s busy in eval, {} connection(s) served",
                    st.completed,
                    st.busy_us as f64 / 1e6,
                    st.conns
                );
                server_rows.push(format!(
                    "    {{\"addr\": \"{addr}\", \"completed\": {}, \
                     \"busy_us\": {}, \"conns\": {}}}",
                    st.completed, st.busy_us, st.conns
                ));
            }
            Err(e) => {
                eprintln!("[pool] shard {addr}: server-side stats unavailable ({e})");
                server_rows.push(format!(
                    "    {{\"addr\": \"{addr}\", \"error\": \"unavailable\"}}"
                ));
            }
        }
    }
    let identical = hashes.iter().all(|&h| h == hashes[0]);
    let bench = format!(
        "{{\n  \"bench\": \"pool_smoke\",\n  \"seed\": {},\n  \"hedge_factor\": {},\n  \
         \"identical_archives\": \
         {identical},\n  \"runs\": [\n{rows}\n  ]\n}}\n",
        params.seed, args.hedge_factor
    );
    let bench_path = std::path::Path::new(&args.out).join("BENCH_pool_smoke.json");
    std::fs::write(&bench_path, bench)?;
    eprintln!("[report] wrote {}", bench_path.display());
    let report_json = format!(
        "{{\n  \"report\": \"pool_smoke_topologies\",\n  \"seed\": {},\n  \
         \"hedge_factor\": {},\n  \
         \"identical_archives\": {identical},\n  \"shard_servers\": [\n{}\n  ],\n  \
         \"topologies\": [\n{report}\n  ]\n}}\n",
        params.seed,
        args.hedge_factor,
        server_rows.join(",\n")
    );
    let report_path = std::path::Path::new(&args.out).join("search_report.json");
    std::fs::write(&report_path, report_json)?;
    eprintln!("[report] wrote {}", report_path.display());
    eyre::ensure!(
        identical,
        "archives diverged across topologies: {:?}",
        hashes.iter().map(|h| format!("{h:016x}")).collect::<Vec<_>>()
    );
    println!("[smoke] archives identical across all {} topologies", hashes.len());
    Ok(())
}

/// Per-method gene counts of a config, e.g. `"hqq:20 rtn:8"`.
fn method_mix(config: &[amq::coordinator::Gene]) -> String {
    let mut counts: Vec<(&'static str, usize)> = Vec::new();
    for &g in config {
        let name = amq::coordinator::gene_method(g).name();
        match counts.iter_mut().find(|(n, _)| *n == name) {
            Some((_, c)) => *c += 1,
            None => counts.push((name, 1)),
        }
    }
    counts
        .iter()
        .map(|(n, c)| format!("{n}:{c}"))
        .collect::<Vec<_>>()
        .join(" ")
}

/// JSON search report: enabled methods, per-method proxy build stats, the
/// genome size, and the frontier with per-layer (method, bits) assignments.
fn write_search_report(
    path: &std::path::Path,
    ctx: &Ctx,
    pipe: &exp::common::Pipeline,
    archive: &amq::coordinator::Archive,
    frontier: &[&amq::coordinator::Sample],
) -> Result<()> {
    use std::fmt::Write as _;
    let mut s = String::from("{\n");
    let _ = write!(
        s,
        "  \"methods\": [{}],\n",
        ctx.registry
            .names()
            .iter()
            .map(|n| format!("\"{n}\""))
            .collect::<Vec<_>>()
            .join(", ")
    );
    let _ = write!(s, "  \"predictor\": \"{}\",\n", ctx.preset.predictor.name());
    let _ = write!(s, "  \"ucb_kappa\": {},\n", ctx.preset.ucb_kappa);
    let _ = write!(s, "  \"warm_start\": \"{}\",\n", ctx.warm_tier());
    // Per-budget probes: `null` marks a budget no archive sample satisfies
    // (the old report code unwrapped here and panicked on thin archives).
    s.push_str("  \"best_under\": {");
    for (i, &b) in exp::common::BUDGETS.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        match archive.best_under(b, exp::common::TOL) {
            Some(smp) => {
                let _ = write!(s, "\"{b}\": {}", smp.jsd);
            }
            None => {
                let _ = write!(s, "\"{b}\": null");
            }
        }
    }
    s.push_str("},\n");
    let _ = write!(s, "  \"workers\": {},\n", ctx.workers);
    let _ = write!(s, "  \"topology\": \"{}\",\n", topology_of(ctx));
    let _ = write!(s, "  \"remote_shards\": {},\n", ctx.shards.len());
    let _ = write!(s, "  \"score_batch\": {},\n", ctx.score_batch);
    let _ = write!(s, "  \"hedge_factor\": {},\n", ctx.hedge_factor);
    if let Some(pool) = ctx.pool_stats() {
        let _ = write!(
            s,
            "  \"hedging\": {{\"hedged_dispatched\": {}, \"hedged_won\": {}, \
             \"hedged_wasted\": {}, \"requeued_duplicates\": {}, \
             \"latency_p50_ms\": {:.3}}},\n",
            pool.hedged_dispatched,
            pool.hedged_won,
            pool.hedged_wasted,
            pool.requeued_duplicates,
            pool.latency_p50.as_secs_f64() * 1e3,
        );
    }
    let variant = ctx.rt.scorer_variant();
    let rstats = ctx.rt.stats();
    let _ = write!(
        s,
        "  \"scorer\": {{\"variant\": \"{}\", \"lanes\": {}, \
         \"lane_dispatches\": {}, \"lane_fill_fraction\": {:.4}}},\n",
        variant.name(),
        variant.lanes(),
        rstats.lane_dispatches,
        rstats.lane_fill_fraction(),
    );
    let _ = write!(
        s,
        "  \"slab_gather\": {{\"mode\": \"{}\", \"enabled\": {}, \
         \"gather_dispatches\": {}, \"gather_seconds\": {:.4}, \
         \"slab_upload_bytes_avoided\": {}}},\n",
        ctx.slab_gather.name(),
        ctx.rt.slab_gather_enabled(),
        rstats.gather_dispatches,
        rstats.gather_time.as_secs_f64(),
        rstats.slab_upload_bytes_avoided,
    );
    if let Some(ss) = ctx.slab_cache_stats() {
        let _ = write!(
            s,
            "  \"slab_cache\": {{\"budget_mb\": {}, \"hits\": {}, \"misses\": {}, \
             \"hit_fraction\": {:.4}, \"resident_slabs\": {}, \"resident_mb\": {:.3}, \
             \"evictions\": {}}},\n",
            ctx.slab_cache_mb,
            ss.hits,
            ss.misses,
            ss.hit_fraction(),
            ss.resident_slabs,
            ss.resident_bytes as f64 / 1e6,
            ss.evictions,
        );
    }
    if let Some(es) = ctx.last_eval_stats() {
        let _ = write!(
            s,
            "  \"eval\": {{\"requested\": {}, \"cache_hits\": {}, \"dup_hits\": {}, \
             \"evaluated\": {}, \"dispatches\": {}, \"dedup_fraction\": {:.4}, \
             \"dispatch_reduction\": {:.3}}},\n",
            es.requested,
            es.cache_hits,
            es.dup_hits,
            es.evaluated,
            es.dispatches,
            es.dedup_fraction(),
            es.dispatch_reduction(),
        );
    }
    if let Some(bs) = ctx.bank_share_stats() {
        let _ = write!(
            s,
            "  \"bank_sharing\": {{\"shards\": {}, \"resident_mb\": {:.3}, \
             \"unshared_mb\": {:.3}, \"slab_cache_mb_resident\": {:.3}, \
             \"total_resident_mb\": {:.3}}},\n",
            bs.shards,
            bs.resident_bytes as f64 / 1e6,
            bs.referenced_bytes as f64 / 1e6,
            bs.slab_cache_bytes as f64 / 1e6,
            bs.total_resident_bytes() as f64 / 1e6,
        );
    }
    let _ = write!(s, "  \"log10_space_size\": {:.3},\n", pipe.space.log10_size());
    let _ = write!(s, "  \"n_layers\": {},\n", pipe.space.n_layers());
    s.push_str("  \"proxy_bank\": [");
    for (i, st) in pipe.proxy.bank.stats.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        let _ = write!(
            s,
            "{{\"method\": \"{}\", \"build_seconds\": {:.4}, \"memory_mb\": {:.3}}}",
            st.method.name(),
            st.build_time.as_secs_f64(),
            st.memory_bytes as f64 / 1e6,
        );
    }
    s.push_str("],\n  \"frontier\": [\n");
    for (i, smp) in frontier.iter().enumerate() {
        if i > 0 {
            s.push_str(",\n");
        }
        let _ = write!(
            s,
            "    {{\"avg_bits\": {:.4}, \"jsd\": {}, \"layers\": [",
            smp.avg_bits, smp.jsd
        );
        for (li, &g) in smp.config.iter().enumerate() {
            if li > 0 {
                s.push_str(", ");
            }
            let _ = write!(
                s,
                "{{\"name\": \"{}\", \"method\": \"{}\", \"bits\": {}}}",
                ctx.assets.manifest.layers[li].name,
                amq::coordinator::gene_method(g).name(),
                amq::coordinator::gene_bits(g),
            );
        }
        s.push_str("]}");
    }
    s.push_str("\n  ]\n}\n");
    std::fs::write(path, s)?;
    Ok(())
}

/// Machine-readable perf snapshot of the search hot path (CI uploads this
/// as the `BENCH_search` artifact; the coordinator bench emits the same
/// schema on synthetic workloads).  `cached: true` means the archive came
/// from disk and the dispatch counters refer to no fresh work.
fn write_bench_json(path: &std::path::Path, ctx: &Ctx, pipe: &exp::common::Pipeline) -> Result<()> {
    use std::fmt::Write as _;
    let mut s = String::from("{\n");
    let _ = write!(s, "  \"bench\": \"repro_search\",\n");
    let _ = write!(s, "  \"workers\": {},\n", ctx.workers);
    let _ = write!(s, "  \"topology\": \"{}\",\n", topology_of(ctx));
    let _ = write!(s, "  \"remote_shards\": {},\n", ctx.shards.len());
    let _ = write!(
        s,
        "  \"requeued_chunks\": {},\n",
        ctx.pool_stats().map(|p| p.requeued).unwrap_or(0)
    );
    let _ = write!(s, "  \"score_batch\": {},\n", ctx.score_batch);
    let _ = write!(s, "  \"hedge_factor\": {},\n", ctx.hedge_factor);
    let _ = write!(
        s,
        "  \"hedged_dispatched\": {},\n",
        ctx.pool_stats().map(|p| p.hedged_dispatched).unwrap_or(0)
    );
    let _ = write!(
        s,
        "  \"hedged_won\": {},\n",
        ctx.pool_stats().map(|p| p.hedged_won).unwrap_or(0)
    );
    let _ = write!(
        s,
        "  \"hedged_wasted\": {},\n",
        ctx.pool_stats().map(|p| p.hedged_wasted).unwrap_or(0)
    );
    let _ = write!(s, "  \"methods\": \"{}\",\n", ctx.registry.names().join(","));
    let _ = write!(s, "  \"predictor\": \"{}\",\n", ctx.preset.predictor.name());
    let _ = write!(s, "  \"ucb_kappa\": {},\n", ctx.preset.ucb_kappa);
    let _ = write!(s, "  \"warm_start\": \"{}\",\n", ctx.warm_tier());
    let _ = write!(s, "  \"cached\": {},\n", ctx.last_search_stats().is_none());
    if let Some(run) = ctx.last_search_stats() {
        let _ = write!(s, "  \"wall_seconds\": {:.3},\n", run.wall_secs);
        let _ = write!(s, "  \"true_evals\": {},\n", run.true_evals);
        let _ = write!(s, "  \"predictor_queries\": {},\n", run.predictor_queries);
        let _ = write!(
            s,
            "  \"candidates_per_sec\": {:.2},\n",
            run.true_evals as f64 / run.wall_secs.max(1e-9),
        );
    }
    if let Some(es) = ctx.last_eval_stats() {
        let _ = write!(s, "  \"scorer_dispatches\": {},\n", es.dispatches);
        let _ = write!(s, "  \"requested_configs\": {},\n", es.requested);
        let _ = write!(s, "  \"dedup_hits\": {},\n", es.cache_hits + es.dup_hits);
        let _ = write!(s, "  \"dedup_fraction\": {:.4},\n", es.dedup_fraction());
        let _ = write!(s, "  \"dispatch_reduction\": {:.3},\n", es.dispatch_reduction());
    }
    // Device-level truth: with the lane-stacked scorer, one device dispatch
    // carries up to `lanes` candidates (lane_fill_fraction says how full the
    // lanes ran); per-candidate dispatches are the fallback counter.
    let variant = ctx.rt.scorer_variant();
    let rstats = ctx.rt.stats();
    let _ = write!(s, "  \"scorer_variant\": \"{}\",\n", variant.name());
    let _ = write!(s, "  \"lanes\": {},\n", variant.lanes());
    let _ = write!(s, "  \"lane_dispatches\": {},\n", rstats.lane_dispatches);
    let _ = write!(s, "  \"lane_candidates\": {},\n", rstats.lane_candidates);
    let _ = write!(
        s,
        "  \"lane_fill_fraction\": {:.4},\n",
        rstats.lane_fill_fraction()
    );
    let _ = write!(s, "  \"device_scorer_calls\": {},\n", rstats.scores_calls);
    // Slab-gather truth: with the gather artifact, a slab-cache miss is a
    // device dispatch over resident bank pieces instead of a host upload —
    // bytes_avoided is exactly what the host path would have re-uploaded.
    let _ = write!(s, "  \"slab_gather\": \"{}\",\n", ctx.slab_gather.name());
    let _ = write!(s, "  \"gather_dispatches\": {},\n", rstats.gather_dispatches);
    let _ = write!(
        s,
        "  \"slab_upload_bytes_avoided\": {},\n",
        rstats.slab_upload_bytes_avoided
    );
    // Slab-cache truth: lane dispatches re-upload nothing on a hit, so the
    // hit fraction is the share of slab traffic the cache absorbed.
    if let Some(ss) = ctx.slab_cache_stats() {
        let _ = write!(
            s,
            "  \"slab_cache\": {{\"budget_mb\": {}, \"hits\": {}, \"misses\": {}, \
             \"hit_fraction\": {:.4}, \"built_bytes\": {}, \"resident_bytes\": {}, \
             \"resident_slabs\": {}, \"evictions\": {}}},\n",
            ctx.slab_cache_mb,
            ss.hits,
            ss.misses,
            ss.hit_fraction(),
            ss.built_bytes,
            ss.resident_bytes,
            ss.resident_slabs,
            ss.evictions,
        );
    }
    if let Some(pool) = ctx.pool_stats() {
        let _ = write!(
            s,
            "  \"pool\": {{\"dispatches\": {}, \"requeued\": {}, \"retired_shards\": {}, \
             \"hedged_dispatched\": {}, \"hedged_won\": {}, \"hedged_wasted\": {}, \
             \"requeued_duplicates\": {}, \"latency_p50_ms\": {:.3}, \
             \"mean_wait_ms\": {:.3}, \"mean_service_ms\": {:.3}}},\n",
            pool.completed,
            pool.requeued,
            pool.retired_shards(),
            pool.hedged_dispatched,
            pool.hedged_won,
            pool.hedged_wasted,
            pool.requeued_duplicates,
            pool.latency_p50.as_secs_f64() * 1e3,
            pool.mean_wait().as_secs_f64() * 1e3,
            pool.mean_service().as_secs_f64() * 1e3,
        );
    }
    let bank_bytes = pipe.proxy.bank.memory_bytes();
    let slab_bytes = ctx.slab_cache_stats().map(|s| s.resident_bytes).unwrap_or(0);
    if let Some(bs) = ctx.bank_share_stats() {
        let _ = write!(
            s,
            "  \"bank\": {{\"resident_bytes\": {}, \"unshared_bytes\": {}, \
             \"slab_cache_bytes\": {}, \"total_resident_bytes\": {}, \"shards\": {}}}\n",
            bs.resident_bytes,
            bs.referenced_bytes,
            bs.slab_cache_bytes,
            bs.total_resident_bytes(),
            bs.shards,
        );
    } else {
        let _ = write!(
            s,
            "  \"bank\": {{\"resident_bytes\": {bank_bytes}, \"unshared_bytes\": {bank_bytes}, \
             \"slab_cache_bytes\": {slab_bytes}, \"total_resident_bytes\": {}, \
             \"shards\": 1}}\n",
            bank_bytes + slab_bytes,
        );
    }
    s.push_str("}\n");
    std::fs::write(path, s)?;
    Ok(())
}

fn main() -> Result<()> {
    let args = parse_args();
    if args.cmd.is_empty() || args.cmd == "help" {
        println!("usage: repro <list|check|search|all|shard-serve|pool-smoke|serve|serve-bench|EXPERIMENT> [--preset smoke|repro|paper] [--fresh] [--seed N] [--out DIR] [--workers N] [--shards a:p,b:p] [--hedge-factor F] [--chunk-timeout-ms N] [--fault-spec SEED:KIND:RATE] [--listen ADDR] [--synthetic] [--score-batch K] [--lanes N] [--slab-cache-mb N] [--slab-gather auto|off|require] [--methods LIST] [--predictor rbf|mlp|gp] [--ucb-kappa F] [--warm-start DIR] [--config ARCHIVE.json] [--budget B] [--max-wait-us N] [--queue-cap N] [--conn-cap N] [--addr ADDR] [--clients N] [--rps R] [--duration S]");
        println!("experiments:");
        for (name, desc) in exp::EXPERIMENTS {
            println!("  {name:8} {desc}");
        }
        return Ok(());
    }
    if args.cmd == "list" {
        for (name, desc) in exp::EXPERIMENTS {
            println!("{name:8} {desc}");
        }
        return Ok(());
    }
    // The two distributed-topology commands run before the artifacts gate:
    // shard-serve handles its own artifacts (or none, with --synthetic) and
    // pool-smoke is artifact-free by design.
    if args.cmd == "shard-serve" {
        return run_shard_serve(&args);
    }
    if args.cmd == "pool-smoke" {
        return run_pool_smoke(&args);
    }
    // The serving pair also runs before the artifacts gate: serve handles
    // its own artifacts (or none, with --synthetic) and serve-bench only
    // ever talks to a server over TCP.
    if args.cmd == "serve" {
        return run_serve(&args);
    }
    if args.cmd == "serve-bench" {
        return run_serve_bench(&args);
    }

    let artifacts = args
        .artifacts
        .as_ref()
        .map(std::path::PathBuf::from)
        .unwrap_or_else(amq::artifacts_dir);
    eyre::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts not found at {} — run `make artifacts`",
        artifacts.display()
    );

    let params = preset(&args);
    let registry = match args.methods.as_deref() {
        Some(list) => Some(MethodRegistry::parse(list)?),
        None => None,
    };
    let t0 = std::time::Instant::now();
    let mut ctx = Ctx::load_with_opts(
        &artifacts,
        std::path::Path::new(&args.out),
        params,
        args.workers,
        registry,
        args.score_batch,
        args.lanes,
        args.slab_cache_mb,
        args.slab_gather,
    )?;
    ctx.set_shards(args.shards.clone());
    ctx.set_hedge_factor(args.hedge_factor);
    ctx.set_warm_start(args.warm_start.clone());
    let variant = ctx.rt.scorer_variant();
    eprintln!(
        "[repro] runtime + artifacts loaded in {:.1}s ({} eval worker{}, {} remote shard{}, score-batch {}, scorer: {} x{}, slab-cache {} MB, slab-gather {} ({}), methods: {}, predictor: {})",
        t0.elapsed().as_secs_f64(),
        ctx.local_workers(),
        if ctx.local_workers() == 1 { "" } else { "s" },
        ctx.shards.len(),
        if ctx.shards.len() == 1 { "" } else { "s" },
        ctx.score_batch,
        variant.name(),
        variant.lanes(),
        ctx.slab_cache_mb,
        ctx.slab_gather.name(),
        if ctx.rt.slab_gather_enabled() { "device" } else { "host-pack" },
        ctx.registry.names().join(","),
        ctx.preset.predictor.name(),
    );

    if args.cmd == "check" {
        println!("artifacts: {}", artifacts.display());
        println!("model: {} layers, {} searchable linears, vocab {}",
                 ctx.assets.manifest.model.n_layers,
                 ctx.assets.manifest.layers.len(),
                 ctx.assets.manifest.model.vocab_size);
        let space =
            amq::coordinator::SearchSpace::with_methods(&ctx.assets.manifest, &ctx.registry);
        let per_layer = space.choices.first().map(|c| c.len()).unwrap_or(0);
        println!(
            "search space: {per_layer}^{} ≈ 10^{:.1} configurations ({} method{})",
            space.n_layers(),
            space.log10_size(),
            ctx.registry.len(),
            if ctx.registry.len() == 1 { "" } else { "s" }
        );
        let q = exp::common::quality(&ctx, &amq::eval::ModelHandle::Fp)?;
        println!("fp16: wiki_ppl {:.3}  c4_ppl {:.3}  zero-shot avg {:.1}%",
                 q.wiki_ppl, q.c4_ppl,
                 q.zero_shot.macro_avg(&amq::data::ZERO_SHOT));
        println!("check OK");
        return Ok(());
    }

    let t0 = std::time::Instant::now();
    let pipe = exp::common::Pipeline::build(&ctx)?;
    eprintln!(
        "[repro] pipeline: proxy {:.1}s, {} outliers pruned, space 10^{:.1} -> 10^{:.1}",
        pipe.proxy_build_secs,
        pipe.prune_report.outliers.len(),
        pipe.full_space.log10_size(),
        pipe.space.log10_size()
    );
    for s in &pipe.proxy.bank.stats {
        eprintln!(
            "[bank] {:>8}: {} (layer, bits) pieces built in {:.2}s, {:.1} MB resident",
            s.method.name(),
            pipe.proxy.bank.n_layers() * pipe.proxy.bank.bit_choices.len(),
            s.build_time.as_secs_f64(),
            s.memory_bytes as f64 / 1e6,
        );
    }
    let _ = t0;

    let fresh = args.fresh;
    let run_one = |name: &str| -> Result<()> {
        eprintln!("\n===== {name} =====");
        let t = std::time::Instant::now();
        match name {
            "fig1" | "fig7" => exp::fig1::run(&ctx, &pipe, fresh)?,
            "fig2" => exp::fig2::run(&ctx, &pipe)?,
            "fig5" => exp::speed::run_fig5(&ctx, &pipe)?,
            "fig6" => exp::fig6::run(&ctx, &pipe, fresh)?,
            "fig8" => exp::speed::run_fig8(&ctx, &pipe, fresh)?,
            "fig9" | "fig10" => exp::fig9::run(&ctx, &pipe, fresh)?,
            "genescan" => exp::genescan::run(&ctx, &pipe)?,
            "fig11" => exp::fig11::run(&ctx, &pipe)?,
            "fig12" => exp::fig12::run(&ctx, &pipe, fresh)?,
            "table1" => exp::table1::run(&ctx, &pipe, fresh)?,
            "table2" => exp::table2::run(&ctx, &pipe, fresh)?,
            "table3" => exp::table3::run(&ctx, &pipe, fresh)?,
            "table4" => exp::table4::run(&ctx, &pipe)?,
            "table5" => exp::pruning_ablation::run(&ctx, &pipe, fresh)?,
            "table7" => exp::table78::run_table7(&ctx, &pipe, fresh)?,
            "table8" => exp::table78::run_table8(&ctx, &pipe, fresh)?,
            "table9" => exp::table9::run(&ctx, &pipe, fresh)?,
            "table10" => exp::table10::run(&ctx, &pipe, fresh)?,
            "table11" | "table12" => exp::table11::run(&ctx, &pipe, fresh)?,
            other => eyre::bail!("unknown experiment {other} (try `repro list`)"),
        }
        eprintln!("[{name}] done in {:.1}s", t.elapsed().as_secs_f64());
        Ok(())
    };

    match args.cmd.as_str() {
        "search" => {
            let archive = exp::common::main_archive(&ctx, &pipe, fresh)?;
            let front = archive.pareto_front();
            println!("Pareto frontier ({} of {} samples):", front.len(), archive.len());
            let mut rows: Vec<_> = front.iter().map(|&i| &archive.samples[i]).collect();
            rows.sort_by(|a, b| a.avg_bits.partial_cmp(&b.avg_bits).unwrap());
            let multi = ctx.registry.len() > 1;
            for s in &rows {
                if multi {
                    println!(
                        "  bits {:.3}  jsd {:.5}  methods [{}]",
                        s.avg_bits,
                        s.jsd,
                        method_mix(&s.config)
                    );
                } else {
                    println!("  bits {:.3}  jsd {:.5}", s.avg_bits, s.jsd);
                }
            }
            // Per-budget summary: "-" marks a budget with no feasible
            // sample instead of panicking on an empty selection.
            for &b in &exp::common::BUDGETS {
                let best = archive.best_under(b, exp::common::TOL).map(|s| s.jsd);
                println!("  best under {b} bits: jsd {}", amq::report::fmt_opt(best, 5));
            }
            let report = ctx.out_dir.join("search_report.json");
            write_search_report(&report, &ctx, &pipe, &archive, &rows)?;
            eprintln!("[report] wrote {}", report.display());
            let bench = ctx.out_dir.join("BENCH_search.json");
            write_bench_json(&bench, &ctx, &pipe)?;
            eprintln!("[report] wrote {}", bench.display());
        }
        "all" => {
            let order = [
                "fig2", "table4", "table1", "table2", "table3", "fig1", "fig5",
                "fig6", "fig8", "fig9", "fig12", "table9", "table11", "table7",
                "table8", "table10", "table5", "fig11",
            ];
            for name in order {
                run_one(name)?;
            }
        }
        name => run_one(name)?,
    }
    let stats = ctx.rt.stats();
    eprintln!(
        "[runtime] fp {} calls {:.1}s | quant {} calls {:.1}s | scorer {} calls {:.1}s",
        stats.fp_calls, stats.fp_time.as_secs_f64(),
        stats.quant_calls, stats.quant_time.as_secs_f64(),
        stats.scores_calls, stats.scores_time.as_secs_f64(),
    );
    if stats.lane_dispatches > 0 {
        eprintln!(
            "[scorer] lane-stacked x{}: {} dispatches carried {} candidates \
             ({} padded lanes, {:.0}% lane fill) in {:.1}s",
            ctx.rt.scorer_variant().lanes(),
            stats.lane_dispatches,
            stats.lane_candidates,
            stats.lane_padded,
            stats.lane_fill_fraction() * 100.0,
            stats.lane_time.as_secs_f64(),
        );
    }
    if ctx.rt.slab_gather_enabled() {
        eprintln!(
            "[scorer] slab gather ({}): {} device dispatch(es) in {:.2}s \
             assembled lane slabs from resident bank pieces",
            ctx.slab_gather.name(),
            stats.gather_dispatches,
            stats.gather_time.as_secs_f64(),
        );
    }
    if let Some(ss) = ctx.slab_cache_stats() {
        if ss.hits + ss.misses > 0 {
            eprintln!(
                "[scorer] slab cache ({} MB budget): {} hits / {} misses \
                 ({:.0}% hit), {} slabs resident ({:.1} MB), {} evictions",
                ctx.slab_cache_mb,
                ss.hits,
                ss.misses,
                ss.hit_fraction() * 100.0,
                ss.resident_slabs,
                ss.resident_bytes as f64 / 1e6,
                ss.evictions,
            );
        }
    }
    if let Some(pool) = ctx.pool_stats() {
        let per_shard: Vec<String> = pool
            .per_shard
            .iter()
            .map(|s| {
                format!(
                    "{}:{} ({:.1}s busy{})",
                    s.label,
                    s.completed,
                    s.busy.as_secs_f64(),
                    if s.retired { ", retired" } else { "" },
                )
            })
            .collect();
        eprintln!(
            "[pool] {} dispatches ({} requeued) | hedged {} (won {}, wasted {}) | p50 {:.1}ms | mean wait {:.1}ms | mean service {:.1}ms | shards {}",
            pool.completed,
            pool.requeued,
            pool.hedged_dispatched,
            pool.hedged_won,
            pool.hedged_wasted,
            pool.latency_p50.as_secs_f64() * 1e3,
            pool.mean_wait().as_secs_f64() * 1e3,
            pool.mean_service().as_secs_f64() * 1e3,
            per_shard.join(" "),
        );
    }
    if let Some(bs) = ctx.bank_share_stats() {
        eprintln!(
            "[bank] {:.1} MB resident + {:.1} MB slab cache, shared by {} shard{} \
             (private copies would hold {:.1} MB)",
            bs.resident_bytes as f64 / 1e6,
            bs.slab_cache_bytes as f64 / 1e6,
            bs.shards,
            if bs.shards == 1 { "" } else { "s" },
            bs.referenced_bytes as f64 / 1e6,
        );
    }
    if stats.slab_upload_bytes_avoided > 0 {
        eprintln!(
            "[bank] device-side gather kept {:.1} MB of lane slabs off the \
             host upload path",
            stats.slab_upload_bytes_avoided as f64 / 1e6,
        );
    }
    if !ctx.shards.is_empty() {
        // Server-side truth for the remote shards: shut the pool down first
        // so the feeder connections close and the sequential shard servers
        // can accept the dedicated stats-probe connections.  pipe borrows
        // ctx; release it before the mutable shutdown.
        drop(pipe);
        let shards = ctx.shards.clone();
        ctx.shutdown_pool();
        for addr in &shards {
            match amq::runtime::remote::fetch_shard_stats(
                addr,
                std::time::Duration::from_secs(5),
            ) {
                Ok(st) => eprintln!(
                    "[pool] shard {addr}: server-side {} chunk(s) completed, \
                     {:.2}s busy in eval, {} connection(s) served",
                    st.completed,
                    st.busy_us as f64 / 1e6,
                    st.conns,
                ),
                Err(e) => eprintln!(
                    "[pool] shard {addr}: server-side stats unavailable ({e})"
                ),
            }
        }
    }
    Ok(())
}

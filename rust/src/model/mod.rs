//! Subject-model state on the rust side: named fp weights, calibration
//! statistics, and the per-layer inventory the search runs over.

use crate::data::{Bundle, Manifest};
use crate::tensor::Mat;
use crate::Result;
use std::collections::HashMap;
use std::path::Path;

/// All fp32 parameters of the subject model, keyed by manifest names.
pub struct WeightStore {
    tensors: HashMap<String, (Vec<usize>, Vec<f32>)>,
}

impl WeightStore {
    pub fn load(path: &Path) -> Result<WeightStore> {
        let bundle = Bundle::read(path)?;
        let mut tensors = HashMap::new();
        for name in bundle.names().map(str::to_string).collect::<Vec<_>>() {
            let t = bundle.tensor(&name)?;
            tensors.insert(name, (t.shape.clone(), t.as_f32()?.to_vec()));
        }
        Ok(WeightStore { tensors })
    }

    pub fn get(&self, name: &str) -> Result<(&[usize], &[f32])> {
        self.tensors
            .get(name)
            .map(|(s, d)| (s.as_slice(), d.as_slice()))
            .ok_or_else(|| eyre::anyhow!("weight `{name}` missing"))
    }

    /// A 2-D linear weight as a [out, in] matrix.
    pub fn linear(&self, name: &str) -> Result<Mat> {
        let (shape, data) = self.get(name)?;
        eyre::ensure!(shape.len() == 2, "{name} is not 2-D: {shape:?}");
        Ok(Mat::from_vec(shape[0], shape[1], data.to_vec()))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }
}

/// Calibration statistics for one activation slot: H = E[x x^T], E[|x|].
pub struct CalibStats {
    pub hessian: Mat,      // [K, K]
    pub mean_abs: Vec<f32>, // [K]
}

/// Per-layer calibration stats, resolved through the Q/K/V- and
/// Gate/Up-sharing slot map (see python/compile/hessian.py).
pub struct HessianStore {
    slots: HashMap<String, CalibStats>,
}

/// Activation slot feeding a linear kind.
pub fn act_slot(kind: &str) -> &'static str {
    match kind {
        "q" | "k" | "v" => "attn_in",
        "o" => "o_in",
        "gate" | "up" => "mlp_in",
        "down" => "down_in",
        other => panic!("unknown linear kind {other}"),
    }
}

impl HessianStore {
    pub fn load(path: &Path) -> Result<HessianStore> {
        let bundle = Bundle::read(path)?;
        let mut slots = HashMap::new();
        let names: Vec<String> = bundle
            .names()
            .filter(|n| n.ends_with(".hessian"))
            .map(str::to_string)
            .collect();
        for hname in names {
            let slot = hname.trim_end_matches(".hessian").to_string();
            let h = bundle.tensor(&hname)?;
            eyre::ensure!(h.shape.len() == 2 && h.shape[0] == h.shape[1]);
            let hess = Mat::from_vec(h.shape[0], h.shape[1], h.as_f32()?.to_vec());
            let ma = bundle.tensor(&format!("{slot}.mean_abs"))?;
            slots.insert(
                slot,
                CalibStats { hessian: hess, mean_abs: ma.as_f32()?.to_vec() },
            );
        }
        Ok(HessianStore { slots })
    }

    /// Stats for a linear layer, e.g. "blk1.gate" -> slot "blk1.mlp_in".
    pub fn for_layer(&self, layer_name: &str) -> Result<&CalibStats> {
        let mut parts = layer_name.split('.');
        let blk = parts.next().unwrap_or("");
        let kind = parts.next().unwrap_or("");
        let slot = format!("{blk}.{}", act_slot(kind));
        self.slots
            .get(&slot)
            .ok_or_else(|| eyre::anyhow!("no calib stats for {layer_name} ({slot})"))
    }
}

/// Convenience: load everything the coordinator needs from `artifacts/`.
pub struct ModelAssets {
    pub manifest: Manifest,
    pub weights: WeightStore,
    pub hessians: HessianStore,
}

impl ModelAssets {
    pub fn load(artifacts_dir: &Path) -> Result<ModelAssets> {
        let manifest = Manifest::load(artifacts_dir)?;
        let weights = WeightStore::load(&manifest.file("weights")?)?;
        let hessians = HessianStore::load(&manifest.file("hessians")?)?;
        // sanity: every searchable layer has a weight + calib stats
        for l in &manifest.layers {
            let w = weights.linear(&l.name)?;
            eyre::ensure!(
                w.rows == l.out_features && w.cols == l.in_features,
                "weight shape mismatch for {}", l.name
            );
            let st = hessians.for_layer(&l.name)?;
            eyre::ensure!(st.hessian.rows == l.in_features);
        }
        Ok(ModelAssets { manifest, weights, hessians })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_mapping() {
        assert_eq!(act_slot("q"), "attn_in");
        assert_eq!(act_slot("v"), "attn_in");
        assert_eq!(act_slot("o"), "o_in");
        assert_eq!(act_slot("up"), "mlp_in");
        assert_eq!(act_slot("down"), "down_in");
    }

    #[test]
    #[should_panic]
    fn slot_mapping_rejects_unknown() {
        act_slot("lm_head");
    }
}

//! AWQ-style quantization with asymmetric clipping (Lin et al. 2024 +
//! Gong et al. 2024) — the paper's deploy-time method for AMQ configs.
//!
//! Two activation-aware ingredients on top of grouped RTN:
//!  1. *channel scaling*: input channel j is scaled by s_j = E|x_j|^alpha
//!     before quantization (and the inverse folded into dequant via the
//!     group scale), protecting salient channels;
//!  2. *asymmetric clip search*: per group, grid-search independent shrink
//!     factors for the min and max edge of the range, scoring candidates by
//!     the Hessian-weighted output error tr(ΔW H ΔW^T).
//!
//! We fold the channel scale exactly into W (scale then unscale) rather than
//! into neighboring layers, which keeps the representation layer-local — the
//! property the quantization proxy relies on.

use super::{affine_params, group_minmax, QuantizedLinear, Quantizer};
use crate::model::CalibStats;
use crate::tensor::Mat;

pub struct AwqClip {
    pub alpha_grid: Vec<f32>,
    pub clip_grid: Vec<f32>,
}

impl Default for AwqClip {
    fn default() -> Self {
        AwqClip {
            alpha_grid: vec![0.0, 0.25, 0.5],
            clip_grid: vec![1.0, 0.9, 0.8, 0.7, 0.6],
        }
    }
}

impl Quantizer for AwqClip {
    fn name(&self) -> &'static str {
        "awq_clip"
    }

    fn quantize(
        &self,
        w: &Mat,
        bits: u8,
        group_size: usize,
        stats: Option<&CalibStats>,
    ) -> QuantizedLinear {
        match stats {
            Some(st) => self.quantize_with_stats(w, bits, group_size, st),
            None => super::rtn::quantize_rtn(w, bits, group_size, 1.0),
        }
    }
}

impl AwqClip {
    fn quantize_with_stats(
        &self,
        w: &Mat,
        bits: u8,
        group_size: usize,
        st: &CalibStats,
    ) -> QuantizedLinear {
        let k = w.cols;
        let mut best: Option<(f64, QuantizedLinear)> = None;
        for &alpha in &self.alpha_grid {
            // channel scale s_j = (E|x_j|)^alpha, normalized to mean 1
            let mut s = vec![1.0f32; k];
            if alpha > 0.0 {
                let mut mean = 0.0f32;
                for j in 0..k {
                    s[j] = st.mean_abs[j].max(1e-8).powf(alpha);
                    mean += s[j];
                }
                mean /= k as f32;
                for v in &mut s {
                    *v /= mean;
                }
            }
            // W' = W * diag(s): quantize W', then fold 1/s back via dequant
            // comparison (we keep codes/scale/zero of W' but divide scale
            // per column is impossible in grouped form, so instead we score
            // the *effective* W reconstruction: dequant(W')_oj / s_j).
            let q = self.clip_quantize(w, &s, bits, group_size, st);
            let dq = dequant_unscaled(&q, &s);
            let err = super::hessian_error(w, &dq, &st.hessian);
            if best.as_ref().map(|(e, _)| err < *e).unwrap_or(true) {
                best = Some((err, q));
            }
        }
        let (_, mut q) = best.unwrap();
        // Bake the channel scale back into scale-per-group approximately is
        // impossible when s varies within a group; instead we store codes of
        // the *scaled* weights and fold s into a corrected dequant by
        // re-fitting scale/zero per group against the true W (least-squares
        // affine refit keeps the grouped representation exact-form).
        refit_affine(&mut q, w);
        q
    }

    /// Grouped RTN of diag-scaled weights with per-group asymmetric clip
    /// search under the Hessian metric (diagonal surrogate per group).
    fn clip_quantize(
        &self,
        w: &Mat,
        chan_scale: &[f32],
        bits: u8,
        group_size: usize,
        st: &CalibStats,
    ) -> QuantizedLinear {
        let (n, k) = (w.rows, w.cols);
        let g = k / group_size;
        let qmax = ((1u32 << bits) - 1) as f32;
        let mut codes = vec![0u8; n * k];
        let mut scale = vec![0f32; n * g];
        let mut zero = vec![0f32; n * g];
        // diagonal Hessian weights for the group-local clip score
        let hdiag: Vec<f32> = (0..k).map(|i| st.hessian[(i, i)].max(0.0)).collect();

        let mut ws = vec![0.0f32; group_size];
        for o in 0..n {
            for gi in 0..g {
                let cols = gi * group_size..(gi + 1) * group_size;
                for (j, c) in cols.clone().enumerate() {
                    ws[j] = w[(o, c)] * chan_scale[c];
                }
                let (lo0, hi0) = group_minmax(&ws);
                let mut best = (f64::INFINITY, 1.0f32, 1.0f32);
                for &cl in &self.clip_grid {
                    for &ch in &self.clip_grid {
                        let lo = lo0 * cl;
                        let hi = hi0 * ch;
                        if hi <= lo {
                            continue;
                        }
                        let (s, z) = affine_params(lo, hi, bits);
                        let zr = z.round();
                        let mut err = 0.0f64;
                        for (j, c) in cols.clone().enumerate() {
                            let q = (ws[j] / s + zr).round().clamp(0.0, qmax);
                            let d = ws[j] - (q - zr) * s;
                            let dw = d / chan_scale[c];
                            err += (dw * dw * hdiag[c]) as f64;
                        }
                        if err < best.0 {
                            best = (err, cl, ch);
                        }
                    }
                }
                let (s, z) = affine_params(lo0 * best.1, hi0 * best.2, bits);
                let zr = z.round();
                scale[o * g + gi] = s;
                zero[o * g + gi] = zr;
                for (j, c) in cols.clone().enumerate() {
                    let q = (ws[j] / s + zr).round().clamp(0.0, qmax);
                    codes[o * k + c] = q as u8;
                }
            }
        }
        QuantizedLinear {
            out_features: n,
            in_features: k,
            group_size,
            bits,
            codes,
            scale,
            zero,
        }
    }
}

/// Reconstruction of channel-scaled codes back in original weight space.
fn dequant_unscaled(q: &QuantizedLinear, chan_scale: &[f32]) -> Mat {
    let mut dq = q.dequant();
    for o in 0..dq.rows {
        let row = dq.row_mut(o);
        for (j, v) in row.iter_mut().enumerate() {
            *v /= chan_scale[j];
        }
    }
    dq
}

/// Least-squares refit of (scale, zero) per group against the target W,
/// keeping codes fixed: min_{s,b} Σ (w - (s*c + b))^2 with zero = -b/s.
fn refit_affine(q: &mut QuantizedLinear, w: &Mat) {
    let (n, k, gs) = (q.out_features, q.in_features, q.group_size);
    let g = k / gs;
    for o in 0..n {
        for gi in 0..g {
            let mut sc = 0.0f64;
            let mut sw = 0.0f64;
            let mut scc = 0.0f64;
            let mut scw = 0.0f64;
            for j in 0..gs {
                let idx = o * k + gi * gs + j;
                let c = q.codes[idx] as f64;
                let wv = w.data[idx] as f64;
                sc += c;
                sw += wv;
                scc += c * c;
                scw += c * wv;
            }
            let m = gs as f64;
            let denom = m * scc - sc * sc;
            if denom.abs() < 1e-12 {
                continue;
            }
            let s = (m * scw - sc * sw) / denom;
            let b = (sw - s * sc) / m;
            if s.abs() < 1e-12 {
                continue;
            }
            q.scale[o * g + gi] = s as f32;
            q.zero[o * g + gi] = (-b / s) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CalibStats;
    use crate::quant::{hessian_error, Rtn};

    fn rand_w(n: usize, k: usize, seed: u64) -> Mat {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut w = Mat::zeros(n, k);
        for v in &mut w.data {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            let u = (state >> 11) as f32 / (1u64 << 53) as f32 - 0.5;
            *v = if state & 15 == 0 { u * 1.0 } else { u * 0.1 }; // outliers
        }
        w
    }

    fn stats(k: usize, seed: u64) -> CalibStats {
        let x = rand_w(4 * k, k, seed);
        let mut h = Mat::zeros(k, k);
        let mut ma = vec![0.0f32; k];
        for r in 0..x.rows {
            let row = x.row(r);
            for i in 0..k {
                ma[i] += row[i].abs();
                for j in 0..k {
                    h[(i, j)] += row[i] * row[j];
                }
            }
        }
        for v in &mut ma {
            *v /= x.rows as f32;
        }
        CalibStats { hessian: h, mean_abs: ma }
    }

    #[test]
    fn awq_improves_over_rtn_at_low_bits() {
        let k = 32;
        let w = rand_w(8, k, 21);
        let st = stats(k, 22);
        for bits in [2u8, 3] {
            let e_rtn = hessian_error(
                &w, &Rtn.quantize(&w, bits, 16, None).dequant(), &st.hessian);
            let e_awq = hessian_error(
                &w,
                &AwqClip::default().quantize(&w, bits, 16, Some(&st)).dequant(),
                &st.hessian,
            );
            assert!(e_awq <= e_rtn * 1.001, "bits={bits}: {e_awq} vs {e_rtn}");
        }
    }

    #[test]
    fn refit_affine_never_hurts_l2() {
        let w = rand_w(4, 32, 23);
        let mut q = Rtn.quantize(&w, 2, 16, None);
        let before = crate::quant::frob_error(&w, &q);
        refit_affine(&mut q, &w);
        let after = crate::quant::frob_error(&w, &q);
        assert!(after <= before + 1e-5, "{after} vs {before}");
    }

    #[test]
    fn codes_in_range() {
        let k = 32;
        let w = rand_w(4, k, 24);
        let st = stats(k, 25);
        let q = AwqClip::default().quantize(&w, 2, 16, Some(&st));
        assert!(q.codes.iter().all(|&c| c <= 3));
    }
}

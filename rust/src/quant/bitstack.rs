//! BitStack (Wang et al., 2024) — any-size compression baseline.
//!
//! Each layer's weight is decomposed into a stack of *residual blocks*:
//! block i stores `sign(R_i)` (1 bit/weight) plus a rank-1 magnitude factor
//! `u σ v^T` from a power-iteration SVD of `|R_i|` (fp16 vectors), so
//!
//!   W ≈ Σ_i  sign(R_i) ⊙ (u_i σ_i v_i^T),   R_{i+1} = R_i - W_i.
//!
//! Any memory budget is met by loading a prefix of each layer's stack; the
//! global allocator spends the budget greedily on the block with the best
//! marginal error reduction per byte (the paper's "block sorting").  At
//! inference every loaded block is re-materialized, which is what makes
//! BitStack slower than kernel-based quantization (Fig. 8).

use crate::tensor::{power_iteration_rank1, Mat};

/// One residual block.
#[derive(Clone)]
pub struct Block {
    pub signs: Vec<u8>,   // bit-packed sign(R) (1 = negative)
    pub u: Vec<f32>,      // [n]
    pub sigma: f32,
    pub v: Vec<f32>,      // [k]
    pub err_after: f32,   // ||R_{i+1}||_F after applying this block
}

/// The per-layer block stack.
pub struct BitStackLayer {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub blocks: Vec<Block>,
    pub err_before: f32, // ||W||_F (error with 0 blocks loaded)
}

impl BitStackLayer {
    /// Decompose `w` into up to `max_blocks` residual blocks.
    pub fn decompose(name: &str, w: &Mat, max_blocks: usize) -> BitStackLayer {
        let (n, k) = (w.rows, w.cols);
        let mut residual = w.clone();
        let mut blocks = Vec::with_capacity(max_blocks);
        let err_before = residual.frob_norm();
        for _ in 0..max_blocks {
            // |R| and sign(R)
            let mut absr = Mat::zeros(n, k);
            let mut signs = vec![0u8; (n * k).div_ceil(8)];
            for idx in 0..n * k {
                let v = residual.data[idx];
                absr.data[idx] = v.abs();
                if v < 0.0 {
                    signs[idx / 8] |= 1 << (idx % 8);
                }
            }
            let (u, sigma, v) = power_iteration_rank1(&absr, 12);
            // apply block, update residual
            for i in 0..n {
                let ui = sigma * u[i];
                let rrow = residual.row_mut(i);
                for j in 0..k {
                    let idx = i * k + j;
                    let sgn = if signs[idx / 8] >> (idx % 8) & 1 == 1 { -1.0 } else { 1.0 };
                    rrow[j] -= sgn * ui * v[j];
                }
            }
            let err_after = residual.frob_norm();
            blocks.push(Block { signs, u, sigma, v, err_after });
        }
        BitStackLayer { name: name.to_string(), rows: n, cols: k, blocks, err_before }
    }

    /// Bytes per block: packed signs + fp16 u, v, sigma.
    pub fn block_bytes(&self) -> usize {
        (self.rows * self.cols).div_ceil(8) + 2 * (self.rows + self.cols) + 2
    }

    /// Reconstruct the weight from the first `n_blocks` blocks.
    pub fn reconstruct(&self, n_blocks: usize) -> Mat {
        let (n, k) = (self.rows, self.cols);
        let mut w = Mat::zeros(n, k);
        for b in self.blocks.iter().take(n_blocks) {
            for i in 0..n {
                let ui = b.sigma * b.u[i];
                let wrow = w.row_mut(i);
                for j in 0..k {
                    let idx = i * k + j;
                    let sgn = if b.signs[idx / 8] >> (idx % 8) & 1 == 1 { -1.0 } else { 1.0 };
                    wrow[j] += sgn * ui * b.v[j];
                }
            }
        }
        w
    }

    /// Residual error with `n_blocks` loaded.
    pub fn error(&self, n_blocks: usize) -> f32 {
        if n_blocks == 0 {
            self.err_before
        } else {
            self.blocks[n_blocks.min(self.blocks.len()) - 1].err_after
        }
    }
}

/// BitStack over a whole model: stacks for every searchable layer.
pub struct BitStack {
    pub layers: Vec<BitStackLayer>,
}

impl BitStack {
    pub fn decompose(weights: &[(String, Mat)], max_blocks: usize) -> BitStack {
        let layers = weights
            .iter()
            .map(|(name, w)| BitStackLayer::decompose(name, w, max_blocks))
            .collect();
        BitStack { layers }
    }

    /// Greedy budget allocation: returns blocks-per-layer for a total byte
    /// budget (the paper's sorted block loading).
    pub fn allocate(&self, budget_bytes: usize) -> Vec<usize> {
        let mut loaded = vec![0usize; self.layers.len()];
        let mut spent = 0usize;
        loop {
            // best marginal (error drop)/(bytes) among next blocks
            let mut best: Option<(f64, usize)> = None;
            for (li, layer) in self.layers.iter().enumerate() {
                let i = loaded[li];
                if i >= layer.blocks.len() {
                    continue;
                }
                let bytes = layer.block_bytes();
                if spent + bytes > budget_bytes {
                    continue;
                }
                let drop = (layer.error(i) - layer.error(i + 1)) as f64;
                let gain = drop / bytes as f64;
                if best.map(|(g, _)| gain > g).unwrap_or(true) {
                    best = Some((gain, li));
                }
            }
            match best {
                Some((_, li)) => {
                    spent += self.layers[li].block_bytes();
                    loaded[li] += 1;
                }
                None => break,
            }
        }
        loaded
    }

    /// Total bytes for an allocation.
    pub fn bytes(&self, loaded: &[usize]) -> usize {
        self.layers
            .iter()
            .zip(loaded)
            .map(|(l, &n)| n * l.block_bytes())
            .sum()
    }

    /// Reconstruct all layers under an allocation.
    pub fn reconstruct_all(&self, loaded: &[usize]) -> Vec<(String, Mat)> {
        self.layers
            .iter()
            .zip(loaded)
            .map(|(l, &n)| (l.name.clone(), l.reconstruct(n)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_w(n: usize, k: usize, seed: u64) -> Mat {
        let mut state = seed | 1;
        let mut w = Mat::zeros(n, k);
        for v in &mut w.data {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *v = ((state >> 11) as f32 / (1u64 << 53) as f32 - 0.5) * 0.2;
        }
        w
    }

    #[test]
    fn residual_error_monotone() {
        let w = rand_w(16, 24, 31);
        let layer = BitStackLayer::decompose("t", &w, 6);
        for i in 0..6 {
            assert!(
                layer.error(i + 1) <= layer.error(i) + 1e-6,
                "block {i}: {} -> {}", layer.error(i), layer.error(i + 1)
            );
        }
    }

    #[test]
    fn reconstruct_matches_residual_error() {
        let w = rand_w(8, 12, 32);
        let layer = BitStackLayer::decompose("t", &w, 4);
        let rec = layer.reconstruct(4);
        let mut err = 0.0f32;
        for (a, b) in w.data.iter().zip(&rec.data) {
            err += (a - b) * (a - b);
        }
        assert!((err.sqrt() - layer.error(4)).abs() < 1e-4);
    }

    #[test]
    fn allocator_respects_budget_and_spends_it() {
        let ws = vec![
            ("a".to_string(), rand_w(16, 16, 33)),
            ("b".to_string(), rand_w(16, 32, 34)),
        ];
        let bs = BitStack::decompose(&ws, 8);
        let per_block = bs.layers[0].block_bytes();
        let budget = per_block * 6;
        let loaded = bs.allocate(budget);
        let bytes = bs.bytes(&loaded);
        assert!(bytes <= budget);
        // should load at least a few blocks
        assert!(loaded.iter().sum::<usize>() >= 3);
    }

    #[test]
    fn more_budget_less_error() {
        let ws = vec![("a".to_string(), rand_w(16, 16, 35))];
        let bs = BitStack::decompose(&ws, 8);
        let small = bs.allocate(bs.layers[0].block_bytes() * 2);
        let large = bs.allocate(bs.layers[0].block_bytes() * 6);
        assert!(bs.layers[0].error(large[0]) <= bs.layers[0].error(small[0]));
    }
}

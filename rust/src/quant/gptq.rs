//! GPTQ (Frantar et al., 2022) — the activation-dependent deploy-time
//! quantizer used as a Figure-6 comparator and a deployment backend.
//!
//! Column-wise quantization with optimal error feedback under the
//! calibration Hessian H = E[x x^T]:  iterate columns j, quantize, and
//! spread the error over the remaining columns using the rows of
//! `U = cholesky(H^{-1}, upper=true)` — the standard GPTQ recurrence.

use super::{affine_params, group_minmax, QuantizedLinear, Quantizer};
use crate::model::CalibStats;
use crate::tensor::{cholesky_inverse_upper, Mat};

pub struct Gptq {
    /// Fractional dampening added to diag(H) (paper default 0.01).
    pub damp: f64,
}

impl Default for Gptq {
    fn default() -> Self {
        Gptq { damp: 0.01 }
    }
}

impl Quantizer for Gptq {
    fn name(&self) -> &'static str {
        "gptq"
    }

    fn quantize(
        &self,
        w: &Mat,
        bits: u8,
        group_size: usize,
        stats: Option<&CalibStats>,
    ) -> QuantizedLinear {
        let (n, k) = (w.rows, w.cols);
        assert_eq!(k % group_size, 0);
        let g = k / group_size;
        let qmax = ((1u32 << bits) - 1) as f32;

        // Without calibration stats GPTQ degenerates to RTN.
        let u = stats.and_then(|s| cholesky_inverse_upper(&s.hessian, self.damp));
        let u = match u {
            Some(u) => u,
            None => return super::rtn::quantize_rtn(w, bits, group_size, 1.0),
        };

        let mut codes = vec![0u8; n * k];
        let mut scale = vec![0f32; n * g];
        let mut zero = vec![0f32; n * g];

        // Work on an error-compensated copy of W, all rows in parallel
        // (row-major: process column j across all rows, like GPTQ's blocked
        // implementation with block = group).
        let mut werr = w.clone();
        for gi in 0..g {
            let lo_col = gi * group_size;
            let hi_col = lo_col + group_size;
            // group parameters from the *current* (compensated) weights
            for o in 0..n {
                let grp = &werr.row(o)[lo_col..hi_col];
                let (lo, hi) = group_minmax(grp);
                let (s, z) = affine_params(lo, hi, bits);
                scale[o * g + gi] = s;
                zero[o * g + gi] = z.round();
            }
            for j in lo_col..hi_col {
                let d = u[(j, j)].max(1e-10);
                for o in 0..n {
                    let s = scale[o * g + gi];
                    let z = zero[o * g + gi];
                    let wv = werr[(o, j)];
                    let q = (wv / s + z).round().clamp(0.0, qmax);
                    codes[o * k + j] = q as u8;
                    let dq = (q - z) * s;
                    let err = (wv - dq) / d;
                    // feedback into remaining columns: w[:, j+1:] -= err * U[j, j+1:]/U[j,j]
                    let urow = u.row(j);
                    let wrow = werr.row_mut(o);
                    for jj in j + 1..k {
                        wrow[jj] -= err * urow[jj];
                    }
                }
            }
        }

        QuantizedLinear {
            out_features: n,
            in_features: k,
            group_size,
            bits,
            codes,
            scale,
            zero,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CalibStats;
    use crate::quant::{hessian_error, Rtn};

    fn rand_w(n: usize, k: usize, seed: u64) -> Mat {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut w = Mat::zeros(n, k);
        for v in &mut w.data {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *v = ((state >> 11) as f32 / (1u64 << 53) as f32 - 0.5) * 0.3;
        }
        w
    }

    /// SPD Hessian with strong off-diagonal structure (correlated inputs).
    fn toy_hessian(k: usize, seed: u64) -> Mat {
        let x = rand_w(3 * k, k, seed); // [m, k] "activations"
        let mut h = Mat::zeros(k, k);
        for r in 0..x.rows {
            let row = x.row(r);
            for i in 0..k {
                for j in 0..k {
                    h[(i, j)] += row[i] * row[j];
                }
            }
        }
        for i in 0..k {
            h[(i, i)] += 0.01;
        }
        h
    }

    #[test]
    fn gptq_beats_rtn_on_hessian_error() {
        let k = 32;
        let w = rand_w(8, k, 11);
        let h = toy_hessian(k, 12);
        let stats = CalibStats { hessian: h.clone(), mean_abs: vec![1.0; k] };
        for bits in [2u8, 3] {
            let q_rtn = Rtn.quantize(&w, bits, 16, None);
            let q_gptq = Gptq::default().quantize(&w, bits, 16, Some(&stats));
            let e_rtn = hessian_error(&w, &q_rtn.dequant(), &h);
            let e_gptq = hessian_error(&w, &q_gptq.dequant(), &h);
            assert!(
                e_gptq < e_rtn,
                "bits={bits}: gptq {e_gptq} !< rtn {e_rtn}"
            );
        }
    }

    #[test]
    fn falls_back_to_rtn_without_stats() {
        let w = rand_w(4, 32, 13);
        let a = Gptq::default().quantize(&w, 3, 16, None);
        let b = Rtn.quantize(&w, 3, 16, None);
        assert_eq!(a.codes, b.codes);
    }

    #[test]
    fn codes_in_range() {
        let k = 32;
        let w = rand_w(4, k, 14);
        let h = toy_hessian(k, 15);
        let stats = CalibStats { hessian: h, mean_abs: vec![1.0; k] };
        let q = Gptq::default().quantize(&w, 2, 16, Some(&stats));
        assert!(q.codes.iter().all(|&c| c <= 3));
    }
}

//! HQQ — Half-Quadratic Quantization (Badri & Shaji, 2023): AMQ's proxy.
//!
//! Activation-independent: per group, the scale is fixed from the min/max
//! range and the *zero point* is optimized against a sparsity-promoting
//! lp-norm (p < 1) of the reconstruction error via half-quadratic splitting:
//!
//!   min_z  phi(W - s*(round(W/s + z) - z))        phi = |.|_p^p
//!
//! alternating (e-step) a generalized soft-threshold on the residual and
//! (z-step) a closed-form group mean.  This is what makes the quantization
//! proxy cheap: each layer is quantized once per bit-width, with no
//! activation data and no inter-layer dependencies.

use super::{affine_params, group_minmax, QuantizedLinear, Quantizer};
use crate::model::CalibStats;
use crate::tensor::Mat;

pub struct Hqq {
    pub iters: usize,
    pub p: f32,
    pub beta0: f32,
    pub kappa: f32,
}

impl Default for Hqq {
    fn default() -> Self {
        Hqq { iters: 20, p: 0.7, beta0: 10.0, kappa: 1.01 }
    }
}

/// Generalized soft-threshold — prox of (1/beta)*|x|_p^p for p < 1
/// (the HQQ paper's shrinkage operator):
/// `max(0, |x| - (p/beta)|x|^{p-1}) * sign(x)`.
#[inline]
fn shrink(x: f32, beta: f32, p: f32) -> f32 {
    let ax = x.abs();
    if ax < 1e-12 {
        return 0.0;
    }
    let mag = (ax - (p / beta) * ax.powf(p - 1.0)).max(0.0);
    mag * x.signum()
}

impl Quantizer for Hqq {
    fn name(&self) -> &'static str {
        "hqq"
    }

    fn quantize(
        &self,
        w: &Mat,
        bits: u8,
        group_size: usize,
        _stats: Option<&CalibStats>,
    ) -> QuantizedLinear {
        let (n, k) = (w.rows, w.cols);
        assert_eq!(k % group_size, 0);
        let g = k / group_size;
        let qmax = ((1u32 << bits) - 1) as f32;
        let mut codes = vec![0u8; n * k];
        let mut scale = vec![0f32; n * g];
        let mut zero = vec![0f32; n * g];

        let mut wq = vec![0f32; group_size];
        let mut e = vec![0f32; group_size];
        for o in 0..n {
            for gi in 0..g {
                let grp = &w.row(o)[gi * group_size..(gi + 1) * group_size];
                let (lo, hi) = group_minmax(grp);
                let (s, z0) = affine_params(lo, hi, bits);
                // start from the *rounded* zero (the RTN grid): at very low
                // bits an integer zero-point keeps an exact grid point at 0,
                // which dominates the lp objective for near-zero weights;
                // the half-quadratic iterations then refine from there.
                let mut z = z0.round();
                let mut beta = self.beta0;
                for _ in 0..self.iters {
                    // quantize with current zero
                    for (j, &v) in grp.iter().enumerate() {
                        wq[j] = (v / s + z).round().clamp(0.0, qmax);
                    }
                    // e-step: residual shrinkage
                    for (j, &v) in grp.iter().enumerate() {
                        let r = v - s * (wq[j] - z);
                        e[j] = shrink(r, beta, self.p);
                    }
                    // z-step: closed form group mean
                    let mut acc = 0.0f32;
                    for (j, &v) in grp.iter().enumerate() {
                        acc += wq[j] - (v - e[j]) / s;
                    }
                    z = acc / group_size as f32;
                    beta *= self.kappa;
                }
                scale[o * g + gi] = s;
                zero[o * g + gi] = z;
                for (j, &v) in grp.iter().enumerate() {
                    let q = (v / s + z).round().clamp(0.0, qmax);
                    codes[o * k + gi * group_size + j] = q as u8;
                }
            }
        }
        QuantizedLinear {
            out_features: n,
            in_features: k,
            group_size,
            bits,
            codes,
            scale,
            zero,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{frob_error, Rtn};

    fn rand_w(n: usize, k: usize, seed: u64) -> Mat {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut w = Mat::zeros(n, k);
        for v in &mut w.data {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            // heavy-ish tail: mix two scales so the lp objective matters
            let u = (state >> 11) as f32 / (1u64 << 53) as f32 - 0.5;
            *v = if state & 7 == 0 { u * 0.8 } else { u * 0.1 };
        }
        w
    }

    #[test]
    fn shrink_is_contraction() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let y = shrink(x, 10.0, 0.7);
            assert!(y.abs() <= x.abs() + 1e-7);
            assert!(y * x >= 0.0, "sign preserved");
        }
    }

    /// lp^p reconstruction error (HQQ's actual objective).
    fn lp_error(w: &Mat, q: &crate::quant::QuantizedLinear, p: f32) -> f64 {
        let dq = q.dequant();
        w.data
            .iter()
            .zip(&dq.data)
            .map(|(a, b)| ((a - b).abs() as f64).powf(p as f64))
            .sum()
    }

    #[test]
    fn hqq_beats_rtn_on_lp_objective() {
        let w = rand_w(16, 128, 5);
        for bits in [2u8, 3] {
            let p = Hqq::default().p;
            let e_rtn = lp_error(&w, &Rtn.quantize(&w, bits, 64, None), p);
            let e_hqq = lp_error(&w, &Hqq::default().quantize(&w, bits, 64, None), p);
            assert!(e_hqq <= e_rtn * 1.001, "bits={bits}: {e_hqq} vs {e_rtn}");
        }
    }

    #[test]
    fn hqq_l2_not_catastrophically_worse_than_rtn() {
        let w = rand_w(16, 128, 5);
        for bits in [2u8, 3] {
            let e_rtn = frob_error(&w, &Rtn.quantize(&w, bits, 64, None));
            let e_hqq = frob_error(&w, &Hqq::default().quantize(&w, bits, 64, None));
            // HQQ optimizes lp(0.7), not L2; it may trade some L2 error
            assert!(e_hqq <= e_rtn * 1.35, "bits={bits}: {e_hqq} vs {e_rtn}");
        }
    }

    #[test]
    fn codes_in_range() {
        let w = rand_w(8, 64, 6);
        for bits in [2u8, 3, 4] {
            let q = Hqq::default().quantize(&w, bits, 32, None);
            let max = (1i16 << bits) - 1;
            assert!(q.codes.iter().all(|&c| (c as i16) <= max));
        }
    }

    #[test]
    fn deterministic() {
        let w = rand_w(4, 64, 7);
        let a = Hqq::default().quantize(&w, 3, 64, None);
        let b = Hqq::default().quantize(&w, 3, 64, None);
        assert_eq!(a.codes, b.codes);
        assert_eq!(a.zero, b.zero);
    }
}

//! Weight-only quantizers: the proxy (HQQ), deploy-time comparators
//! (RTN, GPTQ, AWQ-clip) and the any-size baselines (BitStack, PB-LLM).
//!
//! All grouped quantizers emit the shared [`QuantizedLinear`] representation
//! (int8 codes + per-group f32 scale/zero along `in_features`) that the L1
//! Pallas kernel consumes; [`pack`] provides the physical 2/3/4-bit layouts
//! used for memory accounting and the CPU fallback path.

pub mod awq_clip;
pub mod bitstack;
pub mod gptq;
pub mod hqq;
pub mod pack;
pub mod pbllm;
pub mod registry;
pub mod rtn;

pub use awq_clip::AwqClip;
pub use bitstack::{BitStack, BitStackLayer};
pub use gptq::Gptq;
pub use hqq::Hqq;
pub use pbllm::PbLlm;
pub use registry::{MethodId, MethodRegistry};
pub use rtn::Rtn;

use crate::model::CalibStats;
use crate::tensor::Mat;

/// Per-group fp16 scale + fp16 zero -> 32 bits per group of weights.
/// With group size 128 this is the paper's +0.25 bits/weight overhead.
pub const GROUP_OVERHEAD_BITS: f64 = 32.0;

/// A grouped-quantized linear layer `W[out, in]`:
/// `W[o, g*gs+j] ≈ (codes[o, g*gs+j] - zero[o, g]) * scale[o, g]`.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub out_features: usize,
    pub in_features: usize,
    pub group_size: usize,
    pub bits: u8,
    pub codes: Vec<u8>,   // [out * in]
    pub scale: Vec<f32>,  // [out * groups]
    pub zero: Vec<f32>,   // [out * groups]
}

impl QuantizedLinear {
    pub fn n_groups(&self) -> usize {
        self.in_features / self.group_size
    }

    /// Reconstruct the f32 weight matrix.
    pub fn dequant(&self) -> Mat {
        let (n, k, gs) = (self.out_features, self.in_features, self.group_size);
        let g = self.n_groups();
        let mut w = Mat::zeros(n, k);
        for o in 0..n {
            for gi in 0..g {
                let s = self.scale[o * g + gi];
                let z = self.zero[o * g + gi];
                for j in 0..gs {
                    let idx = o * k + gi * gs + j;
                    w.data[idx] = (self.codes[idx] as f32 - z) * s;
                }
            }
        }
        w
    }

    /// Logical bits per weight including group metadata overhead.
    pub fn bits_per_weight(&self) -> f64 {
        self.bits as f64 + GROUP_OVERHEAD_BITS / self.group_size as f64
    }

    /// Memory in bytes (packed codes + fp16 scale/zero per group).
    pub fn memory_bytes(&self) -> usize {
        pack::packed_bytes(self.out_features * self.in_features, self.bits)
            + self.n_groups() * self.out_features * 4
    }
}

/// Frobenius reconstruction error ||W - Wq||_F.
pub fn frob_error(w: &Mat, q: &QuantizedLinear) -> f32 {
    let dq = q.dequant();
    debug_assert_eq!(w.rows, dq.rows);
    w.data
        .iter()
        .zip(&dq.data)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        .sqrt()
}

/// Hessian-weighted output error  tr(ΔW H ΔW^T)  — the calibration-aware
/// proxy for E ||(W - Wq) x||^2 (used by AWQ-clip and ablations).
pub fn hessian_error(w: &Mat, dq: &Mat, h: &Mat) -> f64 {
    let n = w.rows;
    let k = w.cols;
    debug_assert_eq!(h.rows, k);
    let mut total = 0.0f64;
    let mut delta = vec![0.0f32; k];
    for o in 0..n {
        let wr = w.row(o);
        let qr = dq.row(o);
        for j in 0..k {
            delta[j] = wr[j] - qr[j];
        }
        // delta^T H delta
        let mut acc = 0.0f64;
        for i in 0..k {
            let di = delta[i];
            if di == 0.0 {
                continue;
            }
            let hrow = h.row(i);
            let mut s = 0.0f32;
            for j in 0..k {
                s += hrow[j] * delta[j];
            }
            acc += (di * s) as f64;
        }
        total += acc;
    }
    total
}

/// A grouped weight-only quantizer (one layer at a time).
pub trait Quantizer {
    fn name(&self) -> &'static str;

    /// Quantize `w` to `bits` with the layer's calibration stats (may be
    /// ignored by activation-independent methods).
    fn quantize(
        &self,
        w: &Mat,
        bits: u8,
        group_size: usize,
        stats: Option<&CalibStats>,
    ) -> QuantizedLinear;
}

/// Group-wise min/max affine parameters used by RTN/HQQ/AWQ starts.
pub(crate) fn group_minmax(w: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in w {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || !hi.is_finite() {
        (0.0, 0.0)
    } else {
        (lo, hi)
    }
}

/// Affine (scale, zero) for an asymmetric range [lo, hi] at `bits`.
pub(crate) fn affine_params(lo: f32, hi: f32, bits: u8) -> (f32, f32) {
    let qmax = ((1u32 << bits) - 1) as f32;
    let scale = ((hi - lo) / qmax).max(1e-8);
    let zero = -lo / scale;
    (scale, zero)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_w() -> Mat {
        let mut w = Mat::zeros(4, 8);
        for (i, v) in w.data.iter_mut().enumerate() {
            *v = ((i as f32) * 0.37).sin() * 0.1;
        }
        w
    }

    #[test]
    fn dequant_roundtrip_exact_codes() {
        let q = QuantizedLinear {
            out_features: 2,
            in_features: 4,
            group_size: 2,
            bits: 2,
            codes: vec![0u8, 1, 2, 3, 3, 2, 1, 0],
            scale: vec![0.5, 1.0, 0.25, 2.0],
            zero: vec![1.0, 0.0, 2.0, 3.0],
        };
        let w = q.dequant();
        assert_eq!(w[(0, 0)], (0.0 - 1.0) * 0.5);
        assert_eq!(w[(0, 2)], 2.0 * 1.0);
        assert_eq!(w[(1, 3)], (0.0 - 3.0) * 2.0);
    }

    #[test]
    fn bits_accounting() {
        let q = QuantizedLinear {
            out_features: 1,
            in_features: 128,
            group_size: 128,
            bits: 3,
            codes: vec![0; 128],
            scale: vec![1.0],
            zero: vec![0.0],
        };
        assert!((q.bits_per_weight() - 3.25).abs() < 1e-9);
    }

    #[test]
    fn hessian_error_identity_matches_frobenius() {
        let w = toy_w();
        let q = Rtn.quantize(&w, 3, 4, None);
        let h = Mat::eye(8);
        let he = hessian_error(&w, &q.dequant(), &h);
        let fe = frob_error(&w, &q) as f64;
        assert!((he - fe * fe).abs() < 1e-6, "{he} vs {}", fe * fe);
    }

    #[test]
    fn affine_params_cover_range() {
        let (s, z) = affine_params(-1.0, 1.0, 2);
        // code 0 -> -1.0, code 3 -> 1.0
        assert!(((0.0 - z) * s - -1.0).abs() < 1e-6);
        assert!(((3.0 - z) * s - 1.0).abs() < 1e-6);
    }
}

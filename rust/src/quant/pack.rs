//! Physical bit-packing for 2/3/4-bit codes.
//!
//! The AOT executables consume int8 codes (the logical representation); the
//! packed layouts here are what a deployment kernel would stream, and they
//! drive the memory accounting in the cost model and the tables.  3-bit uses
//! the AutoGPTQ-style layout: 32 codes packed into three u32 words.

/// Bytes needed to store `n` codes at `bits` (2, 3, 4 or 8).
pub fn packed_bytes(n: usize, bits: u8) -> usize {
    match bits {
        2 => n.div_ceil(4),
        3 => n.div_ceil(32) * 12, // 32 codes -> 3 u32 words
        4 => n.div_ceil(2),
        8 => n,
        other => panic!("unsupported bit width {other}"),
    }
}

/// Pack codes (< 2^bits each) into the physical layout.
pub fn pack(codes: &[u8], bits: u8) -> Vec<u8> {
    let n = codes.len();
    match bits {
        2 => {
            let mut out = vec![0u8; packed_bytes(n, 2)];
            for (i, &c) in codes.iter().enumerate() {
                debug_assert!(c < 4);
                out[i / 4] |= (c & 0b11) << ((i % 4) * 2);
            }
            out
        }
        4 => {
            let mut out = vec![0u8; packed_bytes(n, 4)];
            for (i, &c) in codes.iter().enumerate() {
                debug_assert!(c < 16);
                out[i / 2] |= (c & 0b1111) << ((i % 2) * 4);
            }
            out
        }
        3 => {
            // 32 3-bit codes in 96 bits = three u32 little-endian words.
            let mut out = vec![0u8; packed_bytes(n, 3)];
            for (i, &c) in codes.iter().enumerate() {
                debug_assert!(c < 8);
                let block = i / 32;
                let pos = (i % 32) * 3; // bit position within the 96-bit block
                let base = block * 12;
                let byte = base + pos / 8;
                let shift = pos % 8;
                let v = (c as u16 & 0b111) << shift;
                out[byte] |= (v & 0xFF) as u8;
                if shift > 5 {
                    out[byte + 1] |= (v >> 8) as u8;
                }
            }
            out
        }
        8 => codes.to_vec(),
        other => panic!("unsupported bit width {other}"),
    }
}

/// Unpack back to int8 codes (inverse of [`pack`]).
pub fn unpack(data: &[u8], bits: u8, n: usize) -> Vec<u8> {
    match bits {
        2 => (0..n)
            .map(|i| (data[i / 4] >> ((i % 4) * 2)) & 0b11)
            .collect(),
        4 => (0..n)
            .map(|i| (data[i / 2] >> ((i % 2) * 4)) & 0b1111)
            .collect(),
        3 => (0..n)
            .map(|i| {
                let block = i / 32;
                let pos = (i % 32) * 3;
                let base = block * 12;
                let byte = base + pos / 8;
                let shift = pos % 8;
                let mut v = (data[byte] as u16) >> shift;
                if shift > 5 {
                    v |= (data[byte + 1] as u16) << (8 - shift);
                }
                (v & 0b111) as u8
            })
            .collect(),
        8 => data.to_vec(),
        other => panic!("unsupported bit width {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(n: usize, bits: u8, seed: u64) -> Vec<u8> {
        let mut state = seed | 1;
        (0..n)
            .map(|_| {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                (state % (1 << bits)) as u8
            })
            .collect()
    }

    #[test]
    fn roundtrip_all_widths() {
        for bits in [2u8, 3, 4, 8] {
            for n in [1usize, 7, 32, 33, 100, 1024] {
                let c = codes(n, bits, (bits as u64) * 1000 + n as u64);
                let packed = pack(&c, bits);
                assert_eq!(packed.len(), packed_bytes(n, bits));
                assert_eq!(unpack(&packed, bits, n), c, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn sizes() {
        assert_eq!(packed_bytes(128, 2), 32);
        assert_eq!(packed_bytes(128, 3), 48);
        assert_eq!(packed_bytes(128, 4), 64);
        assert_eq!(packed_bytes(128, 8), 128);
        // 3-bit rounds up to whole 32-code blocks
        assert_eq!(packed_bytes(33, 3), 24);
    }

    #[test]
    fn density_matches_bits() {
        // per-weight storage converges to bits/8 bytes
        let n = 1 << 16;
        for bits in [2u8, 3, 4] {
            let bytes = packed_bytes(n, bits) as f64;
            let per = bytes * 8.0 / n as f64;
            assert!((per - bits as f64).abs() < 0.01, "bits={bits} per={per}");
        }
    }
}

//! PB-LLM (Shang et al., 2023) — partial binarization baseline.
//!
//! A salient fraction ρ of weights (ranked by the diagonal-Hessian-weighted
//! magnitude h_jj * w^2, falling back to |w|) is kept in 8-bit grouped RTN;
//! the remaining (1-ρ) are binarized per group to  sign(w) * E|w|.
//! Memory ≈ ρ*8 + (1-ρ)*1 bits per weight (the paper's accounting: weight
//! memory only, index overhead excluded — matching our Table 1 analog).

use super::rtn::quantize_rtn;
use crate::model::CalibStats;
use crate::tensor::Mat;

pub struct PbLlm {
    /// Salient fraction kept at 8-bit.
    pub rho: f32,
    pub group_size: usize,
}

pub struct PbLlmLayer {
    pub rows: usize,
    pub cols: usize,
    pub rho: f32,
    pub group_size: usize,
    dequant: Mat,
}

impl PbLlm {
    pub fn new(rho: f32, group_size: usize) -> Self {
        assert!((0.0..=1.0).contains(&rho));
        PbLlm { rho, group_size }
    }

    /// Average bits per weight for a given salient fraction.
    pub fn bits_per_weight(rho: f32) -> f64 {
        (rho as f64) * 8.0 + (1.0 - rho as f64) * 1.0
    }

    pub fn quantize(&self, w: &Mat, stats: Option<&CalibStats>) -> PbLlmLayer {
        let (n, k) = (w.rows, w.cols);
        let gs = self.group_size.min(k);
        // salience = h_jj * w^2 (sensitivity of the output to this weight)
        let mut sal: Vec<(f32, usize)> = Vec::with_capacity(n * k);
        for o in 0..n {
            for j in 0..k {
                let h = stats
                    .map(|s| s.hessian[(j, j)].max(1e-12))
                    .unwrap_or(1.0);
                let v = w[(o, j)];
                sal.push((h * v * v, o * k + j));
            }
        }
        let n_salient = ((n * k) as f32 * self.rho).round() as usize;
        sal.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut salient = vec![false; n * k];
        for &(_, idx) in sal.iter().take(n_salient) {
            salient[idx] = true;
        }

        // 8-bit RTN of the full matrix (salient entries copy from here).
        let q8 = quantize_rtn(w, 8, gs, 1.0);
        let dq8 = q8.dequant();

        // binarize the rest per group: sign(w) * mean|w| over non-salient
        let mut dequant = Mat::zeros(n, k);
        let g = k / gs;
        for o in 0..n {
            for gi in 0..g {
                let mut sum = 0.0f32;
                let mut cnt = 0usize;
                for j in gi * gs..(gi + 1) * gs {
                    let idx = o * k + j;
                    if !salient[idx] {
                        sum += w.data[idx].abs();
                        cnt += 1;
                    }
                }
                let alpha = if cnt > 0 { sum / cnt as f32 } else { 0.0 };
                for j in gi * gs..(gi + 1) * gs {
                    let idx = o * k + j;
                    dequant.data[idx] = if salient[idx] {
                        dq8.data[idx]
                    } else {
                        alpha * w.data[idx].signum()
                    };
                }
            }
        }
        PbLlmLayer { rows: n, cols: k, rho: self.rho, group_size: gs, dequant }
    }
}

impl PbLlmLayer {
    pub fn dequant(&self) -> &Mat {
        &self.dequant
    }

    /// Weight-memory bytes (paper accounting: codes + group scales).
    pub fn memory_bytes(&self) -> usize {
        let n_w = self.rows * self.cols;
        let bits = PbLlm::bits_per_weight(self.rho);
        let code_bytes = (n_w as f64 * bits / 8.0).ceil() as usize;
        let groups = self.rows * (self.cols / self.group_size);
        code_bytes + groups * 4 // fp16 scale+zero / fp16 alpha per group
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_w(n: usize, k: usize, seed: u64) -> Mat {
        let mut state = seed | 1;
        let mut w = Mat::zeros(n, k);
        for v in &mut w.data {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            let u = (state >> 11) as f32 / (1u64 << 53) as f32 - 0.5;
            *v = if state & 15 == 0 { u } else { u * 0.1 };
        }
        w
    }

    fn err(w: &Mat, dq: &Mat) -> f32 {
        w.data
            .iter()
            .zip(&dq.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }

    #[test]
    fn higher_rho_lower_error() {
        let w = rand_w(16, 64, 41);
        let e1 = err(&w, PbLlm::new(0.05, 32).quantize(&w, None).dequant());
        let e2 = err(&w, PbLlm::new(0.3, 32).quantize(&w, None).dequant());
        let e3 = err(&w, PbLlm::new(0.8, 32).quantize(&w, None).dequant());
        assert!(e1 > e2 && e2 > e3, "{e1} {e2} {e3}");
    }

    #[test]
    fn bits_accounting() {
        assert!((PbLlm::bits_per_weight(0.0) - 1.0).abs() < 1e-9);
        assert!((PbLlm::bits_per_weight(1.0) - 8.0).abs() < 1e-9);
        let b = PbLlm::bits_per_weight(0.2);
        assert!((b - (0.2 * 8.0 + 0.8)).abs() < 1e-6);
    }

    #[test]
    fn rho_one_matches_8bit_rtn() {
        let w = rand_w(8, 32, 42);
        let dq = PbLlm::new(1.0, 32).quantize(&w, None);
        let q8 = quantize_rtn(&w, 8, 32, 1.0).dequant();
        for (a, b) in dq.dequant().data.iter().zip(&q8.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn binarized_part_uses_sign() {
        let w = rand_w(4, 32, 43);
        let layer = PbLlm::new(0.0, 32).quantize(&w, None);
        for (a, b) in layer.dequant().data.iter().zip(&w.data) {
            if *b != 0.0 {
                assert!(a.signum() == b.signum() || *a == 0.0);
            }
        }
    }
}

//! Quantization-method registry: names, parses and constructs every grouped
//! quantizer the search genome can assign to a layer.
//!
//! The genome (see [`crate::coordinator::space`]) stores a [`MethodId`] next
//! to the bit-width in every per-layer gene, so the *method* is a searched
//! axis exactly like the precision.  The registry is the single source of
//! truth for method identity: stable indices (the gene encoding), display
//! names (CLI / manifest / reports), construction of the `dyn Quantizer`,
//! and per-method accounting metadata.

use super::{AwqClip, Gptq, Hqq, Quantizer, Rtn, GROUP_OVERHEAD_BITS};
use crate::Result;

/// A registered grouped weight-only quantization method.
///
/// The discriminants are the *stable* gene encoding (high byte of a packed
/// gene) — append new methods, never renumber, or serialized archives stop
/// round-tripping.  Index 0 must stay the activation-independent proxy
/// (HQQ) so single-method genes are numerically identical to the legacy
/// bits-only genome.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MethodId {
    Hqq = 0,
    Rtn = 1,
    Gptq = 2,
    AwqClip = 3,
}

impl MethodId {
    /// All registered methods, in stable index order.
    pub const ALL: [MethodId; 4] = [
        MethodId::Hqq,
        MethodId::Rtn,
        MethodId::Gptq,
        MethodId::AwqClip,
    ];

    /// Stable numeric index (the gene encoding).
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Option<MethodId> {
        MethodId::ALL.get(i).copied()
    }

    pub fn name(self) -> &'static str {
        match self {
            MethodId::Hqq => "hqq",
            MethodId::Rtn => "rtn",
            MethodId::Gptq => "gptq",
            MethodId::AwqClip => "awq_clip",
        }
    }

    /// Parse a CLI / manifest method name ("awq" aliases "awq_clip").
    pub fn parse(s: &str) -> Result<MethodId> {
        match s.trim() {
            "hqq" => Ok(MethodId::Hqq),
            "rtn" => Ok(MethodId::Rtn),
            "gptq" => Ok(MethodId::Gptq),
            "awq" | "awq_clip" => Ok(MethodId::AwqClip),
            other => eyre::bail!(
                "unknown quantization method `{other}` (available: {})",
                MethodId::ALL.map(|m| m.name()).join(", ")
            ),
        }
    }

    /// Construct the quantizer.
    pub fn build(self) -> Box<dyn Quantizer> {
        match self {
            MethodId::Hqq => Box::new(Hqq::default()),
            MethodId::Rtn => Box::new(Rtn),
            MethodId::Gptq => Box::new(Gptq::default()),
            MethodId::AwqClip => Box::new(AwqClip::default()),
        }
    }

    /// Whether `quantize()` consumes calibration statistics (Hessian
    /// diagonals); activation-independent methods ignore them.
    pub fn needs_stats(self) -> bool {
        matches!(self, MethodId::Gptq | MethodId::AwqClip)
    }

    /// Per-group metadata overhead in bits (fp16 scale + fp16 zero for all
    /// currently registered grouped methods).  The search-space objectives
    /// consult this per gene, so a future method with different metadata
    /// geometry is accounted correctly without touching the objectives.
    pub fn group_overhead_bits(self) -> f64 {
        GROUP_OVERHEAD_BITS
    }
}

/// An ordered set of *enabled* methods (manifest- or CLI-driven).
///
/// Order is user-facing only (reports, bank slots); the gene encoding uses
/// the stable [`MethodId`] index, never the position in this list.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MethodRegistry {
    enabled: Vec<MethodId>,
}

impl Default for MethodRegistry {
    /// The single-method default: the HQQ proxy, i.e. the legacy genome.
    fn default() -> Self {
        MethodRegistry { enabled: vec![MethodId::Hqq] }
    }
}

impl MethodRegistry {
    /// Build from an explicit list; deduplicates, preserves first-seen
    /// order, rejects an empty result.
    pub fn new(methods: &[MethodId]) -> Result<MethodRegistry> {
        let mut enabled: Vec<MethodId> = Vec::new();
        for &m in methods {
            if !enabled.contains(&m) {
                enabled.push(m);
            }
        }
        eyre::ensure!(!enabled.is_empty(), "method registry cannot be empty");
        Ok(MethodRegistry { enabled })
    }

    /// Parse a comma-separated enable list, e.g. `"hqq,rtn,gptq"`.
    pub fn parse(list: &str) -> Result<MethodRegistry> {
        let methods = list
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(MethodId::parse)
            .collect::<Result<Vec<_>>>()?;
        Self::new(&methods)
    }

    /// Build from manifest-style names, warning on (and skipping) unknown
    /// entries; falls back to the default when nothing parses.  Infallible
    /// so `SearchSpace::full` stays infallible.
    pub fn from_names(names: &[String]) -> MethodRegistry {
        let mut methods = Vec::new();
        for n in names {
            match MethodId::parse(n) {
                Ok(m) => methods.push(m),
                Err(e) => eprintln!("[registry] skipping manifest method: {e}"),
            }
        }
        Self::new(&methods).unwrap_or_default()
    }

    pub fn enabled(&self) -> &[MethodId] {
        &self.enabled
    }

    pub fn len(&self) -> usize {
        self.enabled.len()
    }

    pub fn is_empty(&self) -> bool {
        self.enabled.is_empty()
    }

    pub fn contains(&self, m: MethodId) -> bool {
        self.enabled.contains(&m)
    }

    /// The one enabled method, when exactly one is enabled.
    pub fn single(&self) -> Option<MethodId> {
        match self.enabled.as_slice() {
            [m] => Some(*m),
            _ => None,
        }
    }

    /// Whether any enabled method consumes calibration statistics.
    pub fn any_needs_stats(&self) -> bool {
        self.enabled.iter().any(|m| m.needs_stats())
    }

    /// Display names in enable order.
    pub fn names(&self) -> Vec<&'static str> {
        self.enabled.iter().map(|m| m.name()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_are_stable() {
        for (i, m) in MethodId::ALL.iter().enumerate() {
            assert_eq!(m.index(), i);
            assert_eq!(MethodId::from_index(i), Some(*m));
        }
        assert_eq!(MethodId::from_index(MethodId::ALL.len()), None);
        // index 0 is the legacy single-method proxy — load-bearing for the
        // bits-only genome compatibility
        assert_eq!(MethodId::from_index(0), Some(MethodId::Hqq));
    }

    #[test]
    fn parse_roundtrip_and_aliases() {
        for m in MethodId::ALL {
            assert_eq!(MethodId::parse(m.name()).unwrap(), m);
        }
        assert_eq!(MethodId::parse("awq").unwrap(), MethodId::AwqClip);
        assert!(MethodId::parse("nope").is_err());
    }

    #[test]
    fn registry_parse_dedups_and_orders() {
        let r = MethodRegistry::parse("rtn,hqq,rtn").unwrap();
        assert_eq!(r.enabled(), &[MethodId::Rtn, MethodId::Hqq]);
        assert_eq!(r.len(), 2);
        assert!(r.single().is_none());
        assert!(MethodRegistry::parse("").is_err());
        assert!(MethodRegistry::parse("hqq,bogus").is_err());
    }

    #[test]
    fn default_is_single_hqq() {
        let r = MethodRegistry::default();
        assert_eq!(r.single(), Some(MethodId::Hqq));
        assert!(!r.any_needs_stats());
        let multi = MethodRegistry::parse("hqq,gptq").unwrap();
        assert!(multi.any_needs_stats());
    }

    #[test]
    fn from_names_skips_unknown_and_falls_back() {
        let r = MethodRegistry::from_names(&["rtn".into(), "bogus".into()]);
        assert_eq!(r.enabled(), &[MethodId::Rtn]);
        let r = MethodRegistry::from_names(&["bogus".into()]);
        assert_eq!(r.single(), Some(MethodId::Hqq));
        let r = MethodRegistry::from_names(&[]);
        assert_eq!(r.single(), Some(MethodId::Hqq));
    }

    #[test]
    fn builders_construct_named_quantizers() {
        for m in MethodId::ALL {
            assert_eq!(m.build().name(), m.name());
        }
    }
}

//! RTN (round-to-nearest) grouped quantization — the simplest baseline and
//! the starting point for the AWQ-style clip search.

use super::{affine_params, group_minmax, QuantizedLinear, Quantizer};
use crate::model::CalibStats;
use crate::tensor::Mat;

pub struct Rtn;

impl Quantizer for Rtn {
    fn name(&self) -> &'static str {
        "rtn"
    }

    fn quantize(
        &self,
        w: &Mat,
        bits: u8,
        group_size: usize,
        _stats: Option<&CalibStats>,
    ) -> QuantizedLinear {
        quantize_rtn(w, bits, group_size, 1.0)
    }
}

/// RTN with a symmetric range-shrink factor `clip` (1.0 = full range).
pub fn quantize_rtn(w: &Mat, bits: u8, group_size: usize, clip: f32) -> QuantizedLinear {
    let (n, k) = (w.rows, w.cols);
    assert_eq!(k % group_size, 0, "in_features % group_size != 0");
    let g = k / group_size;
    let qmax = ((1u32 << bits) - 1) as f32;
    let mut codes = vec![0u8; n * k];
    let mut scale = vec![0f32; n * g];
    let mut zero = vec![0f32; n * g];
    for o in 0..n {
        for gi in 0..g {
            let grp = &w.row(o)[gi * group_size..(gi + 1) * group_size];
            let (lo, hi) = group_minmax(grp);
            let mid = 0.5 * (lo + hi);
            let (lo, hi) = (mid + (lo - mid) * clip, mid + (hi - mid) * clip);
            let (s, z) = affine_params(lo, hi, bits);
            let zr = z.round();
            scale[o * g + gi] = s;
            zero[o * g + gi] = zr;
            for (j, &v) in grp.iter().enumerate() {
                let q = (v / s + zr).round().clamp(0.0, qmax);
                codes[o * k + gi * group_size + j] = q as u8;
            }
        }
    }
    QuantizedLinear {
        out_features: n,
        in_features: k,
        group_size,
        bits,
        codes,
        scale,
        zero,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::frob_error;

    fn rand_w(n: usize, k: usize, seed: u64) -> Mat {
        // simple xorshift-based deterministic pseudo-random weights
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut w = Mat::zeros(n, k);
        for v in &mut w.data {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *v = ((state >> 11) as f32 / (1u64 << 53) as f32 - 0.5) * 0.2;
        }
        w
    }

    #[test]
    fn codes_in_range() {
        let w = rand_w(8, 64, 1);
        for bits in [2u8, 3, 4] {
            let q = Rtn.quantize(&w, bits, 32, None);
            let max = (1i16 << bits) - 1;
            assert!(q.codes.iter().all(|&c| (c as i16) <= max));
        }
    }

    #[test]
    fn error_decreases_with_bits() {
        let w = rand_w(16, 128, 2);
        let e2 = frob_error(&w, &Rtn.quantize(&w, 2, 64, None));
        let e3 = frob_error(&w, &Rtn.quantize(&w, 3, 64, None));
        let e4 = frob_error(&w, &Rtn.quantize(&w, 4, 64, None));
        assert!(e2 > e3 && e3 > e4, "{e2} {e3} {e4}");
    }

    #[test]
    fn four_bit_relative_error_reasonable() {
        let w = rand_w(16, 128, 3);
        let q = Rtn.quantize(&w, 4, 64, None);
        let rel = frob_error(&w, &q) / w.frob_norm();
        // uniform weights, 16 levels: expected rel err ~ step/range ~ 0.067
        assert!(rel < 0.08, "rel err {rel}");
    }

    #[test]
    fn constant_group_is_exact() {
        let w = Mat::from_vec(1, 4, vec![0.3; 4]);
        let q = Rtn.quantize(&w, 2, 4, None);
        let dq = q.dequant();
        for v in &dq.data {
            assert!((v - 0.3).abs() < 1e-3);
        }
    }
}

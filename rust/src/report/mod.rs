//! Aligned text tables + CSV dumps for the experiment harnesses.

use std::fmt::Write as _;
use std::path::Path;

/// A simple table: headers + string rows, printed aligned and dumpable as CSV.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let headers = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>();
        let _ = writeln!(s, "{}", headers.join(","));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        std::fs::write(path, s)
    }
}

/// Format helper: f32 with fixed decimals, NaN as "-".
pub fn fmt(v: f32, decimals: usize) -> String {
    if v.is_nan() {
        "-".to_string()
    } else if v.abs() >= 1e4 {
        format!("{v:.2e}")
    } else {
        format!("{v:.decimals$}")
    }
}

/// Format helper for probes that may come up empty (e.g. `best_under` on
/// a budget no archive sample satisfies): `None` renders as the same "-"
/// placeholder [`fmt`] uses for NaN, so tables skip the cell instead of
/// forcing callers to unwrap.
pub fn fmt_opt(v: Option<f32>, decimals: usize) -> String {
    match v {
        Some(v) => fmt(v, decimals),
        None => "-".to_string(),
    }
}

/// Write a simple series CSV (figure data): (x, multiple named ys).
pub fn series_csv(path: &Path, xname: &str, ynames: &[&str],
                  rows: &[(f32, Vec<f32>)]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut s = String::new();
    let _ = writeln!(s, "{xname},{}", ynames.join(","));
    for (x, ys) in rows {
        let yy: Vec<String> = ys.iter().map(|y| format!("{y}")).collect();
        let _ = writeln!(s, "{x},{}", yy.join(","));
    }
    std::fs::write(path, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("t", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("long_header"));
        assert!(r.lines().count() >= 3);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_handles_extremes() {
        assert_eq!(fmt(f32::NAN, 2), "-");
        assert_eq!(fmt(1.2345, 2), "1.23");
        assert!(fmt(2.2e5, 2).contains('e'));
    }

    #[test]
    fn fmt_opt_matches_fmt_on_some() {
        assert_eq!(fmt_opt(Some(1.2345), 2), fmt(1.2345, 2));
        assert_eq!(fmt_opt(None, 2), "-");
        assert_eq!(fmt_opt(Some(f32::NAN), 2), "-");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("t", &["a,b", "c"]);
        t.row(vec!["x\"y".into(), "z".into()]);
        let dir = std::env::temp_dir().join("amq_report_test");
        let path = dir.join("t.csv");
        t.to_csv(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.starts_with("\"a,b\",c"));
        assert!(s.contains("\"x\"\"y\""));
    }
}

//! Deterministic fault injection for the eval pool — the seeded,
//! replayable layer every straggler/crash scenario in the chaos tests and
//! CI is built on.  No timing-dependent failure simulation anywhere: which
//! chunk faults is a pure function of `(seed, decision index)`, so a
//! failing run replays exactly from its spec string.
//!
//! A [`FaultSpec`] is parsed from `SEED:KIND:RATE` (the `repro shard-serve
//! --fault-spec` syntax) and compiled into a [`FaultPlan`], which is
//! injectable at three levels:
//!
//!  * **local shard flows** — [`FaultPlan::wrap_flow`] wraps the closure an
//!    [`crate::runtime::EvalService`] shard runs;
//!  * **remote feeders** — `RemoteShard::with_fault_plan` perturbs the
//!    client side of a TCP shard connection;
//!  * **shard servers** — `serve_shard_with_faults` perturbs the server's
//!    chunk handling (`repro shard-serve --fault-spec`), which is how CI
//!    wedges a *real process* deterministically.
//!
//! Fault kinds ([`FaultKind`]):
//!
//!  * `delay` — sleep [`FaultPlan::with_delay`] before evaluating (a slow
//!    shard / straggler);
//!  * `wedge` — block on an internal gate until [`FaultPlan::release_wedges`]
//!    (a hung shard: the canonical hedging scenario.  In-process tests MUST
//!    release before dropping the service, whose `Drop` joins workers);
//!  * `drop` — the chunk's reply is lost (local flows retire; servers
//!    swallow the reply so the client's read times out);
//!  * `disconnect` — the transport dies (local flows retire; servers close
//!    the connection after the eval).
//!
//! Faults are injected *around* evaluations, never inside them: evaluation
//! results stay pure functions of the payload, which is what lets the chaos
//! tests pin archive `content_hash` equality under every fault mix.

use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::ShardFlow;
use crate::util::Rng;

/// Default sleep for [`FaultKind::Delay`] faults — long enough to register
/// as a straggler against micro-eval p50s, short enough for tight tests.
pub const DEFAULT_FAULT_DELAY: Duration = Duration::from_millis(30);

/// What a triggered fault does to the chunk it hits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Sleep before evaluating (straggler).
    Delay,
    /// Block on the plan's gate until [`FaultPlan::release_wedges`] (hang).
    Wedge,
    /// Lose the reply: local flows retire, servers never answer the chunk.
    Drop,
    /// Kill the transport: local flows retire, servers close the connection.
    Disconnect,
}

impl FaultKind {
    /// Parse the `KIND` field of a `--fault-spec` (case-insensitive).
    pub fn parse(s: &str) -> crate::Result<FaultKind> {
        match s.to_ascii_lowercase().as_str() {
            "delay" => Ok(FaultKind::Delay),
            "wedge" => Ok(FaultKind::Wedge),
            "drop" => Ok(FaultKind::Drop),
            "disconnect" => Ok(FaultKind::Disconnect),
            other => Err(eyre::anyhow!(
                "unknown fault kind `{other}` (expected delay|wedge|drop|disconnect)"
            )),
        }
    }

    /// The spec-string name of this kind.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Delay => "delay",
            FaultKind::Wedge => "wedge",
            FaultKind::Drop => "drop",
            FaultKind::Disconnect => "disconnect",
        }
    }
}

/// Parsed `SEED:KIND:RATE` fault spec (e.g. `7:wedge:1.0`): which kind of
/// fault to inject, how often, and the seed that makes every decision
/// replayable.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    /// Seed for the per-decision RNG — same seed, same fault sequence.
    pub seed: u64,
    /// What a triggered fault does.
    pub kind: FaultKind,
    /// Probability in `[0, 1]` that any given decision triggers.
    pub rate: f64,
}

impl FaultSpec {
    /// Parse `SEED:KIND:RATE`, validating each field.
    pub fn parse(s: &str) -> crate::Result<FaultSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 3 {
            eyre::bail!("fault spec `{s}` is not SEED:KIND:RATE (e.g. 7:wedge:1.0)");
        }
        let seed: u64 = parts[0]
            .parse()
            .map_err(|_| eyre::anyhow!("fault spec seed `{}` is not a u64", parts[0]))?;
        let kind = FaultKind::parse(parts[1])?;
        let rate: f64 = parts[2]
            .parse()
            .map_err(|_| eyre::anyhow!("fault spec rate `{}` is not a float", parts[2]))?;
        if !(0.0..=1.0).contains(&rate) {
            eyre::bail!("fault spec rate {rate} must be within [0, 1]");
        }
        Ok(FaultSpec { seed, kind, rate })
    }

    /// Compile into an injectable plan.
    pub fn plan(&self) -> FaultPlan {
        FaultPlan::new(*self)
    }

    /// Render back to the `SEED:KIND:RATE` string (replay instructions).
    pub fn to_spec_string(&self) -> String {
        format!("{}:{}:{}", self.seed, self.kind.name(), self.rate)
    }
}

/// Decision counters behind the plan's lock.
#[derive(Default)]
struct PlanState {
    /// Decisions made so far — the index into the seeded sequence.
    decisions: u64,
    /// Decisions that triggered a fault.
    injected: u64,
}

/// A compiled, seeded fault sequence.  Every call site that *could* fault
/// asks [`FaultPlan::decide`]; decision `k` triggers iff
/// `Rng::new(seed ^ mix(k)).f64() < rate`, so the fault pattern is a pure
/// function of the spec and the decision order — independent of wall-clock,
/// scheduling, or machine.
///
/// Wedge gate: all `Wedge` faults block on one internal gate until
/// [`FaultPlan::release_wedges`] opens it (idempotent, and permanent — once
/// released, later wedge decisions pass straight through).  In-process
/// tests must release before dropping the `EvalService`, whose `Drop` joins
/// worker threads.
pub struct FaultPlan {
    spec: FaultSpec,
    delay: Duration,
    /// Stop injecting after this many faults (`None` = unbounded).  The
    /// deterministic-single-crash knob for tests.
    max_faults: Option<u64>,
    state: Mutex<PlanState>,
    wedge_open: Mutex<bool>,
    wedge_cv: Condvar,
}

impl FaultPlan {
    /// Plan from a spec, with the default delay and no fault cap.
    pub fn new(spec: FaultSpec) -> FaultPlan {
        FaultPlan {
            spec,
            delay: DEFAULT_FAULT_DELAY,
            max_faults: None,
            state: Mutex::new(PlanState::default()),
            wedge_open: Mutex::new(false),
            wedge_cv: Condvar::new(),
        }
    }

    /// Override the sleep applied by [`FaultKind::Delay`] faults.
    pub fn with_delay(mut self, delay: Duration) -> Self {
        self.delay = delay;
        self
    }

    /// Cap the number of injected faults (e.g. 1 = exactly one
    /// deterministic crash, every later decision passes clean).
    pub fn with_max_faults(mut self, n: u64) -> Self {
        self.max_faults = Some(n);
        self
    }

    /// The spec this plan was compiled from.
    pub fn spec(&self) -> FaultSpec {
        self.spec
    }

    /// The sleep applied by delay faults.
    pub fn delay(&self) -> Duration {
        self.delay
    }

    /// Decisions made so far.
    pub fn decisions(&self) -> u64 {
        self.state.lock().unwrap().decisions
    }

    /// Faults actually injected so far.
    pub fn injected(&self) -> u64 {
        self.state.lock().unwrap().injected
    }

    /// One seeded decision: `Some(kind)` if this call site should fault.
    /// Decision `k` of a plan is the same everywhere, every run.
    pub fn decide(&self) -> Option<FaultKind> {
        let mut st = self.state.lock().unwrap();
        let k = st.decisions;
        st.decisions += 1;
        if let Some(max) = self.max_faults {
            if st.injected >= max {
                return None;
            }
        }
        // Fresh RNG per decision index: the sequence is random-access, so
        // concurrent deciders (several shard flows sharing one plan) still
        // see a deterministic *set* of triggered indices.
        let mix = k.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        let hit = Rng::new(self.spec.seed ^ mix).f64() < self.spec.rate;
        if hit {
            st.injected += 1;
            Some(self.spec.kind)
        } else {
            None
        }
    }

    /// Block until [`FaultPlan::release_wedges`] — what a `Wedge` fault does.
    pub fn hold_wedge(&self) {
        let mut open = self.wedge_open.lock().unwrap();
        while !*open {
            open = self.wedge_cv.wait(open).unwrap();
        }
    }

    /// Open the wedge gate (idempotent, permanent): every currently-wedged
    /// evaluation resumes and later wedge decisions pass straight through.
    pub fn release_wedges(&self) {
        *self.wedge_open.lock().unwrap() = true;
        self.wedge_cv.notify_all();
    }

    /// Wrap a shard flow closure with this plan.  Triggered faults act
    /// *around* the inner evaluation:
    ///
    ///  * `Delay` — sleep, then evaluate normally;
    ///  * `Wedge` — block on the gate, then evaluate (by the time the gate
    ///    opens the chunk has usually been hedged or requeued elsewhere, and
    ///    the late reply is discarded by chunk id);
    ///  * `Drop` / `Disconnect` — retire the shard without answering (the
    ///    local analogue of a lost reply / dead transport), requeueing the
    ///    in-flight chunk onto the surviving shards.
    pub fn wrap_flow<Q, A>(
        self: &std::sync::Arc<Self>,
        mut inner: Box<dyn FnMut(Q) -> ShardFlow<A>>,
    ) -> Box<dyn FnMut(Q) -> ShardFlow<A>> {
        let plan = self.clone();
        Box::new(move |q: Q| match plan.decide() {
            None => inner(q),
            Some(FaultKind::Delay) => {
                std::thread::sleep(plan.delay);
                inner(q)
            }
            Some(FaultKind::Wedge) => {
                plan.hold_wedge();
                inner(q)
            }
            Some(FaultKind::Drop) => ShardFlow::Retire {
                reason: "fault injection: reply dropped".into(),
            },
            Some(FaultKind::Disconnect) => ShardFlow::Retire {
                reason: "fault injection: transport disconnected".into(),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn spec_parses_and_round_trips() {
        let spec = FaultSpec::parse("7:wedge:1.0").unwrap();
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.kind, FaultKind::Wedge);
        assert!((spec.rate - 1.0).abs() < 1e-12);
        assert_eq!(spec.to_spec_string(), "7:wedge:1");
        let spec = FaultSpec::parse(&spec.to_spec_string()).unwrap();
        assert_eq!(spec.kind, FaultKind::Wedge);

        for kind in ["delay", "drop", "disconnect", "WEDGE"] {
            assert!(FaultSpec::parse(&format!("0:{kind}:0.5")).is_ok(), "{kind}");
        }
    }

    #[test]
    fn malformed_specs_error_cleanly() {
        for bad in [
            "", "7:wedge", "7:wedge:1.0:extra", "x:wedge:1.0", "7:fizzle:1.0",
            "7:wedge:nan", "7:wedge:1.5", "7:wedge:-0.1",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn decisions_are_deterministic_per_seed() {
        let seq = |seed: u64| -> Vec<bool> {
            let plan = FaultSpec { seed, kind: FaultKind::Drop, rate: 0.4 }.plan();
            (0..64).map(|_| plan.decide().is_some()).collect()
        };
        assert_eq!(seq(17), seq(17), "same seed must replay identically");
        assert_ne!(seq(17), seq(18), "different seeds must differ somewhere");
        let hits = seq(17).iter().filter(|&&h| h).count();
        assert!(
            (8..=44).contains(&hits),
            "rate 0.4 over 64 draws should land near 26, got {hits}"
        );
    }

    #[test]
    fn rate_bounds_are_exact() {
        let never = FaultSpec { seed: 3, kind: FaultKind::Delay, rate: 0.0 }.plan();
        assert!((0..128).all(|_| never.decide().is_none()));
        let always = FaultSpec { seed: 3, kind: FaultKind::Delay, rate: 1.0 }.plan();
        assert!((0..128).all(|_| always.decide() == Some(FaultKind::Delay)));
        assert_eq!(always.decisions(), 128);
        assert_eq!(always.injected(), 128);
    }

    #[test]
    fn max_faults_caps_the_injection() {
        let plan = FaultSpec { seed: 9, kind: FaultKind::Disconnect, rate: 1.0 }
            .plan()
            .with_max_faults(1);
        let hits: Vec<bool> = (0..16).map(|_| plan.decide().is_some()).collect();
        assert_eq!(hits.iter().filter(|&&h| h).count(), 1);
        assert!(hits[0], "rate 1.0 must fire on the first decision");
        assert_eq!(plan.injected(), 1);
        assert_eq!(plan.decisions(), 16);
    }

    #[test]
    fn wedge_gate_blocks_until_released_then_stays_open() {
        let plan = Arc::new(
            FaultSpec { seed: 1, kind: FaultKind::Wedge, rate: 1.0 }.plan(),
        );
        let p = plan.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            p.hold_wedge();
            tx.send(()).unwrap();
        });
        assert!(
            rx.recv_timeout(Duration::from_millis(20)).is_err(),
            "gate must hold before release"
        );
        plan.release_wedges();
        rx.recv_timeout(Duration::from_secs(5))
            .expect("release must unblock the wedged thread");
        h.join().unwrap();
        // permanent: a post-release hold returns immediately
        plan.hold_wedge();
    }

    #[test]
    fn wrapped_flow_injects_retires_and_passes_clean_decisions_through() {
        let plan = Arc::new(
            FaultSpec { seed: 5, kind: FaultKind::Drop, rate: 1.0 }
                .plan()
                .with_max_faults(1),
        );
        let mut flow = plan.wrap_flow(Box::new(|x: u32| ShardFlow::Reply(x * 2)));
        match flow(7) {
            ShardFlow::Retire { reason } => {
                assert!(reason.contains("fault injection"), "got: {reason}")
            }
            ShardFlow::Reply(_) => panic!("first decision at rate 1.0 must fault"),
        }
        // the cap is exhausted: subsequent chunks evaluate normally
        match flow(7) {
            ShardFlow::Reply(v) => assert_eq!(v, 14),
            ShardFlow::Retire { reason } => panic!("unexpected retire: {reason}"),
        }
        assert_eq!(plan.injected(), 1);
    }

    #[test]
    fn delay_fault_still_returns_the_pure_answer() {
        let plan = Arc::new(
            FaultSpec { seed: 2, kind: FaultKind::Delay, rate: 1.0 }
                .plan()
                .with_delay(Duration::from_millis(1)),
        );
        let mut flow = plan.wrap_flow(Box::new(|x: u32| ShardFlow::Reply(x + 1)));
        match flow(41) {
            ShardFlow::Reply(v) => assert_eq!(v, 42, "delay must not change results"),
            ShardFlow::Retire { reason } => panic!("unexpected retire: {reason}"),
        }
    }
}

//! PJRT runtime: loads the AOT HLO-text artifacts once, keeps all static
//! inputs resident as device buffers, and exposes the three entry points the
//! coordinator uses (fp logits / quant logits / fused scorer).
//!
//! This is the L3 hot path.  Design rules:
//!  * compile each executable once (`HloModuleProto::from_text_file` →
//!    `client.compile`) and reuse forever;
//!  * upload invariant inputs (fp weights, calibration batches, fp logits)
//!    once as `PjRtBuffer`s; per-candidate marshalling is limited to the
//!    quantized-layer buffers, which the proxy bank also uploads only once
//!    per (method, layer, bit-width) — so an *assembled candidate costs zero
//!    host→device copies* (see coordinator::proxy);
//!  * `Runtime` is `Sync` (PJRT clients are thread-safe; every entry point
//!    takes `&self`), so one runtime + one uploaded `DeviceBank` serve every
//!    evaluation-pool shard — stats live behind a `Mutex`, not a `RefCell`;
//!  * python never runs here.

mod service;

pub use service::{EvalService, ServiceStats, ShardStats};

use crate::data::Manifest;
use crate::model::WeightStore;
use crate::quant::QuantizedLinear;
use crate::Result;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// How each executable argument is sourced, precomputed from the manifest
/// argument-name list.
#[derive(Clone, Debug, PartialEq)]
enum ArgSlot {
    Tokens,
    Mask,
    FpLogits,
    FpParam(String),
    /// (layer index in manifest order, 0=codes 1=scale 2=zero)
    Quant(usize, u8),
}

fn plan_args(manifest: &Manifest, args: &[String]) -> Result<Vec<ArgSlot>> {
    args.iter()
        .map(|a| {
            Ok(match a.as_str() {
                "tokens" => ArgSlot::Tokens,
                "mask" => ArgSlot::Mask,
                "fp_logits" => ArgSlot::FpLogits,
                name => {
                    if let Some(rest) = name.strip_suffix(".codes") {
                        ArgSlot::Quant(idx(manifest, rest)?, 0)
                    } else if let Some(rest) = name.strip_suffix(".scale") {
                        ArgSlot::Quant(idx(manifest, rest)?, 1)
                    } else if let Some(rest) = name.strip_suffix(".zero") {
                        ArgSlot::Quant(idx(manifest, rest)?, 2)
                    } else {
                        ArgSlot::FpParam(name.to_string())
                    }
                }
            })
        })
        .collect()
}

fn idx(manifest: &Manifest, layer: &str) -> Result<usize> {
    manifest
        .layer_index(layer)
        .ok_or_else(|| eyre::anyhow!("arg references unknown layer {layer}"))
}

/// Uploaded buffers for one quantized layer (codes/scale/zero).
pub struct QuantLayerBufs {
    pub codes: xla::PjRtBuffer,
    pub scale: xla::PjRtBuffer,
    pub zero: xla::PjRtBuffer,
    pub bits: u8,
}

/// A calibration/evaluation batch resident on device.
pub struct ScoreBatch {
    pub tokens: xla::PjRtBuffer,
    pub mask: xla::PjRtBuffer,
    pub fp_logits: xla::PjRtBuffer,
    pub host_tokens: Vec<i32>,
    pub host_mask: Vec<f32>,
    pub host_fp_logits: Vec<f32>,
}

/// Wall-clock accounting per executable (perf reporting, Table 4 analog).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub fp_calls: u64,
    pub fp_time: Duration,
    pub quant_calls: u64,
    pub quant_time: Duration,
    pub scores_calls: u64,
    pub scores_time: Duration,
    pub upload_bytes: u64,
}

pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    fp_exec: xla::PjRtLoadedExecutable,
    quant_exec: xla::PjRtLoadedExecutable,
    scores_exec: xla::PjRtLoadedExecutable,
    fp_plan: Vec<ArgSlot>,
    quant_plan: Vec<ArgSlot>,
    scores_plan: Vec<ArgSlot>,
    fp_param_bufs: HashMap<String, xla::PjRtBuffer>,
    stats: Mutex<RuntimeStats>,
}

impl Runtime {
    /// Load + compile everything from `artifacts/`.
    pub fn load(artifacts_dir: &Path, weights: &WeightStore) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;

        let compile = |key: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest.hlo_path(key)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| eyre::anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let fp_exec = compile("model_fp")?;
        let quant_exec = compile("model_quant")?;
        let scores_exec = compile("scores_quant")?;

        let fp_plan = plan_args(&manifest, &manifest.executable("model_fp")?.args)?;
        let quant_plan = plan_args(&manifest, &manifest.executable("model_quant")?.args)?;
        let scores_plan = plan_args(&manifest, &manifest.executable("scores_quant")?.args)?;

        let mut rt = Runtime {
            manifest,
            client,
            fp_exec,
            quant_exec,
            scores_exec,
            fp_plan,
            quant_plan,
            scores_plan,
            fp_param_bufs: HashMap::new(),
            stats: Mutex::new(RuntimeStats::default()),
        };
        rt.upload_fp_params(weights)?;
        Ok(rt)
    }

    /// Upload (or replace) the resident fp parameter buffers.
    pub fn upload_fp_params(&mut self, weights: &WeightStore) -> Result<()> {
        let mut bufs = HashMap::new();
        let names: Vec<String> = self
            .fp_plan
            .iter()
            .chain(&self.quant_plan)
            .chain(&self.scores_plan)
            .filter_map(|s| match s {
                ArgSlot::FpParam(n) => Some(n.clone()),
                _ => None,
            })
            .collect();
        for name in names {
            if bufs.contains_key(&name) {
                continue;
            }
            let (shape, data) = weights.get(&name)?;
            let buf = self.upload_f32(data, shape)?;
            bufs.insert(name, buf);
        }
        self.fp_param_bufs = bufs;
        Ok(())
    }

    pub fn batch_size(&self) -> usize {
        self.manifest.eval_batch
    }

    pub fn seq_len(&self) -> usize {
        self.manifest.model.seq_len
    }

    pub fn vocab(&self) -> usize {
        self.manifest.model.vocab_size
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.lock().unwrap() = RuntimeStats::default();
    }

    // -- uploads ----------------------------------------------------------

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.stats.lock().unwrap().upload_bytes += (data.len() * 4) as u64;
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.stats.lock().unwrap().upload_bytes += (data.len() * 4) as u64;
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i8(&self, data: &[i8], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.stats.lock().unwrap().upload_bytes += data.len() as u64;
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload one quantized layer (codes as int8 + f32 scale/zero).
    /// The AOT kernel consumes s8 codes; grouped codes are <= 15 so the
    /// u8 -> i8 conversion is lossless (asserted).
    pub fn upload_quant_layer(&self, q: &QuantizedLinear) -> Result<QuantLayerBufs> {
        let n = q.out_features;
        let k = q.in_features;
        let g = q.n_groups();
        eyre::ensure!(q.bits <= 4, "AOT kernel path supports <= 4-bit codes");
        let codes_i8: Vec<i8> = q.codes.iter().map(|&c| c as i8).collect();
        Ok(QuantLayerBufs {
            codes: self.upload_i8(&codes_i8, &[n, k])?,
            scale: self.upload_f32(&q.scale, &[n, g])?,
            zero: self.upload_f32(&q.zero, &[n, g])?,
            bits: q.bits,
        })
    }

    /// Upload a named set of fp weight overrides ([out,in] row-major mats).
    pub fn upload_weight_overrides(
        &self,
        overrides: &[(String, crate::tensor::Mat)],
    ) -> Result<HashMap<String, xla::PjRtBuffer>> {
        let mut out = HashMap::new();
        for (name, mat) in overrides {
            out.insert(
                name.clone(),
                self.upload_f32(&mat.data, &[mat.rows, mat.cols])?,
            );
        }
        Ok(out)
    }

    // -- fp path ----------------------------------------------------------

    /// Run the fp executable with the resident weights.
    pub fn fp_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.fp_logits_with(tokens, &HashMap::new())
    }

    /// Run the fp executable with some weights overridden (baselines:
    /// BitStack / PB-LLM / fixed-precision reconstructions).
    pub fn fp_logits_with(
        &self,
        tokens: &[i32],
        overrides: &HashMap<String, xla::PjRtBuffer>,
    ) -> Result<Vec<f32>> {
        let b = self.batch_size();
        let t = self.seq_len();
        eyre::ensure!(tokens.len() == b * t, "tokens must be [{b},{t}]");
        let tok_buf = self.upload_i32(tokens, &[b, t])?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.fp_plan.len());
        for slot in &self.fp_plan {
            match slot {
                ArgSlot::Tokens => args.push(&tok_buf),
                ArgSlot::FpParam(name) => {
                    let buf = overrides.get(name).or_else(|| self.fp_param_bufs.get(name));
                    args.push(buf.ok_or_else(|| eyre::anyhow!("missing fp param {name}"))?)
                }
                other => eyre::bail!("unexpected slot {other:?} in fp plan"),
            }
        }
        let t0 = Instant::now();
        let out = self.fp_exec.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        {
            let mut s = self.stats.lock().unwrap();
            s.fp_calls += 1;
            s.fp_time += t0.elapsed();
        }
        let logits = lit.to_tuple1()?;
        Ok(logits.to_vec::<f32>()?)
    }

    /// Prepare a resident evaluation batch: computes fp logits and uploads
    /// tokens/mask/fp_logits once.
    pub fn prepare_batch(&self, tokens: &[i32], mask: &[f32]) -> Result<ScoreBatch> {
        let b = self.batch_size();
        let t = self.seq_len();
        eyre::ensure!(tokens.len() == b * t && mask.len() == b * t);
        let fp = self.fp_logits(tokens)?;
        Ok(ScoreBatch {
            tokens: self.upload_i32(tokens, &[b, t])?,
            mask: self.upload_f32(mask, &[b, t])?,
            fp_logits: self.upload_f32(&fp, &[b, t, self.vocab()])?,
            host_tokens: tokens.to_vec(),
            host_mask: mask.to_vec(),
            host_fp_logits: fp,
        })
    }

    // -- quant path -------------------------------------------------------

    /// Fused scorer: (mean JSD vs fp, mean CE) for an assembled candidate.
    /// `layers[i]` must follow manifest layer order.
    pub fn scores(&self, batch: &ScoreBatch, layers: &[&QuantLayerBufs]) -> Result<(f32, f32)> {
        Ok(self.scores_chunk(batch, &[layers])?[0])
    }

    /// Fused scorer over a *chunk* of assembled candidates on one batch —
    /// the microbatch dispatch unit of the evaluation hot path.  The static
    /// argument slots (tokens/mask/fp logits/fp params) are resolved once
    /// per chunk; per-candidate marshalling is limited to patching the
    /// quant-slot positions in place.  Results are per-candidate, in input
    /// order, and bit-identical to calling [`Runtime::scores`] per candidate.
    pub fn scores_chunk(
        &self,
        batch: &ScoreBatch,
        candidates: &[&[&QuantLayerBufs]],
    ) -> Result<Vec<(f32, f32)>> {
        let mut out = Vec::with_capacity(candidates.len());
        if candidates.is_empty() {
            return Ok(out);
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.scores_plan.len());
        // (argument position, layer index, 0=codes 1=scale 2=zero)
        let mut quant_slots: Vec<(usize, usize, u8)> = Vec::new();
        for (pos, slot) in self.scores_plan.iter().enumerate() {
            match slot {
                ArgSlot::Tokens => args.push(&batch.tokens),
                ArgSlot::Mask => args.push(&batch.mask),
                ArgSlot::FpLogits => args.push(&batch.fp_logits),
                ArgSlot::FpParam(name) => args.push(
                    self.fp_param_bufs
                        .get(name)
                        .ok_or_else(|| eyre::anyhow!("missing fp param {name}"))?,
                ),
                ArgSlot::Quant(li, part) => {
                    quant_slots.push((pos, *li, *part));
                    // placeholder, patched per candidate below
                    args.push(&batch.tokens);
                }
            }
        }
        for layers in candidates {
            eyre::ensure!(layers.len() == self.manifest.layers.len());
            for &(pos, li, part) in &quant_slots {
                let l = layers[li];
                args[pos] = match part {
                    0 => &l.codes,
                    1 => &l.scale,
                    _ => &l.zero,
                };
            }
            let t0 = Instant::now();
            let res = self.scores_exec.execute_b(&args)?;
            let lit = res[0][0].to_literal_sync()?;
            {
                let mut s = self.stats.lock().unwrap();
                s.scores_calls += 1;
                s.scores_time += t0.elapsed();
            }
            let (jsd, ce) = lit.to_tuple2()?;
            out.push((jsd.to_vec::<f32>()?[0], ce.to_vec::<f32>()?[0]));
        }
        Ok(out)
    }

    /// Quantized-model logits (task evaluation path).
    pub fn quant_logits(&self, tokens: &[i32], layers: &[&QuantLayerBufs]) -> Result<Vec<f32>> {
        eyre::ensure!(layers.len() == self.manifest.layers.len());
        let b = self.batch_size();
        let t = self.seq_len();
        eyre::ensure!(tokens.len() == b * t);
        let tok_buf = self.upload_i32(tokens, &[b, t])?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.quant_plan.len());
        for slot in &self.quant_plan {
            match slot {
                ArgSlot::Tokens => args.push(&tok_buf),
                ArgSlot::FpParam(name) => args.push(
                    self.fp_param_bufs
                        .get(name)
                        .ok_or_else(|| eyre::anyhow!("missing fp param {name}"))?,
                ),
                ArgSlot::Quant(li, part) => {
                    let l = layers[*li];
                    args.push(match part {
                        0 => &l.codes,
                        1 => &l.scale,
                        _ => &l.zero,
                    });
                }
                other => eyre::bail!("unexpected slot {other:?} in quant plan"),
            }
        }
        let t0 = Instant::now();
        let out = self.quant_exec.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        {
            let mut s = self.stats.lock().unwrap();
            s.quant_calls += 1;
            s.quant_time += t0.elapsed();
        }
        let logits = lit.to_tuple1()?;
        Ok(logits.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        crate::data::Manifest::from_json(
            r#"{
            "model": {"vocab_size": 512, "d_model": 128, "n_layers": 1,
                      "n_heads": 4, "d_ff": 256, "seq_len": 128,
                      "rope_theta": 10000.0, "rms_eps": 1e-5},
            "group_size": 128, "bit_choices": [2,3,4], "eval_batch": 16,
            "layers": [{"name": "blk0.q", "out_features": 128, "in_features": 128}],
            "fp_side_names": ["embed"],
            "executables": {}, "files": {}
        }"#,
        )
        .unwrap()
    }


    #[test]
    fn plan_args_classifies_slots() {
        let m = toy_manifest();
        let args: Vec<String> = [
            "tokens", "mask", "fp_logits", "embed",
            "blk0.q.codes", "blk0.q.scale", "blk0.q.zero",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let plan = plan_args(&m, &args).unwrap();
        assert_eq!(plan[0], ArgSlot::Tokens);
        assert_eq!(plan[1], ArgSlot::Mask);
        assert_eq!(plan[2], ArgSlot::FpLogits);
        assert_eq!(plan[3], ArgSlot::FpParam("embed".into()));
        assert_eq!(plan[4], ArgSlot::Quant(0, 0));
        assert_eq!(plan[5], ArgSlot::Quant(0, 1));
        assert_eq!(plan[6], ArgSlot::Quant(0, 2));
    }

    #[test]
    fn plan_args_rejects_unknown_layer() {
        let m = toy_manifest();
        assert!(plan_args(&m, &["blkX.q.codes".to_string()]).is_err());
    }
}

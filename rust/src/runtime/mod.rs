//! PJRT runtime: loads the AOT HLO-text artifacts once, keeps all static
//! inputs resident as device buffers, and exposes the entry points the
//! coordinator uses (fp logits / quant logits / fused scorer).
//!
//! This is the L3 hot path.  Design rules:
//!  * compile each executable once (`HloModuleProto::from_text_file` →
//!    `client.compile`) and reuse forever;
//!  * upload invariant inputs (fp weights, calibration batches, fp logits)
//!    once as `PjRtBuffer`s; per-candidate marshalling is limited to the
//!    quantized-layer buffers, which the proxy bank also uploads only once
//!    per (method, layer, bit-width) — so an *assembled candidate costs zero
//!    host→device copies* (see coordinator::proxy);
//!  * when the artifacts carry a **lane-stacked scorer**
//!    (`scores_quant_lanes{L}.hlo.txt`), a chunk of up to `L` candidates is
//!    packed into stacked quant-slot slabs and scored by **one** device
//!    dispatch — per-lane results are bitwise identical to the
//!    single-candidate scorer, so archives never depend on the dispatch
//!    strategy (see [`ScorerVariant`]).  Slab packing **borrows** its rows
//!    straight from the proxy bank's host pieces (no host mirrors, 1× host
//!    bank bytes), and packed slabs stay device-resident in a [`SlabCache`]
//!    so repeat candidate groups — across calibration batches and across
//!    search generations — cost zero re-uploads (see [`LaneChunkPlan`]);
//!  * `Runtime` is `Sync` (PJRT clients are thread-safe; every entry point
//!    takes `&self`), so one runtime + one uploaded `DeviceBank` serve every
//!    evaluation-pool shard — stats live behind a `Mutex`, not a `RefCell`,
//!    and the scoring hot loop takes that lock once per chunk;
//!  * python never runs here.

pub mod faults;
pub mod remote;
pub mod serve;
mod service;
pub mod wire;

pub use faults::{FaultKind, FaultPlan, FaultSpec};
pub use serve::{ContinuousBatcher, SchedulerOptions, SchedulerStats};
pub use service::{
    EvalService, HedgePolicy, ServiceStats, ShardFlow, ShardStats, DEFAULT_HEDGE_FACTOR,
};

use crate::data::Manifest;
use crate::model::WeightStore;
use crate::quant::QuantizedLinear;
use crate::Result;
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How each executable argument is sourced, precomputed from the manifest
/// argument-name list.
#[derive(Clone, Debug, PartialEq)]
enum ArgSlot {
    Tokens,
    Mask,
    FpLogits,
    FpParam(String),
    /// (layer index in manifest order, 0=codes 1=scale 2=zero)
    Quant(usize, u8),
}

fn plan_args(manifest: &Manifest, args: &[String]) -> Result<Vec<ArgSlot>> {
    args.iter()
        .map(|a| {
            Ok(match a.as_str() {
                "tokens" => ArgSlot::Tokens,
                "mask" => ArgSlot::Mask,
                "fp_logits" => ArgSlot::FpLogits,
                name => {
                    if let Some(rest) = name.strip_suffix(".codes") {
                        ArgSlot::Quant(idx(manifest, rest)?, 0)
                    } else if let Some(rest) = name.strip_suffix(".scale") {
                        ArgSlot::Quant(idx(manifest, rest)?, 1)
                    } else if let Some(rest) = name.strip_suffix(".zero") {
                        ArgSlot::Quant(idx(manifest, rest)?, 2)
                    } else {
                        ArgSlot::FpParam(name.to_string())
                    }
                }
            })
        })
        .collect()
}

fn idx(manifest: &Manifest, layer: &str) -> Result<usize> {
    manifest
        .layer_index(layer)
        .ok_or_else(|| eyre::anyhow!("arg references unknown layer {layer}"))
}

// ---------------------------------------------------------------------------
// Lane packing (pure host-side helpers, unit-testable without a device)
// ---------------------------------------------------------------------------

/// Which executable the fused scorer dispatches through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScorerVariant {
    /// One execution of the single-candidate scorer per candidate — the
    /// fallback when the artifacts carry no lane-stacked executable (or
    /// lane stacking is disabled with `--lanes 1`).
    PerCandidate,
    /// One execution of the lane-stacked scorer per group of up to `lanes`
    /// candidates; partial groups are padded with lane 0 and the padded
    /// outputs discarded.
    LaneStacked {
        /// Candidate lanes per dispatch (the leading axis of the stacked
        /// quant-slot arguments).
        lanes: usize,
    },
}

impl ScorerVariant {
    /// Stable name for reports (`"per-candidate"` / `"lane-stacked"`).
    pub fn name(&self) -> &'static str {
        match self {
            ScorerVariant::PerCandidate => "per-candidate",
            ScorerVariant::LaneStacked { .. } => "lane-stacked",
        }
    }

    /// Candidates one scorer dispatch can carry (1 for per-candidate).
    pub fn lanes(&self) -> usize {
        match self {
            ScorerVariant::PerCandidate => 1,
            ScorerVariant::LaneStacked { lanes } => *lanes,
        }
    }
}

/// How lane-slab cache misses are assembled (`--slab-gather`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SlabGatherMode {
    /// Use the device-side gather executables when the artifacts carry
    /// them (and the lane-stacked scorer is active); otherwise fall back
    /// to the host pack + upload path.  Legacy manifests keep working.
    #[default]
    Auto,
    /// Always host-pack + upload, even when gather artifacts exist
    /// (baseline / bisection switch).
    Off,
    /// Error at load time unless the gather executables are present —
    /// guards perf runs against silently re-entering the upload path.
    Require,
}

impl SlabGatherMode {
    /// Parse a `--slab-gather` CLI value.
    pub fn parse(s: &str) -> Result<SlabGatherMode> {
        Ok(match s {
            "auto" => SlabGatherMode::Auto,
            "off" => SlabGatherMode::Off,
            "require" => SlabGatherMode::Require,
            other => eyre::bail!(
                "--slab-gather must be auto|off|require, got `{other}`"
            ),
        })
    }

    /// Stable name for reports (`"auto"` / `"off"` / `"require"`).
    pub fn name(&self) -> &'static str {
        match self {
            SlabGatherMode::Auto => "auto",
            SlabGatherMode::Off => "off",
            SlabGatherMode::Require => "require",
        }
    }
}

/// Whether a chunk of `pending` candidates routes through the lane-stacked
/// executable: it must exist (`lanes > 1`) and the chunk must have more
/// than one candidate — a single candidate's resident per-candidate
/// buffers are already on device, so slab packing would only add cost.
/// The single routing predicate shared by [`Runtime::scores_chunk`] and
/// the scheduler simulations in tests/benches.
pub fn lane_routed(pending: usize, lanes: usize) -> bool {
    lanes > 1 && pending > 1
}

/// Scorer dispatches needed for a chunk of `pending` candidates at this
/// lane width: `ceil(pending / lanes)` when lane-stacked, one per
/// candidate otherwise.
pub fn lane_dispatch_count(pending: usize, lanes: usize) -> usize {
    if lanes <= 1 {
        pending
    } else {
        pending.div_ceil(lanes)
    }
}

/// Idle (padded) lanes executed and discarded when dispatching `pending`
/// candidates through a `lanes`-wide scorer.
pub fn lane_padding(pending: usize, lanes: usize) -> usize {
    if pending == 0 || lanes <= 1 {
        0
    } else {
        lane_dispatch_count(pending, lanes) * lanes - pending
    }
}

/// Stack per-candidate buffers into one `lanes`-wide slab (row-major,
/// candidate axis leading).  A partial group (`rows.len() < lanes`) is
/// padded by repeating lane 0, so the stacked executable always sees a full
/// lane axis; callers discard the padded outputs.  All rows must have lane
/// 0's length.
pub fn pack_lane_slab<T: Copy>(rows: &[&[T]], lanes: usize) -> Result<Vec<T>> {
    eyre::ensure!(!rows.is_empty(), "lane slab needs at least one candidate");
    eyre::ensure!(
        rows.len() <= lanes,
        "lane slab overflow: {} candidates for {lanes} lanes",
        rows.len()
    );
    let per = rows[0].len();
    let mut out = Vec::with_capacity(lanes * per);
    for lane in 0..lanes {
        let row = rows.get(lane).copied().unwrap_or(rows[0]);
        eyre::ensure!(
            row.len() == per,
            "lane {lane} has {} elements, lane 0 has {per}",
            row.len()
        );
        out.extend_from_slice(row);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Slab cache (device-resident lane slabs, LRU under a byte budget)
// ---------------------------------------------------------------------------

/// Cache key of one packed lane slab: `(layer index, per-lane gene
/// signature)`.  The signature is the *padded* lane column — each lane's
/// `(method, bits)` gene at that layer, with partial groups extended by
/// repeating lane 0 — so two groups that pack to identical slab bytes share
/// one entry (e.g. `[a, b]` and `[a, b, a]` at 4 lanes both key as
/// `[a, b, a, a]`).
pub type SlabKey = (usize, Vec<u16>);

/// Canonical slab signature of one layer of a candidate group: the
/// per-lane gene column padded to `lanes` by repeating lane 0 — exactly
/// mirroring the padded slab bytes ([`pack_lane_slab`]), so any two groups
/// that pack identical slabs share one [`SlabKey`].  The single definition
/// used by the production planner and the scheduler simulations in
/// tests/benches.
///
/// Panics if `group` is empty or `li` is out of range (caller bugs).
pub fn lane_slab_sig(group: &[Vec<u16>], li: usize, lanes: usize) -> Vec<u16> {
    let mut sig: Vec<u16> = group.iter().map(|c| c[li]).collect();
    sig.resize(lanes, group[0][li]);
    sig
}

/// Snapshot of a [`SlabCache`]'s hit/residency counters.  `resident_bytes`
/// is recomputed from the live entries on every snapshot, so it is exact by
/// construction (asserted by unit + property tests).
#[derive(Clone, Debug, Default)]
pub struct SlabCacheStats {
    /// Lookups served from a resident slab (zero pack + upload work).
    pub hits: u64,
    /// Lookups that had to pack + upload (includes budget-0 bypasses).
    pub misses: u64,
    /// Entries dropped to make room under the byte budget.
    pub evictions: u64,
    /// Total bytes built through misses (the upload traffic the cache
    /// could not avoid).
    pub built_bytes: u64,
    /// Bytes of the currently resident slabs (sum of live entry sizes).
    pub resident_bytes: usize,
    /// Number of currently resident slabs.
    pub resident_slabs: usize,
    /// The configured byte budget (`--slab-cache-mb`; 0 = caching off).
    pub budget_bytes: usize,
}

impl SlabCacheStats {
    /// Fraction of lookups served without packing/uploading.
    pub fn hit_fraction(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct SlabEntry<T> {
    payload: Arc<T>,
    bytes: usize,
    last_used: u64,
}

/// Per-key build latch: the first shard to miss a key registers one, builds
/// *outside* the cache lock, then publishes the result here; concurrent
/// same-key lookups wait on the condvar instead of rebuilding (and instead
/// of blocking every *other* key behind the build, which is the bug this
/// replaces).  Build errors are broadcast as the error text so waiters fail
/// with the same cause.
struct BuildLatch<T> {
    done: Mutex<Option<std::result::Result<Arc<T>, String>>>,
    cv: Condvar,
}

impl<T> BuildLatch<T> {
    fn new() -> Self {
        BuildLatch { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn fill(&self, result: std::result::Result<Arc<T>, String>) {
        *self.done.lock().unwrap() = Some(result);
        self.cv.notify_all();
    }

    fn wait(&self) -> std::result::Result<Arc<T>, String> {
        let mut done = self.done.lock().unwrap();
        while done.is_none() {
            done = self.cv.wait(done).unwrap();
        }
        done.clone().expect("loop exits only once filled")
    }
}

/// A cache slot is either a finished slab or a build in flight.
enum Slot<T> {
    Ready(SlabEntry<T>),
    Building(Arc<BuildLatch<T>>),
}

struct SlabCacheInner<T> {
    entries: HashMap<SlabKey, Slot<T>>,
    clock: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    built_bytes: u64,
}

/// An LRU cache of packed lane slabs keyed by [`SlabKey`], bounded by a
/// byte budget.  The production instance ([`LaneSlabCache`] on the device
/// bank) stores uploaded [`LaneSlabBufs`], keeping slabs device-resident
/// across calibration batches and across search generations; the generic
/// payload keeps the eviction/accounting logic testable without a PJRT
/// device.
///
/// Semantics:
///  * budget `0` disables retention entirely — every lookup builds (and
///    returns) a fresh slab that is dropped when its last `Arc` goes away;
///  * a miss whose slab alone exceeds the budget is returned unstored;
///  * otherwise least-recently-used entries are evicted until the new slab
///    fits.  Returned `Arc`s pin their slab for as long as the caller holds
///    them, so eviction can never invalidate an in-flight dispatch plan.
///
/// The cache is a correctness no-op by design: contents are a pure
/// function of the key, so hit/miss/eviction patterns can change upload
/// counts but never scores (property-tested in `rust/tests/proptests.rs`).
pub struct SlabCache<T> {
    inner: Mutex<SlabCacheInner<T>>,
    budget_bytes: usize,
}

impl<T> SlabCache<T> {
    /// An empty cache with the given byte budget (0 = caching off).
    pub fn new(budget_bytes: usize) -> SlabCache<T> {
        SlabCache {
            inner: Mutex::new(SlabCacheInner {
                entries: HashMap::new(),
                clock: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                built_bytes: 0,
            }),
            budget_bytes,
        }
    }

    /// The configured byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Look up `key`, building (pack + upload) on a miss.  `build` returns
    /// the payload and its resident byte size.
    ///
    /// Locking discipline: the cache lock covers only bookkeeping — the
    /// build itself runs *outside* it behind a per-key [`BuildLatch`].
    /// Concurrent shards resolving the *same* key share one build (upload
    /// counts stay exact: waiters count as hits, exactly as they did when
    /// they queued on the cache mutex), while misses on *distinct* keys
    /// pack + upload fully in parallel (pinned by the two-key
    /// concurrent-miss test below).
    pub fn get_or_build<F>(&self, key: SlabKey, build: F) -> Result<Arc<T>>
    where
        F: FnOnce() -> Result<(T, usize)>,
    {
        enum Action<T> {
            Hit(Arc<T>),
            Wait(Arc<BuildLatch<T>>),
            Build(Arc<BuildLatch<T>>),
        }
        let action = {
            let mut inner = self.inner.lock().unwrap();
            inner.clock += 1;
            let now = inner.clock;
            let action = match inner.entries.get_mut(&key) {
                Some(Slot::Ready(e)) => {
                    e.last_used = now;
                    Action::Hit(e.payload.clone())
                }
                Some(Slot::Building(latch)) => Action::Wait(latch.clone()),
                None => {
                    let latch = Arc::new(BuildLatch::new());
                    inner.entries.insert(key.clone(), Slot::Building(latch.clone()));
                    Action::Build(latch)
                }
            };
            match &action {
                Action::Hit(_) | Action::Wait(_) => inner.hits += 1,
                Action::Build(_) => inner.misses += 1,
            }
            action
        };
        match action {
            Action::Hit(payload) => Ok(payload),
            Action::Wait(latch) => latch.wait().map_err(|msg| {
                eyre::anyhow!("shared slab build for {key:?} failed: {msg}")
            }),
            Action::Build(latch) => match build() {
                Ok((payload, bytes)) => {
                    let payload = Arc::new(payload);
                    {
                        let mut inner = self.inner.lock().unwrap();
                        inner.built_bytes += bytes as u64;
                        inner.entries.remove(&key);
                        if self.budget_bytes > 0 && bytes <= self.budget_bytes {
                            // LRU eviction (over finished slabs; in-flight
                            // builds own no resident bytes yet) until the new
                            // slab fits the budget
                            let mut resident: usize = inner
                                .entries
                                .values()
                                .filter_map(|s| match s {
                                    Slot::Ready(e) => Some(e.bytes),
                                    Slot::Building(_) => None,
                                })
                                .sum();
                            while resident + bytes > self.budget_bytes {
                                let oldest = inner
                                    .entries
                                    .iter()
                                    .filter_map(|(k, s)| match s {
                                        Slot::Ready(e) => {
                                            Some((k.clone(), e.last_used, e.bytes))
                                        }
                                        Slot::Building(_) => None,
                                    })
                                    .min_by_key(|(_, last_used, _)| *last_used);
                                let Some((oldest, _, evicted_bytes)) = oldest else {
                                    break;
                                };
                                inner.entries.remove(&oldest);
                                resident -= evicted_bytes;
                                inner.evictions += 1;
                            }
                            let now = inner.clock;
                            inner.entries.insert(
                                key,
                                Slot::Ready(SlabEntry {
                                    payload: payload.clone(),
                                    bytes,
                                    last_used: now,
                                }),
                            );
                        }
                    }
                    latch.fill(Ok(payload.clone()));
                    Ok(payload)
                }
                Err(e) => {
                    {
                        let mut inner = self.inner.lock().unwrap();
                        inner.entries.remove(&key);
                    }
                    latch.fill(Err(e.to_string()));
                    Err(e)
                }
            },
        }
    }

    /// Counter + residency snapshot (`resident_bytes` recomputed from the
    /// live entries — exact accounting, never a drifting counter).  Slots
    /// with a build still in flight are not resident yet.
    pub fn stats(&self) -> SlabCacheStats {
        let inner = self.inner.lock().unwrap();
        let ready = |s: &Slot<T>| match s {
            Slot::Ready(e) => Some(e.bytes),
            Slot::Building(_) => None,
        };
        SlabCacheStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            built_bytes: inner.built_bytes,
            resident_bytes: inner.entries.values().filter_map(&ready).sum(),
            resident_slabs: inner.entries.values().filter_map(&ready).count(),
            budget_bytes: self.budget_bytes,
        }
    }
}

/// One uploaded lane slab: the three stacked quant-slot buffers of a
/// candidate group at one layer (`codes s8[L,N,K]`, `scale f32[L,N,G]`,
/// `zero f32[L,N,G]`).
pub struct LaneSlabBufs {
    /// Stacked codes, `[lanes, out_features, in_features]`.
    pub codes: xla::PjRtBuffer,
    /// Stacked scales, `[lanes, out_features, n_groups]`.
    pub scale: xla::PjRtBuffer,
    /// Stacked zero points, `[lanes, out_features, n_groups]`.
    pub zero: xla::PjRtBuffer,
    /// Device bytes of the three buffers together.
    pub bytes: usize,
}

/// The production slab cache: uploaded lane slabs, one per
/// `(layer, lane signature)` ([`SlabKey`]), owned by the shared device bank.
pub type LaneSlabCache = SlabCache<LaneSlabBufs>;

/// One lane group of a resolved [`LaneChunkPlan`]: up to `lanes` real
/// candidates plus the pinned per-layer slabs feeding the dispatch.
pub struct LaneGroup {
    /// Real (non-padding) candidates in this group.
    pub real: usize,
    /// Per-layer slab buffers, manifest layer order.  `Arc`s pin the slabs
    /// against cache eviction for the plan's lifetime.
    pub slabs: Vec<Arc<LaneSlabBufs>>,
}

/// A chunk's lane-dispatch plan: candidates grouped `lanes` at a time, each
/// group's quant slabs resolved (packed from borrowed bank pieces, or
/// reused from the [`SlabCache`]) exactly once.  Build it once per chunk —
/// [`DeviceProxy::plan_lane_chunk`] — then dispatch it against every
/// calibration batch ([`Runtime::scores_lane_chunk`]): slab uploads scale
/// with *distinct slabs*, never with `slabs × batches`.
///
/// [`DeviceProxy::plan_lane_chunk`]: crate::coordinator::proxy::DeviceProxy::plan_lane_chunk
pub struct LaneChunkPlan {
    groups: Vec<LaneGroup>,
    n_candidates: usize,
}

impl LaneChunkPlan {
    /// Assemble a plan from resolved groups (validated at dispatch time
    /// against the runtime's lane width and layer count).
    pub fn new(groups: Vec<LaneGroup>) -> Result<LaneChunkPlan> {
        eyre::ensure!(!groups.is_empty(), "lane plan needs at least one group");
        let n_candidates = groups.iter().map(|g| g.real).sum();
        for g in &groups {
            eyre::ensure!(g.real > 0, "lane group with zero real candidates");
        }
        Ok(LaneChunkPlan { groups, n_candidates })
    }

    /// Total real candidates across all groups.
    pub fn n_candidates(&self) -> usize {
        self.n_candidates
    }

    /// Device dispatches this plan costs (one per group).
    pub fn n_dispatches(&self) -> usize {
        self.groups.len()
    }
}

/// Uploaded buffers for one quantized layer (codes/scale/zero).  Holds no
/// host copies: the lane-stacked scorer packs its slabs straight from the
/// proxy bank's host pieces ([`Runtime::upload_lane_slab`]), so uploading a
/// layer costs device bytes only.
pub struct QuantLayerBufs {
    /// Device-resident int8 codes, `[out_features, in_features]`.
    pub codes: xla::PjRtBuffer,
    /// Device-resident per-group scales, `[out_features, n_groups]`.
    pub scale: xla::PjRtBuffer,
    /// Device-resident per-group zero points, `[out_features, n_groups]`.
    pub zero: xla::PjRtBuffer,
    /// Bit-width the codes were quantized at.
    pub bits: u8,
    /// `out_features`.
    pub rows: usize,
    /// `in_features`.
    pub cols: usize,
    /// `in_features / group_size`.
    pub groups: usize,
}

/// A calibration/evaluation batch resident on device.
pub struct ScoreBatch {
    /// Uploaded token ids, `[eval_batch, seq_len]` i32.
    pub tokens: xla::PjRtBuffer,
    /// Uploaded validity mask, `[eval_batch, seq_len]` f32.
    pub mask: xla::PjRtBuffer,
    /// Uploaded fp reference logits, `[eval_batch, seq_len, vocab]` f32.
    pub fp_logits: xla::PjRtBuffer,
    /// Host copy of the token ids (baseline evaluation paths).
    pub host_tokens: Vec<i32>,
    /// Host copy of the mask.
    pub host_mask: Vec<f32>,
    /// Host copy of the fp reference logits.
    pub host_fp_logits: Vec<f32>,
}

/// Wall-clock accounting per executable (perf reporting, Table 4 analog).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// fp-executable executions.
    pub fp_calls: u64,
    /// Wall-clock spent in fp executions (incl. device→host transfer).
    pub fp_time: Duration,
    /// Quant-executable executions (task evaluation path).
    pub quant_calls: u64,
    /// Wall-clock spent in quant executions.
    pub quant_time: Duration,
    /// Single-candidate scorer executions.
    pub scores_calls: u64,
    /// Wall-clock spent in single-candidate scorer executions.
    pub scores_time: Duration,
    /// Lane-stacked scorer executions (each carries up to `lanes`
    /// candidates).
    pub lane_dispatches: u64,
    /// Candidates scored through the lane-stacked executable.
    pub lane_candidates: u64,
    /// Padding lanes executed and discarded (partial groups).
    pub lane_padded: u64,
    /// Wall-clock spent in lane-stacked scorer executions.
    pub lane_time: Duration,
    /// Host→device bytes uploaded through this runtime.
    pub upload_bytes: u64,
    /// Device-side slab-gather dispatches (one per lane-slab cache miss
    /// routed through the gather executable instead of a host upload).
    pub gather_dispatches: u64,
    /// Wall-clock spent in slab-gather dispatches.
    pub gather_time: Duration,
    /// Host→device slab bytes the gather path avoided uploading (what
    /// [`Runtime::upload_lane_slab`] would have pushed for the same slabs;
    /// never added to `upload_bytes`).
    pub slab_upload_bytes_avoided: u64,
}

impl RuntimeStats {
    /// Total scorer dispatches, both variants.
    pub fn scorer_dispatches(&self) -> u64 {
        self.scores_calls + self.lane_dispatches
    }

    /// Fraction of executed lanes that carried real candidates (1.0 = every
    /// dispatch full; 0.0 when the lane path never ran).
    pub fn lane_fill_fraction(&self) -> f64 {
        let executed = self.lane_candidates + self.lane_padded;
        if executed == 0 {
            0.0
        } else {
            self.lane_candidates as f64 / executed as f64
        }
    }
}

/// The PJRT execution engine: compiled executables + resident static
/// buffers + wall-clock stats.  One instance serves the whole process
/// (`Sync`; see the module docs).
pub struct Runtime {
    /// The artifact manifest the executables were loaded from.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    fp_exec: xla::PjRtLoadedExecutable,
    quant_exec: xla::PjRtLoadedExecutable,
    scores_exec: xla::PjRtLoadedExecutable,
    /// Lane-stacked scorer, when the artifacts carry one and it is enabled.
    lanes_exec: Option<xla::PjRtLoadedExecutable>,
    /// Slab-gather executables by shape family `(out_features,
    /// in_features)`; empty when misses take the host pack + upload path.
    gather_execs: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
    fp_plan: Vec<ArgSlot>,
    quant_plan: Vec<ArgSlot>,
    scores_plan: Vec<ArgSlot>,
    lanes_plan: Vec<ArgSlot>,
    /// Lane width of `lanes_exec` (1 when per-candidate only).
    lanes: usize,
    fp_param_bufs: HashMap<String, xla::PjRtBuffer>,
    stats: Mutex<RuntimeStats>,
}

impl Runtime {
    /// Load + compile everything from `artifacts/`, using the lane-stacked
    /// scorer automatically when the manifest carries one.
    pub fn load(artifacts_dir: &Path, weights: &WeightStore) -> Result<Runtime> {
        Self::load_with_lanes(artifacts_dir, weights, 0)
    }

    /// Load with an explicit lane request (`--lanes`): `0` = auto (use the
    /// lane-stacked artifact when present), `1` = force the per-candidate
    /// scorer even if the artifact exists, `N > 1` = require the artifact
    /// at exactly `N` lanes (error otherwise — the lane count is baked into
    /// the HLO at AOT time; rebuild with `AMQ_SCORE_LANES=N make artifacts`
    /// to change it).
    pub fn load_with_lanes(
        artifacts_dir: &Path,
        weights: &WeightStore,
        lanes_request: usize,
    ) -> Result<Runtime> {
        Self::load_with_opts(artifacts_dir, weights, lanes_request, SlabGatherMode::Auto)
    }

    /// Load with explicit lane *and* slab-gather requests
    /// (`--lanes` / `--slab-gather`; see [`Runtime::load_with_lanes`] and
    /// [`SlabGatherMode`] for the request semantics).
    pub fn load_with_opts(
        artifacts_dir: &Path,
        weights: &WeightStore,
        lanes_request: usize,
        gather_mode: SlabGatherMode,
    ) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;

        let compile = |key: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest.hlo_path(key)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| eyre::anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let fp_exec = compile("model_fp")?;
        let quant_exec = compile("model_quant")?;
        let scores_exec = compile("scores_quant")?;

        let fp_plan = plan_args(&manifest, &manifest.executable("model_fp")?.args)?;
        let quant_plan = plan_args(&manifest, &manifest.executable("model_quant")?.args)?;
        let scores_plan = plan_args(&manifest, &manifest.executable("scores_quant")?.args)?;

        let lanes = resolve_lanes(&manifest, lanes_request)?;
        let (lanes_exec, lanes_plan) = match lanes {
            Some(_) => (
                Some(compile("scores_quant_lanes")?),
                plan_args(&manifest, &manifest.executable("scores_quant_lanes")?.args)?,
            ),
            None => (None, Vec::new()),
        };

        // Slab-gather executables: one per shape family, compiled once.
        // `resolve_gather` already validated completeness/consistency, so
        // this only compiles what the manifest promises.
        let mut gather_execs = HashMap::new();
        if resolve_gather(&manifest, lanes, gather_mode)? {
            for (n, k) in manifest.shape_families() {
                gather_execs.insert((n, k), compile(&Manifest::gather_key(n, k))?);
            }
        }

        let mut rt = Runtime {
            manifest,
            client,
            fp_exec,
            quant_exec,
            scores_exec,
            lanes_exec,
            gather_execs,
            fp_plan,
            quant_plan,
            scores_plan,
            lanes_plan,
            lanes: lanes.unwrap_or(1),
            fp_param_bufs: HashMap::new(),
            stats: Mutex::new(RuntimeStats::default()),
        };
        rt.upload_fp_params(weights)?;
        Ok(rt)
    }

    /// Upload (or replace) the resident fp parameter buffers.
    pub fn upload_fp_params(&mut self, weights: &WeightStore) -> Result<()> {
        let mut bufs = HashMap::new();
        let names: Vec<String> = self
            .fp_plan
            .iter()
            .chain(&self.quant_plan)
            .chain(&self.scores_plan)
            .chain(&self.lanes_plan)
            .filter_map(|s| match s {
                ArgSlot::FpParam(n) => Some(n.clone()),
                _ => None,
            })
            .collect();
        for name in names {
            if bufs.contains_key(&name) {
                continue;
            }
            let (shape, data) = weights.get(&name)?;
            let buf = self.upload_f32(data, shape)?;
            bufs.insert(name, buf);
        }
        self.fp_param_bufs = bufs;
        Ok(())
    }

    /// Sequences per executable call (the fixed AOT batch shape).
    pub fn batch_size(&self) -> usize {
        self.manifest.eval_batch
    }

    /// Tokens per sequence (the fixed AOT sequence length).
    pub fn seq_len(&self) -> usize {
        self.manifest.model.seq_len
    }

    /// Vocabulary size of the subject model.
    pub fn vocab(&self) -> usize {
        self.manifest.model.vocab_size
    }

    /// Which scorer executable *multi-candidate* chunks dispatch through
    /// (the evaluator routes on the shared [`lane_routed`] predicate).
    /// Single-candidate chunks always take the per-candidate path
    /// (resident buffers, no slab packing), so a lane-stacked runtime
    /// driven only by 1-candidate chunks (e.g. `--score-batch 1`) reports
    /// this variant with `lane_dispatches = 0` — the stats, not the
    /// variant, say what actually ran.
    pub fn scorer_variant(&self) -> ScorerVariant {
        if self.lanes_exec.is_some() {
            ScorerVariant::LaneStacked { lanes: self.lanes }
        } else {
            ScorerVariant::PerCandidate
        }
    }

    /// Snapshot of the wall-clock/dispatch counters.
    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Zero all counters (bench harnesses).
    pub fn reset_stats(&self) {
        *self.stats.lock().unwrap() = RuntimeStats::default();
    }

    // -- uploads ----------------------------------------------------------

    /// Upload an f32 host array as a device buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.stats.lock().unwrap().upload_bytes += (data.len() * 4) as u64;
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 host array as a device buffer.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.stats.lock().unwrap().upload_bytes += (data.len() * 4) as u64;
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i8 host array as a device buffer.
    pub fn upload_i8(&self, data: &[i8], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.stats.lock().unwrap().upload_bytes += data.len() as u64;
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload one quantized layer (codes as int8 + f32 scale/zero).
    /// The AOT kernel consumes s8 codes; grouped codes are <= 15 so the
    /// u8 -> i8 conversion is lossless (asserted).  No host copies are
    /// retained: lane-slab packing borrows rows from the proxy bank's host
    /// pieces instead ([`Runtime::upload_lane_slab`]), so the host bank is
    /// resident exactly once whatever scorer variant runs.
    pub fn upload_quant_layer(&self, q: &QuantizedLinear) -> Result<QuantLayerBufs> {
        let n = q.out_features;
        let k = q.in_features;
        let g = q.n_groups();
        eyre::ensure!(q.bits <= 4, "AOT kernel path supports <= 4-bit codes");
        let codes_i8: Vec<i8> = q.codes.iter().map(|&c| c as i8).collect();
        Ok(QuantLayerBufs {
            codes: self.upload_i8(&codes_i8, &[n, k])?,
            scale: self.upload_f32(&q.scale, &[n, g])?,
            zero: self.upload_f32(&q.zero, &[n, g])?,
            bits: q.bits,
            rows: n,
            cols: k,
            groups: g,
        })
    }

    /// Pack one candidate group's pieces at one layer into a `[lanes, ...]`
    /// slab set and upload it.  `pieces` are **borrowed** straight from the
    /// proxy bank (or any host-side [`QuantizedLinear`]s) — zero host
    /// copies beyond the transient packed slab itself; partial groups are
    /// padded by repeating lane 0 ([`pack_lane_slab`]).  Requires the
    /// lane-stacked executable; all pieces must share lane 0's geometry.
    pub fn upload_lane_slab(&self, pieces: &[&QuantizedLinear]) -> Result<LaneSlabBufs> {
        eyre::ensure!(
            self.lanes_exec.is_some(),
            "lane-slab upload without a lane-stacked executable"
        );
        let lanes = self.lanes;
        let lead = pieces
            .first()
            .ok_or_else(|| eyre::anyhow!("lane slab needs at least one piece"))?;
        let (n, k, g) = (lead.out_features, lead.in_features, lead.n_groups());
        for p in pieces {
            eyre::ensure!(p.bits <= 4, "AOT kernel path supports <= 4-bit codes");
            eyre::ensure!(
                p.out_features == n && p.in_features == k && p.n_groups() == g,
                "lane slab pieces must share lane 0's geometry"
            );
        }
        let code_rows: Vec<&[u8]> = pieces.iter().map(|p| p.codes.as_slice()).collect();
        let codes: Vec<i8> =
            pack_lane_slab(&code_rows, lanes)?.iter().map(|&c| c as i8).collect();
        let scale_rows: Vec<&[f32]> = pieces.iter().map(|p| p.scale.as_slice()).collect();
        let scale = pack_lane_slab(&scale_rows, lanes)?;
        let zero_rows: Vec<&[f32]> = pieces.iter().map(|p| p.zero.as_slice()).collect();
        let zero = pack_lane_slab(&zero_rows, lanes)?;
        let bytes = codes.len() + (scale.len() + zero.len()) * 4;
        Ok(LaneSlabBufs {
            codes: self.upload_i8(&codes, &[lanes, n, k])?,
            scale: self.upload_f32(&scale, &[lanes, n, g])?,
            zero: self.upload_f32(&zero, &[lanes, n, g])?,
            bytes,
        })
    }

    /// Whether lane-slab cache misses route through the device-side gather
    /// executables (vs. host pack + upload).  Decided once at load time
    /// from the artifacts and the `--slab-gather` mode.
    pub fn slab_gather_enabled(&self) -> bool {
        !self.gather_execs.is_empty()
    }

    /// Assemble one candidate group's lane slab **on device**: one gather
    /// dispatch reading the already-resident bank buffers, producing the
    /// same padded `[lanes, ...]` slab set [`Runtime::upload_lane_slab`]
    /// would build on the host — lane-0 padding semantics identical to
    /// [`pack_lane_slab`], zero host→device bytes.  All pieces must share
    /// lane 0's geometry; the group's shape family must have a gather
    /// executable (guaranteed complete by load-time validation).
    pub fn gather_lane_slab(&self, pieces: &[&QuantLayerBufs]) -> Result<LaneSlabBufs> {
        let lanes = self.lanes;
        let lead = pieces
            .first()
            .ok_or_else(|| eyre::anyhow!("lane slab needs at least one piece"))?;
        let (n, k, g) = (lead.rows, lead.cols, lead.groups);
        eyre::ensure!(
            pieces.len() <= lanes,
            "lane slab overflow: {} pieces for {lanes} lanes",
            pieces.len()
        );
        for p in pieces {
            eyre::ensure!(p.bits <= 4, "AOT kernel path supports <= 4-bit codes");
            eyre::ensure!(
                p.rows == n && p.cols == k && p.groups == g,
                "lane slab pieces must share lane 0's geometry"
            );
        }
        let exec = self.gather_execs.get(&(n, k)).ok_or_else(|| {
            eyre::anyhow!(
                "no slab-gather executable for shape family {n}x{k} \
                 (slab gather disabled or artifacts incomplete)"
            )
        })?;
        // Lane-major (codes, scale, zero) triples, partial groups padded by
        // repeating lane 0 — the manifest `args` contract of the gather
        // executables, mirroring pack_lane_slab's padded layout.
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(3 * lanes);
        for lane in 0..lanes {
            let p = pieces.get(lane).copied().unwrap_or(pieces[0]);
            args.push(&p.codes);
            args.push(&p.scale);
            args.push(&p.zero);
        }
        let t0 = Instant::now();
        let mut res = exec.execute_b(&args)?;
        eyre::ensure!(!res.is_empty(), "gather executable returned no device results");
        let outs = res.swap_remove(0);
        eyre::ensure!(
            outs.len() == 3,
            "gather executable returned {} output buffers, expected 3 \
             (codes, scale, zero)",
            outs.len()
        );
        // What upload_lane_slab would have pushed over the host→device
        // link for the same slab set (i8 codes + f32 scale/zero).
        let bytes = lanes * (n * k + 2 * n * g * 4);
        {
            let mut s = self.stats.lock().unwrap();
            s.gather_dispatches += 1;
            s.gather_time += t0.elapsed();
            s.slab_upload_bytes_avoided += bytes as u64;
        }
        let mut outs = outs.into_iter();
        Ok(LaneSlabBufs {
            codes: outs.next().expect("len checked"),
            scale: outs.next().expect("len checked"),
            zero: outs.next().expect("len checked"),
            bytes,
        })
    }

    /// Upload a named set of fp weight overrides ([out,in] row-major mats).
    pub fn upload_weight_overrides(
        &self,
        overrides: &[(String, crate::tensor::Mat)],
    ) -> Result<HashMap<String, xla::PjRtBuffer>> {
        let mut out = HashMap::new();
        for (name, mat) in overrides {
            out.insert(
                name.clone(),
                self.upload_f32(&mat.data, &[mat.rows, mat.cols])?,
            );
        }
        Ok(out)
    }

    // -- fp path ----------------------------------------------------------

    /// Run the fp executable with the resident weights.
    pub fn fp_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.fp_logits_with(tokens, &HashMap::new())
    }

    /// Run the fp executable with some weights overridden (baselines:
    /// BitStack / PB-LLM / fixed-precision reconstructions).
    pub fn fp_logits_with(
        &self,
        tokens: &[i32],
        overrides: &HashMap<String, xla::PjRtBuffer>,
    ) -> Result<Vec<f32>> {
        let b = self.batch_size();
        let t = self.seq_len();
        eyre::ensure!(tokens.len() == b * t, "tokens must be [{b},{t}]");
        let tok_buf = self.upload_i32(tokens, &[b, t])?;
        self.fp_logits_exec(&tok_buf, overrides)
    }

    /// Run the fp executable against a prepared batch's resident token
    /// buffer — zero host→device copies (vs. [`Runtime::fp_logits`], which
    /// re-uploads the tokens on every call).
    pub fn fp_logits_for_batch(
        &self,
        batch: &ScoreBatch,
        overrides: &HashMap<String, xla::PjRtBuffer>,
    ) -> Result<Vec<f32>> {
        self.fp_logits_exec(&batch.tokens, overrides)
    }

    fn fp_logits_exec(
        &self,
        tok_buf: &xla::PjRtBuffer,
        overrides: &HashMap<String, xla::PjRtBuffer>,
    ) -> Result<Vec<f32>> {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.fp_plan.len());
        for slot in &self.fp_plan {
            match slot {
                ArgSlot::Tokens => args.push(tok_buf),
                ArgSlot::FpParam(name) => {
                    let buf = overrides.get(name).or_else(|| self.fp_param_bufs.get(name));
                    args.push(buf.ok_or_else(|| eyre::anyhow!("missing fp param {name}"))?)
                }
                other => eyre::bail!("unexpected slot {other:?} in fp plan"),
            }
        }
        let t0 = Instant::now();
        let out = self.fp_exec.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        {
            let mut s = self.stats.lock().unwrap();
            s.fp_calls += 1;
            s.fp_time += t0.elapsed();
        }
        let logits = lit.to_tuple1()?;
        Ok(logits.to_vec::<f32>()?)
    }

    /// Prepare a resident evaluation batch: computes fp logits and uploads
    /// tokens/mask/fp_logits once.
    pub fn prepare_batch(&self, tokens: &[i32], mask: &[f32]) -> Result<ScoreBatch> {
        let b = self.batch_size();
        let t = self.seq_len();
        eyre::ensure!(tokens.len() == b * t && mask.len() == b * t);
        let fp = self.fp_logits(tokens)?;
        Ok(ScoreBatch {
            tokens: self.upload_i32(tokens, &[b, t])?,
            mask: self.upload_f32(mask, &[b, t])?,
            fp_logits: self.upload_f32(&fp, &[b, t, self.vocab()])?,
            host_tokens: tokens.to_vec(),
            host_mask: mask.to_vec(),
            host_fp_logits: fp,
        })
    }

    // -- quant path -------------------------------------------------------

    /// Fused scorer: (mean JSD vs fp, mean CE) for an assembled candidate.
    /// `layers[i]` must follow manifest layer order.
    pub fn scores(&self, batch: &ScoreBatch, layers: &[&QuantLayerBufs]) -> Result<(f32, f32)> {
        Ok(self.scores_chunk(batch, &[layers])?[0])
    }

    /// Fused scorer over a *chunk* of assembled candidates on one batch —
    /// the **per-candidate** microbatch dispatch unit: static argument
    /// slots (tokens/mask/fp logits/fp params) are resolved once per chunk
    /// and per-candidate marshalling patches only the quant-slot positions
    /// to the resident bank buffers — zero uploads, one device call per
    /// candidate.  Results are per-candidate, in input order.
    ///
    /// Multi-candidate chunks on a lane-stacked runtime go through
    /// [`Runtime::scores_lane_chunk`] instead (the packing sources live on
    /// the proxy bank, so the routing decision belongs to the caller — see
    /// `coordinator::proxy::mean_jsd_batch` and the shared [`lane_routed`]
    /// predicate); both paths are bit-identical per candidate.
    ///
    /// The stats lock is taken once per chunk, not once per candidate.
    pub fn scores_chunk(
        &self,
        batch: &ScoreBatch,
        candidates: &[&[&QuantLayerBufs]],
    ) -> Result<Vec<(f32, f32)>> {
        if candidates.is_empty() {
            return Ok(Vec::new());
        }
        for layers in candidates {
            eyre::ensure!(layers.len() == self.manifest.layers.len());
        }
        self.scores_chunk_per_candidate(batch, candidates)
    }

    fn scores_chunk_per_candidate(
        &self,
        batch: &ScoreBatch,
        candidates: &[&[&QuantLayerBufs]],
    ) -> Result<Vec<(f32, f32)>> {
        let mut out = Vec::with_capacity(candidates.len());
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.scores_plan.len());
        // (argument position, layer index, 0=codes 1=scale 2=zero)
        let mut quant_slots: Vec<(usize, usize, u8)> = Vec::new();
        for (pos, slot) in self.scores_plan.iter().enumerate() {
            match slot {
                ArgSlot::Tokens => args.push(&batch.tokens),
                ArgSlot::Mask => args.push(&batch.mask),
                ArgSlot::FpLogits => args.push(&batch.fp_logits),
                ArgSlot::FpParam(name) => args.push(
                    self.fp_param_bufs
                        .get(name)
                        .ok_or_else(|| eyre::anyhow!("missing fp param {name}"))?,
                ),
                ArgSlot::Quant(li, part) => {
                    quant_slots.push((pos, *li, *part));
                    // placeholder, patched per candidate below
                    args.push(&batch.tokens);
                }
            }
        }
        let mut calls = 0u64;
        let mut spent = Duration::ZERO;
        for layers in candidates {
            for &(pos, li, part) in &quant_slots {
                let l = layers[li];
                args[pos] = match part {
                    0 => &l.codes,
                    1 => &l.scale,
                    _ => &l.zero,
                };
            }
            let t0 = Instant::now();
            let res = self.scores_exec.execute_b(&args)?;
            let lit = res[0][0].to_literal_sync()?;
            calls += 1;
            spent += t0.elapsed();
            let (jsd, ce) = lit.to_tuple2()?;
            out.push((jsd.to_vec::<f32>()?[0], ce.to_vec::<f32>()?[0]));
        }
        {
            let mut s = self.stats.lock().unwrap();
            s.scores_calls += calls;
            s.scores_time += spent;
        }
        Ok(out)
    }

    /// Fused scorer over a resolved [`LaneChunkPlan`] on one batch: one
    /// device dispatch per lane group, static slots fed from the resident
    /// batch/param buffers and quant slots from the plan's pinned slabs —
    /// **zero uploads per call** (all upload work happened when the plan
    /// was built, typically amortized away by the [`SlabCache`]).  Padded
    /// lanes' outputs are discarded; per-lane results are bitwise identical
    /// to [`Runtime::scores`] on the same candidate.
    ///
    /// Call the plan against every calibration batch: that is what makes
    /// multi-batch lane scoring cost one upload per *distinct slab* per
    /// search instead of per `(slab, batch)` pair.
    pub fn scores_lane_chunk(
        &self,
        batch: &ScoreBatch,
        plan: &LaneChunkPlan,
    ) -> Result<Vec<(f32, f32)>> {
        let exec = self
            .lanes_exec
            .as_ref()
            .ok_or_else(|| eyre::anyhow!("lane dispatch without a lane-stacked executable"))?;
        let lanes = self.lanes;
        let mut out = Vec::with_capacity(plan.n_candidates);
        let mut dispatches = 0u64;
        let mut padded = 0u64;
        let mut spent = Duration::ZERO;
        for group in &plan.groups {
            eyre::ensure!(
                group.slabs.len() == self.manifest.layers.len(),
                "lane group resolved {} layer slabs, manifest has {}",
                group.slabs.len(),
                self.manifest.layers.len()
            );
            eyre::ensure!(
                group.real <= lanes,
                "lane group carries {} candidates for {lanes} lanes",
                group.real
            );
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.lanes_plan.len());
            for slot in &self.lanes_plan {
                match slot {
                    ArgSlot::Tokens => args.push(&batch.tokens),
                    ArgSlot::Mask => args.push(&batch.mask),
                    ArgSlot::FpLogits => args.push(&batch.fp_logits),
                    ArgSlot::FpParam(name) => args.push(
                        self.fp_param_bufs
                            .get(name)
                            .ok_or_else(|| eyre::anyhow!("missing fp param {name}"))?,
                    ),
                    ArgSlot::Quant(li, part) => {
                        let slab = &group.slabs[*li];
                        args.push(match part {
                            0 => &slab.codes,
                            1 => &slab.scale,
                            _ => &slab.zero,
                        });
                    }
                }
            }
            let t0 = Instant::now();
            let res = exec.execute_b(&args)?;
            let lit = res[0][0].to_literal_sync()?;
            dispatches += 1;
            padded += (lanes - group.real) as u64;
            spent += t0.elapsed();
            let (jsd, ce) = lit.to_tuple2()?;
            let jsd = jsd.to_vec::<f32>()?;
            let ce = ce.to_vec::<f32>()?;
            eyre::ensure!(
                jsd.len() == lanes && ce.len() == lanes,
                "lane scorer returned {} lanes, expected {lanes}",
                jsd.len()
            );
            // keep real lanes, discard the lane-0 padding copies
            for (&j, &c) in jsd.iter().zip(&ce).take(group.real) {
                out.push((j, c));
            }
        }
        {
            let mut s = self.stats.lock().unwrap();
            s.lane_dispatches += dispatches;
            s.lane_candidates += plan.n_candidates as u64;
            s.lane_padded += padded;
            s.lane_time += spent;
        }
        Ok(out)
    }

    /// Quantized-model logits (task evaluation path).
    pub fn quant_logits(&self, tokens: &[i32], layers: &[&QuantLayerBufs]) -> Result<Vec<f32>> {
        let b = self.batch_size();
        let t = self.seq_len();
        eyre::ensure!(tokens.len() == b * t);
        let tok_buf = self.upload_i32(tokens, &[b, t])?;
        self.quant_logits_exec(&tok_buf, layers)
    }

    /// Quantized-model logits against a prepared batch's resident token
    /// buffer — zero host→device copies (vs. [`Runtime::quant_logits`],
    /// which re-uploads the tokens on every call).
    pub fn quant_logits_for_batch(
        &self,
        batch: &ScoreBatch,
        layers: &[&QuantLayerBufs],
    ) -> Result<Vec<f32>> {
        self.quant_logits_exec(&batch.tokens, layers)
    }

    fn quant_logits_exec(
        &self,
        tok_buf: &xla::PjRtBuffer,
        layers: &[&QuantLayerBufs],
    ) -> Result<Vec<f32>> {
        eyre::ensure!(layers.len() == self.manifest.layers.len());
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.quant_plan.len());
        for slot in &self.quant_plan {
            match slot {
                ArgSlot::Tokens => args.push(tok_buf),
                ArgSlot::FpParam(name) => args.push(
                    self.fp_param_bufs
                        .get(name)
                        .ok_or_else(|| eyre::anyhow!("missing fp param {name}"))?,
                ),
                ArgSlot::Quant(li, part) => {
                    let l = layers[*li];
                    args.push(match part {
                        0 => &l.codes,
                        1 => &l.scale,
                        _ => &l.zero,
                    });
                }
                other => eyre::bail!("unexpected slot {other:?} in quant plan"),
            }
        }
        let t0 = Instant::now();
        let out = self.quant_exec.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        {
            let mut s = self.stats.lock().unwrap();
            s.quant_calls += 1;
            s.quant_time += t0.elapsed();
        }
        let logits = lit.to_tuple1()?;
        Ok(logits.to_vec::<f32>()?)
    }
}

/// The [`ScorerVariant`] a runtime loaded from `manifest` with this lane
/// request would dispatch through — pure planning over the manifest, usable
/// (and tested) without a PJRT device.  Request semantics as in
/// [`Runtime::load_with_lanes`].
pub fn planned_scorer_variant(
    manifest: &Manifest,
    lanes_request: usize,
) -> Result<ScorerVariant> {
    Ok(match resolve_lanes(manifest, lanes_request)? {
        Some(lanes) => ScorerVariant::LaneStacked { lanes },
        None => ScorerVariant::PerCandidate,
    })
}

/// Resolve the effective lane width from the manifest and the CLI request
/// (see [`Runtime::load_with_lanes`] for the request semantics).
fn resolve_lanes(manifest: &Manifest, lanes_request: usize) -> Result<Option<usize>> {
    let artifact = manifest.scorer_lanes();
    match lanes_request {
        0 => Ok(artifact),
        1 => Ok(None),
        n => match artifact {
            Some(l) if l == n => Ok(Some(l)),
            Some(l) => eyre::bail!(
                "lane-stacked scorer artifact has {l} lanes but --lanes {n} was \
                 requested; rebuild with `AMQ_SCORE_LANES={n} make artifacts`"
            ),
            None => eyre::bail!(
                "--lanes {n} requested but the artifacts carry no lane-stacked \
                 scorer; rebuild with `AMQ_SCORE_LANES={n} make artifacts`"
            ),
        },
    }
}

/// Whether a runtime loaded from `manifest` with this lane request and
/// gather mode would route lane-slab misses through the device-side gather
/// executables — pure planning over the manifest, usable (and tested)
/// without a PJRT device.
pub fn planned_slab_gather(
    manifest: &Manifest,
    lanes_request: usize,
    gather_mode: SlabGatherMode,
) -> Result<bool> {
    let lanes = resolve_lanes(manifest, lanes_request)?;
    resolve_gather(manifest, lanes, gather_mode)
}

/// The manifest `args` contract of a slab-gather executable: lane-major
/// `(codes, scale, zero)` triples.
fn gather_args(lanes: usize) -> Vec<String> {
    (0..lanes)
        .flat_map(|i| {
            ["codes", "scale", "zero"].iter().map(move |p| format!("lane{i}.{p}"))
        })
        .collect()
}

/// Resolve whether slab gather is active, given the already-resolved lane
/// width.  Semantics:
///  * `Off` → never;
///  * no lane-stacked scorer → never (`Require` errors: slabs only exist
///    at `lanes > 1`);
///  * no gather entries in the manifest → legacy fallback to host packing
///    (`Require` errors with a rebuild hint);
///  * entries present → they must be complete (every shape family) and
///    consistent (lane count matches the scorer, canonical args/outputs),
///    else the artifacts are corrupt and loading fails loudly in every
///    mode rather than silently re-entering the upload path.
fn resolve_gather(
    manifest: &Manifest,
    lanes: Option<usize>,
    mode: SlabGatherMode,
) -> Result<bool> {
    if mode == SlabGatherMode::Off {
        return Ok(false);
    }
    let Some(lanes) = lanes else {
        eyre::ensure!(
            mode != SlabGatherMode::Require,
            "--slab-gather require needs the lane-stacked scorer: lane slabs \
             only exist at lanes > 1 (check --lanes and the artifacts)"
        );
        return Ok(false);
    };
    let families = manifest.shape_families();
    let present = families
        .iter()
        .filter(|&&(n, k)| manifest.gather_executable(n, k).is_some())
        .count();
    if present == 0 {
        eyre::ensure!(
            mode != SlabGatherMode::Require,
            "--slab-gather require, but the artifacts carry no slab-gather \
             executables; rebuild with `AMQ_SLAB_GATHER=1 make artifacts`"
        );
        return Ok(false);
    }
    let want_args = gather_args(lanes);
    for &(n, k) in &families {
        let key = Manifest::gather_key(n, k);
        let e = manifest.gather_executable(n, k).ok_or_else(|| {
            eyre::anyhow!(
                "slab-gather artifacts incomplete: missing `{key}` \
                 ({present} of {} shape families present); rebuild with \
                 `make artifacts`",
                families.len()
            )
        })?;
        eyre::ensure!(
            e.lanes == Some(lanes),
            "`{key}` was built for {:?} lanes but the scorer runs {lanes}; \
             rebuild with `AMQ_SCORE_LANES={lanes} make artifacts`",
            e.lanes
        );
        eyre::ensure!(
            e.args == want_args,
            "`{key}` argument order differs from the lane-major \
             (codes, scale, zero) contract; rebuild with `make artifacts`"
        );
        eyre::ensure!(
            e.outputs == ["codes", "scale", "zero"],
            "`{key}` outputs differ from (codes, scale, zero); rebuild \
             with `make artifacts`"
        );
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        crate::data::Manifest::from_json(
            r#"{
            "model": {"vocab_size": 512, "d_model": 128, "n_layers": 1,
                      "n_heads": 4, "d_ff": 256, "seq_len": 128,
                      "rope_theta": 10000.0, "rms_eps": 1e-5},
            "group_size": 128, "bit_choices": [2,3,4], "eval_batch": 16,
            "layers": [{"name": "blk0.q", "out_features": 128, "in_features": 128}],
            "fp_side_names": ["embed"],
            "executables": {}, "files": {}
        }"#,
        )
        .unwrap()
    }

    fn lanes_manifest(lanes: usize) -> Manifest {
        crate::data::Manifest::from_json(&format!(
            r#"{{
            "model": {{"vocab_size": 512, "d_model": 128, "n_layers": 1,
                      "n_heads": 4, "d_ff": 256, "seq_len": 128,
                      "rope_theta": 10000.0, "rms_eps": 1e-5}},
            "group_size": 128, "bit_choices": [2,3,4], "eval_batch": 16,
            "layers": [{{"name": "blk0.q", "out_features": 128, "in_features": 128}}],
            "fp_side_names": ["embed"],
            "executables": {{
                "scores_quant_lanes": {{"file": "scores_quant_lanes{lanes}.hlo.txt",
                                       "args": ["tokens"], "outputs": ["jsd", "ce"],
                                       "lanes": {lanes}}}
            }}, "files": {{}}
        }}"#,
        ))
        .unwrap()
    }

    /// Lane-scorer manifest over two shape families (128x128, 128x256),
    /// with gather entries for `gather_fams` built at `gather_lanes` lanes.
    fn gather_manifest(
        lanes: usize,
        gather_fams: &[(usize, usize)],
        gather_lanes: usize,
    ) -> Manifest {
        let mut execs = vec![format!(
            r#""scores_quant_lanes": {{"file": "scores_quant_lanes{lanes}.hlo.txt",
                "args": ["tokens"], "outputs": ["jsd", "ce"], "lanes": {lanes}}}"#
        )];
        for &(n, k) in gather_fams {
            let args: Vec<String> = (0..gather_lanes)
                .flat_map(|i| {
                    ["codes", "scale", "zero"]
                        .iter()
                        .map(move |p| format!(r#""lane{i}.{p}""#))
                })
                .collect();
            execs.push(format!(
                r#""gather_lanes_{n}x{k}": {{
                    "file": "gather_lanes{gather_lanes}_{n}x{k}.hlo.txt",
                    "args": [{}],
                    "outputs": ["codes", "scale", "zero"],
                    "lanes": {gather_lanes}}}"#,
                args.join(", ")
            ));
        }
        crate::data::Manifest::from_json(&format!(
            r#"{{
            "model": {{"vocab_size": 512, "d_model": 128, "n_layers": 1,
                      "n_heads": 4, "d_ff": 256, "seq_len": 128,
                      "rope_theta": 10000.0, "rms_eps": 1e-5}},
            "group_size": 128, "bit_choices": [2,3,4], "eval_batch": 16,
            "layers": [
                {{"name": "blk0.q", "out_features": 128, "in_features": 128}},
                {{"name": "blk0.down", "out_features": 128, "in_features": 256}}
            ],
            "fp_side_names": ["embed"],
            "executables": {{{}}}, "files": {{}}
        }}"#,
            execs.join(",\n")
        ))
        .unwrap()
    }

    #[test]
    fn plan_args_classifies_slots() {
        let m = toy_manifest();
        let args: Vec<String> = [
            "tokens", "mask", "fp_logits", "embed",
            "blk0.q.codes", "blk0.q.scale", "blk0.q.zero",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let plan = plan_args(&m, &args).unwrap();
        assert_eq!(plan[0], ArgSlot::Tokens);
        assert_eq!(plan[1], ArgSlot::Mask);
        assert_eq!(plan[2], ArgSlot::FpLogits);
        assert_eq!(plan[3], ArgSlot::FpParam("embed".into()));
        assert_eq!(plan[4], ArgSlot::Quant(0, 0));
        assert_eq!(plan[5], ArgSlot::Quant(0, 1));
        assert_eq!(plan[6], ArgSlot::Quant(0, 2));
    }

    #[test]
    fn plan_args_rejects_unknown_layer() {
        let m = toy_manifest();
        assert!(plan_args(&m, &["blkX.q.codes".to_string()]).is_err());
    }

    #[test]
    fn scorer_variant_reporting() {
        let per = ScorerVariant::PerCandidate;
        assert_eq!(per.name(), "per-candidate");
        assert_eq!(per.lanes(), 1);
        let ls = ScorerVariant::LaneStacked { lanes: 8 };
        assert_eq!(ls.name(), "lane-stacked");
        assert_eq!(ls.lanes(), 8);
    }

    #[test]
    fn lane_routing_predicate() {
        // lane path needs a lane executable AND a multi-candidate chunk
        assert!(lane_routed(2, 8));
        assert!(lane_routed(13, 8));
        assert!(!lane_routed(1, 8), "single candidates stay per-candidate");
        assert!(!lane_routed(0, 8));
        assert!(!lane_routed(5, 1), "no lane executable");
    }

    #[test]
    fn lane_dispatch_accounting() {
        // per-candidate: one dispatch per config
        assert_eq!(lane_dispatch_count(5, 1), 5);
        assert_eq!(lane_padding(5, 1), 0);
        // full chunks: K <= L is exactly one dispatch
        assert_eq!(lane_dispatch_count(8, 8), 1);
        assert_eq!(lane_dispatch_count(3, 8), 1);
        assert_eq!(lane_padding(8, 8), 0);
        assert_eq!(lane_padding(3, 8), 5);
        // partial tail: pending % L != 0
        assert_eq!(lane_dispatch_count(13, 8), 2);
        assert_eq!(lane_padding(13, 8), 3);
        assert_eq!(lane_dispatch_count(0, 8), 0);
        assert_eq!(lane_padding(0, 8), 0);
    }

    #[test]
    fn pack_lane_slab_pads_with_lane_zero() {
        let a = [1i8, 2, 3];
        let b = [4i8, 5, 6];
        // full group: straight concatenation, candidate axis leading
        let full = pack_lane_slab(&[&a, &b], 2).unwrap();
        assert_eq!(full, vec![1, 2, 3, 4, 5, 6]);
        // partial group: tail lanes repeat lane 0
        let padded = pack_lane_slab(&[&a, &b], 4).unwrap();
        assert_eq!(padded, vec![1, 2, 3, 4, 5, 6, 1, 2, 3, 1, 2, 3]);
        // single candidate fills every lane with itself
        let solo = pack_lane_slab(&[&a[..]], 2).unwrap();
        assert_eq!(solo, vec![1, 2, 3, 1, 2, 3]);
    }

    #[test]
    fn pack_lane_slab_rejects_bad_groups() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32];
        assert!(pack_lane_slab::<f32>(&[], 4).is_err(), "empty group");
        assert!(pack_lane_slab(&[&a[..], &b[..]], 4).is_err(), "ragged rows");
        let c = [0.0f32; 2];
        assert!(
            pack_lane_slab(&[&a[..], &c[..], &c[..]], 2).is_err(),
            "overflowing group"
        );
    }

    #[test]
    fn resolve_lanes_auto_and_overrides() {
        let with = lanes_manifest(8);
        let without = toy_manifest();
        // auto: follow the artifact
        assert_eq!(resolve_lanes(&with, 0).unwrap(), Some(8));
        assert_eq!(resolve_lanes(&without, 0).unwrap(), None);
        // --lanes 1 forces per-candidate even when the artifact exists
        assert_eq!(resolve_lanes(&with, 1).unwrap(), None);
        // explicit N must match the baked-in lane count
        assert_eq!(resolve_lanes(&with, 8).unwrap(), Some(8));
        assert!(resolve_lanes(&with, 4).is_err());
        assert!(resolve_lanes(&without, 8).is_err());
    }

    #[test]
    fn slab_gather_mode_parse_and_name() {
        assert_eq!(SlabGatherMode::parse("auto").unwrap(), SlabGatherMode::Auto);
        assert_eq!(SlabGatherMode::parse("off").unwrap(), SlabGatherMode::Off);
        assert_eq!(
            SlabGatherMode::parse("require").unwrap(),
            SlabGatherMode::Require
        );
        assert!(SlabGatherMode::parse("on").is_err());
        assert_eq!(SlabGatherMode::default(), SlabGatherMode::Auto);
        assert_eq!(SlabGatherMode::Auto.name(), "auto");
        assert_eq!(SlabGatherMode::Off.name(), "off");
        assert_eq!(SlabGatherMode::Require.name(), "require");
    }

    #[test]
    fn gather_args_are_lane_major_triples() {
        assert_eq!(
            gather_args(2),
            vec![
                "lane0.codes",
                "lane0.scale",
                "lane0.zero",
                "lane1.codes",
                "lane1.scale",
                "lane1.zero"
            ]
        );
    }

    #[test]
    fn planned_slab_gather_legacy_manifests_fall_back() {
        use SlabGatherMode::*;
        // no lane scorer at all: slabs never exist
        let legacy = toy_manifest();
        assert!(!planned_slab_gather(&legacy, 0, Auto).unwrap());
        assert!(planned_slab_gather(&legacy, 0, Require).is_err());
        // lane scorer but no gather entries (PR-6-era artifacts): host pack
        let lanes_only = lanes_manifest(8);
        assert!(!planned_slab_gather(&lanes_only, 0, Auto).unwrap());
        assert!(!planned_slab_gather(&lanes_only, 0, Off).unwrap());
        let err = planned_slab_gather(&lanes_only, 0, Require).unwrap_err();
        assert!(err.to_string().contains("AMQ_SLAB_GATHER=1"), "{err}");
    }

    #[test]
    fn planned_slab_gather_routes_when_artifacts_complete() {
        use SlabGatherMode::*;
        let fams = [(128, 128), (128, 256)];
        let m = gather_manifest(8, &fams, 8);
        assert!(planned_slab_gather(&m, 0, Auto).unwrap());
        assert!(planned_slab_gather(&m, 8, Require).unwrap());
        // off always wins
        assert!(!planned_slab_gather(&m, 0, Off).unwrap());
        // forcing per-candidate scoring disables gather too (no slabs)
        assert!(!planned_slab_gather(&m, 1, Auto).unwrap());
        assert!(planned_slab_gather(&m, 1, Require).is_err());
    }

    #[test]
    fn planned_slab_gather_rejects_corrupt_artifacts() {
        use SlabGatherMode::*;
        // incomplete: only one of two shape families present
        let partial = gather_manifest(8, &[(128, 128)], 8);
        let err = planned_slab_gather(&partial, 0, Auto).unwrap_err();
        assert!(err.to_string().contains("incomplete"), "{err}");
        // lane count disagrees with the scorer
        let mismatched = gather_manifest(8, &[(128, 128), (128, 256)], 4);
        assert!(planned_slab_gather(&mismatched, 0, Auto).is_err());
    }

    #[test]
    fn lane_fill_fraction_accounting() {
        let mut s = RuntimeStats::default();
        assert_eq!(s.lane_fill_fraction(), 0.0);
        // 2 dispatches at 8 lanes carrying 13 candidates: 3 padded lanes
        s.lane_dispatches = 2;
        s.lane_candidates = 13;
        s.lane_padded = 3;
        assert!((s.lane_fill_fraction() - 13.0 / 16.0).abs() < 1e-12);
        assert_eq!(s.scorer_dispatches(), 2);
        s.scores_calls = 5;
        assert_eq!(s.scorer_dispatches(), 7);
    }

    #[test]
    fn lane_slab_sig_is_padded_and_canonical() {
        let a = vec![2u16, 7];
        let b = vec![3u16, 8];
        // padded with lane 0's gene, per layer
        assert_eq!(lane_slab_sig(&[a.clone(), b.clone()], 0, 4), vec![2, 3, 2, 2]);
        assert_eq!(lane_slab_sig(&[a.clone(), b.clone()], 1, 4), vec![7, 8, 7, 7]);
        // a group whose explicit tail repeats lane 0 keys identically —
        // same packed bytes, same slab-cache entry
        assert_eq!(
            lane_slab_sig(&[a.clone(), b, a.clone()], 0, 4),
            lane_slab_sig(&[a.clone(), vec![3, 8]], 0, 4)
        );
        // full group: no padding
        assert_eq!(lane_slab_sig(&[a.clone(), a], 0, 2), vec![2, 2]);
    }

    // -- slab cache (host-testable generic payload) ----------------------

    fn key(li: usize, sig: &[u16]) -> SlabKey {
        (li, sig.to_vec())
    }

    /// Build closure standing in for pack+upload: payload = the key echoed
    /// back, so a stale/wrong entry is detectable by the caller.
    fn build(li: usize, sig: &[u16], bytes: usize) -> Result<((usize, Vec<u16>), usize)> {
        Ok(((li, sig.to_vec()), bytes))
    }

    #[test]
    fn slab_cache_hits_and_exact_residency() {
        let cache: SlabCache<(usize, Vec<u16>)> = SlabCache::new(1000);
        let a = cache.get_or_build(key(0, &[2, 3]), || build(0, &[2, 3], 300)).unwrap();
        assert_eq!(*a, (0, vec![2, 3]));
        let b = cache.get_or_build(key(1, &[2, 3]), || build(1, &[2, 3], 400)).unwrap();
        assert_eq!(*b, (1, vec![2, 3]));
        // same key again: a hit returning the same Arc, no rebuild
        let a2 = cache
            .get_or_build(key(0, &[2, 3]), || panic!("hit must not rebuild"))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &a2));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 2, 0));
        // exact accounting: reported bytes == sum of live entry sizes
        assert_eq!(s.resident_bytes, 300 + 400);
        assert_eq!(s.resident_slabs, 2);
        assert_eq!(s.built_bytes, 700);
        assert!((s.hit_fraction() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.budget_bytes, 1000);
    }

    #[test]
    fn slab_cache_evicts_least_recently_used() {
        let cache: SlabCache<(usize, Vec<u16>)> = SlabCache::new(1000);
        cache.get_or_build(key(0, &[2]), || build(0, &[2], 400)).unwrap();
        cache.get_or_build(key(1, &[2]), || build(1, &[2], 400)).unwrap();
        // touch key 0 so key 1 becomes the LRU victim
        cache.get_or_build(key(0, &[2]), || panic!("hit")).unwrap();
        cache.get_or_build(key(2, &[2]), || build(2, &[2], 400)).unwrap();
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.resident_bytes, 800);
        // key 0 survived (it was touched), key 1 was evicted
        cache.get_or_build(key(0, &[2]), || panic!("0 must be resident")).unwrap();
        let mut rebuilt = false;
        cache
            .get_or_build(key(1, &[2]), || {
                rebuilt = true;
                build(1, &[2], 400)
            })
            .unwrap();
        assert!(rebuilt, "evicted key must rebuild");
    }

    #[test]
    fn slab_cache_budget_zero_bypasses_retention() {
        let cache: SlabCache<(usize, Vec<u16>)> = SlabCache::new(0);
        for _ in 0..3 {
            let v = cache.get_or_build(key(0, &[2]), || build(0, &[2], 100)).unwrap();
            assert_eq!(*v, (0, vec![2]), "bypass still returns correct content");
        }
        let s = cache.stats();
        assert_eq!(s.hits, 0, "budget 0 never retains, so never hits");
        assert_eq!(s.misses, 3);
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.resident_slabs, 0);
    }

    #[test]
    fn slab_cache_oversized_entry_returned_unstored() {
        let cache: SlabCache<(usize, Vec<u16>)> = SlabCache::new(100);
        cache.get_or_build(key(0, &[2]), || build(0, &[2], 80)).unwrap();
        // a slab bigger than the whole budget must not wipe the cache
        let big = cache.get_or_build(key(9, &[4]), || build(9, &[4], 500)).unwrap();
        assert_eq!(*big, (9, vec![4]));
        let s = cache.stats();
        assert_eq!(s.evictions, 0, "oversized entries evict nothing");
        assert_eq!(s.resident_bytes, 80, "prior resident entry survives");
        cache.get_or_build(key(0, &[2]), || panic!("must still be resident")).unwrap();
    }

    #[test]
    fn slab_cache_distinct_key_misses_build_concurrently() {
        // The latch regression test: two shards cold-missing *different*
        // keys must overlap their builds.  Each build closure waits on a
        // shared barrier, so the test deadlocks (and times out) if the
        // cache still serializes distinct-key builds under one lock.
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Barrier;
        let cache: Arc<SlabCache<(usize, Vec<u16>)>> = Arc::new(SlabCache::new(10_000));
        let rendezvous = Arc::new(Barrier::new(2));
        let builds = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..2)
            .map(|li| {
                let cache = cache.clone();
                let rendezvous = rendezvous.clone();
                let builds = builds.clone();
                std::thread::spawn(move || {
                    cache
                        .get_or_build(key(li, &[2]), || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            // both builds must be in flight at once
                            rendezvous.wait();
                            build(li, &[2], 100)
                        })
                        .unwrap()
                })
            })
            .collect();
        for (li, t) in threads.into_iter().enumerate() {
            assert_eq!(*t.join().unwrap(), (li, vec![2]));
        }
        // upload counts stay exact: one build per key, no duplicates
        assert_eq!(builds.load(Ordering::SeqCst), 2);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 2));
        assert_eq!(s.resident_slabs, 2);
        // and both entries are genuinely resident afterwards
        for li in 0..2 {
            cache.get_or_build(key(li, &[2]), || panic!("must hit")).unwrap();
        }
        assert_eq!(cache.stats().hits, 2);
    }

    #[test]
    fn slab_cache_same_key_concurrent_miss_builds_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let cache: Arc<SlabCache<(usize, Vec<u16>)>> = Arc::new(SlabCache::new(10_000));
        let builds = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let cache = cache.clone();
                let builds = builds.clone();
                std::thread::spawn(move || {
                    cache
                        .get_or_build(key(0, &[7]), || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            // stretch the build window so the other threads
                            // arrive while it is in flight
                            std::thread::sleep(std::time::Duration::from_millis(30));
                            build(0, &[7], 100)
                        })
                        .unwrap()
                })
            })
            .collect();
        let results: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
        for r in &results {
            assert_eq!(**r, (0, vec![7]));
        }
        // exactly one pack+upload no matter how many waiters piled on
        assert_eq!(builds.load(Ordering::SeqCst), 1);
        let s = cache.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 3, "latch waiters count as hits");
        assert_eq!(s.built_bytes, 100);
    }

    #[test]
    fn slab_cache_failed_build_propagates_to_waiters_and_retries() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let cache: SlabCache<(usize, Vec<u16>)> = SlabCache::new(1000);
        let attempts = AtomicUsize::new(0);
        let err = cache
            .get_or_build(key(0, &[2]), || -> Result<((usize, Vec<u16>), usize)> {
                attempts.fetch_add(1, Ordering::SeqCst);
                Err(eyre::anyhow!("upload failed"))
            })
            .unwrap_err();
        assert!(err.to_string().contains("upload failed"));
        // a failed build leaves no slot behind: the next lookup retries
        let v = cache.get_or_build(key(0, &[2]), || build(0, &[2], 100)).unwrap();
        assert_eq!(*v, (0, vec![2]));
        assert_eq!(attempts.load(Ordering::SeqCst), 1);
        let s = cache.stats();
        assert_eq!(s.misses, 2);
        assert_eq!(s.resident_slabs, 1);
    }

    #[test]
    fn lane_chunk_plan_validates_groups() {
        assert!(LaneChunkPlan::new(Vec::new()).is_err(), "empty plan");
        assert!(
            LaneChunkPlan::new(vec![LaneGroup { real: 0, slabs: Vec::new() }]).is_err(),
            "zero-real group"
        );
        let plan = LaneChunkPlan::new(vec![
            LaneGroup { real: 8, slabs: Vec::new() },
            LaneGroup { real: 5, slabs: Vec::new() },
        ])
        .unwrap();
        assert_eq!(plan.n_candidates(), 13);
        assert_eq!(plan.n_dispatches(), 2);
    }
}

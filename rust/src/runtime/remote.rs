//! TCP transport for the eval pool: shard *clients* the coordinator feeds
//! chunks through, and the shard *server* loop behind
//! `repro shard-serve --listen ADDR`.
//!
//! Protocol (see [`crate::runtime::wire`] for the frame layout): the server
//! greets each connection with `Hello { n_layers }`, then answers every
//! `Chunk { id, genes }` with either `Scores { id, scores }` (bit-exact
//! per-candidate f32s, input order) or `Error { id, message }` for a
//! *deterministic* evaluation failure (the connection stays usable).
//! Transport failures are a different axis entirely: the client reconnects
//! with bounded backoff and — because evaluations are pure functions of the
//! genes — simply resends the in-flight chunk.  A connection that stays dead
//! beyond the retry budget retires the feeder shard
//! ([`crate::runtime::ShardFlow::Retire`]); the pool requeues the chunk onto
//! its surviving shards.

use std::io::{self, Read};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::faults::{FaultKind, FaultPlan};
use super::wire::{read_frame, write_frame, WireMsg};
use super::ShardFlow;
use crate::coordinator::Config;

/// Default per-chunk read timeout for a [`RemoteShard`] (the *reply* axis —
/// distinct from the connect-time [`RetryPolicy`]).  A hung server that
/// accepted the chunk but never answers must not stall a feeder forever:
/// after this long without a reply byte, the call fails as a transport
/// error, the feeder retires, and the pool requeues the chunk onto its
/// surviving shards.  Generous by design — a real artifact-backed chunk is
/// seconds, not minutes.
pub const DEFAULT_CHUNK_TIMEOUT: Duration = Duration::from_secs(300);

/// Default cap on simultaneously-open connections in [`serve_shard`]'s
/// concurrent accept loop.  Accepts beyond the cap wait for a slot instead
/// of spawning unboundedly.
pub const DEFAULT_LIVE_CONNS: usize = 64;

/// Bounded-backoff reconnect policy for a remote shard.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Connection attempts per (re)connect, minimum 1.
    pub attempts: u32,
    /// Delay before the second attempt; doubles per attempt thereafter.
    pub base_delay: Duration,
    /// Ceiling on the per-attempt delay.
    pub max_delay: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 4,
            base_delay: Duration::from_millis(100),
            max_delay: Duration::from_secs(1),
        }
    }
}

impl RetryPolicy {
    /// Backoff before attempt `i` (0-based; attempt 0 is immediate).
    fn delay(&self, attempt: u32) -> Duration {
        if attempt == 0 {
            Duration::ZERO
        } else {
            let factor = 1u32 << (attempt - 1).min(16);
            self.base_delay.saturating_mul(factor).min(self.max_delay)
        }
    }
}

/// Client half of one coordinator→shard connection.  Owns the stream and
/// the chunk-id counter; reconnects (and resends the in-flight chunk — safe
/// because evaluations are pure) on transport errors.
pub struct RemoteShard {
    addr: String,
    policy: RetryPolicy,
    /// Per-chunk reply deadline (`None` = wait forever).  Distinct from the
    /// connect-time `policy`: this bounds how long an *accepted* chunk may
    /// go unanswered before the call fails as a transport error.
    chunk_timeout: Option<Duration>,
    /// Deterministic client-side fault injection (tests/chaos only): one
    /// seeded decision per `call`, perturbing this feeder's transport.
    fault_plan: Option<Arc<FaultPlan>>,
    stream: Option<TcpStream>,
    next_id: u64,
}

impl RemoteShard {
    pub fn new(addr: impl Into<String>, policy: RetryPolicy) -> Self {
        RemoteShard {
            addr: addr.into(),
            policy,
            chunk_timeout: Some(DEFAULT_CHUNK_TIMEOUT),
            fault_plan: None,
            stream: None,
            next_id: 0,
        }
    }

    /// Override the per-chunk reply deadline (`None` = wait forever).
    /// Applies from the next (re)connect — call before the first `call`.
    pub fn with_chunk_timeout(mut self, timeout: Option<Duration>) -> Self {
        self.chunk_timeout = timeout;
        self
    }

    /// Attach a seeded [`FaultPlan`] to this client.  Each `call` draws one
    /// decision; a triggered fault perturbs the *transport*, never the
    /// payload: `Delay` sleeps before sending, `Wedge` blocks on the plan's
    /// gate, `Drop` fails the call as a timeout without touching the wire,
    /// `Disconnect` kills the stream and fails as a connection reset.
    pub fn with_fault_plan(mut self, plan: Option<Arc<FaultPlan>>) -> Self {
        self.fault_plan = plan;
        self
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Connect (with backoff) and consume the server's `Hello`.  No-op when
    /// already connected.
    fn ensure_connected(&mut self) -> io::Result<()> {
        if self.stream.is_some() {
            return Ok(());
        }
        let mut last_err = None;
        for attempt in 0..self.policy.attempts.max(1) {
            let delay = self.policy.delay(attempt);
            if !delay.is_zero() {
                std::thread::sleep(delay);
            }
            match TcpStream::connect(&self.addr) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    // The reply deadline covers the hello too: a server that
                    // accepts but never greets is as hung as one that never
                    // scores.  (On timeout the read surfaces WouldBlock /
                    // TimedOut — both are transport errors here.)
                    let _ = stream.set_read_timeout(self.chunk_timeout);
                    let mut stream = stream;
                    match read_hello(&mut stream) {
                        Ok(_n_layers) => {
                            self.stream = Some(stream);
                            return Ok(());
                        }
                        Err(e) => last_err = Some(e),
                    }
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            io::Error::new(io::ErrorKind::Other, "no connection attempts made")
        }))
    }

    /// Score one chunk of gene vectors on the remote shard.
    ///
    /// The outer `io::Result` is the *transport* axis (connection dead
    /// beyond the retry budget — the caller should retire this shard).  The
    /// inner `Result<Vec<f32>, String>` is the *evaluation* axis: the
    /// remote's deterministic error text comes back as `Err(message)` with
    /// the connection still healthy.
    pub fn call(
        &mut self,
        genes: &[Vec<u16>],
    ) -> io::Result<std::result::Result<Vec<f32>, String>> {
        if let Some(plan) = &self.fault_plan {
            match plan.decide() {
                None => {}
                Some(FaultKind::Delay) => std::thread::sleep(plan.delay()),
                Some(FaultKind::Wedge) => plan.hold_wedge(),
                Some(FaultKind::Drop) => {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        "fault injection: reply dropped",
                    ));
                }
                Some(FaultKind::Disconnect) => {
                    self.stream = None;
                    return Err(io::Error::new(
                        io::ErrorKind::ConnectionReset,
                        "fault injection: transport disconnected",
                    ));
                }
            }
        }
        // One reconnect-and-resend cycle beyond the current connection:
        // either the existing stream works, or we rebuild it once (with the
        // policy's full backoff schedule) and resend the identical chunk.
        let mut retried = false;
        loop {
            self.ensure_connected()?;
            let id = self.next_id;
            match self.exchange(id, genes) {
                Ok(reply) => {
                    self.next_id += 1;
                    return Ok(reply);
                }
                Err(e) => {
                    self.stream = None;
                    if retried {
                        return Err(e);
                    }
                    retried = true;
                }
            }
        }
    }

    fn exchange(
        &mut self,
        id: u64,
        genes: &[Vec<u16>],
    ) -> io::Result<std::result::Result<Vec<f32>, String>> {
        let stream = self
            .stream
            .as_mut()
            .expect("exchange called without a connection");
        let msg = WireMsg::Chunk { id, genes: genes.to_vec() };
        write_frame(stream, &msg)?;
        let reply = read_frame(stream)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "shard closed the connection mid-call",
                )
            })?;
        match reply {
            WireMsg::Scores { id: rid, scores } => {
                if rid != id {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("reply id {rid} does not match request id {id}"),
                    ));
                }
                if scores.len() != genes.len() {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!(
                            "shard returned {} scores for {} candidates",
                            scores.len(),
                            genes.len()
                        ),
                    ));
                }
                Ok(Ok(scores))
            }
            WireMsg::Error { id: rid, message } => {
                if rid != id {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("error reply id {rid} does not match request id {id}"),
                    ));
                }
                Ok(Err(message))
            }
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected reply op {other:?}"),
            )),
        }
    }
}

/// Server-side lifetime counters for one `serve_shard` loop, accumulated
/// across all accepted connections (stats-probe connections included).
/// `busy` is wall time spent inside the eval closure only — transport and
/// queueing are excluded, which is exactly the gap the coordinator's
/// client-side estimate cannot see.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    /// Chunks answered with `Scores` (eval errors are not counted).
    pub completed: u64,
    /// Cumulative wall time inside the eval closure.
    pub busy: Duration,
    /// Connections accepted, stats probes included.
    pub conns: u64,
}

/// Server-side counters as reported by a shard over a
/// [`WireMsg::Stats`] frame — the decoded form of [`ServeStats`].
#[derive(Clone, Copy, Debug)]
pub struct ShardServerStats {
    /// Chunks the server answered with `Scores`.
    pub completed: u64,
    /// Microseconds the server spent inside its eval closure.
    pub busy_us: u64,
    /// Connections the server has accepted (this probe included).
    pub conns: u64,
}

/// Probe `addr` for server-side stats on a dedicated, freshly opened
/// connection, then drop it.
///
/// The server's accept loop is concurrent and its stats path never takes
/// the eval lock, so the probe answers even *mid-search* — while feeder
/// connections are open and a chunk is mid-eval.  `timeout` still bounds
/// the wait (a wedged server reports as unavailable rather than hanging).
/// Pre-stats servers reject the probe frame and drop the connection, which
/// also surfaces here as an error — callers should degrade to "server-side
/// stats unavailable", not treat it as a shard failure.
pub fn fetch_shard_stats(addr: &str, timeout: Duration) -> io::Result<ShardServerStats> {
    let stream = TcpStream::connect(addr)?;
    let _ = stream.set_nodelay(true);
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let mut stream = stream;
    read_hello(&mut stream)?;
    write_frame(&mut stream, &WireMsg::StatsReq { id: 0 })?;
    let reply = read_frame(&mut stream)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?
        .ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "shard closed the connection on stats probe (pre-stats server?)",
            )
        })?;
    match reply {
        WireMsg::Stats { id: 0, completed, busy_us, conns } => {
            Ok(ShardServerStats { completed, busy_us, conns })
        }
        WireMsg::Stats { id, .. } => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("stats reply id {id} does not match request id 0"),
        )),
        other => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unexpected stats reply op {other:?}"),
        )),
    }
}

pub(crate) fn read_hello<R: Read>(r: &mut R) -> io::Result<u64> {
    let msg = read_frame(r)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?
        .ok_or_else(|| {
            io::Error::new(io::ErrorKind::UnexpectedEof, "connection closed before hello")
        })?;
    match msg {
        WireMsg::Hello { n_layers } => Ok(n_layers),
        other => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("expected hello, got {other:?}"),
        )),
    }
}

/// Build the per-shard feeder closure the pool runs for one remote shard:
/// chunks go out as frames, scores come back as the pool's normal
/// `Result<Vec<f32>>` reply.  Evaluation errors from the remote are
/// *deterministic* and reported as `Reply(Err(..))` (requeueing them would
/// just fail again elsewhere — the search surfaces them like any local
/// eval error); transport death beyond the retry budget retires the shard,
/// so the pool requeues the in-flight chunk onto its surviving shards.
pub fn remote_eval_flow(
    addr: String,
    policy: RetryPolicy,
) -> Box<dyn FnMut(Vec<Config>) -> ShardFlow<crate::Result<Vec<f32>>>> {
    remote_eval_flow_with_timeout(addr, policy, Some(DEFAULT_CHUNK_TIMEOUT))
}

/// [`remote_eval_flow`] with an explicit per-chunk reply deadline (`None` =
/// wait forever — the pre-timeout behaviour).  A chunk that times out is a
/// transport failure: the feeder retires and the pool requeues the chunk,
/// so one hung server costs throughput, never results.
pub fn remote_eval_flow_with_timeout(
    addr: String,
    policy: RetryPolicy,
    chunk_timeout: Option<Duration>,
) -> Box<dyn FnMut(Vec<Config>) -> ShardFlow<crate::Result<Vec<f32>>>> {
    let mut shard = RemoteShard::new(addr, policy).with_chunk_timeout(chunk_timeout);
    Box::new(move |chunk: Vec<Config>| match shard.call(&chunk) {
        Ok(Ok(scores)) => ShardFlow::Reply(Ok(scores)),
        Ok(Err(message)) => ShardFlow::Reply(Err(eyre::anyhow!(
            "remote shard {} eval error: {message}",
            shard.addr()
        ))),
        Err(e) => ShardFlow::Retire {
            reason: format!("transport to {}: {e}", shard.addr()),
        },
    })
}

/// Serve chunk frames on `listener` until `max_conns` connections have been
/// accepted (`None` = forever).  `eval` scores a chunk of gene vectors; its
/// error text is sent back verbatim as an `Error` frame.  This is the loop
/// behind `repro shard-serve`.
///
/// The accept loop is *concurrent*: each connection gets its own handler
/// thread (capped at [`DEFAULT_LIVE_CONNS`] simultaneous connections —
/// accepts beyond the cap wait for a slot).  Evaluation itself stays
/// serialized behind a mutex — one shard process backs one device — but the
/// stats path never touches the eval lock, so a `fetch_shard_stats` probe
/// answers *while a feeder's chunk is mid-eval*: live mid-search stats
/// polling, not just post-run.  With `max_conns = Some(n)` the loop stops
/// accepting after `n` connections and joins every handler before
/// returning.
pub fn serve_shard<F>(
    listener: TcpListener,
    n_layers: u64,
    max_conns: Option<usize>,
    eval: F,
) -> crate::Result<()>
where
    F: FnMut(&[Vec<u16>]) -> crate::Result<Vec<f32>> + Send,
{
    serve_shard_capped(listener, n_layers, max_conns, DEFAULT_LIVE_CONNS, eval)
}

/// [`serve_shard`] with an explicit cap on simultaneously-open connections.
pub fn serve_shard_capped<F>(
    listener: TcpListener,
    n_layers: u64,
    max_conns: Option<usize>,
    live_cap: usize,
    eval: F,
) -> crate::Result<()>
where
    F: FnMut(&[Vec<u16>]) -> crate::Result<Vec<f32>> + Send,
{
    serve_shard_with_faults(listener, n_layers, max_conns, live_cap, None, eval)
}

/// [`serve_shard_capped`] with deterministic server-side fault injection —
/// the loop behind `repro shard-serve --fault-spec SEED:KIND:RATE`.  One
/// seeded decision is drawn per *chunk* (stats probes are never faulted);
/// a triggered fault perturbs the server's handling of that chunk:
///
///  * `Delay` — sleep before taking the eval lock (slow shard);
///  * `Wedge` — block on the plan's gate *before* the eval lock, so stats
///    probes keep answering and other connections keep evaluating while
///    this chunk hangs — exactly a wedged device, not a poisoned server;
///  * `Drop` — evaluate, then swallow the reply (the client's read times
///    out; connection stays open);
///  * `Disconnect` — evaluate, then close the connection without replying.
///
/// Faults never change evaluation results — the reply, when one is sent,
/// is bit-identical to the fault-free one.
pub fn serve_shard_with_faults<F>(
    listener: TcpListener,
    n_layers: u64,
    max_conns: Option<usize>,
    live_cap: usize,
    fault_plan: Option<Arc<FaultPlan>>,
    eval: F,
) -> crate::Result<()>
where
    F: FnMut(&[Vec<u16>]) -> crate::Result<Vec<f32>> + Send,
{
    let live_cap = live_cap.max(1);
    let eval = Mutex::new(eval);
    let stats = Mutex::new(ServeStats::default());
    // (live handler count, slot-freed signal) — the accept loop waits on
    // this pair instead of spawning past the cap.
    let live = (Mutex::new(0usize), Condvar::new());
    std::thread::scope(|scope| {
        let mut accepted = 0usize;
        for conn in listener.incoming() {
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[shard] accept failed: {e}");
                    continue;
                }
            };
            {
                let mut n = live.0.lock().unwrap();
                while *n >= live_cap {
                    n = live.1.wait(n).unwrap();
                }
                *n += 1;
            }
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "<unknown>".into());
            eprintln!("[shard] connection from {peer}");
            stats.lock().unwrap().conns += 1;
            let (eval, stats, live) = (&eval, &stats, &live);
            let plan = fault_plan.clone();
            scope.spawn(move || {
                if let Err(e) = serve_conn(stream, n_layers, eval, stats, plan) {
                    eprintln!("[shard] connection {peer} ended with error: {e}");
                } else {
                    eprintln!("[shard] connection {peer} closed");
                }
                *live.0.lock().unwrap() -= 1;
                live.1.notify_one();
            });
            accepted += 1;
            if let Some(max) = max_conns {
                if accepted >= max {
                    break;
                }
            }
        }
        // scope exit joins every in-flight handler
    });
    Ok(())
}

fn serve_conn<F>(
    stream: TcpStream,
    n_layers: u64,
    eval: &Mutex<F>,
    stats: &Mutex<ServeStats>,
    fault_plan: Option<Arc<FaultPlan>>,
) -> crate::Result<()>
where
    F: FnMut(&[Vec<u16>]) -> crate::Result<Vec<f32>> + Send,
{
    let _ = stream.set_nodelay(true);
    let mut stream = stream;
    write_frame(&mut stream, &WireMsg::Hello { n_layers })?;
    loop {
        let msg = match read_frame(&mut stream)? {
            None => return Ok(()), // clean EOF: coordinator hung up
            Some(m) => m,
        };
        // One fault decision per chunk; pre-eval kinds act here, post-eval
        // kinds (Drop/Disconnect) are deferred until the reply is built so
        // the eval itself (and its stats) stay identical to the clean path.
        let mut post_fault = None;
        let reply = match msg {
            WireMsg::Chunk { id, genes } => {
                if let Some(plan) = fault_plan.as_ref() {
                    match plan.decide() {
                        None => {}
                        Some(FaultKind::Delay) => std::thread::sleep(plan.delay()),
                        // Hold BEFORE the eval lock: a wedged chunk must
                        // look like a hung device, while stats probes and
                        // other connections keep working.
                        Some(FaultKind::Wedge) => plan.hold_wedge(),
                        Some(kind) => post_fault = Some(kind),
                    }
                }
                // Serialize evals across connections (one device behind the
                // shard); busy time is measured inside the lock so it stays
                // pure eval wall-clock, not lock contention.
                let (res, elapsed) = {
                    let mut eval = eval.lock().unwrap();
                    let t0 = Instant::now();
                    let res = eval(&genes);
                    (res, t0.elapsed())
                };
                let mut stats = stats.lock().unwrap();
                stats.busy += elapsed;
                match res {
                    Ok(scores) => {
                        if scores.len() != genes.len() {
                            WireMsg::Error {
                                id,
                                message: format!(
                                    "evaluator returned {} scores for {} candidates",
                                    scores.len(),
                                    genes.len()
                                ),
                            }
                        } else {
                            stats.completed += 1;
                            WireMsg::Scores { id, scores }
                        }
                    }
                    Err(e) => WireMsg::Error { id, message: e.to_string() },
                }
            }
            // Stats never wait on the eval lock: a probe answers while
            // another connection's chunk is mid-eval.
            WireMsg::StatsReq { id } => {
                let stats = stats.lock().unwrap();
                WireMsg::Stats {
                    id,
                    completed: stats.completed,
                    busy_us: stats.busy.as_micros() as u64,
                    conns: stats.conns,
                }
            }
            other => {
                eyre::bail!("unexpected client frame {other:?}");
            }
        };
        match post_fault {
            // Swallow the reply: the client's chunk read times out, but the
            // connection stays open for its reconnect-and-resend.
            Some(FaultKind::Drop) => continue,
            // Kill the connection without replying.
            Some(FaultKind::Disconnect) => return Ok(()),
            _ => {}
        }
        write_frame(&mut stream, &reply)?;
    }
}

/// Spawn a shard server for tests: binds a loopback port, serves `eval` on
/// a background thread, returns the bound address.  The thread exits after
/// `max_conns` connections (or runs until process exit for `None` —
/// listener threads are detached, matching how CI kills the server
/// processes).
pub fn spawn_test_server<F>(
    n_layers: u64,
    max_conns: Option<usize>,
    eval: F,
) -> crate::Result<String>
where
    F: FnMut(&[Vec<u16>]) -> crate::Result<Vec<f32>> + Send + 'static,
{
    spawn_test_server_with_faults(n_layers, max_conns, None, eval)
}

/// [`spawn_test_server`] with a server-side [`FaultPlan`] — the in-process
/// analogue of `repro shard-serve --fault-spec` for chaos tests.
pub fn spawn_test_server_with_faults<F>(
    n_layers: u64,
    max_conns: Option<usize>,
    fault_plan: Option<Arc<FaultPlan>>,
    eval: F,
) -> crate::Result<String>
where
    F: FnMut(&[Vec<u16>]) -> crate::Result<Vec<f32>> + Send + 'static,
{
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    std::thread::spawn(move || {
        if let Err(e) = serve_shard_with_faults(
            listener,
            n_layers,
            max_conns,
            DEFAULT_LIVE_CONNS,
            fault_plan,
            eval,
        ) {
            eprintln!("[shard] server loop failed: {e}");
        }
    });
    Ok(addr)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn double(genes: &[Vec<u16>]) -> crate::Result<Vec<f32>> {
        Ok(genes.iter().map(|g| g.iter().map(|&x| x as f32).sum::<f32>() * 2.0).collect())
    }

    #[test]
    fn client_server_round_trip() {
        let addr = spawn_test_server(0, Some(1), double).unwrap();
        let mut shard = RemoteShard::new(addr, RetryPolicy::default());
        let chunk = vec![vec![1u16, 2, 3], vec![10, 20]];
        let scores = shard.call(&chunk).unwrap().unwrap();
        assert_eq!(scores, vec![12.0, 60.0]);
        // second call reuses the connection; ids advance server-side too
        let scores = shard.call(&[vec![5u16]]).unwrap().unwrap();
        assert_eq!(scores, vec![10.0]);
    }

    #[test]
    fn eval_error_comes_back_as_message_not_transport_failure() {
        let addr = spawn_test_server(0, Some(1), |genes: &[Vec<u16>]| {
            eyre::ensure!(genes.len() != 2, "no pairs allowed");
            double(genes)
        })
        .unwrap();
        let mut shard = RemoteShard::new(addr, RetryPolicy::default());
        let err = shard.call(&[vec![1u16], vec![2]]).unwrap().unwrap_err();
        assert!(err.contains("no pairs allowed"), "got: {err}");
        // connection survives the eval error
        let ok = shard.call(&[vec![3u16]]).unwrap().unwrap();
        assert_eq!(ok, vec![6.0]);
    }

    #[test]
    fn dead_address_errors_after_bounded_retries() {
        // A listener bound then dropped: the port refuses connections.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let fast = RetryPolicy {
            attempts: 2,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(2),
        };
        let mut shard = RemoteShard::new(addr, fast);
        assert!(shard.call(&[vec![1u16]]).is_err());
    }

    #[test]
    fn reconnects_across_server_restarts() {
        // Server accepts exactly one connection; the client's second call
        // hits a dead stream, reconnects (the listener queues a second
        // conn? no — max_conns(2) serves sequentially) and resends.
        let addr = spawn_test_server(0, Some(2), double).unwrap();
        let mut shard = RemoteShard::new(addr.clone(), RetryPolicy::default());
        assert_eq!(shard.call(&[vec![2u16]]).unwrap().unwrap(), vec![4.0]);
        // Drop our stream so the server moves on to the next connection.
        shard.stream = None;
        assert_eq!(shard.call(&[vec![4u16]]).unwrap().unwrap(), vec![8.0]);
    }

    #[test]
    fn stats_probe_reports_server_side_counters() {
        let addr = spawn_test_server(0, Some(2), |genes: &[Vec<u16>]| {
            // a measurable floor on busy time, so the probe's lower-bound
            // assertion below cannot flake
            std::thread::sleep(Duration::from_millis(2));
            eyre::ensure!(genes[0][0] != 99, "poison gene");
            double(genes)
        })
        .unwrap();
        let mut shard = RemoteShard::new(addr.clone(), RetryPolicy::default());
        assert_eq!(shard.call(&[vec![2u16]]).unwrap().unwrap(), vec![4.0]);
        // eval errors burn busy time but do not count as completed
        assert!(shard.call(&[vec![99u16]]).unwrap().is_err());
        // close the search connection so the sequential server can accept
        // the dedicated probe connection
        drop(shard);
        let stats = fetch_shard_stats(&addr, Duration::from_secs(5)).unwrap();
        assert_eq!(stats.completed, 1, "only the Scores reply counts");
        assert_eq!(stats.conns, 2, "the probe connection itself is counted");
        assert!(
            stats.busy_us >= 4_000,
            "two >=2ms evals should report >=4000us busy, got {}",
            stats.busy_us
        );
    }

    #[test]
    fn stats_probe_interleaves_with_live_eval() {
        // Satellite of the concurrent accept loop: a stats probe must be
        // answered while another connection's chunk is *mid-eval* — the
        // live mid-search polling the sequential server could never do.
        let (entered_tx, entered_rx) = std::sync::mpsc::channel::<()>();
        let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
        let chans = Mutex::new((entered_tx, gate_rx));
        let addr = spawn_test_server(0, Some(2), move |genes: &[Vec<u16>]| {
            // Announce we're inside the eval, then block until the main
            // thread releases us — the probe below runs while we are parked
            // here, inside the eval closure.
            let chans = chans.lock().unwrap();
            chans.0.send(()).ok();
            chans.1.recv().ok();
            double(genes)
        })
        .unwrap();

        let addr2 = addr.clone();
        let feeder = std::thread::spawn(move || {
            let mut shard = RemoteShard::new(addr2, RetryPolicy::default());
            shard.call(&[vec![3u16]])
        });
        // Wait until the feeder's chunk is provably mid-eval, then probe on
        // a second connection.  With a sequential accept loop this probe
        // would hang until the feeder finished; concurrently it answers
        // while the eval is still blocked.
        entered_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let stats = fetch_shard_stats(&addr, Duration::from_secs(5))
            .expect("stats probe must interleave with a live eval");
        assert_eq!(stats.completed, 0, "probed mid-eval, before any Scores reply");
        assert_eq!(stats.conns, 2, "feeder + probe both accepted");

        gate_tx.send(()).unwrap();
        let scores = feeder.join().unwrap().unwrap().unwrap();
        assert_eq!(scores, vec![6.0]);
    }

    #[test]
    fn hung_server_chunk_times_out_and_flow_retires() {
        // A server that accepts, greets, reads the chunk and then never
        // replies: without a chunk timeout this stalls a feeder forever.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { continue };
                let _ = write_frame(&mut stream, &WireMsg::Hello { n_layers: 0 });
                let _ = read_frame(&mut stream); // swallow the chunk...
                std::thread::sleep(Duration::from_secs(600)); // ...and hang
            }
        });
        let fast = RetryPolicy {
            attempts: 1,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(1),
        };
        let t0 = Instant::now();
        let mut flow = remote_eval_flow_with_timeout(
            addr,
            fast,
            Some(Duration::from_millis(50)),
        );
        match flow(vec![vec![1u16]]) {
            ShardFlow::Retire { reason } => {
                assert!(reason.contains("transport"), "got: {reason}");
            }
            ShardFlow::Reply(_) => panic!("expected retire on hung server"),
        }
        // Bounded by ~2 timeout windows (one reconnect-and-resend cycle),
        // not the server's 600s nap.
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "timed out in {:?}, should be ~100ms",
            t0.elapsed()
        );
    }

    #[test]
    fn server_drop_fault_times_out_client_and_resend_succeeds() {
        use super::super::faults::FaultSpec;
        // Exactly one Drop fault: the first chunk's reply is swallowed, the
        // client's read times out, it reconnects and the resend scores
        // normally — all seeded, no timing dependence beyond the timeout.
        let plan = Arc::new(
            FaultSpec { seed: 11, kind: FaultKind::Drop, rate: 1.0 }
                .plan()
                .with_max_faults(1),
        );
        let addr =
            spawn_test_server_with_faults(0, Some(2), Some(plan.clone()), double).unwrap();
        let mut shard = RemoteShard::new(addr, RetryPolicy::default())
            .with_chunk_timeout(Some(Duration::from_millis(50)));
        let t0 = Instant::now();
        let scores = shard.call(&[vec![3u16]]).unwrap().unwrap();
        assert_eq!(scores, vec![6.0], "resend must score bit-identically");
        assert_eq!(plan.injected(), 1);
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "one timeout window + resend, got {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn server_disconnect_fault_closes_conn_and_resend_succeeds() {
        use super::super::faults::FaultSpec;
        let plan = Arc::new(
            FaultSpec { seed: 4, kind: FaultKind::Disconnect, rate: 1.0 }
                .plan()
                .with_max_faults(1),
        );
        let addr =
            spawn_test_server_with_faults(0, Some(2), Some(plan.clone()), double).unwrap();
        let mut shard = RemoteShard::new(addr, RetryPolicy::default());
        // First chunk: server evaluates, then closes without replying; the
        // client sees EOF, reconnects, resends, and the clean retry scores.
        let scores = shard.call(&[vec![5u16]]).unwrap().unwrap();
        assert_eq!(scores, vec![10.0]);
        assert_eq!(plan.injected(), 1);
    }

    #[test]
    fn client_fault_plan_perturbs_transport_not_payload() {
        use super::super::faults::FaultSpec;
        let addr = spawn_test_server(0, Some(1), double).unwrap();
        // Drop: the call fails as a timeout without touching the wire...
        let plan = Arc::new(
            FaultSpec { seed: 2, kind: FaultKind::Drop, rate: 1.0 }
                .plan()
                .with_max_faults(1),
        );
        let mut shard = RemoteShard::new(addr, RetryPolicy::default())
            .with_fault_plan(Some(plan.clone()));
        let err = shard.call(&[vec![1u16]]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);
        // ...and once the cap is exhausted the same client scores normally.
        let scores = shard.call(&[vec![1u16, 2]]).unwrap().unwrap();
        assert_eq!(scores, vec![6.0]);
        assert_eq!(plan.injected(), 1);
    }

    #[test]
    fn flow_retires_on_dead_transport() {
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let fast = RetryPolicy {
            attempts: 1,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(1),
        };
        let mut flow = remote_eval_flow(addr, fast);
        match flow(vec![vec![1u16]]) {
            ShardFlow::Retire { reason } => {
                assert!(reason.contains("transport"), "got: {reason}");
            }
            ShardFlow::Reply(_) => panic!("expected retire on dead transport"),
        }
    }
}

//! TCP transport for the eval pool: shard *clients* the coordinator feeds
//! chunks through, and the shard *server* loop behind
//! `repro shard-serve --listen ADDR`.
//!
//! Protocol (see [`crate::runtime::wire`] for the frame layout): the server
//! greets each connection with `Hello { n_layers }`, then answers every
//! `Chunk { id, genes }` with either `Scores { id, scores }` (bit-exact
//! per-candidate f32s, input order) or `Error { id, message }` for a
//! *deterministic* evaluation failure (the connection stays usable).
//! Transport failures are a different axis entirely: the client reconnects
//! with bounded backoff and — because evaluations are pure functions of the
//! genes — simply resends the in-flight chunk.  A connection that stays dead
//! beyond the retry budget retires the feeder shard
//! ([`crate::runtime::ShardFlow::Retire`]); the pool requeues the chunk onto
//! its surviving shards.

use std::io::{self, Read};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::wire::{read_frame, write_frame, WireMsg};
use super::ShardFlow;
use crate::coordinator::Config;

/// Bounded-backoff reconnect policy for a remote shard.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Connection attempts per (re)connect, minimum 1.
    pub attempts: u32,
    /// Delay before the second attempt; doubles per attempt thereafter.
    pub base_delay: Duration,
    /// Ceiling on the per-attempt delay.
    pub max_delay: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 4,
            base_delay: Duration::from_millis(100),
            max_delay: Duration::from_secs(1),
        }
    }
}

impl RetryPolicy {
    /// Backoff before attempt `i` (0-based; attempt 0 is immediate).
    fn delay(&self, attempt: u32) -> Duration {
        if attempt == 0 {
            Duration::ZERO
        } else {
            let factor = 1u32 << (attempt - 1).min(16);
            self.base_delay.saturating_mul(factor).min(self.max_delay)
        }
    }
}

/// Client half of one coordinator→shard connection.  Owns the stream and
/// the chunk-id counter; reconnects (and resends the in-flight chunk — safe
/// because evaluations are pure) on transport errors.
pub struct RemoteShard {
    addr: String,
    policy: RetryPolicy,
    stream: Option<TcpStream>,
    next_id: u64,
}

impl RemoteShard {
    pub fn new(addr: impl Into<String>, policy: RetryPolicy) -> Self {
        RemoteShard { addr: addr.into(), policy, stream: None, next_id: 0 }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Connect (with backoff) and consume the server's `Hello`.  No-op when
    /// already connected.
    fn ensure_connected(&mut self) -> io::Result<()> {
        if self.stream.is_some() {
            return Ok(());
        }
        let mut last_err = None;
        for attempt in 0..self.policy.attempts.max(1) {
            let delay = self.policy.delay(attempt);
            if !delay.is_zero() {
                std::thread::sleep(delay);
            }
            match TcpStream::connect(&self.addr) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    let mut stream = stream;
                    match read_hello(&mut stream) {
                        Ok(_n_layers) => {
                            self.stream = Some(stream);
                            return Ok(());
                        }
                        Err(e) => last_err = Some(e),
                    }
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            io::Error::new(io::ErrorKind::Other, "no connection attempts made")
        }))
    }

    /// Score one chunk of gene vectors on the remote shard.
    ///
    /// The outer `io::Result` is the *transport* axis (connection dead
    /// beyond the retry budget — the caller should retire this shard).  The
    /// inner `Result<Vec<f32>, String>` is the *evaluation* axis: the
    /// remote's deterministic error text comes back as `Err(message)` with
    /// the connection still healthy.
    pub fn call(
        &mut self,
        genes: &[Vec<u16>],
    ) -> io::Result<std::result::Result<Vec<f32>, String>> {
        // One reconnect-and-resend cycle beyond the current connection:
        // either the existing stream works, or we rebuild it once (with the
        // policy's full backoff schedule) and resend the identical chunk.
        let mut retried = false;
        loop {
            self.ensure_connected()?;
            let id = self.next_id;
            match self.exchange(id, genes) {
                Ok(reply) => {
                    self.next_id += 1;
                    return Ok(reply);
                }
                Err(e) => {
                    self.stream = None;
                    if retried {
                        return Err(e);
                    }
                    retried = true;
                }
            }
        }
    }

    fn exchange(
        &mut self,
        id: u64,
        genes: &[Vec<u16>],
    ) -> io::Result<std::result::Result<Vec<f32>, String>> {
        let stream = self
            .stream
            .as_mut()
            .expect("exchange called without a connection");
        let msg = WireMsg::Chunk { id, genes: genes.to_vec() };
        write_frame(stream, &msg)?;
        let reply = read_frame(stream)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "shard closed the connection mid-call",
                )
            })?;
        match reply {
            WireMsg::Scores { id: rid, scores } => {
                if rid != id {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("reply id {rid} does not match request id {id}"),
                    ));
                }
                if scores.len() != genes.len() {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!(
                            "shard returned {} scores for {} candidates",
                            scores.len(),
                            genes.len()
                        ),
                    ));
                }
                Ok(Ok(scores))
            }
            WireMsg::Error { id: rid, message } => {
                if rid != id {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("error reply id {rid} does not match request id {id}"),
                    ));
                }
                Ok(Err(message))
            }
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected reply op {other:?}"),
            )),
        }
    }
}

/// Server-side lifetime counters for one `serve_shard` loop, accumulated
/// across all accepted connections (stats-probe connections included).
/// `busy` is wall time spent inside the eval closure only — transport and
/// queueing are excluded, which is exactly the gap the coordinator's
/// client-side estimate cannot see.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    /// Chunks answered with `Scores` (eval errors are not counted).
    pub completed: u64,
    /// Cumulative wall time inside the eval closure.
    pub busy: Duration,
    /// Connections accepted, stats probes included.
    pub conns: u64,
}

/// Server-side counters as reported by a shard over a
/// [`WireMsg::Stats`] frame — the decoded form of [`ServeStats`].
#[derive(Clone, Copy, Debug)]
pub struct ShardServerStats {
    /// Chunks the server answered with `Scores`.
    pub completed: u64,
    /// Microseconds the server spent inside its eval closure.
    pub busy_us: u64,
    /// Connections the server has accepted (this probe included).
    pub conns: u64,
}

/// Probe `addr` for server-side stats on a dedicated, freshly opened
/// connection, then drop it.
///
/// Probe only when the shard is expected to be idle — after the search's
/// feeder connections have closed.  The server answers connections
/// sequentially, so a probe racing an open search stream just waits until
/// `timeout` and reports the shard as unavailable rather than hanging.
/// Pre-stats servers reject the probe frame and drop the connection, which
/// also surfaces here as an error — callers should degrade to "server-side
/// stats unavailable", not treat it as a shard failure.
pub fn fetch_shard_stats(addr: &str, timeout: Duration) -> io::Result<ShardServerStats> {
    let stream = TcpStream::connect(addr)?;
    let _ = stream.set_nodelay(true);
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let mut stream = stream;
    read_hello(&mut stream)?;
    write_frame(&mut stream, &WireMsg::StatsReq { id: 0 })?;
    let reply = read_frame(&mut stream)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?
        .ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "shard closed the connection on stats probe (pre-stats server?)",
            )
        })?;
    match reply {
        WireMsg::Stats { id: 0, completed, busy_us, conns } => {
            Ok(ShardServerStats { completed, busy_us, conns })
        }
        WireMsg::Stats { id, .. } => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("stats reply id {id} does not match request id 0"),
        )),
        other => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unexpected stats reply op {other:?}"),
        )),
    }
}

fn read_hello<R: Read>(r: &mut R) -> io::Result<u64> {
    let msg = read_frame(r)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?
        .ok_or_else(|| {
            io::Error::new(io::ErrorKind::UnexpectedEof, "connection closed before hello")
        })?;
    match msg {
        WireMsg::Hello { n_layers } => Ok(n_layers),
        other => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("expected hello, got {other:?}"),
        )),
    }
}

/// Build the per-shard feeder closure the pool runs for one remote shard:
/// chunks go out as frames, scores come back as the pool's normal
/// `Result<Vec<f32>>` reply.  Evaluation errors from the remote are
/// *deterministic* and reported as `Reply(Err(..))` (requeueing them would
/// just fail again elsewhere — the search surfaces them like any local
/// eval error); transport death beyond the retry budget retires the shard,
/// so the pool requeues the in-flight chunk onto its surviving shards.
pub fn remote_eval_flow(
    addr: String,
    policy: RetryPolicy,
) -> Box<dyn FnMut(Vec<Config>) -> ShardFlow<crate::Result<Vec<f32>>>> {
    let mut shard = RemoteShard::new(addr, policy);
    Box::new(move |chunk: Vec<Config>| match shard.call(&chunk) {
        Ok(Ok(scores)) => ShardFlow::Reply(Ok(scores)),
        Ok(Err(message)) => ShardFlow::Reply(Err(eyre::anyhow!(
            "remote shard {} eval error: {message}",
            shard.addr()
        ))),
        Err(e) => ShardFlow::Retire {
            reason: format!("transport to {}: {e}", shard.addr()),
        },
    })
}

/// Serve chunk frames on `listener`, one connection at a time, until
/// `max_conns` connections have come and gone (`None` = forever).  `eval`
/// scores a chunk of gene vectors; its error text is sent back verbatim as
/// an `Error` frame.  This is the loop behind `repro shard-serve`.
pub fn serve_shard<F>(
    listener: TcpListener,
    n_layers: u64,
    max_conns: Option<usize>,
    mut eval: F,
) -> crate::Result<()>
where
    F: FnMut(&[Vec<u16>]) -> crate::Result<Vec<f32>>,
{
    let mut served = 0usize;
    let mut stats = ServeStats::default();
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("[shard] accept failed: {e}");
                continue;
            }
        };
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown>".into());
        eprintln!("[shard] connection from {peer}");
        stats.conns += 1;
        if let Err(e) = serve_conn(stream, n_layers, &mut eval, &mut stats) {
            eprintln!("[shard] connection {peer} ended with error: {e}");
        } else {
            eprintln!("[shard] connection {peer} closed");
        }
        served += 1;
        if let Some(max) = max_conns {
            if served >= max {
                break;
            }
        }
    }
    Ok(())
}

fn serve_conn<F>(
    stream: TcpStream,
    n_layers: u64,
    eval: &mut F,
    stats: &mut ServeStats,
) -> crate::Result<()>
where
    F: FnMut(&[Vec<u16>]) -> crate::Result<Vec<f32>>,
{
    let _ = stream.set_nodelay(true);
    let mut stream = stream;
    write_frame(&mut stream, &WireMsg::Hello { n_layers })?;
    loop {
        let msg = match read_frame(&mut stream)? {
            None => return Ok(()), // clean EOF: coordinator hung up
            Some(m) => m,
        };
        let reply = match msg {
            WireMsg::Chunk { id, genes } => {
                let t0 = Instant::now();
                let res = eval(&genes);
                stats.busy += t0.elapsed();
                match res {
                    Ok(scores) => {
                        if scores.len() != genes.len() {
                            WireMsg::Error {
                                id,
                                message: format!(
                                    "evaluator returned {} scores for {} candidates",
                                    scores.len(),
                                    genes.len()
                                ),
                            }
                        } else {
                            stats.completed += 1;
                            WireMsg::Scores { id, scores }
                        }
                    }
                    Err(e) => WireMsg::Error { id, message: e.to_string() },
                }
            }
            WireMsg::StatsReq { id } => WireMsg::Stats {
                id,
                completed: stats.completed,
                busy_us: stats.busy.as_micros() as u64,
                conns: stats.conns,
            },
            other => {
                eyre::bail!("unexpected client frame {other:?}");
            }
        };
        write_frame(&mut stream, &reply)?;
    }
}

/// Spawn a shard server for tests: binds a loopback port, serves `eval` on
/// a background thread, returns the bound address.  The thread exits after
/// `max_conns` connections (or runs until process exit for `None` —
/// listener threads are detached, matching how CI kills the server
/// processes).
pub fn spawn_test_server<F>(
    n_layers: u64,
    max_conns: Option<usize>,
    eval: F,
) -> crate::Result<String>
where
    F: FnMut(&[Vec<u16>]) -> crate::Result<Vec<f32>> + Send + 'static,
{
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    std::thread::spawn(move || {
        if let Err(e) = serve_shard(listener, n_layers, max_conns, eval) {
            eprintln!("[shard] server loop failed: {e}");
        }
    });
    Ok(addr)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn double(genes: &[Vec<u16>]) -> crate::Result<Vec<f32>> {
        Ok(genes.iter().map(|g| g.iter().map(|&x| x as f32).sum::<f32>() * 2.0).collect())
    }

    #[test]
    fn client_server_round_trip() {
        let addr = spawn_test_server(0, Some(1), double).unwrap();
        let mut shard = RemoteShard::new(addr, RetryPolicy::default());
        let chunk = vec![vec![1u16, 2, 3], vec![10, 20]];
        let scores = shard.call(&chunk).unwrap().unwrap();
        assert_eq!(scores, vec![12.0, 60.0]);
        // second call reuses the connection; ids advance server-side too
        let scores = shard.call(&[vec![5u16]]).unwrap().unwrap();
        assert_eq!(scores, vec![10.0]);
    }

    #[test]
    fn eval_error_comes_back_as_message_not_transport_failure() {
        let addr = spawn_test_server(0, Some(1), |genes: &[Vec<u16>]| {
            eyre::ensure!(genes.len() != 2, "no pairs allowed");
            double(genes)
        })
        .unwrap();
        let mut shard = RemoteShard::new(addr, RetryPolicy::default());
        let err = shard.call(&[vec![1u16], vec![2]]).unwrap().unwrap_err();
        assert!(err.contains("no pairs allowed"), "got: {err}");
        // connection survives the eval error
        let ok = shard.call(&[vec![3u16]]).unwrap().unwrap();
        assert_eq!(ok, vec![6.0]);
    }

    #[test]
    fn dead_address_errors_after_bounded_retries() {
        // A listener bound then dropped: the port refuses connections.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let fast = RetryPolicy {
            attempts: 2,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(2),
        };
        let mut shard = RemoteShard::new(addr, fast);
        assert!(shard.call(&[vec![1u16]]).is_err());
    }

    #[test]
    fn reconnects_across_server_restarts() {
        // Server accepts exactly one connection; the client's second call
        // hits a dead stream, reconnects (the listener queues a second
        // conn? no — max_conns(2) serves sequentially) and resends.
        let addr = spawn_test_server(0, Some(2), double).unwrap();
        let mut shard = RemoteShard::new(addr.clone(), RetryPolicy::default());
        assert_eq!(shard.call(&[vec![2u16]]).unwrap().unwrap(), vec![4.0]);
        // Drop our stream so the server moves on to the next connection.
        shard.stream = None;
        assert_eq!(shard.call(&[vec![4u16]]).unwrap().unwrap(), vec![8.0]);
    }

    #[test]
    fn stats_probe_reports_server_side_counters() {
        let addr = spawn_test_server(0, Some(2), |genes: &[Vec<u16>]| {
            // a measurable floor on busy time, so the probe's lower-bound
            // assertion below cannot flake
            std::thread::sleep(Duration::from_millis(2));
            eyre::ensure!(genes[0][0] != 99, "poison gene");
            double(genes)
        })
        .unwrap();
        let mut shard = RemoteShard::new(addr.clone(), RetryPolicy::default());
        assert_eq!(shard.call(&[vec![2u16]]).unwrap().unwrap(), vec![4.0]);
        // eval errors burn busy time but do not count as completed
        assert!(shard.call(&[vec![99u16]]).unwrap().is_err());
        // close the search connection so the sequential server can accept
        // the dedicated probe connection
        drop(shard);
        let stats = fetch_shard_stats(&addr, Duration::from_secs(5)).unwrap();
        assert_eq!(stats.completed, 1, "only the Scores reply counts");
        assert_eq!(stats.conns, 2, "the probe connection itself is counted");
        assert!(
            stats.busy_us >= 4_000,
            "two >=2ms evals should report >=4000us busy, got {}",
            stats.busy_us
        );
    }

    #[test]
    fn flow_retires_on_dead_transport() {
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let fast = RetryPolicy {
            attempts: 1,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(1),
        };
        let mut flow = remote_eval_flow(addr, fast);
        match flow(vec![vec![1u16]]) {
            ShardFlow::Retire { reason } => {
                assert!(reason.contains("transport"), "got: {reason}");
            }
            ShardFlow::Reply(_) => panic!("expected retire on dead transport"),
        }
    }
}

//! Continuous microbatching for the serving path: admit single-candidate
//! scoring requests from many concurrent clients, coalesce them into
//! lane-sized dispatches, and complete per-request reply channels.
//!
//! The scheduler is the serving-side mirror of the search pool's microbatch
//! scheduler, tuned for *latency under load* instead of search throughput:
//!
//!  * requests enter an **admission queue** (bounded — beyond
//!    [`SchedulerOptions::queue_cap`] a request is rejected immediately
//!    rather than growing the tail latency without bound);
//!  * a **lane batcher** thread coalesces up to `lanes` queued requests
//!    into one evaluator dispatch.  It dispatches *early* when the oldest
//!    queued request has waited [`SchedulerOptions::max_wait`] — a partial
//!    slab at the deadline beats a full slab too late — and *immediately*
//!    when the slab fills before the deadline;
//!  * each request carries its own **reply channel**; the dispatch fans the
//!    per-candidate scores (bit-exact — evaluation is a pure per-candidate
//!    function, so lane grouping can never change a score) back out to the
//!    callers that submitted them.
//!
//! The evaluator closure is the same shape the shard server uses
//! (`FnMut(&[Config]) -> Result<Vec<f32>>`), so a `repro serve` process
//! drives the existing lane-stacked scorer / `SlabCache` / device-gather
//! path: a steady-state serving workload over a fixed set of configs does
//! **zero host slab uploads** after warmup.
//!
//! On top of the scheduler this module carries the TCP server behind
//! `repro serve` (`score_req`/`score` frames — see [`crate::runtime::wire`]),
//! the matching [`ScoreClient`], the `serve_stats` probe, and the
//! fixed-bucket [`LatencyHistogram`] the `repro serve-bench` load generator
//! records into (no external histogram dependency; power-of-two buckets).
//!
//! Shutdown drains: queued requests are dispatched (without waiting out the
//! deadline) before the batcher thread exits, so no accepted request is
//! ever dropped with its reply channel dangling.

use std::collections::VecDeque;
use std::io;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::remote::read_hello;
use super::wire::{read_frame, write_frame, WireMsg};
use crate::coordinator::Config;

/// A scoring reply: the candidate's score, or the evaluator's (or
/// scheduler's) error text.  `String` rather than `eyre::Report` so one
/// batch-level failure can fan out to every request in the batch.
pub type ScoreResult = std::result::Result<f32, String>;

/// Tuning knobs for the [`ContinuousBatcher`].
#[derive(Clone, Copy, Debug)]
pub struct SchedulerOptions {
    /// Dispatch width: how many queued requests one evaluator call may
    /// carry.  Match the scorer's lane count so a full batch fills the lane
    /// slab exactly (minimum 1 — per-candidate serving).
    pub lanes: usize,
    /// Deadline measured from the *oldest* queued request's admission: when
    /// it expires, whatever is queued dispatches as a partial batch.
    pub max_wait: Duration,
    /// Admission-queue bound; requests beyond it are rejected immediately
    /// (the reply channel completes with an error, the wire layer answers
    /// an `Error` frame).  Minimum 1.
    pub queue_cap: usize,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions {
            lanes: 8,
            max_wait: Duration::from_micros(1000),
            queue_cap: 1024,
        }
    }
}

/// Lifetime counters for one [`ContinuousBatcher`].
///
/// Lane fill and queue wait are deliberately *separate* measurements: a low
/// [`lane_fill_fraction`](Self::lane_fill_fraction) with a low mean wait
/// means the deadline is doing its job under light load (under-filled
/// dispatches are latency-driven), while a high wait with high fill points
/// at the evaluator itself (e.g. cold slab-cache misses) — conflating the
/// two hides which knob to turn.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SchedulerStats {
    /// Requests admitted into the queue.
    pub requests: u64,
    /// Requests rejected at admission (queue at `queue_cap`, or submitted
    /// after shutdown).  Not counted in `requests`.
    pub rejected: u64,
    /// Evaluator dispatches.
    pub dispatches: u64,
    /// Dispatches that left with a full `lanes`-wide batch.
    pub full_dispatches: u64,
    /// Partial dispatches flushed because the oldest request hit
    /// `max_wait`.
    pub deadline_dispatches: u64,
    /// Dispatch width the scheduler was configured with.
    pub lanes: u64,
    /// Requests dispatched (slots actually used across all dispatches).
    pub batched: u64,
    /// Cumulative admission-queue wait across dispatched requests, µs.
    pub wait_us: u64,
    /// Queue depth sampled at each dispatch, summed (mean =
    /// `depth_sum / dispatches`).
    pub depth_sum: u64,
    /// High-water queue depth at dispatch time.
    pub depth_max: u64,
}

impl SchedulerStats {
    /// Shutdown-drain dispatches (neither full nor deadline-flushed).
    pub fn drain_dispatches(&self) -> u64 {
        self.dispatches - self.full_dispatches - self.deadline_dispatches
    }

    /// Fraction of dispatched lane slots that carried a real request
    /// (1.0 = every dispatch was full).
    pub fn lane_fill_fraction(&self) -> f64 {
        if self.dispatches == 0 || self.lanes == 0 {
            return 0.0;
        }
        self.batched as f64 / (self.dispatches * self.lanes) as f64
    }

    /// Mean admission-queue wait per dispatched request, µs.
    pub fn mean_wait_us(&self) -> f64 {
        if self.batched == 0 {
            return 0.0;
        }
        self.wait_us as f64 / self.batched as f64
    }

    /// Mean queue depth observed at dispatch time.
    pub fn mean_depth(&self) -> f64 {
        if self.dispatches == 0 {
            return 0.0;
        }
        self.depth_sum as f64 / self.dispatches as f64
    }

    /// One-line human summary (the `[serve]` stdout line): dispatch mix and
    /// lane fill on one side, queue wait and depth on the other.
    pub fn summary(&self) -> String {
        format!(
            "{} requests ({} rejected) | {} dispatches ({} full, {} deadline, {} drain) | lane fill {:.3} | mean queue wait {:.1} us (mean depth {:.1}, max {})",
            self.requests,
            self.rejected,
            self.dispatches,
            self.full_dispatches,
            self.deadline_dispatches,
            self.drain_dispatches(),
            self.lane_fill_fraction(),
            self.mean_wait_us(),
            self.mean_depth(),
            self.depth_max,
        )
    }
}

/// One queued request.
struct Job {
    genes: Config,
    enqueued: Instant,
    reply: mpsc::Sender<ScoreResult>,
}

/// Queue state behind the admission mutex.
struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<QueueState>,
    /// Signalled on admission and on shutdown; the batcher waits on it with
    /// the batch-forming deadline as the timeout.
    cond: Condvar,
    stats: Mutex<SchedulerStats>,
}

/// Why a batch left the queue.
enum DispatchKind {
    Full,
    Deadline,
    Drain,
}

/// The continuous microbatching scheduler.  Construct with
/// [`ContinuousBatcher::spawn`]; submit from any thread; drop (or call
/// [`shutdown`](Self::shutdown)) to drain and join the batcher thread.
pub struct ContinuousBatcher {
    shared: Arc<Shared>,
    opts: SchedulerOptions,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl ContinuousBatcher {
    /// Spawn the batcher thread.  `builder` runs *on that thread* and
    /// constructs the evaluator — the same pattern as the search pool's
    /// shards, so non-`Send` evaluator state (a `DeviceProxy` borrowing the
    /// runtime through captured `Arc`s) lives where it is used.
    pub fn spawn<B, F>(opts: SchedulerOptions, builder: B) -> ContinuousBatcher
    where
        B: FnOnce() -> F + Send + 'static,
        F: FnMut(&[Config]) -> crate::Result<Vec<f32>>,
    {
        let opts = SchedulerOptions {
            lanes: opts.lanes.max(1),
            max_wait: opts.max_wait,
            queue_cap: opts.queue_cap.max(1),
        };
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            cond: Condvar::new(),
            stats: Mutex::new(SchedulerStats {
                lanes: opts.lanes as u64,
                ..SchedulerStats::default()
            }),
        });
        let worker_shared = shared.clone();
        let worker = std::thread::spawn(move || {
            let mut eval = builder();
            batcher_loop(&worker_shared, opts, &mut eval);
        });
        ContinuousBatcher { shared, opts, worker: Some(worker) }
    }

    /// The options the scheduler is running with (normalized: `lanes` and
    /// `queue_cap` floored at 1).
    pub fn options(&self) -> SchedulerOptions {
        self.opts
    }

    /// Submit one candidate; returns the reply channel immediately.  A
    /// rejected request (queue full / shutdown) still gets a channel — it
    /// completes with `Err` right away, so callers have one wait path.
    pub fn submit(&self, genes: Config) -> mpsc::Receiver<ScoreResult> {
        let (tx, rx) = mpsc::channel();
        let mut q = self.shared.queue.lock().unwrap();
        if q.shutdown {
            drop(q);
            self.shared.stats.lock().unwrap().rejected += 1;
            let _ = tx.send(Err("scheduler is shut down".into()));
            return rx;
        }
        if q.jobs.len() >= self.opts.queue_cap {
            drop(q);
            self.shared.stats.lock().unwrap().rejected += 1;
            let _ = tx.send(Err(format!(
                "admission queue full ({} queued)",
                self.opts.queue_cap
            )));
            return rx;
        }
        // Count the admission while still holding the queue lock (lock
        // order is always queue → stats): a concurrent stats probe can
        // never observe `batched > requests`.
        self.shared.stats.lock().unwrap().requests += 1;
        q.jobs.push_back(Job { genes, enqueued: Instant::now(), reply: tx });
        drop(q);
        self.shared.cond.notify_all();
        rx
    }

    /// Submit and block for the reply.
    pub fn score(&self, genes: Config) -> ScoreResult {
        match self.submit(genes).recv() {
            Ok(res) => res,
            Err(_) => Err("scheduler worker died before replying".into()),
        }
    }

    /// Snapshot the lifetime counters.
    pub fn stats(&self) -> SchedulerStats {
        *self.shared.stats.lock().unwrap()
    }

    /// Stop admitting, drain every queued request (dispatched immediately,
    /// no deadline wait), and join the batcher thread.  Idempotent; also
    /// runs on drop.
    pub fn shutdown(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cond.notify_all();
        if let Some(worker) = self.worker.take() {
            let _ = worker.join();
        }
    }
}

impl Drop for ContinuousBatcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn batcher_loop<F>(shared: &Shared, opts: SchedulerOptions, eval: &mut F)
where
    F: FnMut(&[Config]) -> crate::Result<Vec<f32>>,
{
    loop {
        let mut q = shared.queue.lock().unwrap();
        // Sleep until there is something to batch (or we're done).
        loop {
            if !q.jobs.is_empty() {
                break;
            }
            if q.shutdown {
                return;
            }
            q = shared.cond.wait(q).unwrap();
        }
        // Batch-forming window: the oldest request's admission anchors the
        // deadline, so the worst-case queue wait is max_wait + one eval.
        let deadline = q.jobs.front().expect("non-empty queue").enqueued + opts.max_wait;
        let kind = loop {
            if q.jobs.len() >= opts.lanes {
                break DispatchKind::Full;
            }
            if q.shutdown {
                break DispatchKind::Drain;
            }
            let now = Instant::now();
            if now >= deadline {
                break DispatchKind::Deadline;
            }
            let (qq, _timeout) = shared.cond.wait_timeout(q, deadline - now).unwrap();
            q = qq;
        };
        let depth = q.jobs.len();
        let take = depth.min(opts.lanes);
        let batch: Vec<Job> = q.jobs.drain(..take).collect();
        drop(q);

        let now = Instant::now();
        {
            let mut stats = shared.stats.lock().unwrap();
            stats.dispatches += 1;
            match kind {
                DispatchKind::Full => stats.full_dispatches += 1,
                DispatchKind::Deadline => stats.deadline_dispatches += 1,
                DispatchKind::Drain => {}
            }
            stats.batched += batch.len() as u64;
            stats.depth_sum += depth as u64;
            stats.depth_max = stats.depth_max.max(depth as u64);
            for job in &batch {
                stats.wait_us +=
                    now.saturating_duration_since(job.enqueued).as_micros() as u64;
            }
        }

        let genes: Vec<Config> = batch.iter().map(|j| j.genes.clone()).collect();
        match eval(&genes) {
            Ok(scores) if scores.len() == batch.len() => {
                for (job, score) in batch.into_iter().zip(scores) {
                    let _ = job.reply.send(Ok(score));
                }
            }
            Ok(scores) => {
                let msg = format!(
                    "evaluator returned {} scores for {} candidates",
                    scores.len(),
                    batch.len()
                );
                for job in batch {
                    let _ = job.reply.send(Err(msg.clone()));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for job in batch {
                    let _ = job.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Options for [`serve_scores`], the TCP loop behind `repro serve`.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Scheduler knobs (`--lanes`, `--max-wait-us`, queue cap).
    pub scheduler: SchedulerOptions,
    /// Total connections to accept before returning (`None` = forever).
    pub max_conns: Option<usize>,
    /// Cap on simultaneously-open connections.
    pub live_cap: usize,
    /// The default candidate, served when a `score_req` carries empty
    /// genes — the searched archive entry a `repro serve` process was
    /// launched with.  `None` makes empty-genes requests an error.
    pub default_genes: Option<Config>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            scheduler: SchedulerOptions::default(),
            max_conns: None,
            live_cap: super::remote::DEFAULT_LIVE_CONNS,
            default_genes: None,
        }
    }
}

/// Serve `score_req` frames on `listener` through a [`ContinuousBatcher`]
/// until `opts.max_conns` connections have been accepted (`None` =
/// forever).  Thread-per-connection (capped at `opts.live_cap`), all
/// connections feeding the one shared admission queue — which is the whole
/// point: concurrent clients are what fills lanes.  `builder` constructs
/// the evaluator on the batcher thread (see [`ContinuousBatcher::spawn`]).
///
/// Protocol per connection: `Hello { n_layers }` greeting, then any number
/// of `ScoreReq { id, genes }` → `Score { id, score }` / `Error { id,
/// message }` exchanges; `ServeStatsReq` answers the scheduler's counters
/// without touching the admission queue.  On return, every accepted
/// request has been answered and the batcher has drained.
pub fn serve_scores<B, F>(
    listener: TcpListener,
    n_layers: u64,
    opts: ServeOptions,
    builder: B,
) -> crate::Result<SchedulerStats>
where
    B: FnOnce() -> F + Send + 'static,
    F: FnMut(&[Config]) -> crate::Result<Vec<f32>>,
{
    let live_cap = opts.live_cap.max(1);
    let batcher = ContinuousBatcher::spawn(opts.scheduler, builder);
    let default_genes = opts.default_genes.clone();
    let live = (Mutex::new(0usize), Condvar::new());
    std::thread::scope(|scope| {
        let mut accepted = 0usize;
        for conn in listener.incoming() {
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[serve] accept failed: {e}");
                    continue;
                }
            };
            {
                let mut n = live.0.lock().unwrap();
                while *n >= live_cap {
                    n = live.1.wait(n).unwrap();
                }
                *n += 1;
            }
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "<unknown>".into());
            let (batcher, live, default_genes) = (&batcher, &live, &default_genes);
            scope.spawn(move || {
                if let Err(e) =
                    serve_score_conn(stream, n_layers, batcher, default_genes.as_ref())
                {
                    eprintln!("[serve] connection {peer} ended with error: {e}");
                }
                eprintln!("[serve] {}", batcher.stats().summary());
                *live.0.lock().unwrap() -= 1;
                live.1.notify_one();
            });
            accepted += 1;
            if let Some(max) = opts.max_conns {
                if accepted >= max {
                    break;
                }
            }
        }
        // scope exit joins every connection handler; the batcher then
        // drains and joins on drop below
    });
    let mut batcher = batcher;
    batcher.shutdown();
    Ok(batcher.stats())
}

fn serve_score_conn(
    stream: TcpStream,
    n_layers: u64,
    batcher: &ContinuousBatcher,
    default_genes: Option<&Config>,
) -> crate::Result<()> {
    let _ = stream.set_nodelay(true);
    let mut stream = stream;
    write_frame(&mut stream, &WireMsg::Hello { n_layers })?;
    loop {
        let msg = match read_frame(&mut stream)? {
            None => return Ok(()), // clean EOF: client hung up
            Some(m) => m,
        };
        let reply = match msg {
            WireMsg::ScoreReq { id, genes } => {
                let genes = if genes.is_empty() {
                    match default_genes {
                        Some(d) => d.clone(),
                        None => {
                            write_frame(
                                &mut stream,
                                &WireMsg::Error {
                                    id,
                                    message: "empty genes and no default config served \
                                              (launch with --config)"
                                        .into(),
                                },
                            )?;
                            continue;
                        }
                    }
                } else {
                    genes
                };
                match batcher.score(genes) {
                    Ok(score) => WireMsg::Score { id, score },
                    Err(message) => WireMsg::Error { id, message },
                }
            }
            WireMsg::ServeStatsReq { id } => {
                let s = batcher.stats();
                WireMsg::ServeStats {
                    id,
                    requests: s.requests,
                    rejected: s.rejected,
                    dispatches: s.dispatches,
                    full: s.full_dispatches,
                    deadline: s.deadline_dispatches,
                    lanes: s.lanes,
                    batched: s.batched,
                    wait_us: s.wait_us,
                    depth_sum: s.depth_sum,
                    depth_max: s.depth_max,
                }
            }
            other => {
                eyre::bail!("unexpected client frame {other:?}");
            }
        };
        write_frame(&mut stream, &reply)?;
    }
}

/// Client half of one serve connection: submit single-candidate scoring
/// requests and read bit-exact score replies.  One outstanding request per
/// connection — concurrency comes from opening more connections (which is
/// what `repro serve-bench --clients N` does).
pub struct ScoreClient {
    stream: TcpStream,
    next_id: u64,
    n_layers: u64,
}

impl ScoreClient {
    /// Connect, consume the server's `Hello`, apply `timeout` to reads and
    /// writes.
    pub fn connect(addr: &str, timeout: Duration) -> io::Result<ScoreClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        let mut stream = stream;
        let n_layers = read_hello(&mut stream)?;
        Ok(ScoreClient { stream, next_id: 0, n_layers })
    }

    /// Genome length announced by the server (0 = any).
    pub fn n_layers(&self) -> u64 {
        self.n_layers
    }

    /// Score one candidate (empty `genes` = the server's default config).
    /// Outer error = transport; inner = the server's eval/admission error.
    pub fn score(&mut self, genes: &[u16]) -> io::Result<ScoreResult> {
        let id = self.next_id;
        self.next_id += 1;
        write_frame(&mut self.stream, &WireMsg::ScoreReq { id, genes: genes.to_vec() })?;
        let reply = read_frame(&mut self.stream)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "server closed the connection mid-call",
                )
            })?;
        match reply {
            WireMsg::Score { id: rid, score } if rid == id => Ok(Ok(score)),
            WireMsg::Error { id: rid, message } if rid == id => Ok(Err(message)),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected reply {other:?} to score request {id}"),
            )),
        }
    }
}

/// Probe `addr` for the serve scheduler's counters on a dedicated
/// connection (the serving mirror of
/// [`fetch_shard_stats`](super::remote::fetch_shard_stats)).
pub fn fetch_serve_stats(addr: &str, timeout: Duration) -> io::Result<SchedulerStats> {
    let stream = TcpStream::connect(addr)?;
    let _ = stream.set_nodelay(true);
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let mut stream = stream;
    read_hello(&mut stream)?;
    write_frame(&mut stream, &WireMsg::ServeStatsReq { id: 0 })?;
    let reply = read_frame(&mut stream)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?
        .ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "server closed the connection on serve-stats probe",
            )
        })?;
    match reply {
        WireMsg::ServeStats {
            id: 0,
            requests,
            rejected,
            dispatches,
            full,
            deadline,
            lanes,
            batched,
            wait_us,
            depth_sum,
            depth_max,
        } => Ok(SchedulerStats {
            requests,
            rejected,
            dispatches,
            full_dispatches: full,
            deadline_dispatches: deadline,
            lanes,
            batched,
            wait_us,
            depth_sum,
            depth_max,
        }),
        other => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unexpected serve-stats reply {other:?}"),
        )),
    }
}

/// Number of buckets in a [`LatencyHistogram`]: bucket `i` holds samples in
/// `[2^(i-1), 2^i)` µs (bucket 0 holds `0..1` µs), so 64 buckets cover any
/// `u64` latency with a fixed-size array and no allocation on record.
pub const HISTOGRAM_BUCKETS: usize = 64;

/// Fixed-bucket (power-of-two) latency histogram — exact count/sum/max,
/// percentiles interpolated within a bucket (≤ 2× relative error by
/// construction, plenty for p50/p95/p99 trend lines).  No dependencies;
/// merging two histograms is element-wise, so per-client histograms fold
/// into one report.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; HISTOGRAM_BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram { buckets: [0; HISTOGRAM_BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }

    fn bucket(us: u64) -> usize {
        (64 - us.leading_zeros() as usize).min(HISTOGRAM_BUCKETS - 1)
    }

    /// Record one latency sample, in microseconds.
    pub fn record(&mut self, us: u64) {
        self.buckets[Self::bucket(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64
    }

    /// The `p`-th percentile (0.0 ..= 1.0), µs, linearly interpolated
    /// within the covering bucket and clamped to the observed maximum.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (p.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            if seen + n >= rank {
                let lo = if i == 0 { 0u64 } else { 1u64 << (i - 1) };
                let hi = 1u64 << i.min(62);
                let frac = (rank - seen) as f64 / n as f64;
                let est = lo as f64 + frac * (hi - lo) as f64;
                return (est as u64).min(self.max_us).max(lo);
            }
            seen += n;
        }
        self.max_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum_eval(genes: &[Config]) -> crate::Result<Vec<f32>> {
        Ok(genes.iter().map(|g| g.iter().map(|&x| x as f32).sum()).collect())
    }

    #[test]
    fn single_request_scores_through_the_batcher() {
        let opts = SchedulerOptions {
            lanes: 4,
            max_wait: Duration::from_micros(200),
            queue_cap: 16,
        };
        let b = ContinuousBatcher::spawn(opts, || sum_eval);
        assert_eq!(b.score(vec![1, 2, 3]), Ok(6.0));
        let stats = b.stats();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.dispatches, 1);
        assert_eq!(stats.batched, 1);
        assert_eq!(stats.deadline_dispatches, 1, "partial slab flushed at deadline");
        assert!(stats.lane_fill_fraction() > 0.0 && stats.lane_fill_fraction() < 1.0);
    }

    #[test]
    fn eval_error_fans_out_to_every_request_in_the_batch() {
        let opts = SchedulerOptions {
            lanes: 2,
            max_wait: Duration::from_millis(50),
            queue_cap: 16,
        };
        let b = ContinuousBatcher::spawn(opts, || {
            |_genes: &[Config]| -> crate::Result<Vec<f32>> {
                eyre::bail!("device on fire")
            }
        });
        let rx1 = b.submit(vec![1]);
        let rx2 = b.submit(vec![2]);
        assert!(rx1.recv().unwrap().unwrap_err().contains("device on fire"));
        assert!(rx2.recv().unwrap().unwrap_err().contains("device on fire"));
    }

    #[test]
    fn admission_queue_cap_rejects_fast() {
        // An evaluator parked on a gate keeps the queue from draining, so
        // the cap is what rejects — deterministically, not timing-luck.
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let opts = SchedulerOptions {
            lanes: 1,
            max_wait: Duration::ZERO,
            queue_cap: 2,
        };
        let b = ContinuousBatcher::spawn(opts, move || {
            move |genes: &[Config]| {
                gate_rx.recv().ok();
                sum_eval(genes)
            }
        });
        // First dispatch grabs one job and parks in eval; then fill the
        // queue to its cap and overflow it.
        let first = b.submit(vec![1]);
        // Wait until the batcher has drained the first job into its dispatch
        // (the queue is empty while it's parked in eval).
        let t0 = Instant::now();
        while b.stats().dispatches == 0 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(b.stats().dispatches, 1);
        let queued: Vec<_> = (0..2).map(|i| b.submit(vec![i as u16 + 2])).collect();
        let rejected = b.submit(vec![9]);
        let err = rejected.recv().unwrap().unwrap_err();
        assert!(err.contains("queue full"), "got: {err}");
        assert_eq!(b.stats().rejected, 1);
        // Release the evaluator; everything admitted completes.
        for _ in 0..4 {
            gate_tx.send(()).ok();
        }
        assert_eq!(first.recv().unwrap(), Ok(1.0));
        for (i, rx) in queued.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap(), Ok(i as f32 + 2.0));
        }
        drop(gate_tx);
    }

    #[test]
    fn histogram_percentiles_bracket_the_samples() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 1000] {
            h.record(us);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.max_us(), 1000);
        assert!((h.mean_us() - 145.0).abs() < 1e-9);
        let p50 = h.percentile(0.50);
        assert!((16..=64).contains(&p50), "p50 {p50} outside its bucket range");
        let p99 = h.percentile(0.99);
        assert!((512..=1000).contains(&p99), "p99 {p99} outside its bucket range");
        assert_eq!(h.percentile(1.0), 1000);
        assert_eq!(LatencyHistogram::new().percentile(0.5), 0);
    }

    #[test]
    fn histogram_merge_is_elementwise() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for us in [5u64, 100] {
            a.record(us);
        }
        for us in [7u64, 3000] {
            b.record(us);
        }
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.max_us(), 3000);
    }
}

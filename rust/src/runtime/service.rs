//! EvalService — a sharded evaluation pool in the style of a serving
//! router's batcher.  Callers (CLI, examples, the search loop) submit
//! requests through a shared channel and receive results through
//! per-request reply channels.
//!
//! Sharding model:
//!  * N workers share a single FIFO request channel (work-sharing: whichever
//!    shard is idle takes the next request, so a slow candidate never blocks
//!    the queue behind one thread);
//!  * each worker owns its own evaluation state, built *on the worker
//!    thread* by the shard builder — per-shard state can be anything from a
//!    full non-`Send` runtime stack down to a couple of `Arc` handles onto
//!    process-wide shared state (the search pool does the latter: one
//!    `Sync` runtime + one shared device bank serve every shard);
//!  * every request carries its own reply channel, and `call_batch` collects
//!    replies in submission order — results are therefore deterministically
//!    ordered and bit-identical regardless of worker count, **provided** the
//!    evaluation closure is a pure function of the payload (seed any
//!    randomness per-candidate from the payload, never from shard state).
//!
//! Generic over request/response so tests can exercise the queueing logic
//! without PJRT.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-shard accounting: how many requests the shard served and how long it
/// spent serving them (busy time / wall time = utilization).
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Requests this shard served.
    pub completed: u64,
    /// Wall-clock this shard spent inside its evaluation closure.
    pub busy: Duration,
}

/// Queue/latency accounting, aggregated across shards.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    /// Requests submitted to the shared queue.
    pub submitted: u64,
    /// Requests served (across all shards).
    pub completed: u64,
    /// Summed queue wait (enqueue → a shard picked the request up).
    pub total_queue_wait: Duration,
    /// Summed service time (inside the evaluation closures).
    pub total_service_time: Duration,
    /// Per-shard breakdown, shard-index order.
    pub per_shard: Vec<ShardStats>,
}

impl ServiceStats {
    /// Mean queue wait per completed request.
    pub fn mean_wait(&self) -> Duration {
        if self.completed == 0 {
            Duration::ZERO
        } else {
            self.total_queue_wait / self.completed as u32
        }
    }

    /// Mean service time per completed request.
    pub fn mean_service(&self) -> Duration {
        if self.completed == 0 {
            Duration::ZERO
        } else {
            self.total_service_time / self.completed as u32
        }
    }

    /// Fraction of `wall` each shard spent serving requests.
    pub fn shard_utilization(&self, wall: Duration) -> Vec<f64> {
        let w = wall.as_secs_f64().max(1e-12);
        self.per_shard
            .iter()
            .map(|s| s.busy.as_secs_f64() / w)
            .collect()
    }
}

struct Request<Q, A> {
    payload: Q,
    enqueued: Instant,
    reply: mpsc::Sender<A>,
}

/// Handle to the worker pool.  Dropping it shuts every worker down (after
/// the queue drains).
pub struct EvalService<Q: Send + 'static, A: Send + 'static> {
    tx: mpsc::Sender<Request<Q, A>>,
    stats: Arc<Mutex<ServiceStats>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl<Q: Send + 'static, A: Send + 'static> EvalService<Q, A> {
    /// Spawn a single worker.  `builder` runs *on the worker thread* and
    /// constructs the evaluation closure there (back-compat single-shard
    /// API; see [`EvalService::spawn_sharded`]).
    pub fn spawn<B, F>(builder: B) -> Self
    where
        B: FnOnce() -> F + Send + 'static,
        F: FnMut(Q) -> A + 'static,
    {
        let cell = Mutex::new(Some(builder));
        Self::spawn_sharded(1, move |_shard| {
            let b = cell
                .lock()
                .unwrap()
                .take()
                .expect("single-shard builder invoked twice");
            b()
        })
    }

    /// Spawn `workers` shards.  `builder(shard_index)` runs once *on each
    /// worker thread* and constructs that shard's evaluation closure there
    /// (confining non-`Send` runtime state to its shard).
    pub fn spawn_sharded<B, F>(workers: usize, builder: B) -> Self
    where
        B: Fn(usize) -> F + Send + Sync + 'static,
        F: FnMut(Q) -> A + 'static,
    {
        let n = workers.max(1);
        let (tx, rx) = mpsc::channel::<Request<Q, A>>();
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(Mutex::new(ServiceStats {
            per_shard: vec![ShardStats::default(); n],
            ..ServiceStats::default()
        }));
        let builder = Arc::new(builder);
        let mut handles = Vec::with_capacity(n);
        for shard in 0..n {
            let rx = rx.clone();
            let stats = stats.clone();
            let builder = builder.clone();
            handles.push(std::thread::spawn(move || {
                let mut eval = (*builder)(shard);
                loop {
                    // Holding the lock while blocked in recv() is the queue
                    // discipline: exactly one idle shard waits on the channel,
                    // the rest wait on the mutex.  The lock is released before
                    // evaluation so other shards can pick up the next request.
                    let req = {
                        let guard = match rx.lock() {
                            Ok(g) => g,
                            Err(_) => break,
                        };
                        guard.recv()
                    };
                    let Ok(req) = req else { break };
                    let started = Instant::now();
                    let wait = started - req.enqueued;
                    let answer = eval(req.payload);
                    let service = started.elapsed();
                    {
                        let mut s = stats.lock().unwrap();
                        s.completed += 1;
                        s.total_queue_wait += wait;
                        s.total_service_time += service;
                        s.per_shard[shard].completed += 1;
                        s.per_shard[shard].busy += service;
                    }
                    let _ = req.reply.send(answer);
                }
            }));
        }
        EvalService { tx, stats, workers: handles }
    }

    /// Number of worker shards.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a request; returns a receiver for the answer.
    pub fn submit(&self, payload: Q) -> mpsc::Receiver<A> {
        let (rtx, rrx) = mpsc::channel();
        self.stats.lock().unwrap().submitted += 1;
        let _ = self.tx.send(Request { payload, enqueued: Instant::now(), reply: rtx });
        rrx
    }

    /// Submit and block for the answer.
    pub fn call(&self, payload: Q) -> A {
        self.submit(payload).recv().expect("worker died")
    }

    /// Submit a whole batch, then collect replies in submission order —
    /// the deterministic-reassembly primitive the search loop relies on.
    pub fn call_batch(&self, payloads: Vec<Q>) -> Vec<A> {
        let rxs: Vec<_> = payloads.into_iter().map(|p| self.submit(p)).collect();
        rxs.into_iter().map(|rx| rx.recv().expect("worker died")).collect()
    }

    /// Snapshot of the queue/latency counters.
    pub fn stats(&self) -> ServiceStats {
        self.stats.lock().unwrap().clone()
    }
}

impl<Q: Send + 'static, A: Send + 'static> Drop for EvalService<Q, A> {
    fn drop(&mut self) {
        // Closing the channel stops the worker loops once the queue drains.
        let (dead_tx, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, dead_tx));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_single() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x * 2);
        assert_eq!(svc.call(21), 42);
        let s = svc.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.per_shard.len(), 1);
    }

    #[test]
    fn batch_preserves_order() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x + 1);
        let out = svc.call_batch((0..100).collect());
        assert_eq!(out, (1..101).collect::<Vec<_>>());
    }

    #[test]
    fn worker_state_is_threadlocal() {
        // builder runs on the worker: stateful counter works without Sync
        let svc: EvalService<(), u64> = EvalService::spawn(|| {
            let mut count = 0u64;
            move |_| {
                count += 1;
                count
            }
        });
        assert_eq!(svc.call(()), 1);
        assert_eq!(svc.call(()), 2);
    }

    #[test]
    fn shutdown_joins_worker() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x);
        svc.call(1);
        drop(svc); // must not hang
    }

    #[test]
    fn sharded_batch_preserves_order_under_contention() {
        // Payload-dependent delays force out-of-order completion across
        // shards; reply-channel reassembly must still return submission order.
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(4, |_shard| {
            |x: u32| {
                std::thread::sleep(Duration::from_micros(((x * 7919) % 977) as u64));
                x + 1
            }
        });
        let out = svc.call_batch((0..200).collect());
        assert_eq!(out, (1..201).collect::<Vec<_>>());
    }

    #[test]
    fn sharded_results_identical_to_single() {
        let eval = |x: u32| x.wrapping_mul(2654435761) ^ 0x9E37;
        let one: EvalService<u32, u32> = EvalService::spawn_sharded(1, move |_| eval);
        let four: EvalService<u32, u32> = EvalService::spawn_sharded(4, move |_| eval);
        let inputs: Vec<u32> = (0..64).collect();
        assert_eq!(one.call_batch(inputs.clone()), four.call_batch(inputs));
    }

    #[test]
    fn sharded_stats_aggregate() {
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(3, |_s| |x: u32| x);
        let _ = svc.call_batch((0..30).collect());
        let s = svc.stats();
        assert_eq!(s.submitted, 30);
        assert_eq!(s.completed, 30);
        assert_eq!(s.per_shard.len(), 3);
        assert_eq!(s.per_shard.iter().map(|p| p.completed).sum::<u64>(), 30);
        assert_eq!(s.shard_utilization(Duration::from_secs(1)).len(), 3);
    }

    #[test]
    fn sharded_work_actually_distributes() {
        // With blocking work and more requests than shards, no shard can
        // serve everything: at least two shards must complete requests.
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(4, |_s| {
            |x: u32| {
                std::thread::sleep(Duration::from_millis(5));
                x
            }
        });
        let _ = svc.call_batch((0..16).collect());
        let s = svc.stats();
        let active = s.per_shard.iter().filter(|p| p.completed > 0).count();
        assert!(active >= 2, "expected >=2 active shards, got {active}");
    }

    #[test]
    fn shard_builder_sees_its_index() {
        let svc: EvalService<(), usize> =
            EvalService::spawn_sharded(1, |shard| move |_| shard);
        assert_eq!(svc.call(()), 0);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(0, |_s| |x: u32| x);
        assert_eq!(svc.n_workers(), 1);
        assert_eq!(svc.call(7), 7);
    }
}

//! EvalService — a sharded evaluation pool in the style of a serving
//! router's batcher.  Callers (CLI, examples, the search loop) submit
//! requests through a shared channel and receive results through
//! per-request reply channels.
//!
//! Sharding model:
//!  * N workers share a single FIFO request channel (work-sharing: whichever
//!    shard is idle takes the next request, so a slow candidate never blocks
//!    the queue behind one thread);
//!  * each worker owns its own evaluation state, built *on the worker
//!    thread* by the shard builder — per-shard state can be anything from a
//!    full non-`Send` runtime stack down to a couple of `Arc` handles onto
//!    process-wide shared state, or (via [`EvalService::spawn_flow`]) a TCP
//!    connection to a remote shard server speaking the
//!    [`crate::runtime::wire`] protocol;
//!  * every request carries a **chunk id** minted at submission.  The id
//!    keys an in-flight registry (payload snapshot + reply sender + age),
//!    which makes reply delivery idempotent: however many copies of a chunk
//!    end up evaluated — requeues after a shard retirement, speculative
//!    hedge duplicates — exactly one reply reaches the caller, and
//!    `call_batch` reassembles in submission order.  Results are therefore
//!    deterministically ordered and bit-identical regardless of worker
//!    count, **provided** the evaluation closure is a pure function of the
//!    payload (seed any randomness per-candidate from the payload, never
//!    from shard state).
//!
//! Hedged dispatch ([`HedgePolicy`]): an idle shard watches the in-flight
//! registry.  When a chunk has been running longer than
//! `hedge_factor × p50` of recently completed chunks (floored by
//! [`HedgePolicy::floor`] so micro-evals don't hedge-storm), the idle shard
//! claims a **speculative duplicate** and evaluates it itself — first reply
//! wins, the loser is discarded by chunk id.  Evaluations are pure, so
//! either copy is bitwise-identical and archives never depend on who won.
//! A chunk may be re-hedged if its previous hedge also stalls (each hedge
//! re-arms the age clock), so one wedged shard can never absorb the only
//! duplicate.  Counters: `hedged_dispatched` / `hedged_won` /
//! `hedged_wasted` on [`ServiceStats`], plus the rolling `latency_p50`
//! estimate the trigger uses.
//!
//! Failure model: a shard whose closure panics, or that asks to retire
//! ([`ShardFlow::Retire`] — remote transports do this when a connection
//! dies beyond retry), leaves the pool **without poisoning it**.  Its
//! in-flight request is requeued onto the shared FIFO *unless the chunk was
//! already delivered by another copy* (the requeue-after-delivery
//! double-count this registry exists to prevent; suppressed requeues count
//! as `requeued_duplicates`).  Only when the *last* shard retires do
//! pending requests fail — surfaced as `Err` from [`EvalService::call`] /
//! [`EvalService::call_batch`], never a panic.
//!
//! Deterministic fault scenarios (wedged / delayed / crashed shards) are
//! exercised through [`crate::runtime::faults`] rather than timing hacks.
//!
//! Generic over request/response so tests can exercise the queueing logic
//! without PJRT.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Completed-chunk service times kept for the rolling p50 estimate.
const LATENCY_WINDOW: usize = 64;

/// Default `--hedge-factor`: hedge a chunk once it has been in flight for
/// 4× the rolling p50 service time (0 disables hedging).
pub const DEFAULT_HEDGE_FACTOR: f64 = 4.0;

/// Default floor under the hedge threshold: never hedge a chunk younger
/// than this, whatever the p50 says (micro-evals would otherwise duplicate
/// constantly for no win).
pub const DEFAULT_HEDGE_FLOOR: Duration = Duration::from_millis(25);

/// When an idle shard speculatively re-dispatches a straggling chunk.
///
/// The trigger is `age > max(floor, factor × p50)` where `p50` is the
/// rolling median service time of recently completed chunks and `age` is
/// measured from the chunk's (re-)dispatch.  `factor == 0` disables
/// hedging entirely (the worker loop then blocks in plain `recv`, zero
/// overhead).
#[derive(Clone, Copy, Debug)]
pub struct HedgePolicy {
    /// Multiple of the rolling p50 a chunk must exceed before an idle
    /// shard duplicates it (`--hedge-factor`; 0 = off).
    pub factor: f64,
    /// Minimum in-flight age before hedging, independent of the p50.
    pub floor: Duration,
}

impl Default for HedgePolicy {
    fn default() -> Self {
        HedgePolicy { factor: DEFAULT_HEDGE_FACTOR, floor: DEFAULT_HEDGE_FLOOR }
    }
}

impl HedgePolicy {
    /// Hedging off: the worker loop degenerates to the plain blocking
    /// FIFO (the pre-hedging behavior, bit for bit).
    pub fn disabled() -> Self {
        HedgePolicy { factor: 0.0, floor: DEFAULT_HEDGE_FLOOR }
    }

    /// Policy from a `--hedge-factor` value (0 disables).
    pub fn from_factor(factor: f64) -> Self {
        HedgePolicy { factor, ..HedgePolicy::default() }
    }

    /// Whether hedging is active.
    pub fn enabled(&self) -> bool {
        self.factor > 0.0
    }

    /// In-flight age beyond which a chunk becomes a hedge candidate.
    fn threshold(&self, p50: Duration) -> Duration {
        let scaled = Duration::from_secs_f64(p50.as_secs_f64() * self.factor);
        scaled.max(self.floor)
    }
}

/// Per-shard accounting: how many requests the shard served and how long it
/// spent serving them (busy time / wall time = utilization).
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Human-readable shard label (`local#N`, or the remote address).
    pub label: String,
    /// Requests this shard served (winning replies only; discarded
    /// duplicate replies count toward `busy` but not here, so the
    /// per-shard sum always equals [`ServiceStats::completed`]).
    pub completed: u64,
    /// Wall-clock this shard spent inside its evaluation closure.
    pub busy: Duration,
    /// True once the shard has left the pool (panic or [`ShardFlow::Retire`]).
    pub retired: bool,
}

/// Queue/latency accounting, aggregated across shards.
///
/// Copy conservation: every chunk copy that resolves — delivered to the
/// caller, or discarded as a duplicate — increments `dispatched` and
/// exactly one of `completed` / `hedged_wasted` / `requeued_duplicates`,
/// so `completed == dispatched - hedged_wasted - requeued_duplicates`
/// holds at every quiescent point (property-tested).
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    /// Requests submitted to the shared queue (unique chunks).
    pub submitted: u64,
    /// Chunk copies that resolved (delivered or discarded; see above).
    pub dispatched: u64,
    /// Requests served — unique replies delivered to callers.
    pub completed: u64,
    /// Requests put back on the queue after their shard retired mid-flight.
    pub requeued: u64,
    /// Speculative duplicates claimed by idle shards ([`HedgePolicy`]).
    pub hedged_dispatched: u64,
    /// Chunks whose winning reply came from a speculative copy.
    pub hedged_won: u64,
    /// Duplicate replies discarded on hedged chunks (the losing copy).
    pub hedged_wasted: u64,
    /// Requeue-path duplicates suppressed because the chunk had already
    /// been delivered (the double-count bug this registry prevents).
    pub requeued_duplicates: u64,
    /// Rolling median service time of recently completed chunks — the
    /// latency estimate the hedge trigger compares in-flight age against.
    pub latency_p50: Duration,
    /// Summed queue wait (enqueue → a shard picked the request up).
    pub total_queue_wait: Duration,
    /// Summed service time (inside the evaluation closures).
    pub total_service_time: Duration,
    /// Per-shard breakdown, shard-index order.
    pub per_shard: Vec<ShardStats>,
}

impl ServiceStats {
    /// Mean queue wait per completed request.
    pub fn mean_wait(&self) -> Duration {
        if self.completed == 0 {
            Duration::ZERO
        } else {
            self.total_queue_wait / self.completed as u32
        }
    }

    /// Mean service time per completed request.
    pub fn mean_service(&self) -> Duration {
        if self.completed == 0 {
            Duration::ZERO
        } else {
            self.total_service_time / self.completed as u32
        }
    }

    /// Fraction of `wall` each shard spent serving requests.
    pub fn shard_utilization(&self, wall: Duration) -> Vec<f64> {
        let w = wall.as_secs_f64().max(1e-12);
        self.per_shard
            .iter()
            .map(|s| s.busy.as_secs_f64() / w)
            .collect()
    }

    /// Shards that have retired (panicked closures / dead transports).
    pub fn retired_shards(&self) -> usize {
        self.per_shard.iter().filter(|s| s.retired).count()
    }
}

/// What a shard's evaluation closure did with one request: answer it, or
/// take the shard out of the pool (the request is requeued for the
/// surviving shards — pure evaluations make the re-run identical).
pub enum ShardFlow<A> {
    /// The request was served; send this answer back.
    Reply(A),
    /// The shard is no longer usable (e.g. its remote connection died
    /// beyond retry).  The in-flight request goes back on the shared FIFO
    /// and the shard leaves the pool.
    Retire { reason: String },
}

/// What rides the FIFO: just the chunk id.  Payload and reply sender live
/// in the in-flight registry, looked up at pickup — which is what makes
/// delivery idempotent across requeued and speculative copies.
struct Request {
    id: u64,
}

/// What a worker picked up: a queued copy off the FIFO, or a speculative
/// hedge copy claimed straight from the in-flight registry (hedge copies
/// never ride the FIFO — the claiming shard evaluates them itself, payload
/// snapshot cloned under the registry lock at claim time).
enum Work<Q> {
    Queued(u64),
    Hedge(u64, Q),
}

/// Registry entry for one submitted chunk: the payload snapshot every
/// copy evaluates, the caller's reply sender, and the age/copy state the
/// hedge trigger and the idempotent delivery path read.
struct Track<Q, A> {
    payload: Q,
    reply: mpsc::Sender<A>,
    /// (Re-)enqueue time of the queued copy — queue-wait accounting.
    enqueued: Instant,
    /// When a shard last started evaluating a copy (None while queued).
    started: Option<Instant>,
    /// When the chunk was last hedged (re-arms the age clock so a stalled
    /// hedge can itself be re-hedged).
    last_hedge: Option<Instant>,
    /// Speculative copies claimed so far.
    hedges: u32,
    /// Copies currently queued or evaluating.  The entry is dropped once
    /// the chunk is delivered and the last copy resolves.
    active: u32,
    delivered: bool,
}

/// Stats + in-flight registry + latency window behind one lock.  Lock
/// order: the FIFO receiver mutex (if held) is always taken *before* this
/// one; nothing acquires the receiver while holding this.
struct Shared<Q, A> {
    stats: ServiceStats,
    tracks: HashMap<u64, Track<Q, A>>,
    lat: VecDeque<Duration>,
}

impl<Q, A> Shared<Q, A> {
    /// Record a completed service time and refresh the rolling p50.
    fn push_latency(&mut self, service: Duration) {
        if self.lat.len() == LATENCY_WINDOW {
            self.lat.pop_front();
        }
        self.lat.push_back(service);
        let mut sorted: Vec<Duration> = self.lat.iter().copied().collect();
        sorted.sort_unstable();
        self.stats.latency_p50 = sorted[sorted.len() / 2];
    }

    /// Drop one copy of `id`, removing the entry once the chunk is
    /// delivered and no copies remain in flight.
    fn release_copy(&mut self, id: u64) {
        if let Some(t) = self.tracks.get_mut(&id) {
            t.active = t.active.saturating_sub(1);
            if t.delivered && t.active == 0 {
                self.tracks.remove(&id);
            }
        }
    }
}

/// What an idle shard found when it polled the in-flight registry.
enum HedgePoll<Q> {
    /// A straggler was claimed: evaluate this speculative copy now.
    Claim(u64, Q),
    /// Nothing due yet; the earliest candidate matures in this long.
    Wait(Duration),
    /// Nothing in flight to watch; block on the queue.
    Idle,
}

/// Sender half shared with the workers so a retiring shard can requeue its
/// in-flight request.  `Drop` clears it (alongside the caller-side sender)
/// so the channel actually closes at shutdown.
type SharedTx = Arc<Mutex<Option<mpsc::Sender<Request>>>>;

/// Handle to the worker pool.  Dropping it shuts every worker down (after
/// the queue drains).
pub struct EvalService<Q: Send + 'static, A: Send + 'static> {
    tx: mpsc::Sender<Request>,
    shared_tx: SharedTx,
    shared: Arc<Mutex<Shared<Q, A>>>,
    next_id: AtomicU64,
    alive: Arc<AtomicUsize>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl<Q: Send + 'static, A: Send + 'static> EvalService<Q, A> {
    /// Spawn a single worker.  `builder` runs *on the worker thread* and
    /// constructs the evaluation closure there (back-compat single-shard
    /// API; see [`EvalService::spawn_sharded`]).
    pub fn spawn<B, F>(builder: B) -> Self
    where
        Q: Clone,
        B: FnOnce() -> F + Send + 'static,
        F: FnMut(Q) -> A + 'static,
    {
        let cell = Mutex::new(Some(builder));
        Self::spawn_sharded(1, move |_shard| {
            let b = cell
                .lock()
                .unwrap()
                .take()
                .expect("single-shard builder invoked twice");
            b()
        })
    }

    /// Spawn `workers` shards.  `builder(shard_index)` runs once *on each
    /// worker thread* and constructs that shard's evaluation closure there
    /// (confining non-`Send` runtime state to its shard).  Hedging is off;
    /// see [`EvalService::spawn_sharded_with`].
    pub fn spawn_sharded<B, F>(workers: usize, builder: B) -> Self
    where
        Q: Clone,
        B: Fn(usize) -> F + Send + Sync + 'static,
        F: FnMut(Q) -> A + 'static,
    {
        Self::spawn_sharded_with(workers, builder, HedgePolicy::disabled())
    }

    /// [`EvalService::spawn_sharded`] with an explicit [`HedgePolicy`].
    pub fn spawn_sharded_with<B, F>(workers: usize, builder: B, policy: HedgePolicy) -> Self
    where
        Q: Clone,
        B: Fn(usize) -> F + Send + Sync + 'static,
        F: FnMut(Q) -> A + 'static,
    {
        let n = workers.max(1);
        let labels = (0..n).map(|i| format!("local#{i}")).collect();
        Self::spawn_flow_with(
            labels,
            move |shard| {
                let mut eval = builder(shard);
                Box::new(move |q: Q| ShardFlow::Reply(eval(q)))
            },
            policy,
        )
    }

    /// Spawn one shard per label.  The most general constructor: each
    /// shard's closure decides per request whether to [`ShardFlow::Reply`]
    /// or to [`ShardFlow::Retire`] from the pool, which lets heterogeneous
    /// shards (local device closures and remote TCP feeders) share one
    /// FIFO.  A closure that panics is treated as retiring.  Hedging is
    /// off; see [`EvalService::spawn_flow_with`].
    ///
    /// `Q: Clone` because the registry snapshots each payload, so requeues
    /// and speculative duplicates re-evaluate the request intact.
    pub fn spawn_flow<B>(labels: Vec<String>, builder: B) -> Self
    where
        Q: Clone,
        B: Fn(usize) -> Box<dyn FnMut(Q) -> ShardFlow<A>> + Send + Sync + 'static,
    {
        Self::spawn_flow_with(labels, builder, HedgePolicy::disabled())
    }

    /// [`EvalService::spawn_flow`] with an explicit [`HedgePolicy`].
    pub fn spawn_flow_with<B>(labels: Vec<String>, builder: B, policy: HedgePolicy) -> Self
    where
        Q: Clone,
        B: Fn(usize) -> Box<dyn FnMut(Q) -> ShardFlow<A>> + Send + Sync + 'static,
    {
        let n = labels.len().max(1);
        let labels: Vec<String> = if labels.is_empty() {
            vec!["local#0".to_string()]
        } else {
            labels
        };
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let shared_tx: SharedTx = Arc::new(Mutex::new(Some(tx.clone())));
        let shared = Arc::new(Mutex::new(Shared {
            stats: ServiceStats {
                per_shard: labels
                    .iter()
                    .map(|l| ShardStats { label: l.clone(), ..ShardStats::default() })
                    .collect(),
                ..ServiceStats::default()
            },
            tracks: HashMap::new(),
            lat: VecDeque::with_capacity(LATENCY_WINDOW),
        }));
        let alive = Arc::new(AtomicUsize::new(n));
        let builder = Arc::new(builder);
        let mut handles = Vec::with_capacity(n);
        for shard in 0..n {
            let rx = rx.clone();
            let shared = shared.clone();
            let builder = builder.clone();
            let shared_tx = shared_tx.clone();
            let alive = alive.clone();
            handles.push(std::thread::spawn(move || {
                let mut eval = (*builder)(shard);
                'serve: loop {
                    // Holding the lock while blocked in recv() is the queue
                    // discipline: exactly one idle shard waits on the channel,
                    // the rest wait on the mutex.  The lock is released before
                    // evaluation so other shards can pick up the next request.
                    // With hedging enabled, the lock holder periodically polls
                    // the in-flight registry for stragglers instead of
                    // blocking indefinitely.
                    let work = {
                        let guard = match rx.lock() {
                            Ok(g) => g,
                            Err(_) => break,
                        };
                        if !policy.enabled() {
                            match guard.recv() {
                                Ok(req) => Work::Queued(req.id),
                                Err(_) => break,
                            }
                        } else {
                            loop {
                                // Queued work first: hedging only spends
                                // genuinely surplus idle time.
                                match guard.try_recv() {
                                    Ok(req) => break Work::Queued(req.id),
                                    Err(mpsc::TryRecvError::Disconnected) => break 'serve,
                                    Err(mpsc::TryRecvError::Empty) => {}
                                }
                                match poll_hedge(&shared, &policy) {
                                    HedgePoll::Claim(id, payload) => {
                                        break Work::Hedge(id, payload)
                                    }
                                    HedgePoll::Wait(d) => {
                                        let d = d.max(Duration::from_millis(1));
                                        match guard.recv_timeout(d) {
                                            Ok(req) => break Work::Queued(req.id),
                                            Err(mpsc::RecvTimeoutError::Timeout) => {}
                                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                                break 'serve
                                            }
                                        }
                                    }
                                    HedgePoll::Idle => match guard.recv() {
                                        Ok(req) => break Work::Queued(req.id),
                                        Err(_) => break 'serve,
                                    },
                                }
                            }
                        }
                    };
                    let (id, speculative, payload, wait) = match work {
                        // Hedge copies carry their payload from claim time
                        // and pay no queue wait.
                        Work::Hedge(id, payload) => (id, true, payload, Duration::ZERO),
                        Work::Queued(id) => {
                            // Look the queued copy up; a copy of an already-
                            // delivered chunk (a requeue that lost the race)
                            // resolves here without re-evaluating.
                            let mut guard = shared.lock().unwrap();
                            let sh = &mut *guard;
                            let now = Instant::now();
                            let picked = match sh.tracks.get_mut(&id) {
                                Some(t) if !t.delivered => {
                                    let wait = now.duration_since(t.enqueued);
                                    if t.started.is_none() {
                                        t.started = Some(now);
                                    }
                                    Some((t.payload.clone(), wait))
                                }
                                _ => None,
                            };
                            match picked {
                                Some((payload, wait)) => (id, false, payload, wait),
                                None => {
                                    sh.stats.dispatched += 1;
                                    sh.stats.requeued_duplicates += 1;
                                    sh.release_copy(id);
                                    continue;
                                }
                            }
                        }
                    };
                    let started = Instant::now();
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || eval(payload),
                    ));
                    let service = started.elapsed();
                    match outcome {
                        Ok(ShardFlow::Reply(answer)) => {
                            // First reply wins; late copies of an already-
                            // delivered chunk are discarded by chunk id
                            // (idempotent delivery).
                            enum Won {
                                Delivered,
                                LostHedged,
                                LostRequeued,
                            }
                            let mut guard = shared.lock().unwrap();
                            let sh = &mut *guard;
                            sh.stats.dispatched += 1;
                            sh.stats.per_shard[shard].busy += service;
                            let won = match sh.tracks.get_mut(&id) {
                                Some(t) if !t.delivered => {
                                    t.delivered = true;
                                    let _ = t.reply.send(answer);
                                    Won::Delivered
                                }
                                Some(t) if t.hedges > 0 => Won::LostHedged,
                                _ => Won::LostRequeued,
                            };
                            match won {
                                Won::Delivered => {
                                    if speculative {
                                        sh.stats.hedged_won += 1;
                                    }
                                    sh.stats.completed += 1;
                                    sh.stats.total_queue_wait += wait;
                                    sh.stats.total_service_time += service;
                                    sh.stats.per_shard[shard].completed += 1;
                                    sh.push_latency(service);
                                }
                                Won::LostHedged => sh.stats.hedged_wasted += 1,
                                Won::LostRequeued => sh.stats.requeued_duplicates += 1,
                            }
                            sh.release_copy(id);
                        }
                        other => {
                            // Retire path: explicit ShardFlow::Retire or a
                            // panicked closure — both take the shard out of
                            // the pool without poisoning the queue.
                            let reason = match other {
                                Ok(ShardFlow::Retire { reason }) => reason,
                                Err(panic) => {
                                    let msg = panic
                                        .downcast_ref::<String>()
                                        .map(|s| s.as_str())
                                        .or_else(|| {
                                            panic.downcast_ref::<&str>().copied()
                                        })
                                        .unwrap_or("panic");
                                    format!("evaluation panicked: {msg}")
                                }
                                Ok(ShardFlow::Reply(_)) => unreachable!(),
                            };
                            let remaining = alive.fetch_sub(1, Ordering::SeqCst) - 1;
                            let label = {
                                let mut sh = shared.lock().unwrap();
                                sh.stats.per_shard[shard].retired = true;
                                sh.stats.per_shard[shard].busy += service;
                                let delivered = sh
                                    .tracks
                                    .get(&id)
                                    .map(|t| t.delivered)
                                    .unwrap_or(true);
                                if delivered {
                                    // The chunk already reached the caller via
                                    // another copy: requeueing it again is the
                                    // double-count bug — suppress it.
                                    sh.stats.dispatched += 1;
                                    sh.stats.requeued_duplicates += 1;
                                    sh.release_copy(id);
                                } else if remaining > 0 {
                                    // Put the in-flight request back on the
                                    // FIFO (fresh enqueue time; the registry
                                    // entry rides along, so the caller never
                                    // notices beyond added latency).  Sent
                                    // under the registry lock so delivery of a
                                    // racing copy can't interleave.
                                    sh.stats.requeued += 1;
                                    if let Some(t) = sh.tracks.get_mut(&id) {
                                        t.enqueued = Instant::now();
                                        t.started = None;
                                    }
                                    if let Some(tx) = shared_tx.lock().unwrap().as_ref() {
                                        let _ = tx.send(Request { id });
                                    }
                                    // (If the service is mid-shutdown the cell
                                    // is empty and the copy resolves when the
                                    // registry drops with the service.)
                                } else {
                                    // Last shard out: drop the registry entry
                                    // (its reply sender drops with it, so the
                                    // caller gets an immediate error instead
                                    // of a hang) and drain the queue until
                                    // shutdown closes the channel, failing
                                    // queued requests the same way.
                                    sh.tracks.remove(&id);
                                }
                                sh.stats.per_shard[shard].label.clone()
                            };
                            eprintln!(
                                "[pool] shard {label} retired ({reason}); \
                                 {remaining} shard(s) remain"
                            );
                            if remaining == 0 {
                                if let Ok(guard) = rx.lock() {
                                    while let Ok(req) = guard.recv() {
                                        shared.lock().unwrap().tracks.remove(&req.id);
                                    }
                                }
                            }
                            break;
                        }
                    }
                }
            }));
        }
        EvalService {
            tx,
            shared_tx,
            shared,
            next_id: AtomicU64::new(0),
            alive,
            workers: handles,
        }
    }

    /// Number of worker shards spawned (including retired ones).
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Shards still serving (spawned minus retired).
    pub fn live_workers(&self) -> usize {
        self.alive.load(Ordering::SeqCst)
    }

    /// Chunks in the in-flight registry (queued, evaluating, or awaiting
    /// the resolution of a straggling duplicate copy).  Reaches 0 when the
    /// pool is quiescent — the accounting invariants hold exactly there.
    pub fn in_flight(&self) -> usize {
        self.shared.lock().unwrap().tracks.len()
    }

    /// Submit a request; returns a receiver for the answer.  If every shard
    /// has retired, the receiver's `recv()` fails instead of hanging.
    pub fn submit(&self, payload: Q) -> mpsc::Receiver<A> {
        let (rtx, rrx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut sh = self.shared.lock().unwrap();
            sh.stats.submitted += 1;
            sh.tracks.insert(
                id,
                Track {
                    payload,
                    reply: rtx,
                    enqueued: Instant::now(),
                    started: None,
                    last_hedge: None,
                    hedges: 0,
                    active: 1,
                    delivered: false,
                },
            );
        }
        if self.tx.send(Request { id }).is_err() {
            // Every worker exited (fully retired pool): drop the entry so
            // the caller sees a recv error instead of hanging.
            self.shared.lock().unwrap().tracks.remove(&id);
        }
        rrx
    }

    /// Submit and block for the answer.  Errors (instead of panicking) when
    /// the request was dropped because every shard retired.
    pub fn call(&self, payload: Q) -> crate::Result<A> {
        self.submit(payload).recv().map_err(|_| self.dead_pool_error())
    }

    /// Submit a whole batch, then collect replies in submission order —
    /// the deterministic-reassembly primitive the search loop relies on.
    /// A single retired shard is invisible here (its in-flight chunk is
    /// requeued); only a fully-retired pool surfaces as `Err`.
    pub fn call_batch(&self, payloads: Vec<Q>) -> crate::Result<Vec<A>> {
        let rxs: Vec<_> = payloads.into_iter().map(|p| self.submit(p)).collect();
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|_| self.dead_pool_error()))
            .collect()
    }

    fn dead_pool_error(&self) -> eyre::Report {
        let retired = self.shared.lock().unwrap().stats.retired_shards();
        eyre::anyhow!(
            "evaluation pool request dropped: {retired} of {} shard(s) retired, \
             no live shard remains to serve it",
            self.n_workers()
        )
    }

    /// Snapshot of the queue/latency counters.
    pub fn stats(&self) -> ServiceStats {
        self.shared.lock().unwrap().stats.clone()
    }
}

/// One idle-shard poll of the in-flight registry: claim the oldest due
/// straggler, or report how long until the earliest candidate matures.
fn poll_hedge<Q: Clone, A>(
    shared: &Arc<Mutex<Shared<Q, A>>>,
    policy: &HedgePolicy,
) -> HedgePoll<Q> {
    let mut sh = shared.lock().unwrap();
    let threshold = policy.threshold(sh.stats.latency_p50);
    let now = Instant::now();
    let mut due: Option<(u64, Instant)> = None;
    let mut next: Option<Duration> = None;
    for (&id, t) in &sh.tracks {
        if t.delivered {
            continue;
        }
        // Only chunks actually running on a shard: a queued chunk has no
        // straggler to race (an idle shard would just receive it).
        let Some(started) = t.started else { continue };
        // Each hedge re-arms the clock so a stalled duplicate can itself
        // be re-hedged — one wedged shard never absorbs the only copy.
        let basis = t.last_hedge.map_or(started, |h| h.max(started));
        let age = now.duration_since(basis);
        if age >= threshold {
            match due {
                Some((_, b)) if b <= basis => {}
                _ => due = Some((id, basis)),
            }
        } else {
            let remain = threshold - age;
            match next {
                Some(n) if n <= remain => {}
                _ => next = Some(remain),
            }
        }
    }
    if let Some((id, _)) = due {
        let t = sh.tracks.get_mut(&id).expect("candidate selected above");
        t.hedges += 1;
        t.last_hedge = Some(now);
        t.active += 1;
        let payload = t.payload.clone();
        sh.stats.hedged_dispatched += 1;
        return HedgePoll::Claim(id, payload);
    }
    match next {
        Some(d) => HedgePoll::Wait(d),
        None => HedgePoll::Idle,
    }
}

impl<Q: Send + 'static, A: Send + 'static> Drop for EvalService<Q, A> {
    fn drop(&mut self) {
        // Closing the channel stops the worker loops once the queue drains.
        // Both sender halves must go: the caller-side `tx` and the workers'
        // shared requeue sender.
        self.shared_tx.lock().unwrap().take();
        let (dead_tx, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, dead_tx));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Condvar;

    #[test]
    fn roundtrip_single() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x * 2);
        assert_eq!(svc.call(21).unwrap(), 42);
        let s = svc.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.requeued, 0);
        assert_eq!(s.per_shard.len(), 1);
        assert_eq!(s.per_shard[0].label, "local#0");
        assert!(!s.per_shard[0].retired);
        assert_eq!(svc.in_flight(), 0);
    }

    #[test]
    fn batch_preserves_order() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x + 1);
        let out = svc.call_batch((0..100).collect()).unwrap();
        assert_eq!(out, (1..101).collect::<Vec<_>>());
    }

    #[test]
    fn worker_state_is_threadlocal() {
        // builder runs on the worker: stateful counter works without Sync
        let svc: EvalService<(), u64> = EvalService::spawn(|| {
            let mut count = 0u64;
            move |_| {
                count += 1;
                count
            }
        });
        assert_eq!(svc.call(()).unwrap(), 1);
        assert_eq!(svc.call(()).unwrap(), 2);
    }

    #[test]
    fn shutdown_joins_worker() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x);
        svc.call(1).unwrap();
        drop(svc); // must not hang
    }

    #[test]
    fn sharded_batch_preserves_order_under_contention() {
        // Payload-dependent delays force out-of-order completion across
        // shards; reply-channel reassembly must still return submission order.
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(4, |_shard| {
            |x: u32| {
                std::thread::sleep(Duration::from_micros(((x * 7919) % 977) as u64));
                x + 1
            }
        });
        let out = svc.call_batch((0..200).collect()).unwrap();
        assert_eq!(out, (1..201).collect::<Vec<_>>());
    }

    #[test]
    fn sharded_results_identical_to_single() {
        let eval = |x: u32| x.wrapping_mul(2654435761) ^ 0x9E37;
        let one: EvalService<u32, u32> = EvalService::spawn_sharded(1, move |_| eval);
        let four: EvalService<u32, u32> = EvalService::spawn_sharded(4, move |_| eval);
        let inputs: Vec<u32> = (0..64).collect();
        assert_eq!(
            one.call_batch(inputs.clone()).unwrap(),
            four.call_batch(inputs).unwrap()
        );
    }

    #[test]
    fn sharded_stats_aggregate() {
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(3, |_s| |x: u32| x);
        let _ = svc.call_batch((0..30).collect()).unwrap();
        let s = svc.stats();
        assert_eq!(s.submitted, 30);
        assert_eq!(s.completed, 30);
        assert_eq!(s.dispatched, 30, "no faults: every copy resolves delivered");
        assert_eq!(s.per_shard.len(), 3);
        assert_eq!(s.per_shard.iter().map(|p| p.completed).sum::<u64>(), 30);
        assert_eq!(s.shard_utilization(Duration::from_secs(1)).len(), 3);
        assert_eq!(s.retired_shards(), 0);
    }

    #[test]
    fn sharded_work_actually_distributes() {
        // With blocking work and more requests than shards, no shard can
        // serve everything: at least two shards must complete requests.
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(4, |_s| {
            |x: u32| {
                std::thread::sleep(Duration::from_millis(5));
                x
            }
        });
        let _ = svc.call_batch((0..16).collect()).unwrap();
        let s = svc.stats();
        let active = s.per_shard.iter().filter(|p| p.completed > 0).count();
        assert!(active >= 2, "expected >=2 active shards, got {active}");
    }

    #[test]
    fn shard_builder_sees_its_index() {
        let svc: EvalService<(), usize> =
            EvalService::spawn_sharded(1, |shard| move |_| shard);
        assert_eq!(svc.call(()).unwrap(), 0);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(0, |_s| |x: u32| x);
        assert_eq!(svc.n_workers(), 1);
        assert_eq!(svc.call(7).unwrap(), 7);
    }

    #[test]
    fn crashed_shard_requeues_and_pool_degrades() {
        // Whichever shard picks up the poison payload first panics (exactly
        // once, via the shared trip flag), retires and requeues the request;
        // the surviving shard then serves it.  The batch result is complete
        // and correct — one crashed shard means fewer workers, not a failed
        // search.
        let tripped = Arc::new(AtomicBool::new(false));
        let svc: EvalService<u32, u32> = EvalService::spawn_flow(
            vec!["a".into(), "b".into()],
            move |_shard| {
                let tripped = tripped.clone();
                Box::new(move |x: u32| {
                    if x == 999 && !tripped.swap(true, Ordering::SeqCst) {
                        panic!("injected shard crash");
                    }
                    ShardFlow::Reply(x * 2)
                })
            },
        );
        let payloads: Vec<u32> = (0..32).map(|i| if i == 7 { 999 } else { i }).collect();
        let out = svc.call_batch(payloads.clone()).unwrap();
        for (p, o) in payloads.iter().zip(&out) {
            assert_eq!(*o, p * 2, "requeued request must return the pure answer");
        }
        let s = svc.stats();
        assert_eq!(s.requeued, 1, "the poisoned chunk must be requeued once");
        assert_eq!(s.requeued_duplicates, 0);
        assert_eq!(s.retired_shards(), 1);
        assert_eq!(svc.live_workers(), 1);
        assert_eq!(svc.n_workers(), 2);
        // the degraded pool keeps serving
        assert_eq!(svc.call(5).unwrap(), 10);
    }

    #[test]
    fn fully_retired_pool_errors_instead_of_hanging() {
        let svc: EvalService<u32, u32> = EvalService::spawn_flow(
            vec!["solo".into()],
            |_shard| {
                Box::new(|_x: u32| ShardFlow::Retire { reason: "transport gone".into() })
            },
        );
        assert!(svc.call(1).is_err(), "dead pool must error, not panic/hang");
        // queued requests after full retirement drain with errors too
        assert!(svc.call(2).is_err());
        let res = svc.call_batch(vec![3, 4, 5]);
        assert!(res.is_err());
        let s = svc.stats();
        assert_eq!(s.retired_shards(), 1);
        assert_eq!(s.requeued, 0, "nothing left to requeue onto");
        assert_eq!(svc.live_workers(), 0);
        drop(svc); // must not hang
    }

    #[test]
    fn explicit_retire_requeues_like_a_crash() {
        // Same discipline as the panic path, via the ShardFlow::Retire arm
        // (what a remote feeder returns when its connection dies).
        let tripped = Arc::new(AtomicBool::new(false));
        let svc: EvalService<u32, u32> = EvalService::spawn_flow(
            vec!["good".into(), "flaky".into()],
            move |_shard| {
                let tripped = tripped.clone();
                Box::new(move |x: u32| {
                    if x == 42 && !tripped.swap(true, Ordering::SeqCst) {
                        return ShardFlow::Retire { reason: "connection reset".into() };
                    }
                    ShardFlow::Reply(x + 1)
                })
            },
        );
        let out = svc.call_batch((40..50).collect()).unwrap();
        assert_eq!(out, (41..51).collect::<Vec<_>>());
        let s = svc.stats();
        assert_eq!(s.requeued, 1);
        assert_eq!(s.retired_shards(), 1);
    }

    /// A one-shot gate: evaluations of the poison payload block until the
    /// test releases them — a deterministic stand-in for a wedged shard.
    struct Gate {
        state: Mutex<bool>,
        cv: Condvar,
    }

    impl Gate {
        fn new() -> Arc<Gate> {
            Arc::new(Gate { state: Mutex::new(false), cv: Condvar::new() })
        }

        fn wait(&self) {
            let mut open = self.state.lock().unwrap();
            while !*open {
                open = self.cv.wait(open).unwrap();
            }
        }

        fn open(&self) {
            *self.state.lock().unwrap() = true;
            self.cv.notify_all();
        }
    }

    /// Wait for the in-flight registry to drain so the conservation
    /// invariants can be asserted at a quiescent point.
    fn drain(svc: &EvalService<u32, u32>) {
        while svc.in_flight() != 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    fn assert_balanced(s: &ServiceStats) {
        assert_eq!(
            s.completed,
            s.dispatched - s.hedged_wasted - s.requeued_duplicates,
            "copy conservation violated: {s:?}"
        );
    }

    #[test]
    fn hedge_wins_against_wedged_shard_and_duplicate_is_discarded() {
        // The first shard to evaluate the poison payload wedges on the gate;
        // the other shard drains the queue, goes idle, hedges the straggler
        // and wins.  call_batch completes without waiting on the wedge; the
        // wedged copy's late reply is discarded by chunk id once released.
        let gate = Gate::new();
        let tripped = Arc::new(AtomicBool::new(false));
        let flow_gate = gate.clone();
        let svc: EvalService<u32, u32> = EvalService::spawn_flow_with(
            vec!["a".into(), "b".into()],
            move |_shard| {
                let gate = flow_gate.clone();
                let tripped = tripped.clone();
                Box::new(move |x: u32| {
                    if x == 777 && !tripped.swap(true, Ordering::SeqCst) {
                        gate.wait();
                    }
                    ShardFlow::Reply(x * 2)
                })
            },
            HedgePolicy { factor: 1.0, floor: Duration::from_millis(5) },
        );
        let payloads: Vec<u32> = (0..16).map(|i| if i == 3 { 777 } else { i }).collect();
        let out = svc.call_batch(payloads.clone()).unwrap();
        for (p, o) in payloads.iter().zip(&out) {
            assert_eq!(*o, p * 2);
        }
        let s = svc.stats();
        assert!(s.hedged_dispatched >= 1, "straggler must have been hedged: {s:?}");
        assert!(s.hedged_won >= 1, "the speculative copy must have won: {s:?}");
        assert_eq!(s.completed, 16);
        assert_eq!(s.requeued, 0);
        // Release the wedged copy; its reply must be discarded, not
        // double-delivered or double-counted.
        gate.open();
        drain(&svc);
        let s = svc.stats();
        assert!(s.hedged_wasted >= 1, "the losing copy must be discarded: {s:?}");
        assert_eq!(s.completed, 16, "idempotent delivery: still one reply per chunk");
        assert_balanced(&s);
    }

    #[test]
    fn retiring_shard_does_not_requeue_a_delivered_chunk() {
        // Regression for the double-count bug: a shard holds a chunk until
        // another copy (the hedge) has delivered it, then retires.  The
        // requeue must be suppressed — the chunk already reached the caller.
        let gate = Gate::new();
        let tripped = Arc::new(AtomicBool::new(false));
        let flow_gate = gate.clone();
        let svc: EvalService<u32, u32> = EvalService::spawn_flow_with(
            vec!["dying".into(), "healthy".into()],
            move |_shard| {
                let gate = flow_gate.clone();
                let tripped = tripped.clone();
                Box::new(move |x: u32| {
                    if x == 555 && !tripped.swap(true, Ordering::SeqCst) {
                        gate.wait();
                        return ShardFlow::Retire { reason: "injected".into() };
                    }
                    ShardFlow::Reply(x * 2)
                })
            },
            HedgePolicy { factor: 1.0, floor: Duration::from_millis(5) },
        );
        let payloads: Vec<u32> = (0..12).map(|i| if i == 2 { 555 } else { i }).collect();
        let out = svc.call_batch(payloads.clone()).unwrap();
        for (p, o) in payloads.iter().zip(&out) {
            assert_eq!(*o, p * 2);
        }
        // The batch completed via the hedge while the first copy is still
        // gated — now let that shard retire with its stale in-flight chunk.
        gate.open();
        drain(&svc);
        while svc.live_workers() == 2 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let s = svc.stats();
        assert_eq!(s.retired_shards(), 1);
        assert_eq!(
            s.requeued, 0,
            "a delivered chunk must never be requeued: {s:?}"
        );
        assert!(s.requeued_duplicates >= 1, "the suppression must be counted: {s:?}");
        assert_eq!(s.completed, 12, "no double-delivery, no drop");
        assert_balanced(&s);
    }

    #[test]
    fn hedging_disabled_never_duplicates() {
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(4, |_s| {
            |x: u32| {
                std::thread::sleep(Duration::from_millis(2));
                x + 1
            }
        });
        let out = svc.call_batch((0..32).collect()).unwrap();
        assert_eq!(out, (1..33).collect::<Vec<_>>());
        let s = svc.stats();
        assert_eq!(s.hedged_dispatched, 0);
        assert_eq!(s.dispatched, s.completed);
        assert_balanced(&s);
    }

    #[test]
    fn hedging_with_no_straggler_changes_nothing() {
        // Uniformly fast evals under an enabled policy: the floor keeps the
        // trigger quiet, results and counters match the unhedged pool.
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded_with(
            4,
            |_s| |x: u32| x.wrapping_mul(31),
            HedgePolicy { factor: 50.0, floor: Duration::from_secs(3600) },
        );
        let out = svc.call_batch((0..64).collect()).unwrap();
        assert_eq!(out, (0..64).map(|x| x * 31).collect::<Vec<_>>());
        let s = svc.stats();
        assert_eq!(s.hedged_dispatched, 0);
        assert_eq!(s.completed, 64);
        assert_balanced(&s);
    }
}

//! EvalService — a sharded evaluation pool in the style of a serving
//! router's batcher.  Callers (CLI, examples, the search loop) submit
//! requests through a shared channel and receive results through
//! per-request reply channels.
//!
//! Sharding model:
//!  * N workers share a single FIFO request channel (work-sharing: whichever
//!    shard is idle takes the next request, so a slow candidate never blocks
//!    the queue behind one thread);
//!  * each worker owns its own evaluation state, built *on the worker
//!    thread* by the shard builder — per-shard state can be anything from a
//!    full non-`Send` runtime stack down to a couple of `Arc` handles onto
//!    process-wide shared state, or (via [`EvalService::spawn_flow`]) a TCP
//!    connection to a remote shard server speaking the
//!    [`crate::runtime::wire`] protocol;
//!  * every request carries its own reply channel, and `call_batch` collects
//!    replies in submission order — results are therefore deterministically
//!    ordered and bit-identical regardless of worker count, **provided** the
//!    evaluation closure is a pure function of the payload (seed any
//!    randomness per-candidate from the payload, never from shard state).
//!
//! Failure model: a shard whose closure panics, or that asks to retire
//! ([`ShardFlow::Retire`] — remote transports do this when a connection
//! dies beyond retry), leaves the pool **without poisoning it**.  Its
//! in-flight request is requeued onto the shared FIFO (evaluations are pure
//! functions of the payload, so a re-run on another shard returns the
//! identical answer) and the pool degrades to fewer workers.  Only when the
//! *last* shard retires do pending requests fail — surfaced as `Err` from
//! [`EvalService::call`] / [`EvalService::call_batch`], never a panic.
//!
//! Generic over request/response so tests can exercise the queueing logic
//! without PJRT.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-shard accounting: how many requests the shard served and how long it
/// spent serving them (busy time / wall time = utilization).
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Human-readable shard label (`local#N`, or the remote address).
    pub label: String,
    /// Requests this shard served.
    pub completed: u64,
    /// Wall-clock this shard spent inside its evaluation closure.
    pub busy: Duration,
    /// True once the shard has left the pool (panic or [`ShardFlow::Retire`]).
    pub retired: bool,
}

/// Queue/latency accounting, aggregated across shards.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    /// Requests submitted to the shared queue.
    pub submitted: u64,
    /// Requests served (across all shards).
    pub completed: u64,
    /// Requests put back on the queue after their shard retired mid-flight.
    pub requeued: u64,
    /// Summed queue wait (enqueue → a shard picked the request up).
    pub total_queue_wait: Duration,
    /// Summed service time (inside the evaluation closures).
    pub total_service_time: Duration,
    /// Per-shard breakdown, shard-index order.
    pub per_shard: Vec<ShardStats>,
}

impl ServiceStats {
    /// Mean queue wait per completed request.
    pub fn mean_wait(&self) -> Duration {
        if self.completed == 0 {
            Duration::ZERO
        } else {
            self.total_queue_wait / self.completed as u32
        }
    }

    /// Mean service time per completed request.
    pub fn mean_service(&self) -> Duration {
        if self.completed == 0 {
            Duration::ZERO
        } else {
            self.total_service_time / self.completed as u32
        }
    }

    /// Fraction of `wall` each shard spent serving requests.
    pub fn shard_utilization(&self, wall: Duration) -> Vec<f64> {
        let w = wall.as_secs_f64().max(1e-12);
        self.per_shard
            .iter()
            .map(|s| s.busy.as_secs_f64() / w)
            .collect()
    }

    /// Shards that have retired (panicked closures / dead transports).
    pub fn retired_shards(&self) -> usize {
        self.per_shard.iter().filter(|s| s.retired).count()
    }
}

/// What a shard's evaluation closure did with one request: answer it, or
/// take the shard out of the pool (the request is requeued for the
/// surviving shards — pure evaluations make the re-run identical).
pub enum ShardFlow<A> {
    /// The request was served; send this answer back.
    Reply(A),
    /// The shard is no longer usable (e.g. its remote connection died
    /// beyond retry).  The in-flight request goes back on the shared FIFO
    /// and the shard leaves the pool.
    Retire { reason: String },
}

struct Request<Q, A> {
    payload: Q,
    enqueued: Instant,
    reply: mpsc::Sender<A>,
}

/// Sender half shared with the workers so a retiring shard can requeue its
/// in-flight request.  `Drop` clears it (alongside the caller-side sender)
/// so the channel actually closes at shutdown.
type SharedTx<Q, A> = Arc<Mutex<Option<mpsc::Sender<Request<Q, A>>>>>;

/// Handle to the worker pool.  Dropping it shuts every worker down (after
/// the queue drains).
pub struct EvalService<Q: Send + 'static, A: Send + 'static> {
    tx: mpsc::Sender<Request<Q, A>>,
    shared_tx: SharedTx<Q, A>,
    stats: Arc<Mutex<ServiceStats>>,
    alive: Arc<AtomicUsize>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl<Q: Send + 'static, A: Send + 'static> EvalService<Q, A> {
    /// Spawn a single worker.  `builder` runs *on the worker thread* and
    /// constructs the evaluation closure there (back-compat single-shard
    /// API; see [`EvalService::spawn_sharded`]).
    pub fn spawn<B, F>(builder: B) -> Self
    where
        Q: Clone,
        B: FnOnce() -> F + Send + 'static,
        F: FnMut(Q) -> A + 'static,
    {
        let cell = Mutex::new(Some(builder));
        Self::spawn_sharded(1, move |_shard| {
            let b = cell
                .lock()
                .unwrap()
                .take()
                .expect("single-shard builder invoked twice");
            b()
        })
    }

    /// Spawn `workers` shards.  `builder(shard_index)` runs once *on each
    /// worker thread* and constructs that shard's evaluation closure there
    /// (confining non-`Send` runtime state to its shard).
    pub fn spawn_sharded<B, F>(workers: usize, builder: B) -> Self
    where
        Q: Clone,
        B: Fn(usize) -> F + Send + Sync + 'static,
        F: FnMut(Q) -> A + 'static,
    {
        let n = workers.max(1);
        let labels = (0..n).map(|i| format!("local#{i}")).collect();
        Self::spawn_flow(labels, move |shard| {
            let mut eval = builder(shard);
            Box::new(move |q: Q| ShardFlow::Reply(eval(q)))
        })
    }

    /// Spawn one shard per label.  The most general constructor: each
    /// shard's closure decides per request whether to [`ShardFlow::Reply`]
    /// or to [`ShardFlow::Retire`] from the pool, which lets heterogeneous
    /// shards (local device closures and remote TCP feeders) share one
    /// FIFO.  A closure that panics is treated as retiring.
    ///
    /// `Q: Clone` because the worker snapshots each payload before
    /// evaluating it, so a retiring shard can requeue the request intact.
    pub fn spawn_flow<B>(labels: Vec<String>, builder: B) -> Self
    where
        Q: Clone,
        B: Fn(usize) -> Box<dyn FnMut(Q) -> ShardFlow<A>> + Send + Sync + 'static,
    {
        let n = labels.len().max(1);
        let labels: Vec<String> = if labels.is_empty() {
            vec!["local#0".to_string()]
        } else {
            labels
        };
        let (tx, rx) = mpsc::channel::<Request<Q, A>>();
        let rx = Arc::new(Mutex::new(rx));
        let shared_tx: SharedTx<Q, A> = Arc::new(Mutex::new(Some(tx.clone())));
        let stats = Arc::new(Mutex::new(ServiceStats {
            per_shard: labels
                .iter()
                .map(|l| ShardStats { label: l.clone(), ..ShardStats::default() })
                .collect(),
            ..ServiceStats::default()
        }));
        let alive = Arc::new(AtomicUsize::new(n));
        let builder = Arc::new(builder);
        let mut handles = Vec::with_capacity(n);
        for shard in 0..n {
            let rx = rx.clone();
            let stats = stats.clone();
            let builder = builder.clone();
            let shared_tx = shared_tx.clone();
            let alive = alive.clone();
            handles.push(std::thread::spawn(move || {
                let mut eval = (*builder)(shard);
                loop {
                    // Holding the lock while blocked in recv() is the queue
                    // discipline: exactly one idle shard waits on the channel,
                    // the rest wait on the mutex.  The lock is released before
                    // evaluation so other shards can pick up the next request.
                    let req = {
                        let guard = match rx.lock() {
                            Ok(g) => g,
                            Err(_) => break,
                        };
                        guard.recv()
                    };
                    let Ok(req) = req else { break };
                    let started = Instant::now();
                    let wait = started - req.enqueued;
                    // Snapshot the payload so a retiring shard can requeue
                    // the request intact (evaluations are pure, so a re-run
                    // on another shard gives the identical answer).
                    let backup = req.payload.clone();
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || eval(req.payload),
                    ));
                    let service = started.elapsed();
                    match outcome {
                        Ok(ShardFlow::Reply(answer)) => {
                            {
                                let mut s = stats.lock().unwrap();
                                s.completed += 1;
                                s.total_queue_wait += wait;
                                s.total_service_time += service;
                                s.per_shard[shard].completed += 1;
                                s.per_shard[shard].busy += service;
                            }
                            let _ = req.reply.send(answer);
                        }
                        other => {
                            // Retire path: explicit ShardFlow::Retire or a
                            // panicked closure — both take the shard out of
                            // the pool without poisoning the queue.
                            let reason = match other {
                                Ok(ShardFlow::Retire { reason }) => reason,
                                Err(panic) => {
                                    let msg = panic
                                        .downcast_ref::<String>()
                                        .map(|s| s.as_str())
                                        .or_else(|| {
                                            panic.downcast_ref::<&str>().copied()
                                        })
                                        .unwrap_or("panic");
                                    format!("evaluation panicked: {msg}")
                                }
                                Ok(ShardFlow::Reply(_)) => unreachable!(),
                            };
                            let remaining = alive.fetch_sub(1, Ordering::SeqCst) - 1;
                            let label = {
                                let mut s = stats.lock().unwrap();
                                s.per_shard[shard].retired = true;
                                s.per_shard[shard].busy += service;
                                if remaining > 0 {
                                    s.requeued += 1;
                                }
                                s.per_shard[shard].label.clone()
                            };
                            eprintln!(
                                "[pool] shard {label} retired ({reason}); \
                                 {remaining} shard(s) remain"
                            );
                            if remaining > 0 {
                                // Put the in-flight request back on the FIFO
                                // (fresh enqueue time; the original reply
                                // channel rides along, so the caller never
                                // notices beyond added latency).
                                let requeue = Request {
                                    payload: backup,
                                    enqueued: Instant::now(),
                                    reply: req.reply,
                                };
                                if let Some(tx) = shared_tx.lock().unwrap().as_ref() {
                                    let _ = tx.send(requeue);
                                }
                                // (If the service is mid-shutdown the cell is
                                // empty and the request drops: the caller gets
                                // a recv error, same as any shutdown.)
                            } else {
                                // Last shard out: drop the request (its reply
                                // sender drops with it, so the caller gets an
                                // immediate error instead of a hang) and drain
                                // the queue until shutdown closes the channel,
                                // failing queued requests the same way.
                                drop(req.reply);
                                if let Ok(guard) = rx.lock() {
                                    while guard.recv().is_ok() {}
                                }
                            }
                            break;
                        }
                    }
                }
            }));
        }
        EvalService { tx, shared_tx, stats, alive, workers: handles }
    }

    /// Number of worker shards spawned (including retired ones).
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Shards still serving (spawned minus retired).
    pub fn live_workers(&self) -> usize {
        self.alive.load(Ordering::SeqCst)
    }

    /// Submit a request; returns a receiver for the answer.  If every shard
    /// has retired, the receiver's `recv()` fails instead of hanging.
    pub fn submit(&self, payload: Q) -> mpsc::Receiver<A> {
        let (rtx, rrx) = mpsc::channel();
        self.stats.lock().unwrap().submitted += 1;
        let _ = self.tx.send(Request { payload, enqueued: Instant::now(), reply: rtx });
        rrx
    }

    /// Submit and block for the answer.  Errors (instead of panicking) when
    /// the request was dropped because every shard retired.
    pub fn call(&self, payload: Q) -> crate::Result<A> {
        self.submit(payload).recv().map_err(|_| self.dead_pool_error())
    }

    /// Submit a whole batch, then collect replies in submission order —
    /// the deterministic-reassembly primitive the search loop relies on.
    /// A single retired shard is invisible here (its in-flight chunk is
    /// requeued); only a fully-retired pool surfaces as `Err`.
    pub fn call_batch(&self, payloads: Vec<Q>) -> crate::Result<Vec<A>> {
        let rxs: Vec<_> = payloads.into_iter().map(|p| self.submit(p)).collect();
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|_| self.dead_pool_error()))
            .collect()
    }

    fn dead_pool_error(&self) -> eyre::Report {
        let retired = self.stats.lock().unwrap().retired_shards();
        eyre::anyhow!(
            "evaluation pool request dropped: {retired} of {} shard(s) retired, \
             no live shard remains to serve it",
            self.n_workers()
        )
    }

    /// Snapshot of the queue/latency counters.
    pub fn stats(&self) -> ServiceStats {
        self.stats.lock().unwrap().clone()
    }
}

impl<Q: Send + 'static, A: Send + 'static> Drop for EvalService<Q, A> {
    fn drop(&mut self) {
        // Closing the channel stops the worker loops once the queue drains.
        // Both sender halves must go: the caller-side `tx` and the workers'
        // shared requeue sender.
        self.shared_tx.lock().unwrap().take();
        let (dead_tx, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, dead_tx));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn roundtrip_single() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x * 2);
        assert_eq!(svc.call(21).unwrap(), 42);
        let s = svc.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.requeued, 0);
        assert_eq!(s.per_shard.len(), 1);
        assert_eq!(s.per_shard[0].label, "local#0");
        assert!(!s.per_shard[0].retired);
    }

    #[test]
    fn batch_preserves_order() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x + 1);
        let out = svc.call_batch((0..100).collect()).unwrap();
        assert_eq!(out, (1..101).collect::<Vec<_>>());
    }

    #[test]
    fn worker_state_is_threadlocal() {
        // builder runs on the worker: stateful counter works without Sync
        let svc: EvalService<(), u64> = EvalService::spawn(|| {
            let mut count = 0u64;
            move |_| {
                count += 1;
                count
            }
        });
        assert_eq!(svc.call(()).unwrap(), 1);
        assert_eq!(svc.call(()).unwrap(), 2);
    }

    #[test]
    fn shutdown_joins_worker() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x);
        svc.call(1).unwrap();
        drop(svc); // must not hang
    }

    #[test]
    fn sharded_batch_preserves_order_under_contention() {
        // Payload-dependent delays force out-of-order completion across
        // shards; reply-channel reassembly must still return submission order.
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(4, |_shard| {
            |x: u32| {
                std::thread::sleep(Duration::from_micros(((x * 7919) % 977) as u64));
                x + 1
            }
        });
        let out = svc.call_batch((0..200).collect()).unwrap();
        assert_eq!(out, (1..201).collect::<Vec<_>>());
    }

    #[test]
    fn sharded_results_identical_to_single() {
        let eval = |x: u32| x.wrapping_mul(2654435761) ^ 0x9E37;
        let one: EvalService<u32, u32> = EvalService::spawn_sharded(1, move |_| eval);
        let four: EvalService<u32, u32> = EvalService::spawn_sharded(4, move |_| eval);
        let inputs: Vec<u32> = (0..64).collect();
        assert_eq!(
            one.call_batch(inputs.clone()).unwrap(),
            four.call_batch(inputs).unwrap()
        );
    }

    #[test]
    fn sharded_stats_aggregate() {
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(3, |_s| |x: u32| x);
        let _ = svc.call_batch((0..30).collect()).unwrap();
        let s = svc.stats();
        assert_eq!(s.submitted, 30);
        assert_eq!(s.completed, 30);
        assert_eq!(s.per_shard.len(), 3);
        assert_eq!(s.per_shard.iter().map(|p| p.completed).sum::<u64>(), 30);
        assert_eq!(s.shard_utilization(Duration::from_secs(1)).len(), 3);
        assert_eq!(s.retired_shards(), 0);
    }

    #[test]
    fn sharded_work_actually_distributes() {
        // With blocking work and more requests than shards, no shard can
        // serve everything: at least two shards must complete requests.
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(4, |_s| {
            |x: u32| {
                std::thread::sleep(Duration::from_millis(5));
                x
            }
        });
        let _ = svc.call_batch((0..16).collect()).unwrap();
        let s = svc.stats();
        let active = s.per_shard.iter().filter(|p| p.completed > 0).count();
        assert!(active >= 2, "expected >=2 active shards, got {active}");
    }

    #[test]
    fn shard_builder_sees_its_index() {
        let svc: EvalService<(), usize> =
            EvalService::spawn_sharded(1, |shard| move |_| shard);
        assert_eq!(svc.call(()).unwrap(), 0);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(0, |_s| |x: u32| x);
        assert_eq!(svc.n_workers(), 1);
        assert_eq!(svc.call(7).unwrap(), 7);
    }

    #[test]
    fn crashed_shard_requeues_and_pool_degrades() {
        // Whichever shard picks up the poison payload first panics (exactly
        // once, via the shared trip flag), retires and requeues the request;
        // the surviving shard then serves it.  The batch result is complete
        // and correct — one crashed shard means fewer workers, not a failed
        // search.
        let tripped = Arc::new(AtomicBool::new(false));
        let svc: EvalService<u32, u32> = EvalService::spawn_flow(
            vec!["a".into(), "b".into()],
            move |_shard| {
                let tripped = tripped.clone();
                Box::new(move |x: u32| {
                    if x == 999 && !tripped.swap(true, Ordering::SeqCst) {
                        panic!("injected shard crash");
                    }
                    ShardFlow::Reply(x * 2)
                })
            },
        );
        let payloads: Vec<u32> = (0..32).map(|i| if i == 7 { 999 } else { i }).collect();
        let out = svc.call_batch(payloads.clone()).unwrap();
        for (p, o) in payloads.iter().zip(&out) {
            assert_eq!(*o, p * 2, "requeued request must return the pure answer");
        }
        let s = svc.stats();
        assert_eq!(s.requeued, 1, "the poisoned chunk must be requeued once");
        assert_eq!(s.retired_shards(), 1);
        assert_eq!(svc.live_workers(), 1);
        assert_eq!(svc.n_workers(), 2);
        // the degraded pool keeps serving
        assert_eq!(svc.call(5).unwrap(), 10);
    }

    #[test]
    fn fully_retired_pool_errors_instead_of_hanging() {
        let svc: EvalService<u32, u32> = EvalService::spawn_flow(
            vec!["solo".into()],
            |_shard| {
                Box::new(|_x: u32| ShardFlow::Retire { reason: "transport gone".into() })
            },
        );
        assert!(svc.call(1).is_err(), "dead pool must error, not panic/hang");
        // queued requests after full retirement drain with errors too
        assert!(svc.call(2).is_err());
        let res = svc.call_batch(vec![3, 4, 5]);
        assert!(res.is_err());
        let s = svc.stats();
        assert_eq!(s.retired_shards(), 1);
        assert_eq!(s.requeued, 0, "nothing left to requeue onto");
        assert_eq!(svc.live_workers(), 0);
        drop(svc); // must not hang
    }

    #[test]
    fn explicit_retire_requeues_like_a_crash() {
        // Same discipline as the panic path, via the ShardFlow::Retire arm
        // (what a remote feeder returns when its connection dies).
        let tripped = Arc::new(AtomicBool::new(false));
        let svc: EvalService<u32, u32> = EvalService::spawn_flow(
            vec!["good".into(), "flaky".into()],
            move |_shard| {
                let tripped = tripped.clone();
                Box::new(move |x: u32| {
                    if x == 42 && !tripped.swap(true, Ordering::SeqCst) {
                        return ShardFlow::Retire { reason: "connection reset".into() };
                    }
                    ShardFlow::Reply(x + 1)
                })
            },
        );
        let out = svc.call_batch((40..50).collect()).unwrap();
        assert_eq!(out, (41..51).collect::<Vec<_>>());
        let s = svc.stats();
        assert_eq!(s.requeued, 1);
        assert_eq!(s.retired_shards(), 1);
    }
}

//! EvalService — a single-worker request queue in the style of a serving
//! router's batcher.  PJRT objects are not `Send`, so the whole runtime stack
//! lives on one dedicated worker thread; callers (CLI, examples, the search
//! loop when run concurrently) submit requests through a channel and receive
//! results through per-request reply channels.
//!
//! Generic over request/response so tests can exercise the queueing logic
//! without PJRT.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Queue/latency accounting.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub submitted: u64,
    pub completed: u64,
    pub total_queue_wait: Duration,
    pub total_service_time: Duration,
}

impl ServiceStats {
    pub fn mean_wait(&self) -> Duration {
        if self.completed == 0 {
            Duration::ZERO
        } else {
            self.total_queue_wait / self.completed as u32
        }
    }

    pub fn mean_service(&self) -> Duration {
        if self.completed == 0 {
            Duration::ZERO
        } else {
            self.total_service_time / self.completed as u32
        }
    }
}

struct Request<Q, A> {
    payload: Q,
    enqueued: Instant,
    reply: mpsc::Sender<A>,
}

/// Handle to the worker.  Dropping it shuts the worker down.
pub struct EvalService<Q: Send + 'static, A: Send + 'static> {
    tx: mpsc::Sender<Request<Q, A>>,
    stats: Arc<Mutex<ServiceStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl<Q: Send + 'static, A: Send + 'static> EvalService<Q, A> {
    /// Spawn a worker.  `builder` runs *on the worker thread* and constructs
    /// the evaluation closure there (this is how non-Send PJRT state is
    /// confined to the worker).
    pub fn spawn<B, F>(builder: B) -> Self
    where
        B: FnOnce() -> F + Send + 'static,
        F: FnMut(Q) -> A,
    {
        let (tx, rx) = mpsc::channel::<Request<Q, A>>();
        let stats = Arc::new(Mutex::new(ServiceStats::default()));
        let stats2 = stats.clone();
        let worker = std::thread::spawn(move || {
            let mut eval = builder();
            while let Ok(req) = rx.recv() {
                let started = Instant::now();
                let wait = started - req.enqueued;
                let answer = eval(req.payload);
                let service = started.elapsed();
                {
                    let mut s = stats2.lock().unwrap();
                    s.completed += 1;
                    s.total_queue_wait += wait;
                    s.total_service_time += service;
                }
                let _ = req.reply.send(answer);
            }
        });
        EvalService { tx, stats, worker: Some(worker) }
    }

    /// Submit a request; returns a receiver for the answer.
    pub fn submit(&self, payload: Q) -> mpsc::Receiver<A> {
        let (rtx, rrx) = mpsc::channel();
        self.stats.lock().unwrap().submitted += 1;
        let _ = self.tx.send(Request { payload, enqueued: Instant::now(), reply: rtx });
        rrx
    }

    /// Submit and block for the answer.
    pub fn call(&self, payload: Q) -> A {
        self.submit(payload).recv().expect("worker died")
    }

    /// Submit a whole batch, then collect in order (pipeline-friendly).
    pub fn call_batch(&self, payloads: Vec<Q>) -> Vec<A> {
        let rxs: Vec<_> = payloads.into_iter().map(|p| self.submit(p)).collect();
        rxs.into_iter().map(|rx| rx.recv().expect("worker died")).collect()
    }

    pub fn stats(&self) -> ServiceStats {
        self.stats.lock().unwrap().clone()
    }
}

impl<Q: Send + 'static, A: Send + 'static> Drop for EvalService<Q, A> {
    fn drop(&mut self) {
        // Closing the channel stops the worker loop.
        let (dead_tx, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, dead_tx));
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_single() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x * 2);
        assert_eq!(svc.call(21), 42);
        let s = svc.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.completed, 1);
    }

    #[test]
    fn batch_preserves_order() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x + 1);
        let out = svc.call_batch((0..100).collect());
        assert_eq!(out, (1..101).collect::<Vec<_>>());
    }

    #[test]
    fn worker_state_is_threadlocal() {
        // builder runs on the worker: stateful counter works without Sync
        let svc: EvalService<(), u64> = EvalService::spawn(|| {
            let mut count = 0u64;
            move |_| {
                count += 1;
                count
            }
        });
        assert_eq!(svc.call(()), 1);
        assert_eq!(svc.call(()), 2);
    }

    #[test]
    fn shutdown_joins_worker() {
        let svc: EvalService<u32, u32> = EvalService::spawn(|| |x: u32| x);
        svc.call(1);
        drop(svc); // must not hang
    }
}

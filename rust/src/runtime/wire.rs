//! The evaluation pool's wire format: the serialized form of one pool
//! request (a chunk of candidate configurations) and its reply (per-
//! candidate scores, or an error string).
//!
//! Framing is length-prefixed and self-describing:
//!
//! ```text
//!   offset  size  field
//!   0       4     magic  b"AMQW"
//!   4       1     version (WIRE_VERSION)
//!   5       4     payload length, u32 little-endian
//!   9       len   payload: compact JSON (data::json::Value::render)
//! ```
//!
//! The payload reuses the in-tree [`crate::data::json`] value type — the
//! offline build has no serde — and is deterministic: `Value` objects are
//! `BTreeMap`s, so a given message always encodes to the same bytes (the
//! cross-version layout test in `rust/tests/remote.rs` pins them).
//!
//! Exactness rules:
//!  * genes are `u16` integers (exact in JSON);
//!  * chunk ids are sequential `u64` counters, carried as JSON integers
//!    (exact below 2^53 — ids are per-connection counters and never get
//!    anywhere near that);
//!  * **scores are carried as `f32::to_bits()` u32 integers**, never as
//!    decimal floats, so a score crosses the wire bit-exactly and remote
//!    archives stay byte-identical to in-process ones.
//!
//! Decoding never panics: bad magic, unsupported version, truncated input,
//! oversized frames and malformed payloads all surface as errors.

use crate::data::json::Value;
use crate::Result;
use std::collections::BTreeMap;
use std::io::{Read, Write};

/// Frame magic — `b"AMQW"`.
pub const WIRE_MAGIC: [u8; 4] = *b"AMQW";

/// Wire protocol version.  Bump on any layout change; peers reject
/// mismatches instead of misparsing.
pub const WIRE_VERSION: u8 = 1;

/// Frame header size: magic + version + u32 payload length.
pub const HEADER_LEN: usize = 9;

/// Hard cap on payload size.  A chunk is at most `score_batch` configs of
/// `n_layers` genes — a few KB in practice; 32 MiB is far above any real
/// frame and small enough that a corrupted length prefix fails fast instead
/// of attempting a multi-gigabyte allocation.
pub const MAX_FRAME_LEN: usize = 32 * 1024 * 1024;

/// One message of the shard protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    /// Server greeting, sent once per connection before any chunk.
    /// `n_layers` is the genome length the shard can score (0 = any — the
    /// synthetic CI shards score arbitrary-length configs).
    Hello { n_layers: u64 },
    /// A chunk of candidate configurations to score (the pool's request
    /// unit: one chunk = one scorer dispatch on the serving shard).
    Chunk { id: u64, genes: Vec<Vec<u16>> },
    /// Per-candidate scores for chunk `id`, input order, bit-exact.
    Scores { id: u64, scores: Vec<f32> },
    /// Deterministic evaluation failure for chunk `id` (the remote's error
    /// text; *not* a transport failure — the connection stays usable).
    Error { id: u64, message: String },
    /// Client request for the server's lifetime counters.  Answered with a
    /// [`WireMsg::Stats`] echoing `id`.  Servers predating this op reject
    /// the frame as an unknown op (connection-fatal on the server side), so
    /// clients only probe on *dedicated* connections — never mid-search on
    /// a scoring connection.
    StatsReq { id: u64 },
    /// Server-side lifetime counters (across every connection the server
    /// has accepted): chunks completed, busy wall-clock in µs (time inside
    /// the evaluation closure), and connections accepted.  These are the
    /// server's own measurements — unlike the client-side `ShardStats`
    /// estimates, they exclude transport and queueing time.
    Stats { id: u64, completed: u64, busy_us: u64, conns: u64 },
    /// One serving request: score a single candidate configuration through
    /// the continuous batcher (`runtime/serve.rs`).  Empty `genes` means
    /// "score the server's configured default" — the searched archive entry
    /// a `repro serve` process was launched with.  Answered with a
    /// [`WireMsg::Score`] (or [`WireMsg::Error`]) echoing `id`.  Additive in
    /// WIRE_VERSION 1: servers predating it reject the op, never misparse.
    ScoreReq { id: u64, genes: Vec<u16> },
    /// The score for request `id`, bit-exact (`f32::to_bits()` transport,
    /// same rule as [`WireMsg::Scores`]).
    Score { id: u64, score: f32 },
    /// Client request for the serve scheduler's lifetime counters.
    /// Answered with a [`WireMsg::ServeStats`] echoing `id`.  Additive in
    /// WIRE_VERSION 1, same compatibility story as [`WireMsg::StatsReq`].
    ServeStatsReq { id: u64 },
    /// The continuous batcher's lifetime counters.  `dispatches` splits
    /// into `full` (lane slab filled before the deadline) + `deadline`
    /// (partial slab flushed at `--max-wait-us`) + shutdown drains (the
    /// remainder).  `batched / (dispatches * lanes)` is the lane fill
    /// fraction; `wait_us / requests` is the mean admission-queue wait —
    /// reported separately so under-filled (latency-driven) dispatches are
    /// distinguishable from cache-miss stalls.  `depth_sum` accumulates the
    /// queue depth sampled at each dispatch (mean = `depth_sum /
    /// dispatches`), `depth_max` is its high-water mark.
    ServeStats {
        id: u64,
        requests: u64,
        rejected: u64,
        dispatches: u64,
        full: u64,
        deadline: u64,
        lanes: u64,
        batched: u64,
        wait_us: u64,
        depth_sum: u64,
        depth_max: u64,
    },
}

fn obj(entries: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Value::Obj(m)
}

impl WireMsg {
    /// The JSON payload of this message (no framing).
    pub fn to_value(&self) -> Value {
        match self {
            WireMsg::Hello { n_layers } => obj(vec![
                ("n_layers", Value::Num(*n_layers as f64)),
                ("op", Value::Str("hello".into())),
            ]),
            WireMsg::Chunk { id, genes } => obj(vec![
                (
                    "genes",
                    Value::Arr(
                        genes
                            .iter()
                            .map(|c| {
                                Value::Arr(
                                    c.iter().map(|&g| Value::Num(g as f64)).collect(),
                                )
                            })
                            .collect(),
                    ),
                ),
                ("id", Value::Num(*id as f64)),
                ("op", Value::Str("chunk".into())),
            ]),
            WireMsg::Scores { id, scores } => obj(vec![
                ("id", Value::Num(*id as f64)),
                ("op", Value::Str("scores".into())),
                (
                    "scores",
                    Value::Arr(
                        scores
                            .iter()
                            .map(|&s| Value::Num(s.to_bits() as f64))
                            .collect(),
                    ),
                ),
            ]),
            WireMsg::Error { id, message } => obj(vec![
                ("id", Value::Num(*id as f64)),
                ("message", Value::Str(message.clone())),
                ("op", Value::Str("error".into())),
            ]),
            WireMsg::StatsReq { id } => obj(vec![
                ("id", Value::Num(*id as f64)),
                ("op", Value::Str("stats_req".into())),
            ]),
            WireMsg::Stats { id, completed, busy_us, conns } => obj(vec![
                ("busy_us", Value::Num(*busy_us as f64)),
                ("completed", Value::Num(*completed as f64)),
                ("conns", Value::Num(*conns as f64)),
                ("id", Value::Num(*id as f64)),
                ("op", Value::Str("stats".into())),
            ]),
            WireMsg::ScoreReq { id, genes } => obj(vec![
                (
                    "genes",
                    Value::Arr(genes.iter().map(|&g| Value::Num(g as f64)).collect()),
                ),
                ("id", Value::Num(*id as f64)),
                ("op", Value::Str("score_req".into())),
            ]),
            WireMsg::Score { id, score } => obj(vec![
                ("id", Value::Num(*id as f64)),
                ("op", Value::Str("score".into())),
                ("score", Value::Num(score.to_bits() as f64)),
            ]),
            WireMsg::ServeStatsReq { id } => obj(vec![
                ("id", Value::Num(*id as f64)),
                ("op", Value::Str("serve_stats_req".into())),
            ]),
            WireMsg::ServeStats {
                id,
                requests,
                rejected,
                dispatches,
                full,
                deadline,
                lanes,
                batched,
                wait_us,
                depth_sum,
                depth_max,
            } => obj(vec![
                ("batched", Value::Num(*batched as f64)),
                ("deadline", Value::Num(*deadline as f64)),
                ("depth_max", Value::Num(*depth_max as f64)),
                ("depth_sum", Value::Num(*depth_sum as f64)),
                ("dispatches", Value::Num(*dispatches as f64)),
                ("full", Value::Num(*full as f64)),
                ("id", Value::Num(*id as f64)),
                ("lanes", Value::Num(*lanes as f64)),
                ("op", Value::Str("serve_stats".into())),
                ("rejected", Value::Num(*rejected as f64)),
                ("requests", Value::Num(*requests as f64)),
                ("wait_us", Value::Num(*wait_us as f64)),
            ]),
        }
    }

    /// Parse a message from its JSON payload.
    pub fn from_value(v: &Value) -> Result<WireMsg> {
        let op = v.get("op")?.as_str()?;
        match op {
            "hello" => Ok(WireMsg::Hello { n_layers: v.get("n_layers")?.as_u64()? }),
            "chunk" => {
                let id = v.get("id")?.as_u64()?;
                let mut genes = Vec::new();
                for row in v.get("genes")?.as_arr()? {
                    let mut cfg = Vec::new();
                    for g in row.as_arr()? {
                        let g = g.as_u64()?;
                        eyre::ensure!(g <= u16::MAX as u64, "gene {g} exceeds u16");
                        cfg.push(g as u16);
                    }
                    genes.push(cfg);
                }
                Ok(WireMsg::Chunk { id, genes })
            }
            "scores" => {
                let id = v.get("id")?.as_u64()?;
                let mut scores = Vec::new();
                for s in v.get("scores")?.as_arr()? {
                    let bits = s.as_u64()?;
                    eyre::ensure!(bits <= u32::MAX as u64, "score bits {bits} exceed u32");
                    scores.push(f32::from_bits(bits as u32));
                }
                Ok(WireMsg::Scores { id, scores })
            }
            "error" => Ok(WireMsg::Error {
                id: v.get("id")?.as_u64()?,
                message: v.get("message")?.as_str()?.to_string(),
            }),
            "stats_req" => Ok(WireMsg::StatsReq { id: v.get("id")?.as_u64()? }),
            "stats" => Ok(WireMsg::Stats {
                id: v.get("id")?.as_u64()?,
                completed: v.get("completed")?.as_u64()?,
                busy_us: v.get("busy_us")?.as_u64()?,
                conns: v.get("conns")?.as_u64()?,
            }),
            "score_req" => {
                let id = v.get("id")?.as_u64()?;
                let mut genes = Vec::new();
                for g in v.get("genes")?.as_arr()? {
                    let g = g.as_u64()?;
                    eyre::ensure!(g <= u16::MAX as u64, "gene {g} exceeds u16");
                    genes.push(g as u16);
                }
                Ok(WireMsg::ScoreReq { id, genes })
            }
            "score" => {
                let id = v.get("id")?.as_u64()?;
                let bits = v.get("score")?.as_u64()?;
                eyre::ensure!(bits <= u32::MAX as u64, "score bits {bits} exceed u32");
                Ok(WireMsg::Score { id, score: f32::from_bits(bits as u32) })
            }
            "serve_stats_req" => Ok(WireMsg::ServeStatsReq { id: v.get("id")?.as_u64()? }),
            "serve_stats" => Ok(WireMsg::ServeStats {
                id: v.get("id")?.as_u64()?,
                requests: v.get("requests")?.as_u64()?,
                rejected: v.get("rejected")?.as_u64()?,
                dispatches: v.get("dispatches")?.as_u64()?,
                full: v.get("full")?.as_u64()?,
                deadline: v.get("deadline")?.as_u64()?,
                lanes: v.get("lanes")?.as_u64()?,
                batched: v.get("batched")?.as_u64()?,
                wait_us: v.get("wait_us")?.as_u64()?,
                depth_sum: v.get("depth_sum")?.as_u64()?,
                depth_max: v.get("depth_max")?.as_u64()?,
            }),
            other => eyre::bail!("unknown wire op `{other}`"),
        }
    }
}

/// Encode a message into one complete frame (header + payload).
pub fn encode_frame(msg: &WireMsg) -> Vec<u8> {
    let payload = msg.to_value().render().into_bytes();
    debug_assert!(payload.len() <= MAX_FRAME_LEN);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&WIRE_MAGIC);
    out.push(WIRE_VERSION);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Decode exactly one frame from a byte slice (the whole slice must be one
/// frame — trailing bytes are an error).  Never panics on malformed input.
pub fn decode_frame(bytes: &[u8]) -> Result<WireMsg> {
    let mut cursor = std::io::Cursor::new(bytes);
    let msg = read_frame(&mut cursor)?
        .ok_or_else(|| eyre::anyhow!("empty input, expected a frame"))?;
    eyre::ensure!(
        cursor.position() as usize == bytes.len(),
        "trailing bytes after frame ({} of {})",
        cursor.position(),
        bytes.len()
    );
    Ok(msg)
}

/// Write one frame to a stream.
pub fn write_frame<W: Write>(w: &mut W, msg: &WireMsg) -> std::io::Result<()> {
    w.write_all(&encode_frame(msg))?;
    w.flush()
}

/// Read one frame from a stream.  Returns `Ok(None)` on clean EOF at a
/// frame boundary (the peer closed the connection); mid-frame EOF,
/// bad magic/version, oversized lengths and malformed payloads are errors.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<WireMsg>> {
    let mut magic = [0u8; 4];
    match r.read(&mut magic)? {
        0 => return Ok(None),
        n => r.read_exact(&mut magic[n..])?,
    }
    eyre::ensure!(
        magic == WIRE_MAGIC,
        "bad frame magic {:02x?} (expected {:02x?})",
        magic,
        WIRE_MAGIC
    );
    let mut version = [0u8; 1];
    r.read_exact(&mut version)?;
    eyre::ensure!(
        version[0] == WIRE_VERSION,
        "wire version {} unsupported (speaking {})",
        version[0],
        WIRE_VERSION
    );
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    eyre::ensure!(len <= MAX_FRAME_LEN, "frame length {len} exceeds {MAX_FRAME_LEN}");
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let text = std::str::from_utf8(&payload)
        .map_err(|_| eyre::anyhow!("frame payload is not UTF-8"))?;
    let value = Value::parse(text)?;
    Ok(Some(WireMsg::from_value(&value)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_ops() {
        let msgs = [
            WireMsg::Hello { n_layers: 28 },
            WireMsg::Hello { n_layers: 0 },
            WireMsg::Chunk { id: 0, genes: vec![] },
            WireMsg::Chunk { id: 7, genes: vec![vec![2, 3, 4], vec![0x0104, 2]] },
            WireMsg::Scores { id: 7, scores: vec![0.5, -1.25e-3, f32::NAN] },
            WireMsg::Error { id: 9, message: "bank has 28 layers, got 3".into() },
            WireMsg::StatsReq { id: 11 },
            WireMsg::Stats { id: 11, completed: 420, busy_us: 1_234_567, conns: 3 },
            WireMsg::ScoreReq { id: 13, genes: vec![2, 3, 0x0104] },
            WireMsg::ScoreReq { id: 14, genes: vec![] },
            WireMsg::Score { id: 13, score: -1.25e-3 },
            WireMsg::ServeStatsReq { id: 15 },
            WireMsg::ServeStats {
                id: 15,
                requests: 100,
                rejected: 2,
                dispatches: 17,
                full: 11,
                deadline: 5,
                lanes: 8,
                batched: 97,
                wait_us: 84_211,
                depth_sum: 120,
                depth_max: 19,
            },
        ];
        for m in msgs {
            let bytes = encode_frame(&m);
            let back = decode_frame(&bytes).unwrap();
            match (&m, &back) {
                // NaN != NaN under PartialEq; compare scores bitwise
                (WireMsg::Scores { id: a, scores: sa }, WireMsg::Scores { id: b, scores: sb }) => {
                    assert_eq!(a, b);
                    let ba: Vec<u32> = sa.iter().map(|s| s.to_bits()).collect();
                    let bb: Vec<u32> = sb.iter().map(|s| s.to_bits()).collect();
                    assert_eq!(ba, bb);
                }
                _ => assert_eq!(m, back),
            }
        }
    }

    #[test]
    fn stream_carries_multiple_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &WireMsg::Hello { n_layers: 4 }).unwrap();
        write_frame(&mut buf, &WireMsg::Chunk { id: 1, genes: vec![vec![2]] }).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cursor).unwrap(), Some(WireMsg::Hello { n_layers: 4 }));
        assert_eq!(
            read_frame(&mut cursor).unwrap(),
            Some(WireMsg::Chunk { id: 1, genes: vec![vec![2]] })
        );
        assert_eq!(read_frame(&mut cursor).unwrap(), None, "clean EOF");
    }

    #[test]
    fn malformed_frames_error_cleanly() {
        // truncated header
        assert!(decode_frame(b"AM").is_err());
        // bad magic
        assert!(decode_frame(b"XXXX\x01\x02\x00\x00\x00{}").is_err());
        // unsupported version
        assert!(decode_frame(b"AMQW\x63\x02\x00\x00\x00{}").is_err());
        // truncated payload (length says 100, 2 bytes present)
        assert!(decode_frame(b"AMQW\x01\x64\x00\x00\x00{}").is_err());
        // garbage JSON payload
        assert!(decode_frame(b"AMQW\x01\x03\x00\x00\x00{,}").is_err());
        // valid JSON, unknown op
        let bad = {
            let mut f = Vec::new();
            let payload = br#"{"op":"nope"}"#;
            f.extend_from_slice(b"AMQW\x01");
            f.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            f.extend_from_slice(payload);
            f
        };
        assert!(decode_frame(&bad).is_err());
        // valid JSON, missing fields
        let bad = {
            let mut f = Vec::new();
            let payload = br#"{"op":"chunk"}"#;
            f.extend_from_slice(b"AMQW\x01");
            f.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            f.extend_from_slice(payload);
            f
        };
        assert!(decode_frame(&bad).is_err());
        // stats frame missing its counters
        let bad = {
            let mut f = Vec::new();
            let payload = br#"{"id":3,"op":"stats"}"#;
            f.extend_from_slice(b"AMQW\x01");
            f.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            f.extend_from_slice(payload);
            f
        };
        assert!(decode_frame(&bad).is_err());
    }

    #[test]
    fn oversized_length_rejected_without_allocation() {
        let mut f = Vec::new();
        f.extend_from_slice(b"AMQW\x01");
        f.extend_from_slice(&(u32::MAX).to_le_bytes());
        f.extend_from_slice(b"{}");
        assert!(decode_frame(&f).is_err());
    }

    #[test]
    fn frame_layout_bytes_are_pinned() {
        // Cross-version guard: these exact bytes are the protocol.  If this
        // test fails, WIRE_VERSION must be bumped and both ends updated.
        let frame = encode_frame(&WireMsg::Chunk { id: 7, genes: vec![vec![2, 3], vec![4, 2]] });
        let payload = br#"{"genes":[[2,3],[4,2]],"id":7,"op":"chunk"}"#;
        let mut expect = Vec::new();
        expect.extend_from_slice(&[0x41, 0x4D, 0x51, 0x57, 0x01]); // "AMQW" v1
        expect.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        expect.extend_from_slice(payload);
        assert_eq!(frame, expect);

        let frame = encode_frame(&WireMsg::Scores { id: 7, scores: vec![1.0, -2.5] });
        // 1.0f32 = 0x3F800000 = 1065353216; -2.5f32 = 0xC0200000 = 3222274048
        let payload = br#"{"id":7,"op":"scores","scores":[1065353216,3222274048]}"#;
        let mut expect = Vec::new();
        expect.extend_from_slice(&[0x41, 0x4D, 0x51, 0x57, 0x01]);
        expect.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        expect.extend_from_slice(payload);
        assert_eq!(frame, expect);

        // stats ops: new in the same version (old servers reject them as an
        // unknown op instead of misparsing — additive, no layout change)
        let frame = encode_frame(&WireMsg::StatsReq { id: 3 });
        let payload = br#"{"id":3,"op":"stats_req"}"#;
        let mut expect = Vec::new();
        expect.extend_from_slice(&[0x41, 0x4D, 0x51, 0x57, 0x01]);
        expect.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        expect.extend_from_slice(payload);
        assert_eq!(frame, expect);

        let frame = encode_frame(&WireMsg::Stats {
            id: 3,
            completed: 42,
            busy_us: 1_500_000,
            conns: 2,
        });
        let payload =
            br#"{"busy_us":1500000,"completed":42,"conns":2,"id":3,"op":"stats"}"#;
        let mut expect = Vec::new();
        expect.extend_from_slice(&[0x41, 0x4D, 0x51, 0x57, 0x01]);
        expect.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        expect.extend_from_slice(payload);
        assert_eq!(frame, expect);

        // serve ops: additive in the same version, same compatibility rule.
        let frame = encode_frame(&WireMsg::ScoreReq { id: 5, genes: vec![2, 3, 4] });
        let payload = br#"{"genes":[2,3,4],"id":5,"op":"score_req"}"#;
        let mut expect = Vec::new();
        expect.extend_from_slice(&[0x41, 0x4D, 0x51, 0x57, 0x01]);
        expect.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        expect.extend_from_slice(payload);
        assert_eq!(frame, expect);

        let frame = encode_frame(&WireMsg::Score { id: 5, score: 1.0 });
        // 1.0f32 = 0x3F800000 = 1065353216
        let payload = br#"{"id":5,"op":"score","score":1065353216}"#;
        let mut expect = Vec::new();
        expect.extend_from_slice(&[0x41, 0x4D, 0x51, 0x57, 0x01]);
        expect.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        expect.extend_from_slice(payload);
        assert_eq!(frame, expect);

        let frame = encode_frame(&WireMsg::ServeStatsReq { id: 9 });
        let payload = br#"{"id":9,"op":"serve_stats_req"}"#;
        let mut expect = Vec::new();
        expect.extend_from_slice(&[0x41, 0x4D, 0x51, 0x57, 0x01]);
        expect.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        expect.extend_from_slice(payload);
        assert_eq!(frame, expect);

        let frame = encode_frame(&WireMsg::ServeStats {
            id: 9,
            requests: 100,
            rejected: 2,
            dispatches: 17,
            full: 11,
            deadline: 5,
            lanes: 8,
            batched: 97,
            wait_us: 84211,
            depth_sum: 120,
            depth_max: 19,
        });
        let payload = br#"{"batched":97,"deadline":5,"depth_max":19,"depth_sum":120,"dispatches":17,"full":11,"id":9,"lanes":8,"op":"serve_stats","rejected":2,"requests":100,"wait_us":84211}"#;
        let mut expect = Vec::new();
        expect.extend_from_slice(&[0x41, 0x4D, 0x51, 0x57, 0x01]);
        expect.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        expect.extend_from_slice(payload);
        assert_eq!(frame, expect);
    }

    #[test]
    fn mutated_golden_frames_never_panic_and_reject_cleanly() {
        // Seeded byte-mutation fuzz over one golden frame per op: every
        // exhaustive single-bit flip plus a seeded stream of overwrites,
        // truncations, insertions and multi-bit flips must either surface
        // a clean `Err` or decode to a message that is semantically valid
        // — meaning it re-encodes to a stable frame that decodes back to
        // itself.  Decoding must never panic and never misparse.
        use crate::util::Rng;
        let golden: Vec<Vec<u8>> = vec![
            encode_frame(&WireMsg::Hello { n_layers: 28 }),
            encode_frame(&WireMsg::Chunk { id: 7, genes: vec![vec![2, 3, 4], vec![0x0104, 2]] }),
            encode_frame(&WireMsg::Scores { id: 7, scores: vec![0.5, -1.25e-3, 1.0] }),
            encode_frame(&WireMsg::Error { id: 9, message: "bank has 28 layers, got 3".into() }),
            encode_frame(&WireMsg::StatsReq { id: 11 }),
            encode_frame(&WireMsg::Stats { id: 11, completed: 420, busy_us: 1_234_567, conns: 3 }),
            encode_frame(&WireMsg::ScoreReq { id: 13, genes: vec![2, 3, 0x0104] }),
            encode_frame(&WireMsg::Score { id: 13, score: -1.25e-3 }),
            encode_frame(&WireMsg::ServeStatsReq { id: 15 }),
            encode_frame(&WireMsg::ServeStats {
                id: 15,
                requests: 100,
                rejected: 2,
                dispatches: 17,
                full: 11,
                deadline: 5,
                lanes: 8,
                batched: 97,
                wait_us: 84_211,
                depth_sum: 120,
                depth_max: 19,
            }),
        ];
        let check = |bytes: &[u8]| {
            if let Ok(msg) = decode_frame(bytes) {
                // A mutation that still decodes must be a *valid* frame
                // (e.g. a flipped digit inside an id): re-encoding it must
                // produce a stable, self-consistent byte layout.
                let re = encode_frame(&msg);
                match decode_frame(&re) {
                    Ok(back) => assert_eq!(
                        encode_frame(&back),
                        re,
                        "re-encode of a mutated-but-accepted frame is unstable"
                    ),
                    Err(e) => panic!("accepted mutation failed to round trip: {e}"),
                }
            }
        };
        let mut rng = Rng::new(0xF0_553D);
        for frame in &golden {
            // exhaustive single-bit flips over the whole frame
            for pos in 0..frame.len() {
                for bit in 0..8 {
                    let mut m = frame.clone();
                    m[pos] ^= 1 << bit;
                    check(&m);
                }
            }
            // seeded stream of heavier mutations
            for _ in 0..200 {
                let mut m = frame.clone();
                match rng.below(4) {
                    0 => {
                        let i = rng.below(m.len());
                        m[i] = rng.below(256) as u8;
                    }
                    1 => {
                        let cut = rng.below(m.len() + 1);
                        m.truncate(cut);
                    }
                    2 => {
                        let i = rng.below(m.len());
                        m.insert(i, rng.below(256) as u8);
                    }
                    _ => {
                        for _ in 0..1 + rng.below(4) {
                            let i = rng.below(m.len());
                            m[i] ^= 1 << rng.below(8);
                        }
                    }
                }
                check(&m);
            }
        }
    }

    #[test]
    fn scores_cross_bit_exactly() {
        let patterns: Vec<f32> = [
            0x0000_0000u32, // +0.0
            0x8000_0000,    // -0.0
            0x7F80_0000,    // +inf
            0xFF80_0000,    // -inf
            0x7FC0_0001,    // NaN with payload
            0x0000_0001,    // smallest subnormal
            0x3F80_0000,    // 1.0
        ]
        .iter()
        .map(|&b| f32::from_bits(b))
        .collect();
        let bytes = encode_frame(&WireMsg::Scores { id: 1, scores: patterns.clone() });
        match decode_frame(&bytes).unwrap() {
            WireMsg::Scores { scores, .. } => {
                for (a, b) in patterns.iter().zip(&scores) {
                    assert_eq!(a.to_bits(), b.to_bits(), "score bits changed on the wire");
                }
            }
            other => panic!("expected scores, got {other:?}"),
        }
    }
}

//! Small dense linear algebra: Cholesky (f64 internally for stability) and a
//! rank-1 power iteration.  Sizes here are at most d_ff x d_ff (256x256), so
//! simple O(n^3) routines are plenty.

use super::Mat;

/// Cholesky factorization of a symmetric positive-definite matrix (f64).
/// Returns the lower factor L with `A = L L^T`, or None if not SPD.
pub fn cholesky_f64(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve `A x = b` for SPD `A` via Cholesky, with automatic diagonal damping
/// escalation if the factorization fails (predictor ridge solves).
pub fn cholesky_solve(a: &Mat, b: &[f32]) -> Option<Vec<f32>> {
    let n = a.rows;
    assert_eq!(a.rows, a.cols);
    assert_eq!(b.len(), n);
    let mut a64: Vec<f64> = a.data.iter().map(|&v| v as f64).collect();
    let mean_diag: f64 =
        (0..n).map(|i| a64[i * n + i]).sum::<f64>() / n.max(1) as f64;
    let mut damp = 0.0f64;
    for _ in 0..6 {
        let mut try_a = a64.clone();
        if damp > 0.0 {
            for i in 0..n {
                try_a[i * n + i] += damp;
            }
        }
        if let Some(l) = cholesky_f64(&try_a, n) {
            // forward: L y = b
            let mut y = vec![0.0f64; n];
            for i in 0..n {
                let mut s = b[i] as f64;
                for k in 0..i {
                    s -= l[i * n + k] * y[k];
                }
                y[i] = s / l[i * n + i];
            }
            // backward: L^T x = y
            let mut x = vec![0.0f64; n];
            for i in (0..n).rev() {
                let mut s = y[i];
                for k in i + 1..n {
                    s -= l[k * n + i] * x[k];
                }
                x[i] = s / l[i * n + i];
            }
            return Some(x.iter().map(|&v| v as f32).collect());
        }
        damp = if damp == 0.0 { mean_diag.abs() * 1e-8 + 1e-12 } else { damp * 100.0 };
        a64 = a.data.iter().map(|&v| v as f64).collect();
    }
    None
}

/// Upper Cholesky factor of the *inverse* of SPD `H` — the matrix GPTQ
/// iterates on (`torch.linalg.cholesky(H.inverse(), upper=True)`): returns
/// upper-triangular `U` with `H^{-1} = U^T U`; the GPTQ recurrence consumes
/// its rows `U[j, j..]`.  `damp_frac * mean(diag(H))` is added to the
/// diagonal first (escalating automatically if factorization still fails).
pub fn cholesky_inverse_upper(h: &Mat, damp_frac: f64) -> Option<Mat> {
    let n = h.rows;
    let a: Vec<f64> = h.data.iter().map(|&v| v as f64).collect();
    let mean_diag: f64 = (0..n).map(|i| a[i * n + i]).sum::<f64>() / n as f64;
    let mut damp = damp_frac * mean_diag;
    for _ in 0..8 {
        let mut ad = a.clone();
        for i in 0..n {
            ad[i * n + i] += damp;
        }
        if let Some(l) = cholesky_f64(&ad, n) {
            // Invert L (lower-triangular) -> Linv.
            let mut linv = vec![0.0f64; n * n];
            for i in 0..n {
                linv[i * n + i] = 1.0 / l[i * n + i];
                for j in 0..i {
                    let mut s = 0.0;
                    for k in j..i {
                        s -= l[i * n + k] * linv[k * n + j];
                    }
                    linv[i * n + j] = s / l[i * n + i];
                }
            }
            // Hinv = Linv^T Linv  (upper x lower product, symmetric).
            let mut hinv = vec![0.0f64; n * n];
            for i in 0..n {
                for j in i..n {
                    let mut s = 0.0;
                    for k in j..n {
                        // (Linv^T)[i,k] = Linv[k,i]
                        s += linv[k * n + i] * linv[k * n + j];
                    }
                    hinv[i * n + j] = s;
                    hinv[j * n + i] = s;
                }
            }
            // Upper factor: Hinv = L' L'^T  =>  U = L'^T (Hinv = U^T U).
            if let Some(lp) = cholesky_f64(&hinv, n) {
                let mut out = Mat::zeros(n, n);
                for i in 0..n {
                    for j in 0..=i {
                        out[(j, i)] = lp[i * n + j] as f32;
                    }
                }
                return Some(out);
            }
        }
        damp = if damp == 0.0 { 1e-8 } else { damp * 10.0 };
    }
    None
}

/// Rank-1 approximation of a non-negative matrix via power iteration:
/// returns (u, sigma, v) with `A ≈ sigma * u v^T`, |u|=|v|=1.
pub fn power_iteration_rank1(a: &Mat, iters: usize) -> (Vec<f32>, f32, Vec<f32>) {
    let (m, n) = (a.rows, a.cols);
    // varied init so start vectors are never orthogonal to the top
    // singular vector (a uniform start is degenerate for signed inputs)
    let mut v: Vec<f32> = (0..n).map(|j| 1.0 + 0.37 * ((j as f32) * 0.91).sin()).collect();
    let vn0 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    v.iter_mut().for_each(|x| *x /= vn0);
    let mut u = vec![0.0f32; m];
    for _ in 0..iters.max(1) {
        // u = A v
        for i in 0..m {
            let row = a.row(i);
            u[i] = row.iter().zip(&v).map(|(a, b)| a * b).sum();
        }
        let un = u.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-20);
        u.iter_mut().for_each(|x| *x /= un);
        // v = A^T u
        for j in 0..n {
            let mut s = 0.0;
            for i in 0..m {
                s += a[(i, j)] * u[i];
            }
            v[j] = s;
        }
        let vn = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-20);
        v.iter_mut().for_each(|x| *x /= vn);
    }
    // sigma = u^T A v
    let mut sigma = 0.0f32;
    for i in 0..m {
        let row = a.row(i);
        let av: f32 = row.iter().zip(&v).map(|(a, b)| a * b).sum();
        sigma += u[i] * av;
    }
    (u, sigma, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_solve_spd() {
        // A = [[4,1],[1,3]], b = [1,2] -> x = [1/11, 7/11]
        let a = Mat::from_vec(2, 2, vec![4., 1., 1., 3.]);
        let x = cholesky_solve(&a, &[1., 2.]).unwrap();
        assert!((x[0] - 1.0 / 11.0).abs() < 1e-5);
        assert!((x[1] - 7.0 / 11.0).abs() < 1e-5);
    }

    #[test]
    fn cholesky_solve_damps_semidefinite() {
        let a = Mat::from_vec(2, 2, vec![1., 1., 1., 1.]); // singular
        let x = cholesky_solve(&a, &[2., 2.]).unwrap();
        // damped solution still approximately satisfies A x = b
        let r0 = x[0] + x[1];
        assert!((r0 - 2.0).abs() < 1e-2, "{r0}");
    }

    #[test]
    fn cholesky_inverse_upper_reconstructs() {
        // H SPD; check U^T U = H^{-1} (with tiny damping tolerance).
        let h = Mat::from_vec(3, 3, vec![4., 1., 0., 1., 3., 0.5, 0., 0.5, 2.]);
        let u = cholesky_inverse_upper(&h, 0.0).unwrap();
        let hinv_rec = u.transpose().matmul(&u);
        let ident = hinv_rec.matmul(&h);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((ident[(i, j)] - want).abs() < 1e-3,
                        "ident[{i},{j}]={}", ident[(i, j)]);
            }
        }
        // upper-triangular
        for i in 1..3 {
            for j in 0..i {
                assert_eq!(u[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn rank1_exact_on_rank1_input() {
        let u0 = [1.0f32, 2.0, 3.0];
        let v0 = [0.5f32, -0.5];
        let mut a = Mat::zeros(3, 2);
        for i in 0..3 {
            for j in 0..2 {
                a[(i, j)] = u0[i] * v0[j];
            }
        }
        let (u, s, v) = power_iteration_rank1(&a, 30);
        let mut rec = Mat::zeros(3, 2);
        for i in 0..3 {
            for j in 0..2 {
                rec[(i, j)] = s * u[i] * v[j];
            }
        }
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}

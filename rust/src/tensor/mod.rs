//! Minimal dense-matrix substrate used by the quantizers and predictors.
//!
//! The heavy model math runs inside the AOT XLA executables; this module only
//! needs to be good enough for the *coordinator-side* numerics: Hessian
//! manipulation (GPTQ/AWQ), RBF/MLP predictor fitting, BitStack SVD blocks.

mod linalg;

pub use linalg::{cholesky_f64, cholesky_solve, cholesky_inverse_upper, power_iteration_rank1};

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// `self @ other` — blocked i-k-j loop (cache-friendly for our sizes).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn scale_in_place(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

/// Median (copies + sorts; fine at coordinator scale).
pub fn median(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let i = Mat::eye(3);
        assert_eq!(a.matmul(&i).data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        assert_eq!(a.matmul(&b).data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3., 1., 2.]), 2.0);
        assert_eq!(median(&[4., 1., 3., 2.]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }
}

//! Tiny benchmark harness (criterion is unavailable in the offline build):
//! warms up, runs adaptively until a time budget, reports median / mean /
//! min over iterations.  Used by the `cargo bench` targets.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.mean),
            self.iters
        );
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Run `f` repeatedly for ~`budget` (after 1 warmup call), max 1000 iters.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    f(); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget && times.len() < 1000 {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let n = times.len();
    let mean = times.iter().sum::<Duration>() / n as u32;
    BenchResult {
        name: name.to_string(),
        iters: n,
        median: times[n / 2],
        mean,
        min: times[0],
    }
}

pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>10} {:>12} {:>12}",
        "benchmark", "min", "median", "mean"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", Duration::from_millis(20), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 10);
        assert!(r.min <= r.median && r.median <= r.mean * 10);
    }

    #[test]
    fn format_durations() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}

//! Small shared utilities: a seedable PRNG (no external rand crate in the
//! offline build) and a tiny benchmark harness.

pub mod bench;

/// SplitMix64 — tiny, fast, statistically solid for coordinator use
/// (NSGA-II operators, sampling, MLP init).  Deterministic per seed.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }

    /// Derive an independent stream (for per-seed experiment runs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_all_values() {
        let mut r = Rng::new(2);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[r.below(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(10, 4);
        assert_eq!(s.len(), 4);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 4);
    }
}

//! Microbatched-scoring integration tests (no artifacts required): the
//! dedup + `--score-batch` dispatch pipeline must change *dispatch counts
//! only* — the search archive stays byte-identical across every
//! `(workers, score-batch)` combination, and the shared device bank's
//! bytes are counted once no matter how many shards reference it.

use amq::coordinator::{
    run_search, Archive, BankShareStats, Config, ConfigEvaluator, PooledEvaluator, ProxyBank,
    SearchParams, SearchSpace,
};
use amq::quant::{MethodId, Quantizer};
use amq::tensor::Mat;
use amq::util::Rng;
use std::sync::Arc;

fn toy_space(n: usize) -> SearchSpace {
    SearchSpace {
        choices: vec![vec![2, 3, 4]; n],
        params: vec![128 * 128; n],
        groups: vec![128; n],
        group_size: 128,
    }
}

/// Deterministic synthetic "true evaluation", seeded purely from the
/// payload (the pool determinism contract).
fn synth_jsd(cfg: &Config) -> f32 {
    let mut seed = 0x9E37_79B9_7F4A_7C15u64;
    for &g in cfg {
        seed = seed.wrapping_mul(0x1000_0000_01B3).wrapping_add(g as u64);
    }
    let mut rng = Rng::new(seed);
    let base: f32 = cfg
        .iter()
        .enumerate()
        .map(|(i, &g)| {
            let w = if i % 5 == 0 { 1.0 } else { 0.04 };
            w * ((4 - g) as f32).powi(2)
        })
        .sum();
    base + rng.f32() * 1e-4
}

fn pooled(workers: usize, score_batch: usize) -> PooledEvaluator {
    PooledEvaluator::spawn(workers, |_shard| {
        |cfg: Config| -> amq::Result<f32> { Ok(synth_jsd(&cfg)) }
    })
    .with_score_batch(score_batch)
}

/// FNV-1a over the archive's full content — the reproducibility fingerprint.
fn archive_hash(archive: &Archive) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x1000_0000_01B3);
    };
    for s in &archive.samples {
        for &g in &s.config {
            mix(g as u64);
        }
        mix(s.jsd.to_bits() as u64);
        mix(s.avg_bits.to_bits());
    }
    h
}

#[test]
fn archive_identical_across_workers_and_score_batch() {
    let space = toy_space(14);
    let mut params = SearchParams::smoke();
    params.seed = 29;

    // sequential trait-default baseline
    struct Seq(usize);
    impl ConfigEvaluator for Seq {
        fn eval_jsd(&mut self, config: &Config) -> amq::Result<f32> {
            self.0 += 1;
            Ok(synth_jsd(config))
        }
        fn count(&self) -> usize {
            self.0
        }
    }
    let baseline = run_search(&space, &mut Seq(0), &params).unwrap();
    let expect = archive_hash(&baseline.archive);

    for workers in [1usize, 4] {
        for score_batch in [1usize, 8] {
            let mut ev = pooled(workers, score_batch);
            let res = run_search(&space, &mut ev, &params).unwrap();
            assert_eq!(
                archive_hash(&res.archive),
                expect,
                "archive diverged at workers={workers} score_batch={score_batch}"
            );
            assert_eq!(
                res.true_evals, baseline.true_evals,
                "eval count diverged at workers={workers} score_batch={score_batch}"
            );
            assert_eq!(res.predictor_queries, baseline.predictor_queries);
        }
    }
}

#[test]
fn microbatching_cuts_dispatches_without_changing_results() {
    let space = toy_space(10);
    let mut params = SearchParams::smoke();
    params.seed = 3;

    let mut k1 = pooled(2, 1);
    let a = run_search(&space, &mut k1, &params).unwrap();
    let mut k8 = pooled(2, 8);
    let b = run_search(&space, &mut k8, &params).unwrap();
    assert_eq!(archive_hash(&a.archive), archive_hash(&b.archive));

    let (s1, s8) = (k1.batch_stats().unwrap(), k8.batch_stats().unwrap());
    assert_eq!(s1.evaluated, s8.evaluated, "same configs must reach the scorer");
    assert_eq!(s1.evaluated as usize, a.true_evals);
    assert_eq!(s1.dispatches, s1.evaluated, "k=1 is one dispatch per config");
    assert!(
        s8.dispatches < s8.evaluated,
        "k=8 must pack chunks: {} dispatches for {} evals",
        s8.dispatches,
        s8.evaluated
    );
    // the acceptance direction: requested-per-dispatch must beat the
    // k=1 pipeline (which already banks the dedup savings alone), and no
    // chunk may carry more than k configs
    assert!(
        s8.dispatch_reduction() > s1.dispatch_reduction(),
        "batching added nothing: k=8 {:.3} vs k=1 {:.3}",
        s8.dispatch_reduction(),
        s1.dispatch_reduction()
    );
    assert!(s8.dispatches >= (s8.evaluated as usize).div_ceil(8) as u64);
    assert!(
        s1.dispatch_reduction() >= 1.0 / (1.0 - s1.dedup_fraction()).max(1e-9) * 0.999,
        "dedup savings not realized: {:.3} for dedup fraction {:.3}",
        s1.dispatch_reduction(),
        s1.dedup_fraction()
    );
}

#[test]
fn search_reuses_cache_across_generations() {
    // the dedup counters must actually see cross-batch traffic: replaying
    // the same candidate set twice costs zero extra dispatches
    let mut ev = pooled(2, 4);
    let configs: Vec<Config> = (0..12)
        .map(|i| (0..6).map(|j| [2u16, 3, 4][(i + j) % 3]).collect())
        .collect();
    let first = ev.eval_jsd_batch(&configs).unwrap();
    let d0 = ev.batch_stats().unwrap().dispatches;
    let second = ev.eval_jsd_batch(&configs).unwrap();
    let s = ev.batch_stats().unwrap();
    assert_eq!(first, second);
    assert_eq!(s.dispatches, d0, "cached batch must not dispatch");
    assert_eq!(s.cache_hits, configs.len() as u64);
}

#[test]
fn shared_device_bank_bytes_count_once() {
    // a real (host-side) bank: 2 layers x 3 bits of quantized weights
    let quantizer = MethodId::Hqq.build();
    let pieces = vec![(0..2u64)
        .map(|i| {
            let mut rng = Rng::new(1 + i);
            let mut w = Mat::zeros(8, 128);
            for v in &mut w.data {
                *v = rng.normal() * 0.1;
            }
            vec![
                quantizer.quantize(&w, 2, 128, None),
                quantizer.quantize(&w, 3, 128, None),
                quantizer.quantize(&w, 4, 128, None),
            ]
        })
        .collect()];
    let bank =
        Arc::new(ProxyBank::from_parts(vec![MethodId::Hqq], vec![2, 3, 4], pieces).unwrap());
    let bytes = bank.memory_bytes();
    assert!(bytes > 0);

    // 4 pool shards all referencing the one Arc'd bank
    let shards: Vec<Arc<ProxyBank>> = (0..4).map(|_| bank.clone()).collect();
    let share = BankShareStats::from_shard_banks(&shards);
    assert_eq!(share.shards, 4);
    assert_eq!(share.resident_bytes, bytes, "shared bank must be counted once");
    assert_eq!(share.referenced_bytes, 4 * bytes);
}
